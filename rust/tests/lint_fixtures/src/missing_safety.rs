//! Fixture: `unsafe` without `// SAFETY:` justifications.
//! Never compiled — scanned by `tests/integration_lint.rs` only.

pub fn first_byte(v: &[u8]) -> u8 {
    // A comment that is not a SAFETY justification.
    // VIOLATION(safety-comment) on the next line (line 7).
    unsafe { *v.get_unchecked(0) }
}

pub struct Wrapper(*const u8);

// VIOLATION(safety-comment) on the next line (line 13).
unsafe impl Send for Wrapper {}

// SAFETY: the pointer is never dereferenced through a shared reference;
// NOT a violation (justified by this comment block).
unsafe impl Sync for Wrapper {}

pub fn justified(v: &[u8]) -> u8 {
    // SAFETY: caller guarantees `v` is non-empty.
    unsafe { *v.get_unchecked(0) }
}
