//! Spark's two restricted shared-variable kinds (§2.2 of the paper):
//! read-only **broadcast variables** and add-only **accumulators**.
//!
//! EclatV2+ broadcast the frequent-item trie to every task; EclatV1/V2
//! accumulate the triangular 2-itemset count matrix; EclatV3 accumulates
//! the vertical `item → tidset` hashmap. In this single-process engine a
//! broadcast is an `Arc` (zero-copy, which is exactly what Spark's
//! torrent broadcast approximates within one executor), and an accumulator
//! is a mutex-guarded value with a user-supplied associative+commutative
//! merge. Tasks are expected to merge *per-partition* local values, not
//! per-record, mirroring efficient Spark accumulator usage.

use std::sync::{Arc, Mutex};

use crate::sync::global::lock_unpoisoned;

/// Read-only value shared with every task.
#[derive(Debug)]
pub struct Broadcast<T: Send + Sync + 'static> {
    value: Arc<T>,
}

impl<T: Send + Sync + 'static> Broadcast<T> {
    /// Wrap a value for broadcast.
    pub fn new(value: T) -> Self {
        Broadcast { value: Arc::new(value) }
    }

    /// Access the broadcast value.
    pub fn value(&self) -> &T {
        &self.value
    }
}

impl<T: Send + Sync + 'static> Clone for Broadcast<T> {
    fn clone(&self) -> Self {
        Broadcast { value: Arc::clone(&self.value) }
    }
}

/// Add-only shared variable. Workers call [`Accumulator::add`] with local
/// contributions merged by an associative, commutative `merge`; only the
/// driver should read [`Accumulator::value`] (after the job completes),
/// matching Spark's accumulator contract.
pub struct Accumulator<T: Send + 'static> {
    state: Arc<Mutex<T>>,
    merge: Arc<dyn Fn(&mut T, T) + Send + Sync>,
}

impl<T: Send + 'static> Clone for Accumulator<T> {
    fn clone(&self) -> Self {
        Accumulator { state: Arc::clone(&self.state), merge: Arc::clone(&self.merge) }
    }
}

impl<T: Send + 'static> Accumulator<T> {
    /// Create an accumulator with initial (zero) value and merge operation.
    pub fn new(zero: T, merge: impl Fn(&mut T, T) + Send + Sync + 'static) -> Self {
        Accumulator { state: Arc::new(Mutex::new(zero)), merge: Arc::new(merge) }
    }

    /// Merge a local contribution into the shared state.
    ///
    /// Poison-tolerant: a user `merge` that panics poisons the mutex,
    /// but that task's failure is already reported through the
    /// scheduler; other tasks keep accumulating. The contribution whose
    /// merge panicked is (partially or wholly) lost — acceptable,
    /// because the scheduler fails the whole job on a panicked task
    /// anyway, so a poisoned accumulator is only ever read on an
    /// already-failed path.
    pub fn add(&self, local: T) {
        let mut guard = lock_unpoisoned(&self.state);
        (self.merge)(&mut guard, local);
    }

    /// Read the accumulated value (driver side, after the job).
    pub fn value(&self) -> T
    where
        T: Clone,
    {
        lock_unpoisoned(&self.state).clone()
    }

    /// Run a closure against the accumulated state without cloning it out
    /// (for large values like the triangular matrix).
    pub fn with_value<R>(&self, f: impl FnOnce(&T) -> R) -> R {
        f(&lock_unpoisoned(&self.state))
    }

    /// Extract the accumulated state, leaving `replacement` behind. Avoids
    /// cloning multi-megabyte matrices on the driver path.
    pub fn take(&self, replacement: T) -> T {
        std::mem::replace(&mut lock_unpoisoned(&self.state), replacement)
    }
}

/// Convenience constructor: a summing counter accumulator.
pub fn counter() -> Accumulator<u64> {
    Accumulator::new(0, |a, b| *a += b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    #[test]
    fn broadcast_shares_one_allocation() {
        let b = Broadcast::new(vec![1, 2, 3]);
        let b2 = b.clone();
        assert_eq!(b.value(), b2.value());
        assert!(std::ptr::eq(b.value(), b2.value()));
    }

    #[test]
    fn counter_accumulates_across_clones() {
        let acc = counter();
        let a2 = acc.clone();
        acc.add(5);
        a2.add(7);
        assert_eq!(acc.value(), 12);
    }

    #[test]
    fn accumulator_threads() {
        let acc = counter();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let acc = acc.clone();
                std::thread::spawn(move || {
                    for _ in 0..1000 {
                        acc.add(1);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(acc.value(), 8000);
    }

    #[test]
    fn hashmap_accumulator_merges() {
        // The EclatV3 pattern: accumulate item -> tid list maps.
        let acc: Accumulator<HashMap<u32, Vec<u32>>> = Accumulator::new(HashMap::new(), |a, b| {
            for (k, mut v) in b {
                a.entry(k).or_default().append(&mut v);
            }
        });
        acc.add(HashMap::from([(1, vec![10]), (2, vec![20])]));
        acc.add(HashMap::from([(1, vec![11])]));
        let v = acc.value();
        let mut ones = v[&1].clone();
        ones.sort_unstable();
        assert_eq!(ones, vec![10, 11]);
        assert_eq!(v[&2], vec![20]);
    }

    #[test]
    fn take_swaps_out_state() {
        let acc = counter();
        acc.add(3);
        assert_eq!(acc.take(0), 3);
        assert_eq!(acc.value(), 0);
    }

    #[test]
    fn poisoned_accumulator_stays_readable() {
        // A merge closure that panics poisons the mutex through the
        // public API; lock_unpoisoned must keep the accumulator usable
        // for every later add/read instead of cascading the panic.
        let acc: Accumulator<u64> = Accumulator::new(0, |a, b| {
            assert!(b != 13, "injected merge panic");
            *a += b;
        });
        acc.add(5);
        let poisoner = acc.clone();
        let res = std::thread::spawn(move || poisoner.add(13)).join();
        assert!(res.is_err(), "merge panic must propagate to the task");
        // State before the panicking merge mutated anything survives.
        assert_eq!(acc.value(), 5);
        acc.add(2);
        assert_eq!(acc.value(), 7);
        assert_eq!(acc.take(0), 7);
    }
}
