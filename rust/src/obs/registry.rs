//! Metrics registry: atomic counters, gauges, and log2 histograms,
//! registered once by static name and recorded lock-free on hot paths.
//!
//! The registration maps are behind a `Mutex`, but registration happens
//! once per call site (cached in a `OnceLock`): steady-state recording is
//! a relaxed `fetch_add`/`fetch_max` on a leaked `'static` cell, with no
//! locks and no allocation. Snapshots ([`snapshot`]) walk the maps and
//! produce a flat [`MetricsSnapshot`] that serializes through
//! [`crate::util::json`] for `BENCH_*.json` rows and CLI digests.
//!
//! The metric cells build on [`crate::sync`], so the relaxed-ordering
//! claims (exact counter totals, monotone gauge high-water marks, exact
//! histogram counts) are model-checked by loom (`tests/loom_models.rs`).
//! The registration maps themselves stay on the std-only
//! [`crate::sync::global`] plane: loom types cannot live in statics, and
//! registration is mutex-serialized bookkeeping, not a lock-free
//! protocol.

use std::collections::BTreeMap;

use crate::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use crate::sync::{fetch_max_i64, fetch_max_u64, global};
use crate::util::json::json_str;

/// Monotonic event counter. `incr` is a single relaxed `fetch_add`.
#[derive(Debug)]
pub struct Counter(AtomicU64);

impl Default for Counter {
    fn default() -> Counter {
        Counter::new()
    }
}

impl Counter {
    /// New counter at zero (const — usable in statics).
    #[cfg(not(loom))]
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    /// New counter at zero. (Non-const under `cfg(loom)`: loom atomics
    /// cannot be const-constructed; models build cells at runtime.)
    #[cfg(loom)]
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    /// Add `n` (relaxed).
    #[inline]
    pub fn incr(&self, n: u64) {
        // ordering: Relaxed — an independent event count: the RMW's
        // atomicity alone makes the total exact (loom-checked in
        // loom_counter_concurrent_increments_exact), and no other
        // memory is published through this cell.
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    /// Current value.
    #[inline]
    pub fn get(&self) -> u64 {
        // ordering: Relaxed — monitoring read; bounded staleness is
        // fine, exactness comes from the RMW increments.
        self.0.load(Ordering::Relaxed)
    }

    /// Reset to zero (between benchmark repetitions).
    pub fn reset(&self) {
        // ordering: Relaxed — reset happens at external sync points
        // (benchmark repetition boundaries), not concurrently with
        // recording that must be kept.
        self.0.store(0, Ordering::Relaxed);
    }
}

/// Instantaneous level (queue depth, lane depth) with a high-water mark.
///
/// `add`/`set` update the level and fold the new level into the
/// high-water mark, both with relaxed atomics.
#[derive(Debug)]
pub struct Gauge {
    value: AtomicI64,
    high_water: AtomicI64,
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

impl Gauge {
    /// New gauge at zero (const — usable in statics).
    #[cfg(not(loom))]
    pub const fn new() -> Gauge {
        Gauge { value: AtomicI64::new(0), high_water: AtomicI64::new(0) }
    }

    /// New gauge at zero. (Non-const under `cfg(loom)`; see
    /// [`Counter::new`].)
    #[cfg(loom)]
    pub fn new() -> Gauge {
        Gauge { value: AtomicI64::new(0), high_water: AtomicI64::new(0) }
    }

    /// Add `delta` (may be negative) and update the high-water mark.
    #[inline]
    pub fn add(&self, delta: i64) {
        // ordering: Relaxed — the RMW return value gives this thread's
        // exact post-add level; no cross-cell ordering is implied.
        let now = self.value.fetch_add(delta, Ordering::Relaxed) + delta;
        // ordering: Relaxed — max-folding is commutative and monotone,
        // so any interleaving yields the true high-water mark
        // (loom-checked in loom_gauge_high_water_is_monotone_max).
        fetch_max_i64(&self.high_water, now, Ordering::Relaxed);
    }

    /// Set the level and update the high-water mark.
    #[inline]
    pub fn set(&self, v: i64) {
        // ordering: Relaxed — last-writer-wins level; `set` races are
        // meaningless for a sampled gauge.
        self.value.store(v, Ordering::Relaxed);
        // ordering: Relaxed — see `add`: max-folding is order-free.
        fetch_max_i64(&self.high_water, v, Ordering::Relaxed);
    }

    /// Current level.
    #[inline]
    pub fn get(&self) -> i64 {
        // ordering: Relaxed — monitoring read.
        self.value.load(Ordering::Relaxed)
    }

    /// Highest level ever observed.
    #[inline]
    pub fn high_water(&self) -> i64 {
        // ordering: Relaxed — monitoring read of a monotone cell.
        self.high_water.load(Ordering::Relaxed)
    }

    /// Reset level and high-water mark to zero.
    pub fn reset(&self) {
        // ordering: Relaxed — external sync point; see `Counter::reset`.
        self.value.store(0, Ordering::Relaxed);
        // ordering: Relaxed — as above.
        self.high_water.store(0, Ordering::Relaxed);
    }
}

/// Number of log2 buckets: bucket 0 holds the value 0, bucket `b >= 1`
/// holds values in `[2^(b-1), 2^b)`, so bucket 64 holds the top half of
/// the `u64` range.
pub const HIST_BUCKETS: usize = 65;

/// Fixed-bucket log2 histogram. Recording is three relaxed `fetch_add`s
/// and one `fetch_max` — no locks, no allocation, exact `count`/`sum`.
#[derive(Debug)]
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
    buckets: [AtomicU64; HIST_BUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// Index of the log2 bucket holding `v`.
#[inline]
fn bucket_of(v: u64) -> usize {
    (64 - v.leading_zeros()) as usize
}

/// Inclusive upper bound of bucket `b` (saturating at `u64::MAX`).
fn bucket_upper(b: usize) -> u64 {
    if b == 0 {
        0
    } else if b >= 64 {
        u64::MAX
    } else {
        (1u64 << b) - 1
    }
}

impl Histogram {
    /// New empty histogram (const — usable in statics).
    #[cfg(not(loom))]
    pub const fn new() -> Histogram {
        const Z: AtomicU64 = AtomicU64::new(0);
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            buckets: [Z; HIST_BUCKETS],
        }
    }

    /// New empty histogram. (Non-const under `cfg(loom)`; see
    /// [`Counter::new`].)
    #[cfg(loom)]
    pub fn new() -> Histogram {
        Histogram {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Record one observation.
    #[inline]
    pub fn record(&self, v: u64) {
        // ordering: Relaxed — per-cell RMW exactness is all the
        // histogram claims; `count`/`sum`/`buckets` are not read as a
        // consistent triple mid-flight, only after recorders quiesce
        // (loom-checked in loom_histogram_concurrent_records_exact).
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        // ordering: Relaxed — as above.
        self.count.fetch_add(1, Ordering::Relaxed);
        // ordering: Relaxed — as above.
        self.sum.fetch_add(v, Ordering::Relaxed);
        // ordering: Relaxed — max-folding is order-free; see `Gauge::add`.
        fetch_max_u64(&self.max, v, Ordering::Relaxed);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        // ordering: Relaxed — monitoring read.
        self.count.load(Ordering::Relaxed)
    }

    /// Exact sum of observations.
    pub fn sum(&self) -> u64 {
        // ordering: Relaxed — monitoring read.
        self.sum.load(Ordering::Relaxed)
    }

    /// Exact maximum observation (0 when empty).
    pub fn max(&self) -> u64 {
        // ordering: Relaxed — monitoring read of a monotone cell.
        self.max.load(Ordering::Relaxed)
    }

    /// Approximate quantile (`q` in `[0, 1]`): the inclusive upper bound
    /// of the first bucket whose cumulative count reaches `q * count`.
    pub fn quantile(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut cum = 0u64;
        for (b, slot) in self.buckets.iter().enumerate() {
            // ordering: Relaxed — quantiles over a live histogram are
            // approximate by contract; each bucket read is itself exact.
            cum += slot.load(Ordering::Relaxed);
            if cum >= target {
                return bucket_upper(b);
            }
        }
        bucket_upper(HIST_BUCKETS - 1)
    }

    /// Summarize for snapshots.
    pub fn summary(&self) -> HistogramSummary {
        let count = self.count();
        let sum = self.sum();
        HistogramSummary {
            count,
            sum,
            mean: if count == 0 { 0.0 } else { sum as f64 / count as f64 },
            p50: self.quantile(0.5),
            p99: self.quantile(0.99),
            max: self.max(),
        }
    }

    /// Clear all buckets and totals.
    pub fn reset(&self) {
        // ordering: Relaxed — external sync point; see `Counter::reset`.
        self.count.store(0, Ordering::Relaxed);
        // ordering: Relaxed — as above.
        self.sum.store(0, Ordering::Relaxed);
        // ordering: Relaxed — as above.
        self.max.store(0, Ordering::Relaxed);
        for b in &self.buckets {
            // ordering: Relaxed — as above.
            b.store(0, Ordering::Relaxed);
        }
    }
}

/// Flat summary of one histogram for snapshots.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HistogramSummary {
    /// Number of observations.
    pub count: u64,
    /// Exact sum of observations.
    pub sum: u64,
    /// `sum / count` (0 when empty).
    pub mean: f64,
    /// Approximate median (log2-bucket upper bound).
    pub p50: u64,
    /// Approximate 99th percentile (log2-bucket upper bound).
    pub p99: u64,
    /// Exact maximum observation.
    pub max: u64,
}

// Global registration maps, on the std-only `sync::global` plane (loom
// types cannot live in statics). `Mutex<BTreeMap>` is
// const-constructible, so no lazy-init machinery is needed;
// deterministic iteration order keeps snapshots stable.
static COUNTERS: global::Mutex<BTreeMap<&'static str, &'static Counter>> =
    global::Mutex::new(BTreeMap::new());
static GAUGES: global::Mutex<BTreeMap<&'static str, &'static Gauge>> =
    global::Mutex::new(BTreeMap::new());
static HISTOGRAMS: global::Mutex<BTreeMap<&'static str, &'static Histogram>> =
    global::Mutex::new(BTreeMap::new());

/// Look up (or register) the counter named `name`. The returned
/// reference is `'static`; call sites cache it (typically in a
/// `OnceLock`) so the map lookup happens once, not per record.
pub fn counter(name: &'static str) -> &'static Counter {
    let mut map = global::lock_unpoisoned(&COUNTERS);
    *map.entry(name).or_insert_with(|| Box::leak(Box::new(Counter::new())))
}

/// Look up (or register) the gauge named `name`.
pub fn gauge(name: &'static str) -> &'static Gauge {
    let mut map = global::lock_unpoisoned(&GAUGES);
    *map.entry(name).or_insert_with(|| Box::leak(Box::new(Gauge::new())))
}

/// Look up (or register) the histogram named `name`.
pub fn histogram(name: &'static str) -> &'static Histogram {
    let mut map = global::lock_unpoisoned(&HISTOGRAMS);
    *map.entry(name).or_insert_with(|| Box::leak(Box::new(Histogram::new())))
}

/// Zero every registered metric (between benchmark repetitions; the
/// registrations themselves persist).
pub fn reset_metrics() {
    for c in global::lock_unpoisoned(&COUNTERS).values() {
        c.reset();
    }
    for g in global::lock_unpoisoned(&GAUGES).values() {
        g.reset();
    }
    for h in global::lock_unpoisoned(&HISTOGRAMS).values() {
        h.reset();
    }
}

/// Point-in-time copy of every registered metric, ready to serialize.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// `(name, value)` for every registered counter, name-sorted.
    pub counters: Vec<(String, u64)>,
    /// `(name, level, high_water)` for every registered gauge.
    pub gauges: Vec<(String, i64, i64)>,
    /// `(name, summary)` for every registered histogram.
    pub histograms: Vec<(String, HistogramSummary)>,
}

/// Snapshot every registered metric.
pub fn snapshot() -> MetricsSnapshot {
    let counters = global::lock_unpoisoned(&COUNTERS)
        .iter()
        .map(|(name, c)| (name.to_string(), c.get()))
        .collect();
    let gauges = global::lock_unpoisoned(&GAUGES)
        .iter()
        .map(|(name, g)| (name.to_string(), g.get(), g.high_water()))
        .collect();
    let histograms = global::lock_unpoisoned(&HISTOGRAMS)
        .iter()
        .map(|(name, h)| (name.to_string(), h.summary()))
        .collect();
    MetricsSnapshot { counters, gauges, histograms }
}

impl MetricsSnapshot {
    /// Serialize as one JSON object:
    /// `{"counters": {...}, "gauges": {...}, "histograms": {...}}`.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\"counters\": {");
        for (i, (name, v)) in self.counters.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("{}: {v}", json_str(name)));
        }
        out.push_str("}, \"gauges\": {");
        for (i, (name, v, hw)) in self.gauges.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("{}: {{\"value\": {v}, \"high_water\": {hw}}}", json_str(name)));
        }
        out.push_str("}, \"histograms\": {");
        for (i, (name, h)) in self.histograms.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!(
                "{}: {{\"count\": {}, \"sum\": {}, \"mean\": {}, \"p50\": {}, \"p99\": {}, \
                 \"max\": {}}}",
                json_str(name),
                h.count,
                h.sum,
                crate::util::json::json_f64(h.mean),
                h.p50,
                h.p99,
                h.max
            ));
        }
        out.push_str("}}");
        out
    }

    /// One-line digest for periodic CLI prints (`--stats-every`): every
    /// non-zero counter and gauge, plus `count/p50` per histogram.
    pub fn digest(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        for (name, v) in &self.counters {
            if *v != 0 {
                parts.push(format!("{name}={v}"));
            }
        }
        for (name, v, hw) in &self.gauges {
            if *v != 0 || *hw != 0 {
                parts.push(format!("{name}={v}(hi {hw})"));
            }
        }
        for (name, h) in &self.histograms {
            if h.count != 0 {
                parts.push(format!("{name}[n={} p50={}]", h.count, h.p50));
            }
        }
        if parts.is_empty() {
            "no metrics recorded".to_string()
        } else {
            parts.join(" ")
        }
    }
}

// Not compiled under `cfg(loom)`: the hammer versions of these
// invariants live in `tests/loom_models.rs` as exhaustive models.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn counter_concurrent_totals_exact() {
        // Miri explores this with a slow interpreter; shrink the load
        // there (loom proves the same invariant exhaustively).
        const THREADS: u64 = if cfg!(miri) { 2 } else { 8 };
        const PER: u64 = if cfg!(miri) { 500 } else { 10_000 };
        let c = Arc::new(Counter::new());
        let threads: Vec<_> = (0..THREADS)
            .map(|_| {
                let c = Arc::clone(&c);
                std::thread::spawn(move || {
                    for _ in 0..PER {
                        c.incr(1);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(c.get(), THREADS * PER);
    }

    #[test]
    fn histogram_concurrent_totals_exact() {
        const THREADS: u64 = if cfg!(miri) { 2 } else { 4 };
        const PER: u64 = if cfg!(miri) { 500 } else { 5_000 };
        let h = Arc::new(Histogram::new());
        let threads: Vec<_> = (0..THREADS)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..PER {
                        h.record(t * PER + i);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(h.count(), THREADS * PER);
        // Sum of 0..THREADS*PER regardless of interleaving.
        assert_eq!(h.sum(), (0..THREADS * PER).sum::<u64>());
        assert_eq!(h.max(), THREADS * PER - 1);
    }

    #[test]
    fn histogram_bucket_boundaries() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), 64);
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(1), 1);
        assert_eq!(bucket_upper(10), 1023);
        assert_eq!(bucket_upper(64), u64::MAX);

        let h = Histogram::new();
        for v in [0, 1, 2, 3, 4, 1023, 1024, u64::MAX] {
            h.record(v);
        }
        assert_eq!(h.count(), 8);
        assert_eq!(h.max(), u64::MAX);
        // Quantiles land on bucket upper bounds.
        assert_eq!(h.quantile(0.0), 0);
        assert_eq!(h.quantile(1.0), u64::MAX);
        let h2 = Histogram::new();
        h2.record(5);
        assert_eq!(h2.quantile(0.5), 7, "one value in [4,8) reports the bucket bound");
    }

    #[test]
    fn gauge_tracks_level_and_high_water() {
        let g = Gauge::new();
        g.add(3);
        g.add(2);
        g.add(-4);
        assert_eq!(g.get(), 1);
        assert_eq!(g.high_water(), 5);
        g.set(2);
        assert_eq!(g.get(), 2);
        assert_eq!(g.high_water(), 5);
        g.reset();
        assert_eq!((g.get(), g.high_water()), (0, 0));
    }

    #[test]
    fn registry_returns_same_cell_and_snapshots() {
        let a = counter("test.registry.hits");
        let b = counter("test.registry.hits");
        assert!(std::ptr::eq(a, b), "same name resolves to the same cell");
        a.reset();
        a.incr(7);
        gauge("test.registry.depth").set(3);
        histogram("test.registry.lat_us").record(100);
        let snap = snapshot();
        assert!(snap.counters.iter().any(|(n, v)| n == "test.registry.hits" && *v == 7));
        assert!(snap.gauges.iter().any(|(n, v, _)| n == "test.registry.depth" && *v == 3));
        assert!(snap.histograms.iter().any(|(n, h)| n == "test.registry.lat_us" && h.count >= 1));
        let json = snap.to_json();
        assert!(json.contains("\"test.registry.hits\": 7"), "{json}");
        assert!(json.contains("\"high_water\""), "{json}");
        let digest = snap.digest();
        assert!(digest.contains("test.registry.hits=7"), "{digest}");
    }
}
