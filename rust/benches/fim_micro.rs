//! Micro-benchmarks of the FIM hot paths (criterion-style, own harness):
//! tidset vs bitmap intersection, triangular-matrix updates, bottom-up
//! recursion (arena vs the pre-refactor cloning baseline), candidate
//! counting. These are the knobs the §Perf pass tunes.
//!
//! Besides the CSV under `results/`, the run emits the perf-trajectory
//! file `BENCH_fim.json` at the repository root (override the path with
//! `BENCH_FIM_OUT`). Reproduce with:
//!
//! ```text
//! cargo bench --bench fim_micro          # SCALE=paper for full samples
//! cargo bench --bench fim_micro --features alloc-count -- --quick
//! ```
//!
//! With `--features alloc-count` the binary installs a counting global
//! allocator and each `bottomup/*` row carries the heap-allocation count
//! of one invocation — the zero-allocation claim of the arena miner is
//! measured, not asserted (the arena rows should show only the emitted
//! `Frequent`s plus output-vector growth; the `*_cloning` rows add one
//! allocation per candidate tidset and per recursion node on top).

use rdd_eclat::bench::{alloc, black_box, Bench, Report};
use rdd_eclat::fim::{
    bottom_up_with, bottomup::reference, intersect, intersect_count, intersect_into,
    CandidateTrie, Frequent, MineScratch, PooledSink, TidBitmap, Tidset, TriMatrix,
};
use rdd_eclat::util::prng::Rng;

#[cfg(feature = "alloc-count")]
#[global_allocator]
static GLOBAL: alloc::CountingAllocator = alloc::CountingAllocator::new();

fn random_tidset(rng: &mut Rng, universe: usize, density: f64) -> Tidset {
    (0..universe as u32).filter(|_| rng.chance(density)).collect()
}

fn main() {
    #[cfg(feature = "alloc-count")]
    alloc::mark_installed();
    let bench = Bench::from_env();
    let mut report = Report::new();
    let mut rng = Rng::new(2024);

    // --- tidset intersection: sorted-vec vs bitmap, two densities ---
    for &density in &[0.05, 0.4] {
        let universe = 100_000;
        let a = random_tidset(&mut rng, universe, density);
        let b = random_tidset(&mut rng, universe, density);
        let ba = TidBitmap::from_tids(universe, a.iter().copied());
        let bb = TidBitmap::from_tids(universe, b.iter().copied());

        report.add(bench.run(format!("intersect/vec/d={density}"), || {
            black_box(intersect(&a, &b).len())
        }));
        let mut into_buf = Tidset::new();
        report.add(bench.run(format!("intersect/vec_into/d={density}"), || {
            intersect_into(&a, &b, &mut into_buf);
            black_box(into_buf.len())
        }));
        report.add(bench.run(format!("intersect/vec_count/d={density}"), || {
            black_box(intersect_count(&a, &b))
        }));
        report.add(bench.run(format!("intersect/bitmap_count/d={density}"), || {
            black_box(ba.and_count(&bb))
        }));
        report.add(bench.run(format!("intersect/bitmap_and/d={density}"), || {
            black_box(ba.and(&bb).count())
        }));
        let mut bm_buf = TidBitmap::new(0);
        report.add(bench.run(format!("intersect/bitmap_and_into/d={density}"), || {
            black_box(ba.and_counted_into(&bb, &mut bm_buf))
        }));
    }

    // --- skewed (galloping) intersection ---
    {
        let small = random_tidset(&mut rng, 100_000, 0.001);
        let large = random_tidset(&mut rng, 100_000, 0.5);
        report.add(bench.run("intersect/vec_galloping", || {
            black_box(intersect(&small, &large).len())
        }));
    }

    // --- triangular matrix updates over transactions ---
    {
        let txns: Vec<Vec<u32>> = (0..5000)
            .map(|_| {
                let mut t: Vec<u32> = (0..20).map(|_| rng.below(200) as u32).collect();
                t.sort_unstable();
                t.dedup();
                t
            })
            .collect();
        report.add(bench.run("trimatrix/update_5k_txns_w20", || {
            let mut m = TriMatrix::new(199);
            for t in &txns {
                m.update_transaction(t);
            }
            black_box(m.support(1, 2))
        }));
    }

    // --- bottom-up recursion over a mid-sized class: arena vs cloning ---
    //
    // The arena rows reuse one MineScratch + output vector across
    // samples (steady state: lanes and candidate buffers are recycled,
    // intersections abort early); the `_cloning` rows run the pre-arena
    // reference implementation. Under --features alloc-count each row
    // also reports the heap allocations of one invocation.
    {
        let universe = 20_000;
        let members: Vec<(u32, Tidset)> = (0..24)
            .map(|i| (i, random_tidset(&mut rng, universe, 0.12)))
            .collect();
        let bitmap_members: Vec<(u32, TidBitmap)> = members
            .iter()
            .map(|(i, t)| (*i, TidBitmap::from_tids(universe, t.iter().copied())))
            .collect();
        let min_sup = (universe as f64 * 0.012) as u32;
        let mut out: Vec<Frequent> = Vec::new();

        let mut tid_scratch = MineScratch::<Tidset>::new();
        let m = bench.run("bottomup/tidset_24atoms", || {
            out.clear();
            bottom_up_with(&mut tid_scratch, &[100], &members, min_sup, &mut out);
            black_box(out.len())
        });
        let allocs = alloc::count_in(|| {
            out.clear();
            bottom_up_with(&mut tid_scratch, &[100], &members, min_sup, &mut out);
        })
        .1;
        report.add(m.with_allocs(allocs));

        let m = bench.run("bottomup/tidset_24atoms_cloning", || {
            let mut out = Vec::new();
            reference::bottom_up::<Tidset>(&[100], &members, min_sup, &mut out);
            black_box(out.len())
        });
        let allocs = alloc::count_in(|| {
            let mut out = Vec::new();
            reference::bottom_up::<Tidset>(&[100], &members, min_sup, &mut out);
            black_box(out.len());
        })
        .1;
        report.add(m.with_allocs(allocs));

        let mut bm_scratch = MineScratch::<TidBitmap>::new();
        let m = bench.run("bottomup/bitmap_24atoms", || {
            out.clear();
            bottom_up_with(&mut bm_scratch, &[100], &bitmap_members, min_sup, &mut out);
            black_box(out.len())
        });
        let allocs = alloc::count_in(|| {
            out.clear();
            bottom_up_with(&mut bm_scratch, &[100], &bitmap_members, min_sup, &mut out);
        })
        .1;
        report.add(m.with_allocs(allocs));

        let m = bench.run("bottomup/bitmap_24atoms_cloning", || {
            let mut out = Vec::new();
            reference::bottom_up::<TidBitmap>(&[100], &bitmap_members, min_sup, &mut out);
            black_box(out.len())
        });
        let allocs = alloc::count_in(|| {
            let mut out = Vec::new();
            reference::bottom_up::<TidBitmap>(&[100], &bitmap_members, min_sup, &mut out);
            black_box(out.len());
        })
        .1;
        report.add(m.with_allocs(allocs));

        // The zero-allocation claim, made checkable: a warm-arena run's
        // allocation count minus the emitted itemsets (each Frequent owns
        // its items Vec — that's the output, not mining machinery) is the
        // per-run machinery allocation figure, which should be ~0.
        out.clear();
        bottom_up_with(&mut tid_scratch, &[100], &members, min_sup, &mut out);
        let emits = out.len() as u64;
        if let (_, Some(arena_allocs)) = alloc::count_in(|| {
            out.clear();
            bottom_up_with(&mut tid_scratch, &[100], &members, min_sup, &mut out);
        }) {
            println!(
                "bottomup/tidset_24atoms steady state: {arena_allocs} allocs for {emits} \
                 emitted itemsets => {} machinery allocations",
                arena_allocs.saturating_sub(emits)
            );
        }

        // --- adaptive early-abort order: members handed over in
        // descending-support (worst-case) order. The arena miner
        // re-sorts rarest-first internally, so its row should track the
        // ascending-order row above; the cloning reference processes
        // members as given and pays the difference.
        let mut desc = members.clone();
        desc.sort_by(|a, b| b.1.len().cmp(&a.1.len()));
        let m = bench.run("bottomup/tidset_24atoms_descorder", || {
            out.clear();
            bottom_up_with(&mut tid_scratch, &[100], &desc, min_sup, &mut out);
            black_box(out.len())
        });
        report.add(m);
        let m = bench.run("bottomup/tidset_24atoms_descorder_cloning", || {
            let mut out = Vec::new();
            reference::bottom_up::<Tidset>(&[100], &desc, min_sup, &mut out);
            black_box(out.len())
        });
        report.add(m);

        // --- emission path: pooled (flat arena) vs collect (one owned
        // Frequent per emission). Both run the same warm mining arena;
        // the difference is purely what an emission costs. With
        // --features alloc-count the pooled row is the zero-allocation
        // claim for the full mining loop: warm scratch + warm pool =>
        // 0 steady-state heap allocations.
        let mut pooled = PooledSink::new();
        bottom_up_with(&mut tid_scratch, &[100], &members, min_sup, &mut pooled); // warm the pool
        let m = bench.run("emission/pooled_vs_collect/pooled_24atoms", || {
            pooled.clear();
            bottom_up_with(&mut tid_scratch, &[100], &members, min_sup, &mut pooled);
            black_box(pooled.len())
        });
        let pooled_allocs = alloc::count_in(|| {
            pooled.clear();
            bottom_up_with(&mut tid_scratch, &[100], &members, min_sup, &mut pooled);
        })
        .1;
        report.add(m.with_allocs(pooled_allocs));

        let m = bench.run("emission/pooled_vs_collect/collect_24atoms", || {
            out.clear();
            bottom_up_with(&mut tid_scratch, &[100], &members, min_sup, &mut out);
            black_box(out.len())
        });
        let collect_allocs = alloc::count_in(|| {
            out.clear();
            bottom_up_with(&mut tid_scratch, &[100], &members, min_sup, &mut out);
        })
        .1;
        report.add(m.with_allocs(collect_allocs));

        if let (Some(p), Some(c)) = (pooled_allocs, collect_allocs) {
            println!(
                "emission steady state: PooledSink {p} allocations (target 0) vs \
                 CollectSink {c} for {} itemsets",
                pooled.len()
            );
        }

        // --- observability overhead: the same warm-arena mine with the
        // obs layer off (the default) vs on. The instrumentation sites
        // batch counts into locals and flush once per sweep, so the
        // enabled row should sit within a few percent of the disabled
        // one — the "near-zero overhead" claim, measured not asserted.
        rdd_eclat::obs::set_enabled(false);
        let m = bench.run("obs/overhead/bottomup_disabled", || {
            out.clear();
            bottom_up_with(&mut tid_scratch, &[100], &members, min_sup, &mut out);
            black_box(out.len())
        });
        report.add(m);
        rdd_eclat::obs::set_enabled(true);
        let m = bench.run("obs/overhead/bottomup_enabled", || {
            out.clear();
            bottom_up_with(&mut tid_scratch, &[100], &members, min_sup, &mut out);
            black_box(out.len())
        });
        report.add(m);
        rdd_eclat::obs::set_enabled(false);
    }

    // --- Apriori candidate subset counting ---
    {
        let mut trie = CandidateTrie::new();
        for i in 0..40u32 {
            for j in (i + 1)..40 {
                trie.insert(&[i, j]);
            }
        }
        let txns: Vec<Vec<u32>> = (0..2000)
            .map(|_| {
                let mut t: Vec<u32> = (0..15).map(|_| rng.below(40) as u32).collect();
                t.sort_unstable();
                t.dedup();
                t
            })
            .collect();
        report.add(bench.run("apriori/count_780cands_2k_txns", || {
            let mut counts = vec![0u32; trie.len()];
            for t in &txns {
                trie.count_subsets(t, &mut counts);
            }
            black_box(counts[0])
        }));
    }

    report.write_csv("bench_fim_micro.csv").expect("write csv");
    println!("\nwrote results/bench_fim_micro.csv");

    // Perf trajectory: BENCH_fim.json at the repo root (cargo runs
    // benches with the package dir as CWD, hence the `..`).
    let out = std::env::var("BENCH_FIM_OUT").unwrap_or_else(|_| {
        format!("{}/../BENCH_fim.json", env!("CARGO_MANIFEST_DIR"))
    });
    let scale = Bench::scale_from_env();
    // The counters the enabled obs/overhead pass recorded ride along in
    // the trajectory row — intersections attempted, early-aborts, emits.
    report.add_extra("metrics", rdd_eclat::obs::snapshot().to_json());
    report.write_json(&out, "fim_micro", scale).expect("write BENCH_fim.json");
    println!("wrote {out}");
}
