//! Wire format and transport layer that moves streaming shards out of
//! the process.
//!
//! Two layers, zero-dependency like the rest of the crate:
//!
//! * [`wire`] — a versioned, length-prefixed, explicitly little-endian
//!   wire format. Every payload that crosses a process boundary
//!   (tid-bitmap columns, pooled itemset arenas, window batches, shard
//!   stats) implements the [`wire::Wire`] codec, and every message
//!   travels inside a CRC-guarded [`wire::Frame`]. Corrupt, truncated,
//!   or version-skewed bytes decode to typed [`crate::error::Error::Net`]
//!   values — never panics.
//! * [`transport`] — blocking framed TCP on `std::net`: the
//!   [`transport::ShardWorker`] accept loop hosting shard replicas
//!   (`repro shard-worker --listen ADDR`), and the driver-side
//!   [`transport::RemoteShardSet`] that mirrors the in-process
//!   `ShardedVerticalDb` apply/mine surface, with seeded chaos faults,
//!   bounded retries, and degradation to driver-local mining on worker
//!   loss.

pub mod transport;
pub mod wire;

pub use transport::{
    Bounds, FramedConn, RemoteNetStats, RemoteShardSet, ShardWorker, WorkerShardStats,
};
pub use wire::{Frame, FrameKind, Reader, Wire, VERSION};
