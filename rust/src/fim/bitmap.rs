//! Packed-u64 tidset bitmaps — the optimized representation for Eclat's
//! tidset-intersection hot path.
//!
//! A [`TidBitmap`] covers tids `0..universe` in 64-bit words. Intersection
//! support (`|A ∩ B|`) is an AND + popcount sweep, the same computation
//! the L1 Pallas `popcount` kernel performs on 32-bit lanes (see
//! `python/compile/kernels/popcount.py`); the native and AOT backends are
//! cross-checked in `runtime::intersect` tests.

use super::itemset::Tid;

/// A fixed-universe bitset over transaction ids.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TidBitmap {
    words: Vec<u64>,
    universe: usize,
}

impl TidBitmap {
    /// Empty bitmap covering `0..universe`.
    pub fn new(universe: usize) -> TidBitmap {
        TidBitmap { words: vec![0; universe.div_ceil(64)], universe }
    }

    /// Build from an iterator of tids (need not be sorted).
    pub fn from_tids(universe: usize, tids: impl IntoIterator<Item = Tid>) -> TidBitmap {
        let mut bm = TidBitmap::new(universe);
        for t in tids {
            bm.insert(t);
        }
        bm
    }

    /// Rebuild from raw words (the wire-decode fast path): `words` must
    /// be exactly `universe.div_ceil(64)` long with no bits set at or
    /// beyond `universe`. Returns `None` when either invariant fails, so
    /// a corrupt frame surfaces as a decode error instead of a bitmap
    /// that disagrees with its own universe.
    pub fn from_raw_words(universe: usize, words: Vec<u64>) -> Option<TidBitmap> {
        if words.len() != universe.div_ceil(64) {
            return None;
        }
        if universe % 64 != 0 {
            if let Some(&last) = words.last() {
                if last >> (universe % 64) != 0 {
                    return None;
                }
            }
        }
        Some(TidBitmap { words, universe })
    }

    /// Universe size (exclusive upper bound on tids).
    pub fn universe(&self) -> usize {
        self.universe
    }

    /// Raw words (read-only; used by the XLA backend to build buffers).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Set tid `t`. Panics in debug if out of universe.
    #[inline]
    pub fn insert(&mut self, t: Tid) {
        debug_assert!((t as usize) < self.universe, "tid {t} out of universe {}", self.universe);
        self.words[(t as usize) >> 6] |= 1u64 << (t & 63);
    }

    /// Membership test.
    #[inline]
    pub fn contains(&self, t: Tid) -> bool {
        let idx = (t as usize) >> 6;
        idx < self.words.len() && (self.words[idx] >> (t & 63)) & 1 == 1
    }

    /// Number of set bits (the support of the itemset this tidset backs).
    pub fn count(&self) -> u32 {
        self.words.iter().map(|w| w.count_ones()).sum()
    }

    /// `|self ∩ other|` without materializing the intersection — the
    /// support-count fast path of the bottom-up search.
    #[inline]
    pub fn and_count(&self, other: &TidBitmap) -> u32 {
        let n = self.words.len().min(other.words.len());
        let mut acc = 0u32;
        for i in 0..n {
            acc += (self.words[i] & other.words[i]).count_ones();
        }
        acc
    }

    /// Fused materialize + count of `self ∩ other` — one pass over the
    /// words (the bottom-up search's hot call; §Perf iteration 3).
    ///
    /// Mismatched universes use pad-with-zero semantics (the shorter
    /// word vector behaves as if extended with zero words), matching
    /// [`TidBitmap::and_count`] / [`TidBitmap::andnot_count`]. The
    /// result covers the larger universe.
    pub fn and_counted(&self, other: &TidBitmap) -> (TidBitmap, u32) {
        let mut out = TidBitmap::new(0);
        let count = self.and_counted_into(other, &mut out);
        (out, count)
    }

    /// [`TidBitmap::and_counted`] **into** a caller-owned bitmap, reusing
    /// its word buffer — the arena-mining hot path. `out` is completely
    /// overwritten (padded universe semantics as in `and_counted`).
    pub fn and_counted_into(&self, other: &TidBitmap, out: &mut TidBitmap) -> u32 {
        out.universe = self.universe.max(other.universe);
        out.words.clear();
        out.words.resize(self.words.len().max(other.words.len()), 0);
        let mut count = 0u32;
        for ((w, &x), &y) in out.words.iter_mut().zip(&self.words).zip(&other.words) {
            let v = x & y;
            count += v.count_ones();
            *w = v;
        }
        count
    }

    /// Bounded [`TidBitmap::and_counted_into`]: keep a running popcount
    /// and abort mid-sweep as soon as `count + 64·(words left)` proves the
    /// intersection cannot reach `min_sup` — candidates that cannot be
    /// frequent stop without finishing the pass. `Some(n)` means `out`
    /// holds the complete intersection and `n ≥ min_sup`; on `None` the
    /// contents of `out` are unspecified.
    pub fn and_bounded_into(
        &self,
        other: &TidBitmap,
        min_sup: u32,
        out: &mut TidBitmap,
    ) -> Option<u32> {
        out.universe = self.universe.max(other.universe);
        out.words.clear();
        out.words.resize(self.words.len().max(other.words.len()), 0);
        let mut count = 0u32;
        let mut words_left = self.words.len().min(other.words.len()) as u64;
        for ((w, &x), &y) in out.words.iter_mut().zip(&self.words).zip(&other.words) {
            if u64::from(count) + words_left * 64 < u64::from(min_sup) {
                return None;
            }
            let v = x & y;
            count += v.count_ones();
            *w = v;
            words_left -= 1;
        }
        if count >= min_sup {
            Some(count)
        } else {
            None
        }
    }

    /// `|self ∩ other| ≥ min_sup`, count-only, with **both** early exits:
    /// success as soon as the running popcount reaches `min_sup`, abort as
    /// soon as the remaining-words upper bound rules it out.
    pub fn and_count_at_least(&self, other: &TidBitmap, min_sup: u32) -> bool {
        let mut count = 0u32;
        let mut words_left = self.words.len().min(other.words.len()) as u64;
        for (&x, &y) in self.words.iter().zip(&other.words) {
            if count >= min_sup {
                return true;
            }
            if u64::from(count) + words_left * 64 < u64::from(min_sup) {
                return false;
            }
            count += (x & y).count_ones();
            words_left -= 1;
        }
        count >= min_sup
    }

    /// Reset to an empty bitmap over `universe`, reusing the word buffer
    /// (the local-universe remap of `EqClass::mine_auto` recycles member
    /// bitmaps across classes through this).
    pub fn reset(&mut self, universe: usize) {
        self.universe = universe;
        self.words.clear();
        self.words.resize(universe.div_ceil(64), 0);
    }

    /// Materialize `self ∩ other`. Mismatched universes pad the shorter
    /// side with zero words (see [`TidBitmap::and_counted`]).
    pub fn and(&self, other: &TidBitmap) -> TidBitmap {
        self.and_counted(other).0
    }

    /// Extend the universe to at least `universe`, padding with zero
    /// words. Never shrinks. The streaming vertical store grows per-item
    /// bitmaps lazily as new transaction ids arrive.
    pub fn grow(&mut self, universe: usize) {
        if universe > self.universe {
            self.universe = universe;
            self.words.resize(universe.div_ceil(64), 0);
        }
    }

    /// Clear every bit in `[lo, hi)` and return how many were set — the
    /// range-masking primitive behind sliding-window eviction (tids of
    /// evicted batches form contiguous ranges). Bits outside the current
    /// universe are treated as already clear.
    pub fn clear_range(&mut self, lo: Tid, hi: Tid) -> u32 {
        if hi <= lo {
            return 0;
        }
        let hi = (hi as usize).min(self.universe) as Tid;
        if hi <= lo {
            return 0;
        }
        // hi <= universe here, so w_hi < words.len().
        let (w_lo, w_hi) = ((lo as usize) >> 6, ((hi - 1) as usize) >> 6);
        let mut cleared = 0u32;
        for wi in w_lo..=w_hi {
            let mut mask = u64::MAX;
            if wi == w_lo {
                mask &= u64::MAX << (lo & 63);
            }
            if wi == w_hi && (hi & 63) != 0 {
                mask &= u64::MAX >> (64 - (hi & 63));
            }
            cleared += (self.words[wi] & mask).count_ones();
            self.words[wi] &= !mask;
        }
        cleared
    }

    /// `|self \ other|` — powering the diffset variant of Eclat.
    pub fn andnot_count(&self, other: &TidBitmap) -> u32 {
        let mut acc = 0u32;
        for (i, w) in self.words.iter().enumerate() {
            let o = other.words.get(i).copied().unwrap_or(0);
            acc += (w & !o).count_ones();
        }
        acc
    }

    /// Materialize `self \ other`. Missing `other` words count as zero
    /// (pad-with-zero, as in [`TidBitmap::andnot_count`]); the result is
    /// a subset of `self`, so it keeps `self`'s universe.
    pub fn andnot(&self, other: &TidBitmap) -> TidBitmap {
        let words = self
            .words
            .iter()
            .enumerate()
            .map(|(i, w)| w & !other.words.get(i).copied().unwrap_or(0))
            .collect();
        TidBitmap { words, universe: self.universe }
    }

    /// Iterate set tids ascending.
    pub fn iter(&self) -> impl Iterator<Item = Tid> + '_ {
        self.words.iter().enumerate().flat_map(|(wi, &w)| {
            let mut bits = w;
            std::iter::from_fn(move || {
                if bits == 0 {
                    None
                } else {
                    let b = bits.trailing_zeros();
                    bits &= bits - 1;
                    Some((wi as u32) * 64 + b)
                }
            })
        })
    }

    /// Export the words as little-endian u32 lanes (the layout the AOT
    /// popcount kernel consumes: one u64 word = two consecutive u32s).
    pub fn to_u32_lanes(&self, lanes: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(lanes);
        for w in &self.words {
            out.push(*w as u32);
            out.push((*w >> 32) as u32);
        }
        out.resize(lanes, 0);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn insert_contains_count() {
        let mut bm = TidBitmap::new(200);
        for t in [0u32, 63, 64, 127, 128, 199] {
            bm.insert(t);
            assert!(bm.contains(t));
        }
        assert!(!bm.contains(1));
        assert_eq!(bm.count(), 6);
    }

    #[test]
    fn from_raw_words_validates_shape_and_tail_bits() {
        let bm = TidBitmap::from_tids(70, [0u32, 63, 69]);
        let rebuilt = TidBitmap::from_raw_words(70, bm.words().to_vec()).unwrap();
        assert_eq!(rebuilt, bm);
        // Wrong word count for the universe.
        assert!(TidBitmap::from_raw_words(70, vec![0u64; 3]).is_none());
        assert!(TidBitmap::from_raw_words(70, vec![0u64; 1]).is_none());
        // A bit at/beyond the universe (tid 70 in universe 70).
        assert!(TidBitmap::from_raw_words(70, vec![0, 1u64 << 6]).is_none());
        // Word-aligned universes have no tail mask to violate.
        assert_eq!(TidBitmap::from_raw_words(128, vec![u64::MAX; 2]).unwrap().count(), 128);
        assert_eq!(TidBitmap::from_raw_words(0, vec![]).unwrap().count(), 0);
    }

    #[test]
    fn and_count_matches_materialized() {
        let a = TidBitmap::from_tids(300, (0..300).filter(|t| t % 3 == 0));
        let b = TidBitmap::from_tids(300, (0..300).filter(|t| t % 5 == 0));
        let expect = (0..300).filter(|t| t % 15 == 0).count() as u32;
        assert_eq!(a.and_count(&b), expect);
        assert_eq!(a.and(&b).count(), expect);
    }

    #[test]
    fn andnot_is_difference() {
        let a = TidBitmap::from_tids(100, 0..50u32);
        let b = TidBitmap::from_tids(100, 25..75u32);
        assert_eq!(a.andnot_count(&b), 25);
        assert_eq!(a.andnot(&b).iter().collect::<Vec<_>>(), (0..25).collect::<Vec<_>>());
    }

    #[test]
    fn iter_ascending_roundtrip() {
        let tids = vec![3u32, 64, 65, 190];
        let bm = TidBitmap::from_tids(200, tids.clone());
        assert_eq!(bm.iter().collect::<Vec<_>>(), tids);
    }

    #[test]
    fn u32_lanes_layout() {
        let mut bm = TidBitmap::new(128);
        bm.insert(0); // word 0, low half
        bm.insert(33); // word 0, high half -> lane 1 bit 1
        bm.insert(64); // word 1, low half -> lane 2 bit 0
        let lanes = bm.to_u32_lanes(4);
        assert_eq!(lanes, vec![1, 2, 1, 0]);
        // Padding beyond words:
        assert_eq!(bm.to_u32_lanes(6), vec![1, 2, 1, 0, 0, 0]);
    }

    #[test]
    fn mismatched_universes_pad_with_zero() {
        // a covers 0..70 (two words), b covers 0..200 (four words): the
        // old `zip`-based and/and_counted silently truncated b's view of
        // a to two words — consistent here — but dropped a's view when
        // called the other way around only by luck of zip's min-length
        // semantics. All six ops must agree with explicit set math.
        let a = TidBitmap::from_tids(70, [0u32, 5, 63, 64, 69]);
        let b = TidBitmap::from_tids(200, [5u32, 64, 128, 199]);
        let expect_and: Vec<Tid> = vec![5, 64];

        for (x, y) in [(&a, &b), (&b, &a)] {
            let (m, c) = x.and_counted(y);
            assert_eq!(c, 2, "and_counted count");
            assert_eq!(m.iter().collect::<Vec<_>>(), expect_and, "and_counted words");
            assert_eq!(m.universe(), 200, "result covers the larger universe");
            assert_eq!(m.words().len(), 4, "result padded to the longer word vec");
            assert_eq!(x.and(y).iter().collect::<Vec<_>>(), expect_and);
            assert_eq!(x.and_count(y), 2);
        }
        // Difference is relative to the left side's universe.
        assert_eq!(a.andnot_count(&b), 3);
        assert_eq!(a.andnot(&b).iter().collect::<Vec<_>>(), vec![0, 63, 69]);
        assert_eq!(b.andnot_count(&a), 2);
        assert_eq!(b.andnot(&a).iter().collect::<Vec<_>>(), vec![128, 199]);
        // Set bits beyond the shorter side's words survive andnot.
        assert!(b.andnot(&a).contains(199));
    }

    #[test]
    fn grow_extends_universe_preserving_bits() {
        let mut bm = TidBitmap::from_tids(70, [0u32, 63, 69]);
        bm.grow(50); // no-op: never shrinks
        assert_eq!(bm.universe(), 70);
        bm.grow(200);
        assert_eq!(bm.universe(), 200);
        assert_eq!(bm.words().len(), 4);
        assert_eq!(bm.iter().collect::<Vec<_>>(), vec![0, 63, 69]);
        bm.insert(199);
        assert_eq!(bm.count(), 4);
    }

    #[test]
    fn clear_range_masks_and_counts() {
        // Bits straddling word boundaries; range [60, 70) clears 63, 64, 69.
        let mut bm = TidBitmap::from_tids(200, [0u32, 5, 63, 64, 69, 128, 199]);
        assert_eq!(bm.clear_range(60, 70), 3);
        assert_eq!(bm.iter().collect::<Vec<_>>(), vec![0, 5, 128, 199]);
        // Already-clear range counts zero.
        assert_eq!(bm.clear_range(60, 70), 0);
        // Word-aligned end, single-word range, full-universe range.
        assert_eq!(bm.clear_range(0, 64), 2);
        assert_eq!(bm.clear_range(0, 200), 2);
        assert_eq!(bm.count(), 0);
        // Degenerate ranges and out-of-universe ranges are no-ops.
        assert_eq!(bm.clear_range(10, 10), 0);
        assert_eq!(bm.clear_range(10, 5), 0);
        assert_eq!(bm.clear_range(500, 900), 0);
        let mut empty = TidBitmap::new(0);
        assert_eq!(empty.clear_range(0, 100), 0);
    }

    #[test]
    fn clear_range_random_cross_check() {
        let mut rng = Rng::new(77);
        for _ in 0..50 {
            let universe = rng.range(1, 400);
            let tids: Vec<u32> =
                (0..rng.range(0, universe)).map(|_| rng.below(universe as u64) as u32).collect();
            let mut bm = TidBitmap::from_tids(universe, tids.iter().copied());
            let lo = rng.range(0, universe + 1) as u32;
            let hi = rng.range(0, universe + 50) as u32;
            let mut set: std::collections::HashSet<u32> = tids.into_iter().collect();
            let before = set.len();
            set.retain(|&t| !(lo..hi).contains(&t));
            let cleared = bm.clear_range(lo, hi);
            assert_eq!(cleared as usize, before - set.len());
            let mut want: Vec<u32> = set.into_iter().collect();
            want.sort_unstable();
            assert_eq!(bm.iter().collect::<Vec<_>>(), want);
        }
    }

    #[test]
    fn counted_into_reuses_buffer_and_matches_allocating_path() {
        let mut rng = Rng::new(5);
        let mut out = TidBitmap::new(0);
        for case in 0..60 {
            // Mismatched universes on purpose: the into-path must honor
            // the same pad-with-zero semantics as and_counted.
            let (ua, ub) = (rng.range(1, 400), rng.range(1, 400));
            let na = rng.range(0, ua);
            let a = TidBitmap::from_tids(ua, (0..na).map(|_| rng.below(ua as u64) as u32));
            let nb = rng.range(0, ub);
            let b = TidBitmap::from_tids(ub, (0..nb).map(|_| rng.below(ub as u64) as u32));
            let (want, want_n) = a.and_counted(&b);
            let got_n = a.and_counted_into(&b, &mut out);
            assert_eq!(got_n, want_n, "case {case}");
            assert_eq!(out, want, "case {case}");
            // Bounded path: reachable thresholds materialize the full
            // result, unreachable ones abort.
            for min_sup in [0, want_n / 2, want_n, want_n + 1] {
                let bounded = a.and_bounded_into(&b, min_sup, &mut out);
                if min_sup <= want_n {
                    assert_eq!(bounded, Some(want_n), "case {case} min_sup={min_sup}");
                    assert_eq!(out, want, "case {case} min_sup={min_sup}");
                } else {
                    assert_eq!(bounded, None, "case {case} min_sup={min_sup}");
                }
                assert_eq!(
                    a.and_count_at_least(&b, min_sup),
                    min_sup <= want_n,
                    "case {case} at_least min_sup={min_sup}"
                );
            }
        }
    }

    #[test]
    fn bounded_and_aborts_on_impossible_threshold() {
        // 2 words of universe: upper bound is 128, so min_sup 129 must
        // abort before touching any word; min_sup within reach must not.
        let a = TidBitmap::from_tids(128, 0..128u32);
        let b = TidBitmap::from_tids(128, 0..128u32);
        let mut out = TidBitmap::new(0);
        assert_eq!(a.and_bounded_into(&b, 129, &mut out), None);
        assert_eq!(a.and_bounded_into(&b, 128, &mut out), Some(128));
        assert!(!a.and_count_at_least(&b, 129));
        assert!(a.and_count_at_least(&b, 128));
        assert!(a.and_count_at_least(&b, 0), "trivial threshold");
    }

    #[test]
    fn reset_reuses_buffer_and_clears_bits() {
        let mut bm = TidBitmap::from_tids(200, [0u32, 63, 64, 199]);
        bm.reset(70);
        assert_eq!(bm.universe(), 70);
        assert_eq!(bm.count(), 0);
        assert_eq!(bm.words().len(), 2);
        bm.insert(69);
        assert!(bm.contains(69));
        bm.reset(300);
        assert_eq!(bm.count(), 0, "grown reset starts empty");
        assert_eq!(bm.words().len(), 5);
    }

    #[test]
    fn random_cross_check_with_sets() {
        let mut rng = Rng::new(42);
        for _ in 0..50 {
            let universe = rng.range(1, 500);
            let mk = |rng: &mut Rng| -> (TidBitmap, std::collections::HashSet<u32>) {
                let mut bm = TidBitmap::new(universe);
                let mut set = std::collections::HashSet::new();
                let n = rng.range(0, universe);
                for _ in 0..n {
                    let t = rng.range(0, universe) as u32;
                    bm.insert(t);
                    set.insert(t);
                }
                (bm, set)
            };
            let (a, sa) = mk(&mut rng);
            let (b, sb) = mk(&mut rng);
            assert_eq!(a.count() as usize, sa.len());
            assert_eq!(a.and_count(&b) as usize, sa.intersection(&sb).count());
            assert_eq!(a.andnot_count(&b) as usize, sa.difference(&sb).count());
        }
    }
}
