//! Crate-specific concurrency lint — zero dependencies, line-wise.
//!
//! `cargo run --bin lint` scans the crate sources (default
//! `src/`, override with `--root <dir>`) and enforces the PR-9
//! concurrency-hygiene rules that rustc and clippy cannot express:
//!
//! | rule id            | scope                 | requirement                                             |
//! |--------------------|-----------------------|---------------------------------------------------------|
//! | `bare-lock-unwrap` | all files             | no `.lock().unwrap()` / `.read().unwrap()` /            |
//! |                    |                       | `.write().unwrap()` — use the poison-tolerant           |
//! |                    |                       | `crate::sync::*_unpoisoned` helpers or map the error    |
//! | `ordering-comment` | all files             | every `Ordering::{Relaxed,Acquire,Release,AcqRel,`      |
//! |                    |                       | `SeqCst}` use carries an `// ordering:` justification   |
//! | `safety-comment`   | all files             | every `unsafe` block/impl/fn carries a `// SAFETY:`     |
//! |                    |                       | justification                                           |
//! | `chaos-determinism`| `engine/chaos.rs`     | no `Instant::now` / `SystemTime` — fault decisions must |
//! |                    |                       | be a pure function of the seeded policy                 |
//! | `shim-imports`     | the shimmed           | no `std::sync` / `std::thread` — loom-modelable modules |
//! |                    | concurrency modules   | import `crate::sync` so `--cfg loom` swaps the types    |
//! | `socket-unwrap`    | `net/` modules        | no `.unwrap()` on a line doing socket I/O — transport   |
//! |                    |                       | failures are routine and must map into `Error::Net`     |
//!
//! Justification comments may sit on the offending line or in the
//! contiguous `//` comment block above the statement (attribute lines
//! and statement continuations are looked through). Test-only regions —
//! items gated by a `#[cfg(...)]` containing `test` — are exempt from
//! every rule: tests may use bare `unwrap` (a poisoned lock *should*
//! fail a test loudly) and std types (they never compile under loom,
//! or only behind `cfg(all(loom, test))`).
//!
//! The scanner is a heuristic, not a parser: it is string-, char-,
//! raw-string- and comment-aware (including block comments) so braces
//! and keywords inside literals don't confuse it, but pathological
//! formatting can evade it. That is fine — it is a tripwire for the
//! crate's own conventions, reviewed alongside the code it checks.
//!
//! Exit status: 0 when clean, 1 with one `file:line: [rule] message`
//! diagnostic per violation otherwise. `--list` prints the rule table.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Which files a rule applies to.
#[derive(Clone, Copy)]
enum Scope {
    All,
    /// Only files whose `/`-separated path ends with one of these suffixes.
    Only(&'static [&'static str]),
}

#[derive(Clone, Copy)]
enum Kind {
    /// The code portion of a non-test line must not contain any needle.
    Forbid(&'static [&'static str]),
    /// A non-test line whose code portion contains a trigger must carry
    /// `marker` in its own comment or the comment block above it.
    RequireComment { triggers: &'static [&'static str], marker: &'static str },
    /// The code portion of a non-test line containing any `when` needle
    /// must not also contain `then` (conjunction forbid).
    ForbidPair { when: &'static [&'static str], then: &'static str },
}

struct Rule {
    id: &'static str,
    scope: Scope,
    kind: Kind,
    /// Raw-line substrings that exempt an otherwise-matching line.
    allow: &'static [&'static str],
    summary: &'static str,
}

/// The modules refactored onto the `crate::sync` shim (five in PR 9,
/// plus the `net/` transport layer in PR 10); keep in sync with the
/// list in `src/sync.rs` docs.
const SHIMMED: &[&str] = &[
    "stream/serve.rs",
    "engine/pool.rs",
    "engine/shuffle.rs",
    "obs/registry.rs",
    "obs/span.rs",
    "net/wire.rs",
    "net/transport.rs",
];

/// The wire/transport modules: every socket operation there must map
/// its error instead of unwrapping.
const NET: &[&str] = &["net/wire.rs", "net/transport.rs"];

const RULES: &[Rule] = &[
    Rule {
        id: "bare-lock-unwrap",
        scope: Scope::All,
        kind: Kind::Forbid(&[".lock().unwrap()", ".read().unwrap()", ".write().unwrap()"]),
        allow: &[],
        summary: "unwrap on a poisonable guard propagates panics across threads; use \
                  crate::sync::{lock,read,write}_unpoisoned or map the PoisonError",
    },
    Rule {
        id: "ordering-comment",
        scope: Scope::All,
        kind: Kind::RequireComment {
            triggers: &[
                "Ordering::Relaxed",
                "Ordering::Acquire",
                "Ordering::Release",
                "Ordering::AcqRel",
                "Ordering::SeqCst",
            ],
            marker: "ordering:",
        },
        allow: &[],
        summary: "every atomic memory-ordering choice carries an `// ordering:` comment \
                  saying why that strength is sufficient (or deliberately strong)",
    },
    Rule {
        id: "safety-comment",
        scope: Scope::All,
        kind: Kind::RequireComment { triggers: &["unsafe "], marker: "SAFETY:" },
        allow: &[],
        summary: "every `unsafe` block, fn, or impl carries a `// SAFETY:` comment stating \
                  the invariant that makes it sound",
    },
    Rule {
        id: "chaos-determinism",
        scope: Scope::Only(&["engine/chaos.rs"]),
        kind: Kind::Forbid(&["Instant::now", "SystemTime"]),
        allow: &[],
        summary: "chaos fault decisions must be a pure function of the seeded policy — \
                  wall-clock reads would make failure schedules unreproducible",
    },
    Rule {
        id: "shim-imports",
        scope: Scope::Only(SHIMMED),
        kind: Kind::Forbid(&["std::sync", "std::thread"]),
        allow: &["std::thread::current"],
        summary: "loom-modelable modules import crate::sync (the shim), never std::sync / \
                  std::thread directly, so `--cfg loom` swaps every primitive",
    },
    Rule {
        id: "socket-unwrap",
        scope: Scope::Only(NET),
        kind: Kind::ForbidPair {
            when: &[
                ".read(",
                ".read_exact(",
                ".write(",
                ".write_all(",
                ".flush(",
                ".connect(",
                ".accept(",
                ".send(",
                ".recv(",
                ".recv_bytes(",
                ".set_read_timeout(",
                ".set_write_timeout(",
            ],
            then: ".unwrap()",
        },
        allow: &[],
        summary: "socket I/O fails routinely (timeouts, resets, chaos-dropped peers); \
                  transport code maps those errors into Error::Net, never unwraps them",
    },
];

#[derive(Debug)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

/// One source line, split by the file-global scanner.
struct Line {
    /// Characters outside comments; string/char literal *contents* are
    /// masked out so needles inside literals can't fire rules.
    code: String,
    /// Characters inside `//` or `/* */` comments.
    comment: String,
    /// Brace depth after this line (braces counted in code, outside
    /// strings and comments).
    depth_after: i32,
}

fn main() -> ExitCode {
    let mut root: PathBuf = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--list" => {
                print_rules();
                return ExitCode::SUCCESS;
            }
            "--root" => match args.next() {
                Some(dir) => root = PathBuf::from(dir),
                None => {
                    eprintln!("lint: --root needs a directory argument");
                    return ExitCode::FAILURE;
                }
            },
            other => {
                eprintln!("lint: unknown argument `{other}` (try --root <dir> or --list)");
                return ExitCode::FAILURE;
            }
        }
    }

    let mut files = Vec::new();
    collect_rs_files(&root, &mut files);
    files.sort();
    if files.is_empty() {
        eprintln!("lint: no .rs files under {}", root.display());
        return ExitCode::FAILURE;
    }

    let mut violations = Vec::new();
    for file in &files {
        let rel = file
            .strip_prefix(&root)
            .unwrap_or(file)
            .to_string_lossy()
            .replace('\\', "/");
        // The lint's own rule table spells out the forbidden patterns.
        if rel.ends_with("bin/lint.rs") {
            continue;
        }
        match fs::read_to_string(file) {
            Ok(text) => scan_file(&rel, &text, &mut violations),
            Err(e) => {
                eprintln!("lint: cannot read {}: {e}", file.display());
                return ExitCode::FAILURE;
            }
        }
    }

    for v in &violations {
        println!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
    }
    if violations.is_empty() {
        println!("lint: clean ({} files)", files.len());
        ExitCode::SUCCESS
    } else {
        println!("lint: {} violation(s) in {} files scanned", violations.len(), files.len());
        ExitCode::FAILURE
    }
}

fn print_rules() {
    println!("lint rules (see src/bin/lint.rs docs for the full table):");
    for r in RULES {
        let scope = match r.scope {
            Scope::All => "all files".to_string(),
            Scope::Only(files) => files.join(", "),
        };
        println!("  {:<18} [{}]\n    {}", r.id, scope, r.summary);
    }
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

fn scan_file(rel: &str, text: &str, out: &mut Vec<Violation>) {
    let lines = split_lines(text);
    let masked = test_mask(&lines);
    let raw: Vec<&str> = text.lines().collect();

    for rule in RULES {
        if let Scope::Only(files) = rule.scope {
            if !files.iter().any(|f| rel.ends_with(f)) {
                continue;
            }
        }
        for (i, line) in lines.iter().enumerate() {
            if masked[i] {
                continue;
            }
            if rule.allow.iter().any(|a| raw.get(i).is_some_and(|r| r.contains(a))) {
                continue;
            }
            match rule.kind {
                Kind::Forbid(needles) => {
                    for needle in needles {
                        if line.code.contains(needle) {
                            out.push(Violation {
                                file: rel.to_string(),
                                line: i + 1,
                                rule: rule.id,
                                msg: format!("forbidden pattern `{needle}` — {}", rule.summary),
                            });
                        }
                    }
                }
                Kind::ForbidPair { when, then } => {
                    if line.code.contains(then) {
                        for needle in when {
                            if line.code.contains(needle) {
                                out.push(Violation {
                                    file: rel.to_string(),
                                    line: i + 1,
                                    rule: rule.id,
                                    msg: format!(
                                        "`{needle}...){then}` — {}",
                                        rule.summary
                                    ),
                                });
                            }
                        }
                    }
                }
                Kind::RequireComment { triggers, marker } => {
                    for trigger in triggers {
                        if line.code.contains(trigger) && !justified(&lines, i, marker, triggers)
                        {
                            out.push(Violation {
                                file: rel.to_string(),
                                line: i + 1,
                                rule: rule.id,
                                msg: format!(
                                    "`{}` without a `// {marker}` comment — {}",
                                    trigger.trim_end(),
                                    rule.summary
                                ),
                            });
                        }
                    }
                }
            }
        }
    }
}

/// Is the trigger on `lines[idx]` justified by a `marker` comment — on
/// the line itself, or in the contiguous comment block above the
/// statement? The upward walk looks through attribute lines, sibling
/// trigger lines (one comment block may cover a run of annotated
/// statements), and statement continuations (a preceding code line that
/// doesn't end a statement).
fn justified(lines: &[Line], idx: usize, marker: &str, triggers: &[&str]) -> bool {
    if lines[idx].comment.contains(marker) {
        return true;
    }
    let mut i = idx;
    while i > 0 {
        i -= 1;
        let line = &lines[i];
        let code = line.code.trim();
        if code.is_empty() {
            if line.comment.contains(marker) {
                return true;
            }
            if line.comment.trim().is_empty() {
                // Blank line: the comment block (if any) ended.
                return false;
            }
            continue; // pure comment line, keep walking the block
        }
        if code.starts_with("#[") || code.starts_with("#![") {
            continue; // attribute between comment and item
        }
        if triggers.iter().any(|t| code.contains(t)) {
            // A sibling annotated statement; its comment may say "as
            // above" — keep walking to the block that opened the run.
            if line.comment.contains(marker) {
                return true;
            }
            continue;
        }
        let ends_statement = code.ends_with(';')
            || code.ends_with('{')
            || code.ends_with('}')
            || code.ends_with(',');
        if !ends_statement {
            // Continuation of the same multi-line statement.
            if line.comment.contains(marker) {
                return true;
            }
            continue;
        }
        return line.comment.contains(marker);
    }
    false
}

/// Mark every line inside a `#[cfg(...)]`-gated test region. A cfg is a
/// test cfg when it mentions `test` outside `not(...)` — `cfg(test)`,
/// `cfg(all(test, not(loom)))`, and `cfg(all(loom, test))` all count;
/// `cfg(not(test))` does not.
fn test_mask(lines: &[Line]) -> Vec<bool> {
    let mut mask = vec![false; lines.len()];
    let mut active: Option<i32> = None; // base depth of the gated item
    let mut entered = false;
    let mut depth_before = 0;
    for (i, line) in lines.iter().enumerate() {
        if active.is_none() && is_test_cfg(&line.code) {
            active = Some(depth_before);
            entered = false;
        }
        if let Some(base) = active {
            mask[i] = true;
            if line.depth_after > base {
                entered = true;
            }
            if entered && line.depth_after <= base {
                active = None;
            }
        }
        depth_before = line.depth_after;
    }
    mask
}

fn is_test_cfg(code: &str) -> bool {
    let Some(at) = code.find("#[cfg(") else { return false };
    let attr = &code[at..];
    let mut search = attr;
    while let Some(pos) = search.find("test") {
        // `test` as its own cfg token, not a substring of e.g. `latest`.
        let before = attr.len() - search.len() + pos;
        let prev_ok = before == 0
            || !attr[..before]
                .chars()
                .next_back()
                .is_some_and(|c| c.is_alphanumeric() || c == '_');
        let after = &search[pos + 4..];
        let next_ok = !after.chars().next().is_some_and(|c| c.is_alphanumeric() || c == '_');
        if prev_ok && next_ok && !attr[..before].ends_with("not(") {
            return true;
        }
        search = &search[pos + 4..];
    }
    false
}

/// File-global scanner: split `text` into per-line code/comment parts
/// and track brace depth, carrying string/char/comment state across
/// newlines so multi-line literals and block comments can't confuse the
/// rules.
fn split_lines(text: &str) -> Vec<Line> {
    #[derive(Clone, Copy, PartialEq)]
    enum State {
        Code,
        LineComment,
        BlockComment(u32),
        Str,
        RawStr(u32),
        Char,
    }
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut depth = 0i32;
    let mut state = State::Code;
    let chars: Vec<char> = text.chars().collect();
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if state == State::LineComment {
                state = State::Code;
            }
            lines.push(Line {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
                depth_after: depth,
            });
            i += 1;
            continue;
        }
        match state {
            State::Code => {
                let next = chars.get(i + 1).copied();
                match c {
                    '/' if next == Some('/') => {
                        state = State::LineComment;
                        i += 2;
                        continue;
                    }
                    '/' if next == Some('*') => {
                        state = State::BlockComment(1);
                        i += 2;
                        continue;
                    }
                    '"' => {
                        state = State::Str;
                        code.push(c);
                    }
                    'r' | 'b'
                        if is_raw_string_start(&chars, i)
                            && (i == 0 || !is_ident(chars[i - 1])) =>
                    {
                        // Consume `r`/`br` + hashes + opening quote.
                        let mut j = i + 1;
                        if chars.get(i) == Some(&'b') {
                            j += 1; // the `r` after `b`
                        }
                        let mut hashes = 0;
                        while chars.get(j) == Some(&'#') {
                            hashes += 1;
                            j += 1;
                        }
                        for k in i..=j {
                            code.push(chars[k]);
                        }
                        state = State::RawStr(hashes);
                        i = j + 1;
                        continue;
                    }
                    '\'' => {
                        // Char literal vs lifetime: `'x'` / `'\n'` are
                        // chars; `'a>` / `'static` are lifetimes.
                        code.push(c);
                        if next == Some('\\')
                            || (next.is_some()
                                && chars.get(i + 2) == Some(&'\'')
                                && next != Some('\''))
                        {
                            state = State::Char;
                        }
                    }
                    '{' => {
                        depth += 1;
                        code.push(c);
                    }
                    '}' => {
                        depth -= 1;
                        code.push(c);
                    }
                    _ => code.push(c),
                }
                i += 1;
            }
            State::LineComment => {
                comment.push(c);
                i += 1;
            }
            State::BlockComment(n) => {
                let next = chars.get(i + 1).copied();
                if c == '*' && next == Some('/') {
                    state = if n == 1 { State::Code } else { State::BlockComment(n - 1) };
                    comment.push_str("*/");
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    state = State::BlockComment(n + 1);
                    comment.push_str("/*");
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                // Literal contents are masked (not pushed to `code`).
                if c == '\\' {
                    // Consume the escaped char — except a line
                    // continuation's newline, which the top-of-loop
                    // handler must still see to keep line counts true.
                    if let Some(&esc) = chars.get(i + 1) {
                        if esc != '\n' {
                            i += 1;
                        }
                    }
                } else if c == '"' {
                    code.push(c);
                    state = State::Code;
                }
                i += 1;
            }
            State::RawStr(hashes) => {
                if c == '"' {
                    let mut ok = true;
                    for k in 0..hashes as usize {
                        if chars.get(i + 1 + k) != Some(&'#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        code.push('"');
                        for k in 0..hashes as usize {
                            code.push(chars[i + 1 + k]);
                        }
                        i += hashes as usize;
                        state = State::Code;
                    }
                }
                i += 1;
            }
            State::Char => {
                if c == '\\' {
                    if chars.get(i + 1).is_some() {
                        i += 1;
                    }
                } else if c == '\'' {
                    code.push(c);
                    state = State::Code;
                }
                i += 1;
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        lines.push(Line { code, comment, depth_after: depth });
    }
    lines
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// At `chars[i]` (an `r` or `b`), does a raw string literal start?
fn is_raw_string_start(chars: &[char], i: usize) -> bool {
    let mut j = i + 1;
    if chars.get(i) == Some(&'b') {
        if chars.get(j) != Some(&'r') {
            return false;
        }
        j += 1;
    }
    while chars.get(j) == Some(&'#') {
        j += 1;
    }
    chars.get(j) == Some(&'"')
}
