//! Minimal JSON emission helpers (no `serde` offline). Shared by the
//! bench reports and the rule/stream snapshot writers — flat schemas
//! emitted by hand, with only string escaping needing care.

/// Quote and escape a string as a JSON string literal (quotes,
/// backslashes, control characters).
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format an `f64` for JSON: finite values as-is, non-finite as `null`
/// (JSON has no NaN/Infinity).
pub fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escapes_quotes_and_controls() {
        assert_eq!(json_str("plain"), "\"plain\"");
        assert_eq!(json_str("a\"b\\c"), "\"a\\\"b\\\\c\"");
        assert_eq!(json_str("x\ny\t\r"), "\"x\\ny\\t\\r\"");
        assert_eq!(json_str("\u{01}"), "\"\\u0001\"");
    }

    #[test]
    fn f64_non_finite_is_null() {
        assert_eq!(json_f64(1.5), "1.500000");
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_f64(f64::INFINITY), "null");
    }
}
