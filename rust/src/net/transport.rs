//! Blocking framed TCP transport: shard workers and the driver-side
//! remote shard set.
//!
//! One [`FramedConn`] carries [`Frame`]s over a `std::net::TcpStream`
//! with connect/read/write timeouts. A [`ShardWorker`] hosts N
//! [`IncrementalVerticalDb`] shard replicas behind an accept loop and
//! serves four RPCs — `ApplyBatch`, `MineClasses`, `Stats`, `Shutdown`
//! — each request answered by exactly one reply frame. The driver side
//! is [`RemoteShardSet`]: the same apply/mine surface as the in-process
//! [`crate::stream::ShardedVerticalDb`], so the streaming miner
//! dispatches local-vs-remote behind one enum.
//!
//! **Tid-space alignment across the wire.** The driver keeps its own
//! always-exact store; workers hold replicas of their shard slices.
//! Every `ApplyBatch` reply carries the worker's post-apply
//! [`Bounds`] (`txns`, `live_lo`, `next`), which the driver checks
//! against its mirror — replicas therefore advance (and compact) in
//! lockstep with the driver or get marked lost, never silently drift.
//! `MineClasses` re-checks the invariant from the other side: the
//! worker verifies that the shipped supports of atoms it owns match its
//! replica before mining.
//!
//! **Fault handling.** Each logical RPC is retried once (reconnect +
//! resend) — the bounded-retry shape of the PR-8 scheduler, and exactly
//! what the seeded [`ChaosPolicy`] net faults (connection drops, reply
//! corruption) are bounded against. `ApplyBatch` is not idempotent, so
//! its recovery goes through a `Stats` probe: the replica's bounds
//! reveal whether the apply landed before the reply was lost. A worker
//! that stays unreachable is marked **lost** and the miner degrades to
//! a driver-local full re-mine from its always-exact store.

use std::collections::HashSet;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::time::Duration;

use crate::algorithms::partitioners::ReverseHashClassPartitioner;
use crate::engine::chaos::{ChaosPolicy, NetFault};
use crate::engine::Partitioner;
use crate::error::{Error, Result};
use crate::fim::{Item, MineScratch, PooledSink, Tid, TidBitmap};
use crate::stream::job::mine_class;
use crate::stream::sharded::ShardLoad;
use crate::stream::IncrementalVerticalDb;
use crate::util::Stopwatch;

use super::wire::{Frame, FrameKind, Reader, Wire, HEADER_LEN, MAX_BODY};

/// Timeout for establishing a worker connection.
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);
/// Driver-side read timeout per reply (covers one remote mine).
pub const READ_TIMEOUT: Duration = Duration::from_secs(60);
/// Write timeout for one frame, both sides.
pub const WRITE_TIMEOUT: Duration = Duration::from_secs(10);

/// Network-plane instrumentation cells, resolved once (see [`crate::obs`]).
struct NetObs {
    bytes_tx: &'static crate::obs::Counter,
    bytes_rx: &'static crate::obs::Counter,
    rpc_wall_us: &'static crate::obs::Histogram,
    rpc_retries: &'static crate::obs::Counter,
    workers_lost: &'static crate::obs::Counter,
}

fn net_obs() -> &'static NetObs {
    static OBS: crate::sync::global::OnceLock<NetObs> = crate::sync::global::OnceLock::new();
    OBS.get_or_init(|| NetObs {
        bytes_tx: crate::obs::counter("net.bytes_tx"),
        bytes_rx: crate::obs::counter("net.bytes_rx"),
        rpc_wall_us: crate::obs::histogram("net.rpc_wall_us"),
        rpc_retries: crate::obs::counter("net.rpc_retries"),
        workers_lost: crate::obs::counter("net.workers_lost"),
    })
}

/// Tid-space position of a shard replica: `(txns, live_lo, next)`. The
/// alignment token exchanged on every handshake and apply.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Bounds {
    /// Live transactions in the window.
    pub txns: u64,
    /// First live tid (grows until compaction rebases it to 0).
    pub live_lo: Tid,
    /// Next tid to be assigned.
    pub next: Tid,
}

impl Wire for Bounds {
    fn encode(&self, out: &mut Vec<u8>) {
        self.txns.encode(out);
        self.live_lo.encode(out);
        self.next.encode(out);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(Bounds { txns: r.u64()?, live_lo: r.u32()?, next: r.u32()? })
    }
}

/// `Hello` request: the shard layout this worker participates in.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Hello {
    /// Total shards across the ensemble (= routing modulus).
    pub total_shards: u64,
    /// Global shard indices this worker hosts.
    pub owned: Vec<u32>,
}

impl Wire for Hello {
    fn encode(&self, out: &mut Vec<u8>) {
        self.total_shards.encode(out);
        self.owned.encode(out);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(Hello { total_shards: r.u64()?, owned: Vec::<u32>::decode(r)? })
    }
}

/// `ApplyBatch` request: one normalized window batch plus the eviction
/// hints previewed for it, broadcast to every worker (each filters rows
/// to its owned items, row counts preserved — the tid-space alignment
/// invariant).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ApplyBatchReq {
    /// Normalized rows of the incoming batch.
    pub rows: Vec<Vec<Item>>,
    /// Evictions to run after the append: `(txns, touched items)`.
    pub evictions: Vec<(u64, Vec<Item>)>,
}

impl Wire for ApplyBatchReq {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.rows.len() as u64).encode(out);
        for row in &self.rows {
            row.encode(out);
        }
        (self.evictions.len() as u64).encode(out);
        for (txns, touched) in &self.evictions {
            txns.encode(out);
            touched.encode(out);
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let n = r.seq_len(8)?;
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            rows.push(Vec::<Item>::decode(r)?);
        }
        let n = r.seq_len(16)?;
        let mut evictions = Vec::with_capacity(n);
        for _ in 0..n {
            evictions.push((r.u64()?, Vec::<Item>::decode(r)?));
        }
        Ok(ApplyBatchReq { rows, evictions })
    }
}

/// `MineClasses` request: the full support-ordered atom list (tid
/// columns included — this is the shard-motion payload) plus the
/// absolute support threshold. Each worker derives its own class groups
/// from the shared reverse-hash dealing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MineReq {
    /// Absolute support threshold for this emission.
    pub min_sup: u32,
    /// Frequent atoms in Phase-1 total order: `(item, tid column,
    /// support)`.
    pub atoms: Vec<(Item, TidBitmap, u32)>,
}

impl Wire for MineReq {
    fn encode(&self, out: &mut Vec<u8>) {
        self.min_sup.encode(out);
        (self.atoms.len() as u64).encode(out);
        for (item, bm, support) in &self.atoms {
            item.encode(out);
            support.encode(out);
            bm.encode(out);
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let min_sup = r.u32()?;
        let n = r.seq_len(24)?;
        let mut atoms = Vec::with_capacity(n);
        for _ in 0..n {
            let item = r.u32()?;
            let support = r.u32()?;
            let bm = TidBitmap::decode(r)?;
            atoms.push((item, bm, support));
        }
        Ok(MineReq { min_sup, atoms })
    }
}

/// One shard's scatter-gather result inside a `Mined` reply.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct MinedShard {
    /// Global shard index that mined this group.
    pub shard: u64,
    /// Wall time of the group's mining task.
    pub wall: Duration,
    /// Itemsets emitted into the sink.
    pub itemsets: u64,
    /// The pooled arena of mined itemsets, shipped as one blob.
    pub sink: PooledSink,
}

impl Wire for MinedShard {
    fn encode(&self, out: &mut Vec<u8>) {
        self.shard.encode(out);
        self.wall.encode(out);
        self.itemsets.encode(out);
        self.sink.encode(out);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(MinedShard {
            shard: r.u64()?,
            wall: Duration::decode(r)?,
            itemsets: r.u64()?,
            sink: PooledSink::decode(r)?,
        })
    }
}

/// Per-shard accounting in a `StatsReply`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkerShardStats {
    /// Global shard index.
    pub shard: u64,
    /// Rows that contained at least one owned item.
    pub rows: u64,
    /// Postings appended to the replica.
    pub postings: u64,
    /// The replica's tid-space position.
    pub bounds: Bounds,
}

impl Wire for WorkerShardStats {
    fn encode(&self, out: &mut Vec<u8>) {
        self.shard.encode(out);
        self.rows.encode(out);
        self.postings.encode(out);
        self.bounds.encode(out);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(WorkerShardStats {
            shard: r.u64()?,
            rows: r.u64()?,
            postings: r.u64()?,
            bounds: Bounds::decode(r)?,
        })
    }
}

/// One framed, timeout-guarded TCP connection. Every transport failure
/// (including timeouts and short reads) surfaces as [`Error::Net`].
#[derive(Debug)]
pub struct FramedConn {
    stream: TcpStream,
    peer: String,
}

impl FramedConn {
    /// Connect to `addr` (`host:port`) with [`CONNECT_TIMEOUT`] and arm
    /// the read/write timeouts. Every resolved address is tried in
    /// order (a hostname may resolve IPv6-first against an IPv4-only
    /// listener); the last error is reported if none accepts.
    pub fn connect(addr: &str) -> Result<FramedConn> {
        let resolved: Vec<SocketAddr> = addr
            .to_socket_addrs()
            .map_err(|e| Error::net(format!("cannot resolve {addr}: {e}")))?
            .collect();
        if resolved.is_empty() {
            return Err(Error::net(format!("{addr} resolves to no address")));
        }
        let mut last = None;
        for sa in &resolved {
            match TcpStream::connect_timeout(sa, CONNECT_TIMEOUT) {
                Ok(stream) => return FramedConn::from_stream(stream, READ_TIMEOUT),
                Err(e) => last = Some(e),
            }
        }
        let e = last.expect("resolved is non-empty");
        Err(Error::net(format!("cannot connect to {addr}: {e}")))
    }

    /// Wrap an accepted stream (worker side: no read timeout, the driver
    /// is allowed to idle between batches).
    fn accept(stream: TcpStream) -> Result<FramedConn> {
        FramedConn::from_stream(stream, Duration::ZERO)
    }

    fn from_stream(stream: TcpStream, read_timeout: Duration) -> Result<FramedConn> {
        let peer = stream.peer_addr().map(|a| a.to_string()).unwrap_or_else(|_| "?".into());
        let wrap = |e: std::io::Error| Error::net(format!("socket setup to {peer}: {e}"));
        stream.set_nodelay(true).map_err(wrap)?;
        let read = if read_timeout.is_zero() { None } else { Some(read_timeout) };
        stream.set_read_timeout(read).map_err(wrap)?;
        stream.set_write_timeout(Some(WRITE_TIMEOUT)).map_err(wrap)?;
        Ok(FramedConn { peer, stream })
    }

    /// The peer address, for diagnostics.
    pub fn peer(&self) -> &str {
        &self.peer
    }

    /// Write one frame (header + body in a single buffer).
    pub fn send(&mut self, frame: &Frame) -> Result<()> {
        let bytes = frame.encode();
        self.stream
            .write_all(&bytes)
            .and_then(|()| self.stream.flush())
            .map_err(|e| Error::net(format!("send to {}: {e}", self.peer)))?;
        if crate::obs::enabled() {
            net_obs().bytes_tx.incr(bytes.len() as u64);
        }
        Ok(())
    }

    /// Read one frame's raw bytes (header + body). Split from
    /// [`FramedConn::recv`] so the chaos reply-corruption fault can flip
    /// a byte *before* the frame is decoded — corruption then flows
    /// through the real CRC/decode rejection path.
    pub fn recv_bytes(&mut self) -> Result<Vec<u8>> {
        let mut header = [0u8; HEADER_LEN];
        self.stream
            .read_exact(&mut header)
            .map_err(|e| Error::net(format!("recv header from {}: {e}", self.peer)))?;
        let (_, len) = Frame::parse_header(&header)?;
        debug_assert!(len <= MAX_BODY, "parse_header bounds the body");
        let mut bytes = vec![0u8; HEADER_LEN + len];
        bytes[..HEADER_LEN].copy_from_slice(&header);
        self.stream
            .read_exact(&mut bytes[HEADER_LEN..])
            .map_err(|e| Error::net(format!("recv body from {}: {e}", self.peer)))?;
        if crate::obs::enabled() {
            net_obs().bytes_rx.incr(bytes.len() as u64);
        }
        Ok(bytes)
    }

    /// Read and decode one frame.
    pub fn recv(&mut self) -> Result<Frame> {
        Frame::decode(&self.recv_bytes()?)
    }
}

// ---------------------------------------------------------------------------
// Worker side
// ---------------------------------------------------------------------------

/// Replica state a worker builds from the driver's `Hello` and keeps
/// across reconnects (a chaos-dropped connection must not reset the
/// replicas — the driver verifies continuity through the handshake
/// bounds).
struct WorkerState {
    total: usize,
    owned: Vec<usize>,
    router: ReverseHashClassPartitioner,
    shards: Vec<IncrementalVerticalDb>,
    loads: Vec<ShardLoad>,
    /// Scratch dirty set for the replica appends (the driver owns the
    /// real dirty bookkeeping).
    dirty: HashSet<Item>,
}

impl WorkerState {
    fn new(hello: &Hello) -> Result<WorkerState> {
        let total = usize::try_from(hello.total_shards)
            .map_err(|_| Error::net("total_shards overflows usize"))?;
        if total == 0 || hello.owned.is_empty() {
            return Err(Error::net("hello must name at least one shard"));
        }
        let owned: Vec<usize> = hello.owned.iter().map(|&s| s as usize).collect();
        if let Some(&bad) = owned.iter().find(|&&s| s >= total) {
            return Err(Error::net(format!("owned shard {bad} out of range 0..{total}")));
        }
        Ok(WorkerState {
            total,
            owned: owned.clone(),
            router: ReverseHashClassPartitioner::new(total),
            shards: owned.iter().map(|_| IncrementalVerticalDb::new()).collect(),
            loads: vec![ShardLoad::default(); owned.len()],
            dirty: HashSet::new(),
        })
    }

    /// The replicas' shared tid-space position; errors if the owned
    /// shards ever disagree (an internal invariant violation).
    fn bounds(&self) -> Result<Bounds> {
        let first = &self.shards[0];
        let (live_lo, next) = first.tid_bounds();
        let bounds = Bounds { txns: first.txns() as u64, live_lo, next };
        for (k, shard) in self.shards.iter().enumerate() {
            let (lo, hi) = shard.tid_bounds();
            if (shard.txns() as u64, lo, hi) != (bounds.txns, bounds.live_lo, bounds.next) {
                return Err(Error::net(format!(
                    "worker replicas out of alignment: shard slot {k} at ({}, {lo}, {hi}), \
                     slot 0 at ({}, {}, {})",
                    shard.txns(),
                    bounds.txns,
                    bounds.live_lo,
                    bounds.next
                )));
            }
        }
        Ok(bounds)
    }

    /// Apply one broadcast batch: per owned shard, filter rows to owned
    /// items (row count preserved) and run append-then-evictions —
    /// byte-for-byte the `ShardedVerticalDb` scatter semantics, so the
    /// replica advances and compacts in lockstep with the driver mirror.
    fn apply(&mut self, req: &ApplyBatchReq) -> Result<Bounds> {
        for k in 0..self.owned.len() {
            let s = self.owned[k];
            let shard_rows: Vec<Vec<Item>> = req
                .rows
                .iter()
                .map(|row| {
                    row.iter().copied().filter(|&i| self.router.shard_of_item(i) == s).collect()
                })
                .collect();
            for row in &shard_rows {
                if !row.is_empty() {
                    self.loads[k].rows += 1;
                    self.loads[k].postings += row.len() as u64;
                }
            }
            self.dirty.clear();
            self.shards[k].append(&shard_rows, &mut self.dirty);
            for (txns, touched) in &req.evictions {
                let txns = usize::try_from(*txns)
                    .map_err(|_| Error::net("eviction txns overflows usize"))?;
                let hint: Vec<Item> = touched
                    .iter()
                    .copied()
                    .filter(|&i| self.router.shard_of_item(i) == s)
                    .collect();
                self.dirty.clear();
                self.shards[k].evict_touched(txns, &hint, &mut self.dirty);
            }
        }
        self.bounds()
    }

    /// Mine this worker's class groups over the shipped atoms. Before
    /// mining, the shipped supports of owned atoms are checked against
    /// the replica — the cross-wire half of the alignment invariant.
    fn mine(&mut self, req: &MineReq) -> Result<Vec<MinedShard>> {
        for (item, _, support) in &req.atoms {
            let s = self.router.shard_of_item(*item);
            if let Some(k) = self.owned.iter().position(|&o| o == s) {
                let local = self.shards[k].support(*item);
                if local != *support {
                    return Err(Error::net(format!(
                        "tid-space misalignment: item {item} has support {local} on the \
                         replica, driver shipped {support}"
                    )));
                }
            }
        }
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.owned.len()];
        if req.atoms.len() >= 2 {
            for i in 0..req.atoms.len() - 1 {
                let s = self.router.partition(&i);
                if let Some(k) = self.owned.iter().position(|&o| o == s) {
                    groups[k].push(i);
                }
            }
        }
        let mut mined = Vec::new();
        for (k, group) in groups.into_iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            let sw = Stopwatch::start();
            let mut found = PooledSink::with_capacity(group.len() * 8, group.len() * 4);
            let mut scratch = MineScratch::new();
            for i in group {
                found = mine_class(&req.atoms, i, req.min_sup, found, &mut scratch);
            }
            mined.push(MinedShard {
                shard: self.owned[k] as u64,
                wall: sw.elapsed(),
                itemsets: found.len() as u64,
                sink: found,
            });
        }
        Ok(mined)
    }

    fn stats(&self) -> Result<Vec<WorkerShardStats>> {
        let bounds = self.bounds()?;
        Ok(self
            .owned
            .iter()
            .zip(&self.loads)
            .map(|(&shard, load)| WorkerShardStats {
                shard: shard as u64,
                rows: load.rows,
                postings: load.postings,
                bounds,
            })
            .collect())
    }
}

/// A bound shard-worker endpoint: accepts driver connections serially
/// and serves the shard RPCs until a `Shutdown` frame arrives. Replica
/// state persists across reconnects; the handshake bounds let the
/// driver verify continuity.
#[derive(Debug)]
pub struct ShardWorker {
    listener: TcpListener,
}

impl ShardWorker {
    /// Bind the listen address (`host:port`; port `0` picks a free one).
    pub fn bind(addr: &str) -> Result<ShardWorker> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::net(format!("cannot bind {addr}: {e}")))?;
        Ok(ShardWorker { listener })
    }

    /// The actually-bound address (resolves port `0`).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        self.listener.local_addr().map_err(|e| Error::net(format!("local_addr: {e}")))
    }

    /// Serve until a `Shutdown` RPC. Connections are handled one at a
    /// time (the driver holds one connection per worker); a dropped
    /// connection sends the worker back to `accept` with its replica
    /// state intact.
    pub fn run(self) -> Result<()> {
        let mut state: Option<WorkerState> = None;
        loop {
            let (stream, _) = self
                .listener
                .accept()
                .map_err(|e| Error::net(format!("accept: {e}")))?;
            let mut conn = match FramedConn::accept(stream) {
                Ok(c) => c,
                Err(_) => continue,
            };
            match serve_conn(&mut conn, &mut state) {
                Ok(true) => return Ok(()),
                // Connection died (driver gone, reconnect pending) or the
                // stream turned to garbage — wait for the next connection.
                Ok(false) | Err(_) => continue,
            }
        }
    }
}

/// Serve one driver connection; `Ok(true)` means a `Shutdown` was
/// acknowledged and the worker should exit.
fn serve_conn(conn: &mut FramedConn, state: &mut Option<WorkerState>) -> Result<bool> {
    loop {
        let frame = conn.recv()?;
        let reply = handle_request(&frame, state);
        match reply {
            Ok(reply) => {
                conn.send(&reply)?;
                if frame.kind == FrameKind::Shutdown {
                    return Ok(true);
                }
            }
            Err(e) => {
                // Request-level failure: report it in-band and keep
                // serving — a misaligned mine must not kill the worker.
                conn.send(&Frame::new(FrameKind::Err, e.to_string().into_bytes()))?;
            }
        }
    }
}

fn handle_request(frame: &Frame, state: &mut Option<WorkerState>) -> Result<Frame> {
    match frame.kind {
        FrameKind::Hello => {
            let hello = Hello::from_bytes(&frame.body)?;
            if let Some(st) = state.as_ref() {
                let owned: Vec<usize> = hello.owned.iter().map(|&s| s as usize).collect();
                if st.total as u64 != hello.total_shards || st.owned != owned {
                    return Err(Error::net(format!(
                        "hello layout changed: worker hosts {:?} of {}, driver says {:?} of {}",
                        st.owned, st.total, owned, hello.total_shards
                    )));
                }
            } else {
                *state = Some(WorkerState::new(&hello)?);
            }
            let st = state.as_ref().expect("hello just ensured state");
            Ok(Frame::from_msg(FrameKind::HelloAck, &st.bounds()?))
        }
        FrameKind::ApplyBatch => {
            let st = state.as_mut().ok_or_else(|| Error::net("ApplyBatch before Hello"))?;
            let req = ApplyBatchReq::from_bytes(&frame.body)?;
            Ok(Frame::from_msg(FrameKind::ApplyAck, &st.apply(&req)?))
        }
        FrameKind::MineClasses => {
            let st = state.as_mut().ok_or_else(|| Error::net("MineClasses before Hello"))?;
            let req = MineReq::from_bytes(&frame.body)?;
            Ok(Frame::from_msg(FrameKind::Mined, &st.mine(&req)?))
        }
        FrameKind::Stats => {
            let st = state.as_ref().ok_or_else(|| Error::net("Stats before Hello"))?;
            Ok(Frame::from_msg(FrameKind::StatsReply, &st.stats()?))
        }
        FrameKind::Shutdown => Ok(Frame::new(FrameKind::Ok, Vec::new())),
        other => Err(Error::net(format!("unexpected request kind {other:?}"))),
    }
}

// ---------------------------------------------------------------------------
// Driver side
// ---------------------------------------------------------------------------

/// One remote worker slot.
#[derive(Debug)]
struct Worker {
    addr: String,
    conn: Option<FramedConn>,
    lost: bool,
    /// Logical RPC sequence number — the stable chaos victim identity;
    /// retries of one RPC share it, so injected faults stay bounded.
    rpc_seq: u64,
}

/// Cumulative remote-plane accounting (driver side), surfaced through
/// [`RemoteShardSet::net_stats`] and the `net.*` metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RemoteNetStats {
    /// Logical RPCs issued.
    pub rpcs: u64,
    /// RPC attempts that failed and were retried or probed.
    pub retries: u64,
    /// Workers marked lost (unreachable after bounded retry).
    pub workers_lost: u64,
}

/// Driver-side handle to an ensemble of shard workers: one global shard
/// per worker, the same apply/mine surface as the in-process
/// [`crate::stream::ShardedVerticalDb`]. See the module docs for the
/// alignment and fault-handling contracts.
#[derive(Debug)]
pub struct RemoteShardSet {
    workers: Vec<Worker>,
    total_shards: usize,
    /// The driver mirror's bounds after the last successful apply — what
    /// reconnect handshakes and recovery probes are verified against.
    bounds: Bounds,
    /// Post-apply bounds of the apply currently in flight, if any. A
    /// replica that already landed the apply before its reply was lost
    /// sits at these bounds, so the recovery reconnect's handshake must
    /// accept them alongside the pre-apply `bounds`.
    applying: Option<Bounds>,
    chaos: Option<ChaosPolicy>,
    stats: RemoteNetStats,
}

impl RemoteShardSet {
    /// Connect to one worker per address and hand shard `w` to worker
    /// `w` (routing modulus = worker count, matching the in-process
    /// `--shards N` twin). Workers must be fresh: a handshake returning
    /// non-zero bounds means the endpoint holds another run's state.
    pub fn connect(addrs: &[String]) -> Result<RemoteShardSet> {
        if addrs.is_empty() {
            return Err(Error::net("need at least one worker address"));
        }
        let mut set = RemoteShardSet {
            workers: addrs
                .iter()
                .map(|a| Worker { addr: a.clone(), conn: None, lost: false, rpc_seq: 0 })
                .collect(),
            total_shards: addrs.len(),
            bounds: Bounds::default(),
            applying: None,
            chaos: None,
            stats: RemoteNetStats::default(),
        };
        for w in 0..set.workers.len() {
            set.ensure_conn(w)?;
        }
        Ok(set)
    }

    /// Arm seeded net faults (connection drops / reply corruption) for
    /// every subsequent RPC. The policy is cloned, so the attempt
    /// counters are this set's own.
    pub fn with_chaos(mut self, chaos: Option<&ChaosPolicy>) -> RemoteShardSet {
        self.chaos = chaos.cloned();
        self
    }

    /// Number of workers (= total shards).
    pub fn worker_count(&self) -> usize {
        self.workers.len()
    }

    /// Total shards across the ensemble.
    pub fn total_shards(&self) -> usize {
        self.total_shards
    }

    /// True while every worker is reachable — the precondition for
    /// remote mining (a lost worker's classes would go unmined).
    pub fn all_live(&self) -> bool {
        self.workers.iter().all(|w| !w.lost)
    }

    /// Cumulative RPC/retry/loss accounting.
    pub fn net_stats(&self) -> RemoteNetStats {
        self.stats
    }

    /// Broadcast one batch to every live worker and verify each reply
    /// against `after` (the driver mirror's post-apply bounds).
    /// Unreachable or misaligned workers are marked lost — the mirror
    /// stays exact regardless, so this never fails the ingest path.
    pub fn apply_batch(
        &mut self,
        rows: &[Vec<Item>],
        evictions: &[(usize, Vec<Item>)],
        after: Bounds,
    ) {
        let req = ApplyBatchReq {
            rows: rows.to_vec(),
            evictions: evictions
                .iter()
                .map(|(txns, touched)| (*txns as u64, touched.clone()))
                .collect(),
        };
        let frame = Frame::from_msg(FrameKind::ApplyBatch, &req);
        let before = self.bounds;
        for w in 0..self.workers.len() {
            if self.workers[w].lost {
                continue;
            }
            if let Err(e) = self.apply_one(w, &frame, before, after) {
                self.mark_lost(w, &e);
            }
        }
        self.bounds = after;
    }

    /// Scatter-gather a mine over the shipped atoms: every live worker
    /// mines its class groups and replies one `Mined` frame. Requires
    /// all workers live (class coverage is partitioned across them);
    /// any failure marks the worker lost and errors, letting the
    /// caller's bounded-retry path degrade to a driver-local re-mine.
    pub fn mine_classes(
        &mut self,
        atoms: &[(Item, TidBitmap, u32)],
        min_sup: u32,
    ) -> Result<Vec<MinedShard>> {
        if !self.all_live() {
            return Err(Error::net("remote mine with lost workers"));
        }
        let req = MineReq { min_sup, atoms: atoms.to_vec() };
        let frame = Frame::from_msg(FrameKind::MineClasses, &req);
        let mut mined = Vec::new();
        for w in 0..self.workers.len() {
            let reply = match self.rpc_idempotent(w, &frame) {
                Ok(r) => r,
                Err(e) => {
                    self.mark_lost(w, &e);
                    return Err(e);
                }
            };
            let shards: Vec<MinedShard> = reply.expect(FrameKind::Mined).map_err(|e| {
                self.mark_lost(w, &e);
                e
            })?;
            mined.extend(shards);
        }
        Ok(mined)
    }

    /// Gather per-shard accounting from every live worker. A worker
    /// that fails both attempts is marked lost and skipped — stats from
    /// the workers that responded are still returned, so end-of-run
    /// reporting survives a worker dying between emissions.
    pub fn worker_stats(&mut self) -> Result<Vec<WorkerShardStats>> {
        let frame = Frame::new(FrameKind::Stats, Vec::new());
        let mut out = Vec::new();
        for w in 0..self.workers.len() {
            if self.workers[w].lost {
                continue;
            }
            let stats = self
                .rpc_idempotent(w, &frame)
                .and_then(|reply| reply.expect::<Vec<WorkerShardStats>>(FrameKind::StatsReply));
            match stats {
                Ok(s) => out.extend(s),
                Err(e) => self.mark_lost(w, &e),
            }
        }
        Ok(out)
    }

    /// Best-effort `Shutdown` to every reachable worker (the worker
    /// process exits after acknowledging).
    pub fn shutdown(&mut self) {
        for w in 0..self.workers.len() {
            self.shutdown_worker(w);
        }
    }

    /// Best-effort `Shutdown` to one worker — drains a single endpoint
    /// (maintenance, or the worker-loss tests). The slot is *not*
    /// marked lost here: the next RPC touching it discovers the dead
    /// endpoint and takes the organic retry → probe → mark-lost path.
    pub fn shutdown_worker(&mut self, w: usize) {
        if self.workers[w].lost {
            return;
        }
        let frame = Frame::new(FrameKind::Shutdown, Vec::new());
        let _ = self.rpc_idempotent(w, &frame);
        self.workers[w].conn = None;
    }

    /// Apply with idempotency recovery: on a failed attempt, probe the
    /// replica's bounds — `after` means the apply landed and only the
    /// reply was lost; `before` means it never arrived and a resend is
    /// safe; anything else is drift and the worker is lost. While the
    /// apply is in flight, reconnect handshakes accept either bound
    /// (see [`RemoteShardSet::ensure_conn`]).
    fn apply_one(&mut self, w: usize, frame: &Frame, before: Bounds, after: Bounds) -> Result<()> {
        self.applying = Some(after);
        let result = self.apply_one_inner(w, frame, before, after);
        self.applying = None;
        result
    }

    fn apply_one_inner(
        &mut self,
        w: usize,
        frame: &Frame,
        before: Bounds,
        after: Bounds,
    ) -> Result<()> {
        let seq = self.next_seq(w);
        let verify = |got: Bounds| {
            if got == after {
                Ok(())
            } else {
                Err(Error::net(format!(
                    "replica bounds {got:?} diverged from driver mirror {after:?}"
                )))
            }
        };
        match self.rpc_once(w, seq, frame) {
            Ok(reply) => verify(reply.expect::<Bounds>(FrameKind::ApplyAck)?),
            Err(_) => {
                self.note_retry();
                let got = self.probe_bounds(w)?;
                if got == after {
                    return Ok(());
                }
                if got != before {
                    return Err(Error::net(format!(
                        "replica bounds {got:?} match neither pre-apply {before:?} nor \
                         post-apply {after:?}"
                    )));
                }
                // Never applied: resend under the same sequence number
                // (chaos already spent this RPC's injection budget).
                let reply = self.rpc_once(w, seq, frame)?;
                verify(reply.expect::<Bounds>(FrameKind::ApplyAck)?)
            }
        }
    }

    /// Read the replica's current bounds via a `Stats` RPC.
    fn probe_bounds(&mut self, w: usize) -> Result<Bounds> {
        let reply = self.rpc_idempotent(w, &Frame::new(FrameKind::Stats, Vec::new()))?;
        let stats: Vec<WorkerShardStats> = reply.expect(FrameKind::StatsReply)?;
        let first = stats
            .first()
            .ok_or_else(|| Error::net("stats probe returned no shards"))?;
        if stats.iter().any(|s| s.bounds != first.bounds) {
            return Err(Error::net("worker replicas disagree on bounds"));
        }
        Ok(first.bounds)
    }

    /// One logical idempotent RPC: a failed first attempt reconnects and
    /// resends once under the same sequence number.
    fn rpc_idempotent(&mut self, w: usize, frame: &Frame) -> Result<Frame> {
        let seq = self.next_seq(w);
        match self.rpc_once(w, seq, frame) {
            Ok(reply) => Ok(reply),
            Err(_) => {
                self.note_retry();
                self.rpc_once(w, seq, frame)
            }
        }
    }

    /// One RPC attempt: (re)connect if needed, send, receive, decode —
    /// with the seeded net faults applied at their injection points.
    fn rpc_once(&mut self, w: usize, seq: u64, frame: &Frame) -> Result<Frame> {
        let fault = self.chaos.as_ref().and_then(|c| c.net_fault(w as u64, seq));
        if fault == Some(NetFault::DropConnection) {
            self.workers[w].conn = None;
            return Err(Error::net(format!(
                "chaos: connection to {} dropped",
                self.workers[w].addr
            )));
        }
        self.ensure_conn(w)?;
        let sw = Stopwatch::start();
        let mut sp = crate::obs::span("net.rpc");
        sp.arg("worker", w as u64).arg("kind", frame.kind as u64);
        let result = self.exchange(w, fault, frame);
        if crate::obs::enabled() {
            net_obs().rpc_wall_us.record(sw.elapsed().as_micros() as u64);
        }
        if result.is_err() {
            // A failed attempt leaves the stream in an unknown framing
            // position; drop it so the retry starts on a clean socket.
            self.workers[w].conn = None;
        }
        result
    }

    /// Send + receive one frame on the established connection, applying
    /// the seeded reply-corruption fault (a flipped byte) *before*
    /// decode so corruption is rejected by the real CRC path.
    fn exchange(&mut self, w: usize, fault: Option<NetFault>, frame: &Frame) -> Result<Frame> {
        let conn = self.workers[w]
            .conn
            .as_mut()
            .ok_or_else(|| Error::net("connection missing after ensure_conn"))?;
        conn.send(frame)?;
        let mut bytes = conn.recv_bytes()?;
        if fault == Some(NetFault::CorruptReply) {
            let mid = bytes.len() / 2;
            bytes[mid] ^= 0x01;
        }
        Frame::decode(&bytes)
    }

    /// Connect + handshake if this worker has no live connection. The
    /// `HelloAck` bounds must match the driver mirror — a restarted
    /// (state-lost) worker is caught here, not at the next mine.
    /// During apply recovery the replica may legitimately sit at the
    /// in-flight post-apply bounds (it applied, the reply was lost), so
    /// `applying` is accepted too; `apply_one`'s probe then settles
    /// which side of the apply the replica is on.
    fn ensure_conn(&mut self, w: usize) -> Result<()> {
        if self.workers[w].conn.is_some() {
            return Ok(());
        }
        let mut conn = FramedConn::connect(&self.workers[w].addr)?;
        let hello = Hello { total_shards: self.total_shards as u64, owned: vec![w as u32] };
        conn.send(&Frame::from_msg(FrameKind::Hello, &hello))?;
        let ack: Bounds = conn.recv()?.expect(FrameKind::HelloAck)?;
        if ack != self.bounds && Some(ack) != self.applying {
            return Err(Error::net(format!(
                "worker {} joined at bounds {ack:?}, driver mirror at {:?} — replica \
                 state was lost",
                self.workers[w].addr, self.bounds
            )));
        }
        self.workers[w].conn = Some(conn);
        Ok(())
    }

    fn next_seq(&mut self, w: usize) -> u64 {
        let seq = self.workers[w].rpc_seq;
        self.workers[w].rpc_seq += 1;
        self.stats.rpcs += 1;
        seq
    }

    fn note_retry(&mut self) {
        self.stats.retries += 1;
        if crate::obs::enabled() {
            net_obs().rpc_retries.incr(1);
        }
    }

    fn mark_lost(&mut self, w: usize, why: &Error) {
        if !self.workers[w].lost {
            self.workers[w].lost = true;
            self.workers[w].conn = None;
            self.stats.workers_lost += 1;
            if crate::obs::enabled() {
                net_obs().workers_lost.incr(1);
            }
            eprintln!("net: worker {} lost: {why}", self.workers[w].addr);
        }
    }
}
