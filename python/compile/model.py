"""L2: the support-counting compute graph, calling the L1 kernels.

The "model" of this paper is not a neural network — it is Eclat's
support-counting arithmetic, the part of the system with dense,
accelerator-shaped compute:

* ``phase2_graph``: for a 0/1 transaction block, item supports (column
  sums) and the co-occurrence matrix (the paper's triangular-matrix
  Phase-2) in one fused graph built on the ``cooc`` Pallas kernel.
* ``cooc_graph``: cross-block co-occurrence ``A^T B`` for tiling the item
  dimension when the vocabulary exceeds one tile.
* ``intersect_graph``: batched tidset-intersection supports on the
  ``popcount`` Pallas kernel (Algorithm 1's inner loop).

``aot.py`` lowers each of these once to HLO text; the rust runtime
(`rust/src/runtime/`) compiles and executes them via PJRT. Python never
runs at mining time.
"""

import jax.numpy as jnp

from .kernels.cooc import cooc
from .kernels.popcount import intersect_support


def phase2_graph(a):
    """Item supports + co-occurrence counts of one transaction block.

    Args:
      a: ``(T, I)`` f32 0/1 block.

    Returns:
      ``(supports (I,), cooc (I, I))`` — both f32 counts.
    """
    supports = jnp.sum(a, axis=0)
    counts = cooc(a, a)
    return supports, counts


def cooc_graph(a, b):
    """Cross-tile co-occurrence ``A^T B`` (item-dimension tiling)."""
    return (cooc(a, b),)


def intersect_graph(a, b):
    """Batched bitmap intersection supports (int32 per row)."""
    return (intersect_support(a, b),)
