//! Hash shuffle — the engine's wide-dependency data plane.
//!
//! A shuffle has `m` map tasks (one per parent partition) and `r` reduce
//! partitions. Each map task writes one type-erased bucket per reduce
//! partition; reduce-side compute fetches column `r` across all map
//! outputs. The store also tracks which shuffles are fully materialized so
//! the stage scheduler runs each map stage exactly once — and can
//! re-materialize after an injected fault (lineage recovery).

use std::any::Any;
use std::collections::{HashMap, HashSet};

use crate::error::{Error, Result};
// Poison-tolerant locking (a panicked executor must not cascade into
// every other task touching the store — buckets are only ever inserted
// or removed whole) now comes from the canonical `crate::sync` helpers;
// building on the shim also makes the store loom-modelable
// (tests/loom_models.rs).
use crate::sync::atomic::{AtomicU64, Ordering};
use crate::sync::global::OnceLock;
use crate::sync::{read_unpoisoned as read, write_unpoisoned as write, RwLock};

/// Shuffle instrumentation cells, resolved once (see [`crate::obs`]).
struct ShuffleObs {
    puts: &'static crate::obs::Counter,
    fetches: &'static crate::obs::Counter,
    records: &'static crate::obs::Counter,
}

fn shuffle_obs() -> &'static ShuffleObs {
    static OBS: OnceLock<ShuffleObs> = OnceLock::new();
    OBS.get_or_init(|| ShuffleObs {
        puts: crate::obs::counter("engine.shuffle.puts"),
        fetches: crate::obs::counter("engine.shuffle.fetches"),
        records: crate::obs::counter("engine.shuffle.records"),
    })
}

/// Identifies one shuffle (one wide dependency).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ShuffleId(pub usize);

type Bucket = Box<dyn Any + Send + Sync>;

/// In-memory map-output store: `(shuffle, map task, reduce partition) →
/// bucket`.
pub struct ShuffleStore {
    buckets: RwLock<HashMap<(ShuffleId, usize, usize), Bucket>>,
    materialized: RwLock<HashSet<ShuffleId>>,
    bytes_approx: AtomicU64,
    records: AtomicU64,
}

// Manual (not derived) so it only needs `new()` on the shimmed types —
// loom's primitives do not all implement `Default`.
impl Default for ShuffleStore {
    fn default() -> Self {
        ShuffleStore {
            buckets: RwLock::new(HashMap::new()),
            materialized: RwLock::new(HashSet::new()),
            bytes_approx: AtomicU64::new(0),
            records: AtomicU64::new(0),
        }
    }
}

impl ShuffleStore {
    /// Create an empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Write one map task's bucket for one reduce partition.
    pub fn put<T: Send + Sync + 'static>(
        &self,
        shuffle: ShuffleId,
        map_task: usize,
        reduce: usize,
        data: Vec<T>,
    ) {
        // ordering: Relaxed — traffic counters are independent tallies;
        // RMW atomicity alone keeps the totals exact (loom-checked in
        // loom_shuffle_concurrent_puts_*), and readers of the buckets
        // synchronize through the RwLock, not these cells.
        self.records.fetch_add(data.len() as u64, Ordering::Relaxed);
        // ordering: Relaxed — as above.
        self.bytes_approx
            .fetch_add((data.len() * std::mem::size_of::<T>()) as u64, Ordering::Relaxed);
        if crate::obs::enabled() {
            let o = shuffle_obs();
            o.puts.incr(1);
            o.records.incr(data.len() as u64);
        }
        write(&self.buckets).insert((shuffle, map_task, reduce), Box::new(data));
    }

    /// Fetch all buckets for reduce partition `reduce`, concatenated in map
    /// task order. Cloning out keeps the store reusable for recomputes.
    /// Missing buckets are skipped (an empty bucket and no bucket are
    /// indistinguishable by design); a bucket stored with a different
    /// element type is an [`Error::Engine`] — callers inside tasks turn
    /// it into a clean job failure instead of an executor panic.
    pub fn fetch<T: Clone + 'static>(
        &self,
        shuffle: ShuffleId,
        num_map_tasks: usize,
        reduce: usize,
    ) -> Result<Vec<T>> {
        if crate::obs::enabled() {
            shuffle_obs().fetches.incr(1);
        }
        let buckets = read(&self.buckets);
        let mut out = Vec::new();
        for m in 0..num_map_tasks {
            if let Some(b) = buckets.get(&(shuffle, m, reduce)) {
                let v = b.downcast_ref::<Vec<T>>().ok_or_else(|| {
                    Error::engine(format!(
                        "shuffle type mismatch: bucket (shuffle {}, map {m}, reduce {reduce}) \
                         stored with a different element type",
                        shuffle.0
                    ))
                })?;
                out.extend(v.iter().cloned());
            }
        }
        Ok(out)
    }

    /// Mark a shuffle's map stage complete.
    pub fn mark_materialized(&self, shuffle: ShuffleId) {
        write(&self.materialized).insert(shuffle);
    }

    /// Whether the map stage for this shuffle already ran.
    pub fn is_materialized(&self, shuffle: ShuffleId) -> bool {
        read(&self.materialized).contains(&shuffle)
    }

    /// Fault injection: drop every map output of a shuffle and clear its
    /// materialized flag — the next job that needs it recomputes the map
    /// stage through lineage. Returns the number of dropped buckets.
    pub fn lose(&self, shuffle: ShuffleId) -> usize {
        let mut buckets = write(&self.buckets);
        let keys: Vec<_> = buckets.keys().filter(|(s, _, _)| *s == shuffle).cloned().collect();
        for k in &keys {
            buckets.remove(k);
        }
        write(&self.materialized).remove(&shuffle);
        keys.len()
    }

    /// (records shuffled, approximate payload bytes) — feeds metrics.
    pub fn traffic(&self) -> (u64, u64) {
        // ordering: Relaxed — monitoring reads of independent tallies.
        (self.records.load(Ordering::Relaxed), self.bytes_approx.load(Ordering::Relaxed))
    }

    /// Number of buckets currently stored.
    pub fn len(&self) -> usize {
        read(&self.buckets).len()
    }

    /// True when no buckets stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// Not compiled under `cfg(loom)`; the concurrent coverage lives in
// `tests/loom_models.rs`.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn put_fetch_concatenates_in_map_order() {
        let s = ShuffleStore::new();
        let id = ShuffleId(0);
        s.put(id, 1, 0, vec![("b", 2)]);
        s.put(id, 0, 0, vec![("a", 1)]);
        s.put(id, 0, 1, vec![("z", 9)]);
        let r0: Vec<(&str, i32)> = s.fetch(id, 2, 0).unwrap();
        assert_eq!(r0, vec![("a", 1), ("b", 2)]);
        let r1: Vec<(&str, i32)> = s.fetch(id, 2, 1).unwrap();
        assert_eq!(r1, vec![("z", 9)]);
        let r2: Vec<(&str, i32)> = s.fetch(id, 2, 2).unwrap();
        assert!(r2.is_empty());
    }

    #[test]
    fn type_mismatch_is_an_error_not_a_panic() {
        let s = ShuffleStore::new();
        let id = ShuffleId(9);
        s.put(id, 0, 0, vec![1u32, 2]);
        let err = s.fetch::<String>(id, 1, 0).unwrap_err();
        assert!(err.to_string().contains("shuffle type mismatch"), "{err}");
        // The store is still usable with the right type.
        assert_eq!(s.fetch::<u32>(id, 1, 0).unwrap(), vec![1, 2]);
    }

    #[test]
    fn materialization_flag_and_loss() {
        let s = ShuffleStore::new();
        let id = ShuffleId(3);
        assert!(!s.is_materialized(id));
        s.put(id, 0, 0, vec![1u64]);
        s.mark_materialized(id);
        assert!(s.is_materialized(id));
        assert_eq!(s.lose(id), 1);
        assert!(!s.is_materialized(id));
        let empty: Vec<u64> = s.fetch(id, 1, 0).unwrap();
        assert!(empty.is_empty());
    }

    #[test]
    fn traffic_counters_accumulate() {
        let s = ShuffleStore::new();
        s.put(ShuffleId(1), 0, 0, vec![1u32, 2, 3]);
        let (recs, bytes) = s.traffic();
        assert_eq!(recs, 3);
        assert_eq!(bytes, 12);
    }

    #[test]
    fn independent_shuffles_do_not_collide() {
        let s = ShuffleStore::new();
        s.put(ShuffleId(1), 0, 0, vec![1u8]);
        s.put(ShuffleId(2), 0, 0, vec![2u8]);
        assert_eq!(s.fetch::<u8>(ShuffleId(1), 1, 0).unwrap(), vec![1]);
        assert_eq!(s.fetch::<u8>(ShuffleId(2), 1, 0).unwrap(), vec![2]);
        s.lose(ShuffleId(1));
        assert_eq!(s.fetch::<u8>(ShuffleId(2), 1, 0).unwrap(), vec![2]);
    }
}
