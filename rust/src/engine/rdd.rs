//! Resilient Distributed Datasets — the lazy, partitioned, lineage-tracked
//! collection at the core of the engine.
//!
//! Semantics follow Spark's RDD model (§2.2 of the paper):
//!
//! * **Transformations are lazy.** `map`/`flat_map`/`filter`/... build a
//!   new [`Rdd`] whose compute closure pulls parent partitions; nothing
//!   runs until an **action** (`collect`, `count`, `save_as_text_file`).
//! * **Narrow dependencies pipeline.** A chain of narrow transformations
//!   executes inside one task per partition, with no materialization
//!   between steps.
//! * **Wide dependencies shuffle.** `group_by_key`, `reduce_by_key`,
//!   `partition_by` and `repartition` cut the job into stages. An action
//!   first materializes every un-materialized shuffle map stage in
//!   topological order (the DAG scheduler), then runs the final result
//!   stage. All stages execute their tasks on the context's executor
//!   pool.
//! * **Lineage.** A cached/shuffled partition that is lost (see
//!   [`super::lineage`]) is transparently recomputed from its parents.
//! * **Tasks are resilient.** The stage scheduler ([`run_stage`]) retries
//!   panicked tasks with backoff up to
//!   [`super::context::SchedulerConfig::max_task_failures`] attempts,
//!   answers a mid-job shuffle-fetch failure by re-running the lost map
//!   stage through lineage, can speculatively duplicate stragglers
//!   (first finisher wins), and converts a hung stage into an
//!   [`Error::Engine`] with the per-task attempt history when a
//!   stage deadline is configured. Fault injection for all of this lives
//!   in [`super::chaos`].
//!
//! Per-task wall time and record counts are recorded in the context's
//! [`super::metrics::MetricsRegistry`] — once per partition, by the
//! winning attempt only, so retries and speculative duplicates do not
//! inflate the numbers the simulator replays.

use std::collections::HashMap;
use std::hash::Hash;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{mpsc, Arc, OnceLock};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::util::Stopwatch;

use super::chaos::TaskFault;
use super::context::ClusterContext;
use super::metrics::{JobId, StageKind, TaskMetric};
use super::partitioner::Partitioner;
use super::pool::panic_message;
use super::shuffle::ShuffleId;
use super::storage::StorageLevel;

/// Typed panic payload raised by the executor-side shuffle fetch
/// (`ClusterContext::fetch_shuffle`) when a reduce task finds its
/// shuffle input missing (executor loss, injected chaos). The stage
/// scheduler downcasts it and re-materializes the map stage through
/// lineage instead of failing the job.
pub(crate) struct FetchFailed {
    pub(crate) shuffle: ShuffleId,
}

/// Typed panic payload for unrecoverable task errors (e.g. a shuffle
/// bucket stored with a different element type). The scheduler fails
/// the job immediately — retrying a deterministic error is pointless —
/// but the executor pool survives.
pub(crate) struct TaskAbort(pub(crate) String);

/// Marker for element types an RDD can carry.
pub trait Data: Send + Sync + Clone + 'static {}
impl<T: Send + Sync + Clone + 'static> Data for T {}

/// Unique id of an RDD within its context.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RddId(pub usize);

/// A dependency edge in the lineage DAG.
pub(crate) enum Dep {
    /// Child partitions are computed from parent partitions directly
    /// (pipelined inside the same task).
    Narrow(Arc<dyn DagNode>),
    /// Child requires a shuffle; the handle knows how to run the map
    /// stage.
    Shuffle(Arc<ShuffleDepHandle>),
}

impl Clone for Dep {
    fn clone(&self) -> Self {
        match self {
            Dep::Narrow(n) => Dep::Narrow(Arc::clone(n)),
            Dep::Shuffle(s) => Dep::Shuffle(Arc::clone(s)),
        }
    }
}

/// A wide dependency: how to (re-)materialize the shuffle's map outputs.
pub(crate) struct ShuffleDepHandle {
    pub(crate) shuffle_id: ShuffleId,
    pub(crate) parent: Arc<dyn DagNode>,
    /// Runs the map stage: `(job, stage index)`.
    pub(crate) run_map_stage: Box<dyn Fn(JobId, usize) -> Result<()> + Send + Sync>,
}

/// Type-erased view of an RDD used by the DAG scheduler walk.
pub(crate) trait DagNode: Send + Sync {
    fn id(&self) -> RddId;
    fn deps(&self) -> Vec<Dep>;
}

pub(crate) struct RddCore<T: Data> {
    id: RddId,
    ctx: ClusterContext,
    name: String,
    parts: usize,
    compute: Box<dyn Fn(usize) -> Vec<T> + Send + Sync>,
    deps: Vec<Dep>,
}

impl<T: Data> DagNode for RddCore<T> {
    fn id(&self) -> RddId {
        self.id
    }
    fn deps(&self) -> Vec<Dep> {
        self.deps.clone()
    }
}

/// A lazy, partitioned, immutable distributed collection.
pub struct Rdd<T: Data> {
    pub(crate) core: Arc<RddCore<T>>,
}

impl<T: Data> Clone for Rdd<T> {
    fn clone(&self) -> Self {
        Rdd { core: Arc::clone(&self.core) }
    }
}

impl<T: Data> std::fmt::Debug for Rdd<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Rdd")
            .field("id", &self.core.id)
            .field("name", &self.core.name)
            .field("parts", &self.core.parts)
            .finish()
    }
}

// ---------------------------------------------------------------------------
// Construction + partition access
// ---------------------------------------------------------------------------

impl<T: Data> Rdd<T> {
    pub(crate) fn new(
        ctx: ClusterContext,
        name: impl Into<String>,
        parts: usize,
        compute: impl Fn(usize) -> Vec<T> + Send + Sync + 'static,
        deps: Vec<Dep>,
    ) -> Rdd<T> {
        let id = ctx.new_rdd_id();
        Rdd {
            core: Arc::new(RddCore {
                id,
                ctx,
                name: name.into(),
                parts,
                compute: Box::new(compute),
                deps,
            }),
        }
    }

    pub(crate) fn from_collection(ctx: ClusterContext, data: Vec<T>, parts: usize) -> Rdd<T> {
        let parts = parts.max(1);
        let n = data.len();
        let data = Arc::new(data);
        // Contiguous chunking, like Spark's ParallelCollectionRDD.
        let chunk = n.div_ceil(parts).max(1);
        Rdd::new(ctx, "parallelize", parts, move |p| {
            let lo = (p * chunk).min(n);
            let hi = ((p + 1) * chunk).min(n);
            data[lo..hi].to_vec()
        }, Vec::new())
    }

    /// The owning context.
    pub fn ctx(&self) -> &ClusterContext {
        &self.core.ctx
    }

    /// Number of partitions.
    pub fn num_partitions(&self) -> usize {
        self.core.parts
    }

    /// Unique id within the context.
    pub fn id(&self) -> RddId {
        self.core.id
    }

    /// Debug name of the last transformation.
    pub fn name(&self) -> &str {
        &self.core.name
    }

    /// Compute (or fetch from cache) one partition. Pipelines through
    /// narrow parents; respects `.cache()`.
    pub(crate) fn partition(&self, p: usize) -> Vec<T> {
        let store = self.ctx().cache_store();
        if store.level(self.core.id) == StorageLevel::Memory {
            if let Some(v) = store.get::<T>(self.core.id, p) {
                return v;
            }
            let v = (self.core.compute)(p);
            store.put(self.core.id, p, v.clone());
            return v;
        }
        (self.core.compute)(p)
    }

    /// Mark this RDD for in-memory caching (Spark's `.cache()`).
    pub fn cache(&self) -> Rdd<T> {
        self.ctx().cache_store().set_level(self.core.id, StorageLevel::Memory);
        self.clone()
    }

    fn dag_node(&self) -> Arc<dyn DagNode> {
        Arc::clone(&self.core) as Arc<dyn DagNode>
    }
}

// ---------------------------------------------------------------------------
// Narrow transformations
// ---------------------------------------------------------------------------

impl<T: Data> Rdd<T> {
    fn derive<U: Data>(
        &self,
        name: &str,
        parts: usize,
        compute: impl Fn(usize) -> Vec<U> + Send + Sync + 'static,
    ) -> Rdd<U> {
        Rdd::new(
            self.ctx().clone(),
            name,
            parts,
            compute,
            vec![Dep::Narrow(self.dag_node())],
        )
    }

    /// Element-wise map.
    pub fn map<U: Data>(&self, f: impl Fn(T) -> U + Send + Sync + 'static) -> Rdd<U> {
        let parent = self.clone();
        self.derive("map", self.num_partitions(), move |p| {
            parent.partition(p).into_iter().map(&f).collect()
        })
    }

    /// Map each element to zero or more outputs.
    pub fn flat_map<U: Data, I>(&self, f: impl Fn(T) -> I + Send + Sync + 'static) -> Rdd<U>
    where
        I: IntoIterator<Item = U>,
    {
        let parent = self.clone();
        self.derive("flatMap", self.num_partitions(), move |p| {
            parent.partition(p).into_iter().flat_map(&f).collect()
        })
    }

    /// Keep elements satisfying the predicate.
    pub fn filter(&self, pred: impl Fn(&T) -> bool + Send + Sync + 'static) -> Rdd<T> {
        let parent = self.clone();
        self.derive("filter", self.num_partitions(), move |p| {
            parent.partition(p).into_iter().filter(|t| pred(t)).collect()
        })
    }

    /// Map a whole partition at once, with its index — Spark's
    /// `mapPartitionsWithIndex`. The workhorse for per-partition local
    /// aggregation (triangular-matrix updates, local tid assignment).
    pub fn map_partitions_with_index<U: Data>(
        &self,
        f: impl Fn(usize, Vec<T>) -> Vec<U> + Send + Sync + 'static,
    ) -> Rdd<U> {
        let parent = self.clone();
        self.derive("mapPartitionsWithIndex", self.num_partitions(), move |p| {
            f(p, parent.partition(p))
        })
    }

    /// Shrink to `n` partitions without a shuffle (Spark's `coalesce`).
    /// Child partition `i` concatenates a contiguous group of parents.
    pub fn coalesce(&self, n: usize) -> Rdd<T> {
        let n = n.clamp(1, self.num_partitions());
        let parent = self.clone();
        let m = self.num_partitions();
        self.derive("coalesce", n, move |p| {
            // Parent j goes to child j * n / m (contiguous, balanced).
            let mut out = Vec::new();
            for j in 0..m {
                if j * n / m == p {
                    out.extend(parent.partition(j));
                }
            }
            out
        })
    }

    /// Key every element with a globally unique, partition-ordered index
    /// (Spark's `zipWithIndex`). Triggers a job to size the partitions.
    pub fn zip_with_index(&self) -> Result<Rdd<(T, u64)>> {
        let sizes = self.partition_sizes()?;
        let mut offsets = vec![0u64; sizes.len()];
        let mut acc = 0u64;
        for (i, s) in sizes.iter().enumerate() {
            offsets[i] = acc;
            acc += *s as u64;
        }
        let parent = self.clone();
        Ok(self.derive("zipWithIndex", self.num_partitions(), move |p| {
            let base = offsets[p];
            parent
                .partition(p)
                .into_iter()
                .enumerate()
                .map(|(i, t)| (t, base + i as u64))
                .collect()
        }))
    }

    /// Distribute elements evenly over `n` partitions via a shuffle
    /// (Spark's `repartition`).
    pub fn repartition(&self, n: usize) -> Rdd<T> {
        let n = n.max(1);
        let ctx = self.ctx().clone();
        let sid = ctx.new_shuffle_id();
        let parent = self.clone();
        let m = self.num_partitions();

        let map_parent = parent.clone();
        let map_ctx = ctx.clone();
        let run_map_stage = Box::new(move |job: JobId, stage: usize| -> Result<()> {
            let tasks: Vec<_> = (0..m)
                .map(|mp| {
                    let parent = map_parent.clone();
                    let ctx = map_ctx.clone();
                    move || {
                        let items = parent.partition(mp);
                        let mut buckets: Vec<Vec<T>> = (0..n).map(|_| Vec::new()).collect();
                        // Round-robin with per-map-task offset => even spread.
                        for (i, t) in items.into_iter().enumerate() {
                            buckets[(i + mp) % n].push(t);
                        }
                        let records: u64 = buckets.iter().map(|b| b.len() as u64).sum();
                        for (r, b) in buckets.into_iter().enumerate() {
                            ctx.shuffle_store().put(sid, mp, r, b);
                        }
                        ((), records)
                    }
                })
                .collect();
            run_stage(&map_ctx, job, stage, StageKind::ShuffleMap, tasks).map(|_| ())
        });

        let fetch_ctx = ctx.clone();
        Rdd::new(
            ctx,
            "repartition",
            n,
            move |r| fetch_ctx.fetch_shuffle::<T>(sid, m, r),
            vec![Dep::Shuffle(Arc::new(ShuffleDepHandle {
                shuffle_id: sid,
                parent: self.dag_node(),
                run_map_stage,
            }))],
        )
    }
}

// ---------------------------------------------------------------------------
// Pair-RDD (shuffle) transformations
// ---------------------------------------------------------------------------

impl<K, V> Rdd<(K, V)>
where
    K: Data + Hash + Eq,
    V: Data,
{
    /// Repartition by key with an explicit partitioner (Spark's
    /// `partitionBy`). Used by the paper's Phase-3/4 to spread equivalence
    /// classes with the default/hash/reverse-hash partitioners.
    pub fn partition_by(&self, partitioner: Arc<dyn Partitioner<K>>) -> Rdd<(K, V)> {
        let ctx = self.ctx().clone();
        let sid = ctx.new_shuffle_id();
        let parent = self.clone();
        let m = self.num_partitions();
        let n = partitioner.num_partitions();

        let map_parent = parent.clone();
        let map_ctx = ctx.clone();
        let map_partitioner = Arc::clone(&partitioner);
        let run_map_stage = Box::new(move |job: JobId, stage: usize| -> Result<()> {
            let tasks: Vec<_> = (0..m)
                .map(|mp| {
                    let parent = map_parent.clone();
                    let ctx = map_ctx.clone();
                    let partitioner = Arc::clone(&map_partitioner);
                    move || {
                        let items = parent.partition(mp);
                        let mut buckets: Vec<Vec<(K, V)>> = (0..n).map(|_| Vec::new()).collect();
                        let records = items.len() as u64;
                        for (k, v) in items {
                            let r = partitioner.partition(&k);
                            debug_assert!(r < n, "partitioner out of range");
                            buckets[r % n].push((k, v));
                        }
                        for (r, b) in buckets.into_iter().enumerate() {
                            ctx.shuffle_store().put(sid, mp, r, b);
                        }
                        ((), records)
                    }
                })
                .collect();
            run_stage(&map_ctx, job, stage, StageKind::ShuffleMap, tasks).map(|_| ())
        });

        let fetch_ctx = ctx.clone();
        Rdd::new(
            ctx,
            "partitionBy",
            n,
            move |r| fetch_ctx.fetch_shuffle::<(K, V)>(sid, m, r),
            vec![Dep::Shuffle(Arc::new(ShuffleDepHandle {
                shuffle_id: sid,
                parent: self.dag_node(),
                run_map_stage,
            }))],
        )
    }

    /// Group values sharing a key (Spark's `groupByKey`) into `n` reduce
    /// partitions with hash partitioning.
    pub fn group_by_key(&self, n: usize) -> Rdd<(K, Vec<V>)> {
        let ctx = self.ctx().clone();
        let sid = ctx.new_shuffle_id();
        let parent = self.clone();
        let m = self.num_partitions();
        let n = n.max(1);
        let hasher = Arc::new(super::partitioner::HashPartitioner::new(n));

        let map_parent = parent.clone();
        let map_ctx = ctx.clone();
        let map_hasher = Arc::clone(&hasher);
        let run_map_stage = Box::new(move |job: JobId, stage: usize| -> Result<()> {
            let tasks: Vec<_> = (0..m)
                .map(|mp| {
                    let parent = map_parent.clone();
                    let ctx = map_ctx.clone();
                    let hasher = Arc::clone(&map_hasher);
                    move || {
                        let items = parent.partition(mp);
                        let records = items.len() as u64;
                        let mut buckets: Vec<Vec<(K, V)>> = (0..n).map(|_| Vec::new()).collect();
                        for (k, v) in items {
                            let r = Partitioner::<K>::partition(hasher.as_ref(), &k);
                            buckets[r].push((k, v));
                        }
                        for (r, b) in buckets.into_iter().enumerate() {
                            ctx.shuffle_store().put(sid, mp, r, b);
                        }
                        ((), records)
                    }
                })
                .collect();
            run_stage(&map_ctx, job, stage, StageKind::ShuffleMap, tasks).map(|_| ())
        });

        let fetch_ctx = ctx.clone();
        Rdd::new(
            ctx,
            "groupByKey",
            n,
            move |r| {
                let raw = fetch_ctx.fetch_shuffle::<(K, V)>(sid, m, r);
                let mut groups: HashMap<K, Vec<V>> = HashMap::new();
                for (k, v) in raw {
                    groups.entry(k).or_default().push(v);
                }
                groups.into_iter().collect()
            },
            vec![Dep::Shuffle(Arc::new(ShuffleDepHandle {
                shuffle_id: sid,
                parent: self.dag_node(),
                run_map_stage,
            }))],
        )
    }

    /// Merge values per key with an associative, commutative `f` (Spark's
    /// `reduceByKey`), with map-side combining.
    pub fn reduce_by_key(&self, n: usize, f: impl Fn(V, V) -> V + Send + Sync + 'static) -> Rdd<(K, V)> {
        let ctx = self.ctx().clone();
        let sid = ctx.new_shuffle_id();
        let parent = self.clone();
        let m = self.num_partitions();
        let n = n.max(1);
        let f = Arc::new(f);
        let hasher = Arc::new(super::partitioner::HashPartitioner::new(n));

        let map_parent = parent.clone();
        let map_ctx = ctx.clone();
        let map_f = Arc::clone(&f);
        let map_hasher = Arc::clone(&hasher);
        let run_map_stage = Box::new(move |job: JobId, stage: usize| -> Result<()> {
            let tasks: Vec<_> = (0..m)
                .map(|mp| {
                    let parent = map_parent.clone();
                    let ctx = map_ctx.clone();
                    let f = Arc::clone(&map_f);
                    let hasher = Arc::clone(&map_hasher);
                    move || {
                        let items = parent.partition(mp);
                        let records = items.len() as u64;
                        // Map-side combine.
                        let mut combined: HashMap<K, V> = HashMap::new();
                        for (k, v) in items {
                            match combined.remove(&k) {
                                Some(prev) => {
                                    combined.insert(k, f(prev, v));
                                }
                                None => {
                                    combined.insert(k, v);
                                }
                            }
                        }
                        let mut buckets: Vec<Vec<(K, V)>> = (0..n).map(|_| Vec::new()).collect();
                        for (k, v) in combined {
                            let r = Partitioner::<K>::partition(hasher.as_ref(), &k);
                            buckets[r].push((k, v));
                        }
                        for (r, b) in buckets.into_iter().enumerate() {
                            ctx.shuffle_store().put(sid, mp, r, b);
                        }
                        ((), records)
                    }
                })
                .collect();
            run_stage(&map_ctx, job, stage, StageKind::ShuffleMap, tasks).map(|_| ())
        });

        let fetch_ctx = ctx.clone();
        let reduce_f = Arc::clone(&f);
        Rdd::new(
            ctx,
            "reduceByKey",
            n,
            move |r| {
                let raw = fetch_ctx.fetch_shuffle::<(K, V)>(sid, m, r);
                let mut merged: HashMap<K, V> = HashMap::new();
                for (k, v) in raw {
                    match merged.remove(&k) {
                        Some(prev) => {
                            merged.insert(k, reduce_f(prev, v));
                        }
                        None => {
                            merged.insert(k, v);
                        }
                    }
                }
                merged.into_iter().collect()
            },
            vec![Dep::Shuffle(Arc::new(ShuffleDepHandle {
                shuffle_id: sid,
                parent: self.dag_node(),
                run_map_stage,
            }))],
        )
    }

    /// Project out the keys.
    pub fn keys(&self) -> Rdd<K> {
        self.map(|(k, _)| k)
    }

    /// Project out the values.
    pub fn values(&self) -> Rdd<V> {
        self.map(|(_, v)| v)
    }

    /// Map over values, keeping keys (no shuffle).
    pub fn map_values<W: Data>(&self, f: impl Fn(V) -> W + Send + Sync + 'static) -> Rdd<(K, W)> {
        self.map(move |(k, v)| (k, f(v)))
    }
}

// ---------------------------------------------------------------------------
// Actions
// ---------------------------------------------------------------------------

impl<T: Data> Rdd<T> {
    /// Materialize every partition and return all elements in partition
    /// order (Spark's `collect`).
    pub fn collect(&self) -> Result<Vec<T>> {
        let parts = self.run_job("collect")?;
        Ok(parts.into_iter().flatten().collect())
    }

    /// Like `collect`, but keeps partition boundaries.
    pub fn collect_partitions(&self) -> Result<Vec<Vec<T>>> {
        self.run_job("collectPartitions")
    }

    /// Count elements (action).
    pub fn count(&self) -> Result<u64> {
        let parts = self.run_job("count")?;
        Ok(parts.iter().map(|p| p.len() as u64).sum())
    }

    /// Run the job for its side effects (accumulator updates), discarding
    /// outputs — Spark's `foreach`-style action. The paper's Phase-2 uses
    /// this shape: a `flatMap` that only updates an accumulator.
    pub fn run(&self) -> Result<()> {
        self.run_job("run").map(|_| ())
    }

    /// Per-partition element counts (used by `zip_with_index`).
    pub fn partition_sizes(&self) -> Result<Vec<usize>> {
        Ok(self.run_job("partitionSizes")?.iter().map(Vec::len).collect())
    }

    /// Write one text file per partition under `dir` (Spark's
    /// `saveAsTextFile`): `part-00000`, `part-00001`, ...
    pub fn save_as_text_file(&self, dir: &str) -> Result<()>
    where
        T: std::fmt::Display,
    {
        std::fs::create_dir_all(dir)?;
        let parts = self.run_job("saveAsTextFile")?;
        for (i, part) in parts.iter().enumerate() {
            let mut out = String::new();
            for item in part {
                out.push_str(&item.to_string());
                out.push('\n');
            }
            std::fs::write(format!("{dir}/part-{i:05}"), out)?;
        }
        Ok(())
    }

    /// DAG-schedule and run this RDD as a job: materialize shuffle
    /// dependencies in topological order, then execute the result stage.
    fn run_job(&self, action: &str) -> Result<Vec<Vec<T>>> {
        let ctx = self.ctx().clone();
        let job = ctx.metrics().next_job_id();
        // Job span on the driver thread; the engine's JobSpan wall is
        // re-emitted into the same obs timeline via this guard.
        let mut obs_span = crate::obs::span("engine.job");
        obs_span.arg("job", job.0 as u64);
        let sw = Stopwatch::start();
        // Register the job's full shuffle lineage before anything runs,
        // so a fetch failure inside *any* stage (including a downstream
        // map stage) can find the map stage to re-run. The guard clears
        // the registration on every exit path.
        let mut visited = std::collections::HashSet::new();
        let mut ordered: Vec<Arc<ShuffleDepHandle>> = Vec::new();
        collect_shuffles(&self.dag_node(), &mut visited, &mut ordered);
        ctx.register_job_shuffles(job, ordered.clone());
        let _lineage = JobLineageScope { ctx: ctx.clone(), job };
        // Materialize every not-yet-materialized shuffle, parents first.
        let mut stage = 0usize;
        for handle in &ordered {
            if !ctx.shuffle_store().is_materialized(handle.shuffle_id) {
                (handle.run_map_stage)(job, stage)?;
                ctx.shuffle_store().mark_materialized(handle.shuffle_id);
                stage += 1;
            }
        }
        let tasks: Vec<_> = (0..self.num_partitions())
            .map(|p| {
                let rdd = self.clone();
                move || {
                    let data = rdd.partition(p);
                    let records = data.len() as u64;
                    (data, records)
                }
            })
            .collect();
        let out = run_stage(&ctx, job, stage, StageKind::Result, tasks)?;
        ctx.metrics().record_job(super::metrics::JobSpan {
            job,
            name: action.to_string(),
            wall: sw.elapsed(),
            stages: stage + 1,
        });
        obs_span.arg("stages", stage as u64 + 1);
        Ok(out)
    }
}

/// Clears a job's lineage registration when the job leaves `run_job`,
/// successfully or not.
struct JobLineageScope {
    ctx: ClusterContext,
    job: JobId,
}

impl Drop for JobLineageScope {
    fn drop(&mut self) {
        self.ctx.clear_job_shuffles(self.job);
    }
}

/// Post-order DFS: parents' shuffles come before children's.
fn collect_shuffles(
    node: &Arc<dyn DagNode>,
    visited: &mut std::collections::HashSet<RddId>,
    out: &mut Vec<Arc<ShuffleDepHandle>>,
) {
    if !visited.insert(node.id()) {
        return;
    }
    for dep in node.deps() {
        match dep {
            Dep::Narrow(parent) => collect_shuffles(&parent, visited, out),
            Dep::Shuffle(handle) => {
                collect_shuffles(&handle.parent, visited, out);
                out.push(handle);
            }
        }
    }
}

/// Counters surfaced through the obs registry by the stage scheduler.
struct SchedObs {
    task_retries: &'static crate::obs::Counter,
    task_failures: &'static crate::obs::Counter,
    speculative_launched: &'static crate::obs::Counter,
    speculative_won: &'static crate::obs::Counter,
}

fn sched_obs() -> &'static SchedObs {
    static OBS: OnceLock<SchedObs> = OnceLock::new();
    OBS.get_or_init(|| SchedObs {
        task_retries: crate::obs::counter("engine.task.retries"),
        task_failures: crate::obs::counter("engine.task.failures"),
        speculative_launched: crate::obs::counter("engine.speculative.launched"),
        speculative_won: crate::obs::counter("engine.speculative.won"),
    })
}

/// How one task attempt ended, reported back to the driver's gather
/// loop. Panics are caught on the worker and classified by payload.
enum Outcome<R> {
    Done { value: R, records: u64, wall: Duration },
    Panicked(String),
    Aborted(String),
    FetchFailed(ShuffleId),
}

fn classify<R>(payload: Box<dyn std::any::Any + Send>) -> Outcome<R> {
    match payload.downcast::<FetchFailed>() {
        Ok(f) => Outcome::FetchFailed(f.shuffle),
        Err(payload) => match payload.downcast::<TaskAbort>() {
            Ok(a) => Outcome::Aborted(a.0),
            Err(payload) => Outcome::Panicked(panic_message(payload)),
        },
    }
}

fn stage_error(stage: usize, job: JobId, msg: &str, history: &[Vec<String>]) -> Error {
    let mut attempts = String::new();
    for (p, h) in history.iter().enumerate() {
        if !h.is_empty() {
            attempts.push_str(&format!(" [task {p}: {}]", h.join("; ")));
        }
    }
    Error::Engine(format!("stage {stage} of job {job:?} failed: {msg}{attempts}"))
}

/// Smallest completed-task count before speculation is considered.
fn speculation_floor(n: usize, quantile: f64) -> usize {
    (((n as f64) * quantile).ceil() as usize).clamp(1, n)
}

/// Re-run the map stage that produced `shuffle` through the lineage
/// handle the owning job registered, then mark it materialized again.
/// No-op when a sibling recovery already restored it.
fn rematerialize(ctx: &ClusterContext, job: JobId, shuffle: ShuffleId) -> Result<()> {
    if ctx.shuffle_store().is_materialized(shuffle) {
        return Ok(());
    }
    let Some(handle) = ctx.job_shuffle_handle(job, shuffle) else {
        return Err(Error::engine(format!(
            "shuffle {} lost mid-job with no lineage handle registered",
            shuffle.0
        )));
    };
    // The recovery stage borrows the shuffle id as its stage index so
    // recovery tasks are distinguishable in metrics and traces.
    (handle.run_map_stage)(job, shuffle.0)?;
    ctx.shuffle_store().mark_materialized(shuffle);
    Ok(())
}

/// Execute one stage's tasks on the context's executor pool. Tasks
/// return `(result, records)`; a [`TaskMetric`] is recorded for the
/// winning attempt of each partition.
///
/// This is the resilient core of the engine: per-task outcomes come back
/// over a channel as `Result`-like [`Outcome`]s (a panic no longer kills
/// the job), panicked tasks are retried with exponential backoff up to
/// [`super::context::SchedulerConfig::max_task_failures`] attempts,
/// fetch failures re-materialize the lost map stage through lineage and
/// re-run the task without charging it a failure, stragglers can be
/// speculatively duplicated (first finisher fills the partition's
/// idempotent result slot; the loser's result is dropped), and an
/// optional stage deadline turns a hung stage into an error carrying the
/// full attempt history.
///
/// Tasks must therefore be re-runnable (`Fn`, not `FnOnce`) and
/// effectively deterministic: a retried or speculated map task rewrites
/// identical shuffle buckets, which is harmless. Accumulator updates
/// from duplicate attempts are the one visible exception — which is why
/// speculation is opt-in and injected chaos faults fire *before* the
/// task body runs.
pub(crate) fn run_stage<R, F>(
    ctx: &ClusterContext,
    job: JobId,
    stage: usize,
    kind: StageKind,
    tasks: Vec<F>,
) -> Result<Vec<R>>
where
    R: Send + 'static,
    F: Fn() -> (R, u64) + Send + Sync + 'static,
{
    let n = tasks.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let cfg = ctx.scheduler_config().clone();
    let chaos = ctx.chaos();
    let tasks: Vec<Arc<F>> = tasks.into_iter().map(Arc::new).collect();
    let (tx, rx) = mpsc::channel::<(usize, bool, Outcome<R>)>();

    let launch = |p: usize, speculative: bool, backoff: Duration| -> Result<()> {
        let task = Arc::clone(&tasks[p]);
        let chaos = chaos.clone();
        let tx = tx.clone();
        ctx.inner.pool.execute(move || {
            if !backoff.is_zero() {
                std::thread::sleep(backoff);
            }
            // Chaos decides before the task body runs, so an injected
            // fault never leaves partial side effects behind.
            if let Some(chaos) = &chaos {
                match chaos.task_fault(job.0 as u64, stage, p) {
                    Some(TaskFault::Panic) => {
                        let _ = tx.send((
                            p,
                            speculative,
                            Outcome::Panicked(format!(
                                "chaos: injected panic (job {} stage {stage} partition {p})",
                                job.0
                            )),
                        ));
                        return;
                    }
                    Some(TaskFault::Straggle(d)) => std::thread::sleep(d),
                    None => {}
                }
            }
            // Task span on the worker thread: the scheduler's TaskMetric
            // and the obs timeline see the same wall.
            let mut obs_span = crate::obs::span(match kind {
                StageKind::ShuffleMap => "engine.task.shuffle_map",
                StageKind::Result => "engine.task.result",
            });
            let sw = Stopwatch::start();
            let outcome = match catch_unwind(AssertUnwindSafe(|| task())) {
                Ok((value, records)) => {
                    obs_span
                        .arg("job", job.0 as u64)
                        .arg("stage", stage as u64)
                        .arg("partition", p as u64)
                        .arg("records", records);
                    Outcome::Done { value, records, wall: sw.elapsed() }
                }
                Err(payload) => classify(payload),
            };
            let _ = tx.send((p, speculative, outcome));
        })
    };

    for p in 0..n {
        launch(p, false, Duration::ZERO)?;
    }

    let stage_start = Instant::now();
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let mut done = 0usize;
    let mut failures = vec![0u32; n];
    let mut history: Vec<Vec<String>> = vec![Vec::new(); n];
    let mut speculated = vec![false; n];
    let mut launched_at = vec![stage_start; n];
    let mut completed_walls: Vec<Duration> = Vec::new();
    // Bounds runaway recovery loops; generous because every reduce
    // partition may independently report the same loss once.
    let mut fetch_recoveries = 0u32;
    let max_fetch_recoveries = 4 + 2 * n as u32;

    while done < n {
        let msg = match rx.recv_timeout(Duration::from_millis(5)) {
            Ok(m) => Some(m),
            Err(mpsc::RecvTimeoutError::Timeout) => None,
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return Err(stage_error(stage, job, "executor pool disconnected", &history));
            }
        };
        if let Some((p, speculative, outcome)) = msg {
            match outcome {
                Outcome::Done { value, records, wall } => {
                    // First finisher wins; a speculative loser's (or
                    // late retry's) duplicate result is dropped here.
                    if slots[p].is_none() {
                        slots[p] = Some(value);
                        done += 1;
                        completed_walls.push(wall);
                        ctx.metrics().record_task(TaskMetric {
                            job,
                            stage,
                            kind,
                            partition: p,
                            wall,
                            records,
                        });
                        if speculative && crate::obs::enabled() {
                            sched_obs().speculative_won.incr(1);
                        }
                    }
                }
                Outcome::FetchFailed(shuffle) if slots[p].is_none() => {
                    fetch_recoveries += 1;
                    if fetch_recoveries > max_fetch_recoveries {
                        return Err(stage_error(
                            stage,
                            job,
                            &format!("shuffle {} kept failing to re-materialize", shuffle.0),
                            &history,
                        ));
                    }
                    history[p].push(format!("fetch failure on shuffle {}", shuffle.0));
                    rematerialize(ctx, job, shuffle).map_err(|e| {
                        stage_error(
                            stage,
                            job,
                            &format!("recovering shuffle {}: {e}", shuffle.0),
                            &history,
                        )
                    })?;
                    // Not charged as a task failure: the task was a
                    // victim of the lost shuffle, not the culprit.
                    launch(p, false, Duration::ZERO)?;
                    launched_at[p] = Instant::now();
                }
                Outcome::Aborted(msg) if slots[p].is_none() => {
                    return Err(stage_error(
                        stage,
                        job,
                        &format!("task {p} aborted: {msg}"),
                        &history,
                    ));
                }
                Outcome::Panicked(msg) if slots[p].is_none() => {
                    failures[p] += 1;
                    history[p].push(format!("attempt {}: {msg}", failures[p]));
                    if crate::obs::enabled() {
                        sched_obs().task_failures.incr(1);
                    }
                    if failures[p] >= cfg.max_task_failures {
                        return Err(stage_error(
                            stage,
                            job,
                            &format!("task {p} failed {} times", failures[p]),
                            &history,
                        ));
                    }
                    if crate::obs::enabled() {
                        sched_obs().task_retries.incr(1);
                    }
                    let exp = (failures[p] - 1).min(6);
                    let backoff =
                        (cfg.retry_backoff * 2u32.pow(exp)).min(Duration::from_millis(100));
                    launch(p, false, backoff)?;
                    launched_at[p] = Instant::now();
                }
                // A failure from an attempt whose partition already has
                // a winner carries no information — drop it.
                _ => {}
            }
        }
        if done == n {
            break;
        }
        if let Some(deadline) = cfg.stage_deadline {
            if stage_start.elapsed() > deadline {
                return Err(stage_error(
                    stage,
                    job,
                    &format!("deadline {deadline:?} exceeded with {done}/{n} tasks complete"),
                    &history,
                ));
            }
        }
        if cfg.speculation && done >= speculation_floor(n, cfg.speculation_quantile) {
            let mut walls = completed_walls.clone();
            walls.sort_unstable();
            let median = walls[walls.len() / 2];
            // The 10 ms floor keeps trivial stages (median ≈ 0) from
            // speculating every still-queued task.
            let threshold =
                median.mul_f64(cfg.speculation_multiplier).max(Duration::from_millis(10));
            for p in 0..n {
                if slots[p].is_none() && !speculated[p] && launched_at[p].elapsed() > threshold {
                    speculated[p] = true;
                    if crate::obs::enabled() {
                        sched_obs().speculative_launched.incr(1);
                    }
                    launch(p, true, Duration::ZERO)?;
                }
            }
        }
    }

    Ok(slots.into_iter().map(|s| s.expect("all result slots filled")).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::context::ClusterContext;
    use crate::engine::partitioner::FnPartitioner;

    fn ctx() -> ClusterContext {
        ClusterContext::builder().cores(4).build()
    }

    #[test]
    fn parallelize_collect_roundtrip() {
        let c = ctx();
        let data: Vec<u32> = (0..100).collect();
        let rdd = c.parallelize(data.clone(), 7);
        assert_eq!(rdd.num_partitions(), 7);
        assert_eq!(rdd.collect().unwrap(), data);
    }

    #[test]
    fn map_filter_flatmap_pipeline() {
        let c = ctx();
        let rdd = c.parallelize((1u32..=10).collect(), 3);
        let out = rdd
            .map(|x| x * 2)
            .filter(|x| x % 3 == 0)
            .flat_map(|x| vec![x, x + 1])
            .collect()
            .unwrap();
        assert_eq!(out, vec![6, 7, 12, 13, 18, 19]);
    }

    #[test]
    fn count_and_partition_sizes() {
        let c = ctx();
        let rdd = c.parallelize((0..10u8).collect(), 4);
        assert_eq!(rdd.count().unwrap(), 10);
        let sizes = rdd.partition_sizes().unwrap();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert_eq!(sizes.len(), 4);
    }

    #[test]
    fn group_by_key_groups_all_values() {
        let c = ctx();
        let pairs: Vec<(u32, u32)> = (0..60).map(|i| (i % 5, i)).collect();
        let rdd = c.parallelize(pairs, 6);
        let mut grouped = rdd.group_by_key(3).collect().unwrap();
        grouped.sort_by_key(|(k, _)| *k);
        assert_eq!(grouped.len(), 5);
        for (k, mut vs) in grouped {
            vs.sort_unstable();
            let expect: Vec<u32> = (0..60).filter(|i| i % 5 == k).collect();
            assert_eq!(vs, expect, "key {k}");
        }
    }

    #[test]
    fn reduce_by_key_matches_fold() {
        let c = ctx();
        let pairs: Vec<(String, u64)> =
            (0..100).map(|i| (format!("k{}", i % 7), i as u64)).collect();
        let expect: std::collections::HashMap<String, u64> =
            pairs.iter().fold(std::collections::HashMap::new(), |mut m, (k, v)| {
                *m.entry(k.clone()).or_default() += v;
                m
            });
        let rdd = c.parallelize(pairs, 5);
        let reduced: std::collections::HashMap<String, u64> =
            rdd.reduce_by_key(4, |a, b| a + b).collect().unwrap().into_iter().collect();
        assert_eq!(reduced, expect);
    }

    #[test]
    fn partition_by_routes_keys() {
        let c = ctx();
        let pairs: Vec<(usize, usize)> = (0..40).map(|i| (i % 8, i)).collect();
        let rdd = c.parallelize(pairs, 4);
        let partitioned = rdd.partition_by(Arc::new(FnPartitioner::new(4, |k: &usize| *k)));
        let parts = partitioned.collect_partitions().unwrap();
        assert_eq!(parts.len(), 4);
        for (r, part) in parts.iter().enumerate() {
            for (k, _) in part {
                assert_eq!(k % 4, r, "key {k} in reduce partition {r}");
            }
        }
        // Nothing lost.
        let total: usize = parts.iter().map(Vec::len).sum();
        assert_eq!(total, 40);
    }

    #[test]
    fn coalesce_preserves_elements_without_shuffle() {
        let c = ctx();
        let rdd = c.parallelize((0..50u32).collect(), 10);
        let co = rdd.coalesce(3);
        assert_eq!(co.num_partitions(), 3);
        let mut all = co.collect().unwrap();
        all.sort_unstable();
        assert_eq!(all, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn coalesce_to_one_keeps_order() {
        let c = ctx();
        let rdd = c.parallelize((0..20u32).collect(), 4);
        assert_eq!(rdd.coalesce(1).collect().unwrap(), (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn repartition_spreads_evenly() {
        let c = ctx();
        let rdd = c.parallelize((0..100u32).collect(), 2);
        let rep = rdd.repartition(5);
        let sizes = rep.partition_sizes().unwrap();
        assert_eq!(sizes.iter().sum::<usize>(), 100);
        assert!(sizes.iter().all(|&s| s == 20), "{sizes:?}");
        let mut all = rep.collect().unwrap();
        all.sort_unstable();
        assert_eq!(all, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zip_with_index_is_dense_and_ordered() {
        let c = ctx();
        let rdd = c.parallelize(vec!["a", "b", "c", "d", "e"], 2);
        let zipped = rdd.zip_with_index().unwrap().collect().unwrap();
        assert_eq!(
            zipped,
            vec![("a", 0), ("b", 1), ("c", 2), ("d", 3), ("e", 4)]
        );
    }

    #[test]
    fn cache_avoids_recompute() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let c = ctx();
        let computes = Arc::new(AtomicUsize::new(0));
        let counter = Arc::clone(&computes);
        let rdd = c
            .parallelize((0..10u32).collect(), 2)
            .map(move |x| {
                counter.fetch_add(1, Ordering::SeqCst);
                x * 2
            })
            .cache();
        rdd.collect().unwrap();
        let after_first = computes.load(Ordering::SeqCst);
        rdd.collect().unwrap();
        assert_eq!(computes.load(Ordering::SeqCst), after_first, "second collect served from cache");
    }

    #[test]
    fn shuffle_map_stage_runs_once_across_jobs() {
        let c = ctx();
        let pairs: Vec<(u32, u32)> = (0..20).map(|i| (i % 4, i)).collect();
        let grouped = c.parallelize(pairs, 4).group_by_key(2);
        grouped.count().unwrap();
        let tasks_after_first = c.metrics().tasks().len();
        grouped.count().unwrap();
        let tasks_after_second = c.metrics().tasks().len();
        // Second job only runs the result stage (2 tasks), not the map stage.
        assert_eq!(tasks_after_second - tasks_after_first, 2);
    }

    #[test]
    fn metrics_record_stages_and_records() {
        let c = ctx();
        let pairs: Vec<(u8, u8)> = (0..30).map(|i| ((i % 3) as u8, i as u8)).collect();
        c.parallelize(pairs, 3).reduce_by_key(2, |a, b| a.wrapping_add(b)).collect().unwrap();
        let tasks = c.metrics().tasks();
        let maps = tasks.iter().filter(|t| t.kind == StageKind::ShuffleMap).count();
        let results = tasks.iter().filter(|t| t.kind == StageKind::Result).count();
        assert_eq!(maps, 3);
        assert_eq!(results, 2);
        let jobs = c.metrics().jobs();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].stages, 2);
    }

    #[test]
    fn chained_shuffles_materialize_in_order() {
        let c = ctx();
        let pairs: Vec<(u32, u64)> = (0..50).map(|i| (i % 10, 1u64)).collect();
        // wordcount -> re-key by parity of count -> group
        let counts = c.parallelize(pairs, 5).reduce_by_key(4, |a, b| a + b);
        let regrouped = counts.map(|(k, v)| (v % 2, k)).group_by_key(2);
        let out = regrouped.collect().unwrap();
        let total: usize = out.iter().map(|(_, vs)| vs.len()).sum();
        assert_eq!(total, 10);
    }

    #[test]
    fn save_as_text_file_writes_parts() {
        let c = ctx();
        let dir = std::env::temp_dir().join("rdd_eclat_save_test");
        let _ = std::fs::remove_dir_all(&dir);
        let rdd = c.parallelize((0..10u32).collect(), 3);
        rdd.save_as_text_file(dir.to_str().unwrap()).unwrap();
        let mut lines = Vec::new();
        for entry in std::fs::read_dir(&dir).unwrap() {
            let content = std::fs::read_to_string(entry.unwrap().path()).unwrap();
            lines.extend(content.lines().map(|l| l.parse::<u32>().unwrap()));
        }
        lines.sort_unstable();
        assert_eq!(lines, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn keys_values_map_values() {
        let c = ctx();
        let rdd = c.parallelize(vec![(1u8, "a"), (2, "b")], 1);
        assert_eq!(rdd.keys().collect().unwrap(), vec![1, 2]);
        assert_eq!(rdd.values().collect().unwrap(), vec!["a", "b"]);
        assert_eq!(
            rdd.map_values(|v| v.to_uppercase()).collect().unwrap(),
            vec![(1, "A".to_string()), (2, "B".to_string())]
        );
    }

    #[test]
    fn mistyped_shuffle_fetch_fails_the_job_cleanly() {
        let c = ctx();
        let sid = c.new_shuffle_id();
        c.shuffle_store().put(sid, 0, 0, vec![1u32, 2]);
        c.shuffle_store().mark_materialized(sid);
        let fetch = c.clone();
        let bad: Rdd<String> = Rdd::new(
            c.clone(),
            "mistyped",
            1,
            move |r| fetch.fetch_shuffle::<String>(sid, 1, r),
            Vec::new(),
        );
        let err = bad.collect().unwrap_err();
        assert!(err.to_string().contains("type mismatch"), "{err}");
        assert!(err.to_string().contains("aborted"), "deterministic errors are not retried: {err}");
        // The executor pool survived the failed job.
        assert_eq!(c.parallelize((0..10u32).collect(), 4).count().unwrap(), 10);
    }

    #[test]
    fn transient_task_panics_are_retried_to_success() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let c = ctx();
        let attempts = Arc::new(AtomicUsize::new(0));
        let a = Arc::clone(&attempts);
        let rdd = c.parallelize((0..8u32).collect(), 2).map_partitions_with_index(
            move |p, data| {
                // Partition 1 panics on its first two attempts.
                if p == 1 && a.fetch_add(1, Ordering::SeqCst) < 2 {
                    panic!("transient executor failure");
                }
                data
            },
        );
        assert_eq!(rdd.collect().unwrap(), (0..8).collect::<Vec<_>>());
        assert_eq!(attempts.load(Ordering::SeqCst), 3, "two failures + the winning attempt");
    }

    #[test]
    fn permanent_task_failure_exhausts_retries_with_history() {
        let c = ClusterContext::builder().cores(2).max_task_failures(2).without_chaos().build();
        let rdd = c.parallelize((0..4u32).collect(), 2).map(|x| {
            if x >= 2 {
                panic!("poison element {x}");
            }
            x
        });
        let err = rdd.collect().unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("failed 2 times"), "{msg}");
        assert!(msg.contains("poison element"), "attempt history carried: {msg}");
        // The pool survives an exhausted job.
        assert_eq!(c.parallelize((0..6u32).collect(), 3).count().unwrap(), 6);
    }

    #[test]
    fn stage_deadline_turns_a_hung_stage_into_an_error() {
        let c = ClusterContext::builder()
            .cores(2)
            .stage_deadline(Duration::from_millis(40))
            .without_chaos()
            .build();
        let rdd = c.parallelize((0..2u32).collect(), 2).map(|x| {
            if x == 1 {
                std::thread::sleep(Duration::from_millis(400));
            }
            x
        });
        let err = rdd.collect().unwrap_err();
        assert!(err.to_string().contains("deadline"), "{err}");
    }

    #[test]
    fn empty_rdd_everything_works() {
        let c = ctx();
        let rdd: Rdd<u32> = c.parallelize(Vec::new(), 3);
        assert_eq!(rdd.count().unwrap(), 0);
        assert!(rdd.map(|x| x + 1).collect().unwrap().is_empty());
        let pairs: Rdd<(u32, u32)> = c.parallelize(Vec::new(), 2);
        assert!(pairs.group_by_key(2).collect().unwrap().is_empty());
    }
}
