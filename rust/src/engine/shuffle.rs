//! Hash shuffle — the engine's wide-dependency data plane.
//!
//! A shuffle has `m` map tasks (one per parent partition) and `r` reduce
//! partitions. Each map task writes one type-erased bucket per reduce
//! partition; reduce-side compute fetches column `r` across all map
//! outputs. The store also tracks which shuffles are fully materialized so
//! the stage scheduler runs each map stage exactly once — and can
//! re-materialize after an injected fault (lineage recovery).

use std::any::Any;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{OnceLock, RwLock};

/// Shuffle instrumentation cells, resolved once (see [`crate::obs`]).
struct ShuffleObs {
    puts: &'static crate::obs::Counter,
    fetches: &'static crate::obs::Counter,
    records: &'static crate::obs::Counter,
}

fn shuffle_obs() -> &'static ShuffleObs {
    static OBS: OnceLock<ShuffleObs> = OnceLock::new();
    OBS.get_or_init(|| ShuffleObs {
        puts: crate::obs::counter("engine.shuffle.puts"),
        fetches: crate::obs::counter("engine.shuffle.fetches"),
        records: crate::obs::counter("engine.shuffle.records"),
    })
}

/// Identifies one shuffle (one wide dependency).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ShuffleId(pub usize);

type Bucket = Box<dyn Any + Send + Sync>;

/// In-memory map-output store: `(shuffle, map task, reduce partition) →
/// bucket`.
#[derive(Default)]
pub struct ShuffleStore {
    buckets: RwLock<HashMap<(ShuffleId, usize, usize), Bucket>>,
    materialized: RwLock<HashSet<ShuffleId>>,
    bytes_approx: AtomicU64,
    records: AtomicU64,
}

impl ShuffleStore {
    /// Create an empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Write one map task's bucket for one reduce partition.
    pub fn put<T: Send + Sync + 'static>(
        &self,
        shuffle: ShuffleId,
        map_task: usize,
        reduce: usize,
        data: Vec<T>,
    ) {
        self.records.fetch_add(data.len() as u64, Ordering::Relaxed);
        self.bytes_approx
            .fetch_add((data.len() * std::mem::size_of::<T>()) as u64, Ordering::Relaxed);
        if crate::obs::enabled() {
            let o = shuffle_obs();
            o.puts.incr(1);
            o.records.incr(data.len() as u64);
        }
        self.buckets
            .write()
            .unwrap()
            .insert((shuffle, map_task, reduce), Box::new(data));
    }

    /// Fetch all buckets for reduce partition `reduce`, concatenated in map
    /// task order. Cloning out keeps the store reusable for recomputes.
    pub fn fetch<T: Clone + 'static>(
        &self,
        shuffle: ShuffleId,
        num_map_tasks: usize,
        reduce: usize,
    ) -> Vec<T> {
        if crate::obs::enabled() {
            shuffle_obs().fetches.incr(1);
        }
        let buckets = self.buckets.read().unwrap();
        let mut out = Vec::new();
        for m in 0..num_map_tasks {
            if let Some(b) = buckets.get(&(shuffle, m, reduce)) {
                let v = b
                    .downcast_ref::<Vec<T>>()
                    .expect("shuffle type mismatch: bucket stored with a different type");
                out.extend(v.iter().cloned());
            }
        }
        out
    }

    /// Mark a shuffle's map stage complete.
    pub fn mark_materialized(&self, shuffle: ShuffleId) {
        self.materialized.write().unwrap().insert(shuffle);
    }

    /// Whether the map stage for this shuffle already ran.
    pub fn is_materialized(&self, shuffle: ShuffleId) -> bool {
        self.materialized.read().unwrap().contains(&shuffle)
    }

    /// Fault injection: drop every map output of a shuffle and clear its
    /// materialized flag — the next job that needs it recomputes the map
    /// stage through lineage. Returns the number of dropped buckets.
    pub fn lose(&self, shuffle: ShuffleId) -> usize {
        let mut buckets = self.buckets.write().unwrap();
        let keys: Vec<_> = buckets.keys().filter(|(s, _, _)| *s == shuffle).cloned().collect();
        for k in &keys {
            buckets.remove(k);
        }
        self.materialized.write().unwrap().remove(&shuffle);
        keys.len()
    }

    /// (records shuffled, approximate payload bytes) — feeds metrics.
    pub fn traffic(&self) -> (u64, u64) {
        (self.records.load(Ordering::Relaxed), self.bytes_approx.load(Ordering::Relaxed))
    }

    /// Number of buckets currently stored.
    pub fn len(&self) -> usize {
        self.buckets.read().unwrap().len()
    }

    /// True when no buckets stored.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_fetch_concatenates_in_map_order() {
        let s = ShuffleStore::new();
        let id = ShuffleId(0);
        s.put(id, 1, 0, vec![("b", 2)]);
        s.put(id, 0, 0, vec![("a", 1)]);
        s.put(id, 0, 1, vec![("z", 9)]);
        let r0: Vec<(&str, i32)> = s.fetch(id, 2, 0);
        assert_eq!(r0, vec![("a", 1), ("b", 2)]);
        let r1: Vec<(&str, i32)> = s.fetch(id, 2, 1);
        assert_eq!(r1, vec![("z", 9)]);
        let r2: Vec<(&str, i32)> = s.fetch(id, 2, 2);
        assert!(r2.is_empty());
    }

    #[test]
    fn materialization_flag_and_loss() {
        let s = ShuffleStore::new();
        let id = ShuffleId(3);
        assert!(!s.is_materialized(id));
        s.put(id, 0, 0, vec![1u64]);
        s.mark_materialized(id);
        assert!(s.is_materialized(id));
        assert_eq!(s.lose(id), 1);
        assert!(!s.is_materialized(id));
        let empty: Vec<u64> = s.fetch(id, 1, 0);
        assert!(empty.is_empty());
    }

    #[test]
    fn traffic_counters_accumulate() {
        let s = ShuffleStore::new();
        s.put(ShuffleId(1), 0, 0, vec![1u32, 2, 3]);
        let (recs, bytes) = s.traffic();
        assert_eq!(recs, 3);
        assert_eq!(bytes, 12);
    }

    #[test]
    fn independent_shuffles_do_not_collide() {
        let s = ShuffleStore::new();
        s.put(ShuffleId(1), 0, 0, vec![1u8]);
        s.put(ShuffleId(2), 0, 0, vec![2u8]);
        assert_eq!(s.fetch::<u8>(ShuffleId(1), 1, 0), vec![1]);
        assert_eq!(s.fetch::<u8>(ShuffleId(2), 1, 0), vec![2]);
        s.lose(ShuffleId(1));
        assert_eq!(s.fetch::<u8>(ShuffleId(2), 1, 0), vec![2]);
    }
}
