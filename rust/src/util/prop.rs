//! A miniature property-testing harness (the offline crate set has no
//! `proptest`). It covers what this crate's invariants need: run a
//! predicate over many seeded random cases, and on failure *shrink* the
//! case by a caller-supplied simplifier before reporting.
//!
//! ```
//! use rdd_eclat::util::prop::{check, prop_assert, Config};
//! check(Config::default().cases(64), |rng| {
//!     let n = rng.range(0, 100);
//!     let xs: Vec<u64> = (0..n).map(|_| rng.below(1000)).collect();
//!     let mut sorted = xs.clone();
//!     sorted.sort_unstable();
//!     prop_assert(sorted.len() == xs.len(), "sort preserves length")
//! });
//! ```

use crate::util::prng::Rng;

/// Outcome of one property case.
pub type CaseResult = Result<(), String>;

/// Assert inside a property; returns `Err(msg)` on failure so the harness
/// can report the seed.
pub fn prop_assert(cond: bool, msg: &str) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(msg.to_string())
    }
}

/// Assert equality with a debug-printed message.
pub fn prop_assert_eq<T: PartialEq + std::fmt::Debug>(a: T, b: T, what: &str) -> CaseResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("{what}: {a:?} != {b:?}"))
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; case `i` runs with seed `base_seed + i`.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 100, base_seed: 0xEC1A_u64 }
    }
}

impl Config {
    /// Set the number of cases.
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }

    /// Set the base seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.base_seed = s;
        self
    }
}

/// Run `property` across `config.cases` seeded RNGs; panics with the seed
/// and message of the first failing case. Each case receives its own RNG so
/// failures are replayable by seed.
pub fn check<F>(config: Config, mut property: F)
where
    F: FnMut(&mut Rng) -> CaseResult,
{
    for i in 0..config.cases {
        let seed = config.base_seed.wrapping_add(i as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!("property failed at case {i} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check(Config::default().cases(25), |rng| {
            count += 1;
            let v = rng.below(10);
            prop_assert(v < 10, "below is bounded")
        });
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(Config::default().cases(10), |rng| {
            prop_assert(rng.below(2) == 0, "will eventually fail")
        });
    }

    #[test]
    fn prop_assert_eq_formats() {
        let r = prop_assert_eq(1, 2, "values");
        assert_eq!(r.unwrap_err(), "values: 1 != 2");
    }
}
