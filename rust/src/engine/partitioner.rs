//! Key partitioners for wide (shuffle) dependencies.
//!
//! The engine ships Spark's `HashPartitioner` equivalent; the paper's
//! equivalence-class partitioners (default `(n-1)`, hash `%p`, reverse
//! hash — Algorithm 10) are built on this trait in
//! [`crate::algorithms::partitioners`].

use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};

/// Maps a key to a reduce partition in `[0, num_partitions)`.
pub trait Partitioner<K>: Send + Sync {
    /// Number of reduce partitions.
    fn num_partitions(&self) -> usize;
    /// Partition index for `key`; must be `< num_partitions()`.
    fn partition(&self, key: &K) -> usize;
}

/// Spark-style hash partitioner: `hash(key) mod p`.
#[derive(Debug, Clone)]
pub struct HashPartitioner {
    parts: usize,
}

impl HashPartitioner {
    /// Create with `parts >= 1` partitions.
    pub fn new(parts: usize) -> Self {
        HashPartitioner { parts: parts.max(1) }
    }
}

impl<K: Hash> Partitioner<K> for HashPartitioner {
    fn num_partitions(&self) -> usize {
        self.parts
    }

    fn partition(&self, key: &K) -> usize {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() % self.parts as u64) as usize
    }
}

/// Partitioner from a plain function — how custom partitioners (the
/// paper's Algorithm 10) are expressed.
pub struct FnPartitioner<K> {
    parts: usize,
    f: Box<dyn Fn(&K) -> usize + Send + Sync>,
}

impl<K> FnPartitioner<K> {
    /// Wrap `f`; the result of `f` is clamped into range by `% parts`.
    pub fn new(parts: usize, f: impl Fn(&K) -> usize + Send + Sync + 'static) -> Self {
        FnPartitioner { parts: parts.max(1), f: Box::new(f) }
    }
}

impl<K> Partitioner<K> for FnPartitioner<K> {
    fn num_partitions(&self) -> usize {
        self.parts
    }

    fn partition(&self, key: &K) -> usize {
        (self.f)(key) % self.parts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_partitioner_in_range_and_deterministic() {
        let p = HashPartitioner::new(7);
        for k in 0..1000u32 {
            let a = p.partition(&k);
            assert!(a < 7);
            assert_eq!(a, p.partition(&k));
        }
    }

    #[test]
    fn hash_partitioner_spreads_keys() {
        let p = HashPartitioner::new(8);
        let mut counts = [0usize; 8];
        for k in 0..8000u32 {
            counts[p.partition(&k)] += 1;
        }
        // Every bucket should get a decent share.
        assert!(counts.iter().all(|&c| c > 500), "{counts:?}");
    }

    #[test]
    fn min_one_partition() {
        let p = HashPartitioner::new(0);
        assert_eq!(Partitioner::<u32>::num_partitions(&p), 1);
        assert_eq!(p.partition(&123u32), 0);
    }

    #[test]
    fn fn_partitioner_clamps() {
        let p = FnPartitioner::new(3, |k: &usize| *k);
        assert_eq!(p.partition(&10), 1);
        assert_eq!(p.num_partitions(), 3);
    }
}
