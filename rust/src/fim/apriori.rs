//! Apriori primitives — candidate generation, pruning and support
//! counting (Agrawal–Srikant). These power both the sequential oracle and
//! the YAFIM-style RDD-Apriori baseline the paper compares against.

use std::collections::HashMap;

use super::itemset::{is_subset, prefix_join, Frequent, Item, ItemSet};
use super::transaction::Database;
use super::trie::CandidateTrie;

/// Generate candidate (k+1)-itemsets from the frequent k-itemsets
/// (sorted, deduped), applying the Apriori prune: every k-subset of a
/// candidate must itself be frequent.
pub fn candidate_gen(frequents: &[ItemSet]) -> Vec<ItemSet> {
    if frequents.is_empty() {
        return Vec::new();
    }
    // Membership structure for pruning.
    let mut known = CandidateTrie::new();
    for f in frequents {
        known.insert(f);
    }
    let mut candidates = Vec::new();
    // Frequents sharing a (k-1)-prefix are adjacent once sorted.
    let mut sorted: Vec<&ItemSet> = frequents.iter().collect();
    sorted.sort();
    for (idx, a) in sorted.iter().enumerate() {
        for b in &sorted[idx + 1..] {
            match prefix_join(a, b) {
                Some(cand) => {
                    if all_subsets_frequent(&cand, &known) {
                        candidates.push(cand);
                    }
                }
                // Sorted order: once prefixes diverge, stop the inner scan.
                None => break,
            }
        }
    }
    candidates
}

/// Check the Apriori prune condition: all k-subsets of the (k+1)-candidate
/// are frequent. (The two subsets used in the join are frequent by
/// construction; checking the rest suffices, but checking all is simpler
/// and costs one trie probe each.)
fn all_subsets_frequent(cand: &[Item], known: &CandidateTrie) -> bool {
    let mut subset = Vec::with_capacity(cand.len() - 1);
    for skip in 0..cand.len() {
        subset.clear();
        subset.extend(cand.iter().enumerate().filter(|(i, _)| *i != skip).map(|(_, &x)| x));
        if !known.contains(&subset) {
            return false;
        }
    }
    true
}

/// Count candidate supports over a slice of transactions using the
/// candidate trie (hash-tree role). Returns per-candidate counts aligned
/// with insertion order.
pub fn count_candidates(candidates: &[ItemSet], transactions: &[Vec<Item>]) -> Vec<u32> {
    let mut trie = CandidateTrie::new();
    let mut order = Vec::with_capacity(candidates.len());
    for c in candidates {
        order.push(trie.insert(c));
    }
    let mut counts = vec![0u32; trie.len()];
    for t in transactions {
        trie.count_subsets(t, &mut counts);
    }
    // Map back to the caller's candidate order (insert deduplicates).
    order.into_iter().map(|idx| counts[idx]).collect()
}

/// Sequential Apriori over a horizontal database — the reference
/// implementation (and the per-partition worker of RDD-Apriori).
pub fn apriori(db: &Database, min_sup_count: u32) -> Vec<Frequent> {
    let mut out: Vec<Frequent> = Vec::new();
    // L1.
    let mut item_counts: HashMap<Item, u32> = HashMap::new();
    for t in db.transactions() {
        for &i in t {
            *item_counts.entry(i).or_default() += 1;
        }
    }
    let mut level: Vec<ItemSet> = item_counts
        .iter()
        .filter(|(_, &c)| c >= min_sup_count)
        .map(|(&i, _)| vec![i])
        .collect();
    level.sort();
    for items in &level {
        out.push(Frequent::new(items.clone(), item_counts[&items[0]]));
    }
    // Lk for k >= 2.
    while !level.is_empty() {
        let candidates = candidate_gen(&level);
        if candidates.is_empty() {
            break;
        }
        let counts = count_candidates(&candidates, db.transactions());
        let mut next: Vec<ItemSet> = Vec::new();
        for (cand, count) in candidates.into_iter().zip(counts) {
            if count >= min_sup_count {
                out.push(Frequent::new(cand.clone(), count));
                next.push(cand);
            }
        }
        next.sort();
        level = next;
    }
    out
}

/// Brute-force support of one itemset (test oracle).
pub fn support_of(db: &Database, itemset: &[Item]) -> u32 {
    db.transactions().iter().filter(|t| is_subset(itemset, t)).count() as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::itemset::sort_frequents;

    fn demo_db() -> Database {
        Database::from_rows(vec![
            vec![1, 3, 4],
            vec![2, 3, 5],
            vec![1, 2, 3, 5],
            vec![2, 5],
            vec![1, 3, 5],
            vec![2, 3, 5],
        ])
    }

    #[test]
    fn candidate_gen_joins_and_prunes() {
        // L2 = {12,13,14,23,24,34} -> joins give 123,124,134,234; all pass
        // the prune.
        let l2: Vec<ItemSet> = vec![
            vec![1, 2], vec![1, 3], vec![1, 4], vec![2, 3], vec![2, 4], vec![3, 4],
        ];
        let mut c3 = candidate_gen(&l2);
        c3.sort();
        assert_eq!(c3, vec![vec![1, 2, 3], vec![1, 2, 4], vec![1, 3, 4], vec![2, 3, 4]]);

        // Remove {3,4}: 134 and 234 must be pruned.
        let l2b: Vec<ItemSet> = vec![vec![1, 2], vec![1, 3], vec![1, 4], vec![2, 3], vec![2, 4]];
        let mut c3b = candidate_gen(&l2b);
        c3b.sort();
        assert_eq!(c3b, vec![vec![1, 2, 3], vec![1, 2, 4]]);
    }

    #[test]
    fn counting_matches_bruteforce() {
        let db = demo_db();
        let candidates: Vec<ItemSet> = vec![vec![2, 5], vec![3, 5], vec![1, 3, 5], vec![2, 3, 5]];
        let counts = count_candidates(&candidates, db.transactions());
        for (c, n) in candidates.iter().zip(&counts) {
            assert_eq!(*n, support_of(&db, c), "candidate {c:?}");
        }
    }

    #[test]
    fn apriori_mines_known_result() {
        let db = demo_db();
        let mut got = apriori(&db, 3);
        sort_frequents(&mut got);
        // Hand-checked: σ(1)=3, σ(2)=4, σ(3)=5, σ(5)=5, σ(13)=3, σ(25)=4,
        // σ(35)=4, σ(23)=3, σ(235)=3.
        let expect: Vec<(Vec<Item>, u32)> = vec![
            (vec![1], 3),
            (vec![2], 4),
            (vec![3], 5),
            (vec![5], 5),
            (vec![1, 3], 3),
            (vec![2, 3], 3),
            (vec![2, 5], 4),
            (vec![3, 5], 4),
            (vec![2, 3, 5], 3),
        ];
        let got: Vec<(Vec<Item>, u32)> = got.into_iter().map(|f| (f.items, f.support)).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn apriori_empty_db_and_high_minsup() {
        let db = Database::from_rows(vec![]);
        assert!(apriori(&db, 1).is_empty());
        let db = demo_db();
        assert!(apriori(&db, 100).is_empty());
    }

    #[test]
    fn candidate_gen_empty() {
        assert!(candidate_gen(&[]).is_empty());
        assert!(candidate_gen(&[vec![1]]).is_empty());
    }
}
