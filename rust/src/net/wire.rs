//! Versioned, length-prefixed binary wire format for shard motion.
//!
//! Everything on the wire is explicit **little-endian**, and every
//! variable-length sequence is length-prefixed (u64 count), so a frame
//! decodes with zero lookahead. The [`Wire`] trait is implemented for
//! the payloads that move between the driver and shard workers:
//! [`TidBitmap`] tid columns, [`PooledSink`] arenas with their
//! `(offset, len, support)` records, window [`Batch`]es with eviction
//! hints, and the [`ShardStats`]/[`IngestStats`] accounting structs.
//!
//! Frames travel in a [`Frame`] envelope whose on-wire layout is
//!
//! ```text
//! magic: u32 | version: u16 | kind: u16 | len: u32 | crc32: u32 | body
//! ```
//!
//! with a hand-rolled IEEE CRC-32 over `kind | len | body` (every
//! header field is either checked by equality or covered by the CRC, so
//! a single flipped bit anywhere in the frame is detected). Corrupt,
//! truncated, and version-skewed frames surface as typed
//! [`Error::Net`] decode errors — never panics, and never an
//! attacker-controlled allocation (sequence counts are validated
//! against the bytes actually present before anything is reserved).

use std::time::Duration;

use crate::error::{Error, Result};
use crate::fim::sink::FrequentSink;
use crate::fim::{Item, PooledSink, TidBitmap};
use crate::stream::job::ShardStats;
use crate::stream::window::Batch;
use crate::stream::IngestStats;

/// Frame magic: `b"rdec"` little-endian — RDD-Eclat.
pub const MAGIC: u32 = u32::from_le_bytes(*b"rdec");

/// Wire-format version; bumped on any layout change. A mismatched
/// version is a typed decode error, not a best-effort parse.
pub const VERSION: u16 = 1;

/// Fixed envelope header size in bytes.
pub const HEADER_LEN: usize = 16;

/// Upper bound on a frame body (1 GiB) — a corrupted length field must
/// not turn into an unbounded allocation or read.
pub const MAX_BODY: usize = 1 << 30;

/// Hand-rolled IEEE CRC-32 (polynomial `0xEDB88320`), bitwise — the
/// envelope checksum. Fast enough for frame headers + bodies at the
/// sizes shard motion uses, and keeps the crate zero-dependency.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in bytes {
        crc ^= u32::from(b);
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

/// RPC frame kinds. Requests are low values, replies high; the split is
/// cosmetic (the kind byte is what dispatches) but keeps captures
/// readable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u16)]
pub enum FrameKind {
    /// Driver → worker: handshake carrying the shard layout.
    Hello = 1,
    /// Driver → worker: one window batch (rows + eviction hints).
    ApplyBatch = 2,
    /// Driver → worker: mine the worker's equivalence-class groups.
    MineClasses = 3,
    /// Driver → worker: per-shard accounting probe.
    Stats = 4,
    /// Driver → worker: stop serving and exit.
    Shutdown = 5,
    /// Worker → driver: handshake reply with current tid bounds.
    HelloAck = 17,
    /// Worker → driver: post-apply tid bounds acknowledgement.
    ApplyAck = 18,
    /// Worker → driver: mined per-shard sinks, one frame.
    Mined = 19,
    /// Worker → driver: per-shard accounting reply.
    StatsReply = 20,
    /// Worker → driver: generic success (shutdown acknowledgement).
    Ok = 21,
    /// Worker → driver: request failed; body is the message.
    Err = 22,
}

impl FrameKind {
    fn from_u16(v: u16) -> Option<FrameKind> {
        use FrameKind::*;
        Some(match v {
            1 => Hello,
            2 => ApplyBatch,
            3 => MineClasses,
            4 => Stats,
            5 => Shutdown,
            17 => HelloAck,
            18 => ApplyAck,
            19 => Mined,
            20 => StatsReply,
            21 => Ok,
            22 => Err,
            _ => return None,
        })
    }
}

/// One framed message: the kind tag plus the raw body bytes. The
/// `magic`/`version`/`len`/`crc32` envelope fields are synthesized on
/// encode and validated on decode (see the module docs for the exact
/// on-wire layout).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    /// What the body means.
    pub kind: FrameKind,
    /// Encoded payload ([`Wire::to_bytes`] of the message struct).
    pub body: Vec<u8>,
}

impl Frame {
    /// Wrap an encoded body.
    pub fn new(kind: FrameKind, body: Vec<u8>) -> Frame {
        Frame { kind, body }
    }

    /// Wrap a [`Wire`] message.
    pub fn from_msg<T: Wire>(kind: FrameKind, msg: &T) -> Frame {
        Frame::new(kind, msg.to_bytes())
    }

    /// Serialize header + body into one buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(HEADER_LEN + self.body.len());
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.extend_from_slice(&(self.kind as u16).to_le_bytes());
        out.extend_from_slice(&(self.body.len() as u32).to_le_bytes());
        out.extend_from_slice(&self.checksum().to_le_bytes());
        out.extend_from_slice(&self.body);
        out
    }

    /// The envelope CRC: over `kind | len | body`, so together with the
    /// equality-checked `magic`/`version` every frame byte is covered.
    pub fn checksum(&self) -> u32 {
        let mut covered = Vec::with_capacity(6 + self.body.len());
        covered.extend_from_slice(&(self.kind as u16).to_le_bytes());
        covered.extend_from_slice(&(self.body.len() as u32).to_le_bytes());
        covered.extend_from_slice(&self.body);
        crc32(&covered)
    }

    /// Parse a complete frame from `buf` (header + body, no trailing
    /// bytes). Transport code reads the header and body separately for
    /// streaming; this is the buffer-shaped twin used by tests and the
    /// chaos corruption path.
    pub fn decode(buf: &[u8]) -> Result<Frame> {
        if buf.len() < HEADER_LEN {
            return Err(Error::net(format!(
                "truncated frame header: {} of {HEADER_LEN} bytes",
                buf.len()
            )));
        }
        let (kind, len) = Frame::parse_header(&buf[..HEADER_LEN])?;
        let body = &buf[HEADER_LEN..];
        if body.len() != len {
            return Err(Error::net(format!(
                "frame length mismatch: header says {len}, got {} body bytes",
                body.len()
            )));
        }
        let frame = Frame::new(kind, body.to_vec());
        let want = u32::from_le_bytes([buf[12], buf[13], buf[14], buf[15]]);
        if frame.checksum() != want {
            return Err(Error::net(format!(
                "frame crc mismatch: computed {:#010x}, header {want:#010x}",
                frame.checksum()
            )));
        }
        Ok(frame)
    }

    /// Validate a 16-byte header and return `(kind, body_len)`.
    /// The CRC cannot be checked until the body has been read; callers
    /// verify it via [`Frame::checksum`] afterwards.
    pub fn parse_header(header: &[u8]) -> Result<(FrameKind, usize)> {
        if header.len() != HEADER_LEN {
            return Err(Error::net(format!(
                "truncated frame header: {} of {HEADER_LEN} bytes",
                header.len()
            )));
        }
        let magic = u32::from_le_bytes([header[0], header[1], header[2], header[3]]);
        if magic != MAGIC {
            return Err(Error::net(format!("bad frame magic {magic:#010x}")));
        }
        let version = u16::from_le_bytes([header[4], header[5]]);
        if version != VERSION {
            return Err(Error::net(format!(
                "wire version mismatch: peer speaks v{version}, this build speaks v{VERSION}"
            )));
        }
        let kind_raw = u16::from_le_bytes([header[6], header[7]]);
        let kind = FrameKind::from_u16(kind_raw)
            .ok_or_else(|| Error::net(format!("unknown frame kind {kind_raw}")))?;
        let len = u32::from_le_bytes([header[8], header[9], header[10], header[11]]) as usize;
        if len > MAX_BODY {
            return Err(Error::net(format!("frame body too large: {len} > {MAX_BODY}")));
        }
        Ok((kind, len))
    }

    /// Decode the body as a [`Wire`] message, checking the kind first.
    pub fn expect<T: Wire>(&self, kind: FrameKind) -> Result<T> {
        if self.kind == FrameKind::Err {
            return Err(Error::net(format!(
                "peer error: {}",
                String::from_utf8_lossy(&self.body)
            )));
        }
        if self.kind != kind {
            return Err(Error::net(format!(
                "unexpected frame kind {:?}, wanted {kind:?}",
                self.kind
            )));
        }
        T::from_bytes(&self.body)
    }
}

/// Bounds-checked little-endian cursor over a received body. Every read
/// is validated against the remaining bytes; running off the end is a
/// typed [`Error::Net`], never a panic.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    /// Cursor at the start of `buf`.
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(Error::net(format!(
                "truncated payload: wanted {n} bytes, {} left",
                self.remaining()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read one byte.
    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    /// Read a little-endian u32.
    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    /// Read a little-endian u64.
    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Read a u64 that must fit in `usize`.
    pub fn usize(&mut self) -> Result<usize> {
        usize::try_from(self.u64()?).map_err(|_| Error::net("length overflows usize"))
    }

    /// Read a length prefix for a sequence whose elements each occupy at
    /// least `elem_min` encoded bytes, validating the count against the
    /// bytes actually present — a corrupted count cannot drive an
    /// allocation past the payload it arrived in.
    pub fn seq_len(&mut self, elem_min: usize) -> Result<usize> {
        let n = self.usize()?;
        let need = n.checked_mul(elem_min.max(1)).ok_or_else(|| {
            Error::net(format!("sequence length {n} overflows"))
        })?;
        if need > self.remaining() {
            return Err(Error::net(format!(
                "sequence claims {n} elements ({need} bytes), {} left",
                self.remaining()
            )));
        }
        Ok(n)
    }

    /// Error unless the payload was consumed exactly.
    pub fn finish(&self) -> Result<()> {
        if self.remaining() != 0 {
            return Err(Error::net(format!("{} trailing bytes after payload", self.remaining())));
        }
        Ok(())
    }
}

/// Encode/decode on the shard-motion wire format. Implementations are
/// exact round-trips: `decode(encode(x)) == x`, pinned by the property
/// tests in `tests/integration_net.rs`.
pub trait Wire: Sized {
    /// Append this value's encoding to `out`.
    fn encode(&self, out: &mut Vec<u8>);

    /// Decode one value from the cursor.
    fn decode(r: &mut Reader<'_>) -> Result<Self>;

    /// Encode into a fresh buffer.
    fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode(&mut out);
        out
    }

    /// Decode from a complete buffer, rejecting trailing bytes.
    fn from_bytes(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let v = Self::decode(&mut r)?;
        r.finish()?;
        Ok(v)
    }
}

impl Wire for u32 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        r.u32()
    }
}

impl Wire for u64 {
    fn encode(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        r.u64()
    }
}

impl Wire for bool {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(u8::from(*self));
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        match r.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            b => Err(Error::net(format!("bad bool byte {b}"))),
        }
    }
}

impl Wire for Duration {
    /// Durations travel as u64 nanoseconds (saturating — the stats walls
    /// this carries are far below the ~584-year cap).
    fn encode(&self, out: &mut Vec<u8>) {
        let nanos = u64::try_from(self.as_nanos()).unwrap_or(u64::MAX);
        nanos.encode(out);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(Duration::from_nanos(r.u64()?))
    }
}

impl<T: Wire> Wire for Vec<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.len() as u64).encode(out);
        for v in self {
            v.encode(out);
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        // Elements occupy ≥ 1 byte each, so the count is bounded by the
        // bytes present and a later truncation fails inside T::decode.
        let n = r.seq_len(1)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(T::decode(r)?);
        }
        Ok(out)
    }
}

impl Wire for TidBitmap {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.universe() as u64).encode(out);
        (self.words().len() as u64).encode(out);
        for w in self.words() {
            w.encode(out);
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let universe = r.usize()?;
        let n = r.seq_len(8)?;
        let mut words = Vec::with_capacity(n);
        for _ in 0..n {
            words.push(r.u64()?);
        }
        TidBitmap::from_raw_words(universe, words)
            .ok_or_else(|| Error::net(format!("bitmap words disagree with universe {universe}")))
    }
}

impl Wire for PooledSink {
    /// The arena travels as its logical records — `(support, items)` per
    /// emission in record order. Re-emitting on decode rebuilds the
    /// identical arena + `(offset, len, support)` records, because
    /// [`PooledSink`] appends contiguously in emission order.
    fn encode(&self, out: &mut Vec<u8>) {
        (self.len() as u64).encode(out);
        for (items, support) in self.iter() {
            support.encode(out);
            (items.len() as u64).encode(out);
            for i in items {
                i.encode(out);
            }
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        // Each record is ≥ 12 bytes (support + empty-itemset length).
        let n = r.seq_len(12)?;
        let mut sink = PooledSink::with_capacity(n * 2, n);
        let mut items: Vec<Item> = Vec::new();
        for _ in 0..n {
            let support = r.u32()?;
            let len = r.seq_len(4)?;
            items.clear();
            for _ in 0..len {
                items.push(r.u32()?);
            }
            sink.emit(&items, support);
        }
        Ok(sink)
    }
}

impl Wire for Batch {
    fn encode(&self, out: &mut Vec<u8>) {
        self.id.encode(out);
        self.tid_lo.encode(out);
        (self.txns as u64).encode(out);
        self.items.encode(out);
        (self.rows.len() as u64).encode(out);
        for row in &self.rows {
            row.encode(out);
        }
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        let id = r.u64()?;
        let tid_lo = r.u32()?;
        let txns = r.usize()?;
        let items = Vec::<Item>::decode(r)?;
        let n = r.seq_len(8)?;
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            rows.push(Vec::<Item>::decode(r)?);
        }
        Ok(Batch { id, tid_lo, txns, items, rows })
    }
}

impl Wire for ShardStats {
    fn encode(&self, out: &mut Vec<u8>) {
        self.rows.encode(out);
        self.postings.encode(out);
        self.mined_itemsets.encode(out);
        self.mine_wall.encode(out);
        self.age.encode(out);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(ShardStats {
            rows: r.u64()?,
            postings: r.u64()?,
            mined_itemsets: r.u64()?,
            mine_wall: Duration::decode(r)?,
            age: Duration::decode(r)?,
        })
    }
}

impl Wire for IngestStats {
    fn encode(&self, out: &mut Vec<u8>) {
        self.batches.encode(out);
        self.emissions.encode(out);
        self.skipped.encode(out);
        self.mine_failures.encode(out);
        self.mine_retries.encode(out);
        self.degraded.encode(out);
        self.shards.encode(out);
        self.age.encode(out);
    }

    fn decode(r: &mut Reader<'_>) -> Result<Self> {
        Ok(IngestStats {
            batches: r.u64()?,
            emissions: r.u64()?,
            skipped: r.u64()?,
            mine_failures: r.u64()?,
            mine_retries: r.u64()?,
            degraded: bool::decode(r)?,
            shards: Vec::<ShardStats>::decode(r)?,
            age: Duration::decode(r)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_ieee_check_value() {
        // The canonical CRC-32 check: crc32("123456789") == 0xCBF43926.
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn frame_round_trips_and_rejects_flips() {
        let frame = Frame::new(FrameKind::Stats, vec![1, 2, 3, 4, 5]);
        let bytes = frame.encode();
        assert_eq!(Frame::decode(&bytes).unwrap(), frame);
        // Any single flipped bit anywhere in the frame must be caught.
        for i in 0..bytes.len() {
            let mut bad = bytes.clone();
            bad[i] ^= 0x10;
            assert!(
                matches!(Frame::decode(&bad), Err(Error::Net(_))),
                "flip at byte {i} slipped through"
            );
        }
        // Every truncation must be caught.
        for n in 0..bytes.len() {
            assert!(matches!(Frame::decode(&bytes[..n]), Err(Error::Net(_))));
        }
    }

    #[test]
    fn version_skew_is_a_typed_error() {
        let mut bytes = Frame::new(FrameKind::Ok, Vec::new()).encode();
        bytes[4] = VERSION as u8 + 1;
        let err = Frame::decode(&bytes).unwrap_err();
        assert!(err.to_string().contains("version mismatch"), "{err}");
    }

    #[test]
    fn corrupt_sequence_count_cannot_force_allocation() {
        let mut body = Vec::new();
        u64::MAX.encode(&mut body);
        let err = Vec::<u64>::from_bytes(&body).unwrap_err();
        assert!(matches!(err, Error::Net(_)));
        let err = TidBitmap::from_bytes(&[0xFF; 16]).unwrap_err();
        assert!(matches!(err, Error::Net(_)));
    }

    #[test]
    fn pooled_sink_round_trip_preserves_arena_layout() {
        let mut sink = PooledSink::new();
        sink.emit(&[3, 5, 9], 7);
        sink.emit(&[1], 2);
        sink.emit(&[], 11);
        let back = PooledSink::from_bytes(&sink.to_bytes()).unwrap();
        assert_eq!(back, sink);
        assert_eq!(back.arena_len(), sink.arena_len());
    }
}
