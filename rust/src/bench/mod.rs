//! A miniature criterion-style benchmark harness (the offline crate set
//! has no `criterion`). Warmup + fixed sample count + summary statistics,
//! plus CSV/markdown reporting used by every bench target and the figure
//! harness.

use crate::util::json::json_str;
use crate::util::{Stopwatch, Summary};

pub mod alloc;

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    /// Warmup iterations (not measured).
    pub warmup: usize,
    /// Measured samples.
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 1, samples: 5 }
    }
}

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Benchmark id (e.g. `fig13/eclatV4/0.01`).
    pub name: String,
    /// Summary of per-sample wall times in seconds.
    pub secs: Summary,
    /// Heap allocations of one invocation, when measured under the
    /// counting allocator (see [`alloc`]); `None` = not measured.
    pub allocs: Option<u64>,
}

impl Measurement {
    /// Mean seconds.
    pub fn mean(&self) -> f64 {
        self.secs.mean
    }

    /// Attach an allocation count (builder-style; used by benches that
    /// measure one extra invocation under [`alloc::count_in`]).
    pub fn with_allocs(mut self, allocs: Option<u64>) -> Measurement {
        self.allocs = allocs;
        self
    }
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10.4}s ±{:>8.4} (n={}, min {:.4}, max {:.4})",
            self.name, self.secs.mean, self.secs.std_dev, self.secs.n, self.secs.min, self.secs.max
        )?;
        if let Some(a) = self.allocs {
            write!(f, " [{a} allocs]")?;
        }
        Ok(())
    }
}

impl Bench {
    /// Quick config for CI-style runs.
    pub fn quick() -> Bench {
        Bench { warmup: 0, samples: 2 }
    }

    /// From the environment: `SCALE=quick` or a `--quick` CLI argument
    /// (cargo forwards arguments after `--` to the bench binary; the CI
    /// perf-trajectory step runs `cargo bench --bench fim_micro -- --quick`).
    pub fn from_env() -> Bench {
        if Bench::quick_requested() {
            Bench::quick()
        } else {
            Bench::default()
        }
    }

    /// The scale label matching [`Bench::from_env`]'s decision — the
    /// single source of truth benches use to tag trajectory JSON.
    pub fn scale_from_env() -> &'static str {
        if Bench::quick_requested() {
            "quick"
        } else {
            "paper"
        }
    }

    fn quick_requested() -> bool {
        std::env::var("SCALE").as_deref() == Ok("quick")
            || std::env::args().any(|a| a == "--quick")
    }

    /// Measure a closure. The closure's return value is black-boxed so
    /// the optimizer cannot delete the work.
    pub fn run<T>(&self, name: impl Into<String>, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples.max(1) {
            let sw = Stopwatch::start();
            black_box(f());
            samples.push(sw.secs());
        }
        Measurement { name: name.into(), secs: Summary::of(&samples), allocs: None }
    }

    /// Measure a fallible closure, propagating the first error.
    pub fn try_run<T, E>(
        &self,
        name: impl Into<String>,
        mut f: impl FnMut() -> Result<T, E>,
    ) -> Result<Measurement, E> {
        for _ in 0..self.warmup {
            black_box(f()?);
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples.max(1) {
            let sw = Stopwatch::start();
            black_box(f()?);
            samples.push(sw.secs());
        }
        Ok(Measurement { name: name.into(), secs: Summary::of(&samples), allocs: None })
    }
}

/// Opaque use of a value (stable `black_box`).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Collects measurements and writes reports.
#[derive(Debug, Default)]
pub struct Report {
    rows: Vec<Measurement>,
    extras: Vec<(String, String)>,
}

impl Report {
    /// Empty report.
    pub fn new() -> Report {
        Report::default()
    }

    /// Add one measurement (also prints it).
    pub fn add(&mut self, m: Measurement) {
        println!("{m}");
        self.rows.push(m);
    }

    /// Attach an extra top-level JSON field to [`Report::to_json`].
    /// `raw_json` is emitted verbatim (it must already be valid JSON —
    /// e.g. a [`crate::obs::MetricsSnapshot::to_json`] object), so
    /// benches can merge observability snapshots into trajectory rows
    /// without the harness knowing their schema.
    pub fn add_extra(&mut self, key: impl Into<String>, raw_json: impl Into<String>) {
        self.extras.push((key.into(), raw_json.into()));
    }

    /// Borrow the rows.
    pub fn rows(&self) -> &[Measurement] {
        &self.rows
    }

    /// Serialize as CSV (`name,mean_s,std_s,min_s,max_s,n,allocs`; the
    /// `allocs` cell is empty when the run was not measured under the
    /// counting allocator).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("name,mean_s,std_s,min_s,max_s,n,allocs\n");
        for m in &self.rows {
            let allocs = m.allocs.map(|a| a.to_string()).unwrap_or_default();
            out.push_str(&format!(
                "{},{:.6},{:.6},{:.6},{:.6},{},{}\n",
                m.name, m.secs.mean, m.secs.std_dev, m.secs.min, m.secs.max, m.secs.n, allocs
            ));
        }
        out
    }

    /// Write the CSV under `results/` (created if needed).
    pub fn write_csv(&self, file: &str) -> crate::error::Result<String> {
        std::fs::create_dir_all("results")?;
        let path = format!("results/{file}");
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }

    /// Serialize as a JSON document (no serde offline; the measurement
    /// schema is flat enough to emit by hand). `bench` names the suite,
    /// `scale` records the `SCALE` setting the numbers were taken at, so
    /// trajectory diffs compare like with like.
    pub fn to_json(&self, bench: &str, scale: &str) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"bench\": {},\n", json_str(bench)));
        out.push_str(&format!("  \"scale\": {},\n", json_str(scale)));
        for (key, raw) in &self.extras {
            out.push_str(&format!("  {}: {raw},\n", json_str(key)));
        }
        out.push_str("  \"results\": [\n");
        for (i, m) in self.rows.iter().enumerate() {
            let allocs = match m.allocs {
                Some(a) => format!(", \"allocs\": {a}"),
                None => String::new(),
            };
            out.push_str(&format!(
                "    {{\"name\": {}, \"mean_s\": {:.6}, \"std_s\": {:.6}, \"min_s\": {:.6}, \"max_s\": {:.6}, \"n\": {}{}}}{}\n",
                json_str(&m.name),
                m.secs.mean,
                m.secs.std_dev,
                m.secs.min,
                m.secs.max,
                m.secs.n,
                allocs,
                if i + 1 < self.rows.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the JSON trajectory file at an explicit path.
    pub fn write_json(&self, path: &str, bench: &str, scale: &str) -> crate::error::Result<()> {
        if let Some(parent) = std::path::Path::new(path).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.to_json(bench, scale))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn run_measures_and_summarizes() {
        let b = Bench { warmup: 1, samples: 3 };
        let m = b.run("sleepy", || std::thread::sleep(Duration::from_millis(2)));
        assert_eq!(m.secs.n, 3);
        assert!(m.secs.mean >= 0.002, "mean {}", m.secs.mean);
    }

    #[test]
    fn try_run_propagates_errors() {
        let b = Bench::quick();
        let r: Result<_, &str> = b.try_run("failing", || Err::<i32, &str>("nope"));
        assert_eq!(r.unwrap_err(), "nope");
        let ok: Result<_, &str> = b.try_run("fine", || Ok(42));
        assert!(ok.is_ok());
    }

    #[test]
    fn csv_shape() {
        let mut r = Report::new();
        r.add(Measurement { name: "a/b".into(), secs: Summary::of(&[1.0, 2.0]), allocs: None });
        let csv = r.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("name,mean_s"));
        assert!(lines[0].ends_with(",allocs"));
        assert!(lines[1].starts_with("a/b,1.5"));
        assert!(lines[1].ends_with(','), "unmeasured allocs cell is empty");
    }

    #[test]
    fn json_shape_and_escaping() {
        let mut r = Report::new();
        r.add(Measurement { name: "a\"b/c".into(), secs: Summary::of(&[1.0, 3.0]), allocs: None });
        r.add(Measurement { name: "plain".into(), secs: Summary::of(&[2.0]), allocs: Some(7) });
        let json = r.to_json("fim_micro", "quick");
        assert!(json.contains("\"bench\": \"fim_micro\""), "{json}");
        assert!(json.contains("\"scale\": \"quick\""), "{json}");
        assert!(json.contains("\"a\\\"b/c\""), "escaped name: {json}");
        assert!(json.contains("\"mean_s\": 2.000000"), "{json}");
        assert!(json.contains("\"allocs\": 7"), "measured allocs emitted: {json}");
        assert_eq!(json.matches("\"allocs\"").count(), 1, "unmeasured rows omit allocs: {json}");
        // Exactly one comma between the two result rows, none trailing.
        assert_eq!(json.matches("},\n").count(), 1, "{json}");
        assert!(!json.contains(",\n  ]"), "no trailing comma: {json}");
    }

    #[test]
    fn extras_emitted_verbatim_before_results() {
        let mut r = Report::new();
        r.add(Measurement { name: "x".into(), secs: Summary::of(&[1.0]), allocs: None });
        r.add_extra("metrics", "{\"counters\": [[\"a\", 3]]}");
        let json = r.to_json("fim_micro", "quick");
        let metrics_at = json.find("\"metrics\": {\"counters\": [[\"a\", 3]]},").expect("extra");
        let results_at = json.find("\"results\"").expect("results");
        assert!(metrics_at < results_at, "extras come before results: {json}");
        assert!(!json.contains(",\n  ]"), "no trailing comma: {json}");
    }

    #[test]
    fn write_json_roundtrip() {
        let dir = std::env::temp_dir().join("rdd_eclat_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_fim.json");
        let mut r = Report::new();
        r.add(Measurement { name: "x".into(), secs: Summary::of(&[0.5]), allocs: None });
        r.write_json(path.to_str().unwrap(), "fim_micro", "paper").unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with('{') && text.trim_end().ends_with('}'));
        assert!(text.contains("\"n\": 1"));
    }

    #[test]
    fn from_env_respects_scale() {
        // Can't set env safely in parallel tests; just check both ctors.
        assert_eq!(Bench::quick().samples, 2);
        assert!(Bench::default().samples >= 3);
    }
}
