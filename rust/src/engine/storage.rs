//! Partition cache — the engine's analogue of Spark's block manager /
//! `RDD.cache()`. Cached partitions are type-erased (`Box<dyn Any>`) and
//! keyed by `(rdd id, partition index)`; the typed accessor lives on the
//! RDD side which knows `T`.

use std::any::Any;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, PoisonError, RwLock};

use super::rdd::RddId;

/// Where a cached partition lives. `Memory` is the only real store in this
/// single-process engine; `None` means not cached. (Spark's disk levels
/// would go here.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StorageLevel {
    /// Not persisted; recomputed from lineage on every access.
    None,
    /// Kept in the in-memory block store after first computation.
    Memory,
}

type Block = Box<dyn Any + Send + Sync>;

/// In-memory block store with hit/miss counters (counters feed the metrics
/// tests and the EXPERIMENTS.md cache-effectiveness note).
#[derive(Default)]
pub struct CacheStore {
    blocks: RwLock<HashMap<(RddId, usize), Block>>,
    levels: Mutex<HashMap<RddId, StorageLevel>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl CacheStore {
    /// Create an empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the declared storage level of an RDD (`.cache()`).
    pub fn set_level(&self, rdd: RddId, level: StorageLevel) {
        self.levels.lock().unwrap_or_else(PoisonError::into_inner).insert(rdd, level);
    }

    /// The declared storage level (None when never declared).
    pub fn level(&self, rdd: RddId) -> StorageLevel {
        let levels = self.levels.lock().unwrap_or_else(PoisonError::into_inner);
        *levels.get(&rdd).unwrap_or(&StorageLevel::None)
    }

    /// Fetch a cached partition, cloning out the typed value.
    pub fn get<T: Clone + 'static>(&self, rdd: RddId, partition: usize) -> Option<Vec<T>> {
        let blocks = self.blocks.read().unwrap_or_else(PoisonError::into_inner);
        match blocks.get(&(rdd, partition)) {
            Some(b) => {
                let v = b
                    .downcast_ref::<Vec<T>>()
                    .expect("cache type mismatch: same RDD id stored with two types");
                // ordering: Relaxed — hit/miss tallies are independent
                // monitoring counters; RMW atomicity keeps them exact,
                // and nothing is published through them (the blocks
                // themselves synchronize via the RwLock).
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(v.clone())
            }
            None => {
                // ordering: Relaxed — as above.
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Insert a computed partition.
    pub fn put<T: Clone + Send + Sync + 'static>(&self, rdd: RddId, partition: usize, data: Vec<T>) {
        let mut blocks = self.blocks.write().unwrap_or_else(PoisonError::into_inner);
        blocks.insert((rdd, partition), Box::new(data));
    }

    /// Drop a single cached partition (fault injection / eviction).
    /// Returns true when something was actually dropped.
    pub fn evict(&self, rdd: RddId, partition: usize) -> bool {
        let mut blocks = self.blocks.write().unwrap_or_else(PoisonError::into_inner);
        blocks.remove(&(rdd, partition)).is_some()
    }

    /// Drop every cached partition of an RDD; returns how many were dropped.
    pub fn evict_rdd(&self, rdd: RddId) -> usize {
        let mut blocks = self.blocks.write().unwrap_or_else(PoisonError::into_inner);
        let keys: Vec<_> = blocks.keys().filter(|(r, _)| *r == rdd).cloned().collect();
        for k in &keys {
            blocks.remove(k);
        }
        keys.len()
    }

    /// Number of cached partitions currently held.
    pub fn len(&self) -> usize {
        self.blocks.read().unwrap_or_else(PoisonError::into_inner).len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (hits, misses) counters.
    pub fn counters(&self) -> (u64, u64) {
        // ordering: Relaxed — monitoring reads of independent tallies.
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let c = CacheStore::new();
        c.put(RddId(1), 0, vec![1u32, 2, 3]);
        assert_eq!(c.get::<u32>(RddId(1), 0), Some(vec![1, 2, 3]));
        assert_eq!(c.get::<u32>(RddId(1), 1), None);
        let (h, m) = c.counters();
        assert_eq!((h, m), (1, 1));
    }

    #[test]
    fn evict_partition_and_rdd() {
        let c = CacheStore::new();
        c.put(RddId(5), 0, vec![0u8]);
        c.put(RddId(5), 1, vec![1u8]);
        c.put(RddId(6), 0, vec![2u8]);
        assert!(c.evict(RddId(5), 0));
        assert!(!c.evict(RddId(5), 0));
        assert_eq!(c.evict_rdd(RddId(5)), 1);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get::<u8>(RddId(6), 0), Some(vec![2]));
    }

    #[test]
    fn levels_tracked() {
        let c = CacheStore::new();
        assert_eq!(c.level(RddId(9)), StorageLevel::None);
        c.set_level(RddId(9), StorageLevel::Memory);
        assert_eq!(c.level(RddId(9)), StorageLevel::Memory);
    }

    #[test]
    #[should_panic(expected = "cache type mismatch")]
    fn type_mismatch_panics() {
        let c = CacheStore::new();
        c.put(RddId(1), 0, vec![1u32]);
        let _ = c.get::<String>(RddId(1), 0);
    }
}
