//! Clickstream mining on a BMS-WebView-like dataset — the sparse,
//! skewed regime where the paper disables the triangular matrix and
//! transaction filtering barely pays (§5.2).
//!
//! Demonstrates per-dataset option tuning, the filtering-shrinkage
//! metric, and the XLA (AOT PJRT) co-occurrence backend when artifacts
//! are available.
//!
//! ```text
//! cargo run --release --example clickstream
//! ```

use rdd_eclat::algorithms::{EclatOptions, MiningSession, Variant};
use rdd_eclat::data::clickstream::{generate, ClickParams};
use rdd_eclat::engine::ClusterContext;
use rdd_eclat::fim::{Database, MinSup};
use rdd_eclat::util::time::fmt_duration;

fn main() -> rdd_eclat::error::Result<()> {
    // A BMS1-like session log (scaled to keep the example snappy).
    let db = generate(
        &ClickParams { sessions: 20_000, ..ClickParams::bms1_like() },
        42,
    );
    let stats = db.stats();
    println!(
        "clickstream: {} sessions, {} products, avg {:.1} clicks/session",
        stats.transactions, stats.distinct_items, stats.avg_width
    );

    let ctx = ClusterContext::builder().build();
    let min_sup = MinSup::fraction(0.003);

    // The paper's setting for BMS: triMatrixMode = false (item universe
    // too large for the triangular matrix to pay off).
    let bms_opts = EclatOptions { tri_matrix: false, ..Default::default() };

    let session = MiningSession::on(&ctx).db(&db).min_sup(min_sup).options(bms_opts);
    let r = session.run(Variant::V2)?;
    println!(
        "\neclatV2 (tri off): {} itemsets in {}; filtering shrank volume by {:.1}%",
        r.len(),
        fmt_duration(r.wall),
        r.filtered_reduction.unwrap_or(0.0) * 100.0
    );

    let r5 = session.run(Variant::V5)?;
    println!(
        "eclatV5 (reverse-hash, p=10): {} itemsets in {}; partition loads {:?}",
        r5.len(),
        fmt_duration(r5.wall),
        r5.partition_loads
    );
    assert_eq!(r.len(), r5.len(), "variants must agree");

    // Optional: the same mining with Phase-2 offloaded to the AOT XLA
    // artifact through PJRT (A4 ablation path). Needs the `xla` cargo
    // feature and `make artifacts`.
    xla_demo(&ctx, &db, min_sup, r5.len())?;
    Ok(())
}

#[cfg(feature = "xla")]
fn xla_demo(
    ctx: &ClusterContext,
    db: &Database,
    min_sup: MinSup,
    baseline_len: usize,
) -> rdd_eclat::error::Result<()> {
    use std::sync::Arc;

    use rdd_eclat::algorithms::CoocStrategy;

    if !rdd_eclat::runtime::artifacts_available() {
        println!("(artifacts/ missing — run `make artifacts` to exercise the XLA backend)");
        return Ok(());
    }
    let svc = Arc::new(rdd_eclat::runtime::XlaService::start(
        rdd_eclat::runtime::default_artifact_dir(),
    )?);
    let opts = EclatOptions {
        tri_matrix: true, // force the matrix on so the backend runs
        cooc: CoocStrategy::Provider(Arc::new(rdd_eclat::runtime::XlaCooc::new(svc))),
        ..Default::default()
    };
    let rx = MiningSession::on(ctx).db(db).min_sup(min_sup).options(opts).run(Variant::V5)?;
    println!(
        "eclatV5 (XLA cooc backend): {} itemsets in {}",
        rx.len(),
        fmt_duration(rx.wall)
    );
    assert_eq!(rx.len(), baseline_len, "XLA backend must agree");
    Ok(())
}

#[cfg(not(feature = "xla"))]
fn xla_demo(
    _ctx: &ClusterContext,
    _db: &Database,
    _min_sup: MinSup,
    _baseline_len: usize,
) -> rdd_eclat::error::Result<()> {
    println!("(built without the `xla` feature — rebuild with `--features xla` to exercise the XLA backend)");
    Ok(())
}
