//! Horizontal transaction database: parsing, stats, filtering.
//!
//! The on-disk format is the FIMI/SPMF standard the paper's datasets use —
//! one transaction per line, space-separated integer items.

use std::collections::HashSet;

use crate::error::{Error, Result};

use super::itemset::Item;

/// A horizontal transaction database. Each transaction's items are sorted
/// ascending and de-duplicated at construction.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Database {
    transactions: Vec<Vec<Item>>,
}

impl Database {
    /// Build from raw rows; sorts and dedups each transaction.
    pub fn from_rows(rows: Vec<Vec<Item>>) -> Database {
        let transactions = rows
            .into_iter()
            .map(|mut t| {
                t.sort_unstable();
                t.dedup();
                t
            })
            .collect();
        Database { transactions }
    }

    /// Parse the FIMI text format (one space-separated transaction per
    /// line; blank lines skipped).
    pub fn parse(text: &str) -> Result<Database> {
        let mut rows = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut t = Vec::new();
            for tok in line.split_ascii_whitespace() {
                let item: Item = tok
                    .parse()
                    .map_err(|_| Error::parse(format!("line {}: bad item {tok:?}", lineno + 1)))?;
                t.push(item);
            }
            rows.push(t);
        }
        Ok(Database::from_rows(rows))
    }

    /// Parse one transaction line (used inside RDD closures).
    pub fn parse_line(line: &str) -> Vec<Item> {
        let mut t: Vec<Item> = line
            .split_ascii_whitespace()
            .filter_map(|tok| tok.parse().ok())
            .collect();
        t.sort_unstable();
        t.dedup();
        t
    }

    /// Number of transactions.
    pub fn len(&self) -> usize {
        self.transactions.len()
    }

    /// True when there are no transactions.
    pub fn is_empty(&self) -> bool {
        self.transactions.is_empty()
    }

    /// Borrow the transactions.
    pub fn transactions(&self) -> &[Vec<Item>] {
        &self.transactions
    }

    /// Consume into the raw rows.
    pub fn into_rows(self) -> Vec<Vec<Item>> {
        self.transactions
    }

    /// Serialize to the FIMI text format.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for t in &self.transactions {
            for (i, item) in t.iter().enumerate() {
                if i > 0 {
                    out.push(' ');
                }
                out.push_str(&item.to_string());
            }
            out.push('\n');
        }
        out
    }

    /// Dataset statistics in the shape of the paper's Table 2.
    pub fn stats(&self) -> DbStats {
        let mut items: HashSet<Item> = HashSet::new();
        let mut total_width = 0usize;
        let mut max_item = 0;
        for t in &self.transactions {
            total_width += t.len();
            for &i in t {
                items.insert(i);
                max_item = max_item.max(i);
            }
        }
        DbStats {
            transactions: self.transactions.len(),
            distinct_items: items.len(),
            avg_width: if self.transactions.is_empty() {
                0.0
            } else {
                total_width as f64 / self.transactions.len() as f64
            },
            max_item,
        }
    }

    /// The filtered-transaction technique of Borgelt [18], used by
    /// EclatV2+: drop infrequent items from every transaction, dropping
    /// transactions that become empty. `keep` must answer membership for
    /// frequent items.
    pub fn filter_items(&self, keep: &dyn Fn(Item) -> bool) -> Database {
        let transactions = self
            .transactions
            .iter()
            .map(|t| t.iter().copied().filter(|&i| keep(i)).collect::<Vec<_>>())
            .filter(|t: &Vec<Item>| !t.is_empty())
            .collect();
        Database { transactions }
    }

    /// Total number of item occurrences (sum of transaction widths) —
    /// the size measure behind the paper's filtering-shrinkage percentages.
    pub fn total_items(&self) -> usize {
        self.transactions.iter().map(Vec::len).sum()
    }
}

/// Table 2-shaped statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct DbStats {
    /// Number of transactions.
    pub transactions: usize,
    /// Number of distinct items occurring.
    pub distinct_items: usize,
    /// Average transaction width.
    pub avg_width: f64,
    /// Largest item id (drives the paper's triangular-matrix size concern).
    pub max_item: Item,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_roundtrip() {
        let db = Database::parse("1 2 3\n2 3\n\n1\n").unwrap();
        assert_eq!(db.len(), 3);
        assert_eq!(db.transactions()[0], vec![1, 2, 3]);
        assert_eq!(db.to_text(), "1 2 3\n2 3\n1\n");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Database::parse("1 x 3").is_err());
    }

    #[test]
    fn rows_are_sorted_and_deduped() {
        let db = Database::from_rows(vec![vec![3, 1, 2, 3, 1]]);
        assert_eq!(db.transactions()[0], vec![1, 2, 3]);
    }

    #[test]
    fn stats_match_hand_count() {
        let db = Database::parse("1 2 3\n2 3\n7\n").unwrap();
        let s = db.stats();
        assert_eq!(s.transactions, 3);
        assert_eq!(s.distinct_items, 4);
        assert!((s.avg_width - 2.0).abs() < 1e-12);
        assert_eq!(s.max_item, 7);
    }

    #[test]
    fn filter_items_borgelt() {
        let db = Database::parse("1 2 3\n2 3\n1 9\n9\n").unwrap();
        // Keep only items 2 and 3 (pretend 1 and 9 are infrequent).
        let filtered = db.filter_items(&|i| i == 2 || i == 3);
        assert_eq!(filtered.len(), 2, "empty transactions dropped");
        assert_eq!(filtered.transactions()[0], vec![2, 3]);
        assert_eq!(filtered.total_items(), 4);
    }

    #[test]
    fn parse_line_lenient() {
        assert_eq!(Database::parse_line("5 1 5 3"), vec![1, 3, 5]);
        assert!(Database::parse_line("").is_empty());
    }
}
