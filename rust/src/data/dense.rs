//! Dense fixed-width dataset generator — statistical twin of the UCI
//! `chess` and `mushroom` datasets the paper evaluates on.
//!
//! Both originals encode categorical attribute/value pairs: every
//! transaction has exactly one item per attribute (chess: 37 attributes,
//! 75 distinct items; mushroom: 23 attributes, 119 items), which makes
//! them extremely dense — the regime where Eclat's tidsets are long and
//! the triangular-matrix optimization matters. We reproduce that shape:
//! attribute `a` owns a contiguous item-id range; each transaction picks
//! one value per attribute from a skewed (Zipf) per-attribute
//! distribution, with pairwise correlation between neighbouring
//! attributes to create deep frequent itemsets like the originals'.

use crate::fim::transaction::Database;
use crate::fim::Item;
use crate::util::prng::{Rng, Zipf};

/// Parameters of the dense generator.
#[derive(Debug, Clone)]
pub struct DenseParams {
    /// Number of transactions.
    pub transactions: usize,
    /// Number of attributes = transaction width.
    pub attributes: usize,
    /// Total distinct items; distributed over attributes as evenly as
    /// possible (each attribute gets ≥ 1 value).
    pub items: usize,
    /// Zipf skew of per-attribute value popularity (higher = denser).
    pub skew: f64,
    /// Fraction of attributes that are "hot": their top value is nearly
    /// universal (the real chess/mushroom datasets have many attribute
    /// values with >90% support — that is what makes them dense).
    pub hot_fraction: f64,
    /// Zipf skew of hot attributes.
    pub hot_skew: f64,
    /// Probability that attribute `a` copies the *rank* chosen by
    /// attribute `a-1` (creates cross-attribute correlation → deep
    /// frequent itemsets).
    pub correlation: f64,
}

impl DenseParams {
    /// chess-like: 3196 × 37 attributes × 75 items.
    pub fn chess_like() -> DenseParams {
        // chess: a third of the attribute values are near-universal
        // (>95% support), the rest moderately skewed.
        DenseParams {
            transactions: 3196,
            attributes: 37,
            items: 75,
            skew: 1.2,
            hot_fraction: 0.35,
            hot_skew: 6.0,
            correlation: 0.35,
        }
    }

    /// mushroom-like: 8124 × 23 attributes × 119 items.
    pub fn mushroom_like() -> DenseParams {
        DenseParams {
            transactions: 8124,
            attributes: 23,
            items: 119,
            skew: 1.3,
            hot_fraction: 0.25,
            hot_skew: 6.0,
            correlation: 0.3,
        }
    }
}

/// Generate the dense database deterministically from `seed`.
pub fn generate(params: &DenseParams, seed: u64) -> Database {
    assert!(params.attributes > 0 && params.items >= params.attributes);
    let mut rng = Rng::new(seed);

    // Distribute items over attributes: first `extra` attributes get one
    // more value.
    let base = params.items / params.attributes;
    let extra = params.items % params.attributes;
    let mut domains: Vec<(Item, usize)> = Vec::with_capacity(params.attributes); // (first id, size)
    let mut next = 0u32;
    for a in 0..params.attributes {
        let size = base + usize::from(a < extra);
        domains.push((next, size.max(1)));
        next += size.max(1) as u32;
    }
    let hot_count = (params.attributes as f64 * params.hot_fraction).round() as usize;
    // Spread hot attributes evenly across the attribute list.
    let is_hot: Vec<bool> = (0..params.attributes)
        .map(|a| hot_count > 0 && a * hot_count / params.attributes < ((a + 1) * hot_count / params.attributes).min(hot_count))
        .collect();
    let samplers: Vec<Zipf> = domains
        .iter()
        .zip(&is_hot)
        .map(|(&(_, size), &hot)| Zipf::new(size, if hot { params.hot_skew } else { params.skew }))
        .collect();

    let mut rows = Vec::with_capacity(params.transactions);
    for _ in 0..params.transactions {
        let mut t = Vec::with_capacity(params.attributes);
        let mut prev_rank = 0usize;
        for (a, &(first, size)) in domains.iter().enumerate() {
            // Hot attributes keep their own near-deterministic draw:
            // copying a neighbour's rank would dilute the near-universal
            // values the real datasets exhibit.
            let rank = if a > 0 && !is_hot[a] && rng.chance(params.correlation) {
                prev_rank.min(size - 1)
            } else {
                samplers[a].sample(&mut rng)
            };
            prev_rank = rank;
            t.push(first + rank as u32);
        }
        rows.push(t);
    }
    Database::from_rows(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let p = DenseParams {
            transactions: 100,
            attributes: 5,
            items: 15,
            skew: 1.5,
            hot_fraction: 0.4,
            hot_skew: 6.0,
            correlation: 0.3,
        };
        assert_eq!(generate(&p, 1), generate(&p, 1));
        assert_ne!(generate(&p, 1), generate(&p, 2));
    }

    #[test]
    fn fixed_width_and_vocabulary() {
        let p = DenseParams::chess_like();
        let db = generate(&p, 5);
        let s = db.stats();
        assert_eq!(s.transactions, 3196);
        // Each transaction has one item per attribute; all distinct since
        // domains are disjoint.
        assert!((s.avg_width - 37.0).abs() < 1e-9, "width {}", s.avg_width);
        assert!(s.max_item < 75);
        // Skew keeps some rare values unused sometimes; most appear.
        assert!(s.distinct_items > 55, "{}", s.distinct_items);
    }

    #[test]
    fn is_dense_like_chess() {
        // At 85% support, a chess-like dataset must still have frequent
        // items (the originals have dozens).
        let p = DenseParams::chess_like();
        let db = generate(&p, 5);
        let min_sup = (0.85 * db.len() as f64) as u32;
        let mut item_counts = std::collections::HashMap::new();
        for t in db.transactions() {
            for &i in t {
                *item_counts.entry(i).or_insert(0u32) += 1;
            }
        }
        let frequent = item_counts.values().filter(|&&c| c >= min_sup).count();
        assert!(frequent >= 10, "{frequent} frequent items at 85%");
    }

    #[test]
    fn domains_are_disjoint_per_attribute() {
        let p = DenseParams {
            transactions: 50,
            attributes: 4,
            items: 10,
            skew: 1.0,
            hot_fraction: 0.0,
            hot_skew: 1.0,
            correlation: 0.0,
        };
        let db = generate(&p, 9);
        // Items 0..2 attr0 (3 values: base=2 extra=2 -> sizes 3,3,2,2)
        for t in db.transactions() {
            assert_eq!(t.len(), 4, "one per attribute, all distinct");
        }
    }
}
