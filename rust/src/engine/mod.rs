//! A from-scratch Spark-like RDD engine — the distributed substrate the
//! paper's algorithms run on (DESIGN.md §2.1, systems S1–S8).
//!
//! The public surface mirrors the subset of the Spark RDD API that the
//! paper's pseudo code uses: `parallelize`/`textFile`, lazy
//! transformations (`map`, `flatMap`, `filter`, `mapPartitionsWithIndex`,
//! `groupByKey`, `reduceByKey`, `partitionBy`, `coalesce`,
//! `repartition`), actions (`collect`, `count`, `saveAsTextFile`),
//! `.cache()`, broadcast variables and accumulators — plus per-task
//! metrics and a virtual-cluster simulator for core-scaling studies.
//!
//! Execution is fault-tolerant during a job, not just between jobs: the
//! stage scheduler retries panicked tasks, re-materializes lost shuffle
//! outputs through lineage mid-job, and can speculate on stragglers (see
//! [`rdd`] and [`context::SchedulerConfig`]); [`chaos::ChaosPolicy`]
//! injects seeded faults to exercise all of it deterministically.

pub mod chaos;
pub mod context;
pub mod lineage;
pub mod metrics;
pub mod partitioner;
pub mod pool;
pub mod rdd;
pub mod shared;
pub mod shuffle;
pub mod simcluster;
pub mod storage;

pub use chaos::ChaosPolicy;
pub use context::{available_cores, ClusterContext, ContextBuilder, SchedulerConfig};
pub use lineage::FaultInjector;
pub use metrics::{JobId, JobSpan, MetricsRegistry, StageKind, TaskMetric};
pub use partitioner::{FnPartitioner, HashPartitioner, Partitioner};
pub use rdd::{Data, Rdd, RddId};
pub use shared::{Accumulator, Broadcast};
pub use shuffle::{ShuffleId, ShuffleStore};
pub use simcluster::{simulate, stage_makespan, sweep, SimResult};
pub use storage::{CacheStore, StorageLevel};
