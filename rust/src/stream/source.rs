//! Micro-batch sources: replay a static database, or generate a
//! clickstream lazily — optionally paced in wall time, the way a
//! DStream receiver would hand over one RDD per batch interval.

use std::time::Duration;

use crate::data::clickstream::{ClickGen, ClickParams};
use crate::fim::{Database, Item};

/// A producer of micro-batches.
pub trait BatchSource {
    /// The next micro-batch of transactions, or `None` when the stream
    /// is exhausted.
    fn next_batch(&mut self) -> Option<Vec<Vec<Item>>>;
}

impl<S: BatchSource + ?Sized> BatchSource for Box<S> {
    fn next_batch(&mut self) -> Option<Vec<Vec<Item>>> {
        (**self).next_batch()
    }
}

/// Replay any [`Database`] as fixed-size micro-batches, in order — the
/// standard way to turn the Table 2 benchmark datasets into streams.
#[derive(Debug)]
pub struct ReplaySource {
    rows: Vec<Vec<Item>>,
    batch_size: usize,
    pos: usize,
}

impl ReplaySource {
    /// Stream `db` in batches of `batch_size` transactions (the last
    /// batch may be short).
    pub fn new(db: Database, batch_size: usize) -> ReplaySource {
        assert!(batch_size >= 1, "batch size must be at least 1");
        ReplaySource { rows: db.into_rows(), batch_size, pos: 0 }
    }

    /// Transactions not yet emitted.
    pub fn remaining(&self) -> usize {
        self.rows.len() - self.pos
    }
}

impl BatchSource for ReplaySource {
    fn next_batch(&mut self) -> Option<Vec<Vec<Item>>> {
        if self.pos >= self.rows.len() {
            return None;
        }
        let end = (self.pos + self.batch_size).min(self.rows.len());
        let batch = self.rows[self.pos..end].to_vec();
        self.pos = end;
        Some(batch)
    }
}

/// Generate a (possibly drifting) clickstream lazily, one micro-batch at
/// a time. Batches are produced by absolute transaction index, so the
/// stream is identical to `clickstream::generate` with the same
/// parameters and seed — just never materialized whole. The sampler
/// tables ([`ClickGen`]) are built once and reused across batches.
#[derive(Debug)]
pub struct ClickstreamSource {
    generator: ClickGen,
    batch_size: usize,
    pos: usize,
    /// Stop after this many transactions (`params.sessions` by default).
    limit: usize,
}

impl ClickstreamSource {
    /// Stream `params.sessions` transactions from the generator.
    pub fn new(params: ClickParams, seed: u64, batch_size: usize) -> ClickstreamSource {
        assert!(batch_size >= 1, "batch size must be at least 1");
        let limit = params.sessions;
        ClickstreamSource { generator: ClickGen::new(params, seed), batch_size, pos: 0, limit }
    }

    /// Override the total transaction budget (e.g. cap a demo run).
    pub fn with_limit(mut self, total_txns: usize) -> ClickstreamSource {
        self.limit = total_txns;
        self
    }
}

impl BatchSource for ClickstreamSource {
    fn next_batch(&mut self) -> Option<Vec<Vec<Item>>> {
        if self.pos >= self.limit {
            return None;
        }
        let n = self.batch_size.min(self.limit - self.pos);
        let batch = self.generator.range(self.pos, n);
        self.pos += n;
        Some(batch)
    }
}

/// Wrap a source with a fixed inter-batch interval: `next_batch` sleeps
/// so batches arrive at most once per `interval` — live-traffic pacing
/// for the demos (tests and benches use the sources unpaced).
#[derive(Debug)]
pub struct Paced<S> {
    inner: S,
    interval: Duration,
    last: Option<std::time::Instant>,
}

impl<S: BatchSource> Paced<S> {
    /// Pace `inner` to one batch per `interval`.
    pub fn new(inner: S, interval: Duration) -> Paced<S> {
        Paced { inner, interval, last: None }
    }
}

impl<S: BatchSource> BatchSource for Paced<S> {
    fn next_batch(&mut self) -> Option<Vec<Vec<Item>>> {
        if let Some(last) = self.last {
            let elapsed = last.elapsed();
            if elapsed < self.interval {
                std::thread::sleep(self.interval - elapsed);
            }
        }
        self.last = Some(std::time::Instant::now());
        self.inner.next_batch()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::clickstream;

    #[test]
    fn replay_chunks_in_order_with_short_tail() {
        let db = Database::from_rows((0..7).map(|i| vec![i]).collect());
        let mut src = ReplaySource::new(db, 3);
        assert_eq!(src.remaining(), 7);
        assert_eq!(src.next_batch().unwrap().len(), 3);
        assert_eq!(src.next_batch().unwrap().len(), 3);
        let tail = src.next_batch().unwrap();
        assert_eq!(tail, vec![vec![6]]);
        assert!(src.next_batch().is_none());
        assert_eq!(src.remaining(), 0);
    }

    #[test]
    fn clickstream_source_equals_generate() {
        let params = ClickParams { sessions: 500, ..ClickParams::drift() };
        let full = clickstream::generate(&params, 9);
        let mut src = ClickstreamSource::new(params, 9, 128);
        let mut rows = Vec::new();
        let mut batches = 0;
        while let Some(b) = src.next_batch() {
            rows.extend(b);
            batches += 1;
        }
        assert_eq!(batches, 4, "500 txns in batches of 128");
        assert_eq!(Database::from_rows(rows), full);
    }

    #[test]
    fn clickstream_limit_caps_the_stream() {
        let params = ClickParams { sessions: 10_000, ..ClickParams::drift() };
        let mut src = ClickstreamSource::new(params, 1, 64).with_limit(100);
        let mut total = 0;
        while let Some(b) = src.next_batch() {
            total += b.len();
        }
        assert_eq!(total, 100);
    }

    #[test]
    fn paced_source_passes_batches_through() {
        let db = Database::from_rows(vec![vec![1], vec![2]]);
        let mut src = Paced::new(ReplaySource::new(db, 1), Duration::from_millis(1));
        assert_eq!(src.next_batch().unwrap(), vec![vec![1]]);
        assert_eq!(src.next_batch().unwrap(), vec![vec![2]]);
        assert!(src.next_batch().is_none());
    }
}
