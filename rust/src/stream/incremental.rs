//! The incrementally-maintained vertical database.
//!
//! The companion work on RDD-Apriori data structures (arXiv:1908.01338)
//! argues the vertical/bitset layout is what makes re-counting cheap;
//! this module exploits that for streaming: each item keeps one
//! [`TidBitmap`] over the window's transaction-id space. Appending a
//! batch sets bits at the tail; evicting a batch clears one contiguous
//! tid range per touched item ([`TidBitmap::clear_range`]); per-item
//! supports are maintained as running counts, so the frequent-item scan
//! never re-counts bitmaps. When the dead prefix outgrows the live span,
//! the store compacts — rebasing every bitmap onto a fresh tid origin —
//! so memory tracks the window size, not the stream length.
//!
//! Supports of *itemsets* over the window change only when a transaction
//! containing the whole itemset enters or leaves — which requires every
//! one of its items to be **dirty** (present in an appended or evicted
//! batch). The mining job builds its reuse/re-mine split on exactly that
//! observation, so `append`/`evict` report touched items into the
//! caller's dirty set.

use std::collections::{HashMap, HashSet};

use crate::fim::{Item, Tid, TidBitmap};

/// Per-item vertical store maintained across micro-batches. Transactions
/// enter at the tail and leave from the head (FIFO), mirroring the
/// sliding window that drives it.
#[derive(Debug, Default)]
pub struct IncrementalVerticalDb {
    bitmaps: HashMap<Item, TidBitmap>,
    supports: HashMap<Item, u32>,
    /// Local tid one past the newest appended transaction.
    next: Tid,
    /// Local tid of the oldest live transaction.
    live_lo: Tid,
    /// Live transaction count (`next - live_lo`).
    txns: usize,
}

impl IncrementalVerticalDb {
    /// Empty store.
    pub fn new() -> IncrementalVerticalDb {
        IncrementalVerticalDb::default()
    }

    /// Live transaction count.
    pub fn txns(&self) -> usize {
        self.txns
    }

    /// Number of distinct live items.
    pub fn distinct_items(&self) -> usize {
        self.supports.len()
    }

    /// Current support of `item` over the window.
    pub fn support(&self, item: Item) -> u32 {
        self.supports.get(&item).copied().unwrap_or(0)
    }

    /// Append one batch at the tail. Rows must be normalized (sorted,
    /// de-duplicated). Every item occurring in the batch is added to
    /// `dirty`.
    pub fn append(&mut self, rows: &[Vec<Item>], dirty: &mut HashSet<Item>) {
        for row in rows {
            debug_assert!(row.windows(2).all(|w| w[0] < w[1]), "row not normalized: {row:?}");
            let t = self.next;
            self.next += 1;
            for &item in row {
                let bm = self.bitmaps.entry(item).or_insert_with(|| TidBitmap::new(0));
                bm.grow(self.next as usize);
                bm.insert(t);
                *self.supports.entry(item).or_insert(0) += 1;
                dirty.insert(item);
            }
        }
        self.txns += rows.len();
    }

    /// Evict the oldest `rows.len()` transactions, whose contents are
    /// `rows`. Thin wrapper that derives the touched-item hint from the
    /// rows and delegates to [`IncrementalVerticalDb::evict_touched`].
    pub fn evict(&mut self, rows: &[Vec<Item>], dirty: &mut HashSet<Item>) {
        let mut touched: Vec<Item> = rows.iter().flatten().copied().collect();
        touched.sort_unstable();
        touched.dedup();
        self.evict_touched(rows.len(), &touched, dirty);
    }

    /// Evict the oldest `txns` transactions given the distinct items they
    /// contain (`touched` — the window's per-batch item hint, orders of
    /// magnitude smaller than the rows themselves): clears each touched
    /// item's tid range once — O(touched items), not O(all live items) —
    /// updates the running supports from the cleared-bit counts, adds
    /// every touched item to `dirty`, and removes items whose support
    /// drops to zero. Compacts when the dead prefix outgrows the live
    /// span.
    pub fn evict_touched(&mut self, txns: usize, touched: &[Item], dirty: &mut HashSet<Item>) {
        debug_assert!(self.txns >= txns, "evicting more transactions than live");
        let (lo, hi) = (self.live_lo, self.live_lo + txns as Tid);
        for &item in touched {
            dirty.insert(item);
            let Some(bm) = self.bitmaps.get_mut(&item) else { continue };
            let cleared = bm.clear_range(lo, hi);
            let support = self.supports.entry(item).or_insert(0);
            *support = support.saturating_sub(cleared);
            if *support == 0 {
                self.supports.remove(&item);
                self.bitmaps.remove(&item);
            }
        }
        self.live_lo = hi;
        self.txns -= txns;
        self.maybe_compact();
    }

    /// Hint-free eviction of the oldest `txns` transactions: clears the
    /// tid range from **every** item's bitmap — the store itself knows
    /// which items the evicted transactions contained (an item occurred
    /// in them iff its bitmap had bits in the range), so no horizontal
    /// copy of the evicted rows is needed at all. O(all live items) per
    /// call; the streaming job prefers [`IncrementalVerticalDb::evict_touched`]
    /// with the window's per-batch item hint, and the parity tests use
    /// this as the hint-free oracle.
    pub fn evict_range(&mut self, txns: usize, dirty: &mut HashSet<Item>) {
        debug_assert!(self.txns >= txns, "evicting more transactions than live");
        let (lo, hi) = (self.live_lo, self.live_lo + txns as Tid);
        let supports = &mut self.supports;
        self.bitmaps.retain(|&item, bm| {
            let cleared = bm.clear_range(lo, hi);
            if cleared == 0 {
                return true;
            }
            dirty.insert(item);
            let remaining = {
                let s = supports.entry(item).or_insert(0);
                *s = s.saturating_sub(cleared);
                *s
            };
            if remaining == 0 {
                supports.remove(&item);
                false
            } else {
                true
            }
        });
        self.live_lo = hi;
        self.txns -= txns;
        self.maybe_compact();
    }

    /// Reconstruct the live window horizontally, oldest transaction
    /// first: row `t` = the sorted items whose bitmaps contain tid `t`.
    /// This is the row-free streaming driver's materialization/parity
    /// path — the vertical store is the single copy of the window, and
    /// empty transactions come back as empty rows.
    pub fn live_rows(&self) -> Vec<Vec<Item>> {
        let mut rows = vec![Vec::new(); self.txns];
        for (&item, bm) in &self.bitmaps {
            for t in bm.iter() {
                debug_assert!(
                    t >= self.live_lo && t < self.next,
                    "live bitmap bit {t} outside window [{}, {})",
                    self.live_lo,
                    self.next
                );
                rows[(t - self.live_lo) as usize].push(item);
            }
        }
        for row in &mut rows {
            row.sort_unstable();
        }
        rows
    }

    /// Local tid bounds `(live_lo, next)` — the live window spans
    /// `[live_lo, next)`. Used by the sharded store to assert that all
    /// shards stay in the same tid space (identical append/evict/compact
    /// schedules keep the bounds equal across shards).
    pub(crate) fn tid_bounds(&self) -> (Tid, Tid) {
        (self.live_lo, self.next)
    }

    /// Rebase every bitmap onto tid origin 0 once the evicted prefix
    /// exceeds the live span: O(live bits), amortized O(1) per eviction.
    /// Pure renumbering — all pairwise intersection counts are shift
    /// invariant, so mining results (and the job's reuse cache) are
    /// unaffected.
    fn maybe_compact(&mut self) {
        let span = self.next - self.live_lo;
        if self.live_lo < 64 || self.live_lo <= span {
            return;
        }
        let delta = self.live_lo;
        let universe = span as usize;
        let supports = &mut self.supports;
        self.bitmaps.retain(|&item, bm| {
            let shifted = TidBitmap::from_tids(
                universe,
                bm.iter().filter(|&t| t >= delta).map(|t| t - delta),
            );
            debug_assert_eq!(shifted.count(), bm.count(), "compaction dropped live bits");
            if shifted.count() == 0 {
                // Hygiene backstop: both eviction paths already prune
                // zero-support entries, but compaction re-walks every
                // column anyway, so a dead item can never outlive a
                // compaction point — under keyspace drift the store's
                // footprint tracks the live window, not the stream's
                // item history.
                supports.remove(&item);
                false
            } else {
                *bm = shifted;
                true
            }
        });
        self.live_lo = 0;
        self.next = span;
    }

    /// Frequent atoms for mining: `(item, tidset bitmap, support)` for
    /// every item with `support >= min_sup` **and** `keep(item)`, ordered
    /// by ascending support with item id as tie-break (the paper's
    /// Phase-1 total order). Bitmaps are cloned — mining tasks need owned
    /// data to move onto executor threads.
    pub fn atoms(&self, min_sup: u32, keep: impl Fn(Item) -> bool) -> Vec<(Item, TidBitmap, u32)> {
        let mut out: Vec<(Item, TidBitmap, u32)> = Vec::new();
        for (&item, &sup) in &self.supports {
            if sup >= min_sup && keep(item) {
                out.push((item, self.bitmaps[&item].clone(), sup));
            }
        }
        out.sort_by(|a, b| a.2.cmp(&b.2).then_with(|| a.0.cmp(&b.0)));
        out
    }

    /// Number of items with `support >= min_sup`.
    pub fn frequent_count(&self, min_sup: u32) -> usize {
        self.frequent_count_where(min_sup, |_| true)
    }

    /// Number of items with `support >= min_sup` satisfying `keep` —
    /// the churn measurement, taken without cloning any bitmaps.
    pub fn frequent_count_where(&self, min_sup: u32, keep: impl Fn(Item) -> bool) -> usize {
        let mut n = 0;
        for (&item, &sup) in &self.supports {
            if sup >= min_sup && keep(item) {
                n += 1;
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dirty() -> HashSet<Item> {
        HashSet::new()
    }

    #[test]
    fn append_tracks_supports_and_dirty() {
        let mut db = IncrementalVerticalDb::new();
        let mut d = dirty();
        db.append(&[vec![1, 2], vec![2, 3], vec![]], &mut d);
        assert_eq!(db.txns(), 3);
        assert_eq!(db.support(2), 2);
        assert_eq!(db.support(1), 1);
        assert_eq!(db.support(9), 0);
        assert_eq!(d, HashSet::from([1, 2, 3]));
        assert_eq!(db.distinct_items(), 3);
        assert_eq!(db.frequent_count(2), 1);
    }

    #[test]
    fn evict_masks_ranges_and_updates_supports() {
        let mut db = IncrementalVerticalDb::new();
        let mut d = dirty();
        let b0 = vec![vec![1, 2], vec![1, 3]];
        let b1 = vec![vec![1, 2], vec![2, 3]];
        db.append(&b0, &mut d);
        db.append(&b1, &mut d);
        assert_eq!(db.support(1), 3);
        d.clear();
        db.evict(&b0, &mut d);
        assert_eq!(db.txns(), 2);
        assert_eq!(db.support(1), 1);
        assert_eq!(db.support(3), 1);
        assert_eq!(d, HashSet::from([1, 2, 3]), "evicted items are dirty");
        // Item 1's remaining tid is batch 1's first transaction.
        let atoms = db.atoms(1, |_| true);
        let one = atoms.iter().find(|(i, _, _)| *i == 1).unwrap();
        assert_eq!(one.1.iter().collect::<Vec<_>>(), vec![2]);
        assert_eq!(one.2, 1);
    }

    #[test]
    fn evict_to_empty_removes_items() {
        let mut db = IncrementalVerticalDb::new();
        let mut d = dirty();
        let b = vec![vec![4, 5]];
        db.append(&b, &mut d);
        db.evict(&b, &mut d);
        assert_eq!(db.txns(), 0);
        assert_eq!(db.distinct_items(), 0);
        assert!(db.atoms(1, |_| true).is_empty());
        // The store stays usable after full eviction.
        db.append(&[vec![4]], &mut d);
        assert_eq!(db.support(4), 1);
    }

    #[test]
    fn atoms_order_and_filter() {
        let mut db = IncrementalVerticalDb::new();
        let mut d = dirty();
        db.append(&[vec![1, 2, 3], vec![2, 3], vec![3]], &mut d);
        let all = db.atoms(1, |_| true);
        let order: Vec<(Item, u32)> = all.iter().map(|(i, _, s)| (*i, *s)).collect();
        assert_eq!(order, vec![(1, 1), (2, 2), (3, 3)], "ascending support");
        let only_23 = db.atoms(2, |_| true);
        assert_eq!(only_23.len(), 2);
        let filtered = db.atoms(1, |i| i != 2);
        assert_eq!(filtered.iter().map(|(i, _, _)| *i).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(db.frequent_count_where(1, |i| i != 2), 2);
        assert_eq!(db.frequent_count_where(2, |_| true), db.frequent_count(2));
    }

    #[test]
    fn hinted_and_hint_free_eviction_agree() {
        // Three identical stores: evicted with the batch rows in hand,
        // with only the distinct-item hint, and purely by count (the
        // scan-all oracle). All must stay indistinguishable.
        let batches =
            vec![vec![vec![1, 2], vec![3]], vec![vec![2, 3], vec![]], vec![vec![1, 4]]];
        let mut a = IncrementalVerticalDb::new();
        let mut b = IncrementalVerticalDb::new();
        let mut c = IncrementalVerticalDb::new();
        let (mut da, mut db_dirty, mut dc) = (dirty(), dirty(), dirty());
        for batch in &batches {
            a.append(batch, &mut da);
            b.append(batch, &mut db_dirty);
            c.append(batch, &mut dc);
        }
        da.clear();
        db_dirty.clear();
        dc.clear();
        a.evict(&batches[0], &mut da);
        b.evict_touched(batches[0].len(), &[1, 2, 3], &mut db_dirty);
        c.evict_range(batches[0].len(), &mut dc);
        assert_eq!(da, db_dirty, "row-based vs hinted dirty sets");
        assert_eq!(da, dc, "row-based vs scan-all dirty sets");
        assert_eq!(a.txns(), b.txns());
        assert_eq!(a.live_rows(), b.live_rows());
        assert_eq!(a.live_rows(), c.live_rows());
        assert_eq!(a.atoms(1, |_| true).len(), b.atoms(1, |_| true).len());
        assert_eq!(a.atoms(1, |_| true).len(), c.atoms(1, |_| true).len());
    }

    #[test]
    fn live_rows_reconstructs_window_in_tid_order() {
        let mut db = IncrementalVerticalDb::new();
        let mut d = dirty();
        db.append(&[vec![2, 5], vec![], vec![1, 2]], &mut d);
        db.append(&[vec![7]], &mut d);
        assert_eq!(
            db.live_rows(),
            vec![vec![2, 5], vec![], vec![1, 2], vec![7]],
            "rows come back sorted, in ingestion order, empties preserved"
        );
        db.evict_range(2, &mut d);
        assert_eq!(db.live_rows(), vec![vec![1, 2], vec![7]]);
        db.evict_range(2, &mut d);
        assert!(db.live_rows().is_empty());
    }

    #[test]
    fn keyspace_drift_does_not_leak_dead_items() {
        // Regression: sliding a window across a drifting keyspace — each
        // epoch draws from a fresh, disjoint item range, so every item
        // eventually dies. The store must forget dead items (supports
        // AND bitmaps in lockstep), keeping `distinct_items()` equal to
        // the live window's true distinct count instead of growing with
        // the stream's item history.
        let mut db = IncrementalVerticalDb::new();
        let mut d = dirty();
        let mut pending: std::collections::VecDeque<Vec<Vec<Item>>> =
            std::collections::VecDeque::new();
        for step in 0..300u32 {
            let base = (step / 10) * 100; // keyspace shifts every 10 batches
            let batch = vec![vec![base, base + 1], vec![base + 1, base + 2]];
            db.append(&batch, &mut d);
            pending.push_back(batch);
            if pending.len() > 4 {
                db.evict(&pending.pop_front().unwrap(), &mut d);
            }
            let mut live: HashSet<Item> = HashSet::new();
            for b in &pending {
                for row in b {
                    live.extend(row.iter().copied());
                }
            }
            assert_eq!(db.distinct_items(), live.len(), "step {step}: dead items leaked");
            assert_eq!(
                db.bitmaps.len(),
                db.supports.len(),
                "step {step}: columns and supports out of lockstep"
            );
            for (&item, bm) in &db.bitmaps {
                assert!(bm.count() > 0, "step {step}: zero-support column {item} retained");
            }
        }
        assert!(db.distinct_items() <= 6, "window spans at most two 3-item epochs");
    }

    #[test]
    fn compaction_preserves_contents() {
        let mut db = IncrementalVerticalDb::new();
        let mut d = dirty();
        // Slide a window of 2 one-transaction batches far enough that the
        // dead prefix repeatedly exceeds the live span.
        let mut pending: std::collections::VecDeque<Vec<Vec<Item>>> =
            std::collections::VecDeque::new();
        for step in 0..200u32 {
            let batch = vec![vec![step % 5, 5 + (step % 3)]];
            db.append(&batch, &mut d);
            pending.push_back(batch);
            if pending.len() > 2 {
                db.evict(&pending.pop_front().unwrap(), &mut d);
            }
        }
        assert_eq!(db.txns(), 2);
        // Window holds steps 198 and 199: items {198%5, 5+198%3, 199%5, 5+199%3}.
        let expect: HashSet<Item> = HashSet::from([198 % 5, 5 + 198 % 3, 199 % 5, 5 + 199 % 3]);
        let got: HashSet<Item> = db.atoms(1, |_| true).iter().map(|(i, _, _)| *i).collect();
        assert_eq!(got, expect);
        for (_, bm, sup) in db.atoms(1, |_| true) {
            assert_eq!(bm.count(), sup, "running support equals bitmap population");
            assert!(bm.universe() <= 128, "compaction bounded the universe");
        }
    }
}
