//! A from-scratch worker thread pool — the "executors" of the mini-Spark
//! engine. The offline crate set has no `tokio`/`rayon`, and the paper's
//! substrate (Spark executors running tasks) is exactly a fixed pool of
//! workers pulling tasks from a queue, so we build that.
//!
//! Tasks are plain closures; [`ThreadPool::run_all`] is the scatter/gather
//! primitive used by the stage scheduler: submit one closure per partition,
//! block until all complete, and return results in partition order.
//! Panics inside tasks are caught and surfaced as [`Error::Engine`] so a
//! bad task cannot wedge the driver, and submission never panics:
//! [`ThreadPool::execute`] returns `Err` (not a panic) once the pool has
//! shut down, so long-lived drivers — the streaming ingest loop in
//! particular — can race shutdown against in-flight work safely.
//!
//! Shutdown is graceful: [`ThreadPool::shutdown`] (also run on drop)
//! closes the submission side, lets the workers drain every job already
//! queued, and joins them.
//!
//! The submission queue is a hand-rolled `Mutex<VecDeque> + Condvar`
//! (not an `mpsc` channel) built on [`crate::sync`], so the
//! shutdown-vs-`execute` races are model-checked by loom
//! (`tests/loom_models.rs`); the only channel left is the sequential
//! result gather in [`ThreadPool::try_run_all`], which no model runs.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::error::{Error, Result};
use crate::sync::global::OnceLock;
use crate::sync::thread::{Builder, JoinHandle};
use crate::sync::{lock_unpoisoned, mpsc, Arc, Condvar, Mutex, PoisonError};

type Job = Box<dyn FnOnce() + Send + 'static>;

/// What `catch_unwind` hands back for a task: the value, or the panic
/// payload.
type TaskResult<T> = std::result::Result<T, Box<dyn std::any::Any + Send + 'static>>;

/// Pool instrumentation cells, resolved once (see [`crate::obs`]).
struct PoolObs {
    queue_depth: &'static crate::obs::Gauge,
    tasks_run: &'static crate::obs::Counter,
}

fn pool_obs() -> &'static PoolObs {
    static OBS: OnceLock<PoolObs> = OnceLock::new();
    OBS.get_or_init(|| PoolObs {
        queue_depth: crate::obs::gauge("engine.pool.queue_depth"),
        tasks_run: crate::obs::counter("engine.pool.tasks_run"),
    })
}

/// The submission queue, guarded by one mutex. `closed` is part of the
/// same guarded state as `jobs` on purpose: a submitter observes
/// "closed" and "queue contents" atomically, so a job is either rejected
/// or guaranteed to be drained — never silently dropped in between.
struct Queue {
    jobs: VecDeque<Job>,
    /// Set by [`ThreadPool::shutdown`]. Workers drain `jobs` first and
    /// only exit on `closed && empty`.
    closed: bool,
}

/// State shared between the pool handle and its workers.
struct PoolShared {
    queue: Mutex<Queue>,
    /// Signaled on every submit (one waiter) and on close (all).
    work: Condvar,
}

/// Fixed-size worker pool. The number of workers models the number of
/// executor cores of the simulated cluster.
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl std::fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let shut_down = lock_unpoisoned(&self.shared.queue).closed;
        f.debug_struct("ThreadPool")
            .field("size", &self.size)
            .field("shut_down", &shut_down)
            .finish()
    }
}

/// One worker: pop-and-run until the queue is closed *and* drained.
fn worker_loop(shared: &PoolShared) {
    loop {
        let job = {
            let mut q = lock_unpoisoned(&shared.queue);
            loop {
                if let Some(job) = q.jobs.pop_front() {
                    break job;
                }
                if q.closed {
                    return;
                }
                // The wait atomically releases and reacquires the queue
                // lock; poisoning is recovered for the same reason as
                // in `lock_unpoisoned` (a sibling's panic is reported
                // through the scheduler, not by cascading here).
                q = shared.work.wait(q).unwrap_or_else(PoisonError::into_inner);
            }
        };
        if crate::obs::enabled() {
            let o = pool_obs();
            o.queue_depth.add(-1);
            o.tasks_run.incr(1);
        }
        // A panicking fire-and-forget job must not take the worker down
        // with it (run_all additionally reports the panic to the
        // driver).
        let _ = catch_unwind(AssertUnwindSafe(job));
    }
}

impl ThreadPool {
    /// Spawn a pool with `size` workers (`size >= 1`).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let shared = Arc::new(PoolShared {
            queue: Mutex::new(Queue { jobs: VecDeque::new(), closed: false }),
            work: Condvar::new(),
        });
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let shared = Arc::clone(&shared);
            workers.push(
                Builder::new()
                    .name(format!("executor-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn executor thread"),
            );
        }
        ThreadPool { shared, workers, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Submit a fire-and-forget job. Errors (instead of panicking) when
    /// the pool has been shut down.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) -> Result<()> {
        {
            let mut q = lock_unpoisoned(&self.shared.queue);
            if q.closed {
                return Err(Error::engine("thread pool has shut down"));
            }
            q.jobs.push_back(Box::new(f));
        }
        // Outside the lock: the woken worker would otherwise block
        // straight back on the queue mutex we still hold.
        self.shared.work.notify_one();
        if crate::obs::enabled() {
            pool_obs().queue_depth.add(1);
        }
        Ok(())
    }

    /// Run every task and gather **per-slot outcomes in task order**:
    /// `Ok(value)` for each task that completed, `Err(panic message)`
    /// for each task that panicked. All tasks run to completion either
    /// way — one bad slot never hides its siblings' results, which is
    /// what lets the stage scheduler retry exactly the failed partitions.
    /// The outer `Err` only fires when the pool itself is unusable
    /// (shut down or disconnected).
    pub fn try_run_all<T, F>(&self, tasks: Vec<F>) -> Result<Vec<std::result::Result<T, String>>>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = tasks.len();
        if n == 0 {
            return Ok(Vec::new());
        }
        let (tx, rx) = mpsc::channel::<(usize, TaskResult<T>)>();
        for (i, task) in tasks.into_iter().enumerate() {
            let tx = tx.clone();
            self.execute(move || {
                let r = catch_unwind(AssertUnwindSafe(task));
                // Receiver may be gone if the driver already failed; ignore.
                let _ = tx.send((i, r));
            })?;
        }
        drop(tx);
        let mut slots: Vec<Option<std::result::Result<T, String>>> =
            (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rx
                .recv()
                .map_err(|_| Error::engine("executor pool disconnected"))?;
            slots[i] = Some(r.map_err(panic_message));
        }
        Ok(slots.into_iter().map(|s| s.expect("all tasks reported")).collect())
    }

    /// Run every task and gather results **in task order**. Tasks run
    /// concurrently across the pool's workers; the calling thread blocks
    /// until all tasks finish. A panicking task yields `Error::Engine`
    /// carrying the first panic payload (all other tasks still run to
    /// completion); submitting against a shut-down pool yields
    /// `Error::Engine` immediately. Callers that want to keep the good
    /// slots use [`ThreadPool::try_run_all`].
    pub fn run_all<T, F>(&self, tasks: Vec<F>) -> Result<Vec<T>>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let slots = self.try_run_all(tasks)?;
        let mut out = Vec::with_capacity(slots.len());
        for slot in slots {
            match slot {
                Ok(v) => out.push(v),
                Err(msg) => return Err(Error::engine(format!("task panicked: {msg}"))),
            }
        }
        Ok(out)
    }

    /// Close the submission side without joining the workers — the
    /// first half of [`ThreadPool::shutdown`]. Needs only `&self`, so a
    /// driver holding the pool in an `Arc` can race it against
    /// [`ThreadPool::execute`] from other threads: because `closed`
    /// lives under the same mutex as the queue, every job is either
    /// rejected or guaranteed to drain (model-checked in
    /// `loom_pool_execute_vs_close_job_runs_iff_accepted`).
    pub fn close(&self) {
        lock_unpoisoned(&self.shared.queue).closed = true;
        // Every worker must wake: those idle on the condvar see
        // `closed` and exit; those mid-job finish, drain what is left,
        // then exit.
        self.shared.work.notify_all();
    }

    /// Graceful shutdown: stop accepting jobs, let the workers drain
    /// everything already queued, and join them. Idempotent; also run on
    /// drop. After shutdown, [`ThreadPool::execute`] and
    /// [`ThreadPool::run_all`] return `Error::Engine` instead of
    /// panicking.
    pub fn shutdown(&mut self) {
        self.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Best-effort extraction of a human-readable message from a panic
/// payload (shared by the pool, the stage scheduler and the streaming
/// ingest loop).
pub(crate) fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

// Not compiled under `cfg(loom)`: these tests sleep and hammer; the
// model-checked coverage of the shutdown/execute races lives in
// `tests/loom_models.rs`.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::panic::panic_any;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::time::Duration;

    #[test]
    fn run_all_preserves_order() {
        let pool = ThreadPool::new(4);
        let tasks: Vec<_> = (0..32).map(|i| move || i * 10).collect();
        let out = pool.run_all(tasks).unwrap();
        assert_eq!(out, (0..32).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn run_all_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.run_all(Vec::<fn() -> i32>::new()).unwrap();
        assert!(out.is_empty());
    }

    #[test]
    fn tasks_actually_run_concurrently_on_workers() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        let tasks: Vec<_> = (0..100)
            .map(|_| {
                let c = Arc::clone(&counter);
                move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        pool.run_all(tasks).unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn try_run_all_keeps_good_slots_next_to_failed_ones() {
        let pool = ThreadPool::new(2);
        let tasks: Vec<Box<dyn FnOnce() -> i32 + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("slot 1 down")),
            Box::new(|| 3),
        ];
        let slots = pool.try_run_all(tasks).unwrap();
        assert_eq!(slots[0], Ok(1));
        assert_eq!(slots[2], Ok(3));
        let msg = slots[1].as_ref().unwrap_err();
        assert!(msg.contains("slot 1 down"), "{msg}");
    }

    #[test]
    fn panicking_task_reports_engine_error() {
        let pool = ThreadPool::new(2);
        let tasks: Vec<Box<dyn FnOnce() -> i32 + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("boom in task")),
            Box::new(|| 3),
        ];
        let err = pool.run_all(tasks).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("boom in task"), "{msg}");
    }

    #[test]
    fn pool_survives_panic_and_runs_more() {
        let pool = ThreadPool::new(2);
        let _ = pool.run_all(vec![Box::new(|| panic!("x")) as Box<dyn FnOnce() + Send>]);
        let out = pool.run_all(vec![|| 7, || 8]).unwrap();
        assert_eq!(out, vec![7, 8]);
    }

    #[test]
    fn pool_survives_panicking_fire_and_forget_job() {
        let pool = ThreadPool::new(1);
        pool.execute(|| panic!("raw job panic")).unwrap();
        // The single worker must still be alive to run this.
        let out = pool.run_all(vec![|| 5]).unwrap();
        assert_eq!(out, vec![5]);
    }

    #[test]
    fn size_clamped_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        assert_eq!(pool.run_all(vec![|| 42]).unwrap(), vec![42]);
    }

    #[test]
    fn drop_drains_pending_tasks() {
        // More slow tasks than workers: at drop time most are still
        // queued. Shutdown must run them all, not abandon them.
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(2);
            for _ in 0..8 {
                let c = Arc::clone(&counter);
                pool.execute(move || {
                    std::thread::sleep(Duration::from_millis(5));
                    c.fetch_add(1, Ordering::SeqCst);
                })
                .unwrap();
            }
        } // drop == shutdown
        assert_eq!(counter.load(Ordering::SeqCst), 8, "queued tasks drained on drop");
    }

    #[test]
    fn submit_after_shutdown_errors_instead_of_panicking() {
        let mut pool = ThreadPool::new(2);
        pool.shutdown();
        pool.shutdown(); // idempotent
        let err = pool.execute(|| {}).unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
        let err = pool.run_all(vec![|| 1]).unwrap_err();
        assert!(err.to_string().contains("shut down"), "{err}");
    }

    #[test]
    fn shutdown_waits_for_in_flight_work() {
        let counter = Arc::new(AtomicU64::new(0));
        let mut pool = ThreadPool::new(1);
        for _ in 0..3 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(Duration::from_millis(3));
                c.fetch_add(1, Ordering::SeqCst);
            })
            .unwrap();
        }
        pool.shutdown();
        assert_eq!(counter.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn debug_reflects_shutdown_state() {
        let mut pool = ThreadPool::new(2);
        assert!(format!("{pool:?}").contains("shut_down: false"));
        pool.shutdown();
        assert!(format!("{pool:?}").contains("shut_down: true"));
    }

    #[test]
    fn panic_message_extracts_str_and_string_payloads() {
        assert_eq!(panic_message(Box::new("static str")), "static str");
        assert_eq!(panic_message(Box::new(String::from("owned message"))), "owned message");
    }

    #[test]
    fn panic_message_non_string_payloads_fall_back() {
        // `panic_any` carries arbitrary payloads; they must degrade to
        // the sentinel, not crash the reporter.
        let payload = catch_unwind(|| panic_any(42u32)).unwrap_err();
        assert_eq!(panic_message(payload), "<non-string panic>");
        let payload = catch_unwind(|| panic_any(vec![1u8, 2])).unwrap_err();
        assert_eq!(panic_message(payload), "<non-string panic>");
        // While `&str`/`String` payloads thrown through `panic_any`
        // still come out verbatim.
        let payload = catch_unwind(|| panic_any("typed str")).unwrap_err();
        assert_eq!(panic_message(payload), "typed str");
        let payload = catch_unwind(|| panic_any(String::from("typed string"))).unwrap_err();
        assert_eq!(panic_message(payload), "typed string");
    }
}
