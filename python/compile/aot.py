"""AOT: lower the L2 graphs to HLO text artifacts for the rust runtime.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py).

Usage::

    cd python && python -m compile.aot --out-dir ../artifacts

Writes one ``.hlo.txt`` per (graph, shape) plus ``manifest.txt`` with
``name file kind shapes`` rows the rust loader validates against.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.cooc import DEFAULT_I, DEFAULT_T
from .kernels.popcount import DEFAULT_N, DEFAULT_W
from .model import cooc_graph, intersect_graph, phase2_graph


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def artifacts_spec():
    """(name, fn, example args, manifest shape string) for every artifact."""
    f32 = jnp.float32
    u32 = jnp.uint32
    t, i = DEFAULT_T, DEFAULT_I
    n, w = DEFAULT_N, DEFAULT_W
    return [
        (
            f"cooc_{t}x{i}",
            cooc_graph,
            (
                jax.ShapeDtypeStruct((t, i), f32),
                jax.ShapeDtypeStruct((t, i), f32),
            ),
            f"in=f32[{t},{i}]x2 out=f32[{i},{i}]",
        ),
        (
            f"phase2_{t}x{i}",
            phase2_graph,
            (jax.ShapeDtypeStruct((t, i), f32),),
            f"in=f32[{t},{i}] out=f32[{i}],f32[{i},{i}]",
        ),
        (
            f"popcount_{n}x{w}",
            intersect_graph,
            (
                jax.ShapeDtypeStruct((n, w), u32),
                jax.ShapeDtypeStruct((n, w), u32),
            ),
            f"in=u32[{n},{w}]x2 out=s32[{n}]",
        ),
    ]


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_lines = []
    for name, fn, example_args, shapes in artifacts_spec():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest_lines.append(f"{name} {fname} {shapes}")
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    print(f"wrote manifest with {len(manifest_lines)} artifacts")


if __name__ == "__main__":
    main()
