//! End-to-end driver (DESIGN.md; EXPERIMENTS.md §End-to-end): run the
//! full system on a real small workload, proving all layers compose —
//! dataset generation → RDD engine → all six algorithms → result
//! cross-check → headline metric (Eclat-vs-Apriori speedup) → simulated
//! core scaling from measured task metrics.
//!
//! ```text
//! cargo run --release --example scaling_study
//! ```

use rdd_eclat::algorithms::{Algorithm, EclatOptions, SeqEclat, Variant};
use rdd_eclat::data::DatasetSpec;
use rdd_eclat::engine::{simcluster, ClusterContext};
use rdd_eclat::fim::{sort_frequents, MinSup};
use rdd_eclat::util::{Stopwatch, time::fmt_duration};

fn main() -> rdd_eclat::error::Result<()> {
    // Real small workload: the T10I4D100K twin (full 100k transactions).
    let db = DatasetSpec::T10i4d100k.materialize("datasets")?;
    let stats = db.stats();
    let min_sup = MinSup::fraction(0.01);
    println!(
        "workload: {} ({} txns, {} items, avg width {:.1}), min_sup=0.01",
        DatasetSpec::T10i4d100k.name(),
        stats.transactions,
        stats.distinct_items,
        stats.avg_width
    );

    // Ground truth from the sequential oracle.
    let mut want = SeqEclat::mine(&db, min_sup);
    sort_frequents(&mut want);
    println!("oracle: {} frequent itemsets (seq-eclat)", want.len());

    // The six comparison algorithms, built through the Variant registry.
    let opts = EclatOptions::default();
    let algos: Vec<Box<dyn Algorithm>> =
        Variant::STANDARD.iter().map(|v| v.build(&opts)).collect();

    let ctx = ClusterContext::builder().build();
    let mut apriori_secs = 0.0;
    let mut best = ("-", f64::MAX);
    println!("\n{:<10} {:>12} {:>10} {:>8}", "algorithm", "time", "itemsets", "ok");
    for algo in &algos {
        ctx.metrics().reset();
        let sw = Stopwatch::start();
        let r = algo.run_on(&ctx, &db, min_sup)?;
        let wall = sw.elapsed();
        let mut got = r.frequents.clone();
        sort_frequents(&mut got);
        let ok = got == want;
        println!(
            "{:<10} {:>12} {:>10} {:>8}",
            algo.name(),
            fmt_duration(wall),
            r.len(),
            if ok { "agree" } else { "MISMATCH" }
        );
        assert!(ok, "{} diverged from the oracle", algo.name());
        let secs = wall.as_secs_f64();
        if algo.name() == "apriori" {
            apriori_secs = secs;
        } else if secs < best.1 {
            best = (algo.name(), secs);
        }

        // Core-scaling simulation from this run's measured tasks
        // (Fig 15's method; see DESIGN.md §2.3).
        if algo.name() == "eclatV4" {
            let tasks = ctx.metrics().tasks();
            let serial = simcluster::derive_serial(&tasks, wall, ctx.cores());
            println!("  simulated cores sweep (eclatV4):");
            for r in simcluster::sweep(&tasks, &[2, 4, 6, 8, 10], serial) {
                println!(
                    "    {:>2} cores -> {}",
                    r.cores,
                    fmt_duration(r.makespan)
                );
            }
        }
    }

    println!(
        "\nheadline: best Eclat variant ({}) vs RDD-Apriori speedup = {:.1}x (paper band: 2-9x)",
        best.0,
        apriori_secs / best.1
    );
    Ok(())
}
