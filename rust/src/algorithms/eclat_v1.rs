//! EclatV1 — the first RDD-Eclat variant (paper §4.1, Algorithms 2–4).
//!
//! * **Phase-1**: `(item, tidset)` pairs via `flatMapToPair` +
//!   `groupByKey` over the partitioned database (per-partition tid
//!   offsets from prefix sums keep tids globally consistent — see
//!   [`super::common::phase1_group_by_key`]); filter by `min_sup`;
//!   collect and sort ascending by support.
//! * **Phase-2** (optional, `triMatrixMode`): accumulate the triangular
//!   matrix of candidate-2-itemset counts over the raw transactions at
//!   the default parallelism.
//! * **Phase-3**: build 1-prefix equivalence classes on the driver
//!   (pruned by the matrix), `partitionBy` the default `(n−1)`
//!   partitioner, and mine each class with the bottom-up recursion.

use std::sync::Arc;

use crate::engine::ClusterContext;
use crate::error::Result;
use crate::fim::{Database, Frequent, MinSup};

use super::common::{
    mine_equivalence_classes, phase1_group_by_key, phase2_trimatrix, transactions_rdd,
};
use super::partitioners::DefaultClassPartitioner;
use super::{Algorithm, EclatOptions, FimResult};

/// EclatV1 (see module docs).
#[derive(Debug, Clone, Default)]
pub struct EclatV1 {
    /// Shared variant options (`triMatrixMode`; `p` is unused — V1 always
    /// uses the default `(n−1)` partitioner).
    pub options: EclatOptions,
}

impl EclatV1 {
    /// With explicit options.
    pub fn with_options(options: EclatOptions) -> Self {
        EclatV1 { options }
    }
}

impl Algorithm for EclatV1 {
    fn name(&self) -> &'static str {
        "eclatV1"
    }

    fn run_on(&self, ctx: &ClusterContext, db: &Database, min_sup: MinSup) -> Result<FimResult> {
        let min_sup = min_sup.to_count(db.len());
        let mut run = FimResult::builder(self.name());

        // Phase-1 (Algorithm 2).
        let vertical = phase1_group_by_key(ctx, db, min_sup)?;
        run.phase("phase1");

        // Phase-2 (Algorithm 3) — on the *raw* transactions.
        let tri = if self.options.tri_matrix {
            let txns = transactions_rdd(ctx, db, ctx.default_parallelism());
            let max_item = db.stats().max_item;
            Some(phase2_trimatrix(ctx, &txns, max_item, &self.options.cooc)?)
        } else {
            None
        };
        run.phase("phase2");

        // Phase-3 (Algorithm 4): 1-itemsets from the vertical list, then
        // the mined k-itemsets emitted behind them.
        let mut frequents: Vec<Frequent> =
            vertical.iter().map(|(i, t)| Frequent::new(vec![*i], t.len() as u32)).collect();
        let n = vertical.len();
        let loads = mine_equivalence_classes(
            ctx,
            vertical,
            db.len(),
            min_sup,
            tri.as_ref(),
            Arc::new(DefaultClassPartitioner::for_items(n)),
            &mut frequents,
        )?;
        run.phase("phase3");
        run.partition_loads(loads);

        Ok(run.finish(frequents))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::{apriori::apriori, sort_frequents};

    fn demo_db() -> Database {
        Database::from_rows(vec![
            vec![1, 3, 4],
            vec![2, 3, 5],
            vec![1, 2, 3, 5],
            vec![2, 5],
            vec![1, 3, 5],
            vec![2, 3, 5],
        ])
    }

    #[test]
    fn matches_apriori_oracle() {
        let ctx = ClusterContext::builder().cores(2).build();
        let db = demo_db();
        for min_sup in 1..=5 {
            let mut want = apriori(&db, min_sup);
            let mut got = EclatV1::default()
                .run_on(&ctx, &db, MinSup::count(min_sup))
                .unwrap()
                .frequents;
            sort_frequents(&mut want);
            sort_frequents(&mut got);
            assert_eq!(got, want, "min_sup={min_sup}");
        }
    }

    #[test]
    fn tri_matrix_mode_off_gives_same_result() {
        let ctx = ClusterContext::builder().cores(2).build();
        let db = demo_db();
        let on = EclatV1::default().run_on(&ctx, &db, MinSup::count(2)).unwrap();
        let off = EclatV1::with_options(EclatOptions { tri_matrix: false, ..Default::default() })
            .run_on(&ctx, &db, MinSup::count(2))
            .unwrap();
        let (mut a, mut b) = (on.frequents, off.frequents);
        sort_frequents(&mut a);
        sort_frequents(&mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn phases_are_recorded() {
        let ctx = ClusterContext::builder().cores(2).build();
        let r = EclatV1::default().run_on(&ctx, &demo_db(), MinSup::count(2)).unwrap();
        let names: Vec<&str> = r.phases.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["phase1", "phase2", "phase3"]);
        let phase_total: std::time::Duration = r.phases.iter().map(|p| p.wall).sum();
        assert!(r.wall >= phase_total);
    }

    #[test]
    fn fraction_min_sup_supported() {
        let ctx = ClusterContext::builder().cores(2).build();
        let db = demo_db();
        // 0.5 of 6 = 3.
        let a = EclatV1::default().run_on(&ctx, &db, MinSup::fraction(0.5)).unwrap();
        let b = EclatV1::default().run_on(&ctx, &db, MinSup::count(3)).unwrap();
        assert_eq!(a.len(), b.len());
    }
}
