//! The bottom-up recursive search of Eclat (the paper's Algorithm 1,
//! after Zaki), rebuilt around a zero-allocation arena.
//!
//! Generic over the tidset representation: the paper's sorted-vector
//! tidsets ([`Tidset`]) or packed bitmaps ([`TidBitmap`]) — the
//! performance ablation of DESIGN.md §9. A diffset (dEclat) variant is
//! provided as the paper's "future directions" extension.
//!
//! ## The arena (§Perf iteration 5)
//!
//! The paper's headline claim is that tidset intersection is cheap and
//! iterative — so the constant factors of this inner loop dominate FIM
//! wall time (cf. the data-structure companion study, arXiv:1908.01338).
//! The search therefore never allocates per candidate in steady state:
//!
//! * entry **borrows** the class members (`&[(Item, R)]`) instead of
//!   cloning every tidset up front;
//! * each recursion depth owns one [`MineScratch`] *lane* whose candidate
//!   tidset buffers and child list are recycled across siblings
//!   (pop/truncate instead of alloc/drop);
//! * candidate intersections go through
//!   [`TidRepr::intersect_bounded_into`], which writes into a recycled
//!   buffer **and aborts mid-sweep** once the running count plus an
//!   upper bound on the remainder proves the candidate cannot reach
//!   `min_sup` (remaining-words × 64 for bitmaps, remaining-merge-input
//!   for sorted vectors);
//! * emitted itemsets come from an incrementally maintained **sorted
//!   prefix stack** — one buffer copy per emit, no per-emit sort.
//!
//! The only steady-state allocations left are the emitted [`Frequent`]
//! itemsets themselves (the output) and O(depth) arena growth on first
//! descent — measured, not asserted, by the counting allocator in
//! `benches/fim_micro.rs` (`--features alloc-count`). The pre-arena
//! implementation is kept verbatim in [`reference`] as the parity oracle
//! and the bench baseline.

use super::bitmap::TidBitmap;
use super::itemset::{Frequent, Item};
use super::tidset::{
    difference_bounded_into, intersect_bounded_into, intersect_into, Tidset,
};

/// A tidset representation usable by the bottom-up search.
pub trait TidRepr: Clone + Send + Sync + 'static {
    /// Support = number of transactions represented.
    fn support(&self) -> u32;

    /// A fresh empty value — the recyclable scratch buffer the arena
    /// hands to [`TidRepr::intersect_bounded_into`].
    fn empty() -> Self;

    /// Overwrite `out` with `self ∩ other`, reusing its allocation, and
    /// return the intersection size.
    fn intersect_counted_into(&self, other: &Self, out: &mut Self) -> u32;

    /// Like [`TidRepr::intersect_counted_into`], but abort early as soon
    /// as the intersection provably cannot reach `min_sup`. `Some(n)`
    /// guarantees `out` holds the complete intersection and `n ≥
    /// min_sup`; on `None` the contents of `out` are unspecified.
    fn intersect_bounded_into(&self, other: &Self, min_sup: u32, out: &mut Self) -> Option<u32> {
        let n = self.intersect_counted_into(other, out);
        if n >= min_sup {
            Some(n)
        } else {
            None
        }
    }

    /// Allocating convenience: `self ∩ other`.
    fn intersect_with(&self, other: &Self) -> Self {
        let mut out = Self::empty();
        self.intersect_counted_into(other, &mut out);
        out
    }

    /// Allocating convenience: fused intersection + support count.
    fn intersect_counted(&self, other: &Self) -> (Self, u32) {
        let mut out = Self::empty();
        let n = self.intersect_counted_into(other, &mut out);
        (out, n)
    }
}

impl TidRepr for Tidset {
    fn support(&self) -> u32 {
        self.len() as u32
    }
    fn empty() -> Self {
        Vec::new()
    }
    fn intersect_counted_into(&self, other: &Self, out: &mut Self) -> u32 {
        intersect_into(self, other, out);
        out.len() as u32
    }
    fn intersect_bounded_into(&self, other: &Self, min_sup: u32, out: &mut Self) -> Option<u32> {
        intersect_bounded_into(self, other, min_sup, out)
    }
}

impl TidRepr for TidBitmap {
    fn support(&self) -> u32 {
        self.count()
    }
    fn empty() -> Self {
        TidBitmap::new(0)
    }
    fn intersect_counted_into(&self, other: &Self, out: &mut Self) -> u32 {
        self.and_counted_into(other, out)
    }
    fn intersect_bounded_into(&self, other: &Self, min_sup: u32, out: &mut Self) -> Option<u32> {
        self.and_bounded_into(other, min_sup, out)
    }
}

/// One recursion depth's recyclable storage: the live candidate list plus
/// a pool of spare tidset buffers reclaimed from pruned candidates and
/// previous siblings at this depth.
#[derive(Debug)]
struct Lane<R> {
    /// `(item, tidset, support)` of the class currently mined here.
    entries: Vec<(Item, R, u32)>,
    /// Spare buffers, recycled instead of dropped.
    pool: Vec<R>,
}

impl<R> Default for Lane<R> {
    fn default() -> Self {
        Lane { entries: Vec::new(), pool: Vec::new() }
    }
}

impl<R> Lane<R> {
    /// Move every live entry's buffer back to the pool, emptying the
    /// entry list for the next sibling's candidates.
    fn recycle(&mut self) {
        self.pool.extend(self.entries.drain(..).map(|(_, r, _)| r));
    }
}

impl<R: TidRepr> Lane<R> {
    /// A buffer to intersect into: pooled if available, fresh otherwise
    /// (fresh only until the arena warms up to this class's fan-out).
    fn grab(&mut self) -> R {
        self.pool.pop().unwrap_or_else(R::empty)
    }
}

/// The reusable mining arena: depth-indexed candidate lanes plus the
/// incrementally sorted prefix stack. One `MineScratch` serves any number
/// of [`bottom_up_with`] / [`bottom_up_diffset_with`] calls; buffers grow
/// to the high-water mark of the classes mined through it and are then
/// reused, so per-candidate steady-state allocations drop to zero.
#[derive(Debug)]
pub struct MineScratch<R> {
    lanes: Vec<Lane<R>>,
    /// The current prefix itemset, kept **sorted by item id** (mining
    /// order is ascending support, so this is not insertion order).
    prefix: Vec<Item>,
}

impl<R> Default for MineScratch<R> {
    fn default() -> Self {
        MineScratch { lanes: Vec::new(), prefix: Vec::new() }
    }
}

impl<R> MineScratch<R> {
    /// Fresh, empty arena.
    pub fn new() -> MineScratch<R> {
        MineScratch::default()
    }

    /// Detach the lane for `depth` so the caller can fill it while the
    /// rest of the arena recurses deeper (returned via `put_lane`).
    fn take_lane(&mut self, depth: usize) -> Lane<R> {
        while self.lanes.len() <= depth {
            self.lanes.push(Lane::default());
        }
        std::mem::take(&mut self.lanes[depth])
    }

    /// Re-attach a lane taken with `take_lane`, keeping its buffers.
    fn put_lane(&mut self, depth: usize, lane: Lane<R>) {
        self.lanes[depth] = lane;
    }

    /// Install the entry prefix (sorted once per class, not per emit).
    fn begin_prefix(&mut self, prefix: &[Item]) {
        self.prefix.clear();
        self.prefix.extend_from_slice(prefix);
        self.prefix.sort_unstable();
        debug_assert!(self.prefix.windows(2).all(|w| w[0] < w[1]), "duplicate prefix items");
    }

    /// Descend: insert `item` at its sorted position (O(|prefix|) move,
    /// and prefixes are short).
    fn push_prefix(&mut self, item: Item) {
        debug_assert!(!self.prefix.contains(&item), "item {item} already in prefix");
        let pos = self.prefix.binary_search(&item).unwrap_or_else(|p| p);
        self.prefix.insert(pos, item);
    }

    /// Return from a descent: remove the item pushed last for this node.
    fn pop_prefix(&mut self, item: Item) {
        let pos = self.prefix.binary_search(&item).expect("pushed item present");
        self.prefix.remove(pos);
    }

    /// Emit `prefix ∪ {item}`: one merge-copy of the already-sorted
    /// prefix, no sort. The output `Vec` is the only allocation.
    fn emit(&self, item: Item, support: u32, out: &mut Vec<Frequent>) {
        let pos = self.prefix.binary_search(&item).unwrap_or_else(|p| p);
        let mut items = Vec::with_capacity(self.prefix.len() + 1);
        items.extend_from_slice(&self.prefix[..pos]);
        items.push(item);
        items.extend_from_slice(&self.prefix[pos..]);
        out.push(Frequent::new(items, support));
    }
}

/// Fill `lane.entries` with the frequent children of `tids_i` × `rest`,
/// recycling the lane's buffers; infrequent candidates abort mid-sweep
/// and return their buffer to the pool.
fn fill_children<'a, R: TidRepr>(
    lane: &mut Lane<R>,
    tids_i: &R,
    rest: impl Iterator<Item = (Item, &'a R)>,
    min_sup: u32,
) {
    lane.recycle();
    for (item_j, tids_j) in rest {
        let mut buf = lane.grab();
        match tids_i.intersect_bounded_into(tids_j, min_sup, &mut buf) {
            Some(n) => lane.entries.push((item_j, buf, n)),
            None => lane.pool.push(buf),
        }
    }
}

/// Bottom-Up(EC) — Algorithm 1. `prefix` is the class prefix itemset,
/// `members` the class atoms: `(last item, tidset(prefix ∪ item))`, each
/// already frequent. Emits every member itemset and recurses into the
/// next-level classes. Members are processed in the order given (the
/// ascending-support "total order" established in Phase-1).
///
/// Convenience entry that brings its own arena; loops mining many classes
/// should hold a [`MineScratch`] and call [`bottom_up_with`] instead.
pub fn bottom_up<R: TidRepr>(
    prefix: &[Item],
    members: &[(Item, R)],
    min_sup: u32,
    out: &mut Vec<Frequent>,
) {
    let mut scratch = MineScratch::new();
    bottom_up_with(&mut scratch, prefix, members, min_sup, out);
}

/// [`bottom_up`] through a caller-owned arena. Members are borrowed for
/// the whole search — nothing is cloned; each atom's support is counted
/// exactly once here and carried alongside the recursion's candidate
/// tidsets thereafter.
pub fn bottom_up_with<R: TidRepr>(
    scratch: &mut MineScratch<R>,
    prefix: &[Item],
    members: &[(Item, R)],
    min_sup: u32,
    out: &mut Vec<Frequent>,
) {
    scratch.begin_prefix(prefix);
    for (item, tids) in members {
        scratch.emit(*item, tids.support(), out);
    }
    if members.len() < 2 {
        return;
    }
    for i in 0..members.len() - 1 {
        let (item_i, tids_i) = &members[i];
        let mut lane = scratch.take_lane(0);
        fill_children(&mut lane, tids_i, members[i + 1..].iter().map(|(j, t)| (*j, t)), min_sup);
        if !lane.entries.is_empty() {
            scratch.push_prefix(*item_i);
            mine_level(scratch, 1, &lane.entries, min_sup, out);
            scratch.pop_prefix(*item_i);
        }
        scratch.put_lane(0, lane);
    }
}

/// The recursion below the entry level: members live in the parent's
/// detached lane, children are built in this depth's lane.
fn mine_level<R: TidRepr>(
    scratch: &mut MineScratch<R>,
    depth: usize,
    members: &[(Item, R, u32)],
    min_sup: u32,
    out: &mut Vec<Frequent>,
) {
    for (item, _, support) in members {
        scratch.emit(*item, *support, out);
    }
    if members.len() < 2 {
        return;
    }
    for i in 0..members.len() - 1 {
        let (item_i, tids_i, _) = &members[i];
        let mut lane = scratch.take_lane(depth);
        fill_children(&mut lane, tids_i, members[i + 1..].iter().map(|(j, t, _)| (*j, t)), min_sup);
        if !lane.entries.is_empty() {
            scratch.push_prefix(*item_i);
            mine_level(scratch, depth + 1, &lane.entries, min_sup, out);
            scratch.pop_prefix(*item_i);
        }
        scratch.put_lane(depth, lane);
    }
}

/// dEclat: the diffset-based bottom-up search (Zaki's follow-up — the
/// paper's related work cites it via Peclat's mixsets; here it is the
/// ablation extension). Entry takes *tidsets*; the first join converts to
/// diffsets (`d(ab) = t(a) − t(b)`, `σ(ab) = σ(a) − |d(ab)|`), deeper
/// levels stay in diffset space (`d(Pab) = d(Pb) − d(Pa)`).
///
/// Convenience entry that brings its own arena; see
/// [`bottom_up_diffset_with`].
pub fn bottom_up_diffset(
    prefix: &[Item],
    members: &[(Item, Tidset)],
    min_sup: u32,
    out: &mut Vec<Frequent>,
) {
    let mut scratch = MineScratch::new();
    bottom_up_diffset_with(&mut scratch, prefix, members, min_sup, out);
}

/// [`bottom_up_diffset`] through a caller-owned arena. Diffsets get the
/// same treatment as tidsets: borrowed entry members, recycled per-depth
/// lanes, and bounded differences — a difference aborts once it exceeds
/// `σ(parent) − min_sup` elements, the point at which the candidate's
/// support `σ(parent) − |diffset|` can no longer reach `min_sup`.
pub fn bottom_up_diffset_with(
    scratch: &mut MineScratch<Tidset>,
    prefix: &[Item],
    members: &[(Item, Tidset)],
    min_sup: u32,
    out: &mut Vec<Frequent>,
) {
    scratch.begin_prefix(prefix);
    for (item, tids) in members {
        scratch.emit(*item, tids.len() as u32, out);
    }
    if members.len() < 2 {
        return;
    }
    for i in 0..members.len() - 1 {
        let (item_i, tids_i) = &members[i];
        let sup_i = tids_i.len() as u32;
        let budget = sup_i.saturating_sub(min_sup) as usize;
        let mut lane = scratch.take_lane(0);
        lane.recycle();
        for (item_j, tids_j) in &members[i + 1..] {
            let mut buf = lane.grab();
            // d(ab) = t(a) − t(b); σ(ab) = σ(a) − |d(ab)|.
            match difference_bounded_into(tids_i, tids_j, budget, &mut buf) {
                Some(d) if sup_i - d >= min_sup => lane.entries.push((*item_j, buf, sup_i - d)),
                _ => lane.pool.push(buf),
            }
        }
        if !lane.entries.is_empty() {
            scratch.push_prefix(*item_i);
            diffset_level(scratch, 1, &lane.entries, min_sup, out);
            scratch.pop_prefix(*item_i);
        }
        scratch.put_lane(0, lane);
    }
}

fn diffset_level(
    scratch: &mut MineScratch<Tidset>,
    depth: usize,
    members: &[(Item, Tidset, u32)],
    min_sup: u32,
    out: &mut Vec<Frequent>,
) {
    for (item, _, support) in members {
        scratch.emit(*item, *support, out);
    }
    if members.len() < 2 {
        return;
    }
    for i in 0..members.len() - 1 {
        let (item_i, diff_i, sup_i) = &members[i];
        let budget = sup_i.saturating_sub(min_sup) as usize;
        let mut lane = scratch.take_lane(depth);
        lane.recycle();
        for (item_j, diff_j, _) in &members[i + 1..] {
            let mut buf = lane.grab();
            // d(Pab) = d(Pb) − d(Pa); σ(Pab) = σ(Pa) − |d(Pab)|.
            match difference_bounded_into(diff_j, diff_i, budget, &mut buf) {
                Some(d) if sup_i - d >= min_sup => lane.entries.push((*item_j, buf, sup_i - d)),
                _ => lane.pool.push(buf),
            }
        }
        if !lane.entries.is_empty() {
            scratch.push_prefix(*item_i);
            diffset_level(scratch, depth + 1, &lane.entries, min_sup, out);
            scratch.pop_prefix(*item_i);
        }
        scratch.put_lane(depth, lane);
    }
}

/// The pre-arena implementation, kept verbatim: clones every member on
/// entry, heap-allocates each candidate tidset and child list, and sorts
/// a fresh prefix `Vec` per emit. It exists as (a) the parity oracle the
/// property tests pit the arena miner against and (b) the baseline side
/// of the `bottomup/*_cloning` benches in `fim_micro` — do not "optimize"
/// it.
pub mod reference {
    use super::super::tidset::difference;
    use super::{Frequent, Item, TidRepr, Tidset};

    fn emit(prefix: &[Item], item: Item, support: u32, out: &mut Vec<Frequent>) {
        let mut items = Vec::with_capacity(prefix.len() + 1);
        items.extend_from_slice(prefix);
        items.push(item);
        items.sort_unstable();
        out.push(Frequent::new(items, support));
    }

    /// Cloning Bottom-Up(EC): the shape every RDD variant funneled into
    /// before the arena refactor.
    pub fn bottom_up<R: TidRepr>(
        prefix: &[Item],
        members: &[(Item, R)],
        min_sup: u32,
        out: &mut Vec<Frequent>,
    ) {
        let counted: Vec<(Item, R, u32)> =
            members.iter().map(|(i, t)| (*i, t.clone(), t.support())).collect();
        bottom_up_counted(prefix, &counted, min_sup, out);
    }

    fn bottom_up_counted<R: TidRepr>(
        prefix: &[Item],
        members: &[(Item, R, u32)],
        min_sup: u32,
        out: &mut Vec<Frequent>,
    ) {
        for (item, _, support) in members {
            emit(prefix, *item, *support, out);
        }
        if members.len() < 2 {
            return;
        }
        let mut child_prefix = Vec::with_capacity(prefix.len() + 1);
        for i in 0..members.len() - 1 {
            let (item_i, tids_i, _) = &members[i];
            let mut next: Vec<(Item, R, u32)> = Vec::new();
            for (item_j, tids_j, _) in &members[i + 1..] {
                let (tids_ij, count) = tids_i.intersect_counted(tids_j);
                if count >= min_sup {
                    next.push((*item_j, tids_ij, count));
                }
            }
            if !next.is_empty() {
                child_prefix.clear();
                child_prefix.extend_from_slice(prefix);
                child_prefix.push(*item_i);
                bottom_up_counted(&child_prefix, &next, min_sup, out);
            }
        }
    }

    /// Cloning dEclat.
    pub fn bottom_up_diffset(
        prefix: &[Item],
        members: &[(Item, Tidset)],
        min_sup: u32,
        out: &mut Vec<Frequent>,
    ) {
        for (item, tids) in members {
            emit(prefix, *item, tids.len() as u32, out);
        }
        if members.len() < 2 {
            return;
        }
        for i in 0..members.len() - 1 {
            let (item_i, tids_i) = &members[i];
            let sup_i = tids_i.len() as u32;
            let mut next: Vec<(Item, Tidset, u32)> = Vec::new();
            for (item_j, tids_j) in &members[i + 1..] {
                let diff = difference(tids_i, tids_j);
                let support = sup_i - diff.len() as u32;
                if support >= min_sup {
                    next.push((*item_j, diff, support));
                }
            }
            if !next.is_empty() {
                let mut child_prefix = prefix.to_vec();
                child_prefix.push(*item_i);
                diffset_recurse(&child_prefix, &next, min_sup, out);
            }
        }
    }

    fn diffset_recurse(
        prefix: &[Item],
        members: &[(Item, Tidset, u32)],
        min_sup: u32,
        out: &mut Vec<Frequent>,
    ) {
        for (item, _, support) in members {
            emit(prefix, *item, *support, out);
        }
        if members.len() < 2 {
            return;
        }
        for i in 0..members.len() - 1 {
            let (item_i, diff_i, sup_i) = &members[i];
            let mut next: Vec<(Item, Tidset, u32)> = Vec::new();
            for (item_j, diff_j, _) in &members[i + 1..] {
                let diff = difference(diff_j, diff_i);
                let support = sup_i - diff.len() as u32;
                if support >= min_sup {
                    next.push((*item_j, diff, support));
                }
            }
            if !next.is_empty() {
                let mut child_prefix = prefix.to_vec();
                child_prefix.push(*item_i);
                diffset_recurse(&child_prefix, &next, min_sup, out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::itemset::sort_frequents;

    /// Zaki's running example: items 1..5 over 6 transactions.
    fn example_members() -> Vec<(Item, Tidset)> {
        // t(1)={0,2,3}, t(2)={1,2,3,4,5}, t(3)={0,1,2,3,4,5}
        vec![
            (1, vec![0, 2, 3]),
            (2, vec![1, 2, 3, 4, 5]),
            (3, vec![0, 1, 2, 3, 4, 5]),
        ]
    }

    #[test]
    fn bottom_up_enumerates_class() {
        let mut out = Vec::new();
        bottom_up::<Tidset>(&[], &example_members(), 2, &mut out);
        sort_frequents(&mut out);
        let got: Vec<(Vec<Item>, u32)> =
            out.into_iter().map(|f| (f.items, f.support)).collect();
        assert_eq!(
            got,
            vec![
                (vec![1], 3),
                (vec![2], 5),
                (vec![3], 6),
                (vec![1, 2], 2),
                (vec![1, 3], 3),
                (vec![2, 3], 5),
                (vec![1, 2, 3], 2),
            ]
        );
    }

    #[test]
    fn min_sup_prunes_recursion() {
        let mut out = Vec::new();
        bottom_up::<Tidset>(&[], &example_members(), 3, &mut out);
        assert!(out.iter().all(|f| f.support >= 3));
        assert!(!out.iter().any(|f| f.items == vec![1, 2]));
        assert!(!out.iter().any(|f| f.items == vec![1, 2, 3]));
        assert!(out.iter().any(|f| f.items == vec![1, 3] && f.support == 3));
    }

    #[test]
    fn bitmap_repr_agrees_with_tidset_repr() {
        let members = example_members();
        let bitmap_members: Vec<(Item, TidBitmap)> = members
            .iter()
            .map(|(i, t)| (*i, TidBitmap::from_tids(6, t.iter().copied())))
            .collect();
        for min_sup in 1..=6 {
            let mut a = Vec::new();
            bottom_up::<Tidset>(&[], &members, min_sup, &mut a);
            let mut b = Vec::new();
            bottom_up::<TidBitmap>(&[], &bitmap_members, min_sup, &mut b);
            sort_frequents(&mut a);
            sort_frequents(&mut b);
            assert_eq!(a, b, "min_sup={min_sup}");
        }
    }

    #[test]
    fn diffset_variant_agrees() {
        let members = example_members();
        for min_sup in 1..=6 {
            let mut a = Vec::new();
            bottom_up::<Tidset>(&[], &members, min_sup, &mut a);
            let mut b = Vec::new();
            bottom_up_diffset(&[], &members, min_sup, &mut b);
            sort_frequents(&mut a);
            sort_frequents(&mut b);
            assert_eq!(a, b, "min_sup={min_sup}");
        }
    }

    #[test]
    fn emit_sorts_itemsets_with_unsorted_mining_order() {
        // Mining order by ascending support can put a larger item id first;
        // the sorted prefix stack must still emit canonical itemsets.
        let members: Vec<(Item, Tidset)> = vec![(9, vec![0, 1]), (2, vec![0, 1, 2])];
        let mut out = Vec::new();
        bottom_up::<Tidset>(&[], &members, 2, &mut out);
        assert!(out.iter().any(|f| f.items == vec![2, 9] && f.support == 2));
    }

    #[test]
    fn unsorted_entry_prefix_is_canonicalized() {
        // Entry prefixes arrive in mining order too; begin_prefix sorts
        // once so every emit stays a cheap merge.
        let members: Vec<(Item, Tidset)> = vec![(3, vec![0, 1]), (1, vec![0, 1])];
        let mut out = Vec::new();
        bottom_up::<Tidset>(&[7, 5], &members, 2, &mut out);
        let mut got: Vec<Vec<Item>> = out.into_iter().map(|f| f.items).collect();
        got.sort();
        assert_eq!(got, vec![vec![1, 3, 5, 7], vec![1, 5, 7], vec![3, 5, 7]]);
    }

    #[test]
    fn empty_and_singleton_members() {
        let mut out = Vec::new();
        bottom_up::<Tidset>(&[], &[], 1, &mut out);
        assert!(out.is_empty());
        bottom_up::<Tidset>(&[5], &[(7, vec![0])], 1, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].items, vec![5, 7]);
    }

    #[test]
    fn scratch_miner_matches_reference_on_random_databases() {
        // The pre-refactor implementation (kept verbatim in `reference`)
        // is the oracle: across random QUEST and clickstream databases,
        // a min_sup sweep, and all three representations (sorted-vector
        // tidsets, packed bitmaps, diffsets) — plus the auto-remap path —
        // the arena miner must produce identical itemsets. All scratches
        // are shared across every class/db/min_sup so recycled buffers
        // get maximal opportunity to leak stale state.
        use crate::data::clickstream::{self, ClickParams};
        use crate::data::quest::{self, QuestParams};
        use crate::fim::eqclass::{construct_classes, to_bitmap_class, AutoScratch};
        use crate::fim::tidset::VerticalDb;

        let click = ClickParams {
            sessions: 250,
            items: 60,
            avg_len: 5.0,
            skew: 1.1,
            locality: 0.5,
            radius: 6,
            drift: 0.0,
        };
        let dbs = vec![
            ("quest_dense", quest::generate(&QuestParams::tid(10.0, 4.0, 200, 25), 7)),
            ("quest_sparse", quest::generate(&QuestParams::tid(6.0, 3.0, 300, 60), 11)),
            ("clickstream", clickstream::generate(&click, 3)),
        ];
        let mut tid_scratch = MineScratch::<Tidset>::new();
        let mut bm_scratch = MineScratch::<TidBitmap>::new();
        let mut diff_scratch = MineScratch::<Tidset>::new();
        let mut auto_scratch = AutoScratch::new();
        for (tag, db) in &dbs {
            for min_sup in [2u32, 3, 5, 8, 13] {
                let vdb = VerticalDb::build(db, min_sup);
                // Diffset driver over the whole level-1 class.
                let mut want = Vec::new();
                reference::bottom_up_diffset(&[], &vdb.items, min_sup, &mut want);
                let mut got = Vec::new();
                bottom_up_diffset_with(&mut diff_scratch, &[], &vdb.items, min_sup, &mut got);
                sort_frequents(&mut want);
                sort_frequents(&mut got);
                assert_eq!(got, want, "{tag} diffset min_sup={min_sup}");
                // Per-class: tidset, bitmap, and auto-remap arenas.
                for class in construct_classes(&vdb, min_sup, None) {
                    let mut want = Vec::new();
                    reference::bottom_up::<Tidset>(
                        &[class.prefix],
                        &class.members,
                        min_sup,
                        &mut want,
                    );
                    sort_frequents(&mut want);

                    let mut got = class.mine_with(&mut tid_scratch, min_sup);
                    sort_frequents(&mut got);
                    assert_eq!(got, want, "{tag} tidset prefix={} min_sup={min_sup}", class.prefix);

                    let bm_class = to_bitmap_class(&class, db.len());
                    let mut got = bm_class.mine_with(&mut bm_scratch, min_sup);
                    sort_frequents(&mut got);
                    assert_eq!(got, want, "{tag} bitmap prefix={} min_sup={min_sup}", class.prefix);

                    let mut got = class.mine_auto_with(&mut auto_scratch, min_sup, db.len());
                    sort_frequents(&mut got);
                    assert_eq!(got, want, "{tag} auto prefix={} min_sup={min_sup}", class.prefix);
                }
            }
        }
    }

    #[test]
    fn scratch_reuse_across_classes_is_clean() {
        // One arena mines many different classes back to back; recycled
        // buffers must never leak stale tids between classes.
        let mut scratch = MineScratch::new();
        let classes: Vec<Vec<(Item, Tidset)>> = vec![
            example_members(),
            vec![(4, vec![0, 1, 2, 3]), (6, vec![1, 3]), (5, vec![0, 1, 3])],
            vec![(8, vec![2])],
            vec![],
            example_members(),
        ];
        for (k, members) in classes.iter().enumerate() {
            for min_sup in 1..=4 {
                let mut want = Vec::new();
                reference::bottom_up::<Tidset>(&[], members, min_sup, &mut want);
                let mut got = Vec::new();
                bottom_up_with(&mut scratch, &[], members, min_sup, &mut got);
                sort_frequents(&mut want);
                sort_frequents(&mut got);
                assert_eq!(got, want, "class {k} min_sup={min_sup}");
            }
        }
    }
}
