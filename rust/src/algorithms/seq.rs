//! Sequential single-machine miners — correctness oracles and the
//! "one core" reference points for the scaling studies.

use crate::engine::ClusterContext;
use crate::error::Result;
use crate::fim::{
    apriori::apriori, bottom_up_diffset_with, construct_classes, fpgrowth::fp_growth, AutoScratch,
    Database, Frequent, MineScratch, MinSup, VerticalDb,
};
use crate::util::Stopwatch;

use super::{Algorithm, FimResult};

fn wrap(name: &str, frequents: Vec<Frequent>, sw: Stopwatch) -> FimResult {
    FimResult {
        algorithm: name.into(),
        frequents,
        wall: sw.elapsed(),
        phases: Vec::new(),
        partition_loads: Vec::new(),
        filtered_reduction: None,
    }
}

/// Sequential Eclat: vertical DB + equivalence classes + bottom-up, no
/// engine involvement.
#[derive(Debug, Clone, Default)]
pub struct SeqEclat;

impl SeqEclat {
    /// Run directly on a database (no context needed). Uses the
    /// triangular-matrix prune (Zaki's recommendation, §Perf iteration 4)
    /// to avoid intersecting infrequent item pairs during class
    /// construction, and one [`AutoScratch`] arena shared across every
    /// class so steady-state mining allocates nothing per candidate
    /// (§Perf iteration 5).
    pub fn mine(db: &Database, min_sup: MinSup) -> Vec<Frequent> {
        let min_sup = min_sup.to_count(db.len());
        let vdb = VerticalDb::build(db, min_sup);
        let mut tri = crate::fim::TriMatrix::new(db.stats().max_item);
        for t in db.transactions() {
            tri.update_transaction(t);
        }
        let mut out: Vec<Frequent> = vdb
            .items
            .iter()
            .map(|(i, t)| Frequent::new(vec![*i], t.len() as u32))
            .collect();
        let mut scratch = AutoScratch::new();
        for class in construct_classes(&vdb, min_sup, Some(&tri)) {
            out.extend(class.mine_auto_with(&mut scratch, min_sup, db.len()));
        }
        out
    }
}

impl Algorithm for SeqEclat {
    fn name(&self) -> &'static str {
        "seq-eclat"
    }

    fn run_on(&self, _ctx: &ClusterContext, db: &Database, min_sup: MinSup) -> Result<FimResult> {
        let sw = Stopwatch::start();
        Ok(wrap(self.name(), Self::mine(db, min_sup), sw))
    }
}

/// Sequential dEclat (diffset) — extension ablation.
#[derive(Debug, Clone, Default)]
pub struct SeqEclatDiffset;

impl Algorithm for SeqEclatDiffset {
    fn name(&self) -> &'static str {
        "seq-declat"
    }

    fn run_on(&self, _ctx: &ClusterContext, db: &Database, min_sup: MinSup) -> Result<FimResult> {
        let sw = Stopwatch::start();
        let min_sup = min_sup.to_count(db.len());
        let vdb = VerticalDb::build(db, min_sup);
        let mut out: Vec<Frequent> = vdb
            .items
            .iter()
            .map(|(i, t)| Frequent::new(vec![*i], t.len() as u32))
            .collect();
        // One top-level class over all frequent items: the diffset driver
        // handles the level-1 → level-2 conversion internally, through
        // the same reusable mining arena as the tidset path.
        let mut scratch = MineScratch::new();
        bottom_up_diffset_with(&mut scratch, &[], &vdb.items, min_sup, &mut out);
        // bottom_up_diffset re-emits the 1-itemsets; drop the duplicates.
        let mut seen = std::collections::HashSet::new();
        out.retain(|f| seen.insert(f.items.clone()));
        Ok(wrap(self.name(), out, sw))
    }
}

/// Sequential Apriori (Agrawal–Srikant).
#[derive(Debug, Clone, Default)]
pub struct SeqApriori;

impl Algorithm for SeqApriori {
    fn name(&self) -> &'static str {
        "seq-apriori"
    }

    fn run_on(&self, _ctx: &ClusterContext, db: &Database, min_sup: MinSup) -> Result<FimResult> {
        let sw = Stopwatch::start();
        let min_sup = min_sup.to_count(db.len());
        Ok(wrap(self.name(), apriori(db, min_sup), sw))
    }
}

/// Sequential FP-Growth (Han et al.).
#[derive(Debug, Clone, Default)]
pub struct SeqFpGrowth;

impl Algorithm for SeqFpGrowth {
    fn name(&self) -> &'static str {
        "seq-fpgrowth"
    }

    fn run_on(&self, _ctx: &ClusterContext, db: &Database, min_sup: MinSup) -> Result<FimResult> {
        let sw = Stopwatch::start();
        let min_sup = min_sup.to_count(db.len());
        Ok(wrap(self.name(), fp_growth(db, min_sup), sw))
    }
}

/// Look up an algorithm by CLI name.
pub fn by_name(name: &str) -> Option<Box<dyn Algorithm>> {
    use super::{EclatV1, EclatV2, EclatV3, EclatV4, EclatV5, RddApriori};
    match name.to_ascii_lowercase().as_str() {
        "eclatv1" | "v1" => Some(Box::new(EclatV1::default())),
        "eclatv2" | "v2" => Some(Box::new(EclatV2::default())),
        "eclatv3" | "v3" => Some(Box::new(EclatV3::default())),
        "eclatv4" | "v4" => Some(Box::new(EclatV4::default())),
        "eclatv5" | "v5" => Some(Box::new(EclatV5::default())),
        "apriori" | "rdd-apriori" | "yafim" => Some(Box::new(RddApriori)),
        "seq-eclat" => Some(Box::new(SeqEclat)),
        "seq-declat" => Some(Box::new(SeqEclatDiffset)),
        "seq-apriori" => Some(Box::new(SeqApriori)),
        "seq-fpgrowth" | "fpgrowth" => Some(Box::new(SeqFpGrowth)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::sort_frequents;

    fn demo_db() -> Database {
        Database::from_rows(vec![
            vec![1, 3, 4],
            vec![2, 3, 5],
            vec![1, 2, 3, 5],
            vec![2, 5],
            vec![1, 3, 5],
            vec![2, 3, 5],
        ])
    }

    #[test]
    fn all_sequential_miners_agree() {
        let ctx = ClusterContext::builder().cores(1).build();
        let db = demo_db();
        let algos: Vec<Box<dyn Algorithm>> = vec![
            Box::new(SeqEclat),
            Box::new(SeqEclatDiffset),
            Box::new(SeqApriori),
            Box::new(SeqFpGrowth),
        ];
        for min_sup in 1..=5 {
            let mut reference: Option<Vec<Frequent>> = None;
            for a in &algos {
                let mut got = a.run_on(&ctx, &db, MinSup::count(min_sup)).unwrap().frequents;
                sort_frequents(&mut got);
                match &reference {
                    None => reference = Some(got),
                    Some(r) => assert_eq!(&got, r, "{} min_sup={min_sup}", a.name()),
                }
            }
        }
    }

    #[test]
    fn by_name_resolves_everything() {
        for n in [
            "eclatV1", "v2", "EclatV3", "v4", "eclatv5", "apriori", "yafim", "seq-eclat",
            "seq-declat", "seq-apriori", "fpgrowth",
        ] {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("nope").is_none());
    }
}
