//! Concurrency coverage for the async streaming service layer:
//! non-blocking ingest, skip-to-latest coalescing under backpressure,
//! and torn/stale-free snapshot serving — all checked against the
//! `SeqEclat` oracle on the materialized window.

use std::time::{Duration, Instant};

use rdd_eclat::algorithms::SeqEclat;
use rdd_eclat::data::clickstream::{generate_range, ClickParams};
use rdd_eclat::engine::ClusterContext;
use rdd_eclat::fim::{sort_frequents, Database, Frequent, MinSup};
use rdd_eclat::stream::{
    Ingest, IngestConfig, StreamConfig, StreamService, StreamingMiner, WindowSpec,
};

fn ctx() -> ClusterContext {
    ClusterContext::builder().cores(2).build()
}

fn oracle(db: &Database, min_sup: MinSup) -> Vec<Frequent> {
    let mut v = SeqEclat::mine(db, min_sup);
    sort_frequents(&mut v);
    v
}

fn click_batches(n: usize, size: usize, seed: u64) -> Vec<Vec<Vec<u32>>> {
    let params = ClickParams { sessions: n * size, ..ClickParams::drift() };
    (0..n).map(|b| generate_range(&params, seed, b * size, size)).collect()
}

/// Acceptance: a slow emission must not stall a fast producer — the
/// async `push_batch` returns without blocking on mining.
#[test]
fn slow_emissions_do_not_stall_the_producer() {
    const BATCHES: usize = 20;
    const THROTTLE: Duration = Duration::from_millis(25);
    let min_sup = MinSup::count(2);
    let miner = StreamingMiner::new(ctx(), StreamConfig::new(WindowSpec::sliding(4, 1), min_sup));
    let service = StreamService::spawn(miner, IngestConfig::new(2).throttle(THROTTLE));
    let batches = click_batches(BATCHES, 40, 11);

    let push_wall = {
        let start = Instant::now();
        for b in batches {
            service.push_batch(b).unwrap();
        }
        start.elapsed()
    };
    // Mining is throttled to >= 25ms per emission; the producer pushed
    // 20 batches. Had push_batch blocked on mining, the loop would take
    // >= 20 * 25ms = 500ms. Queue appends take microseconds; allow a
    // huge margin for CI noise and still prove the decoupling.
    assert!(
        push_wall < Duration::from_millis(250),
        "producer stalled on mining: pushed {BATCHES} batches in {push_wall:?}"
    );

    // The final snapshot is still window-exact.
    let final_snap = service.drain().unwrap().expect("slide 1 emitted");
    let stats = service.stats();
    let miner = service.shutdown().unwrap();
    assert_eq!(final_snap.batch_id, BATCHES as u64 - 1, "latest state served");
    assert_eq!(final_snap.frequents, oracle(&miner.materialize_window(), min_sup));
    assert_eq!(stats.batches, BATCHES as u64);
    // Every slide-1 emission point was either mined or skipped (catch-up
    // emissions can add to the mined side).
    assert!(
        stats.emissions + stats.skipped >= BATCHES as u64,
        "emission accounting lost points: {stats:?}"
    );
}

/// Backpressure: with a tiny queue cap and throttled mining, emission
/// points must coalesce (some skipped) while bookkeeping stays exact —
/// the drained snapshot equals the oracle on the materialized window.
#[test]
fn backpressure_coalesces_emissions_but_stays_window_exact() {
    const BATCHES: usize = 30;
    let min_sup = MinSup::count(3);
    let miner = StreamingMiner::new(ctx(), StreamConfig::new(WindowSpec::sliding(6, 1), min_sup));
    let service =
        StreamService::spawn(miner, IngestConfig::new(1).throttle(Duration::from_millis(10)));
    let mut saw_backpressure = false;
    for b in click_batches(BATCHES, 50, 23) {
        if let Ingest::Backpressure { pending } = service.push_batch(b).unwrap() {
            assert!(pending > 1);
            saw_backpressure = true;
        }
    }
    assert!(saw_backpressure, "a 1-deep queue against 10ms emissions must back up");
    let final_snap = service.drain().unwrap().expect("emitted");
    let stats = service.stats();
    assert!(stats.skipped > 0, "backpressure must skip emission points, stats {stats:?}");
    assert!(
        stats.emissions < BATCHES as u64,
        "coalescing must publish fewer snapshots than batches, stats {stats:?}"
    );
    let miner = service.shutdown().unwrap();
    assert_eq!(final_snap.batch_id, BATCHES as u64 - 1);
    assert_eq!(
        final_snap.frequents,
        oracle(&miner.materialize_window(), min_sup),
        "skip-to-latest coalescing broke window-exactness"
    );
}

/// Acceptance + satellite: concurrent readers holding a
/// `SnapshotHandle` observe a monotonically advancing, never-torn
/// snapshot sequence while the miner publishes, and end on the final
/// state (no stale-forever).
#[test]
fn readers_observe_monotone_consistent_snapshots_while_mining() {
    const BATCHES: usize = 25;
    const READERS: usize = 3;
    let min_sup = MinSup::count(2);
    let spec = WindowSpec::sliding(5, 1);
    let miner = StreamingMiner::new(ctx(), StreamConfig::new(spec, min_sup));
    let service =
        StreamService::spawn(miner, IngestConfig::new(4).throttle(Duration::from_millis(2)));
    let readers: Vec<_> = (0..READERS)
        .map(|_| {
            let handle = service.handle();
            // Each reader spins on latest() until it observes the final
            // batch — a reader stuck on a stale snapshot hangs the test
            // (bounded by the harness timeout) instead of passing.
            std::thread::spawn(move || {
                let mut last = 0u64;
                let mut observations = 0u64;
                loop {
                    let Some(s) = handle.latest() else { continue };
                    assert!(
                        s.batch_id >= last,
                        "snapshot sequence regressed: {last} -> {}",
                        s.batch_id
                    );
                    last = s.batch_id;
                    observations += 1;
                    // Torn-snapshot checks: the serving indices must
                    // agree with the snapshot they were built from.
                    assert!(s.window_batches <= 5);
                    for f in s.frequents.iter().take(3) {
                        assert_eq!(s.frequent(&f.items), Some(f.support));
                    }
                    if let Some(r) = s.rules.first() {
                        let looked_up = s.rules_for(&r.antecedent);
                        assert!(!looked_up.is_empty());
                        assert!(looked_up.iter().all(|x| x.antecedent == r.antecedent));
                    }
                    if last == BATCHES as u64 - 1 {
                        return observations;
                    }
                }
            })
        })
        .collect();

    for b in click_batches(BATCHES, 40, 5) {
        service.push_batch(b).unwrap();
    }
    let final_snap = service.drain().unwrap().expect("emitted");
    assert_eq!(final_snap.batch_id, BATCHES as u64 - 1);
    for r in readers {
        let observations = r.join().expect("reader panicked == invariant violated");
        assert!(observations > 0, "reader never saw a snapshot");
    }
    let miner = service.shutdown().unwrap();
    assert_eq!(final_snap.frequents, oracle(&miner.materialize_window(), min_sup));
}

/// The sync and async paths must agree batch for batch when the async
/// service is never pressured (cap larger than the stream).
#[test]
fn unpressured_async_service_matches_sync_emission_sequence() {
    let min_sup = MinSup::fraction(0.05);
    let spec = WindowSpec::sliding(3, 2);
    let mut sync = StreamingMiner::new(ctx(), StreamConfig::new(spec, min_sup));
    let service = StreamService::spawn(
        StreamingMiner::new(ctx(), StreamConfig::new(spec, min_sup)),
        IngestConfig::new(64),
    );
    let handle = service.handle();
    let mut sync_last = None;
    for b in click_batches(14, 30, 77) {
        sync_last = sync.push_batch(b.clone()).unwrap().or(sync_last);
        service.push_batch(b).unwrap();
    }
    service.drain().unwrap();
    let want = sync_last.expect("slide 2 over 14 batches emits");
    let got = handle
        .wait_for_batch_timeout(want.batch_id, Duration::from_secs(30))
        .expect("async published the same final emission");
    assert_eq!(got.batch_id, want.batch_id);
    assert_eq!(got.frequents, want.frequents);
    assert_eq!(got.rules.len(), want.rules.len());
    assert_eq!(got.min_sup_count, want.min_sup_count);
    let miner = service.shutdown().unwrap();
    assert_eq!(miner.window_txns(), sync.window_txns());
}

/// Satellite: a blocked `wait_for_batch` waiter must not hang forever
/// when the service (and with it the publisher) goes away — death wakes
/// all waiters, which return `None`.
#[test]
fn service_death_unblocks_wait_for_batch() {
    let min_sup = MinSup::count(2);
    let miner = StreamingMiner::new(ctx(), StreamConfig::new(WindowSpec::sliding(3, 1), min_sup));
    let service = StreamService::spawn(miner, IngestConfig::new(8));
    let handle = service.handle();

    // A waiter blocked on a batch id the stream will never reach.
    let blocked = {
        let handle = service.handle();
        std::thread::spawn(move || handle.wait_for_batch(1_000_000))
    };
    // And one with a timeout far beyond the test budget — death, not
    // the timeout, must be what wakes it.
    let timed = {
        let handle = service.handle();
        std::thread::spawn(move || handle.wait_for_batch_timeout(1_000_000, Duration::from_secs(3600)))
    };

    for b in click_batches(4, 20, 41) {
        service.push_batch(b).unwrap();
    }
    let last = service.drain().unwrap().expect("slide 1 emitted");
    let start = Instant::now();
    service.shutdown().unwrap(); // mining loop exits -> publisher drops

    assert!(blocked.join().unwrap().is_none(), "dead publisher must yield None");
    assert!(timed.join().unwrap().is_none(), "timed waiter must observe death, not sleep");
    assert!(
        start.elapsed() < Duration::from_secs(10),
        "waiters should wake promptly on publisher death"
    );
    // Already-satisfied waits still answer from the retained snapshot.
    let got = handle.wait_for_batch(last.batch_id).expect("retained snapshot");
    assert_eq!(got.batch_id, last.batch_id);
    assert!(!handle.publisher_alive());
}

/// Tentpole end-to-end: a 4-shard service and a 1-shard service fed the
/// same unpressured stream publish identical final snapshots, both
/// oracle-exact, and the sharded service surfaces per-shard stats.
#[test]
fn sharded_service_matches_single_shard_service() {
    let min_sup = MinSup::count(3);
    let spec = WindowSpec::sliding(5, 1);
    let run = |shards: usize| {
        let miner = StreamingMiner::new(
            ClusterContext::builder().cores(3).build(),
            StreamConfig::new(spec, min_sup).shards(shards),
        );
        let service = StreamService::spawn(miner, IngestConfig::new(64));
        for b in click_batches(12, 40, 59) {
            service.push_batch(b).unwrap();
        }
        let snap = service.drain().unwrap().expect("slide 1 emitted");
        let stats = service.stats();
        let miner = service.shutdown().unwrap();
        (snap, stats, miner)
    };
    let (snap4, stats4, miner4) = run(4);
    let (snap1, stats1, miner1) = run(1);

    assert_eq!(snap4.batch_id, snap1.batch_id);
    assert_eq!(snap4.frequents, snap1.frequents, "sharded service diverged from 1-shard");
    assert_eq!(snap4.rules, snap1.rules);
    assert_eq!(snap4.frequents, oracle(&miner4.materialize_window(), min_sup));
    assert_eq!(miner4.window_txns(), miner1.window_txns());

    assert_eq!(stats4.shards.len(), 4, "per-shard stats surfaced: {stats4:?}");
    assert_eq!(stats1.shards.len(), 1);
    let postings4: u64 = stats4.shards.iter().map(|s| s.postings).sum();
    let postings1: u64 = stats1.shards.iter().map(|s| s.postings).sum();
    assert!(postings4 > 0);
    assert_eq!(postings4, postings1, "total postings are shard-count invariant");
    assert!(
        stats4.shards.iter().map(|s| s.mined_itemsets).sum::<u64>() > 0,
        "sharded mining accounted itemsets: {stats4:?}"
    );
}
