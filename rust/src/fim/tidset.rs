//! Sorted-vector tidsets and the vertical database.
//!
//! Eclat's vertical format (§2.1): `item → tidset(item)`. Tidsets here are
//! sorted `Vec<Tid>`; support is length; candidate support is intersection
//! size. The engine-level RDD-Eclat variants move these around as RDD
//! values, so they stay plain clonable vectors. The packed-bitmap
//! representation in [`super::bitmap`] is the optimized alternative used
//! by the bottom-up search once classes are local to a task.

use std::collections::HashMap;

use super::itemset::{Item, Tid};
use super::transaction::Database;

/// A sorted, de-duplicated list of transaction ids.
pub type Tidset = Vec<Tid>;

/// Intersect two sorted tidsets (linear merge; switches to galloping when
/// sizes are very skewed).
pub fn intersect(a: &[Tid], b: &[Tid]) -> Tidset {
    let mut out = Vec::new();
    intersect_into(a, b, &mut out);
    out
}

/// Intersect two sorted tidsets **into** a caller-owned buffer, reusing
/// its allocation (the arena-mining hot path: `out` is a recycled scratch
/// lane, so steady-state intersections allocate nothing). Switches to
/// galloping when sizes are very skewed, like [`intersect`].
pub fn intersect_into(a: &[Tid], b: &[Tid], out: &mut Tidset) {
    out.clear();
    // Galloping pays when one side is ≥ ~8x smaller.
    if a.len() * 8 < b.len() {
        return gallop_intersect_into(a, b, out);
    }
    if b.len() * 8 < a.len() {
        return gallop_intersect_into(b, a, out);
    }
    out.reserve(a.len().min(b.len()));
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
}

/// Bounded intersection into a reused buffer: abort as soon as the
/// running count plus the remaining input can no longer reach `min_sup`
/// (Eclat candidates that cannot become frequent stop mid-merge).
/// `Some(n)` means `out` holds the complete intersection and `n ≥
/// min_sup`; on `None` the contents of `out` are unspecified.
pub fn intersect_bounded_into(
    a: &[Tid],
    b: &[Tid],
    min_sup: u32,
    out: &mut Tidset,
) -> Option<u32> {
    out.clear();
    if a.len() * 8 < b.len() {
        return gallop_bounded_into(a, b, min_sup, out);
    }
    if b.len() * 8 < a.len() {
        return gallop_bounded_into(b, a, min_sup, out);
    }
    let need = min_sup as usize;
    let (mut i, mut j) = (0, 0);
    while i < a.len() && j < b.len() {
        // Upper bound on the final size: matches so far + whatever the
        // shorter remaining side could still contribute.
        if out.len() + (a.len() - i).min(b.len() - j) < need {
            return None;
        }
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                out.push(a[i]);
                i += 1;
                j += 1;
            }
        }
    }
    if out.len() >= need {
        Some(out.len() as u32)
    } else {
        None
    }
}

/// Intersection via binary search of the smaller side into the larger.
fn gallop_intersect_into(small: &[Tid], large: &[Tid], out: &mut Tidset) {
    out.reserve(small.len());
    let mut lo = 0usize;
    for &t in small {
        match large[lo..].binary_search(&t) {
            Ok(pos) => {
                out.push(t);
                lo += pos + 1;
            }
            Err(pos) => {
                lo += pos;
            }
        }
        if lo >= large.len() {
            break;
        }
    }
}

/// Galloping intersection with the same early exit as
/// [`intersect_bounded_into`]: the bound here is matches so far + small
/// elements not yet probed.
fn gallop_bounded_into(
    small: &[Tid],
    large: &[Tid],
    min_sup: u32,
    out: &mut Tidset,
) -> Option<u32> {
    let need = min_sup as usize;
    let mut lo = 0usize;
    for (idx, &t) in small.iter().enumerate() {
        if out.len() + (small.len() - idx) < need {
            return None;
        }
        if lo >= large.len() {
            break;
        }
        match large[lo..].binary_search(&t) {
            Ok(pos) => {
                out.push(t);
                lo += pos + 1;
            }
            Err(pos) => {
                lo += pos;
            }
        }
    }
    if out.len() >= need {
        Some(out.len() as u32)
    } else {
        None
    }
}

/// Count-only galloping intersection: binary-search the smaller side
/// into the larger without materializing the result — skewed support
/// counting allocates nothing.
fn gallop_intersect_count(small: &[Tid], large: &[Tid]) -> u32 {
    let mut n = 0u32;
    let mut lo = 0usize;
    for &t in small {
        match large[lo..].binary_search(&t) {
            Ok(pos) => {
                n += 1;
                lo += pos + 1;
            }
            Err(pos) => {
                lo += pos;
            }
        }
        if lo >= large.len() {
            break;
        }
    }
    n
}

/// `|a ∩ b|` without materializing (support counting). Skewed sizes take
/// the count-only galloping path.
pub fn intersect_count(a: &[Tid], b: &[Tid]) -> u32 {
    if a.len() * 8 < b.len() {
        return gallop_intersect_count(a, b);
    }
    if b.len() * 8 < a.len() {
        return gallop_intersect_count(b, a);
    }
    let (mut i, mut j, mut n) = (0, 0, 0u32);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                n += 1;
                i += 1;
                j += 1;
            }
        }
    }
    n
}

/// Set difference `a \ b` of sorted tidsets — the diffset representation
/// (Zaki's dEclat), an optional optimization ablated in the benches.
pub fn difference(a: &[Tid], b: &[Tid]) -> Tidset {
    let mut out = Vec::new();
    difference_into(a, b, &mut out);
    out
}

/// Set difference into a reused buffer. When `b` dwarfs `a`, each `a`
/// element is binary-searched in `b` (galloping) instead of walking `b`
/// linearly — the same skew cutoff as [`intersect_into`].
pub fn difference_into(a: &[Tid], b: &[Tid], out: &mut Tidset) {
    out.clear();
    out.reserve(a.len());
    if a.len() * 8 < b.len() {
        let mut lo = 0usize;
        for &t in a {
            match b[lo..].binary_search(&t) {
                Ok(pos) => lo += pos + 1,
                Err(pos) => {
                    out.push(t);
                    lo += pos;
                }
            }
        }
        return;
    }
    let (mut i, mut j) = (0, 0);
    while i < a.len() {
        if j >= b.len() || a[i] < b[j] {
            out.push(a[i]);
            i += 1;
        } else if a[i] > b[j] {
            j += 1;
        } else {
            i += 1;
            j += 1;
        }
    }
}

/// Bounded difference into a reused buffer: abort once the difference
/// would exceed `max_len` elements. In dEclat a candidate's support is
/// `σ(parent) − |diffset|`, so with `max_len = σ(parent) − min_sup` the
/// abort fires exactly when the candidate can no longer be frequent.
/// `Some(|a \ b|)` when the full difference fits; on `None` the contents
/// of `out` are unspecified.
pub fn difference_bounded_into(
    a: &[Tid],
    b: &[Tid],
    max_len: usize,
    out: &mut Tidset,
) -> Option<u32> {
    out.clear();
    // Same skew cutoff as `difference_into`: probe each `a` element into
    // the larger `b` instead of walking `b` linearly.
    if a.len() * 8 < b.len() {
        let mut lo = 0usize;
        for &t in a {
            match b[lo..].binary_search(&t) {
                Ok(pos) => lo += pos + 1,
                Err(pos) => {
                    if out.len() == max_len {
                        return None;
                    }
                    out.push(t);
                    lo += pos;
                }
            }
        }
        return Some(out.len() as u32);
    }
    let (mut i, mut j) = (0, 0);
    while i < a.len() {
        if j >= b.len() || a[i] < b[j] {
            if out.len() == max_len {
                return None;
            }
            out.push(a[i]);
            i += 1;
        } else if a[i] > b[j] {
            j += 1;
        } else {
            i += 1;
            j += 1;
        }
    }
    Some(out.len() as u32)
}

/// The vertical database: frequent items with their tidsets, in a chosen
/// item order (the paper sorts by ascending support — the "total order"
/// that balances equivalence-class fan-out).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerticalDb {
    /// `(item, tidset)` pairs, in mining order.
    pub items: Vec<(Item, Tidset)>,
    /// Number of transactions in the underlying horizontal database.
    pub universe: usize,
}

impl VerticalDb {
    /// Build from a horizontal database, keeping only items with support
    /// ≥ `min_sup_count`, ordered by ascending support with item id as the
    /// tie-break (the order EclatV1 Phase-1 produces via
    /// `sort(freqItemTids.collect())`).
    pub fn build(db: &Database, min_sup_count: u32) -> VerticalDb {
        let mut tidsets: HashMap<Item, Tidset> = HashMap::new();
        for (tid, t) in db.transactions().iter().enumerate() {
            for &item in t {
                tidsets.entry(item).or_default().push(tid as Tid);
            }
        }
        let mut items: Vec<(Item, Tidset)> = tidsets
            .into_iter()
            .filter(|(_, tids)| tids.len() as u32 >= min_sup_count)
            .collect();
        items.sort_by(|a, b| a.1.len().cmp(&b.1.len()).then_with(|| a.0.cmp(&b.0)));
        VerticalDb { items, universe: db.len() }
    }

    /// Number of frequent items (`n` in the paper).
    pub fn len(&self) -> usize {
        self.items.len()
    }

    /// True when no item is frequent.
    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// The frequent items in mining order.
    pub fn item_order(&self) -> Vec<Item> {
        self.items.iter().map(|(i, _)| *i).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn intersect_basics() {
        assert_eq!(intersect(&[1, 3, 5, 7], &[3, 4, 5]), vec![3, 5]);
        assert_eq!(intersect(&[], &[1, 2]), Vec::<Tid>::new());
        assert_eq!(intersect_count(&[1, 3, 5, 7], &[3, 4, 5]), 2);
    }

    #[test]
    fn galloping_path_matches_linear() {
        let small = vec![5u32, 100, 900];
        let large: Vec<u32> = (0..1000).collect();
        assert_eq!(intersect(&small, &large), small);
        assert_eq!(intersect(&large, &small), small);
        assert_eq!(intersect_count(&small, &large), 3);
    }

    #[test]
    fn difference_basics() {
        assert_eq!(difference(&[1, 2, 3, 4], &[2, 4]), vec![1, 3]);
        assert_eq!(difference(&[1, 2], &[]), vec![1, 2]);
        assert_eq!(difference(&[], &[1]), Vec::<Tid>::new());
    }

    #[test]
    fn random_against_hashsets() {
        // Case 0..99: similar sizes (linear path); 100..199: heavily
        // skewed sizes so every galloping path (materializing, into,
        // bounded, count-only, difference) is exercised and must agree
        // with the linear walk. The into-buffers are reused across cases
        // to catch stale-content bugs in the recycled-scratch paths.
        let mut rng = Rng::new(9);
        let mut buf = Tidset::new();
        let mut bounded_buf = Tidset::new();
        for case in 0..200 {
            let skewed = case >= 100;
            let (n_a, n_b, universe) = if skewed {
                (rng.range(0, 6), rng.range(100, 300), 2000u64)
            } else {
                (rng.range(0, 80), rng.range(0, 80), 100u64)
            };
            let mut a: Vec<u32> = (0..n_a).map(|_| rng.below(universe) as u32).collect();
            let mut b: Vec<u32> = (0..n_b).map(|_| rng.below(universe) as u32).collect();
            a.sort_unstable();
            a.dedup();
            b.sort_unstable();
            b.dedup();
            let sa: std::collections::HashSet<_> = a.iter().copied().collect();
            let sb: std::collections::HashSet<_> = b.iter().copied().collect();
            let mut want: Vec<u32> = sa.intersection(&sb).copied().collect();
            want.sort_unstable();
            assert_eq!(intersect(&a, &b), want, "case {case}");
            assert_eq!(intersect(&b, &a), want, "case {case} swapped");
            // Count-only path (galloping when skewed) == linear walk.
            assert_eq!(intersect_count(&a, &b) as usize, want.len(), "case {case}");
            assert_eq!(intersect_count(&b, &a) as usize, want.len(), "case {case} swapped");
            // Reused-buffer path == allocating path, both directions.
            intersect_into(&a, &b, &mut buf);
            assert_eq!(buf, want, "case {case} into");
            intersect_into(&b, &a, &mut buf);
            assert_eq!(buf, want, "case {case} into swapped");
            // Bounded path: below/at the true size it must materialize
            // the full result; above it, abort with None.
            for min_sup in [0, want.len() / 2, want.len(), want.len() + 1] {
                let got = intersect_bounded_into(&a, &b, min_sup as u32, &mut bounded_buf);
                if min_sup <= want.len() {
                    assert_eq!(got, Some(want.len() as u32), "case {case} min_sup={min_sup}");
                    assert_eq!(bounded_buf, want, "case {case} min_sup={min_sup}");
                } else {
                    assert_eq!(got, None, "case {case} min_sup={min_sup}");
                }
            }
            let mut want_diff: Vec<u32> = sa.difference(&sb).copied().collect();
            want_diff.sort_unstable();
            assert_eq!(difference(&a, &b), want_diff, "case {case}");
            assert_eq!(difference(&b, &a).len(), sb.difference(&sa).count(), "case {case}");
            difference_into(&a, &b, &mut buf);
            assert_eq!(buf, want_diff, "case {case} diff into");
            // Bounded difference: budget at the true size keeps the full
            // diff; one below aborts.
            assert_eq!(
                difference_bounded_into(&a, &b, want_diff.len(), &mut bounded_buf),
                Some(want_diff.len() as u32),
                "case {case} diff budget"
            );
            assert_eq!(bounded_buf, want_diff, "case {case} diff bounded content");
            if !want_diff.is_empty() {
                assert_eq!(
                    difference_bounded_into(&a, &b, want_diff.len() - 1, &mut bounded_buf),
                    None,
                    "case {case} diff abort"
                );
            }
        }
    }

    #[test]
    fn vertical_build_orders_by_support() {
        // item 1 in 3 txns, item 2 in 2, item 3 in 1, item 9 in 1.
        let db = Database::from_rows(vec![vec![1, 2], vec![1, 2, 3], vec![1, 9]]);
        let v = VerticalDb::build(&db, 2);
        assert_eq!(v.universe, 3);
        assert_eq!(v.item_order(), vec![2, 1], "ascending support");
        assert_eq!(v.items[0].1, vec![0, 1]);
        assert_eq!(v.items[1].1, vec![0, 1, 2]);
    }

    #[test]
    fn vertical_empty_when_nothing_frequent() {
        let db = Database::from_rows(vec![vec![1], vec![2]]);
        let v = VerticalDb::build(&db, 2);
        assert!(v.is_empty());
    }
}
