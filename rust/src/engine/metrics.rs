//! Per-task metrics and the job event log.
//!
//! Every task the scheduler runs records `(job, stage, partition, wall
//! time, records produced)`. The virtual-cluster simulator
//! ([`super::simcluster`]) replays these measurements at different core
//! counts to produce the paper's Fig. 15 scaling curves on a small
//! machine, and the benchmark harness reports stage breakdowns from the
//! same log.
//!
//! Both logs are **bounded**: they keep the latest
//! [`MetricsRegistry::DEFAULT_CAPACITY`] entries and count evicted ones
//! in [`MetricsRegistry::dropped_tasks`]/[`MetricsRegistry::dropped_jobs`],
//! so long `repro stream --serve` runs no longer grow without bound.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Identifies a job (one action).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub usize);

/// What kind of stage a task belonged to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    /// Shuffle map stage (writes buckets).
    ShuffleMap,
    /// Final stage of an action (computes result partitions).
    Result,
}

/// One completed task.
#[derive(Debug, Clone)]
pub struct TaskMetric {
    /// Job this task belonged to.
    pub job: JobId,
    /// Stage index within the job (stages run in submission order).
    pub stage: usize,
    /// Map stage or result stage.
    pub kind: StageKind,
    /// Partition index the task computed.
    pub partition: usize,
    /// Task wall time.
    pub wall: Duration,
    /// Records produced by the task.
    pub records: u64,
}

/// One completed job (action) span.
#[derive(Debug, Clone)]
pub struct JobSpan {
    /// Job id.
    pub job: JobId,
    /// Human-readable action name (`collect`, `count`, ...).
    pub name: String,
    /// Total driver-observed wall time of the job.
    pub wall: Duration,
    /// Number of stages that ran.
    pub stages: usize,
}

/// Keep-latest ring: push evicts the oldest entry once `cap` is
/// reached, counting evictions in `dropped`.
#[derive(Debug)]
struct Ring<T> {
    buf: VecDeque<T>,
    cap: usize,
    dropped: u64,
}

impl<T> Ring<T> {
    fn new(cap: usize) -> Ring<T> {
        Ring { buf: VecDeque::new(), cap: cap.max(1), dropped: 0 }
    }

    fn push(&mut self, v: T) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(v);
    }

    fn clear(&mut self) {
        self.buf.clear();
        self.dropped = 0;
    }
}

/// Registry collecting task metrics and job spans for one context.
pub struct MetricsRegistry {
    tasks: Mutex<Ring<TaskMetric>>,
    jobs: Mutex<Ring<JobSpan>>,
    next_job: AtomicUsize,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        MetricsRegistry::new()
    }
}


/// Poison-tolerant lock: a task that panicked while holding the metrics
/// mutex leaves consistent data behind (pushes are atomic), so recording
/// must keep working on the surviving executors.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl MetricsRegistry {
    /// Default keep-latest capacity of each log (tasks and jobs
    /// separately): enough for every bench/figure run while bounding
    /// week-long streaming services.
    pub const DEFAULT_CAPACITY: usize = 65_536;

    /// Create an empty registry with [`Self::DEFAULT_CAPACITY`].
    pub fn new() -> Self {
        Self::with_capacity(Self::DEFAULT_CAPACITY)
    }

    /// Create an empty registry keeping at most `cap` tasks and `cap`
    /// jobs (latest win; `cap` is clamped to at least 1).
    pub fn with_capacity(cap: usize) -> Self {
        MetricsRegistry {
            tasks: Mutex::new(Ring::new(cap)),
            jobs: Mutex::new(Ring::new(cap)),
            next_job: AtomicUsize::new(0),
        }
    }

    /// Allocate the next job id.
    pub fn next_job_id(&self) -> JobId {
        // ordering: SeqCst — cold id allocation (once per job);
        // uniqueness needs only RMW atomicity, the total order keeps
        // job ids monotone across driver threads. Not worth weakening.
        JobId(self.next_job.fetch_add(1, Ordering::SeqCst))
    }

    /// Record one task.
    pub fn record_task(&self, m: TaskMetric) {
        lock(&self.tasks).push(m);
    }

    /// Record one finished job.
    pub fn record_job(&self, span: JobSpan) {
        lock(&self.jobs).push(span);
    }

    /// Snapshot of the retained task metrics (oldest first).
    pub fn tasks(&self) -> Vec<TaskMetric> {
        lock(&self.tasks).buf.iter().cloned().collect()
    }

    /// Snapshot of the retained job spans (oldest first).
    pub fn jobs(&self) -> Vec<JobSpan> {
        lock(&self.jobs).buf.iter().cloned().collect()
    }

    /// Tasks belonging to one job.
    pub fn tasks_of(&self, job: JobId) -> Vec<TaskMetric> {
        lock(&self.tasks).buf.iter().filter(|t| t.job == job).cloned().collect()
    }

    /// Task metrics evicted from the ring since the last [`Self::reset`].
    pub fn dropped_tasks(&self) -> u64 {
        lock(&self.tasks).dropped
    }

    /// Job spans evicted from the ring since the last [`Self::reset`].
    pub fn dropped_jobs(&self) -> u64 {
        lock(&self.jobs).dropped
    }

    /// Clear everything (between benchmark repetitions).
    pub fn reset(&self) {
        lock(&self.tasks).clear();
        lock(&self.jobs).clear();
    }

    /// Sum of task wall time over all retained tasks (the "total compute"
    /// that the simulator spreads over virtual cores).
    pub fn total_task_time(&self) -> Duration {
        lock(&self.tasks).buf.iter().map(|t| t.wall).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tm(job: usize, stage: usize, part: usize, ms: u64) -> TaskMetric {
        TaskMetric {
            job: JobId(job),
            stage,
            kind: StageKind::Result,
            partition: part,
            wall: Duration::from_millis(ms),
            records: 1,
        }
    }

    #[test]
    fn job_ids_monotonic() {
        let r = MetricsRegistry::new();
        assert_eq!(r.next_job_id(), JobId(0));
        assert_eq!(r.next_job_id(), JobId(1));
    }

    #[test]
    fn record_and_filter_by_job() {
        let r = MetricsRegistry::new();
        r.record_task(tm(0, 0, 0, 5));
        r.record_task(tm(1, 0, 0, 7));
        r.record_task(tm(0, 1, 1, 3));
        assert_eq!(r.tasks().len(), 3);
        assert_eq!(r.tasks_of(JobId(0)).len(), 2);
        assert_eq!(r.total_task_time(), Duration::from_millis(15));
        r.reset();
        assert!(r.tasks().is_empty());
    }

    #[test]
    fn ring_keeps_latest_and_counts_dropped() {
        let r = MetricsRegistry::with_capacity(3);
        for i in 0..5 {
            r.record_task(tm(0, 0, i, i as u64));
        }
        let tasks = r.tasks();
        assert_eq!(tasks.len(), 3, "capped at capacity");
        let parts: Vec<usize> = tasks.iter().map(|t| t.partition).collect();
        assert_eq!(parts, vec![2, 3, 4], "latest kept, oldest first");
        assert_eq!(r.dropped_tasks(), 2);
        assert_eq!(r.dropped_jobs(), 0);
        // total_task_time covers only the retained window.
        assert_eq!(r.total_task_time(), Duration::from_millis(2 + 3 + 4));

        for i in 0..4 {
            r.record_job(JobSpan {
                job: JobId(i),
                name: format!("job{i}"),
                wall: Duration::from_millis(1),
                stages: 1,
            });
        }
        assert_eq!(r.jobs().len(), 3);
        assert_eq!(r.dropped_jobs(), 1);

        r.reset();
        assert_eq!(r.dropped_tasks(), 0);
        assert!(r.tasks().is_empty() && r.jobs().is_empty());
    }
}
