//! End-to-end tests for the `lint` binary (PR 9): the crate's own
//! sources must scan clean, and every seeded violation in
//! `tests/lint_fixtures/` must be reported with its exact file, line,
//! and rule id.

#![cfg(not(loom))]

use std::path::Path;
use std::process::{Command, Output};

fn run_lint(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_lint"))
        .args(args)
        .output()
        .expect("run lint binary")
}

fn fixtures_root() -> String {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures/src")
        .to_string_lossy()
        .into_owned()
}

#[test]
fn crate_sources_are_clean() {
    let out = run_lint(&[]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "lint must exit 0 on the crate's own sources:\n{stdout}{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(stdout.contains("lint: clean"), "clean summary line, got:\n{stdout}");
}

#[test]
fn fixtures_fail_with_file_line_and_rule_diagnostics() {
    let root = fixtures_root();
    let out = run_lint(&["--root", &root]);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(!out.status.success(), "seeded violations must exit nonzero:\n{stdout}");

    // Every seeded violation, by exact file:line and rule id.
    let expected = [
        "bare_lock.rs:8: [bare-lock-unwrap]",
        "bare_lock.rs:13: [bare-lock-unwrap]",
        "bare_lock.rs:18: [bare-lock-unwrap]",
        "missing_ordering.rs:11: [ordering-comment]",
        "missing_ordering.rs:16: [ordering-comment]",
        "missing_safety.rs:7: [safety-comment]",
        "missing_safety.rs:13: [safety-comment]",
        "engine/chaos.rs:8: [chaos-determinism]",
        "engine/chaos.rs:11: [chaos-determinism]",
        "stream/serve.rs:5: [shim-imports]",
        "stream/serve.rs:8: [shim-imports]",
        "net/transport.rs:6: [shim-imports]",
        "net/transport.rs:11: [socket-unwrap]",
        "net/transport.rs:13: [socket-unwrap]",
        "net/transport.rs:18: [socket-unwrap]",
    ];
    for needle in expected {
        assert!(stdout.contains(needle), "missing diagnostic `{needle}` in:\n{stdout}");
    }

    // Exactly the seeded violations — the count pins down false
    // positives anywhere in the fixture tree.
    let diagnostics =
        stdout.lines().filter(|l| l.contains(": [") && !l.starts_with("lint:")).count();
    assert_eq!(
        diagnostics,
        expected.len(),
        "unexpected extra or missing diagnostics:\n{stdout}"
    );
}

#[test]
fn fixtures_respect_exemptions() {
    let root = fixtures_root();
    let out = run_lint(&["--root", &root]);
    let stdout = String::from_utf8_lossy(&out.stdout);

    // The fully-compliant file must not appear at all.
    assert!(!stdout.contains("clean.rs:"), "clean.rs must scan clean:\n{stdout}");
    // Test regions are exempt from bare-lock-unwrap (bare_lock.rs has a
    // `.lock().unwrap()` inside `#[cfg(test)]` on line 30).
    assert!(!stdout.contains("bare_lock.rs:30"), "test region not masked:\n{stdout}");
    // Justified sites are exempt.
    assert!(!stdout.contains("missing_ordering.rs:22"), "justified ordering flagged:\n{stdout}");
    assert!(!stdout.contains("missing_ordering.rs:26"), "inline justification flagged:\n{stdout}");
    assert!(!stdout.contains("missing_safety.rs:17"), "justified unsafe impl flagged:\n{stdout}");
    assert!(!stdout.contains("missing_safety.rs:21"), "justified unsafe block flagged:\n{stdout}");
    // The shim-imports allowlist (std::thread::current).
    assert!(!stdout.contains("stream/serve.rs:15"), "allowlisted line flagged:\n{stdout}");
    // Propagated socket errors are fine; test regions may unwrap them.
    assert!(!stdout.contains("net/transport.rs:22"), "propagated error flagged:\n{stdout}");
    assert!(!stdout.contains("net/transport.rs:29"), "test socket unwrap flagged:\n{stdout}");
}

#[test]
fn list_prints_every_rule() {
    let out = run_lint(&["--list"]);
    assert!(out.status.success());
    let stdout = String::from_utf8_lossy(&out.stdout);
    let rules = [
        "bare-lock-unwrap",
        "ordering-comment",
        "safety-comment",
        "chaos-determinism",
        "shim-imports",
        "socket-unwrap",
    ];
    for rule in rules {
        assert!(stdout.contains(rule), "rule `{rule}` missing from --list:\n{stdout}");
    }
}

#[test]
fn unknown_flags_error_out() {
    let out = run_lint(&["--frobnicate"]);
    assert!(!out.status.success());
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("unknown argument"), "{stderr}");
}
