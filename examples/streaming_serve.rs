//! The streaming subsystem as a *service*: one producer thread feeds a
//! drifting clickstream into the async [`StreamService`], the mining
//! loop publishes every emission through the double-buffered snapshot
//! handle, and N query threads read the live rules concurrently — no
//! reader ever waits on the miner, no batch is ever dropped, and under
//! backpressure emissions coalesce skip-to-latest.
//!
//! ```text
//! cargo run --release --example streaming_serve
//! ```

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use rdd_eclat::data::clickstream::ClickParams;
use rdd_eclat::engine::ClusterContext;
use rdd_eclat::fim::MinSup;
use rdd_eclat::stream::{
    BatchSource, ClickstreamSource, Ingest, IngestConfig, StreamConfig, StreamService,
    StreamingMiner, WindowSpec,
};

const BATCH: usize = 250;
const WINDOW: usize = 12;
const BATCHES: usize = 48;
const QUERY_THREADS: usize = 3;

fn main() -> rdd_eclat::error::Result<()> {
    println!(
        "async serving demo: {BATCHES} batches x {BATCH} sessions, window {WINDOW} slide 1, \
         {QUERY_THREADS} query threads\n"
    );

    let ctx = ClusterContext::builder().build();
    let cfg = StreamConfig::new(WindowSpec::sliding(WINDOW, 1), MinSup::fraction(0.01))
        .min_conf(0.6);
    // A small queue cap plus a per-emission throttle makes backpressure
    // visible in a demo-sized run: the producer outpaces the throttled
    // miner, emissions coalesce, and the handle always serves the
    // freshest window.
    let service = StreamService::spawn(
        StreamingMiner::new(ctx, cfg),
        IngestConfig::new(4).throttle(Duration::from_millis(10)),
    );

    // N concurrent readers over the lock-free handle.
    let stop = Arc::new(AtomicBool::new(false));
    let readers: Vec<_> = (0..QUERY_THREADS)
        .map(|r| {
            let handle = service.handle();
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let (mut queries, mut last) = (0u64, u64::MAX);
                while !stop.load(Ordering::SeqCst) {
                    if let Some(snap) = handle.latest() {
                        queries += 1;
                        if snap.batch_id != last {
                            last = snap.batch_id;
                            let probe = snap
                                .rules
                                .first()
                                .map(|rule| snap.rules_for(&rule.antecedent).len())
                                .unwrap_or(0);
                            println!(
                                "  [reader {r}] live batch {:>3}: {:>4} itemsets, {:>3} rules \
                                 ({} for the strongest antecedent)",
                                snap.batch_id,
                                snap.frequents.len(),
                                snap.rules.len(),
                                probe,
                            );
                        }
                    }
                    std::thread::sleep(Duration::from_millis(40));
                }
                queries
            })
        })
        .collect();

    // One producer: generate and push; pushes return immediately.
    let params = ClickParams { sessions: BATCHES * BATCH, ..ClickParams::drift() };
    let mut source = ClickstreamSource::new(params, 7, BATCH);
    let mut backpressured = 0usize;
    let producer_wall = std::time::Instant::now();
    while let Some(batch) = source.next_batch() {
        if let Ingest::Backpressure { .. } = service.push_batch(batch)? {
            backpressured += 1;
        }
    }
    let producer_wall = producer_wall.elapsed();

    // Lifecycle: drain (catch up to the latest window), then shut down
    // and take the miner back.
    let final_snap = service.drain()?.expect("slide 1 emits");
    stop.store(true, Ordering::SeqCst);
    let queries: u64 = readers.into_iter().map(|r| r.join().unwrap_or(0)).sum();
    let stats = service.stats();
    let miner = service.shutdown()?;

    println!(
        "\nproducer pushed {BATCHES} batches in {producer_wall:?} \
         ({backpressured} pushes saw backpressure)"
    );
    println!(
        "mining loop: {} emissions published, {} skipped (coalesced skip-to-latest)",
        stats.emissions, stats.skipped
    );
    println!("readers answered {queries} live queries while mining ran");
    println!(
        "final window (batch {}): {} txns, {} itemsets, {} rules; strongest:",
        final_snap.batch_id,
        miner.window_txns(),
        final_snap.frequents.len(),
        final_snap.rules.len()
    );
    for r in final_snap.rules.iter().take(5) {
        println!("  {r}");
    }
    Ok(())
}
