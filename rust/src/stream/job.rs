//! The per-batch mining job: ties the window, the incremental vertical
//! store and the bottom-up Eclat search into a DStream-style driver.
//!
//! Every emission mines the live window and produces a
//! [`BatchSnapshot`]: the frequent itemsets plus the association rules
//! (ARM step 2) the serving layer would publish. Two execution modes:
//!
//! * [`MineMode::FromScratch`] — materialize the window and run
//!   [`SeqEclat`] end to end, every time. The baseline the bench
//!   compares against.
//! * [`MineMode::Incremental`] — mine from the maintained vertical
//!   store. The support of an itemset over the window can only change
//!   when a transaction containing **all** of its items enters or
//!   leaves, i.e. when every item is dirty. So only the sub-lattice of
//!   all-dirty itemsets is re-mined (equivalence classes over dirty
//!   frequent atoms, run on the engine's executor pool); every cached
//!   itemset containing at least one clean item is reused verbatim.
//!   When churn exceeds [`StreamConfig::churn_threshold`] — or min_sup
//!   resolves to a different count than the cached snapshot's — the
//!   job falls back to re-mining every class from the store.

use std::collections::HashSet;
use std::sync::{Arc, OnceLock};
use std::time::Duration;

use crate::algorithms::partitioners::ReverseHashClassPartitioner;
use crate::algorithms::SeqEclat;
use crate::engine::{ClusterContext, Partitioner};
use crate::error::{Error, Result};
use crate::fim::{
    bottom_up_with, generate_rules, rules_to_json, sort_frequents, Frequent, Item, MineScratch,
    MinSup, PooledSink, Rule, TidBitmap,
};
use crate::net::{Bounds, RemoteShardSet};
use crate::util::json::{json_f64, json_str};
use crate::util::Stopwatch;

use super::sharded::ShardedVerticalDb;
use super::window::{normalize_row, SlidingWindow, WindowSpec};

/// Streaming-job instrumentation cells, resolved once (see [`crate::obs`]).
struct StreamObs {
    churn_fallback: &'static crate::obs::Counter,
    mine_wall_us: &'static crate::obs::Histogram,
}

fn stream_obs() -> &'static StreamObs {
    static OBS: OnceLock<StreamObs> = OnceLock::new();
    OBS.get_or_init(|| StreamObs {
        churn_fallback: crate::obs::counter("stream.churn_fallback"),
        mine_wall_us: crate::obs::histogram("stream.shard.mine_wall_us"),
    })
}

/// How each emission is mined.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MineMode {
    /// Maintain the vertical store and re-mine only dirty classes.
    Incremental,
    /// Materialize the window and run `SeqEclat` from scratch per batch.
    FromScratch,
}

/// What the job actually executed for one emission.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MinePlan {
    /// Window materialized and mined from scratch (`MineMode::FromScratch`).
    Rebuild,
    /// Every frequent atom re-mined from the maintained store (first
    /// emission, min_sup count change, or churn above threshold).
    FullRemine,
    /// Only the dirty sub-lattice was re-mined.
    Delta {
        /// Dirty frequent atoms the fresh sub-mine ran over.
        remined_atoms: usize,
        /// Cached itemsets (≥ one clean item) reused without recounting.
        reused_itemsets: usize,
    },
}

impl MinePlan {
    fn as_str(&self) -> &'static str {
        match self {
            MinePlan::Rebuild => "rebuild",
            MinePlan::FullRemine => "full",
            MinePlan::Delta { .. } => "delta",
        }
    }
}

/// Configuration of a streaming mining job.
#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// Window geometry.
    pub window: WindowSpec,
    /// Support threshold, resolved against the live window size at every
    /// emission (fractions therefore track the window as it fills).
    pub min_sup: MinSup,
    /// Minimum confidence for the per-batch rule snapshot.
    pub min_conf: f64,
    /// Execution mode.
    pub mode: MineMode,
    /// Fraction of frequent atoms dirty above which `Incremental` falls
    /// back to a full re-mine (delta bookkeeping would outweigh reuse).
    pub churn_threshold: f64,
    /// Keep at most this many rules per snapshot (they are sorted by
    /// confidence, so this keeps the strongest). `None` keeps all.
    pub max_rules: Option<usize>,
    /// Number of store shards (≥ 1). With `1` the job runs the classic
    /// single-store path; with more, item columns are spread across
    /// shards by the EclatV5 reverse-hash partitioner and store
    /// bookkeeping plus mining parallelize per shard. Results are
    /// identical for every shard count.
    pub shards: usize,
}

impl StreamConfig {
    /// Incremental mining with the common defaults (`min_conf` 0.8,
    /// churn fallback at 75% dirty, unbounded rules).
    pub fn new(window: WindowSpec, min_sup: MinSup) -> StreamConfig {
        StreamConfig {
            window,
            min_sup,
            min_conf: 0.8,
            mode: MineMode::Incremental,
            churn_threshold: 0.75,
            max_rules: None,
            shards: 1,
        }
    }

    /// Set the store shard count (≥ 1; see [`StreamConfig::shards`]).
    ///
    /// # Panics
    ///
    /// Panics when `n` is zero.
    pub fn shards(mut self, n: usize) -> StreamConfig {
        assert!(n >= 1, "need at least one shard");
        self.shards = n;
        self
    }

    /// Switch the execution mode.
    pub fn mode(mut self, mode: MineMode) -> StreamConfig {
        self.mode = mode;
        self
    }

    /// Set the rule-confidence threshold.
    pub fn min_conf(mut self, c: f64) -> StreamConfig {
        self.min_conf = c;
        self
    }

    /// Set the churn fallback threshold: the fraction of frequent atoms
    /// dirty above which `Incremental` re-mines every class. Values are
    /// clamped to `[0, 1]` (`0.0` = always fall back when anything
    /// frequent is dirty, `1.0` = never fall back).
    ///
    /// # Panics
    ///
    /// Panics on NaN/infinite input — a non-finite threshold would make
    /// the fallback comparison silently constant (NaN compares false
    /// against everything), which is exactly the class of bug this
    /// validation exists to catch. The same check runs in
    /// [`StreamingMiner::new`] for configs built with struct-update
    /// syntax.
    pub fn churn_threshold(mut self, t: f64) -> StreamConfig {
        assert!(t.is_finite(), "churn_threshold must be finite, got {t}");
        self.churn_threshold = t.clamp(0.0, 1.0);
        self
    }
}

/// One emitted result: the live snapshot a rule-serving layer would
/// swap in atomically.
#[derive(Debug, Clone)]
pub struct BatchSnapshot {
    /// Sequence number of the newest batch in the window.
    pub batch_id: u64,
    /// Live transactions covered.
    pub window_txns: usize,
    /// Live batches covered.
    pub window_batches: usize,
    /// The absolute support threshold this emission used.
    pub min_sup_count: u32,
    /// Frequent 1-itemsets in the window.
    pub frequent_items: usize,
    /// Of those, how many were dirty since the previous emission.
    pub dirty_frequent_items: usize,
    /// What was executed.
    pub plan: MinePlan,
    /// All frequent itemsets, canonically sorted.
    pub frequents: Vec<Frequent>,
    /// Confident association rules over `frequents`, sorted by
    /// confidence descending.
    pub rules: Vec<Rule>,
    /// Wall time of this emission (mining + rule generation).
    pub wall: Duration,
}

impl BatchSnapshot {
    /// One-line progress summary for CLI/demo output.
    pub fn summary(&self) -> String {
        format!(
            "batch {:>4} | window {:>6} txns | {:>5} itemsets | {:>4} rules | {:<7} | {}",
            self.batch_id,
            self.window_txns,
            self.frequents.len(),
            self.rules.len(),
            self.plan.as_str(),
            crate::util::time::fmt_duration(self.wall),
        )
    }

    /// Serialize the snapshot (stats, frequents, rules) as JSON.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"batch_id\": {},\n", self.batch_id));
        out.push_str(&format!("  \"window_txns\": {},\n", self.window_txns));
        out.push_str(&format!("  \"window_batches\": {},\n", self.window_batches));
        out.push_str(&format!("  \"min_sup_count\": {},\n", self.min_sup_count));
        out.push_str(&format!("  \"frequent_items\": {},\n", self.frequent_items));
        out.push_str(&format!("  \"dirty_frequent_items\": {},\n", self.dirty_frequent_items));
        out.push_str(&format!("  \"plan\": {},\n", json_str(self.plan.as_str())));
        out.push_str(&format!("  \"wall_s\": {:.6},\n", self.wall.as_secs_f64()));
        out.push_str("  \"frequents\": [\n");
        for (i, f) in self.frequents.iter().enumerate() {
            let items: Vec<String> = f.items.iter().map(|x| x.to_string()).collect();
            out.push_str(&format!(
                "    {{\"items\": [{}], \"support\": {}}}{}\n",
                items.join(", "),
                f.support,
                if i + 1 < self.frequents.len() { "," } else { "" }
            ));
        }
        out.push_str("  ],\n");
        out.push_str(&format!("  \"rules\": {}\n", rules_to_json(&self.rules).trim_end()));
        out.push_str("}\n");
        out
    }
}

/// Cached result of the previous emission (Incremental mode).
#[derive(Debug)]
struct Cached {
    min_sup_count: u32,
    frequents: Vec<Frequent>,
}

/// Per-shard ingest + mining accounting — the shard-imbalance signal
/// surfaced through `IngestStats::shards` and `repro stream --serve`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardStats {
    /// Rows routed to this shard that contained at least one owned item.
    pub rows: u64,
    /// Item occurrences (postings) appended to this shard.
    pub postings: u64,
    /// Itemsets this shard's mining tasks emitted, cumulative.
    pub mined_itemsets: u64,
    /// Wall time of this shard's most recent mining task.
    pub mine_wall: Duration,
    /// Staleness stamp: monotonic time since these numbers were last
    /// refreshed. Zero when read synchronously from the miner
    /// ([`StreamingMiner::shard_stats`]); the async service
    /// (`IngestStats`) stamps it with now − last mining-loop refresh,
    /// so a stalled miner cannot serve old numbers as current.
    pub age: Duration,
}

impl ShardStats {
    /// Flat JSON object (hand-emitted like the bench reports): counters
    /// verbatim, durations in seconds. Schema pinned by a unit test in
    /// [`crate::stream::ingest`].
    pub fn to_json(&self) -> String {
        format!(
            "{{\"rows\": {}, \"postings\": {}, \"mined_itemsets\": {}, \"mine_wall_s\": {}, \
             \"age_s\": {}}}",
            self.rows,
            self.postings,
            self.mined_itemsets,
            json_f64(self.mine_wall.as_secs_f64()),
            json_f64(self.age.as_secs_f64())
        )
    }
}

/// What one shard's mining task did during one emission.
struct ShardRun {
    shard: usize,
    wall: Duration,
    itemsets: u64,
}

/// The micro-batch mining driver.
pub struct StreamingMiner {
    ctx: ClusterContext,
    cfg: StreamConfig,
    window: SlidingWindow,
    store: ShardedVerticalDb,
    /// Dirty items since the previous emission, one set per shard (a
    /// routed item's entry lives on its owning shard's set).
    dirty: Vec<HashSet<Item>>,
    /// Per-shard `(last mine wall, cumulative mined itemsets)`.
    mine_stats: Vec<(Duration, u64)>,
    cache: Option<Cached>,
    /// Sequence number of the newest ingested batch (0 before the first
    /// push) — what a skip-to-latest emission is attributed to.
    last_batch_id: u64,
    /// Remote worker ensemble mirroring the store's shard layout;
    /// `None` = everything mines in-process.
    remote: Option<RemoteShardSet>,
}

impl StreamingMiner {
    /// New job over an existing cluster context (jobs share executors
    /// with everything else running on the context, like one Spark app).
    ///
    /// Incremental mode keeps every live transaction in the vertical
    /// store, so its window is **row-free** — only batch geometry is
    /// tracked and each transaction is held once, not twice. FromScratch
    /// mode retains rows (it re-materializes the window every emission).
    ///
    /// # Panics
    ///
    /// Panics when `cfg.churn_threshold` is NaN or infinite (see
    /// [`StreamConfig::churn_threshold`]); out-of-range finite values
    /// are clamped to `[0, 1]`.
    pub fn new(ctx: ClusterContext, mut cfg: StreamConfig) -> StreamingMiner {
        assert!(
            cfg.churn_threshold.is_finite(),
            "churn_threshold must be finite, got {}",
            cfg.churn_threshold
        );
        cfg.churn_threshold = cfg.churn_threshold.clamp(0.0, 1.0);
        assert!(cfg.shards >= 1, "need at least one shard");
        let window = match cfg.mode {
            MineMode::Incremental => SlidingWindow::row_free(cfg.window),
            MineMode::FromScratch => SlidingWindow::new(cfg.window),
        };
        StreamingMiner {
            ctx,
            cfg: cfg.clone(),
            window,
            store: ShardedVerticalDb::new(cfg.shards),
            dirty: vec![HashSet::new(); cfg.shards],
            mine_stats: vec![(Duration::ZERO, 0); cfg.shards],
            cache: None,
            last_batch_id: 0,
            remote: None,
        }
    }

    /// Attach a connected remote worker ensemble: every ingested batch
    /// fans out to the workers and emissions mine remotely while all
    /// workers are live. A lost worker degrades mining back in-process
    /// — the local store stays always-exact either way, so snapshots
    /// remain window-exact through worker loss.
    ///
    /// # Panics
    ///
    /// Panics when the ensemble's shard count differs from
    /// `cfg.shards`: driver store and workers must share the routing
    /// modulus or the scattered classes would not line up.
    pub fn attach_remote(&mut self, remote: RemoteShardSet) {
        assert_eq!(
            remote.total_shards(),
            self.cfg.shards,
            "remote ensemble shard count must match cfg.shards"
        );
        self.remote = Some(remote);
    }

    /// The attached remote ensemble, if any.
    pub fn remote(&self) -> Option<&RemoteShardSet> {
        self.remote.as_ref()
    }

    /// Mutable access to the attached remote ensemble (worker stats,
    /// shutdown).
    pub fn remote_mut(&mut self) -> Option<&mut RemoteShardSet> {
        self.remote.as_mut()
    }

    /// The job's configuration.
    pub fn config(&self) -> &StreamConfig {
        &self.cfg
    }

    /// Live window size in transactions.
    pub fn window_txns(&self) -> usize {
        self.window.txns()
    }

    /// Materialize the live window (parity testing / debugging).
    /// Incremental mode reconstructs it from the vertical store — the
    /// single copy of the window's transactions; FromScratch reads the
    /// retained rows.
    pub fn materialize_window(&self) -> crate::fim::Database {
        match self.cfg.mode {
            MineMode::Incremental => crate::fim::Database::from_rows(self.store.live_rows()),
            MineMode::FromScratch => self.window.materialize(),
        }
    }

    /// Per-shard ingest + mining accounting (length = `cfg.shards`).
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.store
            .loads()
            .iter()
            .zip(&self.mine_stats)
            .map(|(load, &(mine_wall, mined_itemsets))| ShardStats {
                rows: load.rows,
                postings: load.postings,
                mined_itemsets,
                mine_wall,
                age: Duration::ZERO,
            })
            .collect()
    }

    /// Whether `item` was touched since the previous emission (its entry
    /// lives on the owning shard's dirty set).
    fn is_dirty(&self, item: Item) -> bool {
        self.dirty[self.store.route(item)].contains(&item)
    }

    /// Fold one emission's per-shard mining runs into the stats.
    fn record_mine(&mut self, runs: Vec<ShardRun>) {
        let obs = crate::obs::enabled();
        for run in runs {
            if obs {
                stream_obs().mine_wall_us.record(run.wall.as_micros() as u64);
            }
            let (wall, itemsets) = &mut self.mine_stats[run.shard];
            *wall = run.wall;
            *itemsets += run.itemsets;
        }
    }

    /// Ingest one micro-batch. Returns a snapshot when the window's
    /// slide cadence makes this batch an emission point, `None`
    /// otherwise. Synchronous: mining runs on the calling thread (the
    /// class tasks still scatter onto the engine pool); the async
    /// service in [`crate::stream::ingest`] decouples the two via
    /// [`StreamingMiner::ingest`] + [`StreamingMiner::mine_now`].
    pub fn push_batch(&mut self, rows: Vec<Vec<Item>>) -> Result<Option<BatchSnapshot>> {
        if self.ingest(rows)? {
            self.mine_now().map(Some)
        } else {
            Ok(None)
        }
    }

    /// Window/store bookkeeping for one micro-batch — normalize, append
    /// to the vertical store, advance the window, evict — **without**
    /// mining. Returns `true` when the slide cadence makes this batch an
    /// emission point. Cheap relative to an emission, which is what lets
    /// the async ingest loop keep bookkeeping exact while emissions
    /// coalesce skip-to-latest under backpressure.
    ///
    /// With `shards > 1` the batch's item columns are scattered to the
    /// store shards and each shard appends + evicts in one pool task;
    /// evictions are previewed from the window *before* the push so the
    /// whole batch is one fused parallel pass. Errors only if a shard
    /// task dies on the pool — the store is then poisoned and the miner
    /// must be discarded.
    pub fn ingest(&mut self, rows: Vec<Vec<Item>>) -> Result<bool> {
        let rows: Vec<Vec<Item>> = rows.into_iter().map(normalize_row).collect();
        if self.cfg.mode == MineMode::Incremental {
            // The row-free window carries no row contents — only the
            // per-batch distinct-item hint, so the store clears each
            // evicted tid range from exactly the touched bitmaps.
            let evictions = self.window.pending_evictions();
            self.store.apply_batch_on(&self.ctx.inner.pool, &rows, &evictions, &mut self.dirty)?;
            if let Some(remote) = self.remote.as_mut() {
                // Broadcast the batch to the worker replicas and hand
                // them the mirror's post-apply bounds to verify against
                // — the cross-process half of tid-space alignment.
                // Worker loss is absorbed here (the mirror is exact);
                // mining degrades in-process at the next emission.
                let (live_lo, next) = self.store.shard(0).tid_bounds();
                let after = Bounds { txns: self.store.txns() as u64, live_lo, next };
                remote.apply_batch(&rows, &evictions, after);
            }
            let res = self.window.push(rows);
            debug_assert_eq!(res.evicted.len(), evictions.len(), "eviction preview diverged");
            self.last_batch_id = res.batch_id;
            Ok(res.emit)
        } else {
            let res = self.window.push(rows);
            self.last_batch_id = res.batch_id;
            Ok(res.emit)
        }
    }

    /// Mine the window as it stands **now** and emit a snapshot,
    /// regardless of the slide cadence. The snapshot is attributed to
    /// the newest ingested batch — the skip-to-latest catch-up emission
    /// of the async service, and the second half of
    /// [`StreamingMiner::push_batch`].
    ///
    /// When the context has an armed [`crate::engine::ChaosPolicy`] with
    /// emission failures enabled, this is the injection point: the
    /// emission fails *before* mining (no partial state), exactly like a
    /// mid-mine panic surfaced as an error — the retry path in
    /// [`crate::stream::ingest`] takes over from there.
    pub fn mine_now(&mut self) -> Result<BatchSnapshot> {
        if let Some(chaos) = self.ctx.chaos() {
            if chaos.fail_emission() {
                return Err(Error::engine("chaos: injected emission failure"));
            }
        }
        self.emit()
    }

    /// Drop the incremental reuse cache so the next emission re-mines
    /// every class from the vertical store. The degraded-mode retry in
    /// [`crate::stream::ingest`] calls this after a failed emission: the
    /// cache may describe a snapshot that was never published, and a
    /// full re-mine from the (always-exact) store is the safe restart.
    pub fn invalidate_cache(&mut self) {
        self.cache = None;
    }

    fn emit(&mut self) -> Result<BatchSnapshot> {
        let sw = Stopwatch::start();
        let window_txns = self.window.txns();
        let min_sup_count = self.cfg.min_sup.to_count(window_txns);
        let (mut frequents, plan, dirty_frequent, frequent_items) = match self.cfg.mode {
            MineMode::FromScratch => {
                let db = self.window.materialize();
                let frequents = SeqEclat::mine(&db, MinSup::count(min_sup_count));
                let items = frequents.iter().filter(|f| f.items.len() == 1).count();
                (frequents, MinePlan::Rebuild, 0, items)
            }
            MineMode::Incremental => self.mine_incremental(min_sup_count)?,
        };
        sort_frequents(&mut frequents);
        let mut rules = generate_rules(&frequents, self.cfg.min_conf, Some(window_txns));
        if let Some(cap) = self.cfg.max_rules {
            rules.truncate(cap);
        }
        // Only the incremental path reads the reuse cache; FromScratch
        // skips the clone entirely.
        if self.cfg.mode == MineMode::Incremental {
            self.cache = Some(Cached { min_sup_count, frequents: frequents.clone() });
        }
        for d in &mut self.dirty {
            d.clear();
        }
        Ok(BatchSnapshot {
            batch_id: self.last_batch_id,
            window_txns,
            window_batches: self.window.len_batches(),
            min_sup_count,
            frequent_items,
            dirty_frequent_items: dirty_frequent,
            plan,
            frequents,
            rules,
            wall: sw.elapsed(),
        })
    }

    /// Incremental emission: decide between full re-mine and delta
    /// re-mine + cache reuse.
    fn mine_incremental(
        &mut self,
        min_sup_count: u32,
    ) -> Result<(Vec<Frequent>, MinePlan, usize, usize)> {
        let frequent_items = self.store.frequent_count(min_sup_count);
        // Count before cloning any bitmaps: the fallback path would
        // otherwise materialize the dirty atoms only to throw them away.
        let dirty_frequent =
            self.store.frequent_count_where(min_sup_count, |i| self.is_dirty(i));
        let full = match &self.cache {
            None => true,
            Some(c) => {
                // The churn test is a ratio — with no frequent atoms
                // there is no churn to measure, so the empty window
                // takes the delta path explicitly. (Defensive: since
                // dirty_frequent counts a subset of frequent_items, the
                // `> threshold * 0` comparison below could not fire
                // anyway for a clamped threshold; the guard keeps that
                // from silently depending on the two counts staying
                // subset-related.)
                c.min_sup_count != min_sup_count
                    || (frequent_items > 0
                        && dirty_frequent as f64
                            > self.cfg.churn_threshold * frequent_items as f64)
            }
        };
        if full {
            // A full re-mine with a live cache means reuse was available
            // but abandoned — the churn-fallback signal (also fires on a
            // min_sup change, which likewise invalidates the cache).
            if self.cache.is_some() && crate::obs::enabled() {
                stream_obs().churn_fallback.incr(1);
            }
            let atoms = self.store.atoms(min_sup_count, |_| true);
            let target = match self.remote.as_mut() {
                Some(r) if r.all_live() => MineTarget::Remote(r),
                _ => MineTarget::Local { shards: self.cfg.shards },
            };
            let (frequents, runs) = mine_atoms(&self.ctx, atoms, min_sup_count, target)?;
            self.record_mine(runs);
            return Ok((frequents, MinePlan::FullRemine, dirty_frequent, frequent_items));
        }
        let dirty_atoms = self.store.atoms(min_sup_count, |i| self.is_dirty(i));
        let target = match self.remote.as_mut() {
            Some(r) if r.all_live() => MineTarget::Remote(r),
            _ => MineTarget::Local { shards: self.cfg.shards },
        };
        let (fresh, runs) = mine_atoms(&self.ctx, dirty_atoms, min_sup_count, target)?;
        self.record_mine(runs);
        let cache = self.cache.as_ref().expect("checked above");
        // Reuse every cached itemset with at least one clean item: its
        // window support cannot have changed (any entering/leaving
        // transaction containing it would contain the clean item too).
        let mut merged: Vec<Frequent> = cache
            .frequents
            .iter()
            .filter(|f| f.items.iter().any(|&i| !self.is_dirty(i)))
            .cloned()
            .collect();
        let reused = merged.len();
        merged.extend(fresh);
        let plan = MinePlan::Delta { remined_atoms: dirty_frequent, reused_itemsets: reused };
        Ok((merged, plan, dirty_frequent, frequent_items))
    }
}

impl std::fmt::Debug for StreamingMiner {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StreamingMiner")
            .field("window", &self.window.spec())
            .field("mode", &self.cfg.mode)
            .field("window_txns", &self.window.txns())
            .finish()
    }
}

/// Mine the full sub-lattice over `atoms` (already support-ordered):
/// singletons plus one equivalence class per prefix atom, mined in
/// parallel on the context's executor pool — the same scatter/gather
/// the batch Eclat variants use for Phase 3. Each task builds its class
/// members with bounded intersections (infrequent candidates abort
/// mid-sweep and allocate nothing), mines through its own arena, and
/// emits into a flat [`PooledSink`] (one arena per task instead of one
/// `Vec` per itemset), decoded on the driver.
///
/// With `MineTarget::Local { shards: 1 }` this is one task per class —
/// the classic path. With more shards, classes are dealt to `shards`
/// groups by the EclatV5 reverse-hash partitioner over the dense class
/// key (low key = heavy class, so the dealing balances the triangular
/// weight) and each non-empty group runs as **one** task mining all of
/// its classes through a single scratch arena and sink. With
/// `MineTarget::Remote` the same dealing happens on the workers: the
/// atom columns ship over the wire, each worker mines its owned groups
/// and replies one pooled arena per group. Returns the frequents plus
/// one [`ShardRun`] per executed task group for the shard stats.
fn mine_atoms(
    ctx: &ClusterContext,
    atoms: Vec<(Item, TidBitmap, u32)>,
    min_sup: u32,
    target: MineTarget<'_>,
) -> Result<(Vec<Frequent>, Vec<ShardRun>)> {
    let mut out: Vec<Frequent> =
        atoms.iter().map(|(i, _, s)| Frequent::new(vec![*i], *s)).collect();
    if atoms.len() < 2 {
        return Ok((out, Vec::new()));
    }
    let shards = match target {
        MineTarget::Remote(remote) => {
            let mined = remote.mine_classes(&atoms, min_sup)?;
            let mut runs = Vec::with_capacity(mined.len());
            for m in mined {
                runs.push(ShardRun {
                    shard: m.shard as usize,
                    wall: m.wall,
                    itemsets: m.itemsets,
                });
                m.sink.replay(&mut out);
            }
            return Ok((out, runs));
        }
        MineTarget::Local { shards } => shards,
    };
    let shared = Arc::new(atoms);
    if shards <= 1 {
        let sw = Stopwatch::start();
        let tasks: Vec<_> = (0..shared.len() - 1)
            .map(|i| {
                let atoms = Arc::clone(&shared);
                move || {
                    let mut sp = crate::obs::span("stream.mine_class");
                    let found =
                        mine_class(&atoms, i, min_sup, PooledSink::new(), &mut MineScratch::new());
                    sp.arg("class", i as u64).arg("itemsets", found.len() as u64);
                    found
                }
            })
            .collect();
        let mut itemsets = 0u64;
        for found in ctx.inner.pool.run_all(tasks)? {
            itemsets += found.len() as u64;
            found.replay(&mut out);
        }
        return Ok((out, vec![ShardRun { shard: 0, wall: sw.elapsed(), itemsets }]));
    }
    // Deal class prefixes to shard groups; skip empty groups entirely.
    let part = ReverseHashClassPartitioner::new(shards);
    let mut groups: Vec<Vec<usize>> = vec![Vec::new(); shards];
    for i in 0..shared.len() - 1 {
        groups[part.partition(&i)].push(i);
    }
    let mut task_shards = Vec::with_capacity(shards);
    let mut tasks = Vec::with_capacity(shards);
    for (s, group) in groups.into_iter().enumerate() {
        if group.is_empty() {
            continue;
        }
        task_shards.push(s);
        let atoms = Arc::clone(&shared);
        tasks.push(move || {
            let sw = Stopwatch::start();
            let mut sp = crate::obs::span("stream.mine_shard");
            let classes = group.len() as u64;
            // One sink + one scratch arena across the whole class group;
            // presized so the first classes don't pay warm-up growth.
            let mut found = PooledSink::with_capacity(group.len() * 8, group.len() * 4);
            let mut scratch = MineScratch::new();
            for i in group {
                found = mine_class(&atoms, i, min_sup, found, &mut scratch);
            }
            sp.arg("shard", s as u64)
                .arg("classes", classes)
                .arg("itemsets", found.len() as u64);
            (found, sw.elapsed())
        });
    }
    let mut runs = Vec::with_capacity(task_shards.len());
    for (s, (found, wall)) in task_shards.into_iter().zip(ctx.inner.pool.run_all(tasks)?) {
        runs.push(ShardRun { shard: s, wall, itemsets: found.len() as u64 });
        found.replay(&mut out);
    }
    Ok((out, runs))
}

/// Where one emission's class mining runs: on the in-process executor
/// pool, or scattered across a connected remote worker ensemble. Both
/// arms deal classes with the same reverse-hash partitioner, so they
/// produce the same itemset multiset over the same atoms.
pub(crate) enum MineTarget<'a> {
    /// Mine on the context pool, dealing classes to this many groups.
    Local {
        /// Class-group count (`1` = one task per class).
        shards: usize,
    },
    /// Scatter-gather onto the remote shard workers.
    Remote(&'a mut RemoteShardSet),
}

/// Mine the equivalence class of prefix atom `i` into `found` (returned
/// so callers can thread one sink across several classes): bounded
/// intersections build the members, then the arena-backed bottom-up
/// search emits every frequent extension. `pub(crate)` because the
/// shard-worker transport mines its class groups through the very same
/// routine — remote and local emissions stay byte-identical.
pub(crate) fn mine_class(
    atoms: &[(Item, TidBitmap, u32)],
    i: usize,
    min_sup: u32,
    mut found: PooledSink,
    scratch: &mut MineScratch,
) -> PooledSink {
    let (item_i, bm_i, _) = &atoms[i];
    let mut members: Vec<(Item, TidBitmap)> = Vec::new();
    let mut buf = TidBitmap::new(0);
    for (item_j, bm_j, _) in &atoms[i + 1..] {
        if bm_i.and_bounded_into(bm_j, min_sup, &mut buf).is_some() {
            members.push((*item_j, std::mem::replace(&mut buf, TidBitmap::new(0))));
        }
    }
    if !members.is_empty() {
        bottom_up_with(scratch, &[*item_i], &members, min_sup, &mut found);
    }
    found
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::Database;

    fn ctx() -> ClusterContext {
        ClusterContext::builder().cores(2).build()
    }

    fn oracle(db: &Database, min_sup: MinSup) -> Vec<Frequent> {
        let mut v = SeqEclat::mine(db, min_sup);
        sort_frequents(&mut v);
        v
    }

    #[test]
    fn tumbling_window_matches_oracle_per_emission() {
        let cfg = StreamConfig::new(WindowSpec::tumbling(2), MinSup::count(2));
        let mut miner = StreamingMiner::new(ctx(), cfg);
        let batches = vec![
            vec![vec![1, 2, 3], vec![1, 2]],
            vec![vec![2, 3], vec![1, 2, 3, 4]],
            vec![vec![1, 4], vec![4, 5]],
            vec![vec![1, 4, 5], vec![1, 5]],
        ];
        let mut emissions = 0;
        for b in batches {
            if let Some(snap) = miner.push_batch(b).unwrap() {
                emissions += 1;
                let want = oracle(&miner.materialize_window(), MinSup::count(2));
                assert_eq!(snap.frequents, want, "emission {emissions}");
                assert_eq!(snap.window_batches, 2);
            }
        }
        assert_eq!(emissions, 2);
    }

    #[test]
    fn sliding_delta_path_reuses_clean_itemsets() {
        // Window of 3 batches sliding by 1. Batches after the first touch
        // only items {8, 9} (plus evictions), so itemsets over {1, 2}
        // must be reused from the cache, never re-mined.
        let cfg = StreamConfig {
            churn_threshold: 1.0,
            ..StreamConfig::new(WindowSpec::sliding(3, 1), MinSup::count(2))
        };
        let mut miner = StreamingMiner::new(ctx(), cfg);
        let mut snaps = Vec::new();
        for b in [
            vec![vec![1, 2], vec![1, 2, 3]], // batch 0
            vec![vec![8, 9]],                // batch 1
            vec![vec![8, 9], vec![8, 9]],    // batch 2
            vec![vec![1, 8]],                // batch 3: evicts batch 0
        ] {
            if let Some(s) = miner.push_batch(b).unwrap() {
                let want = oracle(&miner.materialize_window(), MinSup::count(2));
                assert_eq!(s.frequents, want, "plan {:?}", s.plan);
                snaps.push(s);
            }
        }
        assert_eq!(snaps.len(), 4);
        assert_eq!(snaps[0].plan, MinePlan::FullRemine, "first emission is full");
        // Emission 2: nothing frequent among the dirty {8, 9} yet — the
        // whole result is reused ({1}, {2}, {1, 2}).
        assert_eq!(snaps[1].plan, MinePlan::Delta { remined_atoms: 0, reused_itemsets: 3 });
        // Emission 3: {8, 9} cross min_sup; their sub-lattice is mined
        // fresh while the {1, 2} side is still reused.
        assert_eq!(snaps[2].plan, MinePlan::Delta { remined_atoms: 2, reused_itemsets: 3 });
        assert!(snaps[2].frequents.contains(&Frequent::new(vec![8, 9], 3)));
        // Emission 4: batch 0 evicted — {1}, {2}, {1, 2} fall out (all
        // dirty, no longer frequent), but itemsets containing the clean
        // item 9 survive via the cache.
        assert_eq!(snaps[3].plan, MinePlan::Delta { remined_atoms: 1, reused_itemsets: 2 });
        assert_eq!(
            snaps[3].frequents,
            vec![
                Frequent::new(vec![8], 4),
                Frequent::new(vec![9], 3),
                Frequent::new(vec![8, 9], 3),
            ]
        );
    }

    #[test]
    fn from_scratch_mode_matches_incremental() {
        let spec = WindowSpec::sliding(2, 1);
        let mut inc =
            StreamingMiner::new(ctx(), StreamConfig::new(spec, MinSup::fraction(0.4)));
        let mut scratch = StreamingMiner::new(
            ctx(),
            StreamConfig::new(spec, MinSup::fraction(0.4)).mode(MineMode::FromScratch),
        );
        for b in [
            vec![vec![1, 2], vec![2, 3], vec![1, 2, 3]],
            vec![vec![1, 3], vec![2, 3]],
            vec![vec![1, 2]],
            vec![],
        ] {
            let a = inc.push_batch(b.clone()).unwrap();
            let b = scratch.push_batch(b).unwrap();
            match (a, b) {
                (Some(x), Some(y)) => {
                    assert_eq!(x.frequents, y.frequents);
                    assert_eq!(x.min_sup_count, y.min_sup_count);
                    assert_eq!(y.plan, MinePlan::Rebuild);
                }
                (None, None) => {}
                other => panic!("emission cadence diverged: {other:?}"),
            }
        }
    }

    #[test]
    fn rules_snapshot_is_generated_and_capped() {
        let mut cfg = StreamConfig::new(WindowSpec::tumbling(1), MinSup::count(2));
        cfg.min_conf = 0.5;
        cfg.max_rules = Some(3);
        let mut miner = StreamingMiner::new(ctx(), cfg);
        let snap = miner
            .push_batch(vec![vec![1, 2], vec![1, 2], vec![1, 2, 3], vec![1, 3]])
            .unwrap()
            .expect("tumbling(1) emits every batch");
        assert!(!snap.rules.is_empty());
        assert!(snap.rules.len() <= 3);
        assert!(snap.rules.iter().all(|r| r.confidence >= 0.5));
        for w in snap.rules.windows(2) {
            assert!(w[0].confidence >= w[1].confidence);
        }
        // JSON snapshot is well-formed-ish and carries both sections.
        let json = snap.to_json();
        assert!(json.contains("\"frequents\": ["));
        assert!(json.contains("\"rules\": ["));
        assert!(json.contains("\"plan\": \"full\""));
        // Summary mentions the plan and the batch id.
        assert!(snap.summary().contains("full"));
    }

    #[test]
    fn incremental_window_is_row_free_but_materializes_via_store() {
        // Incremental mode holds each live transaction once (vertically);
        // the window keeps geometry only, yet materialization still
        // reconstructs the exact horizontal contents.
        let mut miner = StreamingMiner::new(
            ctx(),
            StreamConfig::new(WindowSpec::sliding(2, 1), MinSup::count(1)),
        );
        miner.push_batch(vec![vec![3, 1], vec![2]]).unwrap();
        miner.push_batch(vec![vec![1, 2], vec![]]).unwrap();
        miner.push_batch(vec![vec![5]]).unwrap(); // evicts batch 0
        let db = miner.materialize_window();
        assert_eq!(
            db.transactions(),
            &[vec![1, 2], vec![], vec![5]],
            "store-backed reconstruction, normalized rows, empties kept"
        );
        assert_eq!(miner.window_txns(), 3);
    }

    #[test]
    fn empty_stream_and_empty_batches() {
        let mut miner = StreamingMiner::new(
            ctx(),
            StreamConfig::new(WindowSpec::sliding(2, 1), MinSup::count(1)),
        );
        let s1 = miner.push_batch(vec![]).unwrap().unwrap();
        assert!(s1.frequents.is_empty());
        assert_eq!(s1.window_txns, 0);
        let s2 = miner.push_batch(vec![vec![7]]).unwrap().unwrap();
        assert_eq!(s2.frequents, vec![Frequent::new(vec![7], 1)]);
        // Full eviction: two empty batches push the lone transaction out.
        let s3 = miner.push_batch(vec![]).unwrap().unwrap();
        assert_eq!(s3.window_txns, 1);
        let s4 = miner.push_batch(vec![]).unwrap().unwrap();
        assert!(s4.frequents.is_empty());
        assert_eq!(s4.window_txns, 0);
    }

    #[test]
    fn ingest_and_mine_now_compose_to_push_batch() {
        // The split API used by the async service must agree with the
        // one-shot path batch for batch.
        let spec = WindowSpec::sliding(2, 1);
        let mut one_shot =
            StreamingMiner::new(ctx(), StreamConfig::new(spec, MinSup::count(2)));
        let mut split = StreamingMiner::new(ctx(), StreamConfig::new(spec, MinSup::count(2)));
        for b in [
            vec![vec![1, 2], vec![2, 3]],
            vec![vec![1, 2, 3]],
            vec![vec![2, 3], vec![1, 2]],
        ] {
            let want = one_shot.push_batch(b.clone()).unwrap().expect("slide 1 emits");
            assert!(split.ingest(b).unwrap(), "slide 1: every batch is an emission point");
            let got = split.mine_now().unwrap();
            assert_eq!(got.frequents, want.frequents);
            assert_eq!(got.batch_id, want.batch_id);
            assert_eq!(got.plan, want.plan);
        }
    }

    #[test]
    fn mine_now_between_emission_points_reflects_latest_window() {
        // Skip-to-latest: bookkeeping advanced past the cadence point,
        // then a catch-up emission mines the *current* window state and
        // is attributed to the newest batch.
        let mut miner = StreamingMiner::new(
            ctx(),
            StreamConfig::new(WindowSpec::sliding(4, 4), MinSup::count(1)),
        );
        assert!(!miner.ingest(vec![vec![1, 2]]).unwrap());
        assert!(!miner.ingest(vec![vec![2, 3]]).unwrap());
        let snap = miner.mine_now().unwrap();
        assert_eq!(snap.batch_id, 1, "attributed to the newest batch");
        assert_eq!(snap.window_txns, 2);
        let want = oracle(&miner.materialize_window(), MinSup::count(1));
        assert_eq!(snap.frequents, want);
    }

    #[test]
    fn empty_window_short_circuits_churn_fallback() {
        // churn_threshold 0.0 is the most trigger-happy fallback setting;
        // even so, an emptied window (no frequent atoms) must not force a
        // full re-mine — there is no churn ratio to measure.
        let cfg = StreamConfig {
            churn_threshold: 0.0,
            ..StreamConfig::new(WindowSpec::sliding(2, 1), MinSup::count(2))
        };
        let mut miner = StreamingMiner::new(ctx(), cfg);
        let s1 = miner.push_batch(vec![vec![1, 2], vec![1, 2]]).unwrap().unwrap();
        assert_eq!(s1.plan, MinePlan::FullRemine, "first emission is always full");
        // Two empty batches evict everything frequent.
        let s2 = miner.push_batch(vec![]).unwrap().unwrap();
        let s3 = miner.push_batch(vec![]).unwrap().unwrap();
        assert_eq!(s3.window_txns, 0);
        assert!(s3.frequents.is_empty());
        for s in [&s2, &s3] {
            assert!(
                matches!(s.plan, MinePlan::Delta { .. }),
                "empty-window emission must not full-re-mine, got {:?}",
                s.plan
            );
        }
    }

    #[test]
    fn negative_churn_threshold_clamps_to_always_full() {
        // Clamped to 0.0: any dirty frequent atom tips the ratio, so
        // every emission after the first falls back to a full re-mine —
        // loudly-defined behavior instead of a silent sign bug.
        let cfg = StreamConfig::new(WindowSpec::sliding(3, 1), MinSup::count(2))
            .churn_threshold(-7.5);
        assert_eq!(cfg.churn_threshold, 0.0);
        let mut miner = StreamingMiner::new(ctx(), cfg);
        miner.push_batch(vec![vec![1, 2], vec![1, 2]]).unwrap().unwrap();
        let s = miner.push_batch(vec![vec![1, 2]]).unwrap().unwrap();
        assert_eq!(s.plan, MinePlan::FullRemine);
        let over = StreamConfig::new(WindowSpec::tumbling(1), MinSup::count(1))
            .churn_threshold(3.0);
        assert_eq!(over.churn_threshold, 1.0, "clamped from above too");
    }

    #[test]
    #[should_panic(expected = "churn_threshold must be finite")]
    fn nan_churn_threshold_rejected_by_setter() {
        let _ = StreamConfig::new(WindowSpec::tumbling(1), MinSup::count(1))
            .churn_threshold(f64::NAN);
    }

    #[test]
    fn sharded_miner_matches_single_shard_snapshot_for_snapshot() {
        let spec = WindowSpec::sliding(3, 1);
        let min_sup = MinSup::count(2);
        let batches = [
            vec![vec![1, 2, 5], vec![2, 7], vec![1, 2]],
            vec![vec![1, 5, 7], vec![3, 5]],
            vec![],
            vec![vec![2, 3, 5], vec![1, 2, 5]],
            vec![vec![1, 2], vec![2, 5]],
        ];
        let mut one = StreamingMiner::new(ctx(), StreamConfig::new(spec, min_sup));
        for shards in [2usize, 4, 7] {
            let mut many =
                StreamingMiner::new(ctx(), StreamConfig::new(spec, min_sup).shards(shards));
            for b in &batches {
                let a = one.push_batch(b.clone()).unwrap().expect("slide 1 emits");
                let m = many.push_batch(b.clone()).unwrap().expect("slide 1 emits");
                assert_eq!(m.frequents, a.frequents, "{shards} shards");
                assert_eq!(m.plan, a.plan, "{shards} shards: plan diverged");
                assert_eq!(m.min_sup_count, a.min_sup_count);
                assert_eq!(m.window_txns, a.window_txns);
                assert_eq!(m.rules.len(), a.rules.len());
            }
            // Reset the single-shard twin for the next shard count.
            one = StreamingMiner::new(ctx(), StreamConfig::new(spec, min_sup));
            let stats = many.shard_stats();
            assert_eq!(stats.len(), shards);
            let postings: u64 = stats.iter().map(|s| s.postings).sum();
            assert_eq!(postings, 24, "every posting lands on exactly one shard");
        }
    }

    #[test]
    fn shard_stats_track_mining_on_the_single_shard_path() {
        let mut miner = StreamingMiner::new(
            ctx(),
            StreamConfig::new(WindowSpec::tumbling(1), MinSup::count(2)),
        );
        let snap =
            miner.push_batch(vec![vec![1, 2], vec![1, 2], vec![1, 2]]).unwrap().unwrap();
        assert!(snap.frequents.contains(&Frequent::new(vec![1, 2], 3)));
        let stats = miner.shard_stats();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].rows, 3);
        assert_eq!(stats[0].postings, 6);
        assert!(stats[0].mined_itemsets >= 1, "the {{1,2}} class was mined");
    }

    #[test]
    #[should_panic(expected = "at least one shard")]
    fn zero_shards_rejected_by_builder() {
        let _ = StreamConfig::new(WindowSpec::tumbling(1), MinSup::count(1)).shards(0);
    }

    #[test]
    #[should_panic(expected = "at least one shard")]
    fn zero_shards_rejected_by_miner() {
        let cfg = StreamConfig {
            shards: 0,
            ..StreamConfig::new(WindowSpec::tumbling(1), MinSup::count(1))
        };
        let _ = StreamingMiner::new(ctx(), cfg);
    }

    #[test]
    #[should_panic(expected = "churn_threshold must be finite")]
    fn nan_churn_threshold_rejected_by_miner() {
        // Struct-update construction bypasses the setter; the miner's
        // constructor is the backstop.
        let cfg = StreamConfig {
            churn_threshold: f64::NAN,
            ..StreamConfig::new(WindowSpec::tumbling(1), MinSup::count(1))
        };
        let _ = StreamingMiner::new(ctx(), cfg);
    }
}
