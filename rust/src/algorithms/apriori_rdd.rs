//! RDD-Apriori — the Spark-based Apriori baseline the paper compares
//! against ("similar to YAFIM [11]", §5).
//!
//! Level-wise: Phase-1 word-counts the frequent items; each subsequent
//! level generates candidates from the previous level on the driver,
//! broadcasts them in a prefix trie (YAFIM's hash-tree role), counts
//! subsets per partition, `reduceByKey`s the counts, and filters by
//! support. Iterates until no candidates survive.


use crate::engine::ClusterContext;
use crate::error::Result;
use crate::fim::apriori::candidate_gen;
use crate::fim::{CandidateTrie, Database, Frequent, ItemSet, MinSup};

use super::common::transactions_rdd;
use super::{Algorithm, FimResult};

/// The YAFIM-style RDD-Apriori baseline.
#[derive(Debug, Clone, Default)]
pub struct RddApriori;

impl Algorithm for RddApriori {
    fn name(&self) -> &'static str {
        "apriori"
    }

    fn run_on(&self, ctx: &ClusterContext, db: &Database, min_sup: MinSup) -> Result<FimResult> {
        let min_sup = min_sup.to_count(db.len());
        let mut run = FimResult::builder(self.name());
        let par = ctx.default_parallelism();

        let transactions = transactions_rdd(ctx, db, par).cache();

        // Phase-1: frequent items.
        let mut freq_items: Vec<(u32, u32)> = transactions
            .flat_map(|t| t)
            .map(|i| (i, 1u32))
            .reduce_by_key(par, |a, b| a + b)
            .filter(move |(_, c)| *c >= min_sup)
            .collect()?;
        freq_items.sort_unstable();
        let mut out: Vec<Frequent> =
            freq_items.iter().map(|&(i, c)| Frequent::new(vec![i], c)).collect();
        run.phase("phase1");

        // Phase-2: levels k >= 2.
        let mut level: Vec<ItemSet> = freq_items.iter().map(|&(i, _)| vec![i]).collect();
        let mut k = 2usize;
        while !level.is_empty() {
            let candidates = candidate_gen(&level);
            if candidates.is_empty() {
                break;
            }
            // Broadcast the candidate trie (YAFIM broadcasts the hash tree).
            let mut trie = CandidateTrie::new();
            let index: Vec<usize> = candidates.iter().map(|c| trie.insert(c)).collect();
            let n_slots = trie.len();
            let bcast = ctx.broadcast((trie, candidates.clone()));

            let counting = bcast.clone();
            let counts: Vec<(usize, u32)> = transactions
                .map_partitions_with_index(move |_idx, txns| {
                    let (trie, _) = counting.value();
                    let mut local = vec![0u32; n_slots];
                    for t in &txns {
                        trie.count_subsets(t, &mut local);
                    }
                    local
                        .into_iter()
                        .enumerate()
                        .filter(|(_, c)| *c > 0)
                        .collect::<Vec<_>>()
                })
                .reduce_by_key(par, |a, b| a + b)
                .filter(move |(_, c)| *c >= min_sup)
                .collect()?;

            let mut next: Vec<ItemSet> = Vec::new();
            let count_of: std::collections::HashMap<usize, u32> = counts.into_iter().collect();
            for (cand, slot) in candidates.into_iter().zip(index) {
                if let Some(&c) = count_of.get(&slot) {
                    out.push(Frequent::new(cand.clone(), c));
                    next.push(cand);
                }
            }
            next.sort();
            level = next;
            run.phase(&format!("level{k}"));
            k += 1;
        }

        Ok(run.finish(out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::{apriori::apriori, sort_frequents};

    fn demo_db() -> Database {
        Database::from_rows(vec![
            vec![1, 3, 4],
            vec![2, 3, 5],
            vec![1, 2, 3, 5],
            vec![2, 5],
            vec![1, 3, 5],
            vec![2, 3, 5],
        ])
    }

    #[test]
    fn matches_sequential_apriori() {
        let ctx = ClusterContext::builder().cores(2).build();
        let db = demo_db();
        for min_sup in 1..=5 {
            let mut want = apriori(&db, min_sup);
            let mut got =
                RddApriori.run_on(&ctx, &db, MinSup::count(min_sup)).unwrap().frequents;
            sort_frequents(&mut want);
            sort_frequents(&mut got);
            assert_eq!(got, want, "min_sup={min_sup}");
        }
    }

    #[test]
    fn records_level_phases() {
        let ctx = ClusterContext::builder().cores(2).build();
        let r = RddApriori.run_on(&ctx, &demo_db(), MinSup::count(3)).unwrap();
        let names: Vec<&str> = r.phases.iter().map(|p| p.name.as_str()).collect();
        assert!(names.contains(&"phase1"));
        assert!(names.contains(&"level2"));
        assert!(names.contains(&"level3"));
    }

    #[test]
    fn nothing_frequent() {
        let ctx = ClusterContext::builder().cores(2).build();
        let r = RddApriori.run_on(&ctx, &demo_db(), MinSup::count(100)).unwrap();
        assert!(r.is_empty());
    }
}
