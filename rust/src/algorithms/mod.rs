//! The paper's algorithms: five RDD-Eclat variants (EclatV1–V5), the
//! YAFIM-style RDD-Apriori baseline, and sequential oracles — all running
//! on the [`crate::engine`] RDD substrate.
//!
//! | Variant | Phase structure (paper §4) |
//! |---|---|
//! | `EclatV1` | vertical DB via `groupByKey` on the raw transactions; triangular matrix accumulator; equivalence classes on the default `(n−1)` partitioner |
//! | `EclatV2` | + Borgelt transaction filtering (word-count Phase-1, broadcast item trie) |
//! | `EclatV3` | vertical DB accumulated in a shared hashmap accumulator instead of a shuffle |
//! | `EclatV4` | EclatV3 + hash partitioner `v % p` |
//! | `EclatV5` | EclatV3 + reverse-hash partitioner |
//! | `RddApriori` | YAFIM: per-level candidate broadcast + subset-count `reduceByKey` |
//!
//! Public dispatch goes through the [`variant`] façade: [`Variant`] is
//! the name→constructor registry and [`MiningSession`] the run builder;
//! the concrete types below remain available as the low-level escape
//! hatch (and the [`Algorithm`] trait as the extension point).

pub mod apriori_rdd;
pub mod common;
pub mod eclat_v1;
pub mod eclat_v2;
pub mod eclat_v3;
pub mod eclat_v45;
pub mod partitioners;
pub mod seq;
pub mod variant;

use std::sync::Arc;
use std::time::Duration;

use crate::engine::ClusterContext;
use crate::error::{Error, Result};
use crate::fim::{Database, Frequent, Item, MinSup, TriMatrix};
use crate::util::Stopwatch;

pub use apriori_rdd::RddApriori;
pub use eclat_v1::EclatV1;
pub use eclat_v2::EclatV2;
pub use eclat_v3::EclatV3;
pub use eclat_v45::{EclatV4, EclatV5};
pub use seq::{SeqApriori, SeqEclat, SeqEclatDiffset, SeqFpGrowth};
pub use variant::{MiningSession, Variant};

/// One timed phase of an algorithm run.
#[derive(Debug, Clone)]
pub struct Phase {
    /// Phase name as in the paper ("phase1", "phase2", ...).
    pub name: String,
    /// Wall time of the phase.
    pub wall: Duration,
}

/// The output of one mining run: the frequent itemsets plus run metadata
/// used by the experiment harness.
#[derive(Debug, Clone)]
pub struct FimResult {
    /// Which algorithm produced this.
    pub algorithm: String,
    /// All frequent itemsets with supports (unsorted; use
    /// [`crate::fim::sort_frequents`] for canonical order).
    pub frequents: Vec<Frequent>,
    /// Total wall time of the run.
    pub wall: Duration,
    /// Per-phase breakdown.
    pub phases: Vec<Phase>,
    /// Equivalence-class members routed to each partition (the §4.5
    /// workload measure; empty for non-Eclat algorithms).
    pub partition_loads: Vec<usize>,
    /// Fractional reduction of total item occurrences achieved by
    /// transaction filtering (EclatV2+; `None` when not applicable).
    pub filtered_reduction: Option<f64>,
}

impl FimResult {
    /// Start assembling a result through the shared [`FimResultBuilder`]
    /// — the one place run metadata (wall clock, phase laps, partition
    /// loads, filtering reduction) is turned into a `FimResult`, used by
    /// every algorithm in the crate.
    pub fn builder(algorithm: &str) -> FimResultBuilder {
        FimResultBuilder {
            algorithm: algorithm.to_string(),
            sw: Stopwatch::start(),
            phases: Vec::new(),
            partition_loads: Vec::new(),
            filtered_reduction: None,
        }
    }

    /// Does the result contain `items` with exactly `support`? Both
    /// sides are compared in canonical (sorted) order, so a permuted
    /// query like `&[3, 1]` finds the stored `[1, 3]`.
    pub fn contains(&self, items: &[Item], support: u32) -> bool {
        let mut want = items.to_vec();
        want.sort_unstable();
        self.frequents.iter().any(|f| {
            if f.support != support || f.items.len() != want.len() {
                return false;
            }
            if f.items.windows(2).all(|w| w[0] < w[1]) {
                f.items == want
            } else {
                // Defensive: stored itemsets are canonical by
                // construction, but only debug builds assert it.
                let mut have = f.items.clone();
                have.sort_unstable();
                have == want
            }
        })
    }

    /// Number of frequent itemsets found.
    pub fn len(&self) -> usize {
        self.frequents.len()
    }

    /// True when nothing is frequent.
    pub fn is_empty(&self) -> bool {
        self.frequents.is_empty()
    }
}

/// Builder for [`FimResult`]: starts its stopwatch at construction,
/// records phase laps with [`FimResultBuilder::phase`], and stamps the
/// total wall time at [`FimResultBuilder::finish`]. Having every
/// algorithm route through this one assembly point is what keeps
/// cross-variant metadata (phase timing, load capture) consistent for
/// the experiment harness and the [`MiningSession`] façade.
#[derive(Debug)]
pub struct FimResultBuilder {
    algorithm: String,
    sw: Stopwatch,
    phases: Vec<Phase>,
    partition_loads: Vec<usize>,
    filtered_reduction: Option<f64>,
}

impl FimResultBuilder {
    /// Close the current phase: records the lap since the previous
    /// `phase` call (or since construction) under `name`.
    pub fn phase(&mut self, name: &str) {
        self.phases.push(Phase { name: name.to_string(), wall: self.sw.lap() });
    }

    /// Record the per-partition equivalence-class loads (§4.5 measure).
    pub fn partition_loads(&mut self, loads: Vec<usize>) {
        self.partition_loads = loads;
    }

    /// Record the transaction-filtering reduction (EclatV2+).
    pub fn filtered_reduction(&mut self, reduction: f64) {
        self.filtered_reduction = Some(reduction);
    }

    /// Stamp the total wall time and produce the result.
    pub fn finish(self, frequents: Vec<Frequent>) -> FimResult {
        FimResult {
            algorithm: self.algorithm,
            frequents,
            wall: self.sw.elapsed(),
            phases: self.phases,
            partition_loads: self.partition_loads,
            filtered_reduction: self.filtered_reduction,
        }
    }
}

/// A frequent-itemset mining algorithm runnable on a cluster context.
pub trait Algorithm: Send + Sync {
    /// Short name for tables/CSV ("eclatV1", "apriori", ...).
    fn name(&self) -> &'static str;

    /// Mine `db` at `min_sup` on `ctx`.
    fn run_on(&self, ctx: &ClusterContext, db: &Database, min_sup: MinSup) -> Result<FimResult>;
}

/// Strategy for computing the Phase-2 triangular matrix.
#[derive(Clone)]
pub enum CoocStrategy {
    /// The paper's approach: per-partition local matrices merged through a
    /// Spark accumulator.
    Accumulator,
    /// A pluggable provider (the XLA/PJRT AOT-kernel backend lives here;
    /// see `runtime::cooc`), called per partition batch.
    Provider(Arc<dyn TriMatrixProvider>),
}

impl std::fmt::Debug for CoocStrategy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CoocStrategy::Accumulator => write!(f, "Accumulator"),
            CoocStrategy::Provider(_) => write!(f, "Provider(..)"),
        }
    }
}

/// Computes the candidate-2-itemset co-occurrence matrix for a batch of
/// transactions. Implemented natively (loops) and by the PJRT runtime
/// (AOT `cooc` kernel).
pub trait TriMatrixProvider: Send + Sync {
    /// Count all 2-itemset occurrences of `transactions` into a matrix
    /// covering items `0..=max_item`.
    fn compute(&self, transactions: &[Vec<Item>], max_item: Item) -> Result<TriMatrix>;
}

/// Shared knobs of the Eclat variants (the paper's `triMatrixMode` and
/// `p`).
#[derive(Debug, Clone)]
pub struct EclatOptions {
    /// Enable the triangular-matrix optimization (`triMatrixMode`).
    pub tri_matrix: bool,
    /// Number of equivalence-class partitions `p` (V4/V5 only; the paper
    /// uses 10).
    pub partitions: usize,
    /// How Phase-2 computes the matrix.
    pub cooc: CoocStrategy,
}

impl EclatOptions {
    /// Cross-variant sanity checks, run once by [`MiningSession`]
    /// before any algorithm is constructed (direct construction skips
    /// them, preserving the low-level escape hatch).
    pub fn validate(&self) -> Result<()> {
        if self.partitions == 0 {
            return Err(Error::Config(
                "EclatOptions: partitions must be >= 1 (the paper uses p = 10)".into(),
            ));
        }
        Ok(())
    }
}

impl Default for EclatOptions {
    fn default() -> Self {
        EclatOptions { tri_matrix: true, partitions: 10, cooc: CoocStrategy::Accumulator }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contains_canonicalizes_the_query_side() {
        let r = FimResult {
            algorithm: "test".into(),
            frequents: vec![Frequent::new(vec![1, 3, 7], 4), Frequent::new(vec![2], 9)],
            wall: Duration::ZERO,
            phases: Vec::new(),
            partition_loads: Vec::new(),
            filtered_reduction: None,
        };
        // Regression: permuted-but-equal itemsets used to be missed.
        assert!(r.contains(&[1, 3, 7], 4));
        assert!(r.contains(&[7, 1, 3], 4));
        assert!(r.contains(&[3, 7, 1], 4));
        assert!(r.contains(&[2], 9));
        assert!(!r.contains(&[1, 3, 7], 5), "support must match");
        assert!(!r.contains(&[1, 3], 4), "length must match");
        assert!(!r.contains(&[1, 3, 8], 4));
    }

    #[test]
    fn builder_records_phases_and_metadata() {
        let mut b = FimResult::builder("x");
        b.phase("phase1");
        b.phase("phase2");
        b.partition_loads(vec![3, 1]);
        b.filtered_reduction(0.25);
        let r = b.finish(vec![Frequent::new(vec![1], 2)]);
        assert_eq!(r.algorithm, "x");
        let names: Vec<&str> = r.phases.iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, vec!["phase1", "phase2"]);
        let phase_total: Duration = r.phases.iter().map(|p| p.wall).sum();
        assert!(r.wall >= phase_total);
        assert_eq!(r.partition_loads, vec![3, 1]);
        assert_eq!(r.filtered_reduction, Some(0.25));
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn options_validation_rejects_zero_partitions() {
        assert!(EclatOptions::default().validate().is_ok());
        let bad = EclatOptions { partitions: 0, ..Default::default() };
        assert!(bad.validate().is_err());
    }
}
