//! Benchmark dataset generators and catalogue (DESIGN.md system S20).
//!
//! The paper evaluates on seven FIMI/SPMF benchmark datasets (Table 2);
//! with no network access those are regenerated as statistical twins by
//! three generators — Quest-style synthetics, dense fixed-width
//! attribute/value data, and Zipf clickstreams — parameterised to match
//! Table 2 exactly. See DESIGN.md §2.2 for the substitution argument.

pub mod catalog;
pub mod clickstream;
pub mod dense;
pub mod quest;

pub use catalog::{DatasetSpec, TABLE2};
// Re-export the database type at the data layer for API convenience.
pub use crate::fim::transaction::{Database, DbStats};

use crate::error::{Error, Result};

/// Resolve a dataset reference: a Table 2 name (through the generator
/// cache in `data_dir`) or a path to a FIMI-format file.
pub fn resolve(name_or_path: &str, data_dir: &str) -> Result<Database> {
    if let Some(spec) = DatasetSpec::parse(name_or_path) {
        return spec.materialize(data_dir);
    }
    if std::path::Path::new(name_or_path).exists() {
        return Database::parse(&std::fs::read_to_string(name_or_path)?);
    }
    Err(Error::config(format!(
        "unknown dataset {name_or_path:?} (not a Table 2 name, not a file)"
    )))
}

#[cfg(test)]
mod resolve_tests {
    use super::*;

    #[test]
    fn resolves_file_paths() {
        let dir = std::env::temp_dir().join("rdd_eclat_resolve_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("db.dat");
        std::fs::write(&p, "1 2\n2 3\n").unwrap();
        let db = resolve(p.to_str().unwrap(), "unused").unwrap();
        assert_eq!(db.len(), 2);
    }

    #[test]
    fn unknown_name_errors() {
        assert!(resolve("no-such-dataset", "/tmp").is_err());
    }
}
