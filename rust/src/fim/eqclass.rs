//! Prefix-based equivalence classes (§2.1 of the paper).
//!
//! From the vertical database, itemsets sharing a 1-length prefix form an
//! independent sub-lattice that one task can mine alone — the unit of
//! parallelism in every RDD-Eclat variant. Construction follows the
//! paper's Algorithm 4/9: for each frequent item `i` (in ascending-support
//! order), intersect `tidset(i)` with every later item's tidset, skipping
//! pairs the triangular matrix already proves infrequent.

use super::bitmap::TidBitmap;
use super::bottomup::{bottom_up_with, MineScratch, TidRepr};
use super::itemset::{Frequent, Item, Tid};
use super::sink::FrequentSink;
use super::tidset::{Tidset, VerticalDb};
use super::trimatrix::TriMatrix;

/// One equivalence class: `prefix` plus atoms `(item, tidset(prefix ∪
/// item))`, every atom frequent.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct EqClass<R = Tidset> {
    /// The 1-length prefix item.
    pub prefix: Item,
    /// Class atoms in mining order.
    pub members: Vec<(Item, R)>,
}

impl<R: TidRepr> EqClass<R> {
    /// Mine this class with the bottom-up recursion, returning all
    /// frequent itemsets of length ≥ 2 under this prefix. Convenience
    /// wrapper over [`EqClass::mine_with`] with a throwaway arena.
    pub fn mine(&self, min_sup: u32) -> Vec<Frequent> {
        self.mine_with(&mut MineScratch::new(), min_sup)
    }

    /// Mine through a caller-owned arena — the class members are
    /// borrowed, never cloned, and the arena's lane buffers are recycled
    /// across every class mined through it.
    pub fn mine_with(&self, scratch: &mut MineScratch<R>, min_sup: u32) -> Vec<Frequent> {
        let mut out = Vec::new();
        self.mine_into(scratch, min_sup, &mut out);
        out
    }

    /// [`EqClass::mine_with`], emitting into an arbitrary
    /// [`FrequentSink`] instead of materializing a `Vec` — with a
    /// [`super::sink::PooledSink`] the whole class mines without a
    /// single steady-state heap allocation.
    pub fn mine_into<S: FrequentSink + ?Sized>(
        &self,
        scratch: &mut MineScratch<R>,
        min_sup: u32,
        out: &mut S,
    ) {
        bottom_up_with(scratch, &[self.prefix], &self.members, min_sup, out);
    }

    /// Workload proxy used by the partitioner ablation (§4.5): number of
    /// members. A class with `m` members generates `O(m²)` candidate
    /// joins at the next level.
    pub fn weight(&self) -> usize {
        self.members.len()
    }
}

/// Reusable buffers for [`EqClass::mine_auto_with`]: one mining arena per
/// representation plus the local-universe remap scratch (union bitmap,
/// rank directory, recycled remapped-member bitmaps). One `AutoScratch`
/// serves any number of classes; steady-state remap + mining allocates
/// nothing per candidate.
#[derive(Debug)]
pub struct AutoScratch {
    tidset: MineScratch<Tidset>,
    bitmap: MineScratch<TidBitmap>,
    /// Union of member tids over the class span (word buffer reused).
    union: TidBitmap,
    /// Exclusive per-word prefix popcounts of `union` — the rank
    /// directory that makes each tid→local-position lookup O(1).
    ranks: Vec<u32>,
    /// Remapped members of the class currently mined (bitmaps recycled
    /// through `pool` between classes).
    members: Vec<(Item, TidBitmap)>,
    /// Spare member bitmaps from previous classes.
    pool: Vec<TidBitmap>,
}

impl Default for AutoScratch {
    fn default() -> Self {
        AutoScratch {
            tidset: MineScratch::new(),
            bitmap: MineScratch::new(),
            union: TidBitmap::new(0),
            ranks: Vec::new(),
            members: Vec::new(),
            pool: Vec::new(),
        }
    }
}

impl AutoScratch {
    /// Fresh, empty scratch.
    pub fn new() -> AutoScratch {
        AutoScratch::default()
    }
}

impl EqClass<Tidset> {
    /// Mine with an automatically chosen representation (§Perf iterations
    /// 1–2); convenience wrapper over [`EqClass::mine_auto_with`] with a
    /// throwaway scratch.
    pub fn mine_auto(&self, min_sup: u32, universe: usize) -> Vec<Frequent> {
        self.mine_auto_with(&mut AutoScratch::new(), min_sup, universe)
    }

    /// Mine with an automatically chosen representation through a
    /// caller-owned scratch. Every member tidset is a subset of the class
    /// prefix's tidset, so the class is first **remapped onto its local
    /// tid universe** (the union of member tidsets): bitmaps then span
    /// `|union|` bits instead of the full database, collapsing the
    /// AND+popcount sweep from `universe/64` words to `|union|/64`.
    /// Sorted-vector mining remains for classes whose members are nearly
    /// disjoint (many members, tiny tidsets — the sparse BMS regime),
    /// where the merge walk beats even the local bitmap.
    ///
    /// The union + remap is O(total tids): member tids are marked in a
    /// reused span bitmap, a per-word rank directory is built in one
    /// sweep, and each tid's local position is its rank (`prefix popcount
    /// + popcount below the bit`) — replacing the old
    /// concatenate/sort/dedup union and its per-tid binary searches.
    pub fn mine_auto_with(
        &self,
        scratch: &mut AutoScratch,
        min_sup: u32,
        universe: usize,
    ) -> Vec<Frequent> {
        let mut out = Vec::new();
        self.mine_auto_into(scratch, min_sup, universe, &mut out);
        out
    }

    /// [`EqClass::mine_auto_with`], emitting into an arbitrary
    /// [`FrequentSink`] — the representation choice and local-universe
    /// remap are unchanged; only the emission path is pluggable.
    pub fn mine_auto_into<S: FrequentSink + ?Sized>(
        &self,
        scratch: &mut AutoScratch,
        min_sup: u32,
        _universe: usize,
        out: &mut S,
    ) {
        let total: usize = self.members.iter().map(|(_, t)| t.len()).sum();
        if total == 0 {
            bottom_up_with(&mut scratch.tidset, &[self.prefix], &self.members, min_sup, out);
            return;
        }
        // Class tid span [lo, hi): member tidsets are sorted, so the
        // span ends come from first/last elements only.
        let (mut lo, mut hi) = (Tid::MAX, 0);
        for (_, t) in &self.members {
            if let (Some(&first), Some(&last)) = (t.first(), t.last()) {
                lo = lo.min(first);
                hi = hi.max(last + 1);
            }
        }
        scratch.union.reset((hi - lo) as usize);
        for (_, t) in &self.members {
            for &tid in t {
                scratch.union.insert(tid - lo);
            }
        }
        let union_len = scratch.union.count() as usize;
        let words = union_len.div_ceil(64);
        let avg = total / self.members.len();
        if 2 * avg > words {
            // Rank directory: ranks[w] = set bits strictly before word w.
            let union_words = scratch.union.words();
            scratch.ranks.clear();
            scratch.ranks.reserve(union_words.len());
            let mut acc = 0u32;
            for &w in union_words {
                scratch.ranks.push(acc);
                acc += w.count_ones();
            }
            // Remap each member onto union ranks, recycling bitmaps.
            for (item, tids) in &self.members {
                let mut bm = scratch.pool.pop().unwrap_or_else(|| TidBitmap::new(0));
                bm.reset(union_len);
                for &tid in tids {
                    let local = (tid - lo) as usize;
                    let (word, bit) = (local >> 6, local & 63);
                    let below = (union_words[word] & ((1u64 << bit) - 1)).count_ones();
                    bm.insert(scratch.ranks[word] + below);
                }
                scratch.members.push((*item, bm));
            }
            let prefix = [self.prefix];
            bottom_up_with(&mut scratch.bitmap, &prefix, &scratch.members, min_sup, out);
            scratch.pool.extend(scratch.members.drain(..).map(|(_, bm)| bm));
        } else {
            bottom_up_with(&mut scratch.tidset, &[self.prefix], &self.members, min_sup, out);
        }
    }
}

/// Build the 1-length-prefix equivalence classes from the vertical
/// database (the paper's Algorithm 4 lines 1–16 / Algorithm 9).
///
/// * `tri`: when present, pairs with matrix support `< min_sup` are
///   skipped without intersecting (the `triMatrixMode` optimization).
/// * Pairs are intersected and kept only when frequent, so every class
///   member is a frequent 2-itemset atom.
///
/// Classes with zero members are dropped (they produce nothing).
pub fn construct_classes(
    vdb: &VerticalDb,
    min_sup: u32,
    tri: Option<&TriMatrix>,
) -> Vec<EqClass<Tidset>> {
    let n = vdb.items.len();
    let mut classes = Vec::new();
    for i in 0..n.saturating_sub(1) {
        let (item_i, tids_i) = &vdb.items[i];
        let mut members: Vec<(Item, Tidset)> = Vec::new();
        for (item_j, tids_j) in &vdb.items[i + 1..] {
            if let Some(m) = tri {
                if m.support(*item_i, *item_j) < min_sup {
                    continue;
                }
            }
            let tids_ij = super::tidset::intersect(tids_i, tids_j);
            if tids_ij.len() as u32 >= min_sup {
                members.push((*item_j, tids_ij));
            }
        }
        if !members.is_empty() {
            classes.push(EqClass { prefix: *item_i, members });
        }
    }
    classes
}

/// Convert a tidset class to the packed-bitmap representation (the
/// optimized local mining path).
pub fn to_bitmap_class(class: &EqClass<Tidset>, universe: usize) -> EqClass<super::bitmap::TidBitmap> {
    EqClass {
        prefix: class.prefix,
        members: class
            .members
            .iter()
            .map(|(i, t)| (*i, super::bitmap::TidBitmap::from_tids(universe, t.iter().copied())))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::itemset::sort_frequents;
    use crate::fim::transaction::Database;

    fn demo_db() -> Database {
        // 6 transactions over items 1..=5 (Zaki-style example).
        Database::from_rows(vec![
            vec![1, 3, 4],
            vec![2, 3, 5],
            vec![1, 2, 3, 5],
            vec![2, 5],
            vec![1, 3, 5],
            vec![2, 3, 5],
        ])
    }

    #[test]
    fn classes_cover_all_frequent_pairs() {
        let db = demo_db();
        let vdb = VerticalDb::build(&db, 2);
        let classes = construct_classes(&vdb, 2, None);
        // Every member atom is a frequent 2-itemset.
        for c in &classes {
            for (item, tids) in &c.members {
                assert!(tids.len() >= 2, "class {} member {item}", c.prefix);
            }
        }
        // Mining all classes + frequent items = full frequent set; checked
        // against known counts: support({3,5})=4 etc.
        let mut all: Vec<Frequent> = Vec::new();
        for c in &classes {
            all.extend(c.mine(2));
        }
        sort_frequents(&mut all);
        assert!(all.iter().any(|f| f.items == vec![3, 5] && f.support == 4));
        assert!(all.iter().any(|f| f.items == vec![2, 3, 5] && f.support == 3));
        // No duplicates across classes (classes are independent).
        let mut seen = std::collections::HashSet::new();
        for f in &all {
            assert!(seen.insert(f.items.clone()), "duplicate {:?}", f.items);
        }
    }

    #[test]
    fn trimatrix_pruning_is_lossless() {
        let db = demo_db();
        let vdb = VerticalDb::build(&db, 2);
        let mut tri = TriMatrix::new(5);
        for t in db.transactions() {
            tri.update_transaction(t);
        }
        let without = construct_classes(&vdb, 2, None);
        let with = construct_classes(&vdb, 2, Some(&tri));
        assert_eq!(without, with, "matrix pruning must not change classes");
    }

    #[test]
    fn class_weight_counts_members() {
        let db = demo_db();
        let vdb = VerticalDb::build(&db, 2);
        let classes = construct_classes(&vdb, 2, None);
        for c in &classes {
            assert_eq!(c.weight(), c.members.len());
        }
    }

    #[test]
    fn bitmap_class_mines_identically() {
        let db = demo_db();
        let vdb = VerticalDb::build(&db, 2);
        let classes = construct_classes(&vdb, 2, None);
        for c in &classes {
            let mut a = c.mine(2);
            let mut b = to_bitmap_class(c, db.len()).mine(2);
            sort_frequents(&mut a);
            sort_frequents(&mut b);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn auto_scratch_shared_across_classes_matches_fresh_mining() {
        // One AutoScratch mines every class at every threshold; recycled
        // remap/lane buffers must not leak state between classes.
        let db = demo_db();
        let vdb = VerticalDb::build(&db, 1);
        let mut scratch = AutoScratch::new();
        for min_sup in 1..=4 {
            for c in &construct_classes(&vdb, min_sup, None) {
                let mut want = c.mine(min_sup);
                let mut got = c.mine_auto_with(&mut scratch, min_sup, db.len());
                sort_frequents(&mut want);
                sort_frequents(&mut got);
                assert_eq!(got, want, "prefix {} min_sup {min_sup}", c.prefix);
            }
        }
    }

    #[test]
    fn empty_vdb_no_classes() {
        let db = Database::from_rows(vec![vec![1], vec![2]]);
        let vdb = VerticalDb::build(&db, 2);
        assert!(construct_classes(&vdb, 2, None).is_empty());
    }
}
