"""Pallas popcount kernel vs SWAR oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.popcount import intersect_support
from compile.kernels.ref import intersect_support_ref


class TestPopcountFixed:
    def test_disjoint_bitmaps(self):
        a = np.full((4, 8), 0xAAAAAAAA, dtype=np.uint32)
        b = np.full((4, 8), 0x55555555, dtype=np.uint32)
        out = np.asarray(intersect_support(a, b))
        np.testing.assert_array_equal(out, np.zeros(4, dtype=np.int32))

    def test_identical_bitmaps(self):
        a = np.full((3, 4), 0xFFFFFFFF, dtype=np.uint32)
        out = np.asarray(intersect_support(a, a))
        np.testing.assert_array_equal(out, np.full(3, 128, dtype=np.int32))

    def test_known_overlap(self):
        a = np.array([[0b1011, 0b1]], dtype=np.uint32)
        b = np.array([[0b0011, 0b1]], dtype=np.uint32)
        out = np.asarray(intersect_support(a, b))
        assert out.tolist() == [3]  # bits {0,1} + bit {32}

    def test_default_aot_shape(self):
        rng = np.random.default_rng(0)
        a = rng.integers(0, 2**32, (256, 64), dtype=np.uint32)
        b = rng.integers(0, 2**32, (256, 64), dtype=np.uint32)
        out = np.asarray(intersect_support(a, b))
        np.testing.assert_array_equal(out, np.asarray(intersect_support_ref(a, b)))

    def test_gridded_matches_single_block(self):
        rng = np.random.default_rng(3)
        a = rng.integers(0, 2**32, (64, 8), dtype=np.uint32)
        b = rng.integers(0, 2**32, (64, 8), dtype=np.uint32)
        whole = np.asarray(intersect_support(a, b))
        blocked = np.asarray(intersect_support(a, b, block_n=16))
        np.testing.assert_array_equal(whole, blocked)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 128),
    w=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_popcount_matches_ref_sweep(n, w, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2**32, (n, w), dtype=np.uint32)
    b = rng.integers(0, 2**32, (n, w), dtype=np.uint32)
    out = np.asarray(intersect_support(a, b))
    ref = np.asarray(intersect_support_ref(a, b))
    np.testing.assert_array_equal(out, ref)


def test_against_python_sets():
    """Cross-check against Python set semantics on dense tid sets."""
    rng = np.random.default_rng(11)
    universe = 256  # 8 lanes
    rows = 32
    a_sets = [set(rng.choice(universe, rng.integers(0, universe), replace=False).tolist()) for _ in range(rows)]
    b_sets = [set(rng.choice(universe, rng.integers(0, universe), replace=False).tolist()) for _ in range(rows)]

    def pack(s):
        lanes = np.zeros(universe // 32, dtype=np.uint32)
        for tid in s:
            lanes[tid // 32] |= np.uint32(1) << np.uint32(tid % 32)
        return lanes

    a = np.stack([pack(s) for s in a_sets])
    b = np.stack([pack(s) for s in b_sets])
    out = np.asarray(intersect_support(a, b))
    expect = np.array([len(x & y) for x, y in zip(a_sets, b_sets)], dtype=np.int32)
    np.testing.assert_array_equal(out, expect)
