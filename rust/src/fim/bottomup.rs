//! The bottom-up recursive search of Eclat (the paper's Algorithm 1,
//! after Zaki), rebuilt around a zero-allocation arena.
//!
//! Generic over the tidset representation: the paper's sorted-vector
//! tidsets ([`Tidset`]) or packed bitmaps ([`TidBitmap`]) — the
//! performance ablation of DESIGN.md §9. A diffset (dEclat) variant is
//! provided as the paper's "future directions" extension.
//!
//! ## The arena (§Perf iteration 5)
//!
//! The paper's headline claim is that tidset intersection is cheap and
//! iterative — so the constant factors of this inner loop dominate FIM
//! wall time (cf. the data-structure companion study, arXiv:1908.01338).
//! The search therefore never allocates per candidate in steady state:
//!
//! * entry **borrows** the class members (`&[(Item, R)]`) instead of
//!   cloning every tidset up front;
//! * each recursion depth owns one [`MineScratch`] *lane* whose candidate
//!   tidset buffers and child list are recycled across siblings
//!   (pop/truncate instead of alloc/drop);
//! * candidate intersections go through
//!   [`TidRepr::intersect_bounded_into`], which writes into a recycled
//!   buffer **and aborts mid-sweep** once the running count plus an
//!   upper bound on the remainder proves the candidate cannot reach
//!   `min_sup` (remaining-words × 64 for bitmaps, remaining-merge-input
//!   for sorted vectors);
//! * emitted itemsets come from an incrementally maintained **sorted
//!   prefix stack** — one buffer copy per emit, no per-emit sort.
//!
//! ## Sinks and ordering (§API redesign)
//!
//! Emission goes through the [`FrequentSink`] trait rather than a
//! hard-wired `Vec<Frequent>`: the itemset is merged into a reusable
//! buffer and handed to the sink as a borrowed slice, so the *sink*
//! decides whether an emission allocates. `Vec<Frequent>` itself
//! implements the trait (the compatibility default); a
//! [`super::sink::PooledSink`] takes the search to literally zero
//! steady-state allocations — measured, not asserted, by the counting
//! allocator in `benches/fim_micro.rs` (`--features alloc-count`).
//!
//! Candidates are processed **rarest-first** at every level (ascending
//! support, item-id tie-break): the smaller `tids_i` is, the earlier the
//! `count + 64·words_left` / merge-remainder bounds prove a candidate
//! infrequent, and the smaller every child class's tidsets start out.
//! The enumerated itemset *set* is order-invariant; only the emission
//! sequence changes. The pre-arena implementation is kept verbatim in
//! [`reference`] as the parity oracle and the bench baseline — it
//! processes members in the order given.

use super::bitmap::TidBitmap;
use super::itemset::{Frequent, Item};
use super::sink::FrequentSink;
use super::tidset::{
    difference_bounded_into, intersect_bounded_into, intersect_into, Tidset,
};

/// Mining-core instrumentation cells, resolved once (see [`crate::obs`]).
/// Recording is gated on [`crate::obs::enabled`] and batched per
/// [`fill_children`] sweep, so the disabled cost of the inner loop is a
/// couple of local register increments. The [`reference`] oracle is
/// deliberately *not* instrumented.
struct FimObs {
    intersections: &'static crate::obs::Counter,
    differences: &'static crate::obs::Counter,
    abort_intersect: &'static crate::obs::Counter,
    abort_diffset: &'static crate::obs::Counter,
    emits: &'static crate::obs::Counter,
    lane_high_water: &'static crate::obs::Gauge,
}

fn fim_obs() -> &'static FimObs {
    static OBS: std::sync::OnceLock<FimObs> = std::sync::OnceLock::new();
    OBS.get_or_init(|| FimObs {
        intersections: crate::obs::counter("fim.bottomup.intersections"),
        differences: crate::obs::counter("fim.bottomup.differences"),
        abort_intersect: crate::obs::counter("fim.bottomup.early_abort.intersect"),
        abort_diffset: crate::obs::counter("fim.bottomup.early_abort.diffset"),
        emits: crate::obs::counter("fim.bottomup.emits"),
        lane_high_water: crate::obs::gauge("fim.bottomup.lane_high_water"),
    })
}

/// A tidset representation usable by the bottom-up search.
pub trait TidRepr: Clone + Send + Sync + 'static {
    /// Support = number of transactions represented.
    fn support(&self) -> u32;

    /// A fresh empty value — the recyclable scratch buffer the arena
    /// hands to [`TidRepr::intersect_bounded_into`].
    fn empty() -> Self;

    /// Overwrite `out` with `self ∩ other`, reusing its allocation, and
    /// return the intersection size.
    fn intersect_counted_into(&self, other: &Self, out: &mut Self) -> u32;

    /// Like [`TidRepr::intersect_counted_into`], but abort early as soon
    /// as the intersection provably cannot reach `min_sup`. `Some(n)`
    /// guarantees `out` holds the complete intersection and `n ≥
    /// min_sup`; on `None` the contents of `out` are unspecified.
    fn intersect_bounded_into(&self, other: &Self, min_sup: u32, out: &mut Self) -> Option<u32> {
        let n = self.intersect_counted_into(other, out);
        if n >= min_sup {
            Some(n)
        } else {
            None
        }
    }

    /// Allocating convenience: `self ∩ other`.
    fn intersect_with(&self, other: &Self) -> Self {
        let mut out = Self::empty();
        self.intersect_counted_into(other, &mut out);
        out
    }

    /// Allocating convenience: fused intersection + support count.
    fn intersect_counted(&self, other: &Self) -> (Self, u32) {
        let mut out = Self::empty();
        let n = self.intersect_counted_into(other, &mut out);
        (out, n)
    }
}

impl TidRepr for Tidset {
    fn support(&self) -> u32 {
        self.len() as u32
    }
    fn empty() -> Self {
        Vec::new()
    }
    fn intersect_counted_into(&self, other: &Self, out: &mut Self) -> u32 {
        intersect_into(self, other, out);
        out.len() as u32
    }
    fn intersect_bounded_into(&self, other: &Self, min_sup: u32, out: &mut Self) -> Option<u32> {
        intersect_bounded_into(self, other, min_sup, out)
    }
}

impl TidRepr for TidBitmap {
    fn support(&self) -> u32 {
        self.count()
    }
    fn empty() -> Self {
        TidBitmap::new(0)
    }
    fn intersect_counted_into(&self, other: &Self, out: &mut Self) -> u32 {
        self.and_counted_into(other, out)
    }
    fn intersect_bounded_into(&self, other: &Self, min_sup: u32, out: &mut Self) -> Option<u32> {
        self.and_bounded_into(other, min_sup, out)
    }
}

/// One recursion depth's recyclable storage: the live candidate list plus
/// a pool of spare tidset buffers reclaimed from pruned candidates and
/// previous siblings at this depth.
#[derive(Debug)]
struct Lane<R> {
    /// `(item, tidset, support)` of the class currently mined here.
    entries: Vec<(Item, R, u32)>,
    /// Spare buffers, recycled instead of dropped.
    pool: Vec<R>,
}

impl<R> Default for Lane<R> {
    fn default() -> Self {
        Lane { entries: Vec::new(), pool: Vec::new() }
    }
}

impl<R> Lane<R> {
    /// Move every live entry's buffer back to the pool, emptying the
    /// entry list for the next sibling's candidates.
    fn recycle(&mut self) {
        self.pool.extend(self.entries.drain(..).map(|(_, r, _)| r));
    }

    /// Rarest-first mining order for the filled entries: ascending
    /// support, item-id tie-break — the single definition every path
    /// (tidset, bitmap, diffset) sorts by.
    fn sort_rarest_first(&mut self) {
        self.entries.sort_unstable_by(|x, y| (x.2, x.0).cmp(&(y.2, y.0)));
    }
}

impl<R: TidRepr> Lane<R> {
    /// A buffer to intersect into: pooled if available, fresh otherwise
    /// (fresh only until the arena warms up to this class's fan-out).
    fn grab(&mut self) -> R {
        self.pool.pop().unwrap_or_else(R::empty)
    }
}

/// The reusable mining arena: depth-indexed candidate lanes plus the
/// incrementally sorted prefix stack. One `MineScratch` serves any number
/// of [`bottom_up_with`] / [`bottom_up_diffset_with`] calls; buffers grow
/// to the high-water mark of the classes mined through it and are then
/// reused, so per-candidate steady-state allocations drop to zero.
#[derive(Debug)]
pub struct MineScratch<R> {
    lanes: Vec<Lane<R>>,
    /// The current prefix itemset, kept **sorted by item id** (mining
    /// order is ascending support, so this is not insertion order).
    prefix: Vec<Item>,
    /// Reused merge buffer for emitted itemsets (prefix ∪ {item}); the
    /// sink copies it out if it keeps emissions.
    emit_buf: Vec<Item>,
    /// Entry-level mining order: `(support, member index)` sorted
    /// ascending so the rarest atom is expanded first.
    order: Vec<(u32, u32)>,
}

impl<R> Default for MineScratch<R> {
    fn default() -> Self {
        MineScratch {
            lanes: Vec::new(),
            prefix: Vec::new(),
            emit_buf: Vec::new(),
            order: Vec::new(),
        }
    }
}

impl<R> MineScratch<R> {
    /// Fresh, empty arena.
    pub fn new() -> MineScratch<R> {
        MineScratch::default()
    }

    /// Detach the lane for `depth` so the caller can fill it while the
    /// rest of the arena recurses deeper (returned via `put_lane`).
    fn take_lane(&mut self, depth: usize) -> Lane<R> {
        while self.lanes.len() <= depth {
            self.lanes.push(Lane::default());
        }
        if crate::obs::enabled() {
            fim_obs().lane_high_water.set(self.lanes.len() as i64);
        }
        std::mem::take(&mut self.lanes[depth])
    }

    /// Re-attach a lane taken with `take_lane`, keeping its buffers.
    fn put_lane(&mut self, depth: usize, lane: Lane<R>) {
        self.lanes[depth] = lane;
    }

    /// Install the entry prefix (sorted once per class, not per emit).
    fn begin_prefix(&mut self, prefix: &[Item]) {
        self.prefix.clear();
        self.prefix.extend_from_slice(prefix);
        self.prefix.sort_unstable();
        debug_assert!(self.prefix.windows(2).all(|w| w[0] < w[1]), "duplicate prefix items");
    }

    /// Descend: insert `item` at its sorted position (O(|prefix|) move,
    /// and prefixes are short).
    fn push_prefix(&mut self, item: Item) {
        debug_assert!(!self.prefix.contains(&item), "item {item} already in prefix");
        let pos = self.prefix.binary_search(&item).unwrap_or_else(|p| p);
        self.prefix.insert(pos, item);
    }

    /// Return from a descent: remove the item pushed last for this node.
    fn pop_prefix(&mut self, item: Item) {
        let pos = self.prefix.binary_search(&item).expect("pushed item present");
        self.prefix.remove(pos);
    }

    /// Emit `prefix ∪ {item}`: one merge-copy of the already-sorted
    /// prefix into the reused emission buffer, no sort, no allocation —
    /// whether the emission allocates is the sink's decision.
    fn emit<S: FrequentSink + ?Sized>(&mut self, item: Item, support: u32, out: &mut S) {
        let pos = self.prefix.binary_search(&item).unwrap_or_else(|p| p);
        self.emit_buf.clear();
        self.emit_buf.extend_from_slice(&self.prefix[..pos]);
        self.emit_buf.push(item);
        self.emit_buf.extend_from_slice(&self.prefix[pos..]);
        if crate::obs::enabled() {
            fim_obs().emits.incr(1);
        }
        out.emit(&self.emit_buf, support);
    }
}

/// Fill `lane.entries` with the frequent children of `tids_i` × `rest`,
/// recycling the lane's buffers; infrequent candidates abort mid-sweep
/// and return their buffer to the pool. Survivors are sorted
/// rarest-first (ascending support, item-id tie-break) so the next
/// level's bounded intersections face the tightest min_sup gap first.
fn fill_children<'a, R: TidRepr>(
    lane: &mut Lane<R>,
    tids_i: &R,
    rest: impl Iterator<Item = (Item, &'a R)>,
    min_sup: u32,
) {
    lane.recycle();
    let mut attempted = 0u64;
    let mut aborted = 0u64;
    for (item_j, tids_j) in rest {
        attempted += 1;
        let mut buf = lane.grab();
        match tids_i.intersect_bounded_into(tids_j, min_sup, &mut buf) {
            Some(n) => lane.entries.push((item_j, buf, n)),
            None => {
                aborted += 1;
                lane.pool.push(buf);
            }
        }
    }
    lane.sort_rarest_first();
    if crate::obs::enabled() {
        let o = fim_obs();
        o.intersections.incr(attempted);
        o.abort_intersect.incr(aborted);
    }
}

/// Bottom-Up(EC) — Algorithm 1. `prefix` is the class prefix itemset,
/// `members` the class atoms: `(last item, tidset(prefix ∪ item))`, each
/// already frequent. Emits every member itemset into `out` and recurses
/// into the next-level classes, expanding members rarest-first.
///
/// Convenience entry that brings its own arena; loops mining many classes
/// should hold a [`MineScratch`] and call [`bottom_up_with`] instead.
pub fn bottom_up<R: TidRepr, S: FrequentSink + ?Sized>(
    prefix: &[Item],
    members: &[(Item, R)],
    min_sup: u32,
    out: &mut S,
) {
    let mut scratch = MineScratch::new();
    bottom_up_with(&mut scratch, prefix, members, min_sup, out);
}

/// [`bottom_up`] through a caller-owned arena. Members are borrowed for
/// the whole search — nothing is cloned; each atom's support is counted
/// exactly once here and carried alongside the recursion's candidate
/// tidsets thereafter. Entry members are visited through a sorted index
/// permutation (rarest-first), not moved.
pub fn bottom_up_with<R: TidRepr, S: FrequentSink + ?Sized>(
    scratch: &mut MineScratch<R>,
    prefix: &[Item],
    members: &[(Item, R)],
    min_sup: u32,
    out: &mut S,
) {
    scratch.begin_prefix(prefix);
    scratch.order.clear();
    for (idx, (item, tids)) in members.iter().enumerate() {
        let support = tids.support();
        scratch.emit(*item, support, out);
        scratch.order.push((support, idx as u32));
    }
    if members.len() < 2 {
        return;
    }
    let mut order = std::mem::take(&mut scratch.order);
    order.sort_unstable_by_key(|&(support, idx)| (support, members[idx as usize].0));
    for a in 0..order.len() - 1 {
        let (item_i, tids_i) = &members[order[a].1 as usize];
        let mut lane = scratch.take_lane(0);
        let rest = order[a + 1..].iter().map(|&(_, j)| {
            let (item_j, tids_j) = &members[j as usize];
            (*item_j, tids_j)
        });
        fill_children(&mut lane, tids_i, rest, min_sup);
        if !lane.entries.is_empty() {
            scratch.push_prefix(*item_i);
            mine_level(scratch, 1, &lane.entries, min_sup, out);
            scratch.pop_prefix(*item_i);
        }
        scratch.put_lane(0, lane);
    }
    scratch.order = order;
}

/// The recursion below the entry level: members live in the parent's
/// detached lane (already sorted rarest-first by [`fill_children`]),
/// children are built in this depth's lane.
fn mine_level<R: TidRepr, S: FrequentSink + ?Sized>(
    scratch: &mut MineScratch<R>,
    depth: usize,
    members: &[(Item, R, u32)],
    min_sup: u32,
    out: &mut S,
) {
    for (item, _, support) in members {
        scratch.emit(*item, *support, out);
    }
    if members.len() < 2 {
        return;
    }
    for i in 0..members.len() - 1 {
        let (item_i, tids_i, _) = &members[i];
        let mut lane = scratch.take_lane(depth);
        fill_children(&mut lane, tids_i, members[i + 1..].iter().map(|(j, t, _)| (*j, t)), min_sup);
        if !lane.entries.is_empty() {
            scratch.push_prefix(*item_i);
            mine_level(scratch, depth + 1, &lane.entries, min_sup, out);
            scratch.pop_prefix(*item_i);
        }
        scratch.put_lane(depth, lane);
    }
}

/// dEclat: the diffset-based bottom-up search (Zaki's follow-up — the
/// paper's related work cites it via Peclat's mixsets; here it is the
/// ablation extension). Entry takes *tidsets*; the first join converts to
/// diffsets (`d(ab) = t(a) − t(b)`, `σ(ab) = σ(a) − |d(ab)|`), deeper
/// levels stay in diffset space (`d(Pab) = d(Pb) − d(Pa)`).
///
/// Convenience entry that brings its own arena; see
/// [`bottom_up_diffset_with`].
pub fn bottom_up_diffset<S: FrequentSink + ?Sized>(
    prefix: &[Item],
    members: &[(Item, Tidset)],
    min_sup: u32,
    out: &mut S,
) {
    let mut scratch = MineScratch::new();
    bottom_up_diffset_with(&mut scratch, prefix, members, min_sup, out);
}

/// [`bottom_up_diffset`] through a caller-owned arena. Diffsets get the
/// same treatment as tidsets: borrowed entry members, recycled per-depth
/// lanes, rarest-first expansion (a rarer parent has the smaller abort
/// budget, so bounded differences give up sooner), and bounded
/// differences — a difference aborts once it exceeds `σ(parent) −
/// min_sup` elements, the point at which the candidate's support
/// `σ(parent) − |diffset|` can no longer reach `min_sup`. The identities
/// `d(ab) = t(a) − t(b)` and `d(Pab) = d(Pb) − d(Pa)` hold for *any*
/// pairing order, so the reordering is lossless here too.
pub fn bottom_up_diffset_with<S: FrequentSink + ?Sized>(
    scratch: &mut MineScratch<Tidset>,
    prefix: &[Item],
    members: &[(Item, Tidset)],
    min_sup: u32,
    out: &mut S,
) {
    scratch.begin_prefix(prefix);
    scratch.order.clear();
    for (idx, (item, tids)) in members.iter().enumerate() {
        let support = tids.len() as u32;
        scratch.emit(*item, support, out);
        scratch.order.push((support, idx as u32));
    }
    if members.len() < 2 {
        return;
    }
    let mut order = std::mem::take(&mut scratch.order);
    order.sort_unstable_by_key(|&(support, idx)| (support, members[idx as usize].0));
    for a in 0..order.len() - 1 {
        let (sup_i, idx_i) = order[a];
        let (item_i, tids_i) = &members[idx_i as usize];
        let budget = sup_i.saturating_sub(min_sup) as usize;
        let mut lane = scratch.take_lane(0);
        lane.recycle();
        let mut attempted = 0u64;
        let mut aborted = 0u64;
        for &(_, j) in &order[a + 1..] {
            let (item_j, tids_j) = &members[j as usize];
            attempted += 1;
            let mut buf = lane.grab();
            // d(ab) = t(a) − t(b); σ(ab) = σ(a) − |d(ab)|.
            match difference_bounded_into(tids_i, tids_j, budget, &mut buf) {
                Some(d) if sup_i - d >= min_sup => lane.entries.push((*item_j, buf, sup_i - d)),
                Some(_) => lane.pool.push(buf),
                None => {
                    aborted += 1;
                    lane.pool.push(buf);
                }
            }
        }
        lane.sort_rarest_first();
        if crate::obs::enabled() {
            let o = fim_obs();
            o.differences.incr(attempted);
            o.abort_diffset.incr(aborted);
        }
        if !lane.entries.is_empty() {
            scratch.push_prefix(*item_i);
            diffset_level(scratch, 1, &lane.entries, min_sup, out);
            scratch.pop_prefix(*item_i);
        }
        scratch.put_lane(0, lane);
    }
    scratch.order = order;
}

fn diffset_level<S: FrequentSink + ?Sized>(
    scratch: &mut MineScratch<Tidset>,
    depth: usize,
    members: &[(Item, Tidset, u32)],
    min_sup: u32,
    out: &mut S,
) {
    for (item, _, support) in members {
        scratch.emit(*item, *support, out);
    }
    if members.len() < 2 {
        return;
    }
    for i in 0..members.len() - 1 {
        let (item_i, diff_i, sup_i) = &members[i];
        let budget = sup_i.saturating_sub(min_sup) as usize;
        let mut lane = scratch.take_lane(depth);
        lane.recycle();
        let mut attempted = 0u64;
        let mut aborted = 0u64;
        for (item_j, diff_j, _) in &members[i + 1..] {
            attempted += 1;
            let mut buf = lane.grab();
            // d(Pab) = d(Pb) − d(Pa); σ(Pab) = σ(Pa) − |d(Pab)|.
            match difference_bounded_into(diff_j, diff_i, budget, &mut buf) {
                Some(d) if sup_i - d >= min_sup => lane.entries.push((*item_j, buf, sup_i - d)),
                Some(_) => lane.pool.push(buf),
                None => {
                    aborted += 1;
                    lane.pool.push(buf);
                }
            }
        }
        lane.sort_rarest_first();
        if crate::obs::enabled() {
            let o = fim_obs();
            o.differences.incr(attempted);
            o.abort_diffset.incr(aborted);
        }
        if !lane.entries.is_empty() {
            scratch.push_prefix(*item_i);
            diffset_level(scratch, depth + 1, &lane.entries, min_sup, out);
            scratch.pop_prefix(*item_i);
        }
        scratch.put_lane(depth, lane);
    }
}

/// The pre-arena implementation, kept verbatim: clones every member on
/// entry, heap-allocates each candidate tidset and child list, and sorts
/// a fresh prefix `Vec` per emit. It exists as (a) the parity oracle the
/// property tests pit the arena miner against and (b) the baseline side
/// of the `bottomup/*_cloning` benches in `fim_micro` — do not "optimize"
/// it.
pub mod reference {
    use super::super::tidset::difference;
    use super::{Frequent, Item, TidRepr, Tidset};

    fn emit(prefix: &[Item], item: Item, support: u32, out: &mut Vec<Frequent>) {
        let mut items = Vec::with_capacity(prefix.len() + 1);
        items.extend_from_slice(prefix);
        items.push(item);
        items.sort_unstable();
        out.push(Frequent::new(items, support));
    }

    /// Cloning Bottom-Up(EC): the shape every RDD variant funneled into
    /// before the arena refactor.
    pub fn bottom_up<R: TidRepr>(
        prefix: &[Item],
        members: &[(Item, R)],
        min_sup: u32,
        out: &mut Vec<Frequent>,
    ) {
        let counted: Vec<(Item, R, u32)> =
            members.iter().map(|(i, t)| (*i, t.clone(), t.support())).collect();
        bottom_up_counted(prefix, &counted, min_sup, out);
    }

    fn bottom_up_counted<R: TidRepr>(
        prefix: &[Item],
        members: &[(Item, R, u32)],
        min_sup: u32,
        out: &mut Vec<Frequent>,
    ) {
        for (item, _, support) in members {
            emit(prefix, *item, *support, out);
        }
        if members.len() < 2 {
            return;
        }
        let mut child_prefix = Vec::with_capacity(prefix.len() + 1);
        for i in 0..members.len() - 1 {
            let (item_i, tids_i, _) = &members[i];
            let mut next: Vec<(Item, R, u32)> = Vec::new();
            for (item_j, tids_j, _) in &members[i + 1..] {
                let (tids_ij, count) = tids_i.intersect_counted(tids_j);
                if count >= min_sup {
                    next.push((*item_j, tids_ij, count));
                }
            }
            if !next.is_empty() {
                child_prefix.clear();
                child_prefix.extend_from_slice(prefix);
                child_prefix.push(*item_i);
                bottom_up_counted(&child_prefix, &next, min_sup, out);
            }
        }
    }

    /// Cloning dEclat.
    pub fn bottom_up_diffset(
        prefix: &[Item],
        members: &[(Item, Tidset)],
        min_sup: u32,
        out: &mut Vec<Frequent>,
    ) {
        for (item, tids) in members {
            emit(prefix, *item, tids.len() as u32, out);
        }
        if members.len() < 2 {
            return;
        }
        for i in 0..members.len() - 1 {
            let (item_i, tids_i) = &members[i];
            let sup_i = tids_i.len() as u32;
            let mut next: Vec<(Item, Tidset, u32)> = Vec::new();
            for (item_j, tids_j) in &members[i + 1..] {
                let diff = difference(tids_i, tids_j);
                let support = sup_i - diff.len() as u32;
                if support >= min_sup {
                    next.push((*item_j, diff, support));
                }
            }
            if !next.is_empty() {
                let mut child_prefix = prefix.to_vec();
                child_prefix.push(*item_i);
                diffset_recurse(&child_prefix, &next, min_sup, out);
            }
        }
    }

    fn diffset_recurse(
        prefix: &[Item],
        members: &[(Item, Tidset, u32)],
        min_sup: u32,
        out: &mut Vec<Frequent>,
    ) {
        for (item, _, support) in members {
            emit(prefix, *item, *support, out);
        }
        if members.len() < 2 {
            return;
        }
        for i in 0..members.len() - 1 {
            let (item_i, diff_i, sup_i) = &members[i];
            let mut next: Vec<(Item, Tidset, u32)> = Vec::new();
            for (item_j, diff_j, _) in &members[i + 1..] {
                let diff = difference(diff_j, diff_i);
                let support = sup_i - diff.len() as u32;
                if support >= min_sup {
                    next.push((*item_j, diff, support));
                }
            }
            if !next.is_empty() {
                let mut child_prefix = prefix.to_vec();
                child_prefix.push(*item_i);
                diffset_recurse(&child_prefix, &next, min_sup, out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::itemset::sort_frequents;

    /// Zaki's running example: items 1..5 over 6 transactions.
    fn example_members() -> Vec<(Item, Tidset)> {
        // t(1)={0,2,3}, t(2)={1,2,3,4,5}, t(3)={0,1,2,3,4,5}
        vec![
            (1, vec![0, 2, 3]),
            (2, vec![1, 2, 3, 4, 5]),
            (3, vec![0, 1, 2, 3, 4, 5]),
        ]
    }

    #[test]
    fn bottom_up_enumerates_class() {
        let mut out = Vec::new();
        bottom_up::<Tidset, _>(&[], &example_members(), 2, &mut out);
        sort_frequents(&mut out);
        let got: Vec<(Vec<Item>, u32)> =
            out.into_iter().map(|f| (f.items, f.support)).collect();
        assert_eq!(
            got,
            vec![
                (vec![1], 3),
                (vec![2], 5),
                (vec![3], 6),
                (vec![1, 2], 2),
                (vec![1, 3], 3),
                (vec![2, 3], 5),
                (vec![1, 2, 3], 2),
            ]
        );
    }

    #[test]
    fn min_sup_prunes_recursion() {
        let mut out = Vec::new();
        bottom_up::<Tidset, _>(&[], &example_members(), 3, &mut out);
        assert!(out.iter().all(|f| f.support >= 3));
        assert!(!out.iter().any(|f| f.items == vec![1, 2]));
        assert!(!out.iter().any(|f| f.items == vec![1, 2, 3]));
        assert!(out.iter().any(|f| f.items == vec![1, 3] && f.support == 3));
    }

    #[test]
    fn bitmap_repr_agrees_with_tidset_repr() {
        let members = example_members();
        let bitmap_members: Vec<(Item, TidBitmap)> = members
            .iter()
            .map(|(i, t)| (*i, TidBitmap::from_tids(6, t.iter().copied())))
            .collect();
        for min_sup in 1..=6 {
            let mut a = Vec::new();
            bottom_up::<Tidset, _>(&[], &members, min_sup, &mut a);
            let mut b = Vec::new();
            bottom_up::<TidBitmap, _>(&[], &bitmap_members, min_sup, &mut b);
            sort_frequents(&mut a);
            sort_frequents(&mut b);
            assert_eq!(a, b, "min_sup={min_sup}");
        }
    }

    #[test]
    fn diffset_variant_agrees() {
        let members = example_members();
        for min_sup in 1..=6 {
            let mut a = Vec::new();
            bottom_up::<Tidset, _>(&[], &members, min_sup, &mut a);
            let mut b = Vec::new();
            bottom_up_diffset(&[], &members, min_sup, &mut b);
            sort_frequents(&mut a);
            sort_frequents(&mut b);
            assert_eq!(a, b, "min_sup={min_sup}");
        }
    }

    #[test]
    fn emit_sorts_itemsets_with_unsorted_mining_order() {
        // Mining order by ascending support can put a larger item id first;
        // the sorted prefix stack must still emit canonical itemsets.
        let members: Vec<(Item, Tidset)> = vec![(9, vec![0, 1]), (2, vec![0, 1, 2])];
        let mut out = Vec::new();
        bottom_up::<Tidset, _>(&[], &members, 2, &mut out);
        assert!(out.iter().any(|f| f.items == vec![2, 9] && f.support == 2));
    }

    #[test]
    fn unsorted_entry_prefix_is_canonicalized() {
        // Entry prefixes arrive in mining order too; begin_prefix sorts
        // once so every emit stays a cheap merge.
        let members: Vec<(Item, Tidset)> = vec![(3, vec![0, 1]), (1, vec![0, 1])];
        let mut out = Vec::new();
        bottom_up::<Tidset, _>(&[7, 5], &members, 2, &mut out);
        let mut got: Vec<Vec<Item>> = out.into_iter().map(|f| f.items).collect();
        got.sort();
        assert_eq!(got, vec![vec![1, 3, 5, 7], vec![1, 5, 7], vec![3, 5, 7]]);
    }

    #[test]
    fn empty_and_singleton_members() {
        let mut out = Vec::new();
        bottom_up::<Tidset, _>(&[], &[], 1, &mut out);
        assert!(out.is_empty());
        bottom_up::<Tidset, _>(&[5], &[(7, vec![0])], 1, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].items, vec![5, 7]);
    }

    #[test]
    fn scratch_miner_matches_reference_on_random_databases() {
        // The pre-refactor implementation (kept verbatim in `reference`)
        // is the oracle: across random QUEST and clickstream databases,
        // a min_sup sweep, and all three representations (sorted-vector
        // tidsets, packed bitmaps, diffsets) — plus the auto-remap path —
        // the arena miner must produce identical itemsets. All scratches
        // are shared across every class/db/min_sup so recycled buffers
        // get maximal opportunity to leak stale state.
        use crate::data::clickstream::{self, ClickParams};
        use crate::data::quest::{self, QuestParams};
        use crate::fim::eqclass::{construct_classes, to_bitmap_class, AutoScratch};
        use crate::fim::tidset::VerticalDb;

        let click = ClickParams {
            sessions: 250,
            items: 60,
            avg_len: 5.0,
            skew: 1.1,
            locality: 0.5,
            radius: 6,
            drift: 0.0,
        };
        let dbs = vec![
            ("quest_dense", quest::generate(&QuestParams::tid(10.0, 4.0, 200, 25), 7)),
            ("quest_sparse", quest::generate(&QuestParams::tid(6.0, 3.0, 300, 60), 11)),
            ("clickstream", clickstream::generate(&click, 3)),
        ];
        let mut tid_scratch = MineScratch::<Tidset>::new();
        let mut bm_scratch = MineScratch::<TidBitmap>::new();
        let mut diff_scratch = MineScratch::<Tidset>::new();
        let mut auto_scratch = AutoScratch::new();
        for (tag, db) in &dbs {
            for min_sup in [2u32, 3, 5, 8, 13] {
                let vdb = VerticalDb::build(db, min_sup);
                // Diffset driver over the whole level-1 class.
                let mut want = Vec::new();
                reference::bottom_up_diffset(&[], &vdb.items, min_sup, &mut want);
                let mut got = Vec::new();
                bottom_up_diffset_with(&mut diff_scratch, &[], &vdb.items, min_sup, &mut got);
                sort_frequents(&mut want);
                sort_frequents(&mut got);
                assert_eq!(got, want, "{tag} diffset min_sup={min_sup}");
                // Per-class: tidset, bitmap, and auto-remap arenas.
                for class in construct_classes(&vdb, min_sup, None) {
                    let mut want = Vec::new();
                    reference::bottom_up::<Tidset>(
                        &[class.prefix],
                        &class.members,
                        min_sup,
                        &mut want,
                    );
                    sort_frequents(&mut want);

                    let mut got = class.mine_with(&mut tid_scratch, min_sup);
                    sort_frequents(&mut got);
                    assert_eq!(got, want, "{tag} tidset prefix={} min_sup={min_sup}", class.prefix);

                    let bm_class = to_bitmap_class(&class, db.len());
                    let mut got = bm_class.mine_with(&mut bm_scratch, min_sup);
                    sort_frequents(&mut got);
                    assert_eq!(got, want, "{tag} bitmap prefix={} min_sup={min_sup}", class.prefix);

                    let mut got = class.mine_auto_with(&mut auto_scratch, min_sup, db.len());
                    sort_frequents(&mut got);
                    assert_eq!(got, want, "{tag} auto prefix={} min_sup={min_sup}", class.prefix);
                }
            }
        }
    }

    #[test]
    fn rarest_first_reorder_matches_unordered_reference() {
        // Members handed over in descending-support (worst-case) and
        // shuffled orders: the arena miner re-sorts rarest-first
        // internally, the reference processes as given — the emitted
        // *sets* must be identical for tidsets, bitmaps and diffsets.
        use crate::data::quest::{self, QuestParams};
        use crate::fim::tidset::VerticalDb;

        let db = quest::generate(&QuestParams::tid(8.0, 4.0, 150, 30), 5);
        for min_sup in [2u32, 4, 7] {
            let vdb = VerticalDb::build(&db, min_sup);
            let mut orders: Vec<Vec<(Item, Tidset)>> = Vec::new();
            let mut desc = vdb.items.clone();
            desc.sort_by(|a, b| b.1.len().cmp(&a.1.len()));
            orders.push(desc);
            let mut shuffled = vdb.items.clone();
            if shuffled.len() > 2 {
                shuffled.swap(0, shuffled.len() / 2);
                shuffled.reverse();
            }
            orders.push(shuffled);
            for members in &orders {
                let mut want = Vec::new();
                reference::bottom_up::<Tidset>(&[], members, min_sup, &mut want);
                sort_frequents(&mut want);

                let mut got = Vec::new();
                bottom_up::<Tidset, _>(&[], members, min_sup, &mut got);
                sort_frequents(&mut got);
                assert_eq!(got, want, "tidset min_sup={min_sup}");

                let bitmap_members: Vec<(Item, TidBitmap)> = members
                    .iter()
                    .map(|(i, t)| (*i, TidBitmap::from_tids(db.len(), t.iter().copied())))
                    .collect();
                let mut got = Vec::new();
                bottom_up::<TidBitmap, _>(&[], &bitmap_members, min_sup, &mut got);
                sort_frequents(&mut got);
                assert_eq!(got, want, "bitmap min_sup={min_sup}");

                let mut got = Vec::new();
                bottom_up_diffset(&[], members, min_sup, &mut got);
                sort_frequents(&mut got);
                assert_eq!(got, want, "diffset min_sup={min_sup}");
            }
        }
    }

    #[test]
    fn pooled_and_topk_sinks_agree_with_vec_sink() {
        use crate::fim::sink::{CountSink, PooledSink, TopKSink};

        let members = example_members();
        let mut scratch = MineScratch::<Tidset>::new();
        for min_sup in 1..=4 {
            let mut collected: Vec<Frequent> = Vec::new();
            bottom_up_with(&mut scratch, &[], &members, min_sup, &mut collected);

            let mut pooled = PooledSink::new();
            bottom_up_with(&mut scratch, &[], &members, min_sup, &mut pooled);
            assert_eq!(pooled.decode(), collected, "min_sup={min_sup}");

            let mut count = CountSink::new();
            bottom_up_with(&mut scratch, &[], &members, min_sup, &mut count);
            assert_eq!(count.count as usize, collected.len());

            let mut topk = TopKSink::new(3);
            bottom_up_with(&mut scratch, &[], &members, min_sup, &mut topk);
            let kept = topk.into_sorted();
            assert_eq!(kept.len(), collected.len().min(3));
            let max_sup = collected.iter().map(|f| f.support).max().unwrap();
            assert_eq!(kept.first().map(|f| f.support), Some(max_sup));
        }
    }

    #[test]
    fn scratch_reuse_across_classes_is_clean() {
        // One arena mines many different classes back to back; recycled
        // buffers must never leak stale tids between classes.
        let mut scratch = MineScratch::new();
        let classes: Vec<Vec<(Item, Tidset)>> = vec![
            example_members(),
            vec![(4, vec![0, 1, 2, 3]), (6, vec![1, 3]), (5, vec![0, 1, 3])],
            vec![(8, vec![2])],
            vec![],
            example_members(),
        ];
        for (k, members) in classes.iter().enumerate() {
            for min_sup in 1..=4 {
                let mut want = Vec::new();
                reference::bottom_up::<Tidset>(&[], members, min_sup, &mut want);
                let mut got = Vec::new();
                bottom_up_with(&mut scratch, &[], members, min_sup, &mut got);
                sort_frequents(&mut want);
                sort_frequents(&mut got);
                assert_eq!(got, want, "class {k} min_sup={min_sup}");
            }
        }
    }
}
