//! Fixture: a shimmed module importing std concurrency directly.
//! Never compiled — scanned by `tests/integration_lint.rs` only.

// VIOLATION(shim-imports) on the next line (line 5).
use std::sync::Mutex;

// VIOLATION(shim-imports) on the next line (line 8).
pub fn spawn_reader() -> std::thread::JoinHandle<()> {
    unreachable!("fixture only")
}

// NOT a violation: the registration-plane thread-name read is
// allowlisted for this rule.
pub fn name() -> Option<String> {
    std::thread::current().name().map(str::to_string)
}

pub fn shared() -> Mutex<u32> {
    Mutex::new(0)
}
