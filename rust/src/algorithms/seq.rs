//! Sequential single-machine miners — correctness oracles and the
//! "one core" reference points for the scaling studies.

use crate::engine::ClusterContext;
use crate::error::Result;
use crate::fim::{
    apriori::apriori, bottom_up_diffset_with, construct_classes, fpgrowth::fp_growth, AutoScratch,
    Database, Frequent, FrequentSink, MineScratch, MinSup, VerticalDb,
};

use super::{Algorithm, EclatOptions, FimResult, Variant};

/// Sequential Eclat: vertical DB + equivalence classes + bottom-up, no
/// engine involvement.
#[derive(Debug, Clone, Default)]
pub struct SeqEclat;

impl SeqEclat {
    /// Run directly on a database (no context needed). Uses the
    /// triangular-matrix prune (Zaki's recommendation, §Perf iteration 4)
    /// to avoid intersecting infrequent item pairs during class
    /// construction, and one [`AutoScratch`] arena shared across every
    /// class so steady-state mining allocates nothing per candidate
    /// (§Perf iteration 5).
    pub fn mine(db: &Database, min_sup: MinSup) -> Vec<Frequent> {
        let mut out = Vec::new();
        Self::mine_into(db, min_sup, &mut out);
        out
    }

    /// [`SeqEclat::mine`] emitting into an arbitrary [`FrequentSink`] —
    /// with a [`crate::fim::PooledSink`] or
    /// [`crate::fim::TopKSink`] the whole run materializes nothing it
    /// does not have to.
    pub fn mine_into<S: FrequentSink + ?Sized>(db: &Database, min_sup: MinSup, out: &mut S) {
        let min_sup = min_sup.to_count(db.len());
        let vdb = VerticalDb::build(db, min_sup);
        let mut tri = crate::fim::TriMatrix::new(db.stats().max_item);
        for t in db.transactions() {
            tri.update_transaction(t);
        }
        for (i, t) in &vdb.items {
            out.emit(std::slice::from_ref(i), t.len() as u32);
        }
        let mut scratch = AutoScratch::new();
        for class in construct_classes(&vdb, min_sup, Some(&tri)) {
            class.mine_auto_into(&mut scratch, min_sup, db.len(), out);
        }
    }
}

impl Algorithm for SeqEclat {
    fn name(&self) -> &'static str {
        "seq-eclat"
    }

    fn run_on(&self, _ctx: &ClusterContext, db: &Database, min_sup: MinSup) -> Result<FimResult> {
        let run = FimResult::builder(self.name());
        Ok(run.finish(Self::mine(db, min_sup)))
    }
}

/// Sequential dEclat (diffset) — extension ablation.
#[derive(Debug, Clone, Default)]
pub struct SeqEclatDiffset;

impl SeqEclatDiffset {
    /// Run directly on a database (no context needed).
    pub fn mine(db: &Database, min_sup: MinSup) -> Vec<Frequent> {
        let mut out = Vec::new();
        Self::mine_into(db, min_sup, &mut out);
        out
    }

    /// [`SeqEclatDiffset::mine`] through an arbitrary [`FrequentSink`].
    /// One top-level class over all frequent items: the diffset driver
    /// handles the level-1 → level-2 conversion internally (and emits
    /// the 1-itemsets itself), through the same reusable mining arena as
    /// the tidset path.
    pub fn mine_into<S: FrequentSink + ?Sized>(db: &Database, min_sup: MinSup, out: &mut S) {
        let min_sup = min_sup.to_count(db.len());
        let vdb = VerticalDb::build(db, min_sup);
        let mut scratch = MineScratch::new();
        bottom_up_diffset_with(&mut scratch, &[], &vdb.items, min_sup, out);
    }
}

impl Algorithm for SeqEclatDiffset {
    fn name(&self) -> &'static str {
        "seq-declat"
    }

    fn run_on(&self, _ctx: &ClusterContext, db: &Database, min_sup: MinSup) -> Result<FimResult> {
        let run = FimResult::builder(self.name());
        Ok(run.finish(Self::mine(db, min_sup)))
    }
}

/// Sequential Apriori (Agrawal–Srikant).
#[derive(Debug, Clone, Default)]
pub struct SeqApriori;

impl Algorithm for SeqApriori {
    fn name(&self) -> &'static str {
        "seq-apriori"
    }

    fn run_on(&self, _ctx: &ClusterContext, db: &Database, min_sup: MinSup) -> Result<FimResult> {
        let run = FimResult::builder(self.name());
        let min_sup = min_sup.to_count(db.len());
        Ok(run.finish(apriori(db, min_sup)))
    }
}

/// Sequential FP-Growth (Han et al.).
#[derive(Debug, Clone, Default)]
pub struct SeqFpGrowth;

impl Algorithm for SeqFpGrowth {
    fn name(&self) -> &'static str {
        "seq-fpgrowth"
    }

    fn run_on(&self, _ctx: &ClusterContext, db: &Database, min_sup: MinSup) -> Result<FimResult> {
        let run = FimResult::builder(self.name());
        let min_sup = min_sup.to_count(db.len());
        Ok(run.finish(fp_growth(db, min_sup)))
    }
}

/// Look up an algorithm by CLI name — a thin compatibility shim over the
/// [`Variant`] registry (which is also where the accepted aliases live).
pub fn by_name(name: &str) -> Option<Box<dyn Algorithm>> {
    name.parse::<Variant>().ok().map(|v| v.build(&EclatOptions::default()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::sort_frequents;

    fn demo_db() -> Database {
        Database::from_rows(vec![
            vec![1, 3, 4],
            vec![2, 3, 5],
            vec![1, 2, 3, 5],
            vec![2, 5],
            vec![1, 3, 5],
            vec![2, 3, 5],
        ])
    }

    #[test]
    fn all_sequential_miners_agree() {
        let ctx = ClusterContext::builder().cores(1).build();
        let db = demo_db();
        let algos: Vec<Box<dyn Algorithm>> = vec![
            Box::new(SeqEclat),
            Box::new(SeqEclatDiffset),
            Box::new(SeqApriori),
            Box::new(SeqFpGrowth),
        ];
        for min_sup in 1..=5 {
            let mut reference: Option<Vec<Frequent>> = None;
            for a in &algos {
                let mut got = a.run_on(&ctx, &db, MinSup::count(min_sup)).unwrap().frequents;
                sort_frequents(&mut got);
                match &reference {
                    None => reference = Some(got),
                    Some(r) => assert_eq!(&got, r, "{} min_sup={min_sup}", a.name()),
                }
            }
        }
    }

    #[test]
    fn by_name_resolves_everything() {
        for n in [
            "eclatV1", "v2", "EclatV3", "v4", "eclatv5", "apriori", "yafim", "seq-eclat",
            "seq-declat", "seq-apriori", "fpgrowth",
        ] {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("nope").is_none());
    }
}
