"""Pure-jnp correctness oracles for the Pallas kernels.

Deliberately written with *different* primitives than the kernels
(einsum instead of blocked dots; shift-and-mask popcount instead of
``lax.population_count``) so a bug in a shared primitive cannot hide.
"""

import jax.numpy as jnp


def cooc_ref(a, b):
    """Reference co-occurrence: plain einsum contraction over rows."""
    return jnp.einsum("ti,tj->ij", a.astype(jnp.float32), b.astype(jnp.float32))


def _popcount32_ref(x):
    """Bit-parallel (SWAR) popcount of uint32 lanes, no population_count."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> 24).astype(jnp.int32)


def intersect_support_ref(a, b):
    """Reference batched intersection support."""
    return jnp.sum(_popcount32_ref(a & b), axis=1, dtype=jnp.int32)
