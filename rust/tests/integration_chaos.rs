//! Recovery equivalence under injected chaos (PR 8 acceptance): every
//! RDD variant, run on a context armed with a seeded [`ChaosPolicy`]
//! (transient task panics, stragglers, mid-job shuffle loss), must
//! produce byte-identical results to a fault-free run — the scheduler's
//! retries, lineage re-materialization and speculative tasks are
//! correctness-preserving, not best-effort. The streaming service gets
//! the same treatment with injected emission failures.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use rdd_eclat::algorithms::{
    Algorithm, EclatV1, EclatV2, EclatV3, EclatV4, EclatV5, SeqEclat,
};
use rdd_eclat::data::Database;
use rdd_eclat::engine::{ChaosPolicy, ClusterContext};
use rdd_eclat::fim::{sort_frequents, Frequent, MinSup};
use rdd_eclat::stream::{IngestConfig, StreamConfig, StreamService, StreamingMiner, WindowSpec};
use rdd_eclat::util::prng::Rng;
use rdd_eclat::util::prop::{check, prop_assert_eq, Config};

fn random_db(rng: &mut Rng) -> Database {
    let n_items = rng.range(3, 25) as u32;
    let n_txns = rng.range(5, 120);
    let density = 0.15 + rng.f64() * 0.4;
    let rows: Vec<Vec<u32>> = (0..n_txns)
        .map(|_| (0..n_items).filter(|_| rng.chance(density)).collect())
        .filter(|t: &Vec<u32>| !t.is_empty())
        .collect();
    Database::from_rows(rows)
}

fn mined(algo: &dyn Algorithm, ctx: &ClusterContext, db: &Database, ms: MinSup) -> Vec<Frequent> {
    let mut v = algo.run_on(ctx, db, ms).expect("run").frequents;
    sort_frequents(&mut v);
    v
}

fn variants() -> Vec<Box<dyn Algorithm>> {
    vec![
        Box::new(EclatV1::default()),
        Box::new(EclatV2::default()),
        Box::new(EclatV3::default()),
        Box::new(EclatV4::default()),
        Box::new(EclatV5::default()),
    ]
}

/// The headline equivalence property: a chaos-armed context (panics +
/// stragglers + shuffle loss from one seed) and a fault-free context
/// mine identical frequent-itemset sets on randomized databases, for
/// all five variants, and both match the sequential oracle.
#[test]
fn chaos_runs_are_byte_identical_to_fault_free_runs() {
    // `without_chaos` shields the baseline from any ambient
    // RDD_ECLAT_CHAOS in the environment (the CI chaos job sets it).
    let clean = ClusterContext::builder().cores(2).without_chaos().build();
    let chaotic = ClusterContext::builder()
        .cores(2)
        .chaos(ChaosPolicy::default_suite(0xC4A05, 0.25))
        .build();
    let algos = variants();
    check(Config::default().cases(6).seed(0x0DD5), |rng| {
        let db = random_db(rng);
        let min_sup = MinSup::count(rng.range(1, 2 + db.len() / 3) as u32);
        let mut want = SeqEclat::mine(&db, min_sup);
        sort_frequents(&mut want);
        for algo in &algos {
            let base = mined(algo.as_ref(), &clean, &db, min_sup);
            prop_assert_eq(base == want, true, &format!("{} fault-free", algo.name()))?;
            let got = mined(algo.as_ref(), &chaotic, &db, min_sup);
            prop_assert_eq(got == want, true, &format!("{} under chaos", algo.name()))?;
        }
        Ok(())
    });
}

/// Certain shuffle loss (p = 1.0): the first fetch of every reduce
/// partition fails mid-job, forcing a lineage re-run of the map stage
/// for each shuffle — and results still match the fault-free run.
#[test]
fn certain_shuffle_loss_recovers_through_lineage_mid_job() {
    let clean = ClusterContext::builder().cores(2).without_chaos().build();
    let chaotic = ClusterContext::builder()
        .cores(2)
        .chaos(ChaosPolicy::new(0x1085).shuffle_loss(1.0))
        .build();
    // A bare shuffle job first: counts survive a guaranteed fetch failure.
    let pairs: Vec<(u32, u64)> = (0..60).map(|i| (i % 5, 1u64)).collect();
    let mut got = chaotic
        .parallelize(pairs.clone(), 4)
        .reduce_by_key(3, |a, b| a + b)
        .collect()
        .unwrap();
    got.sort();
    let mut base = clean
        .parallelize(pairs, 4)
        .reduce_by_key(3, |a, b| a + b)
        .collect()
        .unwrap();
    base.sort();
    assert_eq!(got, base, "re-materialized shuffle changed the answer");

    // Then a full multi-shuffle miner on both contexts.
    let mut rng = Rng::new(0x5107);
    let db = random_db(&mut rng);
    let ms = MinSup::count(2);
    for algo in variants() {
        let got = mined(algo.as_ref(), &chaotic, &db, ms);
        let want = mined(algo.as_ref(), &clean, &db, ms);
        assert_eq!(got, want, "{} under certain shuffle loss", algo.name());
    }
}

/// Speculative execution: one deterministic straggler (first attempt of
/// whichever task grabs the one-shot flag sleeps far past the median),
/// speculation armed. The job must finish with correct results — the
/// speculative copy wins while the original sleeps — and the
/// `engine.speculative.*` counters must move.
#[test]
fn speculation_launches_a_copy_and_first_finisher_wins() {
    rdd_eclat::obs::set_enabled(true);
    let launched0 = rdd_eclat::obs::counter("engine.speculative.launched").get();
    let won0 = rdd_eclat::obs::counter("engine.speculative.won").get();

    let ctx = ClusterContext::builder()
        .cores(4)
        .without_chaos()
        .speculation(true)
        .speculation_multiplier(1.2)
        .speculation_quantile(0.5)
        .build();
    let straggle_once = Arc::new(AtomicBool::new(true));
    let flag = Arc::clone(&straggle_once);
    let mut out = ctx
        .parallelize((0..80u32).collect(), 8)
        .map_partitions_with_index(move |_p, rows| {
            if flag.swap(false, Ordering::SeqCst) {
                // Only the first attempt of one task straggles; its
                // speculative relaunch (and everyone else) is fast.
                std::thread::sleep(Duration::from_millis(300));
            }
            rows.into_iter().map(|x| x * 2).collect()
        })
        .collect()
        .unwrap();
    out.sort();
    assert_eq!(out, (0..80u32).map(|x| x * 2).collect::<Vec<_>>());

    let launched = rdd_eclat::obs::counter("engine.speculative.launched").get() - launched0;
    let won = rdd_eclat::obs::counter("engine.speculative.won").get() - won0;
    assert!(launched >= 1, "no speculative task launched against a 300ms straggler");
    assert!(won >= 1, "speculative copy should beat a sleeping original (launched {launched})");
}

/// Streaming graceful degradation end-to-end: a service whose context
/// injects emission failures (cap 2 consecutive, below the service's
/// death bound of 3) must keep serving, retry with full re-mines, and
/// converge to the exact window oracle.
#[test]
fn streaming_service_survives_emission_panics_and_stays_window_exact() {
    let min_sup = MinSup::count(2);
    let ctx = ClusterContext::builder()
        .cores(2)
        .without_chaos()
        .build();
    ctx.set_chaos(Some(ChaosPolicy::new(0xE).emission_failures(0.9, 2)));
    let miner =
        StreamingMiner::new(ctx, StreamConfig::new(WindowSpec::sliding(3, 1), min_sup));
    let service = StreamService::spawn(miner, IngestConfig::new(16));

    let mut rng = Rng::new(0x5EA);
    for _ in 0..10 {
        let batch: Vec<Vec<u32>> =
            (0..8).map(|_| (0..10u32).filter(|_| rng.chance(0.4)).collect()).collect();
        service.push_batch(batch).unwrap();
    }
    let snap = service.drain().unwrap().expect("slide 1 emitted");
    let stats = service.stats();
    let miner = service.shutdown().unwrap();

    assert!(stats.mine_failures > 0, "p=0.9 over 10 emissions injected nothing: {stats:?}");
    assert!(stats.mine_retries > 0, "failures must schedule retries: {stats:?}");
    assert!(!stats.degraded, "a drained service must have recovered: {stats:?}");
    let mut want = SeqEclat::mine(&miner.materialize_window(), min_sup);
    sort_frequents(&mut want);
    assert_eq!(snap.frequents, want, "degraded-mode retries broke window exactness");
}
