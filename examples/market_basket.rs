//! Market-basket analysis on a Quest-style synthetic retail dataset —
//! the workload the paper's introduction motivates (association rules
//! from transactional data).
//!
//! Generates a T10I4-style database, mines it with every RDD-Eclat
//! variant plus the Apriori baseline, verifies they agree, and derives
//! the top association rules.
//!
//! ```text
//! cargo run --release --example market_basket
//! ```

use rdd_eclat::algorithms::{Algorithm, EclatOptions, Variant};
use rdd_eclat::data::quest::{generate, QuestParams};
use rdd_eclat::engine::ClusterContext;
use rdd_eclat::fim::{generate_rules, sort_frequents, MinSup};
use rdd_eclat::util::time::fmt_duration;

fn main() -> rdd_eclat::error::Result<()> {
    // A 20k-transaction retail-like dataset over 300 products.
    let db = generate(&QuestParams::tid(10.0, 4.0, 20_000, 300), 7);
    let stats = db.stats();
    println!(
        "dataset: {} transactions, {} products, avg basket {:.1}",
        stats.transactions, stats.distinct_items, stats.avg_width
    );

    let ctx = ClusterContext::builder().build();
    let min_sup = MinSup::fraction(0.01);

    // The six comparison algorithms, built through the Variant registry.
    let opts = EclatOptions::default();
    let algos: Vec<Box<dyn Algorithm>> =
        Variant::STANDARD.iter().map(|v| v.build(&opts)).collect();

    let mut reference: Option<Vec<rdd_eclat::fim::Frequent>> = None;
    let mut apriori_time = 0.0;
    let mut best_eclat = f64::MAX;
    for algo in &algos {
        let r = algo.run_on(&ctx, &db, min_sup)?;
        println!(
            "  {:<8} {:>6} itemsets in {:>10}",
            algo.name(),
            r.len(),
            fmt_duration(r.wall)
        );
        if algo.name() == "apriori" {
            apriori_time = r.wall.as_secs_f64();
        } else {
            best_eclat = best_eclat.min(r.wall.as_secs_f64());
        }
        let mut sorted = r.frequents;
        sort_frequents(&mut sorted);
        match &reference {
            None => reference = Some(sorted),
            Some(want) => assert_eq!(&sorted, want, "{} disagrees!", algo.name()),
        }
    }
    println!(
        "\nall six algorithms agree; best Eclat vs Apriori speedup: {:.1}x",
        apriori_time / best_eclat
    );

    let frequents = reference.unwrap();
    println!("\ntop cross-sell rules (conf >= 0.6):");
    for rule in generate_rules(&frequents, 0.6, Some(db.len())).iter().take(10) {
        println!("  {rule}");
    }
    Ok(())
}
