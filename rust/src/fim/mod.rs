//! Frequent-itemset-mining primitives (DESIGN.md systems S9–S15, S27):
//! the domain substrate under the paper's RDD-Eclat algorithms.

pub mod apriori;
pub mod bitmap;
pub mod bottomup;
pub mod eqclass;
pub mod fpgrowth;
pub mod itemset;
pub mod rules;
pub mod sink;
pub mod tidset;
pub mod transaction;
pub mod trie;
pub mod trimatrix;

pub use bitmap::TidBitmap;
pub use bottomup::{
    bottom_up, bottom_up_diffset, bottom_up_diffset_with, bottom_up_with, MineScratch, TidRepr,
};
pub use eqclass::{construct_classes, to_bitmap_class, AutoScratch, EqClass};
pub use itemset::{
    is_subset, prefix_join, sort_frequents, Frequent, Item, ItemSet, MinSup, Tid,
};
pub use rules::{generate_rules, rules_to_json, Rule};
pub use sink::{CollectSink, CountSink, FrequentSink, PooledSink, TopKSink};
pub use tidset::{
    difference, difference_into, intersect, intersect_count, intersect_into, Tidset, VerticalDb,
};
pub use transaction::{Database, DbStats};
pub use trie::{CandidateTrie, ItemFilter};
pub use trimatrix::TriMatrix;
