//! Equivalence-class partitioners — Algorithm 10 of the paper.
//!
//! Each equivalence class is keyed by `v`, the dense index of its prefix
//! item in the mining order (ascending support). Because classes are
//! built over a *totally ordered* item list, class `v` has at most
//! `n−1−v` members: low `v` ⇒ heavy class. The three heuristics spread
//! that skew differently:
//!
//! * **default** — `v` itself: one partition per class (`n−1` partitions),
//!   used by EclatV1–V3.
//! * **hash** — `v % p` (EclatV4): round-robin over `p` partitions.
//! * **reverse hash** — `v % p` reversed to `(p−1) − (v % p)` once
//!   `v ≥ p` (EclatV5): the second and later "rows" of classes are dealt
//!   in the opposite direction, pairing the heaviest remaining class
//!   with the partition that so far received the lightest load.

use crate::engine::Partitioner;

/// The class key: the dense index of the class prefix in mining order.
pub type ClassKey = usize;

/// `getPartition(v) = v` over `n−1` partitions (one class each).
#[derive(Debug, Clone)]
pub struct DefaultClassPartitioner {
    parts: usize,
}

impl DefaultClassPartitioner {
    /// `n` = number of frequent items; classes occupy `n−1` partitions.
    pub fn for_items(n: usize) -> Self {
        DefaultClassPartitioner { parts: n.saturating_sub(1).max(1) }
    }
}

impl Partitioner<ClassKey> for DefaultClassPartitioner {
    fn num_partitions(&self) -> usize {
        self.parts
    }
    fn partition(&self, v: &ClassKey) -> usize {
        *v % self.parts // v < n-1 by construction; % keeps the contract
    }
}

/// `getPartition(v) = v % p` (EclatV4).
#[derive(Debug, Clone)]
pub struct HashClassPartitioner {
    p: usize,
}

impl HashClassPartitioner {
    /// `p` partitions (user-supplied; the paper uses p = 10).
    pub fn new(p: usize) -> Self {
        HashClassPartitioner { p: p.max(1) }
    }
}

impl Partitioner<ClassKey> for HashClassPartitioner {
    fn num_partitions(&self) -> usize {
        self.p
    }
    fn partition(&self, v: &ClassKey) -> usize {
        v % self.p
    }
}

/// Reverse hash (EclatV5): identity on the first row (`v < p`), reversed
/// remainder afterwards.
#[derive(Debug, Clone)]
pub struct ReverseHashClassPartitioner {
    p: usize,
}

impl ReverseHashClassPartitioner {
    /// `p` partitions.
    pub fn new(p: usize) -> Self {
        ReverseHashClassPartitioner { p: p.max(1) }
    }

    /// Route an *item* (rather than a dense class key) to a shard — the
    /// sharded streaming store reuses the reverse-hash dealing to spread
    /// item columns over store shards with the same anti-clustering
    /// property the mining classes get.
    pub fn shard_of_item(&self, item: crate::fim::Item) -> usize {
        self.partition(&(item as ClassKey))
    }
}

impl Partitioner<ClassKey> for ReverseHashClassPartitioner {
    fn num_partitions(&self) -> usize {
        self.p
    }
    fn partition(&self, v: &ClassKey) -> usize {
        let r = v % self.p;
        if *v >= self.p {
            (self.p - 1) - r
        } else {
            r
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::imbalance;

    #[test]
    fn default_partitioner_is_identity() {
        let p = DefaultClassPartitioner::for_items(6); // 5 partitions
        assert_eq!(p.num_partitions(), 5);
        for v in 0..5 {
            assert_eq!(p.partition(&v), v);
        }
    }

    #[test]
    fn hash_partitioner_mods() {
        let p = HashClassPartitioner::new(10);
        assert_eq!(p.partition(&0), 0);
        assert_eq!(p.partition(&13), 3);
        assert_eq!(p.partition(&25), 5);
    }

    #[test]
    fn reverse_hash_matches_algorithm_10() {
        let p = ReverseHashClassPartitioner::new(10);
        // v < p: identity.
        for v in 0..10 {
            assert_eq!(p.partition(&v), v);
        }
        // v >= p: (p-1) - (v % p).
        assert_eq!(p.partition(&10), 9);
        assert_eq!(p.partition(&11), 8);
        assert_eq!(p.partition(&19), 0);
        assert_eq!(p.partition(&20), 9);
    }

    /// The paper's §4.5 motivation: with triangular workloads
    /// (class v has weight n−1−v), both hash partitioners beat nothing,
    /// and reverse hash balances at least as well as plain hash.
    #[test]
    fn reverse_hash_balances_triangular_load() {
        let n = 101usize; // 100 classes, weight(v) = n-1-v
        let p = 10usize;
        let weight = |v: usize| n - 1 - v;
        let mut hash_loads = vec![0usize; p];
        let mut rev_loads = vec![0usize; p];
        let h = HashClassPartitioner::new(p);
        let r = ReverseHashClassPartitioner::new(p);
        for v in 0..(n - 1) {
            hash_loads[h.partition(&v)] += weight(v);
            rev_loads[r.partition(&v)] += weight(v);
        }
        let ih = imbalance(&hash_loads);
        let ir = imbalance(&rev_loads);
        assert!(ir <= ih + 1e-9, "reverse {ir} vs hash {ih}");
        // Both are far better than one-class-per-partition (default), whose
        // max/mean over used partitions is ~2x at this shape.
        assert!(ih < 1.25 && ir < 1.25, "hash {ih} rev {ir}");
    }

    #[test]
    fn shard_of_item_matches_class_routing_and_stays_in_range() {
        for p in [1usize, 2, 4, 7] {
            let part = ReverseHashClassPartitioner::new(p);
            for item in 0u32..300 {
                let s = part.shard_of_item(item);
                assert!(s < p);
                assert_eq!(s, part.partition(&(item as ClassKey)), "item {item}, p {p}");
            }
        }
    }

    #[test]
    fn all_partitions_in_range() {
        let parts: Vec<Box<dyn Partitioner<usize>>> = vec![
            Box::new(DefaultClassPartitioner::for_items(50)),
            Box::new(HashClassPartitioner::new(7)),
            Box::new(ReverseHashClassPartitioner::new(7)),
        ];
        for p in &parts {
            for v in 0..200 {
                assert!(p.partition(&v) < p.num_partitions());
            }
        }
    }
}
