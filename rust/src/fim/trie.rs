//! Item tries: the frequent-item filter trie (`trieL1` in the paper's
//! Phase-2 of EclatV2) and the candidate prefix trie used by Apriori's
//! subset-counting step (the hash-tree role in the classic algorithm).

use std::collections::HashMap;

use super::itemset::Item;

/// Membership structure for frequent items — the paper stores `trieL1`
/// and broadcasts it to executors for transaction filtering. Backed by a
/// bitset over item ids (dense vocabularies) — the degenerate 1-level
/// trie, matching Borgelt's filter semantics exactly.
#[derive(Debug, Clone)]
pub struct ItemFilter {
    bits: Vec<u64>,
}

impl ItemFilter {
    /// Build from the frequent item list.
    pub fn new(items: impl IntoIterator<Item = Item>) -> ItemFilter {
        let mut bits = Vec::new();
        for i in items {
            let w = (i as usize) >> 6;
            if w >= bits.len() {
                bits.resize(w + 1, 0);
            }
            bits[w] |= 1u64 << (i & 63);
        }
        ItemFilter { bits }
    }

    /// Is `i` frequent?
    #[inline]
    pub fn contains(&self, i: Item) -> bool {
        let w = (i as usize) >> 6;
        w < self.bits.len() && (self.bits[w] >> (i & 63)) & 1 == 1
    }

    /// Borgelt's transaction filter: keep only frequent items.
    pub fn filter_transaction(&self, t: &[Item]) -> Vec<Item> {
        t.iter().copied().filter(|&i| self.contains(i)).collect()
    }

    /// Number of frequent items.
    pub fn len(&self) -> usize {
        self.bits.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// True when no item is frequent.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A prefix trie over sorted candidate itemsets — Apriori's candidate
/// store. Supports insertion of k-itemsets and counting every candidate
/// subset of a transaction in one walk (the role the hash tree plays in
/// Agrawal–Srikant).
#[derive(Debug, Default)]
pub struct CandidateTrie {
    root: Node,
    len: usize,
}

#[derive(Debug, Default)]
struct Node {
    children: HashMap<Item, Node>,
    /// Index into the external count vector when a candidate ends here.
    leaf: Option<usize>,
}

impl CandidateTrie {
    /// Empty trie.
    pub fn new() -> CandidateTrie {
        CandidateTrie::default()
    }

    /// Insert a sorted candidate; returns its dense leaf index.
    pub fn insert(&mut self, itemset: &[Item]) -> usize {
        let mut node = &mut self.root;
        for &i in itemset {
            node = node.children.entry(i).or_default();
        }
        if let Some(idx) = node.leaf {
            idx
        } else {
            let idx = self.len;
            node.leaf = Some(idx);
            self.len += 1;
            idx
        }
    }

    /// Number of distinct candidates inserted.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no candidates were inserted.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Does the trie contain exactly this itemset? (Used by Apriori's
    /// prune step: all (k−1)-subsets must be frequent.)
    pub fn contains(&self, itemset: &[Item]) -> bool {
        let mut node = &self.root;
        for &i in itemset {
            match node.children.get(&i) {
                Some(n) => node = n,
                None => return false,
            }
        }
        node.leaf.is_some()
    }

    /// Count every candidate that is a subset of (sorted) transaction `t`,
    /// incrementing `counts[leaf]`. One recursive walk — each trie edge is
    /// matched against the remaining suffix of the transaction.
    pub fn count_subsets(&self, t: &[Item], counts: &mut [u32]) {
        fn walk(node: &Node, t: &[Item], counts: &mut [u32]) {
            if let Some(idx) = node.leaf {
                counts[idx] += 1;
            }
            if node.children.is_empty() {
                return;
            }
            for (pos, &item) in t.iter().enumerate() {
                if let Some(child) = node.children.get(&item) {
                    walk(child, &t[pos + 1..], counts);
                }
            }
        }
        walk(&self.root, t, counts);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn item_filter_membership() {
        let f = ItemFilter::new([1u32, 70, 500]);
        assert!(f.contains(1) && f.contains(70) && f.contains(500));
        assert!(!f.contains(0) && !f.contains(71) && !f.contains(10_000));
        assert_eq!(f.len(), 3);
    }

    #[test]
    fn item_filter_filters_transactions() {
        let f = ItemFilter::new([2u32, 3]);
        assert_eq!(f.filter_transaction(&[1, 2, 3, 9]), vec![2, 3]);
        assert!(f.filter_transaction(&[1, 9]).is_empty());
    }

    #[test]
    fn trie_insert_contains() {
        let mut t = CandidateTrie::new();
        let a = t.insert(&[1, 2, 3]);
        let b = t.insert(&[1, 2, 4]);
        let a2 = t.insert(&[1, 2, 3]);
        assert_eq!(a, a2, "re-insert returns same index");
        assert_ne!(a, b);
        assert_eq!(t.len(), 2);
        assert!(t.contains(&[1, 2, 3]));
        assert!(!t.contains(&[1, 2]), "prefix is not a member");
        assert!(!t.contains(&[1, 2, 5]));
    }

    #[test]
    fn count_subsets_counts_exactly_contained_candidates() {
        let mut t = CandidateTrie::new();
        let c12 = t.insert(&[1, 2]);
        let c13 = t.insert(&[1, 3]);
        let c23 = t.insert(&[2, 3]);
        let c24 = t.insert(&[2, 4]);
        let mut counts = vec![0u32; t.len()];
        t.count_subsets(&[1, 2, 3], &mut counts);
        assert_eq!(counts[c12], 1);
        assert_eq!(counts[c13], 1);
        assert_eq!(counts[c23], 1);
        assert_eq!(counts[c24], 0);
        t.count_subsets(&[2, 4], &mut counts);
        assert_eq!(counts[c24], 1);
    }

    #[test]
    fn count_subsets_three_level() {
        let mut t = CandidateTrie::new();
        let c = t.insert(&[1, 3, 5]);
        let mut counts = vec![0u32; 1];
        t.count_subsets(&[1, 2, 3, 4, 5], &mut counts);
        assert_eq!(counts[c], 1);
        t.count_subsets(&[1, 3], &mut counts);
        assert_eq!(counts[c], 1, "no false positive on prefix");
    }

    #[test]
    fn empty_structures() {
        let f = ItemFilter::new([]);
        assert!(f.is_empty());
        let t = CandidateTrie::new();
        assert!(t.is_empty());
        let mut counts: Vec<u32> = vec![];
        t.count_subsets(&[1, 2, 3], &mut counts);
    }
}
