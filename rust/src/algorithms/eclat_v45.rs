//! EclatV4 and EclatV5 (paper §4.4): EclatV3 with the equivalence
//! classes spread over `p` user-chosen partitions by the **hash** (`v %
//! p`) and **reverse-hash** partitioners of Algorithm 10 — the workload
//! balancing heuristics that §5.2.1 shows dominating V1–V3.

use std::sync::Arc;

use crate::engine::ClusterContext;
use crate::error::Result;
use crate::fim::{Database, MinSup};

use super::eclat_v3::run_v3_pipeline;
use super::partitioners::{HashClassPartitioner, ReverseHashClassPartitioner};
use super::{Algorithm, EclatOptions, FimResult};

/// EclatV4: hash partitioner `v % p`.
#[derive(Debug, Clone, Default)]
pub struct EclatV4 {
    /// Shared variant options; `options.partitions` is `p`.
    pub options: EclatOptions,
}

impl EclatV4 {
    /// With explicit options.
    pub fn with_options(options: EclatOptions) -> Self {
        EclatV4 { options }
    }

    /// Convenience: set `p` only.
    pub fn with_partitions(p: usize) -> Self {
        EclatV4 { options: EclatOptions { partitions: p, ..Default::default() } }
    }
}

impl Algorithm for EclatV4 {
    fn name(&self) -> &'static str {
        "eclatV4"
    }

    fn run_on(&self, ctx: &ClusterContext, db: &Database, min_sup: MinSup) -> Result<FimResult> {
        let p = self.options.partitions;
        run_v3_pipeline(self.name(), &self.options, ctx, db, min_sup, |_n| {
            Arc::new(HashClassPartitioner::new(p))
        })
    }
}

/// EclatV5: reverse-hash partitioner.
#[derive(Debug, Clone, Default)]
pub struct EclatV5 {
    /// Shared variant options; `options.partitions` is `p`.
    pub options: EclatOptions,
}

impl EclatV5 {
    /// With explicit options.
    pub fn with_options(options: EclatOptions) -> Self {
        EclatV5 { options }
    }

    /// Convenience: set `p` only.
    pub fn with_partitions(p: usize) -> Self {
        EclatV5 { options: EclatOptions { partitions: p, ..Default::default() } }
    }
}

impl Algorithm for EclatV5 {
    fn name(&self) -> &'static str {
        "eclatV5"
    }

    fn run_on(&self, ctx: &ClusterContext, db: &Database, min_sup: MinSup) -> Result<FimResult> {
        let p = self.options.partitions;
        run_v3_pipeline(self.name(), &self.options, ctx, db, min_sup, |_n| {
            Arc::new(ReverseHashClassPartitioner::new(p))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::{apriori::apriori, sort_frequents};

    fn demo_db() -> Database {
        Database::from_rows(vec![
            vec![1, 3, 4],
            vec![2, 3, 5],
            vec![1, 2, 3, 5],
            vec![2, 5],
            vec![1, 3, 5],
            vec![2, 3, 5],
        ])
    }

    #[test]
    fn v4_and_v5_match_oracle() {
        let ctx = ClusterContext::builder().cores(2).build();
        let db = demo_db();
        for min_sup in 1..=4 {
            let mut want = apriori(&db, min_sup);
            sort_frequents(&mut want);
            for algo in [&EclatV4::default() as &dyn Algorithm, &EclatV5::default()] {
                let mut got =
                    algo.run_on(&ctx, &db, MinSup::count(min_sup)).unwrap().frequents;
                sort_frequents(&mut got);
                assert_eq!(got, want, "{} min_sup={min_sup}", algo.name());
            }
        }
    }

    #[test]
    fn partition_loads_use_p_partitions() {
        let ctx = ClusterContext::builder().cores(2).build();
        let db = demo_db();
        let r = EclatV4::with_partitions(3).run_on(&ctx, &db, MinSup::count(2)).unwrap();
        assert_eq!(r.partition_loads.len(), 3);
        let r = EclatV5::with_partitions(4).run_on(&ctx, &db, MinSup::count(2)).unwrap();
        assert_eq!(r.partition_loads.len(), 4);
    }

    #[test]
    fn p_one_still_correct() {
        let ctx = ClusterContext::builder().cores(2).build();
        let db = demo_db();
        let r = EclatV4::with_partitions(1).run_on(&ctx, &db, MinSup::count(2)).unwrap();
        let mut got = r.frequents;
        let mut want = apriori(&db, 2);
        sort_frequents(&mut got);
        sort_frequents(&mut want);
        assert_eq!(got, want);
    }
}
