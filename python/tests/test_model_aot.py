"""L2 model shape checks + AOT lowering round-trip sanity."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import artifacts_spec, to_hlo_text
from compile.model import cooc_graph, intersect_graph, phase2_graph


class TestModelShapes:
    def test_phase2_graph_outputs(self):
        rng = np.random.default_rng(0)
        a = (rng.random((64, 16)) < 0.4).astype(np.float32)
        supports, counts = phase2_graph(a)
        assert supports.shape == (16,)
        assert counts.shape == (16, 16)
        np.testing.assert_allclose(np.asarray(supports), a.sum(axis=0))
        # Diagonal of the co-occurrence matrix = item supports.
        np.testing.assert_allclose(np.diag(np.asarray(counts)), a.sum(axis=0))

    def test_cooc_graph_tuple(self):
        a = np.zeros((64, 8), dtype=np.float32)
        (out,) = cooc_graph(a, a)
        assert out.shape == (8, 8)

    def test_intersect_graph_tuple(self):
        a = np.zeros((16, 4), dtype=np.uint32)
        (out,) = intersect_graph(a, a)
        assert out.shape == (16,)


class TestAotLowering:
    def test_all_artifacts_lower_to_hlo_text(self):
        for name, fn, example_args, _shapes in artifacts_spec():
            lowered = jax.jit(fn).lower(*example_args)
            text = to_hlo_text(lowered)
            assert "HloModule" in text, name
            # Interpret-mode pallas must lower to plain HLO: no Mosaic
            # custom-calls the CPU PJRT client cannot execute.
            assert "mosaic" not in text.lower(), name

    def test_hlo_text_has_no_64bit_id_issue_markers(self):
        # The text format carries no instruction ids at all, which is the
        # point of using it as the interchange (gotcha in aot_recipe).
        name, fn, example_args, _ = artifacts_spec()[0]
        text = to_hlo_text(jax.jit(fn).lower(*example_args))
        assert "id=" not in text

    def test_manifest_spec_is_consistent(self):
        specs = artifacts_spec()
        names = [s[0] for s in specs]
        assert len(names) == len(set(names)), "artifact names unique"
        for name, _fn, _args, shapes in specs:
            assert "in=" in shapes and "out=" in shapes, name


def test_aot_cli_writes_artifacts(tmp_path):
    """End-to-end: the `make artifacts` entry point."""
    env = dict(os.environ)
    out_dir = tmp_path / "artifacts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out_dir)],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert r.returncode == 0, r.stderr
    manifest = (out_dir / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == len(artifacts_spec())
    for line in manifest:
        name, fname, *_ = line.split()
        assert (out_dir / fname).exists(), fname
        head = (out_dir / fname).read_text(errors="ignore")[:200]
        assert "HloModule" in head
