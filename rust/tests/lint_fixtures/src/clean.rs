//! Fixture: fully compliant file — the lint must report nothing here.
//! Never compiled — scanned by `tests/integration_lint.rs` only.
//!
//! Doc text may mention `.lock().unwrap()` or `Ordering::SeqCst` or
//! `unsafe` freely: comments are not code.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

pub static TALLY: AtomicU64 = AtomicU64::new(0);

pub fn bump() {
    // ordering: Relaxed — independent tally; RMW atomicity alone keeps
    // it exact and nothing synchronizes through it.
    TALLY.fetch_add(1, Ordering::Relaxed);
}

pub fn drain(queue: &Mutex<Vec<u32>>) -> Vec<u32> {
    // Poison-tolerant: maps the error instead of unwrapping the guard.
    queue
        .lock()
        .map(|mut q| std::mem::take(&mut *q))
        .unwrap_or_default()
}

pub fn first_byte(v: &[u8]) -> u8 {
    // SAFETY: caller guarantees `v` is non-empty.
    unsafe { *v.get_unchecked(0) }
}

pub fn strings_are_not_code() -> &'static str {
    // Needles inside string literals describe, they don't execute:
    "call .lock().unwrap() and Ordering::SeqCst in an unsafe { } block"
}
