//! Sparse clickstream generator — statistical twin of the BMS-WebView
//! datasets (Blue Martini e-commerce click sessions).
//!
//! BMS1/BMS2 are very sparse (average width 2.5 / 5 over 497 / 3340
//! items) with heavily skewed product popularity and short sessions —
//! the regime where the paper *disables* the triangular matrix (the item
//! universe is large relative to support) and where transaction
//! filtering barely shrinks anything. We reproduce those properties:
//! session length ~ shifted geometric; items drawn from a Zipf catalogue;
//! within a session, subsequent clicks stay near the seed product's
//! popularity rank (browsing locality → some frequent pairs survive).

use crate::fim::transaction::Database;
use crate::fim::Item;
use crate::util::prng::{Rng, Zipf};

/// Parameters of the clickstream generator.
#[derive(Debug, Clone)]
pub struct ClickParams {
    /// Number of sessions (transactions).
    pub sessions: usize,
    /// Catalogue size (distinct items).
    pub items: usize,
    /// Average session length.
    pub avg_len: f64,
    /// Zipf skew of product popularity.
    pub skew: f64,
    /// Browsing locality: probability a click is drawn from the
    /// neighbourhood of the session seed instead of the global catalogue.
    pub locality: f64,
    /// Neighbourhood half-width (in popularity rank space).
    pub radius: usize,
}

impl ClickParams {
    /// BMS_WebView_1-like: 59602 sessions × 497 items, width 2.5.
    pub fn bms1_like() -> ClickParams {
        ClickParams { sessions: 59_602, items: 497, avg_len: 2.5, skew: 1.1, locality: 0.5, radius: 12 }
    }

    /// BMS_WebView_2-like: 77512 sessions × 3340 items, width 5.
    pub fn bms2_like() -> ClickParams {
        ClickParams { sessions: 77_512, items: 3340, avg_len: 5.0, skew: 1.15, locality: 0.5, radius: 25 }
    }
}

/// Generate the clickstream database deterministically from `seed`.
pub fn generate(params: &ClickParams, seed: u64) -> Database {
    let mut rng = Rng::new(seed);
    let zipf = Zipf::new(params.items, params.skew);
    // Rank -> item id mapping is a fixed permutation so item ids do not
    // leak popularity (like real catalogues).
    let mut rank_to_item: Vec<Item> = (0..params.items as u32).collect();
    rng.shuffle(&mut rank_to_item);

    let mut rows = Vec::with_capacity(params.sessions);
    for _ in 0..params.sessions {
        // Shifted geometric with mean avg_len: length >= 1.
        let len = rng.geometric(params.avg_len.max(1.0)).max(1);
        let seed_rank = zipf.sample(&mut rng);
        let mut t: Vec<Item> = Vec::with_capacity(len);
        for click in 0..len {
            let rank = if click > 0 && rng.chance(params.locality) {
                // Stay near the seed's rank (browsing related products).
                let lo = seed_rank.saturating_sub(params.radius);
                let hi = (seed_rank + params.radius + 1).min(params.items);
                rng.range(lo, hi)
            } else {
                zipf.sample(&mut rng)
            };
            t.push(rank_to_item[rank]);
        }
        t.sort_unstable();
        t.dedup();
        rows.push(t);
    }
    Database::from_rows(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ClickParams {
        ClickParams { sessions: 5000, items: 400, avg_len: 2.5, skew: 1.1, locality: 0.5, radius: 10 }
    }

    #[test]
    fn deterministic_by_seed() {
        assert_eq!(generate(&small(), 1), generate(&small(), 1));
        assert_ne!(generate(&small(), 1), generate(&small(), 2));
    }

    #[test]
    fn shape_matches_bms_profile() {
        let db = generate(&small(), 42);
        let s = db.stats();
        assert_eq!(s.transactions, 5000);
        assert!(s.avg_width > 1.5 && s.avg_width < 3.5, "width {}", s.avg_width);
        assert!(s.distinct_items > 250, "{}", s.distinct_items);
        assert!(s.max_item < 400);
    }

    #[test]
    fn popularity_is_skewed() {
        let db = generate(&small(), 7);
        let mut counts = std::collections::HashMap::new();
        for t in db.transactions() {
            for &i in t {
                *counts.entry(i).or_insert(0u32) += 1;
            }
        }
        let mut freqs: Vec<u32> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        let total: u32 = freqs.iter().sum();
        let head: u32 = freqs.iter().take(20).sum();
        assert!(
            head as f64 / total as f64 > 0.25,
            "top-20 items should dominate: {}",
            head as f64 / total as f64
        );
    }

    #[test]
    fn locality_creates_frequent_pairs() {
        let db = generate(&small(), 3);
        let min_sup = (db.len() as f64 * 0.005).ceil() as u32; // 0.5%
        let frequents = crate::fim::apriori::apriori(&db, min_sup);
        let pairs = frequents.iter().filter(|f| f.items.len() == 2).count();
        assert!(pairs > 0, "locality should produce co-clicked pairs");
    }
}
