//! The PJRT execution service.
//!
//! The published `xla` crate's `PjRtClient` is `Rc`-based (`!Send`), so
//! the runtime owns a dedicated **service thread** that holds the client
//! and every compiled executable; callers (driver or executor tasks) talk
//! to it over a channel with plain host buffers. This mirrors a real
//! deployment where one process-wide device service serializes access to
//! an accelerator.
//!
//! Artifacts are the HLO-text files produced by `python/compile/aot.py`
//! (`make artifacts`), listed in `artifacts/manifest.txt`. Each artifact
//! is compiled once, on first use, and cached for the life of the
//! service.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use crate::error::{Error, Result};

/// A typed host-side tensor crossing the service boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum HostBuffer {
    /// f32 tensor with row-major dims.
    F32(Vec<f32>, Vec<i64>),
    /// u32 tensor with row-major dims.
    U32(Vec<u32>, Vec<i64>),
    /// i32 tensor with row-major dims.
    I32(Vec<i32>, Vec<i64>),
}

impl HostBuffer {
    /// Number of elements.
    pub fn len(&self) -> usize {
        match self {
            HostBuffer::F32(v, _) => v.len(),
            HostBuffer::U32(v, _) => v.len(),
            HostBuffer::I32(v, _) => v.len(),
        }
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Borrow as f32 slice (error if a different dtype).
    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostBuffer::F32(v, _) => Ok(v),
            other => Err(Error::runtime(format!("expected f32 buffer, got {other:?}"))),
        }
    }

    /// Borrow as i32 slice.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostBuffer::I32(v, _) => Ok(v),
            other => Err(Error::runtime(format!("expected i32 buffer, got {other:?}"))),
        }
    }
}

struct Request {
    artifact: String,
    inputs: Vec<HostBuffer>,
    reply: Sender<Result<Vec<HostBuffer>>>,
}

/// Handle to the PJRT service thread. Cheap to clone via `Arc`; `Send +
/// Sync`, usable from executor tasks.
pub struct XlaService {
    tx: Sender<Request>,
    handle: Option<JoinHandle<()>>,
    artifacts: Vec<String>,
}

impl std::fmt::Debug for XlaService {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaService").field("artifacts", &self.artifacts).finish()
    }
}

impl XlaService {
    /// Start the service over an artifact directory (must contain
    /// `manifest.txt`). Fails fast if the directory or manifest is
    /// missing; artifact compilation is lazy.
    pub fn start(artifact_dir: impl AsRef<Path>) -> Result<XlaService> {
        let dir: PathBuf = artifact_dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.txt");
        let manifest_text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            Error::runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest_path.display()
            ))
        })?;
        let mut files: HashMap<String, PathBuf> = HashMap::new();
        let mut names = Vec::new();
        for line in manifest_text.lines() {
            let mut parts = line.split_whitespace();
            if let (Some(name), Some(file)) = (parts.next(), parts.next()) {
                files.insert(name.to_string(), dir.join(file));
                names.push(name.to_string());
            }
        }
        if files.is_empty() {
            return Err(Error::runtime("manifest.txt lists no artifacts"));
        }

        let (tx, rx) = channel::<Request>();
        let handle = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || {
                // Client + executable cache live only on this thread.
                let client = match xla::PjRtClient::cpu() {
                    Ok(c) => c,
                    Err(e) => {
                        // Answer every request with the failure.
                        while let Ok(req) = rx.recv() {
                            let _ = req
                                .reply
                                .send(Err(Error::runtime(format!("PJRT client failed: {e}"))));
                        }
                        return;
                    }
                };
                let mut exes: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
                while let Ok(req) = rx.recv() {
                    let result = serve(&client, &mut exes, &files, &req.artifact, &req.inputs);
                    let _ = req.reply.send(result);
                }
            })
            .map_err(|e| Error::runtime(format!("cannot spawn pjrt-service: {e}")))?;

        Ok(XlaService { tx, handle: Some(handle), artifacts: names })
    }

    /// Names of available artifacts.
    pub fn artifacts(&self) -> &[String] {
        &self.artifacts
    }

    /// Execute an artifact with host inputs; blocks for the outputs.
    pub fn execute(&self, artifact: &str, inputs: Vec<HostBuffer>) -> Result<Vec<HostBuffer>> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .send(Request { artifact: artifact.to_string(), inputs, reply: reply_tx })
            .map_err(|_| Error::runtime("pjrt-service is gone"))?;
        reply_rx.recv().map_err(|_| Error::runtime("pjrt-service dropped the reply"))?
    }
}

impl Drop for XlaService {
    fn drop(&mut self) {
        // Closing the channel stops the loop.
        let (dummy_tx, _) = channel();
        let tx = std::mem::replace(&mut self.tx, dummy_tx);
        drop(tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One request, on the service thread.
fn serve(
    client: &xla::PjRtClient,
    exes: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    files: &HashMap<String, PathBuf>,
    artifact: &str,
    inputs: &[HostBuffer],
) -> Result<Vec<HostBuffer>> {
    if !exes.contains_key(artifact) {
        let path = files
            .get(artifact)
            .ok_or_else(|| Error::runtime(format!("unknown artifact {artifact:?}")))?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::runtime("non-utf8 artifact path"))?,
        )
        .map_err(|e| Error::runtime(format!("parse {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| Error::runtime(format!("compile {artifact}: {e}")))?;
        exes.insert(artifact.to_string(), exe);
    }
    let exe = exes.get(artifact).expect("just inserted");

    let literals: Vec<xla::Literal> = inputs
        .iter()
        .map(|b| -> Result<xla::Literal> {
            let lit = match b {
                HostBuffer::F32(v, dims) => xla::Literal::vec1(v)
                    .reshape(dims)
                    .map_err(|e| Error::runtime(format!("reshape f32{dims:?}: {e}")))?,
                HostBuffer::U32(v, dims) => xla::Literal::vec1(v)
                    .reshape(dims)
                    .map_err(|e| Error::runtime(format!("reshape u32{dims:?}: {e}")))?,
                HostBuffer::I32(v, dims) => xla::Literal::vec1(v)
                    .reshape(dims)
                    .map_err(|e| Error::runtime(format!("reshape i32{dims:?}: {e}")))?,
            };
            Ok(lit)
        })
        .collect::<Result<_>>()?;

    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| Error::runtime(format!("execute {artifact}: {e}")))?;
    let first = result
        .first()
        .and_then(|d| d.first())
        .ok_or_else(|| Error::runtime("no output buffer"))?
        .to_literal_sync()
        .map_err(|e| Error::runtime(format!("fetch output: {e}")))?;

    // aot.py lowers with return_tuple=True: unpack the tuple.
    let outputs = first
        .to_tuple()
        .map_err(|e| Error::runtime(format!("untuple output: {e}")))?;
    outputs
        .into_iter()
        .map(|lit| -> Result<HostBuffer> {
            let shape = lit.shape().map_err(|e| Error::runtime(format!("shape: {e}")))?;
            let dims: Vec<i64> = match &shape {
                xla::Shape::Array(a) => a.dims().to_vec(),
                _ => return Err(Error::runtime("nested tuple output unsupported")),
            };
            let ty = lit
                .element_type()
                .map_err(|e| Error::runtime(format!("element type: {e}")))?;
            match ty {
                xla::ElementType::F32 => Ok(HostBuffer::F32(
                    lit.to_vec::<f32>().map_err(|e| Error::runtime(e.to_string()))?,
                    dims,
                )),
                xla::ElementType::U32 => Ok(HostBuffer::U32(
                    lit.to_vec::<u32>().map_err(|e| Error::runtime(e.to_string()))?,
                    dims,
                )),
                xla::ElementType::S32 => Ok(HostBuffer::I32(
                    lit.to_vec::<i32>().map_err(|e| Error::runtime(e.to_string()))?,
                    dims,
                )),
                other => Err(Error::runtime(format!("unsupported output dtype {other:?}"))),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Artifacts directory when built (`make artifacts`), else None and
    /// the PJRT tests are skipped (CI runs them via the Makefile).
    pub(crate) fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.txt").exists().then_some(dir)
    }

    #[test]
    fn missing_dir_errors() {
        let err = XlaService::start("/nonexistent/artifacts").unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(dir) = artifacts_dir() else { return };
        let svc = XlaService::start(dir).unwrap();
        let err = svc.execute("nope", vec![]).unwrap_err();
        assert!(err.to_string().contains("unknown artifact"), "{err}");
    }

    #[test]
    fn cooc_artifact_round_trip() {
        let Some(dir) = artifacts_dir() else { return };
        let svc = XlaService::start(dir).unwrap();
        // A = identity-ish block: transaction t has item t % 128.
        let (t, i) = (256usize, 128usize);
        let mut a = vec![0f32; t * i];
        for row in 0..t {
            a[row * i + (row % i)] = 1.0;
        }
        let out = svc
            .execute(
                "cooc_256x128",
                vec![
                    HostBuffer::F32(a.clone(), vec![t as i64, i as i64]),
                    HostBuffer::F32(a, vec![t as i64, i as i64]),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 1);
        let c = out[0].as_f32().unwrap();
        // Diagonal = 2 (each item appears twice in 256 rows), off-diag 0.
        for x in 0..i {
            for y in 0..i {
                let want = if x == y { 2.0 } else { 0.0 };
                assert_eq!(c[x * i + y], want, "({x},{y})");
            }
        }
    }

    #[test]
    fn popcount_artifact_round_trip() {
        let Some(dir) = artifacts_dir() else { return };
        let svc = XlaService::start(dir).unwrap();
        let (n, w) = (256usize, 64usize);
        let a = vec![0xFFFF_FFFFu32; n * w];
        let mut b = vec![0u32; n * w];
        // Row r: r of 32 bits set in the first lane.
        for (r, chunk) in b.chunks_mut(w).enumerate() {
            let bits = (r % 33) as u32;
            chunk[0] = if bits == 0 { 0 } else { u32::MAX >> (32 - bits) };
        }
        let out = svc
            .execute(
                "popcount_256x64",
                vec![
                    HostBuffer::U32(a, vec![n as i64, w as i64]),
                    HostBuffer::U32(b, vec![n as i64, w as i64]),
                ],
            )
            .unwrap();
        let counts = out[0].as_i32().unwrap();
        for (r, &c) in counts.iter().enumerate() {
            assert_eq!(c as usize, r % 33, "row {r}");
        }
    }

    #[test]
    fn service_is_usable_from_many_threads() {
        let Some(dir) = artifacts_dir() else { return };
        let svc = std::sync::Arc::new(XlaService::start(dir).unwrap());
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let svc = std::sync::Arc::clone(&svc);
                std::thread::spawn(move || {
                    let a = vec![1f32; 256 * 128];
                    let out = svc
                        .execute(
                            "cooc_256x128",
                            vec![
                                HostBuffer::F32(a.clone(), vec![256, 128]),
                                HostBuffer::F32(a, vec![256, 128]),
                            ],
                        )
                        .unwrap();
                    assert_eq!(out[0].as_f32().unwrap()[0], 256.0);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
