//! Configuration: a TOML-subset parser (no `serde`/`toml` offline) and
//! the typed [`EclatConfig`] the launcher and benches consume.
//!
//! Supported TOML subset — everything the config files of this project
//! need: `[section]` headers, `key = value` with string/int/float/bool
//! values, `#` comments. Arrays and nested tables are intentionally out
//! of scope.

pub mod toml;

use crate::error::{Error, Result};

pub use toml::TomlDoc;

/// Runtime configuration of one mining run (CLI flags and config files
/// both land here).
#[derive(Debug, Clone, PartialEq)]
pub struct EclatConfig {
    /// Algorithm name (`eclatV1`..`eclatV5`, `apriori`, `seq-*`).
    pub algorithm: String,
    /// Dataset name (Table 2 names or a path to a FIMI file).
    pub dataset: String,
    /// Minimum support as a fraction (0,1] or an absolute count (>1).
    pub min_sup: f64,
    /// Executor cores (thread-pool size). 0 = all available.
    pub cores: usize,
    /// Equivalence-class partitions `p` (V4/V5; paper default 10).
    pub partitions: usize,
    /// `triMatrixMode` (None = per-dataset default from the paper).
    pub tri_matrix: Option<bool>,
    /// Phase-2 backend: "native" or "xla".
    pub backend: String,
    /// Directory for generated/cached datasets.
    pub data_dir: String,
    /// Optional output directory for `saveAsTextFile`-style results.
    pub output: Option<String>,
    /// Minimum confidence for rule generation (only used by `rules`).
    pub min_conf: f64,
}

impl Default for EclatConfig {
    fn default() -> Self {
        EclatConfig {
            algorithm: "eclatV4".into(),
            dataset: "T10I4D100K".into(),
            min_sup: 0.01,
            cores: 0,
            partitions: 10,
            tri_matrix: None,
            backend: "native".into(),
            data_dir: "datasets".into(),
            output: None,
            min_conf: 0.8,
        }
    }
}

impl EclatConfig {
    /// Load from a TOML-subset file: top-level keys and/or a `[mining]`
    /// section; unknown keys are rejected (typo safety).
    pub fn from_file(path: &str) -> Result<EclatConfig> {
        let text = std::fs::read_to_string(path)?;
        let doc = TomlDoc::parse(&text)?;
        let mut cfg = EclatConfig::default();
        for (section, key, value) in doc.entries() {
            if !(section.is_empty() || section == "mining") {
                return Err(Error::config(format!("unknown section [{section}]")));
            }
            cfg.apply(key, value)?;
        }
        Ok(cfg)
    }

    /// Apply one key/value pair (shared by file and CLI paths).
    pub fn apply(&mut self, key: &str, value: &toml::Value) -> Result<()> {
        use toml::Value;
        let bad = |k: &str, v: &Value| Error::config(format!("bad value for {k}: {v:?}"));
        match key {
            "algorithm" | "algo" => {
                self.algorithm = value.as_str().ok_or_else(|| bad(key, value))?.to_string()
            }
            "dataset" => self.dataset = value.as_str().ok_or_else(|| bad(key, value))?.to_string(),
            "min_sup" => self.min_sup = value.as_f64().ok_or_else(|| bad(key, value))?,
            "min_conf" => self.min_conf = value.as_f64().ok_or_else(|| bad(key, value))?,
            "cores" => self.cores = value.as_int().ok_or_else(|| bad(key, value))? as usize,
            "partitions" | "p" => {
                self.partitions = value.as_int().ok_or_else(|| bad(key, value))? as usize
            }
            "tri_matrix" => {
                self.tri_matrix = Some(value.as_bool().ok_or_else(|| bad(key, value))?)
            }
            "backend" => {
                let b = value.as_str().ok_or_else(|| bad(key, value))?;
                if b != "native" && b != "xla" {
                    return Err(Error::config(format!("backend must be native|xla, got {b}")));
                }
                self.backend = b.to_string();
            }
            "data_dir" => {
                self.data_dir = value.as_str().ok_or_else(|| bad(key, value))?.to_string()
            }
            "output" => {
                self.output = Some(value.as_str().ok_or_else(|| bad(key, value))?.to_string())
            }
            other => return Err(Error::config(format!("unknown config key {other:?}"))),
        }
        Ok(())
    }

    /// Resolve `cores` (0 = all available) to a concrete executor count
    /// — the one place the 0-means-all convention is encoded.
    pub fn effective_cores(&self) -> usize {
        if self.cores == 0 {
            crate::engine::available_cores()
        } else {
            self.cores
        }
    }

    /// Resolve `min_sup` into the typed threshold.
    pub fn min_sup_typed(&self) -> Result<crate::fim::MinSup> {
        if self.min_sup <= 0.0 {
            Err(Error::config(format!("min_sup must be positive, got {}", self.min_sup)))
        } else if self.min_sup <= 1.0 {
            Ok(crate::fim::MinSup::fraction(self.min_sup))
        } else {
            Ok(crate::fim::MinSup::count(self.min_sup as u32))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = EclatConfig::default();
        assert_eq!(c.partitions, 10, "the paper's p");
        assert_eq!(c.backend, "native");
    }

    #[test]
    fn from_file_roundtrip() {
        let dir = std::env::temp_dir().join("rdd_eclat_conf_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.toml");
        std::fs::write(
            &path,
            r#"
# experiment config
algorithm = "eclatV5"

[mining]
dataset = "chess"
min_sup = 0.85
cores = 4
p = 12
tri_matrix = true
backend = "xla"
"#,
        )
        .unwrap();
        let c = EclatConfig::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.algorithm, "eclatV5");
        assert_eq!(c.dataset, "chess");
        assert!((c.min_sup - 0.85).abs() < 1e-12);
        assert_eq!(c.cores, 4);
        assert_eq!(c.partitions, 12);
        assert_eq!(c.tri_matrix, Some(true));
        assert_eq!(c.backend, "xla");
    }

    #[test]
    fn unknown_key_rejected() {
        let mut c = EclatConfig::default();
        let err = c.apply("typo_key", &toml::Value::Int(1)).unwrap_err();
        assert!(err.to_string().contains("unknown config key"));
    }

    #[test]
    fn bad_backend_rejected() {
        let mut c = EclatConfig::default();
        let err = c.apply("backend", &toml::Value::Str("gpu".into())).unwrap_err();
        assert!(err.to_string().contains("native|xla"));
    }

    #[test]
    fn effective_cores_resolves_zero() {
        let mut c = EclatConfig::default();
        assert!(c.effective_cores() >= 1, "0 means all available");
        c.cores = 3;
        assert_eq!(c.effective_cores(), 3);
    }

    #[test]
    fn min_sup_typed_interpretation() {
        let mut c = EclatConfig::default();
        c.min_sup = 0.05;
        assert_eq!(c.min_sup_typed().unwrap().to_count(100), 5);
        c.min_sup = 42.0;
        assert_eq!(c.min_sup_typed().unwrap().to_count(100), 42);
        c.min_sup = 0.0;
        assert!(c.min_sup_typed().is_err());
    }
}
