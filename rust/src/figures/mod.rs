//! Experiment drivers — one per table/figure of the paper's evaluation
//! (DESIGN.md §5 maps each to its experiment id). Every driver prints the
//! series the paper plots and writes a CSV under `results/`.
//!
//! Scale control: `SCALE=quick` (fast sanity sweep on truncated datasets,
//! used by `cargo bench` defaults) vs `SCALE=paper` (full Table 2 sizes).

use crate::algorithms::{Algorithm, EclatOptions, MiningSession, Variant};
use crate::bench::{Bench, Measurement, Report};
use crate::data::{Database, DatasetSpec, TABLE2};
use crate::engine::{simcluster, ClusterContext};
use crate::error::Result;
use crate::fim::MinSup;
use crate::util::stats::imbalance;
use crate::util::{Stopwatch, Summary};

/// Shared driver state.
pub struct FigureCtx {
    /// Measurement harness.
    pub bench: Bench,
    /// Dataset cache directory.
    pub data_dir: String,
    /// Executor cores for live runs.
    pub cores: usize,
    /// Quick mode truncates datasets (see [`FigureCtx::dataset`]).
    pub quick: bool,
}

impl FigureCtx {
    /// From environment (`SCALE`), with defaults.
    pub fn from_env() -> FigureCtx {
        let quick = matches!(std::env::var("SCALE").as_deref(), Ok("quick"));
        FigureCtx {
            // Full-scale mining runs take seconds-to-minutes each; one
            // sample per point keeps `figures --all` tractable (micro
            // benches use multi-sample Bench::from_env instead).
            bench: if quick { Bench::quick() } else { Bench { warmup: 0, samples: 1 } },
            data_dir: "datasets".into(),
            cores: crate::engine::available_cores(),
            quick,
        }
    }

    fn cluster(&self) -> ClusterContext {
        ClusterContext::builder().cores(self.cores).build()
    }

    /// Load (or generate) a dataset; quick mode truncates to keep sweeps
    /// fast while preserving per-transaction statistics.
    pub fn dataset(&self, spec: DatasetSpec) -> Result<Database> {
        let db = spec.materialize(&self.data_dir)?;
        if self.quick {
            let cap = match spec {
                DatasetSpec::Chess => 800,
                DatasetSpec::Mushroom | DatasetSpec::C20d10k => 2000,
                DatasetSpec::Bms1 | DatasetSpec::Bms2 => 8000,
                _ => 5000,
            };
            if db.len() > cap {
                return Ok(Database::from_rows(
                    db.transactions()[..cap].to_vec(),
                ));
            }
        }
        Ok(db)
    }

    /// The paper's per-dataset minimum-support grids (DESIGN.md §5; the
    /// paper's axes are images — grids chosen per dataset density, the
    /// T40 grid is quoted in its text).
    pub fn sup_grid(&self, spec: DatasetSpec) -> Vec<f64> {
        let full: Vec<f64> = match spec {
            DatasetSpec::C20d10k => vec![0.1, 0.08, 0.06, 0.04, 0.02],
            DatasetSpec::Chess => vec![0.95, 0.925, 0.9, 0.875, 0.85],
            DatasetSpec::Mushroom => vec![0.4, 0.35, 0.3, 0.25, 0.2],
            DatasetSpec::Bms1 | DatasetSpec::Bms2 => vec![0.01, 0.008, 0.006, 0.004, 0.002],
            DatasetSpec::T10i4d100k | DatasetSpec::T10i4Scaled(_) => {
                vec![0.05, 0.04, 0.03, 0.02, 0.01]
            }
            DatasetSpec::T40i10d100k => vec![0.04, 0.03, 0.02, 0.01],
        };
        if self.quick {
            // Endpoints only.
            vec![full[0], *full.last().unwrap()]
        } else {
            full
        }
    }

    /// The six algorithms of Figs 8–14(a) with the paper's settings for
    /// `spec` (`triMatrixMode` off for BMS1/2, `p = 10`) — built through
    /// the [`Variant`] registry, the same dispatch path as the CLI.
    pub fn standard_algos(&self, spec: DatasetSpec) -> Vec<Box<dyn Algorithm>> {
        let opts = EclatOptions {
            tri_matrix: spec.tri_matrix_mode(),
            ..Default::default()
        };
        Variant::STANDARD.iter().map(|v| v.build(&opts)).collect()
    }
}

/// Table 2: regenerate every dataset and report its statistics next to
/// the paper's targets.
pub fn run_table2(fx: &FigureCtx) -> Result<Report> {
    let mut report = Report::new();
    println!("\n== Table 2: dataset properties (generated twin vs paper target) ==");
    println!(
        "{:<16} {:>10} {:>10} {:>8} {:>12} {:>12} {:>9}",
        "dataset", "txns", "txns*", "items", "items*", "avg_width", "width*"
    );
    for spec in TABLE2 {
        let sw = Stopwatch::start();
        let db = fx.dataset(spec)?;
        let s = db.stats();
        let (t_txns, t_items, t_width) = spec.table2_row();
        println!(
            "{:<16} {:>10} {:>10} {:>8} {:>12} {:>12.2} {:>9.1}",
            spec.name(),
            s.transactions,
            t_txns,
            s.distinct_items,
            t_items,
            s.avg_width,
            t_width
        );
        report.add(Measurement {
            name: format!("table2/{}/generate", spec.name()),
            secs: Summary::of(&[sw.secs()]),
            allocs: None,
        });
    }
    report.write_csv("table2.csv")?;
    Ok(report)
}

/// Figs 8–14: execution time vs minimum support for one dataset, all six
/// algorithms (the (a) panels; the five Eclat rows are the (b) panels).
pub fn run_fig_minsup(fx: &FigureCtx, fig_no: u32, spec: DatasetSpec) -> Result<Report> {
    let db = fx.dataset(spec)?;
    let mut report = Report::new();
    println!(
        "\n== Fig {fig_no}: exec time vs min_sup on {} ({} txns) ==",
        spec.name(),
        db.len()
    );
    for algo in fx.standard_algos(spec) {
        for &sup in &fx.sup_grid(spec) {
            let ctx = fx.cluster();
            let m = fx.bench.try_run(
                format!("fig{fig_no}/{}/{}/sup={sup}", spec.name(), algo.name()),
                || algo.run_on(&ctx, &db, MinSup::fraction(sup)),
            )?;
            report.add(m);
        }
    }
    report.write_csv(&format!("fig{fig_no}_{}.csv", spec.name()))?;
    Ok(report)
}

/// Fig 15: execution time vs executor cores (simulated makespan from
/// measured task durations; DESIGN.md §2.3 documents the substitution).
pub fn run_fig15(fx: &FigureCtx) -> Result<Report> {
    let panels: Vec<(DatasetSpec, f64)> = vec![
        (DatasetSpec::C20d10k, 0.02),
        (DatasetSpec::Chess, 0.85),
        (DatasetSpec::Mushroom, 0.2),
        (DatasetSpec::T10i4d100k, 0.01),
        (DatasetSpec::T40i10d100k, 0.01),
    ];
    let cores_axis = [2usize, 4, 6, 8, 10];
    let mut report = Report::new();
    println!("\n== Fig 15: exec time vs executor cores (simulated from measured tasks) ==");
    for (spec, sup) in panels {
        let db = fx.dataset(spec)?;
        for algo in fx.standard_algos(spec).into_iter().take(5) {
            // Live run, recording per-task wall times.
            let ctx = fx.cluster();
            ctx.metrics().reset();
            let sw = Stopwatch::start();
            algo.run_on(&ctx, &db, MinSup::fraction(sup))?;
            let wall = sw.elapsed();
            let tasks = ctx.metrics().tasks();
            let serial = simcluster::derive_serial(&tasks, wall, ctx.cores());
            for r in simcluster::sweep(&tasks, &cores_axis, serial) {
                report.add(Measurement {
                    name: format!(
                        "fig15/{}/sup={sup}/{}/cores={}",
                        spec.name(),
                        algo.name(),
                        r.cores
                    ),
                    secs: Summary::of(&[r.makespan.as_secs_f64()]),
                    allocs: None,
                });
            }
        }
    }
    report.write_csv("fig15.csv")?;
    Ok(report)
}

/// Fig 16: execution time vs dataset size (T10I4D100K doubled up to
/// 1600K transactions) at min_sup = 0.05.
pub fn run_fig16(fx: &FigureCtx) -> Result<Report> {
    let max_k: u8 = if fx.quick { 2 } else { 4 };
    let mut report = Report::new();
    println!("\n== Fig 16: exec time vs dataset size (T10I4, min_sup=0.05) ==");
    for k in 0..=max_k {
        let spec = DatasetSpec::T10i4Scaled(k);
        let db = fx.dataset(spec)?;
        for algo in fx.standard_algos(spec).into_iter().take(5) {
            let ctx = fx.cluster();
            let m = fx.bench.try_run(
                format!("fig16/{}/{}/txns={}", spec.name(), algo.name(), db.len()),
                || algo.run_on(&ctx, &db, MinSup::fraction(0.05)),
            )?;
            report.add(m);
        }
    }
    report.write_csv("fig16.csv")?;
    Ok(report)
}

/// A1 (§5.2.1): filtered-transaction shrinkage on T40I10D100K — the paper
/// quotes reductions of 3.2/8.4/16.1/25.8 % at min_sup 0.01–0.04.
pub fn run_a1(fx: &FigureCtx) -> Result<Report> {
    let spec = DatasetSpec::T40i10d100k;
    let db = fx.dataset(spec)?;
    let mut report = Report::new();
    println!("\n== A1: transaction-filtering shrinkage on T40I10D100K ==");
    println!("paper quotes: sup 0.01→3.2%, 0.02→8.4%, 0.03→16.1%, 0.04→25.8%");
    let v2 = Variant::V2.build(&EclatOptions::default());
    for sup in [0.01, 0.02, 0.03, 0.04] {
        let ctx = fx.cluster();
        let r = v2.run_on(&ctx, &db, MinSup::fraction(sup))?;
        let red = r.filtered_reduction.unwrap_or(0.0);
        println!("  sup={sup}: filtered size reduced by {:.1}%", red * 100.0);
        report.add(Measurement {
            name: format!("a1/T40I10D100K/sup={sup}/reduction_pct={:.2}", red * 100.0),
            secs: Summary::of(&[red]),
            allocs: None,
        });
    }
    report.write_csv("a1_filtering.csv")?;
    Ok(report)
}

/// A2 (§4.5): equivalence-class workload balance across the three
/// partitioners, measured as members-per-partition imbalance (max/mean).
pub fn run_a2(fx: &FigureCtx) -> Result<Report> {
    let spec = DatasetSpec::T10i4d100k;
    let db = fx.dataset(spec)?;
    let sup = if fx.quick { 0.02 } else { 0.01 };
    let mut report = Report::new();
    println!("\n== A2: partitioner workload balance on {} (sup={sup}) ==", spec.name());
    // V3 = default (n-1) partitioner, V4 = hash %p, V5 = reverse hash.
    let algos: Vec<Box<dyn Algorithm>> = [Variant::V3, Variant::V4, Variant::V5]
        .iter()
        .map(|v| v.build(&EclatOptions::default()))
        .collect();
    for algo in algos {
        let ctx = fx.cluster();
        let r = algo.run_on(&ctx, &db, MinSup::fraction(sup))?;
        let imb = imbalance(&r.partition_loads);
        let nonzero = r.partition_loads.iter().filter(|&&l| l > 0).count();
        println!(
            "  {:<8} partitions={:<5} nonzero={:<5} imbalance(max/mean)={:.3}",
            algo.name(),
            r.partition_loads.len(),
            nonzero,
            imb
        );
        report.add(Measurement {
            name: format!(
                "a2/{}/partitions={}/imbalance={imb:.4}",
                algo.name(),
                r.partition_loads.len()
            ),
            secs: Summary::of(&[imb]),
            allocs: None,
        });
    }
    report.write_csv("a2_partitioners.csv")?;
    Ok(report)
}

/// A3: triangular-matrix on/off ablation, driven through the
/// [`MiningSession`] façade (one session per setting, re-run per sample).
pub fn run_a3(fx: &FigureCtx) -> Result<Report> {
    let mut report = Report::new();
    println!("\n== A3: triMatrixMode on/off ==");
    for (spec, sup) in [(DatasetSpec::C20d10k, 0.1), (DatasetSpec::T10i4d100k, 0.01)] {
        let db = fx.dataset(spec)?;
        for tri in [true, false] {
            let ctx = fx.cluster();
            let session = MiningSession::on(&ctx)
                .db(&db)
                .min_sup(MinSup::fraction(sup))
                .tri_matrix(tri);
            let m = fx.bench.try_run(
                format!("a3/{}/sup={sup}/tri={tri}", spec.name()),
                || session.run(Variant::V4),
            )?;
            report.add(m);
        }
    }
    report.write_csv("a3_trimatrix.csv")?;
    Ok(report)
}

/// A4: native vs XLA (AOT PJRT artifact) backends for the Phase-2
/// co-occurrence and batched tidset intersection. Skips (with a notice)
/// when `make artifacts` has not run, or when the crate was built
/// without the `xla` feature.
#[cfg(feature = "xla")]
pub fn run_a4(fx: &FigureCtx) -> Result<Report> {
    use std::sync::Arc;

    use crate::algorithms::common::NativeCooc;
    use crate::algorithms::TriMatrixProvider;
    use crate::fim::TidBitmap;
    use crate::runtime::{XlaCooc, XlaIntersect, XlaService};

    let mut report = Report::new();
    println!("\n== A4: native vs XLA backend ==");
    if !crate::runtime::artifacts_available() {
        println!("  artifacts/ missing — run `make artifacts`; skipping A4");
        return Ok(report);
    }
    let svc = Arc::new(XlaService::start(crate::runtime::default_artifact_dir())?);

    // Co-occurrence over a mid-sized block of chess-like transactions.
    let db = fx.dataset(DatasetSpec::Chess)?;
    let max_item = db.stats().max_item;
    let txns = db.transactions().to_vec();
    let native = NativeCooc;
    let xla = XlaCooc::new(Arc::clone(&svc));
    let a = fx.bench.try_run("a4/cooc/native", || native.compute(&txns, max_item))?;
    report.add(a);
    let b = fx.bench.try_run("a4/cooc/xla", || xla.compute(&txns, max_item))?;
    report.add(b);
    // Equality spot check.
    assert_eq!(
        native.compute(&txns, max_item)?,
        xla.compute(&txns, max_item)?,
        "backends disagree"
    );

    // Batched intersection.
    let universe = 2048usize;
    let mut rng = crate::util::prng::Rng::new(99);
    let bitmaps: Vec<(TidBitmap, TidBitmap)> = (0..512)
        .map(|_| {
            let mk = |rng: &mut crate::util::prng::Rng| {
                TidBitmap::from_tids(
                    universe,
                    (0..universe as u32).filter(|_| rng.chance(0.2)),
                )
            };
            (mk(&mut rng), mk(&mut rng))
        })
        .collect();
    let pairs: Vec<(&TidBitmap, &TidBitmap)> = bitmaps.iter().map(|(a, b)| (a, b)).collect();
    let xi = XlaIntersect::new(svc);
    let m = fx.bench.run("a4/intersect/native", || {
        pairs.iter().map(|(a, b)| a.and_count(b)).collect::<Vec<_>>()
    });
    report.add(m);
    let m = fx.bench.try_run("a4/intersect/xla", || xi.batch_supports(&pairs))?;
    report.add(m);

    report.write_csv("a4_backend.csv")?;
    Ok(report)
}

/// A4 placeholder for default builds: the XLA backend is feature-gated.
#[cfg(not(feature = "xla"))]
pub fn run_a4(_fx: &FigureCtx) -> Result<Report> {
    println!("\n== A4: native vs XLA backend ==");
    println!("  built without the `xla` feature — rebuild with `--features xla`; skipping A4");
    Ok(Report::new())
}

/// The seven min-sup figures in paper order.
pub const MINSUP_FIGS: [(u32, DatasetSpec); 7] = [
    (8, DatasetSpec::C20d10k),
    (9, DatasetSpec::Chess),
    (10, DatasetSpec::Mushroom),
    (11, DatasetSpec::Bms1),
    (12, DatasetSpec::Bms2),
    (13, DatasetSpec::T10i4d100k),
    (14, DatasetSpec::T40i10d100k),
];

/// Run one experiment by id (`table2`, `8`..`16`, `a1`..`a4`, `all`).
pub fn run_by_id(fx: &FigureCtx, id: &str) -> Result<()> {
    match id {
        "table2" => {
            run_table2(fx)?;
        }
        "15" => {
            run_fig15(fx)?;
        }
        "16" => {
            run_fig16(fx)?;
        }
        "a1" => {
            run_a1(fx)?;
        }
        "a2" => {
            run_a2(fx)?;
        }
        "a3" => {
            run_a3(fx)?;
        }
        "a4" => {
            run_a4(fx)?;
        }
        "all" => {
            run_table2(fx)?;
            for (no, spec) in MINSUP_FIGS {
                run_fig_minsup(fx, no, spec)?;
            }
            run_fig15(fx)?;
            run_fig16(fx)?;
            run_a1(fx)?;
            run_a2(fx)?;
            run_a3(fx)?;
            run_a4(fx)?;
        }
        other => {
            let fig: u32 = other
                .parse()
                .map_err(|_| crate::error::Error::Usage(format!("unknown figure id {other:?}")))?;
            let spec = MINSUP_FIGS
                .iter()
                .find(|(no, _)| *no == fig)
                .map(|(_, s)| *s)
                .ok_or_else(|| crate::error::Error::Usage(format!("no figure {fig}")))?;
            run_fig_minsup(fx, fig, spec)?;
        }
    }
    Ok(())
}

/// Convenience for tests: a tiny quick-mode context.
pub fn quick_ctx() -> FigureCtx {
    FigureCtx {
        bench: Bench::quick(),
        data_dir: std::env::temp_dir()
            .join("rdd_eclat_fig_cache")
            .to_string_lossy()
            .into_owned(),
        cores: 2,
        quick: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sup_grids_are_descending() {
        let mut fx = quick_ctx();
        fx.quick = false;
        for spec in TABLE2 {
            let grid = fx.sup_grid(spec);
            for w in grid.windows(2) {
                assert!(w[0] > w[1], "{spec:?} grid not descending");
            }
        }
    }

    #[test]
    fn quick_dataset_truncates() {
        let fx = quick_ctx();
        let db = fx.dataset(DatasetSpec::Chess).unwrap();
        assert!(db.len() <= 800);
    }

    #[test]
    fn a2_runs_and_reports_three_partitioners() {
        let fx = quick_ctx();
        let r = run_a2(&fx).unwrap();
        assert_eq!(r.rows().len(), 3);
    }
}
