//! Output sinks for the mining emission path.
//!
//! Every miner in the crate (the arena bottom-up search, the equivalence
//! classes, the sequential oracles, the RDD variants' Phase-3 tasks, the
//! streaming delta re-mine) emits frequent itemsets through one trait,
//! [`FrequentSink`], instead of pushing into a hard-wired
//! `Vec<Frequent>`. The sink decides what an emission costs:
//!
//! * [`CollectSink`] / `Vec<Frequent>` — materialize every itemset (the
//!   pre-redesign behavior and the compatibility default; one heap
//!   allocation per emitted itemset).
//! * [`PooledSink`] — a flat arena: one shared items buffer plus
//!   `(offset, len, support)` records. Zero allocations per emission in
//!   steady state (buffers grow to the high-water mark and are reused
//!   across [`PooledSink::clear`]), summable across partitions with
//!   [`PooledSink::absorb`], and decodable back to [`Frequent`]s.
//! * [`TopKSink`] — a bounded min-heap keeping only the `k` strongest
//!   patterns (the serving workload: "top rules now", without
//!   materializing the full result).
//! * [`CountSink`] — cardinality only; nothing is stored.
//!
//! The `items` slice passed to [`FrequentSink::emit`] is only valid for
//! the duration of the call (miners reuse the buffer), so sinks that
//! keep itemsets must copy it out.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::itemset::{Frequent, Item};

/// Receiver for mined frequent itemsets.
///
/// `items` is sorted ascending and borrowed from the miner's reusable
/// emission buffer — copy it if the sink outlives the call.
pub trait FrequentSink {
    /// Record one frequent itemset with its support count.
    fn emit(&mut self, items: &[Item], support: u32);
}

/// The compatibility default: every emission becomes an owned
/// [`Frequent`]. Existing APIs that return `Vec<Frequent>` are thin
/// wrappers over this impl.
impl FrequentSink for Vec<Frequent> {
    fn emit(&mut self, items: &[Item], support: u32) {
        self.push(Frequent::new(items.to_vec(), support));
    }
}

/// Named wrapper over the `Vec<Frequent>` sink, for call sites that want
/// the sink spelled out (`CollectSink::new()` … `into_vec()`).
#[derive(Debug, Clone, Default)]
pub struct CollectSink {
    /// The collected itemsets, in emission order.
    pub frequents: Vec<Frequent>,
}

impl CollectSink {
    /// Empty sink.
    pub fn new() -> CollectSink {
        CollectSink::default()
    }

    /// Unwrap the collected itemsets.
    pub fn into_vec(self) -> Vec<Frequent> {
        self.frequents
    }
}

impl FrequentSink for CollectSink {
    fn emit(&mut self, items: &[Item], support: u32) {
        self.frequents.emit(items, support);
    }
}

/// Flat-arena sink: one shared items buffer plus `(offset, len,
/// support)` records — the ROADMAP "emit pooling" representation.
///
/// In steady state (after [`PooledSink::clear`], with capacity from a
/// previous run) an emission is two `extend`s into warm buffers: **zero
/// heap allocations**, measured by the `emission/pooled_vs_collect`
/// rows of `benches/fim_micro.rs` under `--features alloc-count`.
///
/// Per-partition pools are summed with [`PooledSink::absorb`] and
/// decoded driver-side with [`PooledSink::decode`] or replayed into
/// another sink with [`PooledSink::replay`].
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct PooledSink {
    /// All emitted itemsets, concatenated.
    items: Vec<Item>,
    /// One `(offset, len, support)` record per emission.
    records: Vec<(usize, u32, u32)>,
}

impl PooledSink {
    /// Empty pool.
    pub fn new() -> PooledSink {
        PooledSink::default()
    }

    /// Empty pool with pre-sized buffers: `arena` items and `records`
    /// emissions. For callers that know the scale of a run up front —
    /// e.g. a sharded streaming mine task re-mining a class group whose
    /// previous emission sizes are known — so the warm-up growth of
    /// [`PooledSink::new`] is skipped entirely.
    pub fn with_capacity(arena: usize, records: usize) -> PooledSink {
        PooledSink {
            items: Vec::with_capacity(arena),
            records: Vec::with_capacity(records),
        }
    }

    /// Number of emitted itemsets.
    pub fn len(&self) -> usize {
        self.records.len()
    }

    /// True when nothing has been emitted.
    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Total items held in the arena (diagnostics / sizing).
    pub fn arena_len(&self) -> usize {
        self.items.len()
    }

    /// Forget all emissions but keep the buffers — the steady-state
    /// reuse entry point.
    pub fn clear(&mut self) {
        self.items.clear();
        self.records.clear();
    }

    /// The `i`-th emission as `(items, support)`.
    pub fn get(&self, i: usize) -> (&[Item], u32) {
        let (off, len, support) = self.records[i];
        (&self.items[off..off + len as usize], support)
    }

    /// Iterate emissions in order as `(items, support)`.
    pub fn iter(&self) -> impl Iterator<Item = (&[Item], u32)> {
        self.records.iter().map(|&(off, len, support)| {
            (&self.items[off..off + len as usize], support)
        })
    }

    /// Append every emission of `other` (per-partition summation; the
    /// records are re-based onto this pool's arena).
    pub fn absorb(&mut self, other: &PooledSink) {
        for (items, support) in other.iter() {
            self.emit(items, support);
        }
    }

    /// Re-emit every record into another sink (e.g. decode a shipped
    /// per-partition pool into the driver's output).
    pub fn replay<S: FrequentSink + ?Sized>(&self, out: &mut S) {
        for (items, support) in self.iter() {
            out.emit(items, support);
        }
    }

    /// Materialize owned [`Frequent`]s (the boundary where the
    /// allocation-free representation ends by design).
    pub fn decode(&self) -> Vec<Frequent> {
        self.iter().map(|(items, support)| Frequent::new(items.to_vec(), support)).collect()
    }
}

impl FrequentSink for PooledSink {
    fn emit(&mut self, items: &[Item], support: u32) {
        let off = self.items.len();
        self.items.extend_from_slice(items);
        self.records.push((off, items.len() as u32, support));
    }
}

/// Strength order used by [`TopKSink`] and its sort-then-truncate
/// oracle: higher support first, then shorter itemsets, then
/// lexicographically smaller items. Returns `Greater` when `a` is the
/// stronger pattern.
fn strength(a_items: &[Item], a_support: u32, b_items: &[Item], b_support: u32) -> Ordering {
    a_support
        .cmp(&b_support)
        .then_with(|| b_items.len().cmp(&a_items.len()))
        .then_with(|| b_items.cmp(a_items))
}

/// Heap entry ordered by [`strength`].
#[derive(Debug, Clone, PartialEq, Eq)]
struct Ranked(Frequent);

impl Ord for Ranked {
    fn cmp(&self, other: &Self) -> Ordering {
        strength(&self.0.items, self.0.support, &other.0.items, other.0.support)
    }
}

impl PartialOrd for Ranked {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Bounded sink keeping only the `k` strongest patterns (by support,
/// ties broken toward shorter then lexicographically smaller itemsets —
/// a total order, so the result is deterministic and equals the
/// sort-then-truncate oracle).
///
/// A weak emission costs one comparison against the current weakest
/// kept pattern and nothing else; only emissions that enter the top-k
/// allocate.
#[derive(Debug, Clone)]
pub struct TopKSink {
    k: usize,
    /// Min-heap over strength: the root is the weakest kept pattern.
    heap: BinaryHeap<std::cmp::Reverse<Ranked>>,
}

impl TopKSink {
    /// Keep the `k` strongest emissions.
    pub fn new(k: usize) -> TopKSink {
        TopKSink { k, heap: BinaryHeap::with_capacity(k.min(1024)) }
    }

    /// Configured bound.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Patterns currently held (≤ `k`).
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when nothing has been kept.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// The kept patterns, strongest first.
    pub fn into_sorted(self) -> Vec<Frequent> {
        self.heap.into_sorted_vec().into_iter().map(|std::cmp::Reverse(r)| r.0).collect()
    }
}

impl FrequentSink for TopKSink {
    fn emit(&mut self, items: &[Item], support: u32) {
        if self.k == 0 {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push(std::cmp::Reverse(Ranked(Frequent::new(items.to_vec(), support))));
            return;
        }
        let weakest = &self.heap.peek().expect("non-empty at capacity").0 .0;
        if strength(items, support, &weakest.items, weakest.support) == Ordering::Greater {
            self.heap.pop();
            self.heap.push(std::cmp::Reverse(Ranked(Frequent::new(items.to_vec(), support))));
        }
    }
}

/// Counts emissions without storing anything — pattern-count probes
/// (e.g. threshold calibration) at zero memory.
#[derive(Debug, Clone, Copy, Default)]
pub struct CountSink {
    /// Number of itemsets emitted.
    pub count: u64,
    /// Length of the longest emitted itemset.
    pub max_len: usize,
}

impl CountSink {
    /// Zeroed counter.
    pub fn new() -> CountSink {
        CountSink::default()
    }
}

impl FrequentSink for CountSink {
    fn emit(&mut self, items: &[Item], _support: u32) {
        self.count += 1;
        self.max_len = self.max_len.max(items.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn feed(sink: &mut impl FrequentSink) {
        sink.emit(&[1], 5);
        sink.emit(&[2], 4);
        sink.emit(&[1, 2], 4);
        sink.emit(&[3], 2);
        sink.emit(&[1, 2, 3], 2);
    }

    #[test]
    fn vec_and_collect_sinks_agree() {
        let mut v: Vec<Frequent> = Vec::new();
        let mut c = CollectSink::new();
        feed(&mut v);
        feed(&mut c);
        assert_eq!(v, c.into_vec());
        assert_eq!(v[0], Frequent::new(vec![1], 5));
    }

    #[test]
    fn pooled_round_trips_and_reuses_capacity() {
        let mut p = PooledSink::new();
        let mut v: Vec<Frequent> = Vec::new();
        feed(&mut p);
        feed(&mut v);
        assert_eq!(p.len(), v.len());
        assert_eq!(p.decode(), v);
        assert_eq!(p.get(2), (&[1u32, 2][..], 4));
        // clear() keeps capacity; refilling identical content must not grow.
        let (ic, rc) = (p.items.capacity(), p.records.capacity());
        p.clear();
        assert!(p.is_empty());
        feed(&mut p);
        assert_eq!(p.items.capacity(), ic);
        assert_eq!(p.records.capacity(), rc);
        assert_eq!(p.decode(), v);
    }

    #[test]
    fn pooled_with_capacity_presizes_and_behaves_identically() {
        let mut p = PooledSink::with_capacity(16, 8);
        assert!(p.is_empty());
        assert!(p.items.capacity() >= 16);
        assert!(p.records.capacity() >= 8);
        let (ic, rc) = (p.items.capacity(), p.records.capacity());
        feed(&mut p);
        // feed() emits 9 items over 5 records — within the presized
        // buffers, so no growth.
        assert_eq!(p.items.capacity(), ic);
        assert_eq!(p.records.capacity(), rc);
        let mut fresh = PooledSink::new();
        feed(&mut fresh);
        assert_eq!(p.decode(), fresh.decode());
    }

    #[test]
    fn pooled_absorb_and_replay_preserve_all_records() {
        let mut a = PooledSink::new();
        a.emit(&[7], 3);
        let mut b = PooledSink::new();
        b.emit(&[8, 9], 2);
        b.emit(&[9], 6);
        a.absorb(&b);
        assert_eq!(a.len(), 3);
        let mut out: Vec<Frequent> = Vec::new();
        a.replay(&mut out);
        assert_eq!(
            out,
            vec![
                Frequent::new(vec![7], 3),
                Frequent::new(vec![8, 9], 2),
                Frequent::new(vec![9], 6),
            ]
        );
    }

    #[test]
    fn topk_matches_sort_then_truncate_oracle() {
        let mut all: Vec<Frequent> = Vec::new();
        feed(&mut all);
        for k in 0..=6 {
            let mut sink = TopKSink::new(k);
            feed(&mut sink);
            let mut want = all.clone();
            want.sort_by(|a, b| strength(&b.items, b.support, &a.items, a.support));
            want.truncate(k);
            assert_eq!(sink.into_sorted(), want, "k={k}");
        }
    }

    #[test]
    fn topk_tie_break_is_deterministic() {
        // All supports equal: shorter itemsets win, then lex order.
        let mut sink = TopKSink::new(2);
        sink.emit(&[5, 6], 3);
        sink.emit(&[9], 3);
        sink.emit(&[2], 3);
        sink.emit(&[1, 2, 3], 3);
        assert_eq!(
            sink.into_sorted(),
            vec![Frequent::new(vec![2], 3), Frequent::new(vec![9], 3)]
        );
    }

    #[test]
    fn count_sink_counts() {
        let mut c = CountSink::new();
        feed(&mut c);
        assert_eq!(c.count, 5);
        assert_eq!(c.max_len, 3);
    }
}
