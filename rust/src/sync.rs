//! Loom-aware synchronization shim — the crate's single doorway to
//! `std::sync` / `std::thread` primitives.
//!
//! Every hand-rolled concurrent structure — the double-buffered snapshot
//! cell in [`crate::stream::serve`], the metric cells in [`crate::obs`]
//! (registry counters/gauges/histograms and the span `EventRing`), the
//! executor queue in [`crate::engine::pool`], the map-output store in
//! [`crate::engine::shuffle`], and the wire/transport layer in
//! [`crate::net`] — imports its primitives from here instead
//! of from `std`. Under an ordinary build the re-exports *are* the `std`
//! types (zero cost). Under `RUSTFLAGS="--cfg loom"` they become the
//! [loom](https://docs.rs/loom) model checker's instrumented twins, and
//! the model suite (`tests/loom_models.rs` plus the
//! `#[cfg(all(loom, test))]` unit mods in `serve.rs` / `span.rs`)
//! exhaustively explores the interleavings of those structures'
//! protocols under the C11 memory model — including weak-memory
//! reorderings that hammer tests on x86 can never exhibit.
//!
//! The crate lint (`cargo run --bin lint`, rule `shim-imports`) enforces
//! that the shimmed modules never import `std::sync` / `std::thread`
//! directly, so new concurrency added to those files stays
//! loom-checkable by construction.
//!
//! ## What deliberately stays `std`: the [`global`] plane
//!
//! loom types cannot be constructed in `const` context and panic when
//! touched outside `loom::model`, so the **registration plane** —
//! process-wide statics such as the metric registration maps, the span
//! event ring and thread-name table, and the trace epoch — keeps using
//! `std` primitives via the [`global`] submodule. That plane is
//! `Mutex`-serialized bookkeeping, not a lock-free protocol; the loom
//! models instead construct the cells they check *inside* the model.
//! The same reasoning covers [`mpsc`]: loom has no channel model, and
//! the only channel left in the crate
//! ([`crate::engine::pool::ThreadPool::try_run_all`]'s result gather) is
//! sequential driver-side code.
//!
//! ## Poison recovery
//!
//! [`lock_unpoisoned`] / [`read_unpoisoned`] / [`write_unpoisoned`] are
//! the canonical PR-8 poison-recovery helpers: a panicked task must not
//! cascade into every other thread touching a shared structure whose
//! data is still consistent (all guarded sections in this crate mutate
//! whole entries, never leave partial states). The lint rule
//! `bare-lock-unwrap` forbids `.lock().unwrap()` and friends outside
//! these helpers so the recovery policy cannot silently regress.

#[cfg(loom)]
pub use loom::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
#[cfg(not(loom))]
pub use std::sync::{Arc, Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};

// loom's lock APIs return the std poison types, so these are shared.
pub use std::sync::{LockResult, PoisonError};

/// Atomic types and [`Ordering`](atomic::Ordering). loom re-exports the
/// std `Ordering` enum, so `Ordering` is the same type either way.
pub mod atomic {
    #[cfg(loom)]
    pub use loom::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering};
    #[cfg(not(loom))]
    pub use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, AtomicUsize, Ordering};
}

/// `UnsafeCell` with loom's closure-based access API.
///
/// loom's `UnsafeCell` only exposes `with` / `with_mut` (so the checker
/// can observe every raw access and flag concurrent conflicting ones —
/// this is exactly how the serve-layer models detect a torn snapshot).
/// The std wrapper mirrors that shape at zero cost.
pub mod cell {
    #[cfg(loom)]
    pub use loom::cell::UnsafeCell;

    /// Mirror of `loom::cell::UnsafeCell` over `std::cell::UnsafeCell`.
    #[cfg(not(loom))]
    #[derive(Debug)]
    pub struct UnsafeCell<T>(std::cell::UnsafeCell<T>);

    #[cfg(not(loom))]
    impl<T> UnsafeCell<T> {
        /// Wrap a value.
        pub const fn new(value: T) -> Self {
            UnsafeCell(std::cell::UnsafeCell::new(value))
        }

        /// Run `f` with a raw const pointer to the contents. The caller
        /// must uphold the aliasing rules exactly as with
        /// `std::cell::UnsafeCell::get`.
        #[inline]
        pub fn with<R>(&self, f: impl FnOnce(*const T) -> R) -> R {
            f(self.0.get())
        }

        /// Run `f` with a raw mut pointer to the contents. Same contract
        /// as [`UnsafeCell::with`], plus exclusivity.
        #[inline]
        pub fn with_mut<R>(&self, f: impl FnOnce(*mut T) -> R) -> R {
            f(self.0.get())
        }
    }
}

/// Spin-loop hint. Under loom a real spin would livelock the model (the
/// checker controls scheduling), so it maps to `loom::thread::yield_now`,
/// which also tells loom the thread cannot make progress alone.
pub mod hint {
    #[cfg(not(loom))]
    pub use std::hint::spin_loop;

    #[cfg(loom)]
    pub fn spin_loop() {
        loom::thread::yield_now();
    }
}

/// Thread spawning for shimmed modules and loom models.
pub mod thread {
    #[cfg(loom)]
    pub use loom::thread::{spawn, yield_now, Builder, JoinHandle};
    #[cfg(not(loom))]
    pub use std::thread::{spawn, yield_now, Builder, JoinHandle};
}

/// Channels stay `std` unconditionally: loom has no channel model, and
/// the crate's only remaining channel use is sequential result
/// gathering on the driver ([`crate::engine::pool::ThreadPool::try_run_all`]),
/// which no loom model executes.
pub mod mpsc {
    pub use std::sync::mpsc::{channel, Receiver, Sender};
}

/// The registration plane: `std` primitives for process-wide statics.
///
/// loom types are not const-constructible and panic outside a model, so
/// anything that must live in a `static` — metric registration maps,
/// the span ring, the trace epoch — uses these instead of the shimmed
/// types above. Code on this plane is plain mutex-serialized
/// bookkeeping; the loom suite checks the *cells* (constructed inside
/// models), not the registration maps.
pub mod global {
    pub use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
    pub use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};

    /// Poison-tolerant lock for registration-plane statics; see
    /// [`crate::sync::lock_unpoisoned`] for the policy.
    pub fn lock_unpoisoned<T>(mutex: &Mutex<T>) -> MutexGuard<'_, T> {
        mutex.lock().unwrap_or_else(PoisonError::into_inner)
    }
}

/// Poison-tolerant `Mutex::lock`: recover the guard from a poisoned
/// mutex instead of propagating the sibling thread's panic. Appropriate
/// whenever every guarded section keeps the data consistent (inserts /
/// removes whole entries); the panic itself is reported through the
/// scheduler's own channels, so re-throwing here would only cascade.
pub fn lock_unpoisoned<T>(mutex: &Mutex<T>) -> MutexGuard<'_, T> {
    mutex.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Poison-tolerant `RwLock::read`; see [`lock_unpoisoned`].
pub fn read_unpoisoned<T>(lock: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    lock.read().unwrap_or_else(PoisonError::into_inner)
}

/// Poison-tolerant `RwLock::write`; see [`lock_unpoisoned`].
pub fn write_unpoisoned<T>(lock: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    lock.write().unwrap_or_else(PoisonError::into_inner)
}

/// `fetch_max` on [`atomic::AtomicU64`]. Native under std; under loom it
/// is emulated with a compare-exchange loop because loom does not model
/// `fetch_max` directly. Callers pass the ordering they need for the
/// *success* case; the emulation's failure reloads are `Relaxed`.
#[inline]
pub fn fetch_max_u64(cell: &atomic::AtomicU64, value: u64, order: atomic::Ordering) -> u64 {
    #[cfg(not(loom))]
    {
        cell.fetch_max(value, order)
    }
    #[cfg(loom)]
    {
        // ordering: Relaxed — optimistic first read; the CAS below is
        // what carries the caller's ordering.
        let mut current = cell.load(atomic::Ordering::Relaxed);
        loop {
            if current >= value {
                return current;
            }
            // ordering: Relaxed on failure — a failed CAS publishes
            // nothing; success uses the caller's `order`.
            match cell.compare_exchange(current, value, order, atomic::Ordering::Relaxed) {
                Ok(previous) => return previous,
                Err(previous) => current = previous,
            }
        }
    }
}

/// `fetch_max` on [`atomic::AtomicI64`]; see [`fetch_max_u64`].
#[inline]
pub fn fetch_max_i64(cell: &atomic::AtomicI64, value: i64, order: atomic::Ordering) -> i64 {
    #[cfg(not(loom))]
    {
        cell.fetch_max(value, order)
    }
    #[cfg(loom)]
    {
        // ordering: Relaxed — see `fetch_max_u64`.
        let mut current = cell.load(atomic::Ordering::Relaxed);
        loop {
            if current >= value {
                return current;
            }
            // ordering: Relaxed on failure — see `fetch_max_u64`.
            match cell.compare_exchange(current, value, order, atomic::Ordering::Relaxed) {
                Ok(previous) => return previous,
                Err(previous) => current = previous,
            }
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};

    #[test]
    fn lock_unpoisoned_recovers_after_holder_panics() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = catch_unwind(AssertUnwindSafe(move || {
            let _guard = m2.lock().unwrap();
            panic!("poison it");
        }));
        assert!(m.lock().is_err(), "mutex must actually be poisoned");
        // Round-trip: recover, mutate, recover again, observe.
        *lock_unpoisoned(&m) = 8;
        assert_eq!(*lock_unpoisoned(&m), 8);
    }

    #[test]
    fn rwlock_helpers_recover_after_writer_panics() {
        let l = Arc::new(RwLock::new(vec![1, 2, 3]));
        let l2 = Arc::clone(&l);
        std::thread::spawn(move || {
            let _guard = l2.write().unwrap();
            panic!("poison it");
        })
        .join()
        .unwrap_err();
        assert!(l.read().is_err(), "rwlock must actually be poisoned");
        assert_eq!(read_unpoisoned(&l).len(), 3);
        write_unpoisoned(&l).push(4);
        assert_eq!(*read_unpoisoned(&l), vec![1, 2, 3, 4]);
    }

    #[test]
    fn global_lock_unpoisoned_recovers() {
        static CELL: global::Mutex<u32> = global::Mutex::new(1);
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let _guard = CELL.lock().unwrap();
            panic!("poison it");
        }));
        assert_eq!(*global::lock_unpoisoned(&CELL), 1);
    }

    #[test]
    fn unsafe_cell_with_and_with_mut_round_trip() {
        let cell = cell::UnsafeCell::new(10u64);
        // SAFETY: single-threaded test — no concurrent access to the cell.
        cell.with_mut(|p| unsafe { *p += 5 });
        // SAFETY: as above; shared read with no live mutable pointer.
        let v = cell.with(|p| unsafe { *p });
        assert_eq!(v, 15);
    }

    #[test]
    fn fetch_max_helpers_keep_the_maximum() {
        let u = atomic::AtomicU64::new(5);
        assert_eq!(fetch_max_u64(&u, 3, atomic::Ordering::Relaxed), 5);
        assert_eq!(fetch_max_u64(&u, 9, atomic::Ordering::Relaxed), 5);
        assert_eq!(u.load(atomic::Ordering::Relaxed), 9);
        let i = atomic::AtomicI64::new(-2);
        assert_eq!(fetch_max_i64(&i, -5, atomic::Ordering::Relaxed), -2);
        assert_eq!(fetch_max_i64(&i, 4, atomic::Ordering::Relaxed), -2);
        assert_eq!(i.load(atomic::Ordering::Relaxed), 4);
    }
}
