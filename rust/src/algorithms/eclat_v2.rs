//! EclatV2 (paper §4.2, Algorithms 5–7 + 4): EclatV1 plus Borgelt's
//! filtered-transaction technique.
//!
//! * **Phase-1**: word-count frequent items (`flatMap` → `mapToPair` →
//!   `reduceByKey` → `filter`), collected and sorted.
//! * **Phase-2**: broadcast the frequent-item trie; `map` every
//!   transaction through the filter; accumulate the triangular matrix
//!   over the *filtered* transactions.
//! * **Phase-3**: vertical dataset from the filtered transactions
//!   (`coalesce(1)` → `flatMapToPair` → `groupByKey`), sorted ascending
//!   by support.
//! * **Phase-4**: identical to EclatV1's Phase-3 (default partitioner).

use std::sync::Arc;

use crate::engine::ClusterContext;
use crate::error::Result;
use crate::fim::{Database, Frequent, ItemFilter, MinSup};

use super::common::{
    mine_equivalence_classes, phase1_wordcount, phase2_trimatrix, phase3_vertical_grouped,
    transactions_rdd,
};
use super::partitioners::DefaultClassPartitioner;
use super::{Algorithm, EclatOptions, FimResult};

/// EclatV2 (see module docs).
#[derive(Debug, Clone, Default)]
pub struct EclatV2 {
    /// Shared variant options.
    pub options: EclatOptions,
}

impl EclatV2 {
    /// With explicit options.
    pub fn with_options(options: EclatOptions) -> Self {
        EclatV2 { options }
    }
}

impl Algorithm for EclatV2 {
    fn name(&self) -> &'static str {
        "eclatV2"
    }

    fn run_on(&self, ctx: &ClusterContext, db: &Database, min_sup: MinSup) -> Result<FimResult> {
        let min_sup = min_sup.to_count(db.len());
        let mut run = FimResult::builder(self.name());

        let transactions = transactions_rdd(ctx, db, ctx.default_parallelism());

        // Phase-1 (Algorithm 5).
        let freq_items = phase1_wordcount(ctx, &transactions, min_sup)?;
        run.phase("phase1");

        // Phase-2 (Algorithm 6): broadcast trie, filter, triangular matrix.
        let trie = ctx.broadcast(ItemFilter::new(freq_items.iter().map(|(i, _)| *i)));
        let filter_trie = trie.clone();
        let filtered = transactions
            .map(move |t| filter_trie.value().filter_transaction(&t))
            .filter(|t| !t.is_empty())
            .cache();
        // Measure the shrinkage the paper quotes in §5.2.1 (A1 ablation).
        let total_before = db.total_items();
        let (total_after, filtered_count) = {
            let acc = ctx.accumulator((0u64, 0u64), |a: &mut (u64, u64), b: (u64, u64)| {
                a.0 += b.0;
                a.1 += b.1;
            });
            let acc2 = acc.clone();
            filtered
                .map_partitions_with_index(move |_i, txns| {
                    acc2.add((txns.iter().map(|t| t.len() as u64).sum(), txns.len() as u64));
                    Vec::<()>::new()
                })
                .run()?;
            acc.value()
        };
        let reduction = 1.0 - total_after as f64 / total_before.max(1) as f64;

        let tri = if self.options.tri_matrix {
            let max_item = freq_items.iter().map(|(i, _)| *i).max().unwrap_or(0);
            Some(phase2_trimatrix(ctx, &filtered, max_item, &self.options.cooc)?)
        } else {
            None
        };
        run.phase("phase2");

        // Phase-3 (Algorithm 7).
        let vertical = phase3_vertical_grouped(ctx, &filtered)?;
        run.phase("phase3");

        // Phase-4 (= Algorithm 4). Universe is the filtered transaction
        // count (tids were re-assigned over filtered data).
        let universe = filtered_count as usize;
        let mut frequents: Vec<Frequent> =
            vertical.iter().map(|(i, t)| Frequent::new(vec![*i], t.len() as u32)).collect();
        let n = vertical.len();
        let loads = mine_equivalence_classes(
            ctx,
            vertical,
            universe,
            min_sup,
            tri.as_ref(),
            Arc::new(DefaultClassPartitioner::for_items(n)),
            &mut frequents,
        )?;
        run.phase("phase4");
        run.partition_loads(loads);
        run.filtered_reduction(reduction);

        Ok(run.finish(frequents))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::{apriori::apriori, sort_frequents};

    fn demo_db() -> Database {
        Database::from_rows(vec![
            vec![1, 3, 4],
            vec![2, 3, 5],
            vec![1, 2, 3, 5],
            vec![2, 5],
            vec![1, 3, 5],
            vec![2, 3, 5],
        ])
    }

    #[test]
    fn matches_apriori_oracle() {
        let ctx = ClusterContext::builder().cores(2).build();
        let db = demo_db();
        for min_sup in 1..=5 {
            let mut want = apriori(&db, min_sup);
            let mut got = EclatV2::default()
                .run_on(&ctx, &db, MinSup::count(min_sup))
                .unwrap()
                .frequents;
            sort_frequents(&mut want);
            sort_frequents(&mut got);
            assert_eq!(got, want, "min_sup={min_sup}");
        }
    }

    #[test]
    fn reports_filtering_reduction() {
        let ctx = ClusterContext::builder().cores(2).build();
        // Items 4 and 9 are infrequent at min_sup 3 -> filtered out.
        let db = Database::from_rows(vec![
            vec![1, 2, 4],
            vec![1, 2, 9],
            vec![1, 2],
        ]);
        let r = EclatV2::default().run_on(&ctx, &db, MinSup::count(3)).unwrap();
        // 8 occurrences before, 6 after -> reduction 0.25.
        let red = r.filtered_reduction.unwrap();
        assert!((red - 0.25).abs() < 1e-9, "reduction {red}");
    }

    #[test]
    fn four_phases_recorded() {
        let ctx = ClusterContext::builder().cores(2).build();
        let r = EclatV2::default().run_on(&ctx, &demo_db(), MinSup::count(2)).unwrap();
        assert_eq!(r.phases.len(), 4);
    }
}
