//! Engine semantics under randomized workloads + fault injection during
//! real mining runs (lineage recovery end-to-end).

use rdd_eclat::algorithms::{Algorithm, EclatV4};
use rdd_eclat::data::Database;
use rdd_eclat::engine::{ClusterContext, FaultInjector, ShuffleId};
use rdd_eclat::fim::{sort_frequents, MinSup};
use rdd_eclat::util::prng::Rng;
use rdd_eclat::util::prop::{check, prop_assert_eq, Config};

#[test]
fn group_by_key_equals_reference_grouping() {
    check(Config::default().cases(20).seed(1), |rng| {
        let ctx = ClusterContext::builder().cores(rng.range(1, 5)).build();
        let n = rng.range(0, 500);
        let keys = rng.range(1, 20) as u64;
        let pairs: Vec<(u64, u64)> = (0..n).map(|i| (rng.below(keys), i as u64)).collect();
        let mut want: std::collections::HashMap<u64, Vec<u64>> = Default::default();
        for (k, v) in &pairs {
            want.entry(*k).or_default().push(*v);
        }
        let parts = rng.range(1, 8);
        let reduces = rng.range(1, 6);
        let mut got: Vec<(u64, Vec<u64>)> =
            ctx.parallelize(pairs, parts).group_by_key(reduces).collect().unwrap();
        for (_, vs) in &mut got {
            vs.sort_unstable();
        }
        let mut want: Vec<(u64, Vec<u64>)> = want
            .into_iter()
            .map(|(k, mut v)| {
                v.sort_unstable();
                (k, v)
            })
            .collect();
        want.sort();
        got.sort();
        prop_assert_eq(got, want, "groupByKey grouping")
    });
}

#[test]
fn reduce_by_key_equals_fold_under_any_partitioning() {
    check(Config::default().cases(20).seed(2), |rng| {
        let ctx = ClusterContext::builder().cores(rng.range(1, 4)).build();
        let pairs: Vec<(u32, u64)> =
            (0..rng.range(0, 400)).map(|_| (rng.below(15) as u32, rng.below(100))).collect();
        let mut want: std::collections::HashMap<u32, u64> = Default::default();
        for (k, v) in &pairs {
            *want.entry(*k).or_default() += v;
        }
        let got: std::collections::HashMap<u32, u64> = ctx
            .parallelize(pairs, rng.range(1, 9))
            .reduce_by_key(rng.range(1, 5), |a, b| a + b)
            .collect()
            .unwrap()
            .into_iter()
            .collect();
        prop_assert_eq(got, want, "reduceByKey sums")
    });
}

#[test]
fn repartition_and_coalesce_preserve_multiset() {
    check(Config::default().cases(20).seed(3), |rng| {
        let ctx = ClusterContext::builder().cores(2).build();
        let data: Vec<u64> = (0..rng.range(0, 300)).map(|_| rng.below(1000)).collect();
        let mut want = data.clone();
        want.sort_unstable();
        let rdd = ctx.parallelize(data, rng.range(1, 10));
        let transformed = if rng.chance(0.5) {
            rdd.repartition(rng.range(1, 12))
        } else {
            rdd.coalesce(rng.range(1, 12))
        };
        let mut got = transformed.collect().unwrap();
        got.sort_unstable();
        prop_assert_eq(got, want, "multiset preserved")
    });
}

#[test]
fn fault_injection_mid_mining_recovers_identical_results() {
    // Mine, inject loss of every shuffle + all cached partitions, re-run
    // the same lazily-defined pipeline: results must be identical.
    let mut rng = Rng::new(44);
    for case in 0..5 {
        let rows: Vec<Vec<u32>> = (0..60)
            .map(|_| (0..15u32).filter(|_| rng.chance(0.35)).collect())
            .filter(|t: &Vec<u32>| !t.is_empty())
            .collect();
        let db = Database::from_rows(rows);
        let ctx = ClusterContext::builder().cores(2).build();
        let algo = EclatV4::default();
        let mut first = algo.run_on(&ctx, &db, MinSup::count(3)).unwrap().frequents;
        sort_frequents(&mut first);

        // Kill everything the first run left behind.
        let mut inj = FaultInjector::new(&ctx, case as u64);
        for sid in 0..64 {
            inj.lose_shuffle(ShuffleId(sid));
        }
        // A fresh run on the SAME context must rebuild all state.
        let mut second = algo.run_on(&ctx, &db, MinSup::count(3)).unwrap().frequents;
        sort_frequents(&mut second);
        assert_eq!(first, second, "case {case}");
    }
}

#[test]
fn accumulators_see_every_partition_exactly_once_per_job() {
    let ctx = ClusterContext::builder().cores(3).build();
    let data: Vec<u32> = (0..1000).collect();
    let rdd = ctx.parallelize(data, 7);
    let acc = ctx.accumulator(0u64, |a, b| *a += b);
    let task_acc = acc.clone();
    rdd.map_partitions_with_index(move |_i, xs| {
        task_acc.add(xs.len() as u64);
        Vec::<()>::new()
    })
    .run()
    .unwrap();
    assert_eq!(acc.value(), 1000);
}

#[test]
fn metrics_feed_simulator_with_sane_scaling() {
    use rdd_eclat::engine::simcluster;
    let ctx = ClusterContext::builder().cores(2).build();
    let db = Database::from_rows(
        (0..200u32).map(|i| vec![i % 7, 7 + i % 5, 12 + i % 3]).collect(),
    );
    ctx.metrics().reset();
    EclatV4::default().run_on(&ctx, &db, MinSup::count(5)).unwrap();
    let tasks = ctx.metrics().tasks();
    assert!(!tasks.is_empty(), "mining recorded tasks");
    let sweep = simcluster::sweep(&tasks, &[1, 2, 4, 8], std::time::Duration::ZERO);
    for w in sweep.windows(2) {
        assert!(
            w[0].makespan >= w[1].makespan,
            "makespan must not increase with cores: {sweep:?}"
        );
    }
}

#[test]
fn zip_with_index_unique_dense_over_random_partitions() {
    check(Config::default().cases(15).seed(5), |rng| {
        let ctx = ClusterContext::builder().cores(2).build();
        let n = rng.range(0, 200);
        let data: Vec<u64> = (0..n as u64).collect();
        let rdd = ctx.parallelize(data, rng.range(1, 9));
        let idx: Vec<u64> = rdd.zip_with_index().unwrap().map(|(_, i)| i).collect().unwrap();
        let mut sorted = idx.clone();
        sorted.sort_unstable();
        prop_assert_eq(sorted, (0..n as u64).collect::<Vec<_>>(), "dense indices")
    });
}
