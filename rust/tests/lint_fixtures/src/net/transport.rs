//! Fixture: transport code unwrapping socket I/O and importing std
//! concurrency directly. Never compiled — scanned by
//! `tests/integration_lint.rs` only.

// VIOLATION(shim-imports) on the next line (line 6).
use std::sync::Arc;

pub fn handshake(stream: &mut TcpStream) -> [u8; 16] {
    let mut header = [0u8; 16];
    // VIOLATION(socket-unwrap) on the next line (line 11).
    stream.read_exact(&mut header).unwrap();
    // VIOLATION(socket-unwrap) on the next line (line 13).
    stream.write_all(&header).unwrap();
    header
}

// VIOLATION(socket-unwrap) on the next line (line 18).
pub fn dial(socket: UdpSocket, addr: &str) { socket.connect(addr).unwrap() }

// NOT a violation: the error is propagated, not unwrapped.
pub fn send(stream: &mut TcpStream, body: &[u8]) -> std::io::Result<()> {
    stream.write_all(body)
}

#[cfg(test)]
mod tests {
    // NOT a violation: test code may unwrap loopback socket calls.
    pub fn drain(stream: &mut std::net::TcpStream) {
        stream.flush().unwrap();
    }
}
