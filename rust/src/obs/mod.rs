//! Crate-wide observability: metrics registry, span tracing, and
//! Chrome-trace export — zero dependencies, near-zero overhead off.
//!
//! The paper's claims are timing claims (RDD-Eclat beating RDD-Apriori
//! "by many times", Fig. 15 core scaling), so every layer of this
//! reproduction reports through one instrumentation spine:
//!
//! * **Metrics** ([`registry`]) — atomic [`Counter`]s, [`Gauge`]s, and
//!   log2 [`Histogram`]s registered by static name ([`counter`],
//!   [`gauge`], [`histogram`]) and recorded lock-free. [`snapshot`]
//!   flattens them into a [`MetricsSnapshot`] for `BENCH_*.json` rows
//!   and the `--stats-every` CLI digest.
//! * **Spans** ([`span`]) — RAII guards on per-thread span stacks
//!   feeding a bounded ring-buffer event log. The engine's scheduler
//!   tasks, per-shard mining, and snapshot publishes all record here,
//!   so one timeline covers driver, executors, and the stream miner.
//! * **Export** ([`trace`]) — [`chrome_trace_json`] writes the event
//!   log as Chrome trace-event JSON (load in Perfetto or
//!   `chrome://tracing`; `tid` = real worker thread), and
//!   [`validate_trace`] is the minimal parser tests and CI use to
//!   prove the export is well-formed.
//!
//! ## Overhead
//!
//! Tracing is **off** by default. Disabled span sites cost one relaxed
//! atomic load; disabled metric sites cost nothing (the sites
//! themselves check [`enabled`]). Enabled counters are single relaxed
//! `fetch_add`s on leaked `'static` cells — no locks, no allocation on
//! any hot path. The `obs/overhead` row in `BENCH_fim.json` (see
//! `benches/fim_micro.rs`) pins the enabled-vs-disabled ratio for the
//! mining inner loop.
//!
//! ```
//! use rdd_eclat::obs;
//!
//! obs::set_enabled(true);
//! {
//!     let mut s = obs::span("phase2.mine_class");
//!     s.arg("class", 7);
//!     obs::counter("fim.emits").incr(1);
//! } // span recorded on drop
//! let json = obs::chrome_trace_json();
//! assert!(obs::validate_trace(&json).unwrap() >= 1);
//! ```

use std::sync::atomic::{AtomicBool, Ordering};

pub mod registry;
pub mod span;
pub mod trace;

pub use registry::{
    counter, gauge, histogram, reset_metrics, snapshot, Counter, Gauge, Histogram,
    HistogramSummary, MetricsSnapshot,
};
pub use span::{
    clear_events, current_depth, current_tid, event_capacity, events, instant, record_span,
    set_event_capacity, span, EventKind, SpanEvent, SpanGuard, DEFAULT_EVENT_CAPACITY,
};
pub use trace::{chrome_trace_json, validate_trace, write_chrome_trace};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Whether observability recording is on (one relaxed load — this is
/// the check every instrumentation site makes first).
#[inline]
pub fn enabled() -> bool {
    // ordering: Relaxed — the flag only gates *whether* events are
    // recorded; a site observing a stale value merely records (or
    // skips) one extra event, it never corrupts state. Nothing is
    // published through this cell.
    ENABLED.load(Ordering::Relaxed)
}

/// Turn observability recording on or off process-wide. The CLI flips
/// this on for `--trace` and `--stats-every` runs.
pub fn set_enabled(on: bool) {
    // ordering: Relaxed — see `enabled`: the flip need not synchronize
    // with in-flight recording.
    ENABLED.store(on, Ordering::Relaxed);
}
