//! Fixture: atomic orderings without `// ordering:` justifications.
//! Never compiled — scanned by `tests/integration_lint.rs` only.

use std::sync::atomic::{AtomicU64, Ordering};

pub static HITS: AtomicU64 = AtomicU64::new(0);

pub fn bump() {
    // A plain comment is not a justification.
    // VIOLATION(ordering-comment) on the next line (line 11).
    HITS.fetch_add(1, Ordering::Relaxed);
}

pub fn read() -> u64 {
    // VIOLATION(ordering-comment) on the next line (line 16).
    HITS.load(Ordering::SeqCst)
}

pub fn annotated() -> u64 {
    // ordering: Relaxed — monitoring read of an independent tally;
    // NOT a violation (justified by this comment block).
    HITS.load(Ordering::Relaxed)
}

pub fn annotated_inline() {
    HITS.store(0, Ordering::Relaxed); // ordering: Relaxed — external sync point.
}
