//! The benchmark dataset catalogue — Table 2 of the paper, regenerated.
//!
//! Each [`DatasetSpec`] names one of the paper's seven benchmark datasets
//! (plus scaled variants for Fig. 16) and knows how to generate its
//! statistical twin (DESIGN.md §2.2 documents the substitution). Datasets
//! are cached on disk in FIMI format under a data directory so repeated
//! experiment runs parse instead of regenerate.

use crate::error::Result;
use crate::fim::transaction::Database;

use super::clickstream::{self, ClickParams};
use super::dense::{self, DenseParams};
use super::quest::{self, QuestParams};

/// Fixed seed base so every experiment in EXPERIMENTS.md is replayable.
const SEED: u64 = 0x5EED_2021;

/// Generator version, embedded in cache filenames so stale on-disk
/// datasets miss automatically whenever a generator's sampling scheme
/// changes. v2: the clickstream generator became randomly accessible by
/// transaction index (per-transaction seeding) for the streaming
/// sources, changing BMS twin contents for identical params + seed.
const GEN_VERSION: u32 = 2;

/// One of the paper's benchmark datasets (Table 2), or a scaled variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DatasetSpec {
    /// Synthetic, 10k × 192 items, width 20.
    C20d10k,
    /// Dense real-life twin, 3196 × 75 items, width 37.
    Chess,
    /// Dense real-life twin, 8124 × 119 items, width 23.
    Mushroom,
    /// Sparse clickstream twin, 59602 × 497 items, width 2.5.
    Bms1,
    /// Sparse clickstream twin, 77512 × 3340 items, width 5.
    Bms2,
    /// Quest synthetic, 100k × 870 items, width 10.
    T10i4d100k,
    /// Quest synthetic, 100k × 1000 items, width 40.
    T40i10d100k,
    /// T10I4D100K scaled by 2^k transactions (Fig. 16: 100K → 1600K).
    T10i4Scaled(u8),
}

/// All seven Table 2 datasets, in the paper's order.
pub const TABLE2: [DatasetSpec; 7] = [
    DatasetSpec::C20d10k,
    DatasetSpec::Chess,
    DatasetSpec::Mushroom,
    DatasetSpec::Bms1,
    DatasetSpec::Bms2,
    DatasetSpec::T10i4d100k,
    DatasetSpec::T40i10d100k,
];

impl DatasetSpec {
    /// Canonical name (used for file names and CSV columns).
    pub fn name(&self) -> String {
        match self {
            DatasetSpec::C20d10k => "c20d10k".into(),
            DatasetSpec::Chess => "chess".into(),
            DatasetSpec::Mushroom => "mushroom".into(),
            DatasetSpec::Bms1 => "BMS_WebView_1".into(),
            DatasetSpec::Bms2 => "BMS_WebView_2".into(),
            DatasetSpec::T10i4d100k => "T10I4D100K".into(),
            DatasetSpec::T40i10d100k => "T40I10D100K".into(),
            DatasetSpec::T10i4Scaled(k) => format!("T10I4D{}K", 100 << k),
        }
    }

    /// Parse a CLI name (case-insensitive; accepts short aliases).
    pub fn parse(s: &str) -> Option<DatasetSpec> {
        match s.to_ascii_lowercase().as_str() {
            "c20d10k" => Some(DatasetSpec::C20d10k),
            "chess" => Some(DatasetSpec::Chess),
            "mushroom" => Some(DatasetSpec::Mushroom),
            "bms1" | "bms_webview_1" => Some(DatasetSpec::Bms1),
            "bms2" | "bms_webview_2" => Some(DatasetSpec::Bms2),
            "t10" | "t10i4d100k" => Some(DatasetSpec::T10i4d100k),
            "t40" | "t40i10d100k" => Some(DatasetSpec::T40i10d100k),
            other => {
                // tNNNxK scaled names: t10x2, t10x4 ...
                other.strip_prefix("t10x").and_then(|k| {
                    k.parse::<u8>().ok().and_then(|f| {
                        if f.is_power_of_two() && f <= 16 {
                            Some(DatasetSpec::T10i4Scaled(f.trailing_zeros() as u8))
                        } else {
                            None
                        }
                    })
                })
            }
        }
    }

    /// Whether the paper enables the triangular-matrix optimization for
    /// this dataset (§5.2: disabled on BMS1/BMS2 — item universe too
    /// large).
    pub fn tri_matrix_mode(&self) -> bool {
        !matches!(self, DatasetSpec::Bms1 | DatasetSpec::Bms2)
    }

    /// The paper's Table 2 target statistics `(transactions, items,
    /// avg width)` for validation and reporting.
    pub fn table2_row(&self) -> (usize, usize, f64) {
        match self {
            DatasetSpec::C20d10k => (10_000, 192, 20.0),
            DatasetSpec::Chess => (3196, 75, 37.0),
            DatasetSpec::Mushroom => (8124, 119, 23.0),
            DatasetSpec::Bms1 => (59_602, 497, 2.5),
            DatasetSpec::Bms2 => (77_512, 3340, 5.0),
            DatasetSpec::T10i4d100k => (100_000, 870, 10.0),
            DatasetSpec::T40i10d100k => (100_000, 1000, 40.0),
            DatasetSpec::T10i4Scaled(k) => (100_000 << k, 870, 10.0),
        }
    }

    /// Generate the dataset (deterministic).
    pub fn generate(&self) -> Database {
        match self {
            DatasetSpec::C20d10k => {
                quest::generate(&QuestParams::tid(20.0, 6.0, 10_000, 192), SEED ^ 1)
            }
            DatasetSpec::Chess => dense::generate(&DenseParams::chess_like(), SEED ^ 2),
            DatasetSpec::Mushroom => dense::generate(&DenseParams::mushroom_like(), SEED ^ 3),
            DatasetSpec::Bms1 => clickstream::generate(&ClickParams::bms1_like(), SEED ^ 4),
            DatasetSpec::Bms2 => clickstream::generate(&ClickParams::bms2_like(), SEED ^ 5),
            DatasetSpec::T10i4d100k => {
                quest::generate(&QuestParams::tid(10.0, 4.0, 100_000, 870), SEED ^ 6)
            }
            DatasetSpec::T40i10d100k => {
                quest::generate(&QuestParams::tid(40.0, 10.0, 100_000, 1000), SEED ^ 7)
            }
            DatasetSpec::T10i4Scaled(k) => {
                // Same process, more transactions; same seed family so the
                // 100K prefix distribution matches T10I4D100K.
                quest::generate(&QuestParams::tid(10.0, 4.0, 100_000 << k, 870), SEED ^ 6)
            }
        }
    }

    /// On-disk cache location under `dir` for this dataset, versioned by
    /// [`GEN_VERSION`] so caches written by older generators are never
    /// silently reused.
    pub fn cache_path(&self, dir: &str) -> String {
        format!("{dir}/{}.v{GEN_VERSION}.dat", self.name())
    }

    /// Generate-or-load through the on-disk cache at `dir`.
    pub fn materialize(&self, dir: &str) -> Result<Database> {
        std::fs::create_dir_all(dir)?;
        let path = self.cache_path(dir);
        if std::path::Path::new(&path).exists() {
            return Database::parse(&std::fs::read_to_string(&path)?);
        }
        let db = self.generate();
        std::fs::write(&path, db.to_text())?;
        Ok(db)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_names() {
        assert_eq!(DatasetSpec::parse("chess"), Some(DatasetSpec::Chess));
        assert_eq!(DatasetSpec::parse("BMS1"), Some(DatasetSpec::Bms1));
        assert_eq!(DatasetSpec::parse("T10"), Some(DatasetSpec::T10i4d100k));
        assert_eq!(DatasetSpec::parse("t10x4"), Some(DatasetSpec::T10i4Scaled(2)));
        assert_eq!(DatasetSpec::parse("t10x3"), None, "non power of two");
        assert_eq!(DatasetSpec::parse("nope"), None);
    }

    #[test]
    fn tri_matrix_mode_matches_paper() {
        assert!(DatasetSpec::Chess.tri_matrix_mode());
        assert!(!DatasetSpec::Bms1.tri_matrix_mode());
        assert!(!DatasetSpec::Bms2.tri_matrix_mode());
    }

    #[test]
    fn scaled_names() {
        assert_eq!(DatasetSpec::T10i4Scaled(0).name(), "T10I4D100K");
        assert_eq!(DatasetSpec::T10i4Scaled(4).name(), "T10I4D1600K");
    }

    #[test]
    fn small_dense_specs_hit_table2_stats() {
        // Only the fast ones in unit tests; the figures harness validates
        // the rest (Table 2 driver).
        let db = DatasetSpec::Chess.generate();
        let s = db.stats();
        let (txns, items, width) = DatasetSpec::Chess.table2_row();
        assert_eq!(s.transactions, txns);
        assert!(s.distinct_items <= items);
        assert!((s.avg_width - width).abs() < 0.5);
    }

    #[test]
    fn materialize_caches_to_disk() {
        let dir = std::env::temp_dir().join("rdd_eclat_catalog_test");
        let _ = std::fs::remove_dir_all(&dir);
        let d = dir.to_str().unwrap();
        let a = DatasetSpec::Chess.materialize(d).unwrap();
        let cache = DatasetSpec::Chess.cache_path(d);
        assert!(cache.ends_with(".v2.dat"), "cache name is generator-versioned: {cache}");
        assert!(std::path::Path::new(&cache).exists());
        let b = DatasetSpec::Chess.materialize(d).unwrap();
        assert_eq!(a, b, "cache read equals generated");
    }
}
