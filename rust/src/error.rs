//! Unified error type for the crate.

use thiserror::Error;

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Errors surfaced by the engine, the FIM algorithms, the dataset layer,
/// the PJRT runtime and the CLI.
#[derive(Error, Debug)]
pub enum Error {
    /// Filesystem / IO failures (dataset files, artifact files, results).
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// A dataset line or CLI value failed to parse.
    #[error("parse error: {0}")]
    Parse(String),

    /// Bad configuration (unknown key, invalid value, missing artifact).
    #[error("config error: {0}")]
    Config(String),

    /// The engine detected an internal inconsistency (lost shuffle output
    /// that cannot be recomputed, a poisoned lock, a panicked task).
    #[error("engine error: {0}")]
    Engine(String),

    /// PJRT / XLA runtime failure (artifact missing, compile or execute
    /// failure, shape mismatch between host buffers and the artifact).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Wire-format or transport failure (corrupt/truncated/version-skewed
    /// frame, RPC protocol violation, unreachable shard worker).
    #[error("net error: {0}")]
    Net(String),

    /// CLI usage error; carries the message shown to the user.
    #[error("usage error: {0}")]
    Usage(String),
}

impl Error {
    /// Shorthand for [`Error::Parse`].
    pub fn parse(msg: impl Into<String>) -> Self {
        Error::Parse(msg.into())
    }

    /// Shorthand for [`Error::Config`].
    pub fn config(msg: impl Into<String>) -> Self {
        Error::Config(msg.into())
    }

    /// Shorthand for [`Error::Engine`].
    pub fn engine(msg: impl Into<String>) -> Self {
        Error::Engine(msg.into())
    }

    /// Shorthand for [`Error::Runtime`].
    pub fn runtime(msg: impl Into<String>) -> Self {
        Error::Runtime(msg.into())
    }

    /// Shorthand for [`Error::Net`].
    pub fn net(msg: impl Into<String>) -> Self {
        Error::Net(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_display_includes_kind_and_message() {
        let e = Error::parse("bad line 3");
        assert_eq!(e.to_string(), "parse error: bad line 3");
        let e = Error::engine("lost partition");
        assert_eq!(e.to_string(), "engine error: lost partition");
        let e = Error::net("truncated frame");
        assert_eq!(e.to_string(), "net error: truncated frame");
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = io.into();
        assert!(matches!(e, Error::Io(_)));
        assert!(e.to_string().contains("gone"));
    }
}
