//! Item-sharded vertical store: N [`IncrementalVerticalDb`] shards in
//! one tid space.
//!
//! The paper's partitioned Eclat distributes equivalence classes across
//! executors with a weight-balancing partitioner; this module applies
//! the same idea one layer down, to the *store*: each item's tid column
//! lives on exactly one shard, routed by the EclatV5 reverse-hash
//! dealing ([`ReverseHashClassPartitioner::shard_of_item`]), so append,
//! evict, compact, and the per-shard dirty bookkeeping all parallelize
//! over the engine pool.
//!
//! The invariant that makes this sound is **tid-space alignment**: every
//! shard sees every batch (rows filtered to its owned items, but the
//! row *count* preserved — empty rows are legal) and every eviction
//! (possibly with an empty touched-item hint), so `live_lo`/`next`/
//! `txns` advance identically everywhere and compaction fires on every
//! shard at the same push with the same rebase delta. Cross-shard
//! bitmap intersections therefore remain valid without any coordination
//! at mine time. Debug builds assert the alignment after every parallel
//! apply.
//!
//! `shards = 1` is the plain single-store path: append/evict take a
//! fast path that hands rows straight to shard 0 (no scatter copy), so
//! the one-shard configuration is byte-for-byte the pre-sharding store
//! and doubles as the parity oracle for every shard count.

use std::collections::HashSet;

use crate::algorithms::partitioners::ReverseHashClassPartitioner;
use crate::engine::pool::ThreadPool;
use crate::error::Result;
use crate::fim::{Item, TidBitmap};
use crate::stream::incremental::IncrementalVerticalDb;

/// Cumulative ingest load observed by one shard — the shard-imbalance
/// signal surfaced through `IngestStats` and `repro stream --serve`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ShardLoad {
    /// Rows routed to this shard that still contained at least one owned
    /// item after filtering.
    pub rows: u64,
    /// Item occurrences (postings) appended to this shard.
    pub postings: u64,
}

/// N [`IncrementalVerticalDb`] shards sharing one tid space, with items
/// routed to shards by the EclatV5 reverse-hash partitioner.
///
/// All read paths (`atoms`, `support`, `frequent_count*`, `live_rows`)
/// gather across shards and return exactly what a single store holding
/// every column would return — same contents, same total order.
#[derive(Debug)]
pub struct ShardedVerticalDb {
    shards: Vec<IncrementalVerticalDb>,
    router: ReverseHashClassPartitioner,
    loads: Vec<ShardLoad>,
}

impl ShardedVerticalDb {
    /// Empty store with `n >= 1` shards.
    pub fn new(n: usize) -> ShardedVerticalDb {
        assert!(n >= 1, "need at least one shard");
        ShardedVerticalDb {
            shards: (0..n).map(|_| IncrementalVerticalDb::new()).collect(),
            router: ReverseHashClassPartitioner::new(n),
            loads: vec![ShardLoad::default(); n],
        }
    }

    /// Number of shards.
    pub fn shard_count(&self) -> usize {
        // From `loads`, not `shards`: a failed parallel apply leaves the
        // store poisoned (shards drained); the count must stay stable so
        // error paths can still report it.
        self.loads.len()
    }

    /// The shard owning `item`'s column.
    pub fn route(&self, item: Item) -> usize {
        self.router.shard_of_item(item)
    }

    /// Borrow one shard (tests and stats).
    pub fn shard(&self, s: usize) -> &IncrementalVerticalDb {
        &self.shards[s]
    }

    /// Per-shard cumulative ingest loads.
    pub fn loads(&self) -> &[ShardLoad] {
        &self.loads
    }

    /// Live transaction count (identical on every shard by alignment).
    pub fn txns(&self) -> usize {
        debug_assert!(self.aligned(), "shards out of tid-space alignment");
        self.shards.first().map_or(0, |s| s.txns())
    }

    /// Number of distinct live items across all shards (disjoint by
    /// routing, so the per-shard counts sum).
    pub fn distinct_items(&self) -> usize {
        self.shards.iter().map(|s| s.distinct_items()).sum()
    }

    /// Current support of `item` over the window.
    pub fn support(&self, item: Item) -> u32 {
        self.shards[self.route(item)].support(item)
    }

    /// Number of items with `support >= min_sup`.
    pub fn frequent_count(&self, min_sup: u32) -> usize {
        self.shards.iter().map(|s| s.frequent_count(min_sup)).sum()
    }

    /// Number of items with `support >= min_sup` satisfying `keep`.
    pub fn frequent_count_where(&self, min_sup: u32, keep: impl Fn(Item) -> bool) -> usize {
        self.shards.iter().map(|s| s.frequent_count_where(min_sup, &keep)).sum()
    }

    /// Frequent atoms gathered from every shard, in the paper's Phase-1
    /// total order (ascending support, item id tie-break) — identical to
    /// what one unsharded store would produce.
    pub fn atoms(&self, min_sup: u32, keep: impl Fn(Item) -> bool) -> Vec<(Item, TidBitmap, u32)> {
        if self.shards.len() == 1 {
            return self.shards[0].atoms(min_sup, keep);
        }
        let mut out = Vec::new();
        for s in &self.shards {
            out.extend(s.atoms(min_sup, &keep));
        }
        out.sort_by(|a, b| a.2.cmp(&b.2).then_with(|| a.0.cmp(&b.0)));
        out
    }

    /// Reconstruct the live window horizontally, oldest transaction
    /// first, merging each shard's partial rows (shards own disjoint
    /// items in the same tid space, so per-tid union + sort is exact).
    pub fn live_rows(&self) -> Vec<Vec<Item>> {
        if self.shards.len() == 1 {
            return self.shards[0].live_rows();
        }
        let mut rows = vec![Vec::new(); self.txns()];
        for s in &self.shards {
            for (t, partial) in s.live_rows().into_iter().enumerate() {
                rows[t].extend(partial);
            }
        }
        for row in &mut rows {
            row.sort_unstable();
        }
        rows
    }

    /// Append one batch to every shard, sequentially. Rows must be
    /// normalized. Each shard's touched items land in its slot of
    /// `dirty` (`dirty.len() == shard_count()`).
    pub fn append(&mut self, rows: &[Vec<Item>], dirty: &mut [HashSet<Item>]) {
        debug_assert_eq!(dirty.len(), self.shards.len());
        if self.shards.len() == 1 {
            for row in rows {
                if !row.is_empty() {
                    self.loads[0].rows += 1;
                    self.loads[0].postings += row.len() as u64;
                }
            }
            self.shards[0].append(rows, &mut dirty[0]);
            return;
        }
        let scattered = self.scatter_rows(rows);
        for (s, shard_rows) in scattered.iter().enumerate() {
            self.shards[s].append(shard_rows, &mut dirty[s]);
        }
    }

    /// Evict the oldest `txns` transactions on every shard. `touched` is
    /// the global distinct-item hint; each shard receives only its owned
    /// items but **every** shard evicts (empty hint included) so tid
    /// bounds stay aligned.
    pub fn evict_touched(&mut self, txns: usize, touched: &[Item], dirty: &mut [HashSet<Item>]) {
        debug_assert_eq!(dirty.len(), self.shards.len());
        if self.shards.len() == 1 {
            self.shards[0].evict_touched(txns, touched, &mut dirty[0]);
            return;
        }
        let scattered = self.scatter_items(touched);
        for (s, hint) in scattered.iter().enumerate() {
            self.shards[s].evict_touched(txns, hint, &mut dirty[s]);
        }
    }

    /// Fused append + evictions, sequentially: append `rows`, then evict
    /// each `(txns, touched)` entry oldest-first. The sequential twin of
    /// [`ShardedVerticalDb::apply_batch_on`], and the reference the
    /// parallel path is tested against.
    pub fn apply_batch(
        &mut self,
        rows: &[Vec<Item>],
        evictions: &[(usize, Vec<Item>)],
        dirty: &mut [HashSet<Item>],
    ) {
        self.append(rows, dirty);
        for (txns, touched) in evictions {
            self.evict_touched(*txns, touched, dirty);
        }
    }

    /// Fused append + evictions with one pool task per shard: scatter
    /// the batch's item columns, then each shard appends, evicts, and
    /// (transparently) compacts independently. Bookkeeping order within
    /// a shard is append-then-evict, matching the sequential path.
    ///
    /// On pool failure (a shard task panicked) the store is **poisoned**
    /// — shards are lost and the error propagates; the streaming service
    /// treats that as terminal.
    pub fn apply_batch_on(
        &mut self,
        pool: &ThreadPool,
        rows: &[Vec<Item>],
        evictions: &[(usize, Vec<Item>)],
        dirty: &mut [HashSet<Item>],
    ) -> Result<()> {
        debug_assert_eq!(dirty.len(), self.shards.len());
        if self.shards.len() == 1 {
            self.apply_batch(rows, evictions, dirty);
            return Ok(());
        }
        let row_scatter = self.scatter_rows(rows);
        let evict_scatter: Vec<Vec<(usize, Vec<Item>)>> = {
            let mut per_shard: Vec<Vec<(usize, Vec<Item>)>> =
                (0..self.shards.len()).map(|_| Vec::with_capacity(evictions.len())).collect();
            for (txns, touched) in evictions {
                for (s, hint) in self.scatter_items(touched).into_iter().enumerate() {
                    per_shard[s].push((*txns, hint));
                }
            }
            per_shard
        };
        // `run_all` needs 'static tasks: move each shard (and its dirty
        // set) into its task and reassemble from the ordered results.
        let shards = std::mem::take(&mut self.shards);
        let mut tasks = Vec::with_capacity(shards.len());
        for ((mut shard, shard_rows), (mut d, shard_evicts)) in shards
            .into_iter()
            .zip(row_scatter)
            .zip(dirty.iter_mut().map(std::mem::take).zip(evict_scatter))
        {
            tasks.push(move || {
                shard.append(&shard_rows, &mut d);
                for (txns, hint) in &shard_evicts {
                    shard.evict_touched(*txns, hint, &mut d);
                }
                (shard, d)
            });
        }
        let results = pool.run_all(tasks)?;
        for (s, (shard, d)) in results.into_iter().enumerate() {
            self.shards.push(shard);
            dirty[s] = d;
        }
        debug_assert!(self.aligned(), "parallel apply desynchronized shard tid spaces");
        Ok(())
    }

    /// Scatter `rows` into per-shard copies: row counts preserved on
    /// every shard (rows filtered to owned items; empty rows kept), so
    /// tid assignment stays global. Tallies per-shard loads.
    fn scatter_rows(&mut self, rows: &[Vec<Item>]) -> Vec<Vec<Vec<Item>>> {
        let n = self.shards.len();
        let mut out: Vec<Vec<Vec<Item>>> =
            (0..n).map(|_| Vec::with_capacity(rows.len())).collect();
        for row in rows {
            for shard_rows in &mut out {
                shard_rows.push(Vec::new());
            }
            for &item in row {
                let s = self.route(item);
                out[s].last_mut().expect("pushed above").push(item);
            }
        }
        for (s, shard_rows) in out.iter().enumerate() {
            for row in shard_rows {
                if !row.is_empty() {
                    self.loads[s].rows += 1;
                    self.loads[s].postings += row.len() as u64;
                }
            }
        }
        out
    }

    /// Scatter a sorted distinct-item hint to per-shard hints (order
    /// preserved within a shard).
    fn scatter_items(&self, touched: &[Item]) -> Vec<Vec<Item>> {
        let mut out: Vec<Vec<Item>> = vec![Vec::new(); self.shards.len()];
        for &item in touched {
            out[self.route(item)].push(item);
        }
        out
    }

    /// True when every shard agrees on `(live_lo, next)` and txns.
    fn aligned(&self) -> bool {
        let Some(first) = self.shards.first() else { return true };
        let (bounds, txns) = (first.tid_bounds(), first.txns());
        self.shards.iter().all(|s| s.tid_bounds() == bounds && s.txns() == txns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dirty(n: usize) -> Vec<HashSet<Item>> {
        vec![HashSet::new(); n]
    }

    fn atoms_flat(db: &ShardedVerticalDb) -> Vec<(Item, Vec<crate::fim::Tid>, u32)> {
        db.atoms(1, |_| true)
            .into_iter()
            .map(|(i, bm, s)| (i, bm.iter().collect(), s))
            .collect()
    }

    #[test]
    #[should_panic(expected = "at least one shard")]
    fn zero_shards_rejected() {
        ShardedVerticalDb::new(0);
    }

    #[test]
    fn sharded_store_matches_single_store_in_lockstep() {
        let mut single = IncrementalVerticalDb::new();
        let mut sharded = ShardedVerticalDb::new(3);
        let mut ds = HashSet::new();
        let mut dm = dirty(3);
        let batches = [
            vec![vec![1, 2, 5], vec![2, 7], vec![]],
            vec![vec![1, 5, 7], vec![3]],
            vec![vec![2, 3, 5]],
            vec![],
        ];
        let mut pending: Vec<&Vec<Vec<Item>>> = Vec::new();
        for batch in &batches {
            single.append(batch, &mut ds);
            sharded.append(batch, &mut dm);
            pending.push(batch);
            if pending.len() > 2 {
                let old = pending.remove(0);
                let mut touched: Vec<Item> = old.iter().flatten().copied().collect();
                touched.sort_unstable();
                touched.dedup();
                single.evict_touched(old.len(), &touched, &mut ds);
                sharded.evict_touched(old.len(), &touched, &mut dm);
            }
            assert_eq!(sharded.txns(), single.txns());
            assert_eq!(sharded.distinct_items(), single.distinct_items());
            assert_eq!(sharded.live_rows(), single.live_rows());
            let want: Vec<(Item, Vec<crate::fim::Tid>, u32)> = single
                .atoms(1, |_| true)
                .into_iter()
                .map(|(i, bm, s)| (i, bm.iter().collect(), s))
                .collect();
            assert_eq!(atoms_flat(&sharded), want, "atoms diverged");
            let merged: HashSet<Item> = dm.iter().flatten().copied().collect();
            assert_eq!(merged, ds, "dirty sets diverged");
        }
        assert_eq!(sharded.frequent_count(2), single.frequent_count(2));
        assert_eq!(
            sharded.frequent_count_where(1, |i| i != 5),
            single.frequent_count_where(1, |i| i != 5)
        );
        // Every routed item's dirty entry sits on the owning shard.
        for (s, d) in dm.iter().enumerate() {
            for &item in d {
                assert_eq!(sharded.route(item), s, "dirty item {item} on wrong shard");
            }
        }
    }

    #[test]
    fn more_shards_than_items_leaves_empty_shards_harmless() {
        let mut db = ShardedVerticalDb::new(7);
        let mut d = dirty(7);
        db.append(&[vec![0, 1], vec![1, 2]], &mut d);
        assert_eq!(db.txns(), 2);
        assert_eq!(db.distinct_items(), 3);
        let populated = (0..7).filter(|&s| db.shard(s).distinct_items() > 0).count();
        assert!(populated <= 3);
        assert_eq!(db.live_rows(), vec![vec![0, 1], vec![1, 2]]);
        db.evict_touched(2, &[0, 1, 2], &mut d);
        assert_eq!(db.txns(), 0);
        assert_eq!(db.distinct_items(), 0);
        db.append(&[vec![5]], &mut d);
        assert_eq!(db.support(5), 1, "store usable after full eviction");
    }

    #[test]
    fn parallel_apply_matches_sequential_apply() {
        let pool = ThreadPool::new(3);
        let mut seq = ShardedVerticalDb::new(4);
        let mut par = ShardedVerticalDb::new(4);
        let (mut ds, mut dp) = (dirty(4), dirty(4));
        let mut held: Vec<Vec<Vec<Item>>> = Vec::new();
        for step in 0..30u32 {
            let batch: Vec<Vec<Item>> = (0..(step % 4) as usize)
                .map(|r| {
                    crate::stream::window::normalize_row(vec![step % 9, (step + 1 + r as u32) % 9])
                })
                .collect();
            held.push(batch.clone());
            let evictions: Vec<(usize, Vec<Item>)> = if held.len() > 3 {
                let old = held.remove(0);
                let mut touched: Vec<Item> = old.iter().flatten().copied().collect();
                touched.sort_unstable();
                touched.dedup();
                vec![(old.len(), touched)]
            } else {
                Vec::new()
            };
            seq.apply_batch(&batch, &evictions, &mut ds);
            par.apply_batch_on(&pool, &batch, &evictions, &mut dp).unwrap();
            assert_eq!(par.txns(), seq.txns(), "step {step}");
            assert_eq!(par.live_rows(), seq.live_rows(), "step {step}");
            assert_eq!(dp, ds, "step {step}: per-shard dirty sets diverged");
            assert_eq!(par.loads(), seq.loads(), "step {step}");
        }
        pool.shutdown();
    }

    #[test]
    fn compaction_stays_aligned_across_shards() {
        // Slide far enough that the dead prefix repeatedly exceeds the
        // live span — compaction must fire identically on every shard
        // (including shards owning no items at all).
        let mut db = ShardedVerticalDb::new(5);
        let mut d = dirty(5);
        let mut held: Vec<Vec<Vec<Item>>> = Vec::new();
        for step in 0..200u32 {
            let batch = vec![vec![step % 3, 3 + (step % 2)]];
            held.push(batch.clone());
            db.append(&batch, &mut d);
            if held.len() > 2 {
                let old = held.remove(0);
                let mut touched: Vec<Item> = old.iter().flatten().copied().collect();
                touched.sort_unstable();
                touched.dedup();
                db.evict_touched(old.len(), &touched, &mut d);
            }
        }
        assert_eq!(db.txns(), 2);
        let bounds = db.shard(0).tid_bounds();
        for s in 0..5 {
            assert_eq!(db.shard(s).tid_bounds(), bounds, "shard {s} bounds");
        }
        assert!(bounds.1 <= 128, "compaction bounded the tid space: {bounds:?}");
        let rows = db.live_rows();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0], crate::stream::window::normalize_row(vec![198 % 3, 3 + 198 % 2]));
    }

    #[test]
    fn loads_track_routed_postings() {
        let mut db = ShardedVerticalDb::new(2);
        let mut d = dirty(2);
        db.append(&[vec![0, 1], vec![0], vec![]], &mut d);
        let total_postings: u64 = db.loads().iter().map(|l| l.postings).sum();
        assert_eq!(total_postings, 3, "one posting per item occurrence");
        let total_rows: u64 = db.loads().iter().map(|l| l.rows).sum();
        // Row {0,1} lands on both shards (0→shard0, 1→shard1), row {0}
        // only on shard 0, the empty row on none.
        assert_eq!(total_rows, 3);
        assert_eq!(db.loads()[db.route(0)].postings, 2);
    }
}
