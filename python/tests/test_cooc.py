"""Pallas cooc kernel vs pure-jnp oracle — the core L1 correctness signal."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.cooc import BLOCK_T, cooc
from compile.kernels.ref import cooc_ref


def random_block(rng, t, i, density=0.3):
    return (rng.random((t, i)) < density).astype(np.float32)


class TestCoocFixedShapes:
    def test_identity_block(self):
        a = np.eye(8, dtype=np.float32)
        out = np.asarray(cooc(a, a, block_t=4))
        np.testing.assert_allclose(out, np.eye(8, dtype=np.float32))

    def test_known_small_case(self):
        # Transactions {0,1}, {1}, {0,1,2}.
        a = np.array(
            [[1, 1, 0], [0, 1, 0], [1, 1, 1], [0, 0, 0]], dtype=np.float32
        )
        out = np.asarray(cooc(a, a, block_t=2))
        expect = np.array(
            [[2, 2, 1], [2, 3, 1], [1, 1, 1]], dtype=np.float32
        )
        np.testing.assert_allclose(out, expect)

    def test_default_aot_shape(self):
        rng = np.random.default_rng(0)
        a = random_block(rng, 256, 128)
        out = np.asarray(cooc(a, a, block_t=BLOCK_T))
        np.testing.assert_allclose(out, np.asarray(cooc_ref(a, a)))

    def test_cross_block_asymmetric(self):
        rng = np.random.default_rng(1)
        a = random_block(rng, 128, 32)
        b = random_block(rng, 128, 16)
        out = np.asarray(cooc(a, b, block_t=32))
        assert out.shape == (32, 16)
        np.testing.assert_allclose(out, np.asarray(cooc_ref(a, b)))

    def test_bad_reduction_tile_rejected(self):
        a = np.zeros((100, 8), dtype=np.float32)
        with pytest.raises(AssertionError):
            cooc(a, a, block_t=64)

    def test_mismatched_rows_rejected(self):
        a = np.zeros((64, 8), dtype=np.float32)
        b = np.zeros((32, 8), dtype=np.float32)
        with pytest.raises(AssertionError):
            cooc(a, b, block_t=32)


@settings(max_examples=25, deadline=None)
@given(
    t_blocks=st.integers(1, 4),
    block_t=st.sampled_from([8, 16, 32]),
    i_a=st.integers(1, 40),
    i_b=st.integers(1, 40),
    density=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_cooc_matches_ref_sweep(t_blocks, block_t, i_a, i_b, density, seed):
    """Hypothesis sweep over shapes and densities (deliverable c)."""
    rng = np.random.default_rng(seed)
    t = t_blocks * block_t
    a = random_block(rng, t, i_a, density)
    b = random_block(rng, t, i_b, density)
    out = np.asarray(cooc(a, b, block_t=block_t))
    np.testing.assert_allclose(out, np.asarray(cooc_ref(a, b)))


def test_counts_are_exact_integers():
    """f32 accumulation stays exact for realistic block sizes (< 2^24)."""
    rng = np.random.default_rng(7)
    a = random_block(rng, 512, 16, density=0.9)
    out = np.asarray(cooc(a, a, block_t=64))
    assert np.all(out == np.round(out))
    assert out.max() <= 512
