//! Per-task metrics and the job event log.
//!
//! Every task the scheduler runs records `(job, stage, partition, wall
//! time, records produced)`. The virtual-cluster simulator
//! ([`super::simcluster`]) replays these measurements at different core
//! counts to produce the paper's Fig. 15 scaling curves on a small
//! machine, and the benchmark harness reports stage breakdowns from the
//! same log.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Identifies a job (one action).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub usize);

/// What kind of stage a task belonged to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    /// Shuffle map stage (writes buckets).
    ShuffleMap,
    /// Final stage of an action (computes result partitions).
    Result,
}

/// One completed task.
#[derive(Debug, Clone)]
pub struct TaskMetric {
    /// Job this task belonged to.
    pub job: JobId,
    /// Stage index within the job (stages run in submission order).
    pub stage: usize,
    /// Map stage or result stage.
    pub kind: StageKind,
    /// Partition index the task computed.
    pub partition: usize,
    /// Task wall time.
    pub wall: Duration,
    /// Records produced by the task.
    pub records: u64,
}

/// One completed job (action) span.
#[derive(Debug, Clone)]
pub struct JobSpan {
    /// Job id.
    pub job: JobId,
    /// Human-readable action name (`collect`, `count`, ...).
    pub name: String,
    /// Total driver-observed wall time of the job.
    pub wall: Duration,
    /// Number of stages that ran.
    pub stages: usize,
}

/// Registry collecting task metrics and job spans for one context.
#[derive(Default)]
pub struct MetricsRegistry {
    tasks: Mutex<Vec<TaskMetric>>,
    jobs: Mutex<Vec<JobSpan>>,
    next_job: AtomicUsize,
}

impl MetricsRegistry {
    /// Create an empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate the next job id.
    pub fn next_job_id(&self) -> JobId {
        JobId(self.next_job.fetch_add(1, Ordering::SeqCst))
    }

    /// Record one task.
    pub fn record_task(&self, m: TaskMetric) {
        self.tasks.lock().unwrap().push(m);
    }

    /// Record one finished job.
    pub fn record_job(&self, span: JobSpan) {
        self.jobs.lock().unwrap().push(span);
    }

    /// Snapshot of all task metrics.
    pub fn tasks(&self) -> Vec<TaskMetric> {
        self.tasks.lock().unwrap().clone()
    }

    /// Snapshot of all job spans.
    pub fn jobs(&self) -> Vec<JobSpan> {
        self.jobs.lock().unwrap().clone()
    }

    /// Tasks belonging to one job.
    pub fn tasks_of(&self, job: JobId) -> Vec<TaskMetric> {
        self.tasks.lock().unwrap().iter().filter(|t| t.job == job).cloned().collect()
    }

    /// Clear everything (between benchmark repetitions).
    pub fn reset(&self) {
        self.tasks.lock().unwrap().clear();
        self.jobs.lock().unwrap().clear();
    }

    /// Sum of task wall time over all recorded tasks (the "total compute"
    /// that the simulator spreads over virtual cores).
    pub fn total_task_time(&self) -> Duration {
        self.tasks.lock().unwrap().iter().map(|t| t.wall).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tm(job: usize, stage: usize, part: usize, ms: u64) -> TaskMetric {
        TaskMetric {
            job: JobId(job),
            stage,
            kind: StageKind::Result,
            partition: part,
            wall: Duration::from_millis(ms),
            records: 1,
        }
    }

    #[test]
    fn job_ids_monotonic() {
        let r = MetricsRegistry::new();
        assert_eq!(r.next_job_id(), JobId(0));
        assert_eq!(r.next_job_id(), JobId(1));
    }

    #[test]
    fn record_and_filter_by_job() {
        let r = MetricsRegistry::new();
        r.record_task(tm(0, 0, 0, 5));
        r.record_task(tm(1, 0, 0, 7));
        r.record_task(tm(0, 1, 1, 3));
        assert_eq!(r.tasks().len(), 3);
        assert_eq!(r.tasks_of(JobId(0)).len(), 2);
        assert_eq!(r.total_task_time(), Duration::from_millis(15));
        r.reset();
        assert!(r.tasks().is_empty());
    }
}
