//! Span tracing: RAII guards on per-thread span stacks feeding a
//! bounded ring-buffer event log.
//!
//! [`span`] returns a [`SpanGuard`] that pushes onto the current
//! thread's span stack; dropping it (including during panic unwinding)
//! pops the stack and appends one [`SpanEvent`] to the global event
//! ring. Events carry a stable small thread id (`tid`), microsecond
//! timestamps against one process-wide epoch, nesting depth, and
//! optional `(key, value)` args attached at close — exactly what the
//! Chrome trace exporter ([`super::trace`]) needs.
//!
//! Tracing is off by default: when disabled ([`super::enabled`] is
//! false) [`span`] costs one relaxed atomic load and returns an inert
//! guard. The ring keeps the latest [`event_capacity`] events and counts
//! overwritten ones in `dropped`, so long `--serve` runs stay bounded.

use std::cell::RefCell;
use std::time::Instant;

// Process-wide statics live on the std-only `sync::global` plane (loom
// types cannot live in statics); the `EventRing` itself is modeled by
// loom in `loom_tests` below, constructed inside the model.
use crate::sync::global::{lock_unpoisoned, AtomicU32, Mutex, OnceLock, Ordering};

/// Default event-ring capacity (latest events kept).
pub const DEFAULT_EVENT_CAPACITY: usize = 65_536;

/// Whether an event is a duration span or a zero-length marker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A completed span with a duration (Chrome `ph: "X"`).
    Span,
    /// An instantaneous marker (Chrome `ph: "i"`).
    Instant,
}

/// One recorded event.
#[derive(Debug, Clone)]
pub struct SpanEvent {
    /// Static event name (`engine.task.result`, `stream.mine_class`...).
    pub name: &'static str,
    /// Stable small id of the recording thread.
    pub tid: u32,
    /// Start time, microseconds since the process trace epoch.
    pub start_us: u64,
    /// Duration in microseconds (0 for [`EventKind::Instant`]).
    pub dur_us: u64,
    /// Span-stack depth at the time the event opened (0 = top level).
    pub depth: usize,
    /// Args attached at close (`("records", 128)`, `("shard", 3)`...).
    pub args: Vec<(&'static str, u64)>,
    /// Span or instant marker.
    pub kind: EventKind,
}

struct EventRing {
    buf: Vec<SpanEvent>,
    next: usize,
    cap: usize,
    dropped: u64,
}

impl EventRing {
    const fn new() -> EventRing {
        EventRing { buf: Vec::new(), next: 0, cap: DEFAULT_EVENT_CAPACITY, dropped: 0 }
    }

    fn push(&mut self, e: SpanEvent) {
        if self.buf.len() < self.cap {
            self.buf.push(e);
        } else {
            self.buf[self.next] = e;
            self.next = (self.next + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Events in chronological order.
    fn snapshot(&self) -> Vec<SpanEvent> {
        if self.buf.len() < self.cap || self.next == 0 {
            self.buf.clone()
        } else {
            let mut out = Vec::with_capacity(self.buf.len());
            out.extend_from_slice(&self.buf[self.next..]);
            out.extend_from_slice(&self.buf[..self.next]);
            out
        }
    }

    fn clear(&mut self) {
        self.buf.clear();
        self.next = 0;
        self.dropped = 0;
    }
}

static EVENTS: Mutex<EventRing> = Mutex::new(EventRing::new());
static THREAD_NAMES: Mutex<Vec<(u32, String)>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU32 = AtomicU32::new(0);
static EPOCH: OnceLock<Instant> = OnceLock::new();

thread_local! {
    static SPAN_STACK: RefCell<Vec<&'static str>> = const { RefCell::new(Vec::new()) };
    static TID: RefCell<Option<u32>> = const { RefCell::new(None) };
}

/// The process trace epoch: timestamps in all events are measured from
/// the first call (made on first use).
fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

fn micros_since_epoch(t: Instant) -> u64 {
    t.saturating_duration_since(epoch()).as_micros() as u64
}

/// Stable small id for the current thread; registers the thread's name
/// (or `thread-N`) on first use so the exporter can label tracks.
pub fn current_tid() -> u32 {
    TID.with(|cell| {
        let mut slot = cell.borrow_mut();
        if let Some(tid) = *slot {
            return tid;
        }
        // ordering: Relaxed — uniqueness comes from the RMW atomicity
        // of fetch_add alone; no other memory is published through it.
        let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        // Name capture is registration-plane code (std thread API;
        // allowlisted for the `shim-imports` lint rule).
        let name = std::thread::current()
            .name()
            .map(str::to_string)
            .unwrap_or_else(|| format!("thread-{tid}"));
        lock_unpoisoned(&THREAD_NAMES).push((tid, name));
        *slot = Some(tid);
        tid
    })
}

/// `(tid, name)` for every thread that has recorded an event.
pub fn thread_names() -> Vec<(u32, String)> {
    lock_unpoisoned(&THREAD_NAMES).clone()
}

/// Current nesting depth of the calling thread's span stack.
pub fn current_depth() -> usize {
    SPAN_STACK.with(|s| s.borrow().len())
}

/// RAII span: records one [`SpanEvent`] when dropped (panic-safe — the
/// stack pop and the event both happen during unwinding too).
pub struct SpanGuard {
    name: &'static str,
    start: Instant,
    depth: usize,
    args: Vec<(&'static str, u64)>,
    active: bool,
}

/// Open a span. When tracing is disabled this is one relaxed load and
/// the returned guard is inert.
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    if !super::enabled() {
        return SpanGuard { name, start: epoch(), depth: 0, args: Vec::new(), active: false };
    }
    let depth = SPAN_STACK.with(|s| {
        let mut s = s.borrow_mut();
        s.push(name);
        s.len() - 1
    });
    SpanGuard { name, start: Instant::now(), depth, args: Vec::new(), active: true }
}

impl SpanGuard {
    /// Attach a counter value to the span; it rides into the Chrome
    /// trace as an `args` entry when the span closes.
    pub fn arg(&mut self, key: &'static str, value: u64) -> &mut SpanGuard {
        if self.active {
            self.args.push((key, value));
        }
        self
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        SPAN_STACK.with(|s| {
            s.borrow_mut().pop();
        });
        let event = SpanEvent {
            name: self.name,
            tid: current_tid(),
            start_us: micros_since_epoch(self.start),
            dur_us: self.start.elapsed().as_micros() as u64,
            depth: self.depth,
            args: std::mem::take(&mut self.args),
            kind: EventKind::Span,
        };
        lock_unpoisoned(&EVENTS).push(event);
    }
}

/// Record an instantaneous marker event (no-op when tracing is off).
#[inline]
pub fn instant(name: &'static str) {
    if !super::enabled() {
        return;
    }
    let event = SpanEvent {
        name,
        tid: current_tid(),
        start_us: micros_since_epoch(Instant::now()),
        dur_us: 0,
        depth: SPAN_STACK.with(|s| s.borrow().len()),
        args: Vec::new(),
        kind: EventKind::Instant,
    };
    lock_unpoisoned(&EVENTS).push(event);
}

/// Record an externally timed span (used to re-emit the engine's
/// `TaskMetric`/`JobSpan` walls into the same timeline as live spans).
pub fn record_span(
    name: &'static str,
    start: Instant,
    dur_us: u64,
    args: Vec<(&'static str, u64)>,
) {
    if !super::enabled() {
        return;
    }
    let event = SpanEvent {
        name,
        tid: current_tid(),
        start_us: micros_since_epoch(start),
        dur_us,
        depth: SPAN_STACK.with(|s| s.borrow().len()),
        args,
        kind: EventKind::Span,
    };
    lock_unpoisoned(&EVENTS).push(event);
}

/// Chronological snapshot of the event ring plus the count of events
/// overwritten after the ring filled.
pub fn events() -> (Vec<SpanEvent>, u64) {
    let ring = lock_unpoisoned(&EVENTS);
    (ring.snapshot(), ring.dropped)
}

/// Clear the event ring (capacity and thread registrations persist).
pub fn clear_events() {
    lock_unpoisoned(&EVENTS).clear();
}

/// Resize the event ring (clears it). The default is
/// [`DEFAULT_EVENT_CAPACITY`].
pub fn set_event_capacity(cap: usize) {
    let mut ring = lock_unpoisoned(&EVENTS);
    ring.cap = cap.max(1);
    ring.clear();
}

/// Current event-ring capacity.
pub fn event_capacity() -> usize {
    lock_unpoisoned(&EVENTS).cap
}

// Not compiled under `cfg(loom)` (real threads and process-global
// state); the concurrent-recorder coverage lives in `loom_tests`.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;

    #[test]
    fn ring_wraps_and_counts_dropped() {
        let mut ring = EventRing::new();
        ring.cap = 4;
        let ev = |i: u64| SpanEvent {
            name: "t",
            tid: 0,
            start_us: i,
            dur_us: 0,
            depth: 0,
            args: Vec::new(),
            kind: EventKind::Span,
        };
        for i in 0..7 {
            ring.push(ev(i));
        }
        assert_eq!(ring.dropped, 3);
        let snap = ring.snapshot();
        assert_eq!(snap.len(), 4);
        let starts: Vec<u64> = snap.iter().map(|e| e.start_us).collect();
        assert_eq!(starts, vec![3, 4, 5, 6], "latest kept, chronological");
        ring.clear();
        assert_eq!(ring.dropped, 0);
        assert!(ring.snapshot().is_empty());
    }

    #[test]
    fn span_stack_nests_and_unwinds_on_panic() {
        // Enabled tracing is process-global; the stack itself is
        // thread-local, so run the scenario on a dedicated thread.
        crate::obs::set_enabled(true);
        let handle = std::thread::Builder::new()
            .name("obs-nest-test".into())
            .spawn(|| {
                assert_eq!(current_depth(), 0);
                {
                    let _a = span("outer");
                    assert_eq!(current_depth(), 1);
                    {
                        let mut b = span("inner");
                        b.arg("k", 7);
                        assert_eq!(current_depth(), 2);
                    }
                    assert_eq!(current_depth(), 1);
                }
                assert_eq!(current_depth(), 0);

                // RAII unwinding: a panic inside a span still pops it.
                let r = std::panic::catch_unwind(|| {
                    let _g = span("doomed");
                    panic!("boom");
                });
                assert!(r.is_err());
                assert_eq!(current_depth(), 0, "stack unwound by Drop");
            })
            .unwrap();
        handle.join().unwrap();

        let (events, _) = events();
        let inner = events.iter().find(|e| e.name == "inner").expect("inner recorded");
        assert_eq!(inner.depth, 1);
        assert_eq!(inner.args, vec![("k", 7)]);
        let doomed = events.iter().find(|e| e.name == "doomed").expect("doomed recorded");
        assert_eq!(doomed.kind, EventKind::Span);
    }

    #[test]
    fn disabled_span_is_inert() {
        // Another test may have enabled tracing concurrently; drive the
        // guard directly to keep this deterministic.
        let g = SpanGuard { name: "x", start: epoch(), depth: 0, args: Vec::new(), active: false };
        drop(g);
        // An inert guard records nothing and touches no stack; nothing
        // to assert beyond "did not panic or deadlock".
    }

    #[test]
    fn tid_is_stable_and_named() {
        let a = current_tid();
        let b = current_tid();
        assert_eq!(a, b);
        assert!(thread_names().iter().any(|(tid, _)| *tid == a));
    }
}

/// Loom model of the `EventRing` under concurrent recorders: kept +
/// dropped must account for every push, exactly, in every interleaving.
/// Run with `RUSTFLAGS="--cfg loom" cargo test --lib loom_`.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;
    use crate::sync::{thread, Arc, Mutex};

    fn ev(i: u64) -> SpanEvent {
        SpanEvent {
            name: "t",
            tid: 0,
            start_us: i,
            dur_us: 0,
            depth: 0,
            args: Vec::new(),
            kind: EventKind::Span,
        }
    }

    #[test]
    fn loom_ring_wrap_vs_concurrent_recorders_dropped_exact() {
        loom::model(|| {
            // Capacity 2, 4 pushes from 2 threads: exactly 2 events kept
            // and exactly 2 dropped, whatever the interleaving.
            let ring = Arc::new(Mutex::new(EventRing {
                buf: Vec::new(),
                next: 0,
                cap: 2,
                dropped: 0,
            }));
            let recorders: Vec<_> = (0..2u64)
                .map(|t| {
                    let ring = Arc::clone(&ring);
                    thread::spawn(move || {
                        for i in 0..2u64 {
                            ring.lock().unwrap().push(ev(t * 2 + i));
                        }
                    })
                })
                .collect();
            for r in recorders {
                r.join().unwrap();
            }
            let ring = ring.lock().unwrap();
            assert_eq!(ring.dropped, 2, "4 pushes into a cap-2 ring drop exactly 2");
            let snap = ring.snapshot();
            assert_eq!(snap.len(), 2, "exactly `cap` latest events kept");
            assert_eq!(ring.dropped + snap.len() as u64, 4, "every push accounted for");
        });
    }
}
