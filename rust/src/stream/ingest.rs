//! Async ingestion: decouple `push_batch` from mining.
//!
//! [`StreamService`] wraps a [`StreamingMiner`] in a
//! producer/miner/reader pipeline with an explicit lifecycle
//! (spawn → push/query → drain → shutdown):
//!
//! * **Producer side** — [`StreamService::push_batch`] appends the
//!   batch to a queue and returns immediately; it never blocks on
//!   mining and never drops rows.
//! * **Mining loop** — a dedicated thread pops batches, runs the
//!   window/store bookkeeping ([`StreamingMiner::ingest`]) for every
//!   batch in arrival order (results stay window-exact), and mines at
//!   emission points — with the class tasks scattered onto the engine's
//!   executor [`ThreadPool`](crate::engine::pool::ThreadPool), exactly
//!   like the synchronous path.
//! * **Backpressure** — the queue is bounded by
//!   [`IngestConfig::queue_cap`] in the Spark-Streaming sense: it
//!   bounds *mining lag*, not ingestion. When an emission point arrives
//!   while more than `queue_cap` batches are still queued, the emission
//!   is **skipped** (coalesced); bookkeeping keeps advancing, and the
//!   next un-skipped emission — or the catch-up emission the loop runs
//!   as soon as the queue empties — publishes the *latest* window
//!   state. Skip-to-latest trades per-slide snapshots for freshness
//!   under load while keeping every published snapshot exact for the
//!   window it covers.
//! * **Reader side** — every emission is published through the
//!   double-buffered [`SnapshotHandle`](super::serve::SnapshotHandle),
//!   so queries run lock-free while the next window is mined.
//! * **Graceful degradation** — a failed or panicked *emission* does
//!   not kill the service: the loop invalidates the miner's reuse cache
//!   (the next attempt is a full re-mine from the always-exact vertical
//!   store), keeps serving the last good snapshot, and retries at its
//!   next pass. Only [`IngestConfig::max_mine_failures`] *consecutive*
//!   failures — or a failure during window/store bookkeeping, which
//!   poisons the store — take the terminal `dead` path. Failure, retry
//!   and degraded-mode state are surfaced through [`IngestStats`].

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::fim::Item;
use crate::util::json::json_f64;

/// Ingest instrumentation cells, resolved once (see [`crate::obs`]).
struct IngestObs {
    queue_depth: &'static crate::obs::Gauge,
    skipped: &'static crate::obs::Counter,
    mine_retries: &'static crate::obs::Counter,
    degraded: &'static crate::obs::Gauge,
}

fn ingest_obs() -> &'static IngestObs {
    static OBS: OnceLock<IngestObs> = OnceLock::new();
    OBS.get_or_init(|| IngestObs {
        queue_depth: crate::obs::gauge("stream.ingest.queue_depth"),
        skipped: crate::obs::counter("stream.ingest.skipped"),
        mine_retries: crate::obs::counter("stream.mine_retries"),
        degraded: crate::obs::gauge("stream.degraded"),
    })
}

use super::job::{ShardStats, StreamingMiner};
use super::serve::{snapshot_pipe, ServingSnapshot, SnapshotHandle, SnapshotPublisher};

/// Configuration of the async ingest service.
#[derive(Debug, Clone)]
pub struct IngestConfig {
    /// Backpressure threshold: an emission point is skipped
    /// (coalesced skip-to-latest) when more than this many batches are
    /// queued behind it. Bounds mining lag — ingestion itself never
    /// blocks and no batch is ever dropped. Must be ≥ 1.
    pub queue_cap: usize,
    /// Minimum wall time per emission. Zero (the default) for
    /// production; demos and tests use it to pace the mining loop
    /// deterministically.
    pub emission_throttle: Duration,
    /// How many **consecutive** emission failures the service tolerates
    /// before declaring the mining loop dead (default 3, floor 1). Each
    /// tolerated failure triggers a degraded-mode retry: the reuse
    /// cache is invalidated and the next pass re-mines the window from
    /// the vertical store while readers keep the last good snapshot. A
    /// single successful emission resets the streak.
    pub max_mine_failures: u32,
}

impl Default for IngestConfig {
    fn default() -> IngestConfig {
        IngestConfig { queue_cap: 8, emission_throttle: Duration::ZERO, max_mine_failures: 3 }
    }
}

impl IngestConfig {
    /// Config with the given backpressure threshold (`queue_cap >= 1`).
    pub fn new(queue_cap: usize) -> IngestConfig {
        assert!(queue_cap >= 1, "queue_cap must be at least 1");
        IngestConfig { queue_cap, ..IngestConfig::default() }
    }

    /// Set the per-emission throttle (builder style).
    pub fn throttle(mut self, d: Duration) -> IngestConfig {
        self.emission_throttle = d;
        self
    }

    /// Set the consecutive-emission-failure bound (builder style;
    /// values below 1 are clamped to 1 — "die on the first failure").
    pub fn max_mine_failures(mut self, n: u32) -> IngestConfig {
        self.max_mine_failures = n.max(1);
        self
    }
}

/// Outcome of one [`StreamService::push_batch`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Ingest {
    /// Enqueued; the miner is keeping up.
    Accepted {
        /// Batches queued (including this one) after the push.
        pending: usize,
    },
    /// Enqueued, but the queue is over `queue_cap`: the miner is behind
    /// and emissions will coalesce skip-to-latest until it catches up.
    Backpressure {
        /// Batches queued (including this one) after the push.
        pending: usize,
    },
}

/// Lifetime counters of one service.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct IngestStats {
    /// Batches accepted by `push_batch`.
    pub batches: u64,
    /// Snapshots actually mined and published.
    pub emissions: u64,
    /// Emission points skipped under backpressure (each later covered
    /// by a catch-up or subsequent emission).
    pub skipped: u64,
    /// Emissions that failed (error or panic while mining), lifetime.
    pub mine_failures: u64,
    /// Of those, how many were retried in degraded mode rather than
    /// killing the service (always `mine_failures` minus at most one —
    /// the final failure of an exhausted streak is not retried).
    pub mine_retries: u64,
    /// True while the service is in degraded mode: the last emission
    /// attempt failed, readers are being served the previous good
    /// snapshot, and a retry is pending. Cleared by the next successful
    /// emission.
    pub degraded: bool,
    /// Per-shard ingest + mining accounting (one entry per store shard;
    /// a single entry for an unsharded miner). Refreshed by the mining
    /// loop after every bookkept batch and every published emission, so
    /// shard imbalance is observable while the service runs.
    pub shards: Vec<ShardStats>,
    /// Staleness of `shards`: monotonic time since the mining loop last
    /// refreshed the per-shard accounting. A stalled or wedged miner
    /// shows up as a growing `age`, instead of silently serving
    /// arbitrarily old numbers as if they were current.
    pub age: Duration,
}

impl IngestStats {
    /// Flat JSON object for `repro stream --serve --stats-json PATH`:
    /// lifetime counters verbatim, durations in seconds, shards in
    /// store order. Schema pinned by `ingest_stats_json_schema` below.
    pub fn to_json(&self) -> String {
        let shards: Vec<String> = self.shards.iter().map(|s| s.to_json()).collect();
        format!(
            "{{\"batches\": {}, \"emissions\": {}, \"skipped\": {}, \"mine_failures\": {}, \
             \"mine_retries\": {}, \"degraded\": {}, \"age_s\": {}, \"shards\": [{}]}}",
            self.batches,
            self.emissions,
            self.skipped,
            self.mine_failures,
            self.mine_retries,
            self.degraded,
            json_f64(self.age.as_secs_f64()),
            shards.join(", ")
        )
    }
}

/// Queue state shared between producers, the mining loop, and `drain`.
struct QueueState {
    queue: VecDeque<Vec<Vec<Item>>>,
    /// Producer-side close signal; the loop drains, catches up, then exits.
    closing: bool,
    /// The loop is between popping work and finishing it.
    busy: bool,
    /// ≥ 1 emission point has passed without mining since the last
    /// publish — the loop owes a catch-up emission.
    unmined: bool,
    /// Terminal mining-loop error, surfaced to producers and `drain`.
    dead: Option<String>,
}

struct Shared {
    q: Mutex<QueueState>,
    /// Wakes the mining loop (new batch / close).
    work_cv: Condvar,
    /// Wakes `drain` (loop went idle / died).
    idle_cv: Condvar,
    cap: usize,
    batches: AtomicU64,
    emissions: AtomicU64,
    skipped: AtomicU64,
    /// Emission failures, lifetime / retried / current streak (the
    /// streak doubles as the degraded-mode flag: non-zero = degraded).
    mine_failures: AtomicU64,
    mine_retries: AtomicU64,
    consecutive_failures: AtomicU64,
    /// Terminal bound on `consecutive_failures`.
    max_mine_failures: u64,
    /// Latest per-shard accounting, copied out of the miner by the
    /// mining loop (the miner itself lives on the loop thread), plus
    /// the monotonic instant of that refresh (drives `IngestStats::age`).
    shard_stats: Mutex<(Instant, Vec<ShardStats>)>,
}

impl Shared {
    fn lock(&self) -> Result<MutexGuard<'_, QueueState>> {
        self.q.lock().map_err(|_| Error::engine("ingest queue poisoned"))
    }
}

/// The async streaming service: owns the mining loop thread, hands out
/// [`SnapshotHandle`]s, and gives the [`StreamingMiner`] back on
/// [`StreamService::shutdown`].
pub struct StreamService {
    shared: Arc<Shared>,
    handle: SnapshotHandle,
    worker: Option<JoinHandle<(StreamingMiner, Result<()>)>>,
}

impl StreamService {
    /// Start the service: spawns the mining-loop thread and returns
    /// immediately. The miner's emissions run their class tasks on the
    /// engine pool of the `ClusterContext` the miner was built over.
    pub fn spawn(miner: StreamingMiner, cfg: IngestConfig) -> StreamService {
        assert!(cfg.queue_cap >= 1, "queue_cap must be at least 1");
        let shared = Arc::new(Shared {
            q: Mutex::new(QueueState {
                queue: VecDeque::new(),
                closing: false,
                busy: false,
                unmined: false,
                dead: None,
            }),
            work_cv: Condvar::new(),
            idle_cv: Condvar::new(),
            cap: cfg.queue_cap,
            batches: AtomicU64::new(0),
            emissions: AtomicU64::new(0),
            skipped: AtomicU64::new(0),
            mine_failures: AtomicU64::new(0),
            mine_retries: AtomicU64::new(0),
            consecutive_failures: AtomicU64::new(0),
            max_mine_failures: cfg.max_mine_failures.max(1) as u64,
            shard_stats: Mutex::new((Instant::now(), miner.shard_stats())),
        });
        let (publisher, handle) = snapshot_pipe();
        let worker = {
            let shared = Arc::clone(&shared);
            let throttle = cfg.emission_throttle;
            std::thread::Builder::new()
                .name("stream-miner".to_string())
                .spawn(move || mining_loop(miner, shared, publisher, throttle))
                .expect("spawn stream-miner thread")
        };
        StreamService { shared, handle, worker: Some(worker) }
    }

    /// A reader handle onto the live snapshot (cheap clone; hand one to
    /// every query thread).
    pub fn handle(&self) -> SnapshotHandle {
        self.handle.clone()
    }

    /// Enqueue one micro-batch and return immediately — mining happens
    /// on the service thread. Never drops rows; reports
    /// [`Ingest::Backpressure`] when the miner has fallen more than
    /// `queue_cap` batches behind (emissions are coalescing). Errors if
    /// the mining loop has died or the service is shutting down.
    pub fn push_batch(&self, rows: Vec<Vec<Item>>) -> Result<Ingest> {
        let mut st = self.shared.lock()?;
        if let Some(msg) = &st.dead {
            return Err(Error::engine(format!("stream service mining loop died: {msg}")));
        }
        if st.closing {
            return Err(Error::engine("stream service is shutting down"));
        }
        st.queue.push_back(rows);
        let pending = st.queue.len();
        drop(st);
        if crate::obs::enabled() {
            ingest_obs().queue_depth.set(pending as i64);
        }
        // ordering: SeqCst — the lifetime counters are asserted against
        // each other by tests and shutdown logic (e.g. retries vs
        // failures), so they stay in one total order; they are cold
        // (once per batch), so the strongest ordering costs nothing.
        // Any weakening is gated on a green loom run (PR 9 note).
        self.shared.batches.fetch_add(1, Ordering::SeqCst);
        self.shared.work_cv.notify_one();
        if pending > self.shared.cap {
            Ok(Ingest::Backpressure { pending })
        } else {
            Ok(Ingest::Accepted { pending })
        }
    }

    /// Batches queued but not yet bookkept by the mining loop.
    pub fn pending(&self) -> usize {
        self.shared.lock().map(|st| st.queue.len()).unwrap_or(0)
    }

    /// Lifetime counters (batches in, emissions published, emissions
    /// skipped under backpressure), per-shard accounting, and the
    /// staleness (`age`) of that accounting.
    pub fn stats(&self) -> IngestStats {
        let (refreshed, shards) = self
            .shared
            .shard_stats
            .lock()
            .map(|s| (s.0, s.1.clone()))
            .unwrap_or_else(|_| (Instant::now(), Vec::new()));
        let age = refreshed.elapsed();
        let shards = shards.into_iter().map(|s| ShardStats { age, ..s }).collect();
        IngestStats {
            // ordering: SeqCst — read side of the lifetime counters; the
            // single total order keeps cross-counter invariants
            // (retries ≤ failures, degraded ⇔ streak > 0) observable
            // exactly as the mining loop established them.
            batches: self.shared.batches.load(Ordering::SeqCst),
            emissions: self.shared.emissions.load(Ordering::SeqCst),
            skipped: self.shared.skipped.load(Ordering::SeqCst),
            mine_failures: self.shared.mine_failures.load(Ordering::SeqCst),
            mine_retries: self.shared.mine_retries.load(Ordering::SeqCst),
            degraded: self.shared.consecutive_failures.load(Ordering::SeqCst) > 0,
            shards,
            age,
        }
    }

    /// Block until every queued batch has been bookkept **and** any
    /// skipped emission has been caught up, then return the latest
    /// published snapshot (`None` if nothing was ever due). The service
    /// stays usable afterwards.
    pub fn drain(&self) -> Result<Option<Arc<ServingSnapshot>>> {
        let mut st = self.shared.lock()?;
        loop {
            if let Some(msg) = &st.dead {
                return Err(Error::engine(format!(
                    "stream service mining loop died: {msg}"
                )));
            }
            if st.queue.is_empty() && !st.busy && !st.unmined {
                return Ok(self.handle.latest());
            }
            st = self
                .shared
                .idle_cv
                .wait(st)
                .map_err(|_| Error::engine("ingest queue poisoned"))?;
        }
    }

    /// Graceful shutdown: drain the queue, run any owed catch-up
    /// emission, stop the loop, and hand the [`StreamingMiner`] back
    /// (e.g. to materialize the final window). Errors if the mining
    /// loop died.
    pub fn shutdown(mut self) -> Result<StreamingMiner> {
        self.close();
        let worker = self.worker.take().expect("shutdown runs once");
        match worker.join() {
            Ok((miner, Ok(()))) => Ok(miner),
            Ok((_, Err(e))) => Err(e),
            Err(_) => Err(Error::engine("stream-miner thread panicked")),
        }
    }

    fn close(&self) {
        if let Ok(mut st) = self.shared.lock() {
            st.closing = true;
        }
        self.shared.work_cv.notify_all();
    }
}

impl Drop for StreamService {
    fn drop(&mut self) {
        if let Some(worker) = self.worker.take() {
            self.close();
            let _ = worker.join();
        }
    }
}

impl std::fmt::Debug for StreamService {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("StreamService")
            .field("pending", &self.pending())
            .field("stats", &self.stats())
            .finish()
    }
}

/// One unit of work for the loop: a batch to bookkeep, or a catch-up
/// emission owed from a skipped emission point.
enum Work {
    Batch(Vec<Vec<Item>>),
    CatchUp,
}

fn mining_loop(
    mut miner: StreamingMiner,
    shared: Arc<Shared>,
    mut publisher: SnapshotPublisher,
    throttle: Duration,
) -> (StreamingMiner, Result<()>) {
    loop {
        // Pick up work (or exit). The lock is held only around queue
        // bookkeeping, never across mining.
        let work = {
            let mut st = match shared.lock() {
                Ok(st) => st,
                Err(e) => return (miner, Err(e)),
            };
            st.busy = false;
            loop {
                if let Some(batch) = st.queue.pop_front() {
                    if crate::obs::enabled() {
                        ingest_obs().queue_depth.set(st.queue.len() as i64);
                    }
                    st.busy = true;
                    break Work::Batch(batch);
                }
                if st.unmined {
                    st.busy = true;
                    break Work::CatchUp;
                }
                if st.closing {
                    shared.idle_cv.notify_all();
                    return (miner, Ok(()));
                }
                shared.idle_cv.notify_all();
                st = match shared.work_cv.wait(st) {
                    Ok(st) => st,
                    Err(_) => return (miner, Err(Error::engine("ingest queue poisoned"))),
                };
            }
        };

        let mine = match work {
            Work::Batch(rows) => {
                // A panic inside the miner must not wedge the service:
                // unwinding past this loop would leave `busy` set and
                // `dead` unset, hanging `drain()` forever while
                // `push_batch` keeps queueing. Catch it and take the
                // same clean death path a mining `Err` takes.
                let due = match catch_unwind(AssertUnwindSafe(|| miner.ingest(rows))) {
                    Ok(Ok(due)) => due,
                    // A failed shard task poisons the store — same
                    // terminal path as a panic.
                    Ok(Err(e)) => return die(miner, &shared, e),
                    Err(payload) => {
                        let e = Error::engine(format!(
                            "mining loop panicked: {}",
                            panic_message(payload)
                        ));
                        return die(miner, &shared, e);
                    }
                };
                refresh_shard_stats(&shared, &miner);
                if !due {
                    false
                } else {
                    // Emission point. Skip it when the queue has fallen
                    // behind the cap — bookkeeping already advanced, and
                    // a later (or catch-up) emission publishes the
                    // latest state instead.
                    let mut st = match shared.lock() {
                        Ok(st) => st,
                        Err(e) => return (miner, Err(e)),
                    };
                    if st.queue.len() > shared.cap {
                        st.unmined = true;
                        drop(st);
                        // ordering: SeqCst — lifetime counter, see
                        // `push_batch`.
                        shared.skipped.fetch_add(1, Ordering::SeqCst);
                        if crate::obs::enabled() {
                            ingest_obs().skipped.incr(1);
                        }
                        false
                    } else {
                        true
                    }
                }
            }
            Work::CatchUp => true,
        };

        if mine {
            match catch_unwind(AssertUnwindSafe(|| {
                let mut sp = crate::obs::span("stream.mine_now");
                let r = miner.mine_now();
                if let Ok(snap) = &r {
                    sp.arg("batch", snap.batch_id).arg("frequents", snap.frequents.len() as u64);
                }
                r
            })) {
                Ok(Ok(snap)) => {
                    publisher.publish(snap);
                    // ordering: SeqCst — lifetime counters, see
                    // `push_batch`; the streak reset must not be
                    // reordered after a later failure's increment in
                    // the total order `stats()` reads.
                    shared.emissions.fetch_add(1, Ordering::SeqCst);
                    shared.consecutive_failures.store(0, Ordering::SeqCst);
                    if crate::obs::enabled() {
                        ingest_obs().degraded.set(0);
                    }
                    refresh_shard_stats(&shared, &miner);
                    if let Ok(mut st) = shared.lock() {
                        st.unmined = false;
                    }
                    if !throttle.is_zero() {
                        std::thread::sleep(throttle);
                    }
                }
                Ok(Err(e)) => {
                    if let Some(fatal) = note_mine_failure(&mut miner, &shared, &e.to_string()) {
                        return die(miner, &shared, fatal);
                    }
                }
                Err(payload) => {
                    let msg = format!("mining panicked: {}", panic_message(payload));
                    if let Some(fatal) = note_mine_failure(&mut miner, &shared, &msg) {
                        return die(miner, &shared, fatal);
                    }
                }
            }
        }
    }
}

/// Handle one failed emission attempt (error or panic while mining).
/// Bumps the failure counters; when the consecutive streak reaches the
/// bound, returns the terminal error for the caller to die with.
/// Otherwise arranges a degraded-mode retry and returns `None`: the
/// reuse cache is invalidated (the failed attempt may have half-built
/// it — the next attempt full-re-mines from the always-exact vertical
/// store) and `unmined` is left set, so the loop's next pass re-mines
/// the live window while readers keep the last good snapshot.
fn note_mine_failure(miner: &mut StreamingMiner, shared: &Shared, msg: &str) -> Option<Error> {
    // ordering: SeqCst — lifetime counters, see `push_batch`; keeping
    // failures/retries/streak in one total order is what lets tests
    // assert exact relationships between them.
    shared.mine_failures.fetch_add(1, Ordering::SeqCst);
    let streak = shared.consecutive_failures.fetch_add(1, Ordering::SeqCst) + 1;
    if crate::obs::enabled() {
        ingest_obs().degraded.set(streak as i64);
    }
    if streak >= shared.max_mine_failures {
        return Some(Error::engine(format!(
            "{streak} consecutive emission failures, last: {msg}"
        )));
    }
    // ordering: SeqCst — lifetime counter, see `push_batch`.
    shared.mine_retries.fetch_add(1, Ordering::SeqCst);
    if crate::obs::enabled() {
        ingest_obs().mine_retries.incr(1);
    }
    miner.invalidate_cache();
    if let Ok(mut st) = shared.lock() {
        st.unmined = true;
    }
    None
}

/// Copy the miner's per-shard accounting into the shared stats cell so
/// `StreamService::stats` observes it from any thread.
fn refresh_shard_stats(shared: &Shared, miner: &StreamingMiner) {
    if let Ok(mut s) = shared.shard_stats.lock() {
        *s = (Instant::now(), miner.shard_stats());
    }
}

/// Terminal error path of the mining loop: record the cause so
/// `push_batch`/`drain` stop cleanly instead of hanging, wake any
/// waiter, and hand the (possibly half-mutated — it is not reused)
/// miner back with the error.
fn die(miner: StreamingMiner, shared: &Shared, e: Error) -> (StreamingMiner, Result<()>) {
    if let Ok(mut st) = shared.q.lock() {
        st.dead = Some(e.to_string());
        st.busy = false;
    }
    shared.idle_cv.notify_all();
    (miner, Err(e))
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

// Not compiled under `cfg(loom)`: these tests drive the real service
// (timed snapshot waits such as `wait_for_batch_timeout` are
// `cfg(not(loom))`-only).
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::algorithms::SeqEclat;
    use crate::engine::ClusterContext;
    use crate::fim::{sort_frequents, MinSup};
    use crate::stream::{StreamConfig, WindowSpec};

    fn ctx() -> ClusterContext {
        ClusterContext::builder().cores(2).build()
    }

    fn batches(n: usize) -> Vec<Vec<Vec<Item>>> {
        (0..n as u32)
            .map(|i| vec![vec![i % 5, 5 + (i % 3)], vec![i % 5, 10 + (i % 2)]])
            .collect()
    }

    #[test]
    fn ingest_stats_json_schema() {
        let stats = IngestStats {
            batches: 4,
            emissions: 2,
            skipped: 1,
            mine_failures: 1,
            mine_retries: 1,
            degraded: false,
            shards: vec![ShardStats {
                rows: 3,
                postings: 9,
                mined_itemsets: 7,
                mine_wall: Duration::from_millis(1500),
                age: Duration::from_secs(2),
            }],
            age: Duration::from_secs(2),
        };
        // Pinned schema: `repro stream --serve --stats-json` consumers
        // parse exactly this shape.
        assert_eq!(
            stats.to_json(),
            "{\"batches\": 4, \"emissions\": 2, \"skipped\": 1, \"mine_failures\": 1, \
             \"mine_retries\": 1, \"degraded\": false, \"age_s\": 2.000000, \"shards\": \
             [{\"rows\": 3, \"postings\": 9, \"mined_itemsets\": 7, \"mine_wall_s\": 1.500000, \
             \"age_s\": 2.000000}]}"
        );
    }

    #[test]
    fn async_path_matches_sync_miner() {
        let spec = WindowSpec::sliding(3, 1);
        let cfg = || StreamConfig::new(spec, MinSup::count(2));
        let mut sync = StreamingMiner::new(ctx(), cfg());
        let service =
            StreamService::spawn(StreamingMiner::new(ctx(), cfg()), IngestConfig::default());
        let mut last_sync = None;
        for b in batches(12) {
            last_sync = sync.push_batch(b.clone()).unwrap().or(last_sync);
            service.push_batch(b).unwrap();
        }
        let final_snap = service.drain().unwrap().expect("slide 1 emitted");
        let want = last_sync.expect("sync path emitted");
        assert_eq!(final_snap.frequents, want.frequents);
        assert_eq!(final_snap.batch_id, want.batch_id);
        let stats = service.stats();
        assert_eq!(stats.batches, 12);
        assert!(stats.emissions >= 1);
        // Window-exactness against the miner's own window.
        let miner = service.shutdown().unwrap();
        let mut oracle = SeqEclat::mine(&miner.materialize_window(), MinSup::count(2));
        sort_frequents(&mut oracle);
        assert_eq!(final_snap.frequents, oracle);
    }

    #[test]
    fn drain_on_idle_service_is_a_noop() {
        let cfg = StreamConfig::new(WindowSpec::tumbling(2), MinSup::count(1));
        let service = StreamService::spawn(StreamingMiner::new(ctx(), cfg), IngestConfig::new(2));
        assert!(service.drain().unwrap().is_none(), "nothing pushed, nothing published");
        assert_eq!(service.pending(), 0);
        // Drain twice; service stays usable in between.
        service.push_batch(vec![vec![1, 2]]).unwrap();
        service.push_batch(vec![vec![1, 2]]).unwrap();
        let snap = service.drain().unwrap().expect("tumbling(2) emitted");
        assert_eq!(snap.window_txns, 2);
        let miner = service.shutdown().unwrap();
        assert_eq!(miner.window_txns(), 2);
    }

    #[test]
    fn push_after_shutdown_like_close_errors() {
        let cfg = StreamConfig::new(WindowSpec::tumbling(1), MinSup::count(1));
        let service =
            StreamService::spawn(StreamingMiner::new(ctx(), cfg), IngestConfig::default());
        service.close();
        let err = service.push_batch(vec![vec![1]]).unwrap_err();
        assert!(err.to_string().contains("shutting down"), "{err}");
        // Shutdown still returns the miner cleanly.
        let miner = service.shutdown().unwrap();
        assert_eq!(miner.window_txns(), 0);
    }

    #[test]
    fn handle_observes_snapshots_while_service_runs() {
        let service = StreamService::spawn(
            StreamingMiner::new(
                ctx(),
                StreamConfig::new(WindowSpec::sliding(2, 1), MinSup::count(1)),
            ),
            IngestConfig::default(),
        );
        let handle = service.handle();
        for b in batches(4) {
            service.push_batch(b).unwrap();
        }
        let snap = handle
            .wait_for_batch_timeout(3, Duration::from_secs(30))
            .expect("final emission published");
        assert_eq!(snap.batch_id, 3);
        assert!(snap.frequent(&[3]).is_some(), "batch 3's items are in the window");
        service.shutdown().unwrap();
    }

    #[test]
    fn emission_failures_degrade_then_recover() {
        // Chaos: every emission attempt fails twice, then the consecutive
        // cap forces a success. The default bound (3) is never reached,
        // so the service degrades, retries, and recovers — it must end
        // window-exact and never die.
        let ctx = ClusterContext::builder()
            .cores(2)
            .chaos(crate::engine::ChaosPolicy::new(11).emission_failures(1.0, 2))
            .build();
        let cfg = StreamConfig::new(WindowSpec::tumbling(1), MinSup::count(2));
        let service = StreamService::spawn(StreamingMiner::new(ctx, cfg), IngestConfig::default());
        for b in batches(3) {
            service.push_batch(b).unwrap();
        }
        let snap = service.drain().unwrap().expect("emissions survived the chaos");
        let stats = service.stats();
        assert!(stats.mine_failures > 0, "chaos fired: {stats:?}");
        assert_eq!(stats.mine_retries, stats.mine_failures, "every failure was retried");
        assert!(!stats.degraded, "a successful emission clears degraded mode");
        assert!(stats.emissions >= 1);
        let miner = service.shutdown().unwrap();
        let mut oracle = SeqEclat::mine(&miner.materialize_window(), MinSup::count(2));
        sort_frequents(&mut oracle);
        assert_eq!(snap.frequents, oracle, "window-exact after recovery");
    }

    #[test]
    fn service_dies_after_consecutive_emission_failures() {
        // Chaos that out-fails the bound: emissions fail 10 times in a
        // row, the service tolerates only 2 — the terminal path must
        // fire with the streak in the message, and producers must see a
        // clean error instead of a hang.
        let ctx = ClusterContext::builder()
            .cores(2)
            .chaos(crate::engine::ChaosPolicy::new(11).emission_failures(1.0, 10))
            .build();
        let cfg = StreamConfig::new(WindowSpec::tumbling(1), MinSup::count(1));
        let service = StreamService::spawn(
            StreamingMiner::new(ctx, cfg),
            IngestConfig::default().max_mine_failures(2),
        );
        service.push_batch(vec![vec![1, 2]]).unwrap();
        let err = service.drain().unwrap_err();
        assert!(err.to_string().contains("consecutive emission failures"), "{err}");
        let stats = service.stats();
        assert_eq!(stats.mine_failures, 2);
        assert_eq!(stats.mine_retries, 1, "the final failure is not retried");
        assert!(stats.degraded, "died degraded");
        assert!(service.push_batch(vec![vec![3]]).is_err(), "producers see the death");
        assert!(service.shutdown().is_err());
    }

    #[test]
    fn stats_surface_per_shard_accounting() {
        let service = StreamService::spawn(
            StreamingMiner::new(
                ctx(),
                StreamConfig::new(WindowSpec::sliding(3, 1), MinSup::count(2)).shards(4),
            ),
            IngestConfig::new(64),
        );
        assert_eq!(service.stats().shards.len(), 4, "stats shaped before any push");
        for b in batches(8) {
            service.push_batch(b).unwrap();
        }
        service.drain().unwrap().expect("slide 1 emitted");
        let stats = service.stats();
        assert_eq!(stats.shards.len(), 4);
        let postings: u64 = stats.shards.iter().map(|s| s.postings).sum();
        // 8 batches × 2 rows × 2 items, every posting on exactly one shard.
        assert_eq!(postings, 32);
        assert!(
            stats.shards.iter().any(|s| s.mined_itemsets > 0 || s.rows > 0),
            "at least one shard did observable work: {stats:?}"
        );
        // Satellite: staleness stamping. Every shard carries the same
        // age as the stats container, and with the loop idle after
        // drain, age grows monotonically instead of masquerading as
        // fresh.
        assert!(stats.shards.iter().all(|s| s.age == stats.age), "uniform age stamp");
        std::thread::sleep(Duration::from_millis(15));
        let older = service.stats();
        assert!(
            older.age >= Duration::from_millis(15),
            "idle mining loop must surface growing staleness, got {:?}",
            older.age
        );
        service.shutdown().unwrap();
    }
}
