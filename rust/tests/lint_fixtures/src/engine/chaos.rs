//! Fixture: wall-clock reads in the chaos module must be flagged.
//! Never compiled — scanned by `tests/integration_lint.rs` only.

use std::time::Instant;

pub fn should_fail(seed: u64, attempt: u64) -> bool {
    // VIOLATION(chaos-determinism) on the next line (line 8).
    let jitter = Instant::now();
    let _ = jitter;
    // VIOLATION(chaos-determinism) on the next line (line 11).
    let wall = std::time::SystemTime::now();
    let _ = wall;
    (seed ^ attempt) % 7 == 0
}
