//! Sparse clickstream generator — statistical twin of the BMS-WebView
//! datasets (Blue Martini e-commerce click sessions).
//!
//! BMS1/BMS2 are very sparse (average width 2.5 / 5 over 497 / 3340
//! items) with heavily skewed product popularity and short sessions —
//! the regime where the paper *disables* the triangular matrix (the item
//! universe is large relative to support) and where transaction
//! filtering barely shrinks anything. We reproduce those properties:
//! session length ~ shifted geometric; items drawn from a Zipf catalogue;
//! within a session, subsequent clicks stay near the seed product's
//! popularity rank (browsing locality → some frequent pairs survive).

use crate::fim::transaction::Database;
use crate::fim::Item;
use crate::util::prng::{Rng, Zipf};

/// Parameters of the clickstream generator.
#[derive(Debug, Clone)]
pub struct ClickParams {
    /// Number of sessions (transactions).
    pub sessions: usize,
    /// Catalogue size (distinct items).
    pub items: usize,
    /// Average session length.
    pub avg_len: f64,
    /// Zipf skew of product popularity.
    pub skew: f64,
    /// Browsing locality: probability a click is drawn from the
    /// neighbourhood of the session seed instead of the global catalogue.
    pub locality: f64,
    /// Neighbourhood half-width (in popularity rank space).
    pub radius: usize,
    /// Popularity drift: how far the hot spot rotates through the
    /// catalogue (in popularity-rank positions) per transaction. `0.0`
    /// keeps the distribution stationary; nonzero values make item
    /// supports churn over the transaction index — the regime streaming
    /// windows must handle.
    pub drift: f64,
}

impl ClickParams {
    /// BMS_WebView_1-like: 59602 sessions × 497 items, width 2.5.
    pub fn bms1_like() -> ClickParams {
        ClickParams {
            sessions: 59_602,
            items: 497,
            avg_len: 2.5,
            skew: 1.1,
            locality: 0.5,
            radius: 12,
            drift: 0.0,
        }
    }

    /// BMS_WebView_2-like: 77512 sessions × 3340 items, width 5.
    pub fn bms2_like() -> ClickParams {
        ClickParams {
            sessions: 77_512,
            items: 3340,
            avg_len: 5.0,
            skew: 1.15,
            locality: 0.5,
            radius: 25,
            drift: 0.0,
        }
    }

    /// Drifting clickstream: a mid-sized catalogue whose popular region
    /// rotates through roughly one full catalogue revolution over the
    /// configured sessions — every streaming window sees both rising and
    /// fading items, so incremental mining faces real support churn.
    pub fn drift() -> ClickParams {
        let sessions = 50_000;
        let items = 800;
        ClickParams {
            sessions,
            items,
            avg_len: 3.0,
            skew: 0.9,
            locality: 0.5,
            radius: 15,
            drift: items as f64 / sessions as f64,
        }
    }
}

/// The per-transaction popularity-rank rotation at transaction `t`.
fn drift_offset(params: &ClickParams, t: usize) -> usize {
    if params.drift <= 0.0 {
        0
    } else {
        (t as f64 * params.drift) as usize % params.items
    }
}

/// Precomputed sampler state for one clickstream `(params, seed)`: the
/// Zipf tables and the rank→item permutation are built once, after which
/// any transaction index generates in O(session length · log items) —
/// the streaming sources hold one of these across batches.
#[derive(Debug, Clone)]
pub struct ClickGen {
    params: ClickParams,
    seed: u64,
    zipf: Zipf,
    rank_to_item: Vec<Item>,
}

impl ClickGen {
    /// Build the sampler tables for `(params, seed)`.
    pub fn new(params: ClickParams, seed: u64) -> ClickGen {
        let zipf = Zipf::new(params.items, params.skew);
        // Rank -> item id mapping is a fixed permutation so item ids do
        // not leak popularity (like real catalogues).
        let mut rank_to_item: Vec<Item> = (0..params.items as u32).collect();
        Rng::new(seed).shuffle(&mut rank_to_item);
        ClickGen { params, seed, zipf, rank_to_item }
    }

    /// The stream's parameters.
    pub fn params(&self) -> &ClickParams {
        &self.params
    }

    /// Generate transaction `t` of the stream. Each transaction derives
    /// its own splitmix-seeded generator from `(seed, t)`, making the
    /// stream randomly accessible by transaction index.
    pub fn session(&self, t: usize) -> Vec<Item> {
        let mut rng =
            Rng::new(self.seed ^ (t as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let offset = drift_offset(&self.params, t);
        // Shifted geometric with mean avg_len: length >= 1.
        let len = rng.geometric(self.params.avg_len.max(1.0)).max(1);
        let seed_rank = self.zipf.sample(&mut rng);
        let mut row: Vec<Item> = Vec::with_capacity(len);
        for click in 0..len {
            let rank = if click > 0 && rng.chance(self.params.locality) {
                // Stay near the seed's rank (browsing related products).
                let lo = seed_rank.saturating_sub(self.params.radius);
                let hi = (seed_rank + self.params.radius + 1).min(self.params.items);
                rng.range(lo, hi)
            } else {
                self.zipf.sample(&mut rng)
            };
            // Drift rotates which items occupy the popular ranks.
            row.push(self.rank_to_item[(rank + offset) % self.params.items]);
        }
        row.sort_unstable();
        row.dedup();
        row
    }

    /// Generate transactions `start..start + count`.
    pub fn range(&self, start: usize, count: usize) -> Vec<Vec<Item>> {
        (start..start + count).map(|t| self.session(t)).collect()
    }
}

/// Generate transactions `start..start + count` of the stream defined by
/// `(params, seed)`. `generate_range(p, s, 0, n)` concatenated in any
/// batching equals `generate(p, s)` rows — the property the streaming
/// sources rely on. One-shot convenience; hold a [`ClickGen`] instead
/// when generating repeatedly.
pub fn generate_range(
    params: &ClickParams,
    seed: u64,
    start: usize,
    count: usize,
) -> Vec<Vec<Item>> {
    ClickGen::new(params.clone(), seed).range(start, count)
}

/// Generate the clickstream database deterministically from `seed`.
pub fn generate(params: &ClickParams, seed: u64) -> Database {
    let sessions = params.sessions;
    Database::from_rows(ClickGen::new(params.clone(), seed).range(0, sessions))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ClickParams {
        ClickParams {
            sessions: 5000,
            items: 400,
            avg_len: 2.5,
            skew: 1.1,
            locality: 0.5,
            radius: 10,
            drift: 0.0,
        }
    }

    #[test]
    fn deterministic_by_seed() {
        assert_eq!(generate(&small(), 1), generate(&small(), 1));
        assert_ne!(generate(&small(), 1), generate(&small(), 2));
    }

    #[test]
    fn shape_matches_bms_profile() {
        let db = generate(&small(), 42);
        let s = db.stats();
        assert_eq!(s.transactions, 5000);
        assert!(s.avg_width > 1.5 && s.avg_width < 3.5, "width {}", s.avg_width);
        assert!(s.distinct_items > 250, "{}", s.distinct_items);
        assert!(s.max_item < 400);
    }

    #[test]
    fn popularity_is_skewed() {
        let db = generate(&small(), 7);
        let mut counts = std::collections::HashMap::new();
        for t in db.transactions() {
            for &i in t {
                *counts.entry(i).or_insert(0u32) += 1;
            }
        }
        let mut freqs: Vec<u32> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        let total: u32 = freqs.iter().sum();
        let head: u32 = freqs.iter().take(20).sum();
        assert!(
            head as f64 / total as f64 > 0.25,
            "top-20 items should dominate: {}",
            head as f64 / total as f64
        );
    }

    #[test]
    fn range_generation_matches_full_generation() {
        let p = small();
        let full = generate(&p, 11);
        // Any batching of generate_range reassembles the same rows.
        let mut rows = Vec::new();
        for (start, count) in [(0usize, 700usize), (700, 1), (701, 2299), (3000, 2000)] {
            rows.extend(generate_range(&p, 11, start, count));
        }
        assert_eq!(Database::from_rows(rows), full);
    }

    /// Top-20 most-clicked items of a row slice.
    fn top_items(rows: &[Vec<Item>]) -> std::collections::HashSet<Item> {
        let mut counts = std::collections::HashMap::new();
        for r in rows {
            for &i in r {
                *counts.entry(i).or_insert(0u32) += 1;
            }
        }
        let mut v: Vec<(Item, u32)> = counts.into_iter().collect();
        v.sort_unstable_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        v.into_iter().take(20).map(|(i, _)| i).collect()
    }

    #[test]
    fn drift_shifts_item_popularity_over_time() {
        let p = ClickParams { sessions: 20_000, drift: 800.0 / 20_000.0, ..ClickParams::drift() };
        // Offsets 0..80 vs 360..440 rank positions: disjoint hot regions.
        let head = top_items(&generate_range(&p, 5, 0, 2000));
        let tail = top_items(&generate_range(&p, 5, 9000, 2000));
        let overlap = head.intersection(&tail).count();
        assert!(overlap < 10, "popular sets should diverge under drift, overlap {overlap}");
    }

    #[test]
    fn zero_drift_is_stationary() {
        // Same stream positions as the drift test, but drift disabled:
        // the popular set must now be stable over the transaction index.
        let p = ClickParams { sessions: 20_000, drift: 0.0, ..ClickParams::drift() };
        let head = top_items(&generate_range(&p, 5, 0, 2000));
        let tail = top_items(&generate_range(&p, 5, 9000, 2000));
        let overlap = head.intersection(&tail).count();
        assert!(overlap >= 12, "popular sets should persist without drift, overlap {overlap}");
    }

    #[test]
    fn locality_creates_frequent_pairs() {
        let db = generate(&small(), 3);
        let min_sup = (db.len() as f64 * 0.005).ceil() as u32; // 0.5%
        let frequents = crate::fim::apriori::apriori(&db, min_sup);
        let pairs = frequents.iter().filter(|f| f.items.len() == 2).count();
        assert!(pairs > 0, "locality should produce co-clicked pairs");
    }
}
