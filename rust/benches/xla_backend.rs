//! Bench: native vs XLA (AOT PJRT) backends for the support-counting hot
//! spots — the L1/runtime side of the A4 ablation, at several block
//! sizes. Skips with a notice when `make artifacts` has not run, and
//! compiles to a notice-only stub without the `xla` cargo feature.

#[cfg(feature = "xla")]
mod real {
    use std::sync::Arc;

    use rdd_eclat::algorithms::common::NativeCooc;
    use rdd_eclat::algorithms::TriMatrixProvider;
    use rdd_eclat::bench::{black_box, Bench, Report};
    use rdd_eclat::fim::TidBitmap;
    use rdd_eclat::runtime::{XlaCooc, XlaIntersect, XlaService};
    use rdd_eclat::util::prng::Rng;

    pub fn main() {
        if !rdd_eclat::runtime::artifacts_available() {
            println!("artifacts/ missing — run `make artifacts`; skipping xla_backend bench");
            return;
        }
        let bench = Bench::from_env();
        let mut report = Report::new();
        let svc =
            Arc::new(XlaService::start(rdd_eclat::runtime::default_artifact_dir()).unwrap());
        let mut rng = Rng::new(7);

        // --- co-occurrence at three transaction-count scales ---
        for &n_txns in &[512usize, 2048, 8192] {
            let txns: Vec<Vec<u32>> = (0..n_txns)
                .map(|_| {
                    let mut t: Vec<u32> = (0..16).map(|_| rng.below(120) as u32).collect();
                    t.sort_unstable();
                    t.dedup();
                    t
                })
                .collect();
            let native = NativeCooc;
            let xla = XlaCooc::new(Arc::clone(&svc));
            report.add(
                bench
                    .try_run(format!("cooc/native/txns={n_txns}"), || native.compute(&txns, 119))
                    .unwrap(),
            );
            report.add(
                bench
                    .try_run(format!("cooc/xla/txns={n_txns}"), || xla.compute(&txns, 119))
                    .unwrap(),
            );
        }

        // --- batched intersection at two batch sizes ---
        let xi = XlaIntersect::new(svc);
        for &batch in &[256usize, 2048] {
            let universe = 2048;
            let bitmaps: Vec<(TidBitmap, TidBitmap)> = (0..batch)
                .map(|_| {
                    let mk = |rng: &mut Rng| {
                        TidBitmap::from_tids(
                            universe,
                            (0..universe as u32).filter(|_| rng.chance(0.15)),
                        )
                    };
                    (mk(&mut rng), mk(&mut rng))
                })
                .collect();
            let pairs: Vec<(&TidBitmap, &TidBitmap)> =
                bitmaps.iter().map(|(a, b)| (a, b)).collect();
            report.add(bench.run(format!("intersect/native/batch={batch}"), || {
                black_box(pairs.iter().map(|(a, b)| a.and_count(b)).sum::<u32>())
            }));
            report.add(
                bench
                    .try_run(format!("intersect/xla/batch={batch}"), || xi.batch_supports(&pairs))
                    .unwrap(),
            );
        }

        report.write_csv("bench_xla_backend.csv").expect("write csv");
        println!("\nwrote results/bench_xla_backend.csv");
    }
}

#[cfg(feature = "xla")]
fn main() {
    real::main();
}

#[cfg(not(feature = "xla"))]
fn main() {
    println!("xla_backend bench requires the `xla` feature — rerun with `cargo bench --features xla`");
}
