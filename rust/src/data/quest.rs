//! IBM Quest-style synthetic transaction generator.
//!
//! The paper's synthetic datasets (T10I4D100K, T40I10D100K, c20d10k) come
//! from the classic IBM Quest generator (Agrawal–Srikant §Experiments):
//! transactions are built from a pool of *potentially frequent patterns* —
//! itemsets with exponentially decaying weights, correlated with their
//! predecessor, "corrupted" when inserted. This module reimplements that
//! process (we have no network access to the originals; DESIGN.md §2.2).
//!
//! Parameter names follow the conventional dataset naming:
//! `T` = average transaction width, `I` = average pattern length,
//! `D` = number of transactions, `N` = number of items.

use crate::fim::transaction::Database;
use crate::fim::Item;
use crate::util::prng::Rng;

/// Quest generator parameters.
#[derive(Debug, Clone)]
pub struct QuestParams {
    /// Number of transactions (`D`).
    pub transactions: usize,
    /// Average transaction width (`T`).
    pub avg_width: f64,
    /// Average pattern length (`I`).
    pub avg_pattern_len: f64,
    /// Number of distinct items (`N`).
    pub items: usize,
    /// Number of potentially frequent patterns (`L`; Quest default 2000,
    /// scaled down with small vocabularies).
    pub patterns: usize,
    /// Fraction of a pattern reused from its predecessor (Quest default
    /// 0.25).
    pub correlation: f64,
    /// Mean corruption level (Quest default 0.5): items are dropped from
    /// a pattern instance while `rand < c`.
    pub corruption: f64,
}

impl QuestParams {
    /// Conventional `T{t}I{i}D{d}` parameterisation with `n` items.
    pub fn tid(t: f64, i: f64, d: usize, n: usize) -> QuestParams {
        QuestParams {
            transactions: d,
            avg_width: t,
            avg_pattern_len: i,
            items: n,
            patterns: (n / 2).clamp(10, 2000),
            correlation: 0.25,
            corruption: 0.5,
        }
    }
}

/// One potentially frequent pattern: items + relative weight.
struct Pattern {
    items: Vec<Item>,
    cum_weight: f64,
}

/// Generate a database per the Quest process, deterministically from
/// `seed`.
pub fn generate(params: &QuestParams, seed: u64) -> Database {
    let mut rng = Rng::new(seed);
    let patterns = build_patterns(params, &mut rng);
    let total_weight = patterns.last().map(|p| p.cum_weight).unwrap_or(0.0);

    let mut rows = Vec::with_capacity(params.transactions);
    for _ in 0..params.transactions {
        // Transaction size ~ Poisson(T), at least 1.
        let size = params.avg_width.max(1.0);
        let want = rng.poisson(size).max(1);
        let mut t: Vec<Item> = Vec::with_capacity(want + 4);
        let mut guard = 0;
        while t.len() < want && guard < 50 {
            guard += 1;
            // Weighted pattern pick (binary search on cumulative weights).
            let u = rng.f64() * total_weight;
            let idx = patterns
                .partition_point(|p| p.cum_weight < u)
                .min(patterns.len() - 1);
            // Corrupt: drop items from the tail while rand < corruption.
            let p = &patterns[idx].items;
            let mut keep = p.len();
            while keep > 0 && rng.chance(params.corruption) {
                keep -= 1;
            }
            if keep == 0 {
                continue;
            }
            // Quest inserts the (corrupted) pattern even if it overshoots
            // the transaction size, half the time.
            if t.len() + keep > want && !t.is_empty() && rng.chance(0.5) {
                break;
            }
            t.extend_from_slice(&p[..keep]);
        }
        t.sort_unstable();
        t.dedup();
        if t.is_empty() {
            t.push(rng.below(params.items as u64) as Item);
        }
        rows.push(t);
    }
    Database::from_rows(rows)
}

fn build_patterns(params: &QuestParams, rng: &mut Rng) -> Vec<Pattern> {
    let mut patterns: Vec<Pattern> = Vec::with_capacity(params.patterns);
    let mut cum = 0.0;
    let mut prev: Vec<Item> = Vec::new();
    for _ in 0..params.patterns {
        let len = rng.poisson(params.avg_pattern_len).max(1);
        let mut items: Vec<Item> = Vec::with_capacity(len);
        // Correlated fraction from the previous pattern.
        if !prev.is_empty() {
            let take = ((len as f64) * params.correlation).round() as usize;
            for _ in 0..take.min(prev.len()) {
                items.push(prev[rng.range(0, prev.len())]);
            }
        }
        while items.len() < len {
            items.push(rng.below(params.items as u64) as Item);
        }
        items.sort_unstable();
        items.dedup();
        // Exponential weights, as in Quest.
        let w = -(rng.f64().max(f64::MIN_POSITIVE)).ln();
        cum += w;
        prev = items.clone();
        patterns.push(Pattern { items, cum_weight: cum });
    }
    patterns
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let p = QuestParams::tid(10.0, 4.0, 200, 100);
        let a = generate(&p, 7);
        let b = generate(&p, 7);
        assert_eq!(a, b);
        let c = generate(&p, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn matches_requested_shape() {
        let p = QuestParams::tid(10.0, 4.0, 2000, 200);
        let db = generate(&p, 42);
        let s = db.stats();
        assert_eq!(s.transactions, 2000);
        assert!(s.max_item < 200);
        // Width within a tolerant band of T (corruption narrows it a bit).
        assert!(
            s.avg_width > 4.0 && s.avg_width < 16.0,
            "avg width {}",
            s.avg_width
        );
        // Vocabulary largely used.
        assert!(s.distinct_items > 120, "{} items", s.distinct_items);
    }

    #[test]
    fn has_correlated_structure() {
        // Patterns create recurring co-occurrence: mining at a moderate
        // threshold should find some 2-itemsets, unlike i.i.d. noise.
        let p = QuestParams::tid(12.0, 4.0, 1000, 150);
        let db = generate(&p, 3);
        let min_sup = 50; // 5%
        let frequents = crate::fim::apriori::apriori(&db, min_sup);
        let pairs = frequents.iter().filter(|f| f.items.len() >= 2).count();
        assert!(pairs > 0, "expected frequent pairs from pattern structure");
    }

    #[test]
    fn no_empty_transactions() {
        let p = QuestParams::tid(2.0, 2.0, 500, 50);
        let db = generate(&p, 11);
        assert!(db.transactions().iter().all(|t| !t.is_empty()));
    }
}
