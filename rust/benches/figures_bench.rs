//! `cargo bench` entry point for the paper's tables and figures.
//!
//! Defaults to `SCALE=quick` sanity sweeps (endpoints of each min-sup
//! grid on truncated datasets) so `cargo bench` terminates in minutes;
//! set `SCALE=paper` for the full Table 2 sizes the EXPERIMENTS.md
//! numbers come from (or run `target/release/figures --all`).

use rdd_eclat::bench::Bench;
use rdd_eclat::figures::{
    run_a1, run_a2, run_a3, run_a4, run_fig15, run_fig16, run_fig_minsup, run_table2,
    FigureCtx, MINSUP_FIGS,
};

fn main() {
    let mut fx = FigureCtx::from_env();
    // cargo bench default: quick, unless SCALE=paper was set explicitly.
    if !matches!(std::env::var("SCALE").as_deref(), Ok("paper")) {
        fx.quick = true;
        fx.bench = Bench::quick();
    }
    println!(
        "figures bench at scale={} (SCALE=paper for full sizes)",
        if fx.quick { "quick" } else { "paper" }
    );

    run_table2(&fx).expect("table2");
    for (no, spec) in MINSUP_FIGS {
        run_fig_minsup(&fx, no, spec).expect("minsup fig");
    }
    run_fig15(&fx).expect("fig15");
    run_fig16(&fx).expect("fig16");
    run_a1(&fx).expect("a1");
    run_a2(&fx).expect("a2");
    run_a3(&fx).expect("a3");
    run_a4(&fx).expect("a4");
    println!("\nall figure benches complete; CSVs under results/");
}
