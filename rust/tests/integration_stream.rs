//! Streaming correctness: at every emission, incremental window mining
//! must equal `SeqEclat` run from scratch on the materialized window
//! contents — across seeds, window geometries, slide steps (including
//! slides larger than the window, i.e. full eviction between emissions),
//! shard counts (1, 2, 4, 7 — including more shards than distinct
//! items) and degenerate batches (empty batches, empty transactions).

use std::collections::HashSet;

use rdd_eclat::algorithms::SeqEclat;
use rdd_eclat::data::clickstream::{generate_range, ClickParams};
use rdd_eclat::engine::ClusterContext;
use rdd_eclat::fim::{sort_frequents, Database, Frequent, MinSup};
use rdd_eclat::stream::{
    IncrementalVerticalDb, MineMode, MinePlan, ShardedVerticalDb, StreamConfig, StreamingMiner,
    WindowSpec,
};
use rdd_eclat::util::prng::Rng;
use rdd_eclat::util::prop::{check, prop_assert_eq, Config};

fn oracle(db: &Database, min_sup: MinSup) -> Vec<Frequent> {
    let mut v = SeqEclat::mine(db, min_sup);
    sort_frequents(&mut v);
    v
}

fn random_batch(rng: &mut Rng, n_items: u32) -> Vec<Vec<u32>> {
    let n_rows = rng.range(0, 9); // empty batches included
    (0..n_rows)
        .map(|_| {
            // Occasionally an empty transaction.
            let width = rng.range(0, 6);
            (0..width).map(|_| rng.below(n_items as u64) as u32).collect()
        })
        .collect()
}

#[test]
fn incremental_equals_from_scratch_oracle_at_every_emission() {
    let ctx = ClusterContext::builder().cores(2).build();
    check(Config::default().cases(40).seed(0x57E0), |rng| {
        let n_items = rng.range(3, 14) as u32;
        let window = rng.range(1, 5);
        let slide = rng.range(1, window + 3); // slide > window covered
        let min_sup = if rng.chance(0.5) {
            MinSup::count(rng.range(1, 5) as u32)
        } else {
            MinSup::fraction(0.05 + rng.f64() * 0.6)
        };
        // Low churn thresholds force the delta path; high ones the full
        // re-mine path — both must agree with the oracle. Shard counts
        // cover the classic path (1) and sharded scatter-gather,
        // including more shards (7) than most runs have items.
        let churn_threshold = if rng.chance(0.5) { 1.0 } else { rng.f64() };
        let shards = [1usize, 2, 4, 7][rng.below(4) as usize];
        let cfg = StreamConfig {
            churn_threshold,
            ..StreamConfig::new(WindowSpec::sliding(window, slide), min_sup).shards(shards)
        };
        let mut twin = StreamingMiner::new(ctx.clone(), StreamConfig { shards: 1, ..cfg.clone() });
        let mut miner = StreamingMiner::new(ctx.clone(), cfg);
        let mut emissions = 0;
        for _ in 0..rng.range(3, 20) {
            let batch = random_batch(rng, n_items);
            let twin_snap = twin.push_batch(batch.clone()).expect("twin push");
            if let Some(snap) = miner.push_batch(batch).expect("push") {
                emissions += 1;
                let db = miner.materialize_window();
                prop_assert_eq(snap.window_txns, db.len(), "window size")?;
                let want = oracle(&db, min_sup);
                if snap.frequents != want {
                    return Err(format!(
                        "emission {emissions} (plan {:?}, {shards} shards, window {window} \
                         slide {slide}, min_sup {min_sup:?}): got {:?} want {want:?}",
                        snap.plan, snap.frequents
                    ));
                }
                // The shards=1 twin is the parity oracle for the whole
                // snapshot, rules included.
                let twin_snap = twin_snap.ok_or("twin skipped an emission")?;
                prop_assert_eq(&snap.frequents, &twin_snap.frequents, "sharded vs 1-shard")?;
                prop_assert_eq(&snap.rules, &twin_snap.rules, "sharded vs 1-shard rules")?;
                prop_assert_eq(snap.batch_id, twin_snap.batch_id, "emission batch ids")?;
            } else if twin_snap.is_some() {
                return Err(format!("{shards}-shard miner skipped an emission the twin made"));
            }
        }
        Ok(())
    });
}

#[test]
fn long_sliding_run_exercises_delta_reuse_and_compaction() {
    // A drifting clickstream sliding far enough that (a) the delta path
    // actually fires with reuse, and (b) the store's dead prefix exceeds
    // the live span repeatedly (compaction). Parity is checked at every
    // emission.
    let ctx = ClusterContext::builder().cores(2).build();
    let params = ClickParams {
        sessions: 4000,
        items: 120,
        avg_len: 2.5,
        skew: 0.9,
        locality: 0.5,
        radius: 8,
        drift: 120.0 / 4000.0,
    };
    let min_sup = MinSup::count(4);
    let cfg = StreamConfig {
        // Never fall back to a full re-mine: this test wants the delta
        // path (and its cache reuse) under real churn.
        churn_threshold: 1.0,
        ..StreamConfig::new(WindowSpec::sliding(8, 1), min_sup)
    };
    let mut miner = StreamingMiner::new(ctx, cfg);
    let (batch_size, n_batches) = (50, 40);
    let mut deltas_with_reuse = 0;
    for b in 0..n_batches {
        let rows = generate_range(&params, 31, b * batch_size, batch_size);
        let snap = miner.push_batch(rows).expect("push").expect("slide 1 emits");
        let want = oracle(&miner.materialize_window(), min_sup);
        assert_eq!(snap.frequents, want, "batch {b}, plan {:?}", snap.plan);
        if let MinePlan::Delta { reused_itemsets, .. } = snap.plan {
            if reused_itemsets > 0 {
                deltas_with_reuse += 1;
            }
        }
    }
    assert!(
        deltas_with_reuse > 0,
        "the delta path with cache reuse never fired over {n_batches} batches"
    );
}

#[test]
fn modes_agree_and_are_deterministic() {
    let params = ClickParams { sessions: 1200, ..ClickParams::drift() };
    let spec = WindowSpec::sliding(4, 2);
    let min_sup = MinSup::fraction(0.02);
    let run = |mode: MineMode| {
        let ctx = ClusterContext::builder().cores(2).build();
        let mut miner =
            StreamingMiner::new(ctx, StreamConfig::new(spec, min_sup).mode(mode));
        let mut out = Vec::new();
        for b in 0..12 {
            let rows = generate_range(&params, 5, b * 100, 100);
            if let Some(snap) = miner.push_batch(rows).expect("push") {
                out.push((snap.batch_id, snap.frequents, snap.rules.len()));
            }
        }
        out
    };
    let inc = run(MineMode::Incremental);
    let scratch = run(MineMode::FromScratch);
    assert_eq!(inc.len(), 6, "12 pushes at slide 2");
    assert_eq!(inc, scratch, "modes must agree emission by emission");
    assert_eq!(inc, run(MineMode::Incremental), "runs are deterministic");
}

#[test]
fn tumbling_full_eviction_between_emissions() {
    // Tumbling geometry: every emission covers a disjoint set of batches;
    // everything from the previous window is evicted in between.
    let ctx = ClusterContext::builder().cores(2).build();
    let min_sup = MinSup::count(2);
    let mut miner = StreamingMiner::new(
        ctx,
        StreamConfig::new(WindowSpec::tumbling(2), min_sup),
    );
    let phases: [Vec<Vec<u32>>; 6] = [
        vec![vec![1, 2], vec![1, 2]],
        vec![vec![1, 2, 3]],
        vec![vec![4, 5], vec![4, 5]], // disjoint vocabulary
        vec![vec![4, 6]],
        vec![],                       // empty batches
        vec![],
    ];
    let mut snaps = Vec::new();
    for batch in phases {
        if let Some(s) = miner.push_batch(batch).expect("push") {
            let want = oracle(&miner.materialize_window(), min_sup);
            assert_eq!(s.frequents, want);
            snaps.push(s);
        }
    }
    assert_eq!(snaps.len(), 3);
    assert!(snaps[0].frequents.contains(&Frequent::new(vec![1, 2], 3)));
    assert!(snaps[1].frequents.contains(&Frequent::new(vec![4], 3)));
    assert!(
        !snaps[1].frequents.iter().any(|f| f.items.contains(&1)),
        "fully evicted items must vanish"
    );
    assert!(snaps[2].frequents.is_empty(), "empty window mines empty");
    assert_eq!(snaps[2].window_txns, 0);
}

#[test]
fn more_shards_than_distinct_items_leaves_empty_shards_exact() {
    // 7 shards over a 3-item vocabulary: at least 4 shards own nothing,
    // yet every one must track the shared tid space through appends,
    // evictions and full drainage — and mining must stay oracle-exact.
    let ctx = ClusterContext::builder().cores(2).build();
    let min_sup = MinSup::count(2);
    let cfg = StreamConfig::new(WindowSpec::sliding(2, 1), min_sup).shards(7);
    let mut miner = StreamingMiner::new(ctx, cfg);
    let batches: [Vec<Vec<u32>>; 6] = [
        vec![vec![0, 1], vec![1, 2]],
        vec![vec![0, 1, 2]],
        vec![],                       // empty batch between emissions
        vec![vec![2], vec![0, 2]],
        vec![vec![1]],
        vec![],                       // window drains down to one batch
    ];
    let mut emissions = 0;
    for batch in batches {
        if let Some(snap) = miner.push_batch(batch).expect("push") {
            emissions += 1;
            let want = oracle(&miner.materialize_window(), min_sup);
            assert_eq!(snap.frequents, want, "emission {emissions}, plan {:?}", snap.plan);
        }
    }
    assert_eq!(emissions, 6, "slide 1 emits on every push");
    let stats = miner.shard_stats();
    assert_eq!(stats.len(), 7);
    let empty = stats.iter().filter(|s| s.postings == 0).count();
    assert!(empty >= 4, "only 3 items can own postings, got {empty} empty shards");
}

#[test]
fn sharded_long_run_stays_aligned_through_compaction() {
    // Long drifting run on a sliding(6, 1) window: the dead prefix
    // repeatedly outgrows the live span, so every shard compacts many
    // times. A 4-shard miner, a 1-shard twin and the from-scratch oracle
    // must agree at all ~30 emissions.
    let params = ClickParams {
        sessions: 2000,
        items: 60,
        avg_len: 2.5,
        skew: 0.9,
        locality: 0.5,
        radius: 6,
        drift: 60.0 / 2000.0,
    };
    let min_sup = MinSup::count(3);
    let spec = WindowSpec::sliding(6, 1);
    let ctx = ClusterContext::builder().cores(3).build();
    let mut sharded = StreamingMiner::new(
        ctx.clone(),
        StreamConfig { churn_threshold: 1.0, ..StreamConfig::new(spec, min_sup).shards(4) },
    );
    let mut single = StreamingMiner::new(
        ctx,
        StreamConfig { churn_threshold: 1.0, ..StreamConfig::new(spec, min_sup) },
    );
    let (batch_size, n_batches) = (40, 36);
    for b in 0..n_batches {
        let rows = generate_range(&params, 77, b * batch_size, batch_size);
        let snap = sharded.push_batch(rows.clone()).expect("push").expect("slide 1 emits");
        let twin = single.push_batch(rows).expect("push").expect("slide 1 emits");
        assert_eq!(snap.frequents, twin.frequents, "batch {b}: sharded vs 1-shard");
        assert_eq!(snap.rules, twin.rules, "batch {b}: rules diverged");
        let want = oracle(&sharded.materialize_window(), min_sup);
        assert_eq!(snap.frequents, want, "batch {b}: sharded vs oracle, plan {:?}", snap.plan);
    }
    let stats = sharded.shard_stats();
    assert_eq!(stats.len(), 4);
    let total: u64 = stats.iter().map(|s| s.postings).sum();
    assert!(total > 0, "sharded run ingested postings");
    assert!(
        stats.iter().filter(|s| s.postings > 0).count() >= 2,
        "reverse-hash routing should spread 60 items over several shards: {stats:?}"
    );
}

#[test]
fn sharded_store_with_one_shard_is_the_single_store() {
    // Through the public API, ShardedVerticalDb::new(1) must behave
    // exactly like a bare IncrementalVerticalDb under the same lockstep
    // append/evict sequence.
    let mut single = IncrementalVerticalDb::new();
    let mut one = ShardedVerticalDb::new(1);
    let mut ds = HashSet::new();
    let mut dm = vec![HashSet::new()];
    let mut held: Vec<Vec<Vec<u32>>> = Vec::new();
    for step in 0..60u32 {
        let batch: Vec<Vec<u32>> = (0..(step % 3) as usize)
            .map(|r| {
                rdd_eclat::stream::window::normalize_row(vec![
                    step % 7,
                    (step + 1 + r as u32) % 7,
                ])
            })
            .collect();
        held.push(batch.clone());
        single.append(&batch, &mut ds);
        one.append(&batch, &mut dm);
        if held.len() > 4 {
            let old = held.remove(0);
            let mut touched: Vec<u32> = old.iter().flatten().copied().collect();
            touched.sort_unstable();
            touched.dedup();
            single.evict_touched(old.len(), &touched, &mut ds);
            one.evict_touched(old.len(), &touched, &mut dm);
        }
        assert_eq!(one.txns(), single.txns(), "step {step}");
        assert_eq!(one.distinct_items(), single.distinct_items(), "step {step}");
        assert_eq!(one.live_rows(), single.live_rows(), "step {step}");
        assert_eq!(dm[0], ds, "step {step}: dirty sets diverged");
        for item in 0..7 {
            assert_eq!(one.support(item), single.support(item), "step {step} item {item}");
        }
        let flat = |v: Vec<(u32, rdd_eclat::fim::TidBitmap, u32)>| -> Vec<(u32, Vec<u32>, u32)> {
            v.into_iter().map(|(i, bm, s)| (i, bm.iter().collect(), s)).collect()
        };
        assert_eq!(
            flat(one.atoms(1, |_| true)),
            flat(single.atoms(1, |_| true)),
            "step {step}: atoms diverged"
        );
    }
}
