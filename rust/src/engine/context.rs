//! The driver-side entry point of the engine — the analogue of Spark's
//! `SparkContext`.
//!
//! A [`ClusterContext`] owns the executor thread pool, the block cache,
//! the shuffle store and the metrics registry. RDDs are created from it
//! (`parallelize`, `text_file`) and carry a handle back to it; all jobs of
//! one context share executors and stores, exactly like one Spark
//! application.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::error::Result;

use super::metrics::MetricsRegistry;
use super::pool::ThreadPool;
use super::rdd::{Rdd, RddId};
use super::shared::{Accumulator, Broadcast};
use super::shuffle::{ShuffleId, ShuffleStore};
use super::storage::CacheStore;

/// Shared internals of one "application".
pub(crate) struct CtxInner {
    pub(crate) pool: ThreadPool,
    pub(crate) cores: usize,
    pub(crate) default_parallelism: usize,
    pub(crate) cache: CacheStore,
    pub(crate) shuffle: ShuffleStore,
    pub(crate) metrics: MetricsRegistry,
    next_rdd: AtomicUsize,
    next_shuffle: AtomicUsize,
}

/// Driver handle; cheap to clone (it is an `Arc`).
#[derive(Clone)]
pub struct ClusterContext {
    pub(crate) inner: Arc<CtxInner>,
}

/// Builder for [`ClusterContext`].
#[derive(Debug, Clone)]
pub struct ContextBuilder {
    cores: usize,
    default_parallelism: Option<usize>,
}

impl Default for ContextBuilder {
    fn default() -> Self {
        ContextBuilder { cores: available_cores(), default_parallelism: None }
    }
}

/// Number of cores the OS exposes (≥1).
pub fn available_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl ContextBuilder {
    /// Executor core count (thread-pool size). Defaults to the machine's
    /// available parallelism.
    pub fn cores(mut self, n: usize) -> Self {
        self.cores = n.max(1);
        self
    }

    /// Default number of partitions for `parallelize`/shuffles. Defaults
    /// to the core count (Spark's `sc.defaultParallelism`).
    pub fn default_parallelism(mut self, n: usize) -> Self {
        self.default_parallelism = Some(n.max(1));
        self
    }

    /// Build the context, spawning executor threads.
    pub fn build(self) -> ClusterContext {
        let parallelism = self.default_parallelism.unwrap_or(self.cores);
        ClusterContext {
            inner: Arc::new(CtxInner {
                pool: ThreadPool::new(self.cores),
                cores: self.cores,
                default_parallelism: parallelism,
                cache: CacheStore::new(),
                shuffle: ShuffleStore::new(),
                metrics: MetricsRegistry::new(),
                next_rdd: AtomicUsize::new(0),
                next_shuffle: AtomicUsize::new(0),
            }),
        }
    }
}

impl ClusterContext {
    /// Start building a context.
    pub fn builder() -> ContextBuilder {
        ContextBuilder::default()
    }

    /// Context with default settings (all available cores).
    pub fn local() -> ClusterContext {
        Self::builder().build()
    }

    /// Executor core count.
    pub fn cores(&self) -> usize {
        self.inner.cores
    }

    /// Default parallelism (`sc.defaultParallelism`).
    pub fn default_parallelism(&self) -> usize {
        self.inner.default_parallelism
    }

    pub(crate) fn new_rdd_id(&self) -> RddId {
        RddId(self.inner.next_rdd.fetch_add(1, Ordering::SeqCst))
    }

    pub(crate) fn new_shuffle_id(&self) -> ShuffleId {
        ShuffleId(self.inner.next_shuffle.fetch_add(1, Ordering::SeqCst))
    }

    /// Metrics registry for this application.
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.inner.metrics
    }

    /// The block cache (exposed for fault-injection tests).
    pub fn cache_store(&self) -> &CacheStore {
        &self.inner.cache
    }

    /// The shuffle store (exposed for fault-injection tests).
    pub fn shuffle_store(&self) -> &ShuffleStore {
        &self.inner.shuffle
    }

    /// Distribute a collection into `parts` partitions (Spark's
    /// `sc.parallelize`). Items are split into contiguous chunks.
    pub fn parallelize<T: super::rdd::Data>(&self, data: Vec<T>, parts: usize) -> Rdd<T> {
        Rdd::from_collection(self.clone(), data, parts.max(1))
    }

    /// `sc.parallelize` with default parallelism.
    pub fn parallelize_default<T: super::rdd::Data>(&self, data: Vec<T>) -> Rdd<T> {
        let p = self.default_parallelism();
        self.parallelize(data, p)
    }

    /// Read a text file into an RDD of lines split into `min_parts`
    /// contiguous partitions (Spark's `sc.textFile`). The whole file is
    /// read eagerly on the driver — the local filesystem plays HDFS here.
    pub fn text_file(&self, path: &str, min_parts: usize) -> Result<Rdd<String>> {
        let content = std::fs::read_to_string(path)?;
        let lines: Vec<String> = content.lines().map(|s| s.to_string()).collect();
        Ok(self.parallelize(lines, min_parts.max(1)))
    }

    /// Broadcast a read-only value to all tasks.
    pub fn broadcast<T: Send + Sync + 'static>(&self, value: T) -> Broadcast<T> {
        Broadcast::new(value)
    }

    /// Create an accumulator with a zero value and an associative,
    /// commutative merge.
    pub fn accumulator<T: Send + 'static>(
        &self,
        zero: T,
        merge: impl Fn(&mut T, T) + Send + Sync + 'static,
    ) -> Accumulator<T> {
        Accumulator::new(zero, merge)
    }
}

impl std::fmt::Debug for ClusterContext {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ClusterContext")
            .field("cores", &self.inner.cores)
            .field("default_parallelism", &self.inner.default_parallelism)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_overrides() {
        let ctx = ClusterContext::builder().cores(3).build();
        assert_eq!(ctx.cores(), 3);
        assert_eq!(ctx.default_parallelism(), 3);
        let ctx = ClusterContext::builder().cores(2).default_parallelism(8).build();
        assert_eq!(ctx.default_parallelism(), 8);
    }

    #[test]
    fn ids_are_unique() {
        let ctx = ClusterContext::builder().cores(1).build();
        let a = ctx.new_rdd_id();
        let b = ctx.new_rdd_id();
        assert_ne!(a, b);
        let s1 = ctx.new_shuffle_id();
        let s2 = ctx.new_shuffle_id();
        assert_ne!(s1, s2);
    }

    #[test]
    fn text_file_roundtrip() {
        let dir = std::env::temp_dir().join("rdd_eclat_ctx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("lines.txt");
        std::fs::write(&path, "a b\nc d\ne\n").unwrap();
        let ctx = ClusterContext::builder().cores(2).build();
        let rdd = ctx.text_file(path.to_str().unwrap(), 2).unwrap();
        let mut lines = rdd.collect().unwrap();
        lines.sort();
        assert_eq!(lines, vec!["a b", "c d", "e"]);
    }
}
