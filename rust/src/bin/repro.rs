//! `repro` — the launcher. Mines a dataset with any of the paper's
//! algorithms, generates benchmark datasets, prints Table 2 statistics,
//! and derives association rules.
//!
//! ```text
//! repro run      --algo eclatV4 --dataset T10I4D100K --min-sup 0.01
//! repro run      --config experiment.toml
//! repro generate --dataset chess --data-dir datasets
//! repro datasets
//! repro rules    --dataset chess --min-sup 0.9 --min-conf 0.95 --json rules.json
//! repro stream   --batch 500 --window 20 --slide 1 --min-sup 0.01
//! ```

use rdd_eclat::algorithms::{CoocStrategy, EclatOptions, MiningSession, Variant};
use rdd_eclat::cli::{App, Command};
use rdd_eclat::conf::EclatConfig;
use rdd_eclat::data::clickstream::ClickParams;
use rdd_eclat::data::{self, DatasetSpec, TABLE2};
use rdd_eclat::engine::{ChaosPolicy, ClusterContext, ContextBuilder};
use rdd_eclat::error::{Error, Result};
use rdd_eclat::fim::{generate_rules, rules_to_json, sort_frequents};
use rdd_eclat::net::{RemoteShardSet, ShardWorker};
use rdd_eclat::stream::{
    BatchSource, ClickstreamSource, IngestConfig, MineMode, Paced, ReplaySource, StreamConfig,
    StreamService, StreamingMiner, WindowSpec,
};
use rdd_eclat::util::time::fmt_duration;

fn app() -> App {
    App::new("repro", "RDD-Eclat: parallel Eclat on a Spark-like RDD engine")
        .command(
            Command::new("run", "mine frequent itemsets")
                .opt("config", "TOML config file (flags override)")
                .opt("algo", "algorithm name (see --list-algos)")
                .flag("list-algos", "list the registered algorithms and exit")
                .opt("dataset", "Table 2 name or FIMI file path")
                .opt("min-sup", "fraction (0,1] or absolute count (>1)")
                .opt("cores", "executor cores (default: all)")
                .opt("p", "equivalence-class partitions for V4/V5 (default 10)")
                .opt("backend", "phase-2 co-occurrence backend: native | xla")
                .opt("data-dir", "dataset cache dir (default datasets/)")
                .opt("output", "save frequent itemsets under this directory")
                .opt("trace", "write a Chrome trace (chrome://tracing, Perfetto) to this path")
                .opt("chaos", "inject seeded faults mid-job: <seed>:<p> (results must not change)")
                .flag("no-tri-matrix", "disable the triangular-matrix optimization")
                .flag("quiet", "suppress the itemset listing"),
        )
        .command(
            Command::new("generate", "generate a benchmark dataset to disk")
                .opt("dataset", "Table 2 name (required)")
                .opt("data-dir", "output dir (default datasets/)"),
        )
        .command(Command::new("datasets", "list Table 2 datasets with generated stats"))
        .command(
            Command::new("rules", "mine association rules (ARM step 2)")
                .opt("dataset", "Table 2 name or FIMI file path")
                .opt("min-sup", "fraction or count")
                .opt("min-conf", "minimum confidence (default 0.8)")
                .opt("top", "print at most N rules (default 20)")
                .opt("json", "also write all rules as JSON to this path")
                .opt("data-dir", "dataset cache dir"),
        )
        .command(
            Command::new("stream", "micro-batch sliding-window mining (DStream-style)")
                .opt("dataset", "Table 2 name or FIMI path to replay (default: drifting clickstream)")
                .opt("batch", "transactions per micro-batch (default 500)")
                .opt("window", "window length in batches (default 20)")
                .opt("slide", "slide step in batches (default 1)")
                .opt("batches", "micro-batches to ingest (default 60)")
                .opt("min-sup", "fraction (0,1] or absolute count (>1)")
                .opt("min-conf", "minimum rule confidence (default 0.8)")
                .opt("cores", "executor cores (default: all)")
                .opt("shards", "store shards mined in parallel per emission (default 1)")
                .opt(
                    "workers",
                    "mine on remote shard workers at host:port,host:port,... \
                     (one shard per worker; mutually exclusive with --shards)",
                )
                .opt("mode", "incremental | from-scratch (default incremental)")
                .opt("interval", "inter-batch pacing in milliseconds (default 0)")
                .opt("json", "write the final snapshot (itemsets + rules) as JSON")
                .opt("data-dir", "dataset cache dir")
                .opt("trace", "write a Chrome trace (chrome://tracing, Perfetto) to this path")
                .opt("chaos", "inject seeded faults mid-job: <seed>:<p> (results must not change)")
                .opt("queue-cap", "--serve: backpressure threshold in queued batches (default 8)")
                .opt("readers", "--serve: concurrent query threads (default 2)")
                .opt("stats-every", "--serve: print a one-line metrics digest every N batches")
                .opt("stats-json", "--serve: write the final ingest stats as JSON to this path")
                .flag(
                    "serve",
                    "async ingest + live snapshot serving: mining runs on a service \
                     thread while query threads read the double-buffered handle",
                )
                .flag("quiet", "suppress the per-emission progress lines"),
        )
        .command(
            Command::new("shard-worker", "host streaming store shards for a remote driver")
                .opt("listen", "host:port to listen on (required; port 0 picks a free one)"),
        )
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match dispatch(&argv) {
        Ok(()) => {}
        Err(Error::Usage(msg)) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn dispatch(argv: &[String]) -> Result<()> {
    let app = app();
    let (cmd, args) = app.dispatch(argv)?;
    match cmd.name {
        "run" => cmd_run(&args),
        "generate" => cmd_generate(&args),
        "datasets" => cmd_datasets(),
        "rules" => cmd_rules(&args),
        "stream" => cmd_stream(&args),
        "shard-worker" => cmd_shard_worker(&args),
        _ => unreachable!(),
    }
}

fn config_from_args(args: &rdd_eclat::cli::Args) -> Result<EclatConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => EclatConfig::from_file(path)?,
        None => EclatConfig::default(),
    };
    if let Some(v) = args.get("algo") {
        cfg.algorithm = v.to_string();
    }
    if let Some(v) = args.get("dataset") {
        cfg.dataset = v.to_string();
    }
    cfg.min_sup = args.get_parse("min-sup", cfg.min_sup)?;
    cfg.cores = args.get_parse("cores", cfg.cores)?;
    cfg.partitions = args.get_parse("p", cfg.partitions)?;
    cfg.min_conf = args.get_parse("min-conf", cfg.min_conf)?;
    if let Some(v) = args.get("backend") {
        if v != "native" && v != "xla" {
            return Err(Error::Usage(format!("--backend must be native|xla, got {v}")));
        }
        cfg.backend = v.to_string();
    }
    if let Some(v) = args.get("data-dir") {
        cfg.data_dir = v.to_string();
    }
    if let Some(v) = args.get("output") {
        cfg.output = Some(v.to_string());
    }
    if args.flag("no-tri-matrix") {
        cfg.tri_matrix = Some(false);
    }
    Ok(cfg)
}

/// The `--backend xla` co-occurrence strategy (feature-gated).
#[cfg(feature = "xla")]
fn xla_cooc_strategy() -> Result<CoocStrategy> {
    let svc = std::sync::Arc::new(rdd_eclat::runtime::XlaService::start(
        rdd_eclat::runtime::default_artifact_dir(),
    )?);
    Ok(CoocStrategy::Provider(std::sync::Arc::new(rdd_eclat::runtime::XlaCooc::new(svc))))
}

#[cfg(not(feature = "xla"))]
fn xla_cooc_strategy() -> Result<CoocStrategy> {
    Err(Error::Usage(
        "this binary was built without the `xla` feature; rebuild with \
         `cargo build --release --features xla` to use --backend xla"
            .into(),
    ))
}

/// The shared variant options from a config: per-dataset `triMatrixMode`
/// default, `p`, and the Phase-2 backend.
fn eclat_options(cfg: &EclatConfig) -> Result<EclatOptions> {
    // Per-dataset default for triMatrixMode (the paper disables it on BMS).
    let tri_default = DatasetSpec::parse(&cfg.dataset).map(|s| s.tri_matrix_mode()).unwrap_or(true);
    let cooc = if cfg.backend == "xla" {
        xla_cooc_strategy()?
    } else {
        CoocStrategy::Accumulator
    };
    Ok(EclatOptions {
        tri_matrix: cfg.tri_matrix.unwrap_or(tri_default),
        partitions: cfg.partitions,
        cooc,
    })
}

fn print_algo_listing() {
    println!("registered algorithms (--algo accepts these and their aliases):");
    for v in Variant::all() {
        println!("  {:<14} {}", v.name(), v.describe());
    }
}

/// Enable the observability layer when the invocation asked for it
/// (`--trace` and/or `--stats-every`). Must run before any instrumented
/// work so spans from worker threads land in the event log.
fn arm_observability(args: &rdd_eclat::cli::Args) {
    if args.get("trace").is_some() || args.get("stats-every").is_some() {
        rdd_eclat::obs::set_enabled(true);
    }
}

/// Resolve the chaos policy for this invocation: the explicit `--chaos
/// <seed>:<p>` flag wins; otherwise the `RDD_ECLAT_CHAOS` environment
/// variable (same syntax) arms it. Both reject malformed specs loudly —
/// a chaos run that silently ran fault-free would prove nothing.
fn chaos_from_args(args: &rdd_eclat::cli::Args) -> Result<Option<ChaosPolicy>> {
    match args.get("chaos") {
        Some(spec) => ChaosPolicy::parse(spec).map(Some),
        None => ChaosPolicy::from_env(),
    }
}

/// Arm `builder` with `chaos` (if any) and announce it in the run
/// header, so chaos-mode output is self-describing in CI logs.
fn arm_chaos(builder: ContextBuilder, chaos: &Option<ChaosPolicy>) -> ContextBuilder {
    match chaos {
        Some(c) => {
            println!("chaos armed: {c}");
            builder.chaos(c.clone())
        }
        None => builder,
    }
}

/// Write the collected span events as a Chrome trace, if `--trace` was
/// given, and print where it went. Also prints the final metrics digest
/// whenever the observability layer is armed.
fn finish_observability(args: &rdd_eclat::cli::Args) -> Result<()> {
    if let Some(path) = args.get("trace") {
        rdd_eclat::obs::write_chrome_trace(path)?;
        let (events, dropped) = rdd_eclat::obs::events();
        println!("wrote {path} ({} trace events, {dropped} dropped)", events.len());
    }
    if rdd_eclat::obs::enabled() {
        println!("metrics: {}", rdd_eclat::obs::snapshot().digest());
    }
    Ok(())
}

fn cmd_run(args: &rdd_eclat::cli::Args) -> Result<()> {
    if args.flag("list-algos") {
        print_algo_listing();
        return Ok(());
    }
    arm_observability(args);
    let cfg = config_from_args(args)?;
    let variant: Variant = cfg.algorithm.parse()?;
    let db = data::resolve(&cfg.dataset, &cfg.data_dir)?;
    let stats = db.stats();
    let cores = cfg.effective_cores();
    let chaos = chaos_from_args(args)?;
    let ctx = arm_chaos(ClusterContext::builder().cores(cores), &chaos).build();
    println!(
        "mining {} ({} txns, {} items, avg width {:.1}) with {} @ min_sup {} on {cores} cores",
        cfg.dataset, stats.transactions, stats.distinct_items, stats.avg_width,
        variant, cfg.min_sup
    );
    let result = MiningSession::on(&ctx)
        .db(&db)
        .min_sup(cfg.min_sup_typed()?)
        .options(eclat_options(&cfg)?)
        .run(variant)?;
    println!(
        "found {} frequent itemsets in {}",
        result.len(),
        fmt_duration(result.wall)
    );
    for p in &result.phases {
        println!("  {:<8} {}", p.name, fmt_duration(p.wall));
    }
    if let Some(red) = result.filtered_reduction {
        println!("  filtering reduced transaction volume by {:.1}%", red * 100.0);
    }
    if let Some(dir) = &cfg.output {
        std::fs::create_dir_all(dir)?;
        let mut sorted = result.frequents.clone();
        sort_frequents(&mut sorted);
        let text: String = sorted.iter().map(|f| format!("{f}\n")).collect();
        let path = format!("{dir}/frequent_itemsets.txt");
        std::fs::write(&path, text)?;
        println!("wrote {path}");
    } else if !args.flag("quiet") {
        let mut sorted = result.frequents.clone();
        sort_frequents(&mut sorted);
        for f in sorted.iter().take(20) {
            println!("  {f}");
        }
        if sorted.len() > 20 {
            println!("  ... ({} more; use --output to save all)", sorted.len() - 20);
        }
    }
    finish_observability(args)
}

fn cmd_generate(args: &rdd_eclat::cli::Args) -> Result<()> {
    let name = args.get("dataset").ok_or_else(|| Error::Usage("--dataset required".into()))?;
    let dir = args.get("data-dir").unwrap_or("datasets");
    let spec = DatasetSpec::parse(name)
        .ok_or_else(|| Error::Usage(format!("unknown dataset {name:?}")))?;
    let db = spec.materialize(dir)?;
    let s = db.stats();
    println!(
        "{}: {} txns, {} items, avg width {:.2}",
        spec.cache_path(dir),
        s.transactions,
        s.distinct_items,
        s.avg_width
    );
    Ok(())
}

fn cmd_datasets() -> Result<()> {
    println!(
        "{:<16} {:>10} {:>8} {:>10}  (paper Table 2 targets)",
        "dataset", "txns", "items", "avg_width"
    );
    for spec in TABLE2 {
        let (t, i, w) = spec.table2_row();
        println!("{:<16} {:>10} {:>8} {:>10.1}", spec.name(), t, i, w);
    }
    println!("\nuse `repro generate --dataset <name>` to materialize the twin");
    Ok(())
}

fn cmd_rules(args: &rdd_eclat::cli::Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    let top: usize = args.get_parse("top", 20usize)?;
    let db = data::resolve(&cfg.dataset, &cfg.data_dir)?;
    let ctx = ClusterContext::builder().build();
    // Itemset mining feeding the ARM step always uses the paper's
    // best-performing variant.
    let result = MiningSession::on(&ctx)
        .db(&db)
        .min_sup(cfg.min_sup_typed()?)
        .options(eclat_options(&cfg)?)
        .run(Variant::V4)?;
    let rules = generate_rules(&result.frequents, cfg.min_conf, Some(db.len()));
    println!(
        "{} frequent itemsets -> {} rules at min_conf {}",
        result.len(),
        rules.len(),
        cfg.min_conf
    );
    for r in rules.iter().take(top) {
        println!("  {r}");
    }
    if let Some(path) = args.get("json") {
        std::fs::write(path, rules_to_json(&rules))?;
        println!("wrote {path}");
    }
    Ok(())
}

fn cmd_stream(args: &rdd_eclat::cli::Args) -> Result<()> {
    arm_observability(args);
    let cfg = config_from_args(args)?;
    let batch: usize = args.get_parse("batch", 500usize)?;
    let window: usize = args.get_parse("window", 20usize)?;
    let slide: usize = args.get_parse("slide", 1usize)?;
    // Replayed datasets default to running until the source is
    // exhausted; the endless generator needs a bound (default 60).
    let batches: usize = match (args.get("batches"), args.get("dataset")) {
        (None, Some(_)) => usize::MAX,
        _ => args.get_parse("batches", 60usize)?,
    };
    let interval_ms: u64 = args.get_parse("interval", 0u64)?;
    // `--workers` moves the store shards out of the process: one shard
    // per worker, so the worker list fixes the shard count and the two
    // flags cannot both be given.
    let workers: Option<Vec<String>> = match args.get("workers") {
        Some(spec) => {
            if args.get("shards").is_some() {
                return Err(Error::Usage(
                    "--workers and --shards are mutually exclusive (one shard per worker)".into(),
                ));
            }
            Some(
                spec.split(',')
                    .map(|a| parse_worker_addr(a.trim()))
                    .collect::<Result<Vec<String>>>()?,
            )
        }
        None => None,
    };
    let shards: usize = match &workers {
        Some(list) => list.len(),
        None => args.get_parse("shards", 1usize)?,
    };
    if batch == 0 || window == 0 || slide == 0 {
        return Err(Error::Usage("--batch, --window and --slide must be >= 1".into()));
    }
    if shards == 0 {
        return Err(Error::Usage("--shards must be >= 1".into()));
    }
    let mode = match args.get("mode").unwrap_or("incremental") {
        "incremental" | "inc" => MineMode::Incremental,
        "from-scratch" | "scratch" | "rebuild" => MineMode::FromScratch,
        other => {
            return Err(Error::Usage(format!(
                "--mode must be incremental|from-scratch, got {other}"
            )))
        }
    };

    // Source: replay a dataset, or run the drifting clickstream generator.
    let mut source: Box<dyn BatchSource> = match args.get("dataset") {
        Some(name) => Box::new(ReplaySource::new(data::resolve(name, &cfg.data_dir)?, batch)),
        None => {
            let params = ClickParams::drift();
            Box::new(ClickstreamSource::new(params, 42, batch).with_limit(batches * batch))
        }
    };
    if interval_ms > 0 {
        source = Box::new(Paced::new(source, std::time::Duration::from_millis(interval_ms)));
    }

    let cores = cfg.effective_cores();
    // `--serve` also injects emission failures (at the engine-fault
    // probability, bounded to 2 consecutive) to exercise the service's
    // degraded-mode retry; the sync path mines inline, where a failed
    // emission would just be the command failing.
    let chaos = chaos_from_args(args)?.map(|c| {
        if args.flag("serve") {
            let p = c.task_panic_p();
            c.emission_failures(p, 2)
        } else {
            c
        }
    });
    let ctx = arm_chaos(ClusterContext::builder().cores(cores), &chaos).build();
    let stream_cfg = StreamConfig::new(WindowSpec::sliding(window, slide), cfg.min_sup_typed()?)
        .mode(mode)
        .min_conf(cfg.min_conf)
        .shards(shards);
    println!(
        "streaming {} txns/batch, window {window} batches slide {slide}, min_sup {} \
         min_conf {} ({mode:?}, {cores} cores, {shards} shards)",
        batch, cfg.min_sup, cfg.min_conf
    );
    let mut miner = StreamingMiner::new(ctx, stream_cfg);
    if let Some(addrs) = &workers {
        println!("remote shards: {} workers ({})", addrs.len(), addrs.join(", "));
        miner.attach_remote(RemoteShardSet::connect(addrs)?.with_chaos(chaos.as_ref()));
    }
    if args.flag("serve") {
        return cmd_stream_serve(args, source, miner, batches);
    }

    let mut last = None;
    let mut emissions = 0usize;
    for _ in 0..batches {
        let Some(rows) = source.next_batch() else { break };
        if let Some(snap) = miner.push_batch(rows)? {
            emissions += 1;
            if !args.flag("quiet") {
                println!("{}", snap.summary());
            }
            last = Some(snap);
        }
    }
    if let Some(remote) = miner.remote_mut() {
        remote.shutdown();
    }
    let Some(snap) = last else {
        println!("stream ended before the first emission (need >= {slide} batches)");
        return finish_observability(args);
    };
    println!(
        "\n{emissions} emissions; final window: {} txns, {} frequent itemsets, {} rules",
        snap.window_txns,
        snap.frequents.len(),
        snap.rules.len()
    );
    if shards > 1 {
        print_shard_stats(&miner.shard_stats());
    }
    for r in snap.rules.iter().take(10) {
        println!("  {r}");
    }
    if snap.rules.len() > 10 {
        println!("  ... ({} more rules)", snap.rules.len() - 10);
    }
    if let Some(path) = args.get("json") {
        std::fs::write(path, snap.to_json())?;
        println!("wrote {path}");
    }
    finish_observability(args)
}

/// Syntax-check one `--workers` address: `host:port` with a numeric
/// port (reachability is only known at connect time).
fn parse_worker_addr(addr: &str) -> Result<String> {
    let bad = || Error::Usage(format!("worker address {addr:?} must be host:port"));
    let (host, port) = addr.rsplit_once(':').ok_or_else(bad)?;
    if host.is_empty() || port.parse::<u16>().is_err() {
        return Err(bad());
    }
    Ok(addr.to_string())
}

/// `repro shard-worker`: host streaming store shards behind a listen
/// address and serve apply/mine/stats RPCs until the driver sends a
/// shutdown frame. Replica state survives reconnects, so a chaos-prone
/// driver can drop and re-establish its connection freely.
fn cmd_shard_worker(args: &rdd_eclat::cli::Args) -> Result<()> {
    let addr = args.get("listen").ok_or_else(|| Error::Usage("--listen required".into()))?;
    let worker = ShardWorker::bind(addr)?;
    println!("shard worker listening on {}", worker.local_addr()?);
    // The accept loop blocks next; flush so a supervising script sees
    // readiness even when stdout is a pipe.
    std::io::Write::flush(&mut std::io::stdout()).ok();
    worker.run()
}

/// Per-shard store/mining accounting, shared by the sync and `--serve`
/// paths of `repro stream` when running with `--shards > 1`.
fn print_shard_stats(shards: &[rdd_eclat::stream::ShardStats]) {
    println!("per-shard accounting:");
    for (s, st) in shards.iter().enumerate() {
        println!(
            "  shard {s}: {} live rows, {} postings, {} itemsets mined, last mine {}, age {}",
            st.rows,
            st.postings,
            st.mined_itemsets,
            fmt_duration(st.mine_wall),
            fmt_duration(st.age)
        );
    }
}

/// `repro stream --serve`: async ingest through a [`StreamService`],
/// with query threads reading the live double-buffered handle while the
/// mining loop publishes.
fn cmd_stream_serve(
    args: &rdd_eclat::cli::Args,
    mut source: Box<dyn BatchSource>,
    miner: StreamingMiner,
    batches: usize,
) -> Result<()> {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    let queue_cap: usize = args.get_parse("queue-cap", 8usize)?;
    let readers: usize = args.get_parse("readers", 2usize)?;
    let stats_every: usize = args.get_parse("stats-every", 0usize)?;
    if queue_cap == 0 {
        return Err(Error::Usage("--queue-cap must be >= 1".into()));
    }
    let quiet = args.flag("quiet");
    let service = StreamService::spawn(miner, IngestConfig::new(queue_cap));
    println!("serving: queue cap {queue_cap}, {readers} query threads\n");

    let stop = Arc::new(AtomicBool::new(false));
    let query_threads: Vec<_> = (0..readers)
        .map(|r| {
            let handle = service.handle();
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let mut last_seen = u64::MAX;
                let mut queries = 0u64;
                // ordering: SeqCst — simple stop flag on a cold loop
                // (each iteration does a snapshot read); strongest
                // ordering keeps the final query counts coherent with
                // the drain that precedes the store. Not a hot path.
                while !stop.load(Ordering::SeqCst) {
                    if let Some(snap) = handle.latest() {
                        queries += 1;
                        if !quiet && snap.batch_id != last_seen {
                            // Demonstrate the antecedent index on the
                            // strongest rule of the live snapshot.
                            let probe = snap.rules.first().map(|rule| {
                                (rule.antecedent.clone(), snap.rules_for(&rule.antecedent).len())
                            });
                            match probe {
                                Some((ante, n)) => println!(
                                    "  [reader {r}] batch {:>4}: {} itemsets, {} rules; \
                                     rules_for({ante:?}) -> {n}",
                                    snap.batch_id,
                                    snap.frequents.len(),
                                    snap.rules.len(),
                                ),
                                None => println!(
                                    "  [reader {r}] batch {:>4}: {} itemsets, no rules yet",
                                    snap.batch_id,
                                    snap.frequents.len(),
                                ),
                            }
                        }
                        last_seen = snap.batch_id;
                    }
                    std::thread::sleep(std::time::Duration::from_millis(100));
                }
                queries
            })
        })
        .collect();

    for i in 0..batches {
        let Some(rows) = source.next_batch() else { break };
        service.push_batch(rows)?;
        if stats_every > 0 && (i + 1) % stats_every == 0 {
            let st = service.stats();
            println!(
                "[stats] batch {:>4} (stats age {}): {}",
                i + 1,
                fmt_duration(st.age),
                rdd_eclat::obs::snapshot().digest()
            );
        }
    }
    let last = service.drain()?;
    // ordering: SeqCst — pairs with the readers' stop-flag load above.
    stop.store(true, Ordering::SeqCst);
    let mut total_queries = 0u64;
    for t in query_threads {
        total_queries += t.join().unwrap_or(0);
    }
    let stats = service.stats();
    let mut miner = service.shutdown()?;
    if let Some(remote) = miner.remote_mut() {
        remote.shutdown();
    }
    if let Some(path) = args.get("stats-json") {
        std::fs::write(path, stats.to_json())?;
        println!("wrote {path}");
    }

    let Some(snap) = last else {
        println!("stream ended before the first emission");
        return finish_observability(args);
    };
    println!(
        "\n{} batches in, {} emissions published, {} skipped under backpressure, \
         {total_queries} live queries answered",
        stats.batches, stats.emissions, stats.skipped
    );
    if stats.shards.len() > 1 {
        print_shard_stats(&stats.shards);
    }
    println!(
        "final window: {} txns, {} frequent itemsets, {} rules ({} distinct antecedents)",
        snap.window_txns,
        snap.frequents.len(),
        snap.rules.len(),
        snap.antecedents()
    );
    for r in snap.rules.iter().take(10) {
        println!("  {r}");
    }
    if let Some(path) = args.get("json") {
        std::fs::write(path, snap.to_json())?;
        println!("wrote {path}");
    }
    finish_observability(args)
}
