//! Small self-contained utilities: deterministic PRNG, summary statistics,
//! timing helpers, and a miniature property-testing harness.
//!
//! The offline crate set has neither `rand` nor `proptest`, so this module
//! provides the pieces the rest of the crate needs, built from scratch.

pub mod json;
pub mod prng;
pub mod prop;
pub mod stats;
pub mod time;

pub use prng::Rng;
pub use stats::Summary;
pub use time::Stopwatch;
