//! Fault injection and lineage-based recovery.
//!
//! Spark's headline fault-tolerance property (§2.2): a lost partition of an
//! RDD is rebuilt from its lineage chain. In this engine, loss means
//! evicting cached blocks and/or dropping a shuffle's map outputs; the
//! next job transparently recomputes through the compute closures and
//! re-runs un-materialized map stages. [`FaultInjector`] drives seeded,
//! repeatable loss scenarios used by the recovery tests and the
//! failure-injection benchmarks.
//!
//! This module covers *between-jobs* loss: everything injected here is
//! observed by the next action, which rebuilds before running. Faults
//! that strike *while a job is running* — task panics, stragglers,
//! mid-job shuffle loss — are the domain of
//! [`super::chaos::ChaosPolicy`] and the retrying stage scheduler in
//! [`super::rdd`].

use crate::util::prng::Rng;

use super::context::ClusterContext;
use super::rdd::RddId;
use super::shuffle::ShuffleId;

/// Seeded fault injector bound to one context.
pub struct FaultInjector {
    ctx: ClusterContext,
    rng: Rng,
    /// Number of cache partitions dropped so far.
    pub cache_losses: usize,
    /// Number of shuffles dropped so far.
    pub shuffle_losses: usize,
}

impl FaultInjector {
    /// Create an injector with a deterministic seed.
    pub fn new(ctx: &ClusterContext, seed: u64) -> Self {
        FaultInjector { ctx: ctx.clone(), rng: Rng::new(seed), cache_losses: 0, shuffle_losses: 0 }
    }

    /// Simulate loss of one cached partition of `rdd`. Returns whether a
    /// block was actually dropped.
    pub fn lose_cached_partition(&mut self, rdd: RddId, partition: usize) -> bool {
        let dropped = self.ctx.cache_store().evict(rdd, partition);
        if dropped {
            self.cache_losses += 1;
        }
        dropped
    }

    /// Simulate loss of an entire cached RDD (an executor dying with all
    /// its blocks). Returns the number of blocks dropped.
    pub fn lose_cached_rdd(&mut self, rdd: RddId) -> usize {
        let n = self.ctx.cache_store().evict_rdd(rdd);
        self.cache_losses += n;
        n
    }

    /// Simulate loss of a shuffle's map outputs (a mapper node dying).
    /// The next job that reads through this shuffle re-runs its map stage.
    pub fn lose_shuffle(&mut self, shuffle: ShuffleId) -> usize {
        let n = self.ctx.shuffle_store().lose(shuffle);
        if n > 0 {
            self.shuffle_losses += 1;
        }
        n
    }

    /// With probability `p`, drop a random cached partition of `rdd`
    /// (which has `parts` partitions). Used in randomized recovery tests.
    pub fn maybe_lose(&mut self, rdd: RddId, parts: usize, p: f64) -> bool {
        if parts > 0 && self.rng.chance(p) {
            let part = self.rng.range(0, parts);
            self.lose_cached_partition(rdd, part)
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::context::ClusterContext;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn cached_partition_loss_recomputes_and_matches() {
        let ctx = ClusterContext::builder().cores(2).build();
        let computes = Arc::new(AtomicUsize::new(0));
        let counter = Arc::clone(&computes);
        let rdd = ctx
            .parallelize((0..40u32).collect(), 4)
            .map(move |x| {
                counter.fetch_add(1, Ordering::SeqCst);
                x * 3
            })
            .cache();
        let before = rdd.collect().unwrap();
        let computed_once = computes.load(Ordering::SeqCst);
        assert_eq!(computed_once, 40);

        let mut inj = FaultInjector::new(&ctx, 1);
        assert!(inj.lose_cached_partition(rdd.id(), 2));

        let after = rdd.collect().unwrap();
        assert_eq!(before, after, "recovered result identical");
        // Only the lost partition was recomputed (10 elements).
        assert_eq!(computes.load(Ordering::SeqCst), computed_once + 10);
    }

    #[test]
    fn shuffle_loss_triggers_map_stage_rerun() {
        let ctx = ClusterContext::builder().cores(2).build();
        let pairs: Vec<(u32, u64)> = (0..30).map(|i| (i % 3, 1u64)).collect();
        let counts = ctx.parallelize(pairs, 3).reduce_by_key(2, |a, b| a + b);
        let mut first = counts.collect().unwrap();
        first.sort();

        // Find the shuffle id from the store: losing shuffle 0 works since
        // this context ran exactly one shuffle.
        let mut inj = FaultInjector::new(&ctx, 2);
        let dropped = inj.lose_shuffle(ShuffleId(0));
        assert!(dropped > 0, "map outputs existed");

        let map_tasks_before = ctx
            .metrics()
            .tasks()
            .iter()
            .filter(|t| t.kind == crate::engine::metrics::StageKind::ShuffleMap)
            .count();
        let mut second = counts.collect().unwrap();
        second.sort();
        assert_eq!(first, second, "recovered result identical");
        let map_tasks_after = ctx
            .metrics()
            .tasks()
            .iter()
            .filter(|t| t.kind == crate::engine::metrics::StageKind::ShuffleMap)
            .count();
        assert_eq!(map_tasks_after, map_tasks_before + 3, "map stage re-ran");
    }

    #[test]
    fn lose_whole_cached_rdd() {
        let ctx = ClusterContext::builder().cores(2).build();
        let rdd = ctx.parallelize((0..20u8).collect(), 4).map(|x| x).cache();
        rdd.collect().unwrap();
        let mut inj = FaultInjector::new(&ctx, 3);
        assert_eq!(inj.lose_cached_rdd(rdd.id()), 4);
        assert_eq!(rdd.collect().unwrap().len(), 20);
    }

    #[test]
    fn maybe_lose_is_seeded_and_bounded() {
        let ctx = ClusterContext::builder().cores(1).build();
        let rdd = ctx.parallelize((0..10u8).collect(), 2).cache();
        rdd.collect().unwrap();
        let mut a = FaultInjector::new(&ctx, 7);
        let mut drops_a = 0;
        for _ in 0..50 {
            if a.maybe_lose(rdd.id(), 2, 0.5) {
                drops_a += 1;
                rdd.collect().unwrap(); // repopulate
            }
        }
        assert!(drops_a > 5, "some losses occurred: {drops_a}");
    }
}
