"""L1 Pallas kernel: batched tidset-intersection support counting.

Eclat's inner loop (Algorithm 1 line 8-10) intersects two tidsets and
needs only the intersection *size*. With tidsets packed as bitmaps, that
is ``sum(popcount(a & b))`` — lane-parallel VPU work on TPU. This kernel
processes a batch of ``N`` candidate pairs at once: inputs are
``(N, W)`` uint32 lane matrices (row = one tidset bitmap, W lanes of 32
tids each), output is ``(N,)`` int32 supports.

Memory-bound by design (DESIGN.md §8): AND + popcount + row reduction is
fused in one pass so each input word is read exactly once.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# Default AOT batch shape: 256 pairs x 64 lanes (= 2048 tids per bitmap).
DEFAULT_N = 256
DEFAULT_W = 64


def _popcount_kernel(a_ref, b_ref, o_ref):
    """Support counts of one batch block: o = sum(popcount(a & b), axis=1)."""
    a = a_ref[...]
    b = b_ref[...]
    bits = lax.population_count(a & b)
    o_ref[...] = jnp.sum(bits.astype(jnp.int32), axis=1)


@partial(jax.jit, static_argnames=("block_n",))
def intersect_support(a, b, *, block_n: int | None = None):
    """Batched bitmap intersection supports.

    Args:
      a: ``(N, W)`` uint32 bitmap lanes.
      b: ``(N, W)`` uint32 bitmap lanes.
      block_n: rows per grid step (defaults to all rows in one step).

    Returns:
      ``(N,)`` int32 — ``|a_row ∩ b_row|`` per row.
    """
    n, w = a.shape
    assert a.shape == b.shape, f"shape mismatch: {a.shape} vs {b.shape}"
    block_n = block_n or n
    assert n % block_n == 0, f"N={n} not divisible by block_n={block_n}"
    grid = (n // block_n,)
    return pl.pallas_call(
        _popcount_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, w), lambda k: (k, 0)),
            pl.BlockSpec((block_n, w), lambda k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda k: (k,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(a, b)
