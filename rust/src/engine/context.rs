//! The driver-side entry point of the engine — the analogue of Spark's
//! `SparkContext`.
//!
//! A [`ClusterContext`] owns the executor thread pool, the block cache,
//! the shuffle store and the metrics registry. RDDs are created from it
//! (`parallelize`, `text_file`) and carry a handle back to it; all jobs of
//! one context share executors and stores, exactly like one Spark
//! application.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, PoisonError, RwLock};
use std::time::Duration;

use crate::error::Result;

use super::chaos::ChaosPolicy;
use super::metrics::{JobId, MetricsRegistry};
use super::pool::ThreadPool;
use super::rdd::{FetchFailed, Rdd, RddId, ShuffleDepHandle, TaskAbort};
use super::shared::{Accumulator, Broadcast};
use super::shuffle::{ShuffleId, ShuffleStore};
use super::storage::CacheStore;

/// Per-application scheduler knobs (the analogue of Spark's
/// `spark.task.maxFailures` / `spark.speculation.*` configuration),
/// set through [`ContextBuilder`] and read by the stage scheduler in
/// [`crate::engine::rdd`].
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Give up on a task (and fail the job) after this many failed
    /// attempts (Spark's `spark.task.maxFailures`, default 4). Fetch
    /// failures do not count — they trigger map-stage recovery instead.
    pub max_task_failures: u32,
    /// Base delay before a failed task is retried; doubles per failure,
    /// capped at 100 ms.
    pub retry_backoff: Duration,
    /// Re-launch straggling tasks speculatively (off by default, like
    /// `spark.speculation`). The first finisher wins; duplicate results
    /// are dropped, so side-effect-free pipelines are unaffected.
    pub speculation: bool,
    /// A running task is a straggler once it has been in flight longer
    /// than `median completed duration × multiplier`.
    pub speculation_multiplier: f64,
    /// Fraction of a stage's tasks that must have completed before
    /// stragglers are considered (Spark's `spark.speculation.quantile`).
    pub speculation_quantile: f64,
    /// Fail a stage that has not completed within this wall-clock bound
    /// with an [`crate::error::Error::Engine`] carrying the per-task
    /// attempt history, instead of wedging the job. `None` = no bound.
    pub stage_deadline: Option<Duration>,
}

impl Default for SchedulerConfig {
    fn default() -> SchedulerConfig {
        SchedulerConfig {
            max_task_failures: 4,
            retry_backoff: Duration::from_millis(1),
            speculation: false,
            speculation_multiplier: 1.5,
            speculation_quantile: 0.75,
            stage_deadline: None,
        }
    }
}

/// How the builder arms chaos: inherit from the environment (default),
/// explicitly off, or an explicit policy.
#[derive(Debug, Clone)]
enum ChaosArm {
    FromEnv,
    Off,
    On(ChaosPolicy),
}

/// Shared internals of one "application".
pub(crate) struct CtxInner {
    pub(crate) pool: ThreadPool,
    pub(crate) cores: usize,
    pub(crate) default_parallelism: usize,
    pub(crate) cache: CacheStore,
    pub(crate) shuffle: ShuffleStore,
    pub(crate) metrics: MetricsRegistry,
    pub(crate) scheduler: SchedulerConfig,
    chaos: RwLock<Option<Arc<ChaosPolicy>>>,
    /// Shuffle lineage of every *running* job, registered by `run_job`
    /// so a mid-job fetch failure can find the map stage to re-run.
    job_shuffles: RwLock<HashMap<usize, Vec<Arc<ShuffleDepHandle>>>>,
    next_rdd: AtomicUsize,
    next_shuffle: AtomicUsize,
}

/// Driver handle; cheap to clone (it is an `Arc`).
#[derive(Clone)]
pub struct ClusterContext {
    pub(crate) inner: Arc<CtxInner>,
}

/// Builder for [`ClusterContext`].
#[derive(Debug, Clone)]
pub struct ContextBuilder {
    cores: usize,
    default_parallelism: Option<usize>,
    scheduler: SchedulerConfig,
    chaos: ChaosArm,
}

impl Default for ContextBuilder {
    fn default() -> Self {
        ContextBuilder {
            cores: available_cores(),
            default_parallelism: None,
            scheduler: SchedulerConfig::default(),
            chaos: ChaosArm::FromEnv,
        }
    }
}

/// Number of cores the OS exposes (≥1).
pub fn available_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl ContextBuilder {
    /// Executor core count (thread-pool size). Defaults to the machine's
    /// available parallelism.
    pub fn cores(mut self, n: usize) -> Self {
        self.cores = n.max(1);
        self
    }

    /// Default number of partitions for `parallelize`/shuffles. Defaults
    /// to the core count (Spark's `sc.defaultParallelism`).
    pub fn default_parallelism(mut self, n: usize) -> Self {
        self.default_parallelism = Some(n.max(1));
        self
    }

    /// Give up on a task after `n` failed attempts (Spark's
    /// `spark.task.maxFailures`; default 4, floor 1).
    pub fn max_task_failures(mut self, n: u32) -> Self {
        self.scheduler.max_task_failures = n.max(1);
        self
    }

    /// Base retry backoff (doubles per failure, capped at 100 ms).
    pub fn retry_backoff(mut self, d: Duration) -> Self {
        self.scheduler.retry_backoff = d;
        self
    }

    /// Enable speculative re-launch of stragglers (`spark.speculation`).
    pub fn speculation(mut self, on: bool) -> Self {
        self.scheduler.speculation = on;
        self
    }

    /// Straggler threshold as a multiple of the median completed task
    /// duration (floor 1.0).
    pub fn speculation_multiplier(mut self, x: f64) -> Self {
        self.scheduler.speculation_multiplier = x.max(1.0);
        self
    }

    /// Fraction of a stage that must complete before speculation kicks
    /// in (clamped to [0, 1]).
    pub fn speculation_quantile(mut self, q: f64) -> Self {
        self.scheduler.speculation_quantile = q.clamp(0.0, 1.0);
        self
    }

    /// Wall-clock bound per stage; a stage still incomplete after `d`
    /// fails the job with its attempt history.
    pub fn stage_deadline(mut self, d: Duration) -> Self {
        self.scheduler.stage_deadline = Some(d);
        self
    }

    /// Arm a [`ChaosPolicy`] on the context (overrides the
    /// `RDD_ECLAT_CHAOS` environment variable).
    pub fn chaos(mut self, policy: ChaosPolicy) -> Self {
        self.chaos = ChaosArm::On(policy);
        self
    }

    /// Build with chaos explicitly disarmed, ignoring `RDD_ECLAT_CHAOS`.
    /// This is how fault-free baselines are built in the equivalence
    /// tests even when CI runs the whole suite under an env-armed policy.
    pub fn without_chaos(mut self) -> Self {
        self.chaos = ChaosArm::Off;
        self
    }

    /// Build the context, spawning executor threads.
    ///
    /// Unless [`ContextBuilder::chaos`] or
    /// [`ContextBuilder::without_chaos`] was called, a chaos policy is
    /// auto-armed from the `RDD_ECLAT_CHAOS=<seed>:<p>` environment
    /// variable when present (malformed specs are ignored here; the CLI
    /// rejects them with a proper error).
    pub fn build(self) -> ClusterContext {
        let parallelism = self.default_parallelism.unwrap_or(self.cores);
        let chaos = match self.chaos {
            ChaosArm::On(policy) => Some(Arc::new(policy)),
            ChaosArm::Off => None,
            ChaosArm::FromEnv => ChaosPolicy::from_env().unwrap_or(None).map(Arc::new),
        };
        ClusterContext {
            inner: Arc::new(CtxInner {
                pool: ThreadPool::new(self.cores),
                cores: self.cores,
                default_parallelism: parallelism,
                cache: CacheStore::new(),
                shuffle: ShuffleStore::new(),
                metrics: MetricsRegistry::new(),
                scheduler: self.scheduler,
                chaos: RwLock::new(chaos),
                job_shuffles: RwLock::new(HashMap::new()),
                next_rdd: AtomicUsize::new(0),
                next_shuffle: AtomicUsize::new(0),
            }),
        }
    }
}

impl ClusterContext {
    /// Start building a context.
    pub fn builder() -> ContextBuilder {
        ContextBuilder::default()
    }

    /// Context with default settings (all available cores).
    pub fn local() -> ClusterContext {
        Self::builder().build()
    }

    /// Executor core count.
    pub fn cores(&self) -> usize {
        self.inner.cores
    }

    /// Default parallelism (`sc.defaultParallelism`).
    pub fn default_parallelism(&self) -> usize {
        self.inner.default_parallelism
    }

    pub(crate) fn new_rdd_id(&self) -> RddId {
        // ordering: SeqCst — id allocation is cold (once per RDD, not
        // per record); uniqueness needs only RMW atomicity, but the
        // total order also makes ids monotone across threads, which
        // debug logs and trace timelines rely on when interleaving
        // driver output. Not worth weakening.
        RddId(self.inner.next_rdd.fetch_add(1, Ordering::SeqCst))
    }

    pub(crate) fn new_shuffle_id(&self) -> ShuffleId {
        // ordering: SeqCst — as `new_rdd_id`.
        ShuffleId(self.inner.next_shuffle.fetch_add(1, Ordering::SeqCst))
    }

    /// Metrics registry for this application.
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.inner.metrics
    }

    /// The block cache (exposed for fault-injection tests).
    pub fn cache_store(&self) -> &CacheStore {
        &self.inner.cache
    }

    /// The shuffle store (exposed for fault-injection tests).
    pub fn shuffle_store(&self) -> &ShuffleStore {
        &self.inner.shuffle
    }

    /// The scheduler configuration this context was built with.
    pub fn scheduler_config(&self) -> &SchedulerConfig {
        &self.inner.scheduler
    }

    /// The armed chaos policy, if any.
    pub fn chaos(&self) -> Option<Arc<ChaosPolicy>> {
        self.inner.chaos.read().unwrap_or_else(PoisonError::into_inner).clone()
    }

    /// Arm (or disarm with `None`) a chaos policy on a live context.
    pub fn set_chaos(&self, policy: Option<ChaosPolicy>) {
        *self.inner.chaos.write().unwrap_or_else(PoisonError::into_inner) =
            policy.map(Arc::new);
    }

    /// Register the ordered shuffle lineage of a starting job so the
    /// stage scheduler can re-materialize a lost shuffle mid-job.
    pub(crate) fn register_job_shuffles(&self, job: JobId, handles: Vec<Arc<ShuffleDepHandle>>) {
        self.inner
            .job_shuffles
            .write()
            .unwrap_or_else(PoisonError::into_inner)
            .insert(job.0, handles);
    }

    /// Drop a finished (or failed) job's lineage registration.
    pub(crate) fn clear_job_shuffles(&self, job: JobId) {
        self.inner.job_shuffles.write().unwrap_or_else(PoisonError::into_inner).remove(&job.0);
    }

    /// Look up the lineage handle for `shuffle` within a running job.
    pub(crate) fn job_shuffle_handle(
        &self,
        job: JobId,
        shuffle: ShuffleId,
    ) -> Option<Arc<ShuffleDepHandle>> {
        self.inner
            .job_shuffles
            .read()
            .unwrap_or_else(PoisonError::into_inner)
            .get(&job.0)?
            .iter()
            .find(|h| h.shuffle_id == shuffle)
            .cloned()
    }

    /// Fetch one reduce partition's shuffle input from inside a task.
    ///
    /// This is the executor-side entry point every reduce task goes
    /// through; it is where during-job fault tolerance hooks in:
    /// an armed [`ChaosPolicy`] may drop the shuffle's buckets and fail
    /// the fetch, a genuinely missing shuffle (lost mid-job) raises a
    /// typed [`FetchFailed`] panic that the stage scheduler catches and
    /// answers by re-running the map stage through lineage, and a bucket
    /// type mismatch raises [`TaskAbort`], failing the job cleanly
    /// without killing the executor.
    pub(crate) fn fetch_shuffle<T: Clone + 'static>(
        &self,
        shuffle: ShuffleId,
        num_map_tasks: usize,
        reduce: usize,
    ) -> Vec<T> {
        if let Some(chaos) = self.chaos() {
            if chaos.fail_fetch(shuffle.0 as u64, reduce) {
                self.shuffle_store().lose(shuffle);
                std::panic::panic_any(FetchFailed { shuffle });
            }
        }
        if !self.shuffle_store().is_materialized(shuffle) {
            std::panic::panic_any(FetchFailed { shuffle });
        }
        match self.shuffle_store().fetch::<T>(shuffle, num_map_tasks, reduce) {
            Ok(v) => v,
            Err(e) => std::panic::panic_any(TaskAbort(e.to_string())),
        }
    }

    /// Distribute a collection into `parts` partitions (Spark's
    /// `sc.parallelize`). Items are split into contiguous chunks.
    pub fn parallelize<T: super::rdd::Data>(&self, data: Vec<T>, parts: usize) -> Rdd<T> {
        Rdd::from_collection(self.clone(), data, parts.max(1))
    }

    /// `sc.parallelize` with default parallelism.
    pub fn parallelize_default<T: super::rdd::Data>(&self, data: Vec<T>) -> Rdd<T> {
        let p = self.default_parallelism();
        self.parallelize(data, p)
    }

    /// Read a text file into an RDD of lines split into `min_parts`
    /// contiguous partitions (Spark's `sc.textFile`). The whole file is
    /// read eagerly on the driver — the local filesystem plays HDFS here.
    pub fn text_file(&self, path: &str, min_parts: usize) -> Result<Rdd<String>> {
        let content = std::fs::read_to_string(path)?;
        let lines: Vec<String> = content.lines().map(|s| s.to_string()).collect();
        Ok(self.parallelize(lines, min_parts.max(1)))
    }

    /// Broadcast a read-only value to all tasks.
    pub fn broadcast<T: Send + Sync + 'static>(&self, value: T) -> Broadcast<T> {
        Broadcast::new(value)
    }

    /// Create an accumulator with a zero value and an associative,
    /// commutative merge.
    pub fn accumulator<T: Send + 'static>(
        &self,
        zero: T,
        merge: impl Fn(&mut T, T) + Send + Sync + 'static,
    ) -> Accumulator<T> {
        Accumulator::new(zero, merge)
    }
}

impl std::fmt::Debug for ClusterContext {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ClusterContext")
            .field("cores", &self.inner.cores)
            .field("default_parallelism", &self.inner.default_parallelism)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_overrides() {
        let ctx = ClusterContext::builder().cores(3).build();
        assert_eq!(ctx.cores(), 3);
        assert_eq!(ctx.default_parallelism(), 3);
        let ctx = ClusterContext::builder().cores(2).default_parallelism(8).build();
        assert_eq!(ctx.default_parallelism(), 8);
    }

    #[test]
    fn scheduler_config_defaults_and_overrides() {
        let ctx = ClusterContext::builder().cores(1).without_chaos().build();
        assert_eq!(ctx.scheduler_config().max_task_failures, 4);
        assert!(!ctx.scheduler_config().speculation);
        assert!(ctx.chaos().is_none());
        let ctx = ClusterContext::builder()
            .cores(1)
            .max_task_failures(0) // floored to 1
            .speculation(true)
            .speculation_multiplier(0.5) // floored to 1.0
            .stage_deadline(Duration::from_secs(5))
            .chaos(ChaosPolicy::new(7))
            .build();
        assert_eq!(ctx.scheduler_config().max_task_failures, 1);
        assert!(ctx.scheduler_config().speculation);
        assert_eq!(ctx.scheduler_config().speculation_multiplier, 1.0);
        assert_eq!(ctx.scheduler_config().stage_deadline, Some(Duration::from_secs(5)));
        assert!(ctx.chaos().is_some());
        ctx.set_chaos(None);
        assert!(ctx.chaos().is_none());
    }

    #[test]
    fn ids_are_unique() {
        let ctx = ClusterContext::builder().cores(1).build();
        let a = ctx.new_rdd_id();
        let b = ctx.new_rdd_id();
        assert_ne!(a, b);
        let s1 = ctx.new_shuffle_id();
        let s2 = ctx.new_shuffle_id();
        assert_ne!(s1, s2);
    }

    #[test]
    fn text_file_roundtrip() {
        let dir = std::env::temp_dir().join("rdd_eclat_ctx_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("lines.txt");
        std::fs::write(&path, "a b\nc d\ne\n").unwrap();
        let ctx = ClusterContext::builder().cores(2).build();
        let rdd = ctx.text_file(path.to_str().unwrap(), 2).unwrap();
        let mut lines = rdd.collect().unwrap();
        lines.sort();
        assert_eq!(lines, vec!["a b", "c d", "e"]);
    }
}
