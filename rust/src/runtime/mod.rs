//! PJRT runtime (DESIGN.md S21–S22): loads the HLO-text artifacts that
//! `python/compile/aot.py` produced (`make artifacts`), compiles them once
//! on a dedicated service thread via the `xla` crate's CPU PJRT client,
//! and executes them from the mining hot path. Python never runs here.
//!
//! The whole backend sits behind the default-off `xla` cargo feature —
//! only the artifact-path helpers below are always available, so the
//! default build carries no `xla`-crate dependency.

#[cfg(feature = "xla")]
pub mod cooc;
#[cfg(feature = "xla")]
pub mod intersect;
#[cfg(feature = "xla")]
pub mod service;

#[cfg(feature = "xla")]
pub use cooc::XlaCooc;
#[cfg(feature = "xla")]
pub use intersect::XlaIntersect;
#[cfg(feature = "xla")]
pub use service::{HostBuffer, XlaService};

use std::path::PathBuf;

/// Default artifact directory: `$REPRO_ARTIFACTS` or `<crate>/artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    match std::env::var("REPRO_ARTIFACTS") {
        Ok(d) => PathBuf::from(d),
        Err(_) => PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
    }
}

/// True when artifacts have been built (`make artifacts`).
pub fn artifacts_available() -> bool {
    default_artifact_dir().join("manifest.txt").exists()
}
