//! A counting global allocator, so "zero-allocation" claims are
//! **measured, not asserted**.
//!
//! The type is always compiled; counting only happens when a bench
//! binary *installs* it as the `#[global_allocator]` and calls
//! [`mark_installed`] — gated behind the `alloc-count` cargo feature so
//! ordinary builds keep the system allocator untouched:
//!
//! ```text
//! cargo bench --bench fim_micro --features alloc-count -- --quick
//! ```
//!
//! [`count_in`] then reports how many heap allocations a closure
//! performed (`None` when no counting allocator is installed, so callers
//! can't mistake "not measured" for "zero").

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

static ALLOCATIONS: AtomicU64 = AtomicU64::new(0);
static INSTALLED: AtomicBool = AtomicBool::new(false);

/// Pass-through allocator over [`System`] that counts every allocation
/// (`alloc`, `alloc_zeroed`, and growth via `realloc`; frees are not
/// counted — the metric is allocation pressure, not live bytes).
#[derive(Debug, Default)]
pub struct CountingAllocator;

impl CountingAllocator {
    /// Const constructor for `#[global_allocator]` statics.
    pub const fn new() -> CountingAllocator {
        CountingAllocator
    }
}

// SAFETY: pure pass-through to `System`, which upholds the GlobalAlloc
// contract; the only addition is a relaxed counter bump, which neither
// allocates (no reentrancy) nor unwinds.
unsafe impl GlobalAlloc for CountingAllocator {
    // SAFETY: caller upholds GlobalAlloc's contract (valid `layout`);
    // we forward it to `System` unchanged.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        // ordering: Relaxed — allocation tally; RMW atomicity keeps the
        // count exact and nothing synchronizes through it. This is the
        // hottest line in the crate when installed — any stronger
        // ordering would tax every allocation.
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    // SAFETY: caller guarantees `ptr` came from this allocator with
    // `layout`; forwarded to `System` unchanged.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    // SAFETY: as `alloc` — valid `layout` forwarded to `System`.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        // ordering: Relaxed — see `alloc`.
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    // SAFETY: caller guarantees `ptr`/`layout` per the GlobalAlloc
    // contract; forwarded to `System` unchanged.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        // ordering: Relaxed — see `alloc`.
        ALLOCATIONS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Record that a [`CountingAllocator`] is the process's global allocator.
/// Call once from the bench binary's `main` (the library cannot know).
pub fn mark_installed() {
    // ordering: Relaxed — write-once flag set in `main` before any
    // measurement thread exists; no data is published through it.
    INSTALLED.store(true, Ordering::Relaxed);
}

/// Whether allocation counts are meaningful in this process.
pub fn installed() -> bool {
    // ordering: Relaxed — see `mark_installed`.
    INSTALLED.load(Ordering::Relaxed)
}

/// Total allocations since process start (monotone counter).
pub fn allocations() -> u64 {
    // ordering: Relaxed — monitoring read of a monotone tally; benches
    // are single-threaded around the measured closure.
    ALLOCATIONS.load(Ordering::Relaxed)
}

/// Run `f`, returning its result plus the number of heap allocations it
/// made — `None` when no counting allocator is installed. Counts are
/// process-wide; run on a quiet process (benches are single-threaded).
pub fn count_in<T>(f: impl FnOnce() -> T) -> (T, Option<u64>) {
    if !installed() {
        return (f(), None);
    }
    let before = allocations();
    let value = f();
    (value, Some(allocations() - before))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn count_in_is_none_without_installed_allocator() {
        // The test binary does not install the counting allocator, so
        // measurements must be explicit about being unavailable.
        let (v, n) = count_in(|| vec![1u8; 128].len());
        assert_eq!(v, 128);
        assert_eq!(n, None);
    }
}
