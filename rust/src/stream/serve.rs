//! Snapshot serving: publish each [`BatchSnapshot`] to concurrent
//! readers without making them wait on the miner (or each other).
//!
//! The serving layer is what turns the streaming job from a
//! call-and-return library into something that can answer queries while
//! the next window is being mined — the related RDD-Apriori work
//! (arXiv:1908.01338) argues that at scale the data-structure/serving
//! side, not the mining kernel, dominates end-to-end behavior. Three
//! pieces:
//!
//! * [`ServingSnapshot`] — a [`BatchSnapshot`] plus the prebuilt query
//!   indices: itemset → support ([`ServingSnapshot::frequent`]) and
//!   antecedent → rules ([`ServingSnapshot::rules_for`]). Built once at
//!   publish time, immutable afterwards, shared by `Arc`.
//! * [`SnapshotPublisher`] — the single writer (the mining loop).
//! * [`SnapshotHandle`] — cloneable reader handle.
//!
//! Publication is an `ArcSwap`-style **double buffer**: two slots each
//! holding an `Arc<ServingSnapshot>`, an atomic index naming the active
//! one. [`SnapshotHandle::latest`] takes **no locks**: it pins the
//! active slot with a reader count, clones the `Arc`, and unpins — a
//! handful of atomic operations regardless of snapshot size. The
//! publisher writes only the *inactive* slot, and only after the slot's
//! reader count drains to zero, then flips the index; a reader that
//! raced the flip notices the index moved and retries on the other
//! slot. Readers therefore never observe a torn snapshot and are never
//! blocked by a publish; the publisher waits only for readers that are
//! mid-`Arc`-clone (nanoseconds), never for readers *using* a snapshot
//! they already fetched.
//!
//! The protocol is model-checked: every primitive here comes from
//! [`crate::sync`], so under `RUSTFLAGS="--cfg loom"` the `loom_tests`
//! mod below (plus `tests/loom_models.rs`) exhaustively explores
//! flip-vs-read interleavings — no torn snapshot, no stale-forever
//! reader, pins always released, dead publishers always wake waiters.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use crate::sync::cell::UnsafeCell;
use crate::sync::global::OnceLock;
use crate::sync::{hint, lock_unpoisoned, Arc, Condvar, Mutex};

use crate::fim::{Item, ItemSet, Rule};

use super::job::BatchSnapshot;
use super::window::normalize_row;

/// Serving-layer instrumentation cells, resolved once (see [`crate::obs`]).
struct ServeObs {
    publishes: &'static crate::obs::Counter,
    reader_wait_us: &'static crate::obs::Histogram,
}

fn serve_obs() -> &'static ServeObs {
    static OBS: OnceLock<ServeObs> = OnceLock::new();
    OBS.get_or_init(|| ServeObs {
        publishes: crate::obs::counter("stream.serve.publishes"),
        reader_wait_us: crate::obs::histogram("stream.serve.reader_wait_us"),
    })
}

/// A published snapshot with its query indices prebuilt — what readers
/// get from [`SnapshotHandle::latest`]. Dereferences to the underlying
/// [`BatchSnapshot`] for the raw stats/itemsets/rules.
#[derive(Debug)]
pub struct ServingSnapshot {
    snap: BatchSnapshot,
    /// Itemset → support, over every frequent itemset of the snapshot.
    frequent: HashMap<ItemSet, u32>,
    /// Antecedent → indices into `snap.rules`. Rules are sorted by
    /// confidence descending, and the index preserves that order within
    /// each antecedent.
    by_antecedent: HashMap<ItemSet, Vec<u32>>,
    /// When this snapshot was indexed (monotonic) — see
    /// [`ServingSnapshot::age`].
    indexed_at: Instant,
}

impl ServingSnapshot {
    /// Index a snapshot for serving. O(itemsets + rules), run once by
    /// the publisher so every reader query is a hash lookup.
    pub fn new(snap: BatchSnapshot) -> ServingSnapshot {
        let frequent: HashMap<ItemSet, u32> =
            snap.frequents.iter().map(|f| (f.items.clone(), f.support)).collect();
        let mut by_antecedent: HashMap<ItemSet, Vec<u32>> = HashMap::new();
        for (i, r) in snap.rules.iter().enumerate() {
            by_antecedent.entry(r.antecedent.clone()).or_default().push(i as u32);
        }
        ServingSnapshot { snap, frequent, by_antecedent, indexed_at: Instant::now() }
    }

    /// Monotonic time since this snapshot was indexed for serving — how
    /// stale the data a reader holding it is looking at. Grows until the
    /// reader re-fetches [`SnapshotHandle::latest`].
    pub fn age(&self) -> Duration {
        self.indexed_at.elapsed()
    }

    /// The raw snapshot (also reachable through `Deref`).
    pub fn snapshot(&self) -> &BatchSnapshot {
        &self.snap
    }

    /// Support of `itemset` over the snapshot's window, `None` when it
    /// was not frequent. The query is normalized (sorted, de-duplicated)
    /// before lookup.
    pub fn frequent(&self, itemset: &[Item]) -> Option<u32> {
        let key = normalize_row(itemset.to_vec());
        self.frequent.get(key.as_slice()).copied()
    }

    /// Every rule whose antecedent is exactly `antecedent`, strongest
    /// confidence first. Empty when no such rule cleared `min_conf`.
    pub fn rules_for(&self, antecedent: &[Item]) -> Vec<&Rule> {
        let key = normalize_row(antecedent.to_vec());
        match self.by_antecedent.get(key.as_slice()) {
            Some(ix) => ix.iter().map(|&i| &self.snap.rules[i as usize]).collect(),
            None => Vec::new(),
        }
    }

    /// Number of distinct rule antecedents in the index.
    pub fn antecedents(&self) -> usize {
        self.by_antecedent.len()
    }
}

impl std::ops::Deref for ServingSnapshot {
    type Target = BatchSnapshot;

    fn deref(&self) -> &BatchSnapshot {
        &self.snap
    }
}

/// One buffer of the double-buffered cell.
struct Slot {
    /// Readers currently pinning this slot (mid-clone). The publisher
    /// mutates a slot only while it is inactive **and** unpinned.
    readers: AtomicUsize,
    /// The published snapshot. `None` only before the first publish.
    snap: UnsafeCell<Option<Arc<ServingSnapshot>>>,
}

impl Slot {
    fn empty() -> Slot {
        Slot { readers: AtomicUsize::new(0), snap: UnsafeCell::new(None) }
    }
}

/// Shared state behind publisher and handles.
struct SnapshotCell {
    slots: [Slot; 2],
    /// Which slot readers should use.
    active: AtomicUsize,
    /// Publishes so far (the "sequence number" of the serving layer).
    version: AtomicU64,
    /// Blocking-wait support ([`SnapshotHandle::wait_for_batch`]); not
    /// on the `latest()` path.
    wait_lock: Mutex<()>,
    wait_cv: Condvar,
    /// Cleared when the [`SnapshotPublisher`] drops — a dead publisher
    /// can never satisfy a waiter, so blocked waits return instead of
    /// hanging forever.
    publisher_alive: AtomicBool,
}

// SAFETY: the `UnsafeCell`s are governed by the double-buffer protocol
// (single writer, which touches only the inactive slot after its reader
// count drains; readers pin a slot before touching it and re-validate
// the active index after pinning — see `latest`/`publish`; the loom
// models in `loom_tests` check exactly this claim). The contained
// `Arc<ServingSnapshot>` is itself Send + Sync.
unsafe impl Sync for SnapshotCell {}
// SAFETY: moving the cell between threads is strictly weaker than the
// shared access justified above; every field is `Send`.
unsafe impl Send for SnapshotCell {}

impl SnapshotCell {
    fn new() -> Arc<SnapshotCell> {
        Arc::new(SnapshotCell {
            slots: [Slot::empty(), Slot::empty()],
            active: AtomicUsize::new(0),
            version: AtomicU64::new(0),
            wait_lock: Mutex::new(()),
            wait_cv: Condvar::new(),
            publisher_alive: AtomicBool::new(true),
        })
    }

    /// Lock-free read of the latest snapshot (see module docs for the
    /// protocol). `None` before the first publish.
    fn latest(&self) -> Option<Arc<ServingSnapshot>> {
        loop {
            // ordering: SeqCst — the pin/revalidate handshake needs a
            // single total order over this load, the pin below, and the
            // publisher's drain/flip; kept at the strongest ordering,
            // and any future weakening is gated on the `loom_tests`
            // models (PR 9 regression note).
            let i = self.active.load(Ordering::SeqCst);
            let slot = &self.slots[i];
            // ordering: SeqCst — the pin (an RMW) must be ordered before
            // the revalidation load below and visible to the publisher's
            // reader-drain loop; see `publish`.
            slot.readers.fetch_add(1, Ordering::SeqCst);
            // Re-validate after pinning: if `i` is still the active
            // slot, the publisher cannot be writing it (it writes only
            // the inactive slot) and cannot start until our pin drops.
            // ordering: SeqCst — pairs with the publisher's flip store.
            if self.active.load(Ordering::SeqCst) == i {
                // SAFETY: slot `i` is pinned and validated active, so
                // the single publisher will neither be mid-write here
                // (writes finish before a slot becomes active) nor
                // start one (it waits for `readers == 0` first).
                let out = slot.snap.with(|p| unsafe { (*p).clone() });
                // ordering: SeqCst — unpin; the publisher's drain loop
                // must not observe the release before our read is done.
                slot.readers.fetch_sub(1, Ordering::SeqCst);
                return out;
            }
            // Raced a publish that flipped the index; unpin and retry.
            // ordering: SeqCst — as the matching pin above.
            slot.readers.fetch_sub(1, Ordering::SeqCst);
            hint::spin_loop();
        }
    }

    /// Publish a new snapshot. Single writer only — enforced by
    /// [`SnapshotPublisher`] being the sole caller and not `Clone`.
    fn publish(&self, snap: Arc<ServingSnapshot>) {
        // ordering: SeqCst — part of the pin/flip handshake; see `latest`.
        let idx = 1 - self.active.load(Ordering::SeqCst);
        let slot = &self.slots[idx];
        // Wait out readers still pinning the slot from before the last
        // flip. Pins last for the duration of an `Arc` clone, so this
        // spin is nanoseconds, not "until the reader finishes with the
        // snapshot".
        // ordering: SeqCst — must observe every pin RMW on this slot
        // before we may touch it; see `latest`.
        while slot.readers.load(Ordering::SeqCst) != 0 {
            hint::spin_loop();
        }
        // SAFETY: `idx` is the inactive slot (readers validate against
        // `active` after pinning, so none can be reading it) and its
        // transient pins have drained; we are the only writer.
        slot.snap.with_mut(|p| unsafe { *p = Some(snap) });
        // ordering: SeqCst — the flip: makes the slot write above
        // visible to readers; do not weaken without a green run of the
        // loom suite (PR 9 regression note).
        self.active.store(idx, Ordering::SeqCst);
        // ordering: SeqCst — the version must never appear to advance
        // before the flip it describes (waiters read it lock-free).
        self.version.fetch_add(1, Ordering::SeqCst);
        let _guard = lock_unpoisoned(&self.wait_lock);
        self.wait_cv.notify_all();
    }
}

/// The single-writer side of a snapshot pipe — owned by the mining
/// loop. Deliberately not `Clone`: one publisher per cell is what makes
/// the lock-free read protocol sound.
pub struct SnapshotPublisher {
    cell: Arc<SnapshotCell>,
}

impl SnapshotPublisher {
    /// Index `snap` and publish it, returning the shared form (so the
    /// publisher can inspect what it just made visible).
    pub fn publish(&mut self, snap: BatchSnapshot) -> Arc<ServingSnapshot> {
        let mut sp = crate::obs::span("stream.publish");
        sp.arg("batch", snap.batch_id)
            .arg("frequents", snap.frequents.len() as u64)
            .arg("rules", snap.rules.len() as u64);
        let served = Arc::new(ServingSnapshot::new(snap));
        self.cell.publish(Arc::clone(&served));
        if crate::obs::enabled() {
            serve_obs().publishes.incr(1);
        }
        served
    }

    /// Publishes so far.
    pub fn version(&self) -> u64 {
        // ordering: SeqCst — must observe its own publishes' increments
        // in flip order; see `SnapshotCell::publish`.
        self.cell.version.load(Ordering::SeqCst)
    }

    /// A reader handle for this publisher's cell.
    pub fn subscribe(&self) -> SnapshotHandle {
        SnapshotHandle { cell: Arc::clone(&self.cell) }
    }
}

impl Drop for SnapshotPublisher {
    /// Dead-publisher wakeup: mark the cell dead, then notify under the
    /// wait lock. Waiters re-check liveness under the same lock before
    /// parking, so none can park after the flag flips and miss the
    /// notification — [`SnapshotHandle::wait_for_batch`] unblocks
    /// instead of waiting forever on a publisher that will never
    /// publish again.
    fn drop(&mut self) {
        // ordering: SeqCst — the liveness flag must be visible before
        // the notify; waiters re-check it under `wait_lock`.
        self.cell.publisher_alive.store(false, Ordering::SeqCst);
        let _guard = lock_unpoisoned(&self.cell.wait_lock);
        self.cell.wait_cv.notify_all();
    }
}

impl std::fmt::Debug for SnapshotPublisher {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SnapshotPublisher").field("version", &self.version()).finish()
    }
}

/// Cloneable reader handle onto the live snapshot. Cheap to clone and
/// `Send`, so every query thread can own one.
#[derive(Clone)]
pub struct SnapshotHandle {
    cell: Arc<SnapshotCell>,
}

impl SnapshotHandle {
    /// The latest published snapshot, without taking any lock (see the
    /// module docs). `None` until the first publish.
    pub fn latest(&self) -> Option<Arc<ServingSnapshot>> {
        self.cell.latest()
    }

    /// Publishes so far. Monotonically non-decreasing; `latest()` never
    /// goes backwards across publishes either (each publish replaces the
    /// snapshot with a newer `batch_id`).
    pub fn version(&self) -> u64 {
        // ordering: SeqCst — a version read must never run ahead of the
        // flips it counts; see `SnapshotCell::publish`.
        self.cell.version.load(Ordering::SeqCst)
    }

    /// Whether the publisher side of the pipe is still alive. A dead
    /// publisher can never publish again; `latest()` keeps serving the
    /// final published snapshot.
    pub fn publisher_alive(&self) -> bool {
        // ordering: SeqCst — pairs with the store in the publisher's
        // `Drop`; waiters rely on re-checking this under `wait_lock`.
        self.cell.publisher_alive.load(Ordering::SeqCst)
    }

    /// Block (on a condvar — not the lock-free read path) until a
    /// snapshot with `batch_id >= min_batch_id` is published. Returns
    /// the qualifying snapshot, or `None` if the publisher dropped
    /// before publishing one — a dead publisher wakes every blocked
    /// waiter instead of leaving it hanging forever. Prefer
    /// [`SnapshotHandle::wait_for_batch_timeout`] when the caller also
    /// needs a wall-clock bound.
    pub fn wait_for_batch(&self, min_batch_id: u64) -> Option<Arc<ServingSnapshot>> {
        let sw = crate::obs::enabled().then(Instant::now);
        let out = self.wait_inner(min_batch_id);
        if let Some(start) = sw {
            serve_obs().reader_wait_us.record(start.elapsed().as_micros() as u64);
        }
        out
    }

    fn wait_inner(&self, min_batch_id: u64) -> Option<Arc<ServingSnapshot>> {
        loop {
            if let Some(s) = self.latest() {
                if s.batch_id >= min_batch_id {
                    return Some(s);
                }
            }
            let guard = lock_unpoisoned(&self.cell.wait_lock);
            // Re-check under the wait lock so a publish (or a publisher
            // death) between our `latest()` and this wait cannot be
            // missed.
            if let Some(s) = self.cell.latest() {
                if s.batch_id >= min_batch_id {
                    return Some(s);
                }
            }
            if !self.publisher_alive() {
                return None;
            }
            let _guard = self.cell.wait_cv.wait(guard).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// [`SnapshotHandle::wait_for_batch`] with a wall-clock bound:
    /// returns the qualifying snapshot, or `None` when the timeout
    /// expires or the publisher dies first. (Not compiled under
    /// `cfg(loom)`: loom has no faithful timed-wait model, and the
    /// models check the untimed protocol.)
    #[cfg(not(loom))]
    pub fn wait_for_batch_timeout(
        &self,
        min_batch_id: u64,
        timeout: Duration,
    ) -> Option<Arc<ServingSnapshot>> {
        let sw = crate::obs::enabled().then(Instant::now);
        let out = self.wait_timeout_inner(min_batch_id, timeout);
        if let Some(start) = sw {
            serve_obs().reader_wait_us.record(start.elapsed().as_micros() as u64);
        }
        out
    }

    #[cfg(not(loom))]
    fn wait_timeout_inner(
        &self,
        min_batch_id: u64,
        timeout: Duration,
    ) -> Option<Arc<ServingSnapshot>> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(s) = self.latest() {
                if s.batch_id >= min_batch_id {
                    return Some(s);
                }
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let guard = lock_unpoisoned(&self.cell.wait_lock);
            // Re-check under the wait lock so a publish between our
            // `latest()` and this wait cannot be missed.
            if let Some(s) = self.cell.latest() {
                if s.batch_id >= min_batch_id {
                    return Some(s);
                }
            }
            if !self.publisher_alive() {
                return None;
            }
            let (_guard, _timeout) = self
                .cell
                .wait_cv
                .wait_timeout(guard, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
        }
    }
}

impl std::fmt::Debug for SnapshotHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SnapshotHandle").field("version", &self.version()).finish()
    }
}

/// A fresh publisher/reader pair over one double-buffered cell.
pub fn snapshot_pipe() -> (SnapshotPublisher, SnapshotHandle) {
    let cell = SnapshotCell::new();
    (SnapshotPublisher { cell: Arc::clone(&cell) }, SnapshotHandle { cell })
}

// Not compiled under `cfg(loom)`: these tests use the timed-wait API
// and real sleeps; the loom-facing coverage lives in `loom_tests`.
#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use crate::fim::Frequent;
    use crate::stream::MinePlan;

    /// A self-consistent synthetic snapshot: every derived field is a
    /// function of `k`, so readers can detect tearing.
    fn snap(k: u64) -> BatchSnapshot {
        BatchSnapshot {
            batch_id: k,
            window_txns: (k as usize) * 3 + 1,
            window_batches: 1,
            min_sup_count: 1,
            frequent_items: 1,
            dirty_frequent_items: 0,
            plan: MinePlan::Rebuild,
            frequents: vec![Frequent::new(vec![k as u32], k as u32 + 1)],
            rules: Vec::new(),
            wall: Duration::ZERO,
        }
    }

    #[test]
    fn served_snapshot_age_grows_monotonically() {
        let served = ServingSnapshot::new(snap(1));
        let a0 = served.age();
        std::thread::sleep(Duration::from_millis(5));
        let a1 = served.age();
        assert!(a1 > a0, "age must grow: {a0:?} -> {a1:?}");
        assert!(a1 >= Duration::from_millis(5));
    }

    #[test]
    fn latest_none_before_first_publish() {
        let (publisher, handle) = snapshot_pipe();
        assert!(handle.latest().is_none());
        assert_eq!(handle.version(), 0);
        assert_eq!(publisher.version(), 0);
    }

    #[test]
    fn publish_then_read_roundtrip() {
        let (mut publisher, handle) = snapshot_pipe();
        publisher.publish(snap(0));
        publisher.publish(snap(1));
        let s = handle.latest().expect("published");
        assert_eq!(s.batch_id, 1);
        assert_eq!(s.window_txns, 4);
        assert_eq!(handle.version(), 2);
        // Old Arcs stay valid after further publishes (readers are never
        // invalidated, only superseded).
        let old = handle.latest().unwrap();
        publisher.publish(snap(2));
        publisher.publish(snap(3));
        assert_eq!(old.batch_id, 1, "held snapshot is immutable");
        assert_eq!(handle.latest().unwrap().batch_id, 3);
    }

    #[test]
    fn indices_answer_frequent_and_rule_queries() {
        let mut s = snap(5);
        s.frequents = vec![
            Frequent::new(vec![1], 4),
            Frequent::new(vec![2], 3),
            Frequent::new(vec![1, 2], 3),
        ];
        s.rules = vec![
            Rule {
                antecedent: vec![2],
                consequent: vec![1],
                support: 3,
                confidence: 1.0,
                lift: None,
            },
            Rule {
                antecedent: vec![1],
                consequent: vec![2],
                support: 3,
                confidence: 0.75,
                lift: None,
            },
        ];
        let served = ServingSnapshot::new(s);
        assert_eq!(served.frequent(&[1, 2]), Some(3));
        assert_eq!(served.frequent(&[2, 1]), Some(3), "query is normalized");
        assert_eq!(served.frequent(&[2, 2, 1]), Some(3), "dedup too");
        assert_eq!(served.frequent(&[9]), None);
        let rules = served.rules_for(&[2]);
        assert_eq!(rules.len(), 1);
        assert_eq!(rules[0].consequent, vec![1]);
        assert!(served.rules_for(&[7]).is_empty());
        assert_eq!(served.antecedents(), 2);
        // Deref reaches the raw snapshot.
        assert_eq!(served.batch_id, 5);
        assert_eq!(served.snapshot().frequents.len(), 3);
    }

    #[test]
    fn rules_for_preserves_confidence_order() {
        let mut s = snap(0);
        s.rules = (0..4)
            .map(|i| Rule {
                antecedent: vec![1],
                consequent: vec![10 + i],
                support: 2,
                confidence: 1.0 - 0.1 * i as f64,
                lift: None,
            })
            .collect();
        let served = ServingSnapshot::new(s);
        let rules = served.rules_for(&[1]);
        assert_eq!(rules.len(), 4);
        for w in rules.windows(2) {
            assert!(w[0].confidence >= w[1].confidence);
        }
    }

    #[test]
    fn wait_for_batch_times_out_and_succeeds() {
        let (mut publisher, handle) = snapshot_pipe();
        assert!(handle.wait_for_batch_timeout(0, Duration::from_millis(10)).is_none());
        publisher.publish(snap(3));
        let s = handle
            .wait_for_batch_timeout(2, Duration::from_millis(10))
            .expect("already there");
        assert_eq!(s.batch_id, 3);
        // A publish from another thread wakes a blocked waiter — both
        // the blocking and the timed variant.
        let blocking = {
            let h = handle.clone();
            std::thread::spawn(move || h.wait_for_batch(7))
        };
        let timed = {
            let h = handle.clone();
            std::thread::spawn(move || h.wait_for_batch_timeout(7, Duration::from_secs(10)))
        };
        std::thread::sleep(Duration::from_millis(5));
        publisher.publish(snap(7));
        assert_eq!(blocking.join().unwrap().expect("woken by publish").batch_id, 7);
        assert_eq!(timed.join().unwrap().expect("woken by publish").batch_id, 7);
    }

    #[test]
    fn dead_publisher_wakes_blocked_waiters() {
        // Regression: a waiter whose target batch never arrives must not
        // hang forever once the publisher is gone.
        let (mut publisher, handle) = snapshot_pipe();
        publisher.publish(snap(2));
        assert!(handle.publisher_alive());
        let blocking = {
            let h = handle.clone();
            std::thread::spawn(move || h.wait_for_batch(10))
        };
        let timed = {
            let h = handle.clone();
            std::thread::spawn(move || h.wait_for_batch_timeout(10, Duration::from_secs(3600)))
        };
        std::thread::sleep(Duration::from_millis(5));
        let start = Instant::now();
        drop(publisher);
        assert!(blocking.join().unwrap().is_none(), "unsatisfiable wait must unblock");
        assert!(timed.join().unwrap().is_none(), "timed wait must not run out its hour");
        assert!(start.elapsed() < Duration::from_secs(10), "woken by drop, not timeout");
        assert!(!handle.publisher_alive());
        // Already-satisfied waits still succeed against a dead publisher…
        assert_eq!(handle.wait_for_batch(2).expect("published before death").batch_id, 2);
        assert_eq!(
            handle.wait_for_batch_timeout(1, Duration::from_millis(10)).unwrap().batch_id,
            2
        );
        // …and unsatisfiable ones return immediately.
        assert!(handle.wait_for_batch(10).is_none());
    }

    #[test]
    fn hammered_readers_never_see_torn_or_regressing_snapshots() {
        // The satellite concurrency test at the cell level: one writer
        // publishing N self-consistent snapshots, M readers spinning on
        // `latest()`. Every observation must be internally consistent
        // (no tearing), per-reader monotone (no regression), and every
        // reader must eventually observe the final snapshot (no
        // stale-forever).
        // Miri runs this exhaustively but ~100× slower; shrink the load
        // there (loom covers the adversarial interleavings anyway).
        const N: u64 = if cfg!(miri) { 25 } else { 500 };
        const READERS: usize = if cfg!(miri) { 2 } else { 4 };
        let (mut publisher, handle) = snapshot_pipe();
        let barrier = Arc::new(std::sync::Barrier::new(READERS + 1));
        let readers: Vec<_> = (0..READERS)
            .map(|_| {
                let h = handle.clone();
                let b = Arc::clone(&barrier);
                std::thread::spawn(move || {
                    b.wait();
                    let mut last = 0u64;
                    let mut seen = 0u64;
                    loop {
                        let Some(s) = h.latest() else { continue };
                        // Torn-snapshot check: all fields derive from k.
                        assert_eq!(s.window_txns, (s.batch_id as usize) * 3 + 1);
                        assert_eq!(s.frequents[0].items, vec![s.batch_id as u32]);
                        assert_eq!(s.frequent(&[s.batch_id as u32]), Some(s.batch_id as u32 + 1));
                        assert!(s.batch_id >= last, "regressed {last} -> {}", s.batch_id);
                        last = s.batch_id;
                        seen += 1;
                        if s.batch_id == N - 1 {
                            return seen;
                        }
                    }
                })
            })
            .collect();
        barrier.wait();
        for k in 0..N {
            publisher.publish(snap(k));
        }
        for r in readers {
            let seen = r.join().expect("reader panicked == invariant violated");
            assert!(seen > 0);
        }
        assert_eq!(handle.version(), N);
    }
}

/// Loom models over the cell internals (pins, flips, waiter wakeups).
/// Run with `RUSTFLAGS="--cfg loom" cargo test --lib loom_`; every test
/// explores the full interleaving space within the preemption bound, so
/// a pass here is a proof over that space, not a lucky schedule.
#[cfg(all(loom, test))]
mod loom_tests {
    use super::*;
    use crate::fim::Frequent;
    use crate::stream::MinePlan;
    use crate::sync::thread;

    /// A self-consistent synthetic snapshot (every derived field is a
    /// function of `k`) so models can detect tearing.
    fn snap(k: u64) -> BatchSnapshot {
        BatchSnapshot {
            batch_id: k,
            window_txns: (k as usize) * 3 + 1,
            window_batches: 1,
            min_sup_count: 1,
            frequent_items: 1,
            dirty_frequent_items: 0,
            plan: MinePlan::Rebuild,
            frequents: vec![Frequent::new(vec![k as u32], k as u32 + 1)],
            rules: Vec::new(),
            wall: Duration::ZERO,
        }
    }

    fn model(f: impl Fn() + Send + Sync + 'static) {
        let mut b = loom::model::Builder::new();
        // Bound preemptions to keep the space tractable; loom still
        // covers every reordering expressible within the bound.
        b.preemption_bound = Some(3);
        b.max_branches = 100_000;
        b.check(f);
    }

    #[test]
    fn loom_reader_vs_two_flips_consistent_monotone_unpinned() {
        model(|| {
            let (mut publisher, handle) = snapshot_pipe();
            let reader = {
                let h = handle.clone();
                thread::spawn(move || {
                    let mut last = 0u64;
                    for _ in 0..2 {
                        if let Some(s) = h.latest() {
                            // Torn-snapshot check: all fields derive
                            // from the batch id.
                            assert_eq!(s.window_txns, (s.batch_id as usize) * 3 + 1, "torn");
                            assert_eq!(s.frequents[0].items, vec![s.batch_id as u32], "torn");
                            assert!(s.batch_id >= last, "regressed {last}->{}", s.batch_id);
                            last = s.batch_id;
                        }
                    }
                })
            };
            publisher.publish(snap(1));
            publisher.publish(snap(2));
            reader.join().unwrap();
            // No stale-forever reader: once the publisher is quiescent,
            // a fresh read observes the newest snapshot.
            assert_eq!(handle.latest().unwrap().batch_id, 2);
            // Pins always released, on both slots.
            // ordering: SeqCst — final-state assertions after the join.
            assert_eq!(handle.cell.slots[0].readers.load(Ordering::SeqCst), 0);
            assert_eq!(handle.cell.slots[1].readers.load(Ordering::SeqCst), 0);
        });
    }

    #[test]
    fn loom_two_readers_race_one_flip() {
        model(|| {
            let (mut publisher, handle) = snapshot_pipe();
            let spawn_reader = |h: SnapshotHandle| {
                thread::spawn(move || {
                    if let Some(s) = h.latest() {
                        assert_eq!(s.window_txns, (s.batch_id as usize) * 3 + 1, "torn");
                        assert_eq!(s.frequents[0].support, s.batch_id as u32 + 1, "torn");
                    }
                })
            };
            let r1 = spawn_reader(handle.clone());
            let r2 = spawn_reader(handle.clone());
            publisher.publish(snap(4));
            r1.join().unwrap();
            r2.join().unwrap();
            assert_eq!(handle.latest().unwrap().batch_id, 4);
            // ordering: SeqCst — final-state assertions after the joins.
            assert_eq!(handle.cell.slots[0].readers.load(Ordering::SeqCst), 0);
            assert_eq!(handle.cell.slots[1].readers.load(Ordering::SeqCst), 0);
        });
    }

    #[test]
    fn loom_dead_publisher_always_wakes_waiter() {
        model(|| {
            let (publisher, handle) = snapshot_pipe();
            let waiter = {
                let h = handle.clone();
                // Nothing is ever published: the waiter may only return
                // through the dead-publisher path, in every schedule.
                thread::spawn(move || h.wait_for_batch(1))
            };
            drop(publisher);
            assert!(waiter.join().unwrap().is_none());
            assert!(!handle.publisher_alive());
        });
    }

    #[test]
    fn loom_publish_vs_waiter_no_lost_wakeup() {
        model(|| {
            let (mut publisher, handle) = snapshot_pipe();
            let waiter = {
                let h = handle.clone();
                thread::spawn(move || h.wait_for_batch(1))
            };
            publisher.publish(snap(1));
            drop(publisher);
            // Whether the waiter checked before or after the publish (or
            // the drop), it must come back with batch 1 — a lost wakeup
            // would hang the model and fail the run.
            assert_eq!(waiter.join().unwrap().expect("published").batch_id, 1);
        });
    }
}
