//! A miniature criterion-style benchmark harness (the offline crate set
//! has no `criterion`). Warmup + fixed sample count + summary statistics,
//! plus CSV/markdown reporting used by every bench target and the figure
//! harness.

use crate::util::{Stopwatch, Summary};

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    /// Warmup iterations (not measured).
    pub warmup: usize,
    /// Measured samples.
    pub samples: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 1, samples: 5 }
    }
}

/// One benchmark measurement.
#[derive(Debug, Clone)]
pub struct Measurement {
    /// Benchmark id (e.g. `fig13/eclatV4/0.01`).
    pub name: String,
    /// Summary of per-sample wall times in seconds.
    pub secs: Summary,
}

impl Measurement {
    /// Mean seconds.
    pub fn mean(&self) -> f64 {
        self.secs.mean
    }
}

impl std::fmt::Display for Measurement {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<44} {:>10.4}s ±{:>8.4} (n={}, min {:.4}, max {:.4})",
            self.name, self.secs.mean, self.secs.std_dev, self.secs.n, self.secs.min, self.secs.max
        )
    }
}

impl Bench {
    /// Quick config for CI-style runs.
    pub fn quick() -> Bench {
        Bench { warmup: 0, samples: 2 }
    }

    /// From the `SCALE` env var: `paper` (default) vs `quick`.
    pub fn from_env() -> Bench {
        match std::env::var("SCALE").as_deref() {
            Ok("quick") => Bench::quick(),
            _ => Bench::default(),
        }
    }

    /// Measure a closure. The closure's return value is black-boxed so
    /// the optimizer cannot delete the work.
    pub fn run<T>(&self, name: impl Into<String>, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples.max(1) {
            let sw = Stopwatch::start();
            black_box(f());
            samples.push(sw.secs());
        }
        Measurement { name: name.into(), secs: Summary::of(&samples) }
    }

    /// Measure a fallible closure, propagating the first error.
    pub fn try_run<T, E>(
        &self,
        name: impl Into<String>,
        mut f: impl FnMut() -> Result<T, E>,
    ) -> Result<Measurement, E> {
        for _ in 0..self.warmup {
            black_box(f()?);
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples.max(1) {
            let sw = Stopwatch::start();
            black_box(f()?);
            samples.push(sw.secs());
        }
        Ok(Measurement { name: name.into(), secs: Summary::of(&samples) })
    }
}

/// Opaque use of a value (stable `black_box`).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Collects measurements and writes reports.
#[derive(Debug, Default)]
pub struct Report {
    rows: Vec<Measurement>,
}

impl Report {
    /// Empty report.
    pub fn new() -> Report {
        Report::default()
    }

    /// Add one measurement (also prints it).
    pub fn add(&mut self, m: Measurement) {
        println!("{m}");
        self.rows.push(m);
    }

    /// Borrow the rows.
    pub fn rows(&self) -> &[Measurement] {
        &self.rows
    }

    /// Serialize as CSV (`name,mean_s,std_s,min_s,max_s,n`).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("name,mean_s,std_s,min_s,max_s,n\n");
        for m in &self.rows {
            out.push_str(&format!(
                "{},{:.6},{:.6},{:.6},{:.6},{}\n",
                m.name, m.secs.mean, m.secs.std_dev, m.secs.min, m.secs.max, m.secs.n
            ));
        }
        out
    }

    /// Write the CSV under `results/` (created if needed).
    pub fn write_csv(&self, file: &str) -> crate::error::Result<String> {
        std::fs::create_dir_all("results")?;
        let path = format!("results/{file}");
        std::fs::write(&path, self.to_csv())?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn run_measures_and_summarizes() {
        let b = Bench { warmup: 1, samples: 3 };
        let m = b.run("sleepy", || std::thread::sleep(Duration::from_millis(2)));
        assert_eq!(m.secs.n, 3);
        assert!(m.secs.mean >= 0.002, "mean {}", m.secs.mean);
    }

    #[test]
    fn try_run_propagates_errors() {
        let b = Bench::quick();
        let r: Result<_, &str> = b.try_run("failing", || Err::<i32, &str>("nope"));
        assert_eq!(r.unwrap_err(), "nope");
        let ok: Result<_, &str> = b.try_run("fine", || Ok(42));
        assert!(ok.is_ok());
    }

    #[test]
    fn csv_shape() {
        let mut r = Report::new();
        r.add(Measurement { name: "a/b".into(), secs: Summary::of(&[1.0, 2.0]) });
        let csv = r.to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("name,mean_s"));
        assert!(lines[1].starts_with("a/b,1.5"));
    }

    #[test]
    fn from_env_respects_scale() {
        // Can't set env safely in parallel tests; just check both ctors.
        assert_eq!(Bench::quick().samples, 2);
        assert!(Bench::default().samples >= 3);
    }
}
