//! `figures` — regenerate every table and figure of the paper's
//! evaluation (DESIGN.md §5 per-experiment index).
//!
//! ```text
//! figures --all                 # everything (SCALE=paper|quick)
//! figures --fig 13              # one min-sup figure
//! figures --fig table2|15|16|a1|a2|a3|a4
//! ```

use rdd_eclat::cli::{App, Command};
use rdd_eclat::error::{Error, Result};
use rdd_eclat::figures::{run_by_id, FigureCtx};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match real_main(&argv) {
        Ok(()) => {}
        Err(Error::Usage(msg)) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn real_main(argv: &[String]) -> Result<()> {
    let app = App::new("figures", "regenerate the paper's tables and figures").command(
        Command::new("gen", "run experiments")
            .opt("fig", "table2 | 8..16 | a1..a4 | all")
            .opt("cores", "executor cores for live runs")
            .opt("data-dir", "dataset cache dir")
            .flag("all", "run everything")
            .flag("quick", "force quick scale (same as SCALE=quick)"),
    );
    // Allow both `figures gen --fig 13` and the shorthand `figures --fig 13`.
    let argv: Vec<String> = if argv.first().map(String::as_str) == Some("gen") {
        argv.to_vec()
    } else {
        let mut v = vec!["gen".to_string()];
        v.extend(argv.iter().cloned());
        v
    };
    let (cmd, args) = app.dispatch(&argv)?;
    debug_assert_eq!(cmd.name, "gen");

    let mut fx = FigureCtx::from_env();
    if args.flag("quick") {
        fx.quick = true;
        fx.bench = rdd_eclat::bench::Bench::quick();
    }
    fx.cores = args.get_parse("cores", fx.cores)?;
    if let Some(d) = args.get("data-dir") {
        fx.data_dir = d.to_string();
    }

    let id = if args.flag("all") {
        "all".to_string()
    } else {
        args.get("fig")
            .ok_or_else(|| Error::Usage("need --fig <id> or --all\n".into()))?
            .to_string()
    };
    println!(
        "running experiment(s) `{id}` at scale={} cores={} (results/ CSVs)",
        if fx.quick { "quick" } else { "paper" },
        fx.cores
    );
    run_by_id(&fx, &id)
}
