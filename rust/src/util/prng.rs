//! Deterministic pseudo-random number generation.
//!
//! `xoshiro256**` seeded through SplitMix64 — the standard construction for
//! reproducible simulation work. All dataset generators and property tests
//! take explicit seeds so every experiment in EXPERIMENTS.md is replayable
//! bit-for-bit.

/// A `xoshiro256**` generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed. Two generators with the same
    /// seed produce identical streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`. `bound` must be > 0. Uses Lemire's
    /// multiply-shift rejection method (unbiased).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform usize in `[lo, hi)` (half-open). Panics if `lo >= hi`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range {lo}..{hi}");
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Poisson-distributed draw with mean `lambda` (Knuth for small lambda,
    /// normal approximation above 30 — plenty for transaction widths).
    pub fn poisson(&mut self, lambda: f64) -> usize {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0usize;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            // Normal approximation with continuity correction.
            let x = lambda + lambda.sqrt() * self.gaussian();
            if x < 0.0 {
                0
            } else {
                x.round() as usize
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Geometric-ish draw used by the Quest generator for itemset-length
    /// "corruption"; returns values >= 1 with mean roughly `mean`.
    pub fn geometric(&mut self, mean: f64) -> usize {
        let p = 1.0 / mean.max(1.0);
        let mut k = 1usize;
        while !self.chance(p) && k < 10_000 {
            k += 1;
        }
        k
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below((j + 1) as u64) as usize;
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Derive an independent child generator (for per-partition streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }
}

/// Sampler for a Zipf distribution over `{0, .., n-1}` with exponent `s`,
/// used by the clickstream generator (BMS-style datasets have heavily
/// skewed item popularity). Uses inverse-CDF over precomputed cumulative
/// weights — O(log n) per draw.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build a sampler over `n` ranks with exponent `s` (s=0 is uniform).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for v in &mut cdf {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Draw a rank in `[0, n)`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|probe| probe.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues reached");
    }

    #[test]
    fn f64_in_unit_interval_with_sane_mean() {
        let mut r = Rng::new(99);
        let n = 10_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn poisson_mean_close() {
        let mut r = Rng::new(5);
        for &lambda in &[2.0, 10.0, 40.0] {
            let n = 5000;
            let total: usize = (0..n).map(|_| r.poisson(lambda)).sum();
            let mean = total as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < lambda * 0.1 + 0.3,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn sample_indices_distinct_and_bounded() {
        let mut r = Rng::new(11);
        let sample = r.sample_indices(100, 20);
        assert_eq!(sample.len(), 20);
        let set: std::collections::HashSet<_> = sample.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(sample.iter().all(|&i| i < 100));
    }

    #[test]
    fn zipf_is_skewed_toward_low_ranks() {
        let mut r = Rng::new(13);
        let z = Zipf::new(1000, 1.1);
        let mut head = 0usize;
        let n = 20_000;
        for _ in 0..n {
            if z.sample(&mut r) < 10 {
                head += 1;
            }
        }
        // Top-10 of 1000 ranks should attract far more than 1% of draws.
        assert!(head as f64 / n as f64 > 0.2, "head share {}", head as f64 / n as f64);
    }

    #[test]
    fn geometric_mean_roughly_matches() {
        let mut r = Rng::new(17);
        let n = 20_000;
        let total: usize = (0..n).map(|_| r.geometric(4.0)).sum();
        let mean = total as f64 / n as f64;
        assert!((mean - 4.0).abs() < 0.3, "mean {mean}");
    }
}
