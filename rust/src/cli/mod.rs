//! A small command-line argument parser (no `clap` offline).
//!
//! Model: `binary <subcommand> [--flag] [--key value] [positional...]`.
//! Flags are declared up front so typos fail fast with usage text.

use std::collections::HashMap;

use crate::error::{Error, Result};

/// Declares one option.
#[derive(Debug, Clone)]
pub struct OptSpec {
    /// Long name without dashes ("min-sup").
    pub name: &'static str,
    /// Takes a value (`--key v`) vs boolean flag (`--flag`).
    pub takes_value: bool,
    /// Help text.
    pub help: &'static str,
}

/// Parsed arguments of one subcommand.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: HashMap<String, String>,
    flags: Vec<String>,
    /// Positional arguments in order.
    pub positional: Vec<String>,
}

impl Args {
    /// String value of `--name v`.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    /// Parsed value with a default.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| Error::Usage(format!("--{name}: cannot parse {s:?}"))),
        }
    }

    /// Was boolean `--name` given?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// A subcommand parser.
#[derive(Debug, Clone)]
pub struct Command {
    /// Subcommand name.
    pub name: &'static str,
    /// One-line description for the usage listing.
    pub about: &'static str,
    opts: Vec<OptSpec>,
}

impl Command {
    /// New subcommand.
    pub fn new(name: &'static str, about: &'static str) -> Command {
        Command { name, about, opts: Vec::new() }
    }

    /// Declare a value option.
    pub fn opt(mut self, name: &'static str, help: &'static str) -> Command {
        self.opts.push(OptSpec { name, takes_value: true, help });
        self
    }

    /// Declare a boolean flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Command {
        self.opts.push(OptSpec { name, takes_value: false, help });
        self
    }

    /// Usage text.
    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n", self.name, self.about);
        for o in &self.opts {
            let arg = if o.takes_value { format!("--{} <v>", o.name) } else { format!("--{}", o.name) };
            out.push_str(&format!("  {arg:24} {}\n", o.help));
        }
        out
    }

    /// Parse this subcommand's argument list (after the subcommand word).
    pub fn parse(&self, argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| Error::Usage(format!("unknown option --{name}\n{}", self.usage())))?;
                if spec.takes_value {
                    let v = argv
                        .get(i + 1)
                        .ok_or_else(|| Error::Usage(format!("--{name} needs a value")))?;
                    args.values.insert(name.to_string(), v.clone());
                    i += 2;
                } else {
                    args.flags.push(name.to_string());
                    i += 1;
                }
            } else {
                args.positional.push(a.clone());
                i += 1;
            }
        }
        Ok(args)
    }
}

/// Top-level dispatcher over subcommands.
pub struct App {
    /// Binary name.
    pub name: &'static str,
    /// App description.
    pub about: &'static str,
    /// Registered subcommands.
    pub commands: Vec<Command>,
}

impl App {
    /// Build the app.
    pub fn new(name: &'static str, about: &'static str) -> App {
        App { name, about, commands: Vec::new() }
    }

    /// Register a subcommand.
    pub fn command(mut self, c: Command) -> App {
        self.commands.push(c);
        self
    }

    /// Full usage text.
    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nsubcommands:\n", self.name, self.about);
        for c in &self.commands {
            out.push_str(&format!("  {:12} {}\n", c.name, c.about));
        }
        out.push_str(&format!("\nrun `{} <subcommand> --help` for options\n", self.name));
        out
    }

    /// Dispatch `argv` (without the binary name). Returns the matched
    /// subcommand name and its parsed args, or a usage error.
    pub fn dispatch(&self, argv: &[String]) -> Result<(&Command, Args)> {
        let Some(sub) = argv.first() else {
            return Err(Error::Usage(self.usage()));
        };
        if sub == "--help" || sub == "help" || sub == "-h" {
            return Err(Error::Usage(self.usage()));
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == sub)
            .ok_or_else(|| Error::Usage(format!("unknown subcommand {sub:?}\n{}", self.usage())))?;
        if argv.iter().any(|a| a == "--help") {
            return Err(Error::Usage(cmd.usage()));
        }
        let args = cmd.parse(&argv[1..])?;
        Ok((cmd, args))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App::new("repro", "test app").command(
            Command::new("run", "run something")
                .opt("algo", "algorithm")
                .opt("min-sup", "support")
                .flag("verbose", "chatty"),
        )
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_flags_positionals() {
        let a = app();
        let (cmd, args) =
            a.dispatch(&sv(&["run", "--algo", "v4", "--verbose", "extra"])).unwrap();
        assert_eq!(cmd.name, "run");
        assert_eq!(args.get("algo"), Some("v4"));
        assert!(args.flag("verbose"));
        assert_eq!(args.positional, vec!["extra"]);
    }

    #[test]
    fn get_parse_with_default() {
        let a = app();
        let (_, args) = a.dispatch(&sv(&["run", "--min-sup", "0.05"])).unwrap();
        assert_eq!(args.get_parse("min-sup", 1.0).unwrap(), 0.05);
        assert_eq!(args.get_parse("algo", 7u32).unwrap(), 7);
        let err = args.get_parse::<u32>("min-sup", 0).unwrap_err();
        assert!(err.to_string().contains("cannot parse"));
    }

    #[test]
    fn unknown_option_and_subcommand_error() {
        let a = app();
        assert!(a.dispatch(&sv(&["run", "--nope"])).is_err());
        assert!(a.dispatch(&sv(&["zap"])).is_err());
        assert!(a.dispatch(&sv(&[])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        let a = app();
        let err = a.dispatch(&sv(&["run", "--algo"])).unwrap_err();
        assert!(err.to_string().contains("needs a value"));
    }

    #[test]
    fn help_yields_usage() {
        let a = app();
        let err = a.dispatch(&sv(&["--help"])).unwrap_err();
        assert!(err.to_string().contains("subcommands"));
        let err = a.dispatch(&sv(&["run", "--help"])).unwrap_err();
        assert!(err.to_string().contains("--algo"));
    }
}
