//! End-to-end runtime integration: mining with the XLA (AOT PJRT)
//! co-occurrence backend must match the native path exactly, on generated
//! benchmark data. Tests no-op politely when `make artifacts` hasn't run
//! (the Makefile orders artifacts before tests). The whole file is gated
//! on the `xla` cargo feature.
#![cfg(feature = "xla")]

use std::sync::Arc;

use rdd_eclat::algorithms::{Algorithm, CoocStrategy, EclatOptions, EclatV4};
use rdd_eclat::data::quest::{generate, QuestParams};
use rdd_eclat::engine::ClusterContext;
use rdd_eclat::fim::{sort_frequents, MinSup};
use rdd_eclat::runtime::{artifacts_available, default_artifact_dir, XlaCooc, XlaService};

fn service() -> Option<Arc<XlaService>> {
    if !artifacts_available() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(Arc::new(XlaService::start(default_artifact_dir()).expect("service")))
}

#[test]
fn mining_with_xla_cooc_backend_matches_native() {
    let Some(svc) = service() else { return };
    let db = generate(&QuestParams::tid(8.0, 3.0, 3000, 200), 17);
    let ctx = ClusterContext::builder().cores(2).build();

    let native = EclatV4::default();
    let mut want = native.run_on(&ctx, &db, MinSup::fraction(0.01)).unwrap().frequents;
    sort_frequents(&mut want);

    let xla = EclatV4::with_options(EclatOptions {
        tri_matrix: true,
        cooc: CoocStrategy::Provider(Arc::new(XlaCooc::new(svc))),
        ..Default::default()
    });
    let mut got = xla.run_on(&ctx, &db, MinSup::fraction(0.01)).unwrap().frequents;
    sort_frequents(&mut got);
    assert_eq!(got, want);
    assert!(!got.is_empty(), "workload actually mined something");
}

#[test]
fn xla_service_survives_repeated_use_across_contexts() {
    let Some(svc) = service() else { return };
    // Several independent mining runs sharing one service (the deployment
    // shape: one device service per process).
    for seed in 0..3 {
        let db = generate(&QuestParams::tid(6.0, 3.0, 1000, 150), seed);
        let ctx = ClusterContext::builder().cores(2).build();
        let algo = EclatV4::with_options(EclatOptions {
            tri_matrix: true,
            cooc: CoocStrategy::Provider(Arc::new(XlaCooc::new(Arc::clone(&svc)))),
            ..Default::default()
        });
        let r = algo.run_on(&ctx, &db, MinSup::fraction(0.02)).unwrap();
        assert!(!r.frequents.is_empty());
    }
}

#[test]
fn artifact_dir_override_via_env_is_respected() {
    // Point at a bogus dir: the service must fail with the make-artifacts
    // hint, proving the env knob is honored.
    let err = XlaService::start("/definitely/not/here").unwrap_err();
    assert!(err.to_string().contains("make artifacts"));
}
