//! Micro-benchmarks of the RDD engine: narrow pipelines, shuffle
//! (groupByKey/reduceByKey), partitionBy, caching, accumulators — the L3
//! substrate costs under the paper's algorithms.

use rdd_eclat::bench::{black_box, Bench, Report};
use rdd_eclat::engine::ClusterContext;

fn main() {
    let bench = Bench::from_env();
    let mut report = Report::new();
    let cores = rdd_eclat::engine::available_cores();

    // --- narrow pipeline: map+filter over 1M u32 ---
    {
        let ctx = ClusterContext::builder().cores(cores).build();
        let data: Vec<u32> = (0..1_000_000).collect();
        let rdd = ctx.parallelize(data, cores * 4);
        report.add(bench.run("engine/narrow_map_filter_1M", || {
            let out = rdd.map(|x| x.wrapping_mul(31)).filter(|x| x % 7 == 0);
            black_box(out.count().unwrap())
        }));
    }

    // --- reduceByKey word-count over 1M pairs, 10k keys ---
    {
        let ctx = ClusterContext::builder().cores(cores).build();
        let data: Vec<(u32, u32)> = (0..1_000_000).map(|i| (i % 10_000, 1)).collect();
        let rdd = ctx.parallelize(data, cores * 4);
        report.add(bench.run("engine/reduce_by_key_1M_10k_keys", || {
            black_box(rdd.reduce_by_key(cores, |a, b| a + b).count().unwrap())
        }));
    }

    // --- groupByKey over 300k pairs, 1k keys ---
    {
        let ctx = ClusterContext::builder().cores(cores).build();
        let data: Vec<(u32, u32)> = (0..300_000).map(|i| (i % 1000, i)).collect();
        let rdd = ctx.parallelize(data, cores * 4);
        report.add(bench.run("engine/group_by_key_300k_1k_keys", || {
            black_box(rdd.group_by_key(cores).count().unwrap())
        }));
    }

    // --- cache effectiveness: second pass should be ~free ---
    {
        let ctx = ClusterContext::builder().cores(cores).build();
        let data: Vec<u64> = (0..500_000).collect();
        let rdd = ctx
            .parallelize(data, cores * 2)
            .map(|x| {
                // Some work worth caching.
                let mut h = x;
                for _ in 0..8 {
                    h = h.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(17);
                }
                h
            })
            .cache();
        rdd.count().unwrap(); // populate
        report.add(bench.run("engine/cached_recount_500k", || {
            black_box(rdd.count().unwrap())
        }));
    }

    // --- accumulator merge cost (per-partition matrices) ---
    {
        let ctx = ClusterContext::builder().cores(cores).build();
        let txns: Vec<Vec<u32>> = (0..20_000)
            .map(|i| (0..10).map(|j| ((i * 7 + j * 13) % 150) as u32).collect())
            .collect();
        let rdd = ctx.parallelize(txns, cores * 2);
        report.add(bench.run("engine/trimatrix_accumulator_20k", || {
            let acc = ctx.accumulator(
                rdd_eclat::fim::TriMatrix::new(149),
                |a: &mut rdd_eclat::fim::TriMatrix, b| a.merge(&b),
            );
            let task_acc = acc.clone();
            rdd.map_partitions_with_index(move |_i, txns| {
                let mut local = rdd_eclat::fim::TriMatrix::new(149);
                for t in &txns {
                    local.update_transaction(t);
                }
                task_acc.add(local);
                Vec::<()>::new()
            })
            .run()
            .unwrap();
            black_box(acc.with_value(|m| m.support(1, 2)))
        }));
    }

    // --- recovery overhead: the same shuffle job fault-free, under
    // transient task panics (retried), and under certain shuffle loss
    // (map stage re-materialized through lineage every iteration) ---
    {
        let data: Vec<(u32, u32)> = (0..200_000).map(|i| (i % 2_000, 1)).collect();
        let job = |ctx: &ClusterContext| {
            let rdd = ctx.parallelize(data.clone(), cores * 2);
            black_box(rdd.reduce_by_key(cores, |a, b| a + b).count().unwrap())
        };
        let ctx = ClusterContext::builder().cores(cores).without_chaos().build();
        report.add(bench.run("engine/recovery/fault_free", || job(&ctx)));

        let ctx = ClusterContext::builder()
            .cores(cores)
            .chaos(rdd_eclat::engine::ChaosPolicy::new(7).task_panics(0.3))
            .build();
        report.add(bench.run("engine/recovery/task_retry", || job(&ctx)));

        let ctx = ClusterContext::builder()
            .cores(cores)
            .chaos(rdd_eclat::engine::ChaosPolicy::new(7).shuffle_loss(1.0))
            .build();
        report.add(bench.run("engine/recovery/shuffle_rerun", || job(&ctx)));
    }

    report.write_csv("bench_engine_micro.csv").expect("write csv");
    println!("\nwrote results/bench_engine_micro.csv");

    // Perf trajectory: BENCH_engine.json at the repo root (cargo runs
    // benches with the package dir as CWD, hence the `..`). A separate
    // file from BENCH_fim.json — write_json replaces a whole file, and
    // the fim bench owns that one.
    let out = std::env::var("BENCH_ENGINE_OUT").unwrap_or_else(|_| {
        format!("{}/../BENCH_engine.json", env!("CARGO_MANIFEST_DIR"))
    });
    let scale = Bench::scale_from_env();
    report.write_json(&out, "engine_micro", scale).expect("write BENCH_engine.json");
    println!("wrote {out}");
}
