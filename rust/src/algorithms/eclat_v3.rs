//! EclatV3 (paper §4.3, Algorithm 8 + 9): EclatV2 with the vertical
//! dataset built in a shared **hashmap accumulator** (`accMap`) instead of
//! a `groupByKey` shuffle. Phases 1–2 are identical to EclatV2; Phase-3
//! accumulates `item → tidset` across executors; Phase-4 reads tidsets
//! from the hashmap (otherwise identical to Algorithm 4).

use std::sync::Arc;

use crate::engine::ClusterContext;
use crate::error::Result;
use crate::fim::{Database, Frequent, ItemFilter, MinSup};

use super::common::{
    mine_equivalence_classes, phase1_wordcount, phase2_trimatrix, phase3_vertical_accumulated,
    transactions_rdd,
};
use super::partitioners::DefaultClassPartitioner;
use super::{Algorithm, EclatOptions, FimResult};

/// EclatV3 (see module docs).
#[derive(Debug, Clone, Default)]
pub struct EclatV3 {
    /// Shared variant options.
    pub options: EclatOptions,
}

impl EclatV3 {
    /// With explicit options.
    pub fn with_options(options: EclatOptions) -> Self {
        EclatV3 { options }
    }
}

/// The common V3/V4/V5 pipeline, parameterised by the Phase-4 partitioner
/// factory (`n` = number of frequent items → partitioner).
pub(crate) fn run_v3_pipeline(
    name: &'static str,
    options: &EclatOptions,
    ctx: &ClusterContext,
    db: &Database,
    min_sup: MinSup,
    make_partitioner: impl FnOnce(usize) -> Arc<dyn crate::engine::Partitioner<usize>>,
) -> Result<FimResult> {
    let min_sup = min_sup.to_count(db.len());
    let mut run = FimResult::builder(name);

    let transactions = transactions_rdd(ctx, db, ctx.default_parallelism());

    // Phase-1 (Algorithm 5).
    let freq_items = phase1_wordcount(ctx, &transactions, min_sup)?;
    run.phase("phase1");

    // Phase-2 (Algorithm 6).
    let trie = ctx.broadcast(ItemFilter::new(freq_items.iter().map(|(i, _)| *i)));
    let filter_trie = trie.clone();
    let filtered = transactions
        .map(move |t| filter_trie.value().filter_transaction(&t))
        .filter(|t| !t.is_empty())
        .cache();
    let total_before = db.total_items();
    let (total_after, filtered_count) = {
        let acc = ctx.accumulator((0u64, 0u64), |a: &mut (u64, u64), b: (u64, u64)| {
            a.0 += b.0;
            a.1 += b.1;
        });
        let acc2 = acc.clone();
        filtered
            .map_partitions_with_index(move |_i, txns| {
                acc2.add((txns.iter().map(|t| t.len() as u64).sum(), txns.len() as u64));
                Vec::<()>::new()
            })
            .run()?;
        acc.value()
    };
    let reduction = 1.0 - total_after as f64 / total_before.max(1) as f64;

    let tri = if options.tri_matrix {
        let max_item = freq_items.iter().map(|(i, _)| *i).max().unwrap_or(0);
        Some(phase2_trimatrix(ctx, &filtered, max_item, &options.cooc)?)
    } else {
        None
    };
    run.phase("phase2");

    // Phase-3 (Algorithm 8): accumulated vertical dataset.
    let vertical = phase3_vertical_accumulated(ctx, &filtered)?;
    run.phase("phase3");

    // Phase-4 (Algorithm 9).
    let universe = filtered_count as usize;
    let mut frequents: Vec<Frequent> =
        vertical.iter().map(|(i, t)| Frequent::new(vec![*i], t.len() as u32)).collect();
    let n = vertical.len();
    let loads = mine_equivalence_classes(
        ctx,
        vertical,
        universe,
        min_sup,
        tri.as_ref(),
        make_partitioner(n),
        &mut frequents,
    )?;
    run.phase("phase4");
    run.partition_loads(loads);
    run.filtered_reduction(reduction);

    Ok(run.finish(frequents))
}

impl Algorithm for EclatV3 {
    fn name(&self) -> &'static str {
        "eclatV3"
    }

    fn run_on(&self, ctx: &ClusterContext, db: &Database, min_sup: MinSup) -> Result<FimResult> {
        run_v3_pipeline(self.name(), &self.options, ctx, db, min_sup, |n| {
            Arc::new(DefaultClassPartitioner::for_items(n))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::{apriori::apriori, sort_frequents};

    fn demo_db() -> Database {
        Database::from_rows(vec![
            vec![1, 3, 4],
            vec![2, 3, 5],
            vec![1, 2, 3, 5],
            vec![2, 5],
            vec![1, 3, 5],
            vec![2, 3, 5],
        ])
    }

    #[test]
    fn matches_apriori_oracle() {
        let ctx = ClusterContext::builder().cores(2).build();
        let db = demo_db();
        for min_sup in 1..=5 {
            let mut want = apriori(&db, min_sup);
            let mut got = EclatV3::default()
                .run_on(&ctx, &db, MinSup::count(min_sup))
                .unwrap()
                .frequents;
            sort_frequents(&mut want);
            sort_frequents(&mut got);
            assert_eq!(got, want, "min_sup={min_sup}");
        }
    }

    #[test]
    fn agrees_with_v2_exactly() {
        let ctx = ClusterContext::builder().cores(2).build();
        let db = demo_db();
        let mut v2 = super::super::EclatV2::default()
            .run_on(&ctx, &db, MinSup::count(2))
            .unwrap()
            .frequents;
        let mut v3 = EclatV3::default().run_on(&ctx, &db, MinSup::count(2)).unwrap().frequents;
        sort_frequents(&mut v2);
        sort_frequents(&mut v3);
        assert_eq!(v2, v3);
    }
}
