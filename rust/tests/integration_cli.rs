//! CLI integration: drive the built `repro` binary end to end (dataset
//! generation → mining → rule extraction → config files), checking the
//! user-visible contract.

use std::process::Command;

fn repro() -> Command {
    Command::new(env!("CARGO_BIN_EXE_repro"))
}

fn tmp_dir(name: &str) -> String {
    let d = std::env::temp_dir().join(format!("rdd_eclat_cli_{name}"));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d.to_string_lossy().into_owned()
}

#[test]
fn datasets_lists_table2() {
    let out = repro().arg("datasets").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for name in ["chess", "mushroom", "BMS_WebView_1", "T40I10D100K"] {
        assert!(text.contains(name), "{name} missing:\n{text}");
    }
}

#[test]
fn generate_then_run_on_file_path() {
    let dir = tmp_dir("genrun");
    let out = repro()
        .args(["generate", "--dataset", "chess", "--data-dir", &dir])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    // Cache filenames carry the generator version (see DatasetSpec).
    let file = format!("{dir}/chess.v2.dat");
    assert!(std::path::Path::new(&file).exists());

    // Mine the generated file by path.
    let out = repro()
        .args(["run", "--algo", "v5", "--dataset", &file, "--min-sup", "0.9", "--quiet"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("found"), "{text}");
}

#[test]
fn run_writes_output_file_sorted() {
    let dir = tmp_dir("output");
    let out = repro()
        .args([
            "run", "--algo", "v4", "--dataset", "chess", "--min-sup", "0.9",
            "--data-dir", &dir, "--output", &format!("{dir}/out"),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let listing = std::fs::read_to_string(format!("{dir}/out/frequent_itemsets.txt")).unwrap();
    assert!(listing.lines().count() > 0);
    assert!(listing.contains("#SUP:"));
}

#[test]
fn config_file_drives_run_and_flags_override() {
    let dir = tmp_dir("config");
    std::fs::write(
        format!("{dir}/exp.toml"),
        format!(
            "algorithm = \"eclatV1\"\ndataset = \"chess\"\nmin_sup = 0.95\ndata_dir = \"{dir}\"\n"
        ),
    )
    .unwrap();
    let out = repro()
        .args(["run", "--config", &format!("{dir}/exp.toml"), "--quiet"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("eclatV1"));

    // Flag overrides config.
    let out = repro()
        .args(["run", "--config", &format!("{dir}/exp.toml"), "--algo", "v3", "--quiet"])
        .output()
        .unwrap();
    assert!(String::from_utf8_lossy(&out.stdout).contains("eclatV3"));
}

#[test]
fn rules_subcommand_prints_confident_rules() {
    let dir = tmp_dir("rules");
    let out = repro()
        .args([
            "rules", "--dataset", "chess", "--min-sup", "0.9", "--min-conf", "0.9",
            "--data-dir", &dir, "--top", "5",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("rules at min_conf"), "{text}");
    assert!(text.contains("=>"), "{text}");
}

#[test]
fn rules_subcommand_writes_json() {
    let dir = tmp_dir("rules_json");
    let json_path = format!("{dir}/rules.json");
    let out = repro()
        .args([
            "rules", "--dataset", "chess", "--min-sup", "0.9", "--min-conf", "0.9",
            "--data-dir", &dir, "--top", "1", "--json", &json_path,
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let json = std::fs::read_to_string(&json_path).unwrap();
    assert!(json.trim_start().starts_with('['), "{json}");
    assert!(json.contains("\"antecedent\""), "{json}");
    assert!(json.contains("\"confidence\""), "{json}");
}

#[test]
fn stream_subcommand_replays_a_file_and_writes_snapshot() {
    let dir = tmp_dir("stream");
    // 12 transactions with a stable frequent pair {1, 2}.
    let file = format!("{dir}/stream.dat");
    let rows: String = (0..12)
        .map(|i| if i % 3 == 2 { "1 3\n".to_string() } else { "1 2\n".to_string() })
        .collect();
    std::fs::write(&file, rows).unwrap();
    let json_path = format!("{dir}/snapshot.json");
    let out = repro()
        .args([
            "stream", "--dataset", &file, "--batch", "4", "--window", "2", "--slide", "1",
            "--batches", "3", "--min-sup", "3", "--min-conf", "0.5", "--json", &json_path,
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("emissions"), "{text}");
    assert!(text.contains("batch"), "{text}");
    let json = std::fs::read_to_string(&json_path).unwrap();
    assert!(json.contains("\"window_txns\": 8"), "{json}");
    assert!(json.contains("\"frequents\""), "{json}");
    assert!(json.contains("\"rules\""), "{json}");

    // From-scratch mode produces the same final itemset count.
    let out = repro()
        .args([
            "stream", "--dataset", &file, "--batch", "4", "--window", "2", "--slide", "1",
            "--batches", "3", "--min-sup", "3", "--mode", "from-scratch", "--quiet",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // Bad mode is a usage error.
    let out = repro().args(["stream", "--mode", "telepathy"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn stream_subcommand_shards_the_store() {
    // --shards 3 must replay the same stream to the same window, with
    // per-shard accounting in the summary.
    let dir = tmp_dir("stream_shards");
    let file = format!("{dir}/stream.dat");
    let rows: String = (0..12)
        .map(|i| if i % 3 == 2 { "1 3\n".to_string() } else { "1 2\n".to_string() })
        .collect();
    std::fs::write(&file, rows).unwrap();
    let json_path = format!("{dir}/snapshot.json");
    let out = repro()
        .args([
            "stream", "--dataset", &file, "--batch", "4", "--window", "2", "--slide", "1",
            "--batches", "3", "--min-sup", "3", "--min-conf", "0.5", "--shards", "3",
            "--json", &json_path,
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("3 shards"), "{text}");
    assert!(text.contains("per-shard accounting"), "{text}");
    assert!(text.contains("shard 2:"), "{text}");
    // Same stream, same window as the unsharded run.
    let json = std::fs::read_to_string(&json_path).unwrap();
    assert!(json.contains("\"window_txns\": 8"), "{json}");
    assert!(json.contains("\"frequents\""), "{json}");

    // --shards must be positive.
    let out = repro()
        .args(["stream", "--dataset", &file, "--batches", "1", "--shards", "0"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--shards"));
}

#[test]
fn stream_serve_mode_runs_async_and_writes_snapshot() {
    // `--serve` routes the same replayed stream through the async
    // service + query threads; the drained final snapshot must cover
    // the same window as the synchronous path.
    let dir = tmp_dir("stream_serve");
    let file = format!("{dir}/stream.dat");
    let rows: String = (0..12)
        .map(|i| if i % 3 == 2 { "1 3\n".to_string() } else { "1 2\n".to_string() })
        .collect();
    std::fs::write(&file, rows).unwrap();
    let json_path = format!("{dir}/snapshot.json");
    let out = repro()
        .args([
            "stream", "--serve", "--dataset", &file, "--batch", "4", "--window", "2",
            "--slide", "1", "--min-sup", "3", "--min-conf", "0.5", "--queue-cap", "2",
            "--readers", "1", "--quiet", "--json", &json_path,
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("serving: queue cap 2"), "{text}");
    assert!(text.contains("emissions published"), "{text}");
    let json = std::fs::read_to_string(&json_path).unwrap();
    assert!(json.contains("\"window_txns\": 8"), "{json}");
    assert!(json.contains("\"frequents\""), "{json}");
    assert!(json.contains("\"rules\""), "{json}");

    // --queue-cap must be positive.
    let out = repro()
        .args(["stream", "--serve", "--batches", "1", "--queue-cap", "0"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn list_algos_prints_the_registry() {
    let out = repro().args(["run", "--list-algos"]).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    for name in [
        "eclatV1", "eclatV2", "eclatV3", "eclatV4", "eclatV5", "apriori", "seq-eclat",
        "seq-declat", "seq-apriori", "seq-fpgrowth",
    ] {
        assert!(text.contains(name), "{name} missing:\n{text}");
    }
    // One-line descriptions ride along.
    assert!(text.contains("reverse-hash"), "{text}");
}

#[test]
fn unknown_algo_error_enumerates_valid_names() {
    let out = repro().args(["run", "--algo", "telepathy"]).output().unwrap();
    assert!(!out.status.success());
    let err = String::from_utf8_lossy(&out.stderr);
    assert!(err.contains("telepathy"), "{err}");
    assert!(err.contains("valid names"), "{err}");
    assert!(err.contains("eclatV4") && err.contains("seq-fpgrowth"), "{err}");
}

#[test]
fn bad_usage_exits_nonzero_with_help() {
    let out = repro().args(["run", "--algo", "not-an-algo"]).output().unwrap();
    assert!(!out.status.success());

    let out = repro().args(["frobnicate"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("subcommands"));

    let out = repro().arg("--help").output().unwrap();
    assert!(String::from_utf8_lossy(&out.stderr).contains("run"));
}

#[test]
fn run_trace_writes_valid_chrome_trace() {
    let dir = tmp_dir("run_trace");
    let trace = format!("{dir}/run.trace.json");
    let out = repro()
        .args([
            "run", "--algo", "v5", "--dataset", "chess", "--min-sup", "0.9",
            "--data-dir", &dir, "--quiet", "--trace", &trace,
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("trace events"), "{text}");
    assert!(text.contains("metrics:"), "{text}");
    let trace_text = std::fs::read_to_string(&trace).unwrap();
    let events = rdd_eclat::obs::validate_trace(&trace_text).expect("well-formed trace");
    assert!(events > 0, "trace must carry events");
    // Scheduler spans land on the executor worker thread tracks.
    assert!(trace_text.contains("engine.job"), "{trace_text}");
    assert!(trace_text.contains("engine.task"), "{trace_text}");
    assert!(trace_text.contains("executor-"), "{trace_text}");
}

#[test]
fn stream_serve_trace_covers_mining_and_publishes() {
    // The PR acceptance trace: async serving with 4 shards must produce
    // a well-formed Chrome trace carrying per-shard mining spans and
    // publish spans on the mining service's thread track.
    let dir = tmp_dir("serve_trace");
    let file = format!("{dir}/stream.dat");
    let rows: String = (0..24)
        .map(|i| if i % 3 == 2 { "1 3\n".to_string() } else { "1 2\n".to_string() })
        .collect();
    std::fs::write(&file, rows).unwrap();
    let trace = format!("{dir}/serve.trace.json");
    let out = repro()
        .args([
            "stream", "--serve", "--dataset", &file, "--batch", "4", "--window", "2",
            "--slide", "1", "--min-sup", "3", "--shards", "4", "--quiet",
            "--stats-every", "2", "--trace", &trace,
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("[stats]"), "digest lines printed: {text}");
    let trace_text = std::fs::read_to_string(&trace).unwrap();
    let events = rdd_eclat::obs::validate_trace(&trace_text).expect("well-formed trace");
    assert!(events > 0, "trace must carry events");
    assert!(trace_text.contains("stream.mine_now"), "{trace_text}");
    assert!(trace_text.contains("stream.mine_shard"), "{trace_text}");
    assert!(trace_text.contains("stream.publish"), "{trace_text}");
    assert!(trace_text.contains("stream-miner"), "{trace_text}");
}

#[test]
fn chaos_run_prints_header_and_matches_fault_free_result() {
    let dir = tmp_dir("chaos_run");
    let found_line = |text: &str| -> String {
        text.lines()
            .find(|l| l.contains("found") && l.contains("frequent itemsets"))
            .unwrap_or_else(|| panic!("no result line in:\n{text}"))
            .to_string()
    };
    // Fault-free baseline; shield it from any ambient CI chaos env.
    let out = repro()
        .args(["run", "--algo", "v2", "--dataset", "chess", "--min-sup", "0.9",
               "--data-dir", &dir, "--quiet"])
        .env_remove("RDD_ECLAT_CHAOS")
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let clean = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(!clean.contains("chaos armed"), "{clean}");

    // Same mine under injected faults: header printed, result unchanged.
    let out = repro()
        .args(["run", "--algo", "v2", "--dataset", "chess", "--min-sup", "0.9",
               "--data-dir", &dir, "--quiet", "--chaos", "7:0.2"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let chaotic = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(chaotic.contains("chaos armed"), "{chaotic}");
    assert_eq!(
        found_line(&chaotic),
        found_line(&clean),
        "chaos changed the mined result"
    );
}

#[test]
fn invalid_chaos_spec_is_a_usage_error() {
    let out = repro()
        .args(["run", "--dataset", "chess", "--chaos", "nope"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("chaos"));
}

#[test]
fn stream_serve_with_chaos_survives_and_stays_window_exact() {
    // `--serve --chaos` arms emission failures on top of engine faults;
    // the service must retry through them and drain to the exact window.
    let dir = tmp_dir("stream_chaos");
    let file = format!("{dir}/stream.dat");
    let rows: String = (0..12)
        .map(|i| if i % 3 == 2 { "1 3\n".to_string() } else { "1 2\n".to_string() })
        .collect();
    std::fs::write(&file, rows).unwrap();
    let json_path = format!("{dir}/snapshot.json");
    let out = repro()
        .args([
            "stream", "--serve", "--dataset", &file, "--batch", "4", "--window", "2",
            "--slide", "1", "--min-sup", "3", "--quiet", "--chaos", "7:0.3",
            "--json", &json_path,
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("chaos armed"), "{text}");
    let json = std::fs::read_to_string(&json_path).unwrap();
    assert!(json.contains("\"window_txns\": 8"), "{json}");
    assert!(json.contains("\"frequents\""), "{json}");
}

#[test]
fn invalid_min_sup_rejected() {
    let out = repro()
        .args(["run", "--dataset", "chess", "--min-sup", "abc"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("cannot parse"));
}
