//! Fixture: bare guard unwraps outside test code must be flagged.
//! Never compiled — scanned by `tests/integration_lint.rs` only.

use std::sync::{Mutex, RwLock};

pub fn drain(queue: &Mutex<Vec<u32>>) -> Vec<u32> {
    // VIOLATION(bare-lock-unwrap) on the next line (line 8).
    std::mem::take(&mut *queue.lock().unwrap())
}

pub fn peek(table: &RwLock<Vec<u32>>) -> usize {
    // VIOLATION(bare-lock-unwrap) on the next line (line 13).
    table.read().unwrap().len()
}

pub fn grow(table: &RwLock<Vec<u32>>, v: u32) {
    // VIOLATION(bare-lock-unwrap) on the next line (line 18).
    table.write().unwrap().push(v);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tests_may_unwrap_guards() {
        // NOT a violation: test regions are exempt — a poisoned lock
        // should fail the test loudly.
        let q = Mutex::new(vec![1]);
        assert_eq!(*q.lock().unwrap(), vec![1]);
    }
}
