//! Timing helpers.

use std::time::{Duration, Instant};

/// A simple stopwatch. `Stopwatch::start()` then `elapsed()`/`lap()`.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
}

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, last: now }
    }

    /// Total elapsed time since `start()`.
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    /// Time since the previous `lap()` (or since start for the first lap).
    pub fn lap(&mut self) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        d
    }

    /// Elapsed seconds as f64 (convenience for metrics/CSV).
    pub fn secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Format a duration compactly for human-readable tables:
/// `1.234s`, `56.7ms`, `890us`.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{:.0}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotonic() {
        let mut sw = Stopwatch::start();
        let l1 = sw.lap();
        let l2 = sw.lap();
        assert!(l1 >= Duration::ZERO && l2 >= Duration::ZERO);
        assert!(sw.elapsed() >= l1);
    }

    #[test]
    fn fmt_duration_units() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000s");
        assert_eq!(fmt_duration(Duration::from_millis(56)), "56.0ms");
        assert_eq!(fmt_duration(Duration::from_micros(890)), "890us");
    }
}
