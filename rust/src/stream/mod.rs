//! Streaming micro-batch mining: sliding-window incremental RDD-Eclat.
//!
//! The paper motivates Spark because FIM is highly iterative and re-runs
//! over fresh data; this subsystem makes that literal — a DStream-style
//! execution mode where transactions arrive as micro-batches and every
//! window emission publishes a live frequent-itemset + association-rule
//! snapshot:
//!
//! * [`source`] — micro-batch producers: replay any [`crate::data::Database`],
//!   or generate a (drifting) clickstream lazily, optionally paced in
//!   wall time.
//! * [`window`] — tumbling/sliding windows measured in batches, with
//!   global tid-range bookkeeping per batch.
//! * [`incremental`] — the maintained per-item vertical bitmap store:
//!   append tids at the tail, mask evicted tid ranges, track dirty
//!   items, compact when the dead prefix outgrows the window.
//! * [`sharded`] — the item-sharded wrapper over N incremental stores
//!   in one tid space, routed by the EclatV5 reverse-hash partitioner;
//!   append/evict/compact and mining parallelize per shard
//!   (`StreamConfig::shards`, `repro stream --shards N`).
//! * [`job`] — the per-batch driver: re-mines only the dirty
//!   sub-lattice on the engine's executor pool (full-re-mine fallback
//!   under churn), reuses every cached itemset containing a clean item,
//!   and emits [`BatchSnapshot`]s.
//! * [`ingest`] — the async service: [`StreamService::push_batch`]
//!   enqueues and returns immediately, a dedicated mining loop keeps
//!   bookkeeping window-exact, and under backpressure emissions
//!   coalesce skip-to-latest.
//! * [`serve`] — snapshot serving: each emission is published through a
//!   double-buffered [`SnapshotHandle`] (lock-free reads) with prebuilt
//!   support and antecedent→rules indices ([`ServingSnapshot`]).
//!
//! ```
//! use rdd_eclat::engine::ClusterContext;
//! use rdd_eclat::fim::MinSup;
//! use rdd_eclat::stream::{StreamConfig, StreamingMiner, WindowSpec};
//!
//! let ctx = ClusterContext::builder().cores(2).build();
//! let cfg = StreamConfig::new(WindowSpec::sliding(3, 1), MinSup::count(2));
//! let mut miner = StreamingMiner::new(ctx, cfg);
//! let mut last = None;
//! for batch in [
//!     vec![vec![1, 2, 3], vec![1, 2]],
//!     vec![vec![2, 3], vec![1, 2]],
//!     vec![vec![1, 2, 3]],
//! ] {
//!     if let Some(snapshot) = miner.push_batch(batch).unwrap() {
//!         last = Some(snapshot);
//!     }
//! }
//! assert!(last.unwrap().frequents.iter().any(|f| f.items == vec![1, 2]));
//! ```

pub mod incremental;
pub mod ingest;
pub mod job;
pub mod serve;
pub mod sharded;
pub mod source;
pub mod window;

pub use incremental::IncrementalVerticalDb;
pub use ingest::{Ingest, IngestConfig, IngestStats, StreamService};
pub use job::{BatchSnapshot, MineMode, MinePlan, ShardStats, StreamConfig, StreamingMiner};
pub use serve::{snapshot_pipe, ServingSnapshot, SnapshotHandle, SnapshotPublisher};
pub use sharded::{ShardLoad, ShardedVerticalDb};
pub use source::{BatchSource, ClickstreamSource, Paced, ReplaySource};
pub use window::{Batch, PushResult, SlidingWindow, WindowSpec};
