//! Sequential FP-Growth (Han et al.) — the third classical miner the
//! paper's related work surveys. Used here as an independent cross-oracle
//! for correctness testing and as an extra baseline in the benches.

use std::collections::HashMap;

use super::itemset::{Frequent, Item};
use super::transaction::Database;

#[derive(Debug)]
struct Node {
    item: Item,
    count: u32,
    parent: usize,
    children: HashMap<Item, usize>,
}

/// An FP-tree with a header table of per-item node lists.
struct FpTree {
    nodes: Vec<Node>,
    header: HashMap<Item, Vec<usize>>,
}

impl FpTree {
    fn new() -> FpTree {
        FpTree {
            nodes: vec![Node { item: u32::MAX, count: 0, parent: usize::MAX, children: HashMap::new() }],
            header: HashMap::new(),
        }
    }

    fn insert(&mut self, items: &[Item], count: u32) {
        let mut cur = 0usize;
        for &item in items {
            let next = match self.nodes[cur].children.get(&item) {
                Some(&n) => {
                    self.nodes[n].count += count;
                    n
                }
                None => {
                    let n = self.nodes.len();
                    self.nodes.push(Node { item, count, parent: cur, children: HashMap::new() });
                    self.nodes[cur].children.insert(item, n);
                    self.header.entry(item).or_default().push(n);
                    n
                }
            };
            cur = next;
        }
    }

    /// Conditional pattern base of `item`: (prefix path, count) pairs.
    fn pattern_base(&self, item: Item) -> Vec<(Vec<Item>, u32)> {
        let mut out = Vec::new();
        if let Some(nodes) = self.header.get(&item) {
            for &n in nodes {
                let count = self.nodes[n].count;
                let mut path = Vec::new();
                let mut cur = self.nodes[n].parent;
                while cur != 0 && cur != usize::MAX {
                    path.push(self.nodes[cur].item);
                    cur = self.nodes[cur].parent;
                }
                path.reverse();
                if !path.is_empty() {
                    out.push((path, count));
                }
            }
        }
        out
    }
}

/// Mine all frequent itemsets with FP-Growth.
pub fn fp_growth(db: &Database, min_sup_count: u32) -> Vec<Frequent> {
    // Global frequent items, ordered by descending support (FP order).
    let mut counts: HashMap<Item, u32> = HashMap::new();
    for t in db.transactions() {
        for &i in t {
            *counts.entry(i).or_default() += 1;
        }
    }
    let weighted: Vec<(Vec<Item>, u32)> = db
        .transactions()
        .iter()
        .map(|t| (t.clone(), 1))
        .collect();
    let mut out = Vec::new();
    mine(&weighted, &counts, min_sup_count, &[], &mut out);
    out
}

/// Recursive FP-Growth over a weighted (conditional) database.
fn mine(
    weighted: &[(Vec<Item>, u32)],
    counts: &HashMap<Item, u32>,
    min_sup: u32,
    suffix: &[Item],
    out: &mut Vec<Frequent>,
) {
    // Frequent items of this conditional DB, descending count (ties by id).
    let mut freq: Vec<(Item, u32)> = counts
        .iter()
        .filter(|(_, &c)| c >= min_sup)
        .map(|(&i, &c)| (i, c))
        .collect();
    freq.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
    if freq.is_empty() {
        return;
    }
    let order: HashMap<Item, usize> = freq.iter().enumerate().map(|(r, (i, _))| (*i, r)).collect();

    // Build the tree with items in FP order.
    let mut tree = FpTree::new();
    for (t, w) in weighted {
        let mut proj: Vec<Item> = t.iter().copied().filter(|i| order.contains_key(i)).collect();
        proj.sort_by_key(|i| order[i]);
        if !proj.is_empty() {
            tree.insert(&proj, *w);
        }
    }

    // For each frequent item (bottom of the order first is conventional;
    // any order is correct), emit suffix∪{item} and recurse on its
    // conditional pattern base.
    for (item, count) in freq.iter().rev() {
        let mut items = suffix.to_vec();
        items.push(*item);
        items.sort_unstable();
        out.push(Frequent::new(items.clone(), *count));

        let base = tree.pattern_base(*item);
        if base.is_empty() {
            continue;
        }
        let mut cond_counts: HashMap<Item, u32> = HashMap::new();
        for (path, w) in &base {
            for &i in path {
                *cond_counts.entry(i).or_default() += w;
            }
        }
        let mut new_suffix = suffix.to_vec();
        new_suffix.push(*item);
        mine(&base, &cond_counts, min_sup, &new_suffix, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::apriori::apriori;
    use crate::fim::itemset::sort_frequents;
    use crate::util::prng::Rng;

    fn demo_db() -> Database {
        Database::from_rows(vec![
            vec![1, 3, 4],
            vec![2, 3, 5],
            vec![1, 2, 3, 5],
            vec![2, 5],
            vec![1, 3, 5],
            vec![2, 3, 5],
        ])
    }

    #[test]
    fn agrees_with_apriori_on_demo() {
        for min_sup in 1..=6 {
            let mut a = apriori(&demo_db(), min_sup);
            let mut f = fp_growth(&demo_db(), min_sup);
            sort_frequents(&mut a);
            sort_frequents(&mut f);
            assert_eq!(a, f, "min_sup={min_sup}");
        }
    }

    #[test]
    fn agrees_with_apriori_on_random_dbs() {
        let mut rng = Rng::new(31);
        for case in 0..20 {
            let n_items = rng.range(3, 12) as u32;
            let n_txns = rng.range(5, 40);
            let rows: Vec<Vec<Item>> = (0..n_txns)
                .map(|_| {
                    (0..n_items).filter(|_| rng.chance(0.4)).collect()
                })
                .filter(|t: &Vec<Item>| !t.is_empty())
                .collect();
            let db = Database::from_rows(rows);
            let min_sup = rng.range(1, 5) as u32;
            let mut a = apriori(&db, min_sup);
            let mut f = fp_growth(&db, min_sup);
            sort_frequents(&mut a);
            sort_frequents(&mut f);
            assert_eq!(a, f, "case {case} min_sup={min_sup}");
        }
    }

    #[test]
    fn empty_db() {
        let db = Database::from_rows(vec![]);
        assert!(fp_growth(&db, 1).is_empty());
    }
}
