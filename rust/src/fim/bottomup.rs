//! The bottom-up recursive search of Eclat (the paper's Algorithm 1,
//! after Zaki).
//!
//! Generic over the tidset representation: the paper's sorted-vector
//! tidsets ([`Tidset`]) or packed bitmaps ([`TidBitmap`]) — the
//! performance ablation of DESIGN.md §9. A diffset (dEclat) variant is
//! provided as the paper's "future directions" extension.

use super::bitmap::TidBitmap;
use super::itemset::{Frequent, Item};
use super::tidset::{difference, intersect, Tidset};

/// A tidset representation usable by the bottom-up search.
pub trait TidRepr: Clone + Send + Sync + 'static {
    /// Support = number of transactions represented.
    fn support(&self) -> u32;
    /// Set intersection.
    fn intersect_with(&self, other: &Self) -> Self;
    /// Fused intersection + support count (§Perf iteration 3: one pass
    /// instead of intersect-then-recount).
    fn intersect_counted(&self, other: &Self) -> (Self, u32) {
        let out = self.intersect_with(other);
        let n = out.support();
        (out, n)
    }
}

impl TidRepr for Tidset {
    fn support(&self) -> u32 {
        self.len() as u32
    }
    fn intersect_with(&self, other: &Self) -> Self {
        intersect(self, other)
    }
    fn intersect_counted(&self, other: &Self) -> (Self, u32) {
        let out = intersect(self, other);
        let n = out.len() as u32;
        (out, n)
    }
}

impl TidRepr for TidBitmap {
    fn support(&self) -> u32 {
        self.count()
    }
    fn intersect_with(&self, other: &Self) -> Self {
        self.and(other)
    }
    fn intersect_counted(&self, other: &Self) -> (Self, u32) {
        self.and_counted(other)
    }
}

fn emit(prefix: &[Item], item: Item, support: u32, out: &mut Vec<Frequent>) {
    let mut items = Vec::with_capacity(prefix.len() + 1);
    items.extend_from_slice(prefix);
    items.push(item);
    items.sort_unstable();
    out.push(Frequent::new(items, support));
}

/// Bottom-Up(EC) — Algorithm 1. `prefix` is the class prefix itemset,
/// `members` the class atoms: `(last item, tidset(prefix ∪ item))`, each
/// already frequent. Emits every member itemset and recurses into the
/// next-level classes. Members are processed in the order given (the
/// ascending-support "total order" established in Phase-1).
pub fn bottom_up<R: TidRepr>(
    prefix: &[Item],
    members: &[(Item, R)],
    min_sup: u32,
    out: &mut Vec<Frequent>,
) {
    // Count each atom once up front; the recursion below carries supports
    // alongside tidsets so nothing is ever re-counted (§Perf iteration 3).
    let counted: Vec<(Item, R, u32)> =
        members.iter().map(|(i, t)| (*i, t.clone(), t.support())).collect();
    bottom_up_counted(prefix, &counted, min_sup, out);
}

fn bottom_up_counted<R: TidRepr>(
    prefix: &[Item],
    members: &[(Item, R, u32)],
    min_sup: u32,
    out: &mut Vec<Frequent>,
) {
    for (item, _, support) in members {
        emit(prefix, *item, *support, out);
    }
    if members.len() < 2 {
        return;
    }
    let mut child_prefix = Vec::with_capacity(prefix.len() + 1);
    for i in 0..members.len() - 1 {
        let (item_i, tids_i, _) = &members[i];
        let mut next: Vec<(Item, R, u32)> = Vec::new();
        for (item_j, tids_j, _) in &members[i + 1..] {
            let (tids_ij, count) = tids_i.intersect_counted(tids_j);
            if count >= min_sup {
                next.push((*item_j, tids_ij, count));
            }
        }
        if !next.is_empty() {
            child_prefix.clear();
            child_prefix.extend_from_slice(prefix);
            child_prefix.push(*item_i);
            bottom_up_counted(&child_prefix, &next, min_sup, out);
        }
    }
}

/// dEclat: the diffset-based bottom-up search (Zaki's follow-up — the
/// paper's related work cites it via Peclat's mixsets; here it is the
/// ablation extension). Entry takes *tidsets*; the first join converts to
/// diffsets (`d(ab) = t(a) − t(b)`, `σ(ab) = σ(a) − |d(ab)|`), deeper
/// levels stay in diffset space (`d(Pab) = d(Pb) − d(Pa)`).
pub fn bottom_up_diffset(
    prefix: &[Item],
    members: &[(Item, Tidset)],
    min_sup: u32,
    out: &mut Vec<Frequent>,
) {
    for (item, tids) in members {
        emit(prefix, *item, tids.len() as u32, out);
    }
    if members.len() < 2 {
        return;
    }
    for i in 0..members.len() - 1 {
        let (item_i, tids_i) = &members[i];
        let sup_i = tids_i.len() as u32;
        let mut next: Vec<(Item, Tidset, u32)> = Vec::new();
        for (item_j, tids_j) in &members[i + 1..] {
            let diff = difference(tids_i, tids_j);
            let support = sup_i - diff.len() as u32;
            if support >= min_sup {
                next.push((*item_j, diff, support));
            }
        }
        if !next.is_empty() {
            let mut child_prefix = prefix.to_vec();
            child_prefix.push(*item_i);
            diffset_recurse(&child_prefix, &next, min_sup, out);
        }
    }
}

fn diffset_recurse(
    prefix: &[Item],
    members: &[(Item, Tidset, u32)],
    min_sup: u32,
    out: &mut Vec<Frequent>,
) {
    for (item, _, support) in members {
        emit(prefix, *item, *support, out);
    }
    if members.len() < 2 {
        return;
    }
    for i in 0..members.len() - 1 {
        let (item_i, diff_i, sup_i) = &members[i];
        let mut next: Vec<(Item, Tidset, u32)> = Vec::new();
        for (item_j, diff_j, _) in &members[i + 1..] {
            // d(Pab) = d(Pb) − d(Pa); σ(Pab) = σ(Pa) − |d(Pab)|.
            let diff = difference(diff_j, diff_i);
            let support = sup_i - diff.len() as u32;
            if support >= min_sup {
                next.push((*item_j, diff, support));
            }
        }
        if !next.is_empty() {
            let mut child_prefix = prefix.to_vec();
            child_prefix.push(*item_i);
            diffset_recurse(&child_prefix, &next, min_sup, out);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fim::itemset::sort_frequents;

    /// Zaki's running example: items 1..5 over 6 transactions.
    fn example_members() -> Vec<(Item, Tidset)> {
        // t(1)={0,2,3}, t(2)={1,2,3,4,5}, t(3)={0,1,2,3,4,5}
        vec![
            (1, vec![0, 2, 3]),
            (2, vec![1, 2, 3, 4, 5]),
            (3, vec![0, 1, 2, 3, 4, 5]),
        ]
    }

    #[test]
    fn bottom_up_enumerates_class() {
        let mut out = Vec::new();
        bottom_up::<Tidset>(&[], &example_members(), 2, &mut out);
        sort_frequents(&mut out);
        let got: Vec<(Vec<Item>, u32)> =
            out.into_iter().map(|f| (f.items, f.support)).collect();
        assert_eq!(
            got,
            vec![
                (vec![1], 3),
                (vec![2], 5),
                (vec![3], 6),
                (vec![1, 2], 2),
                (vec![1, 3], 3),
                (vec![2, 3], 5),
                (vec![1, 2, 3], 2),
            ]
        );
    }

    #[test]
    fn min_sup_prunes_recursion() {
        let mut out = Vec::new();
        bottom_up::<Tidset>(&[], &example_members(), 3, &mut out);
        assert!(out.iter().all(|f| f.support >= 3));
        assert!(!out.iter().any(|f| f.items == vec![1, 2]));
        assert!(!out.iter().any(|f| f.items == vec![1, 2, 3]));
        assert!(out.iter().any(|f| f.items == vec![1, 3] && f.support == 3));
    }

    #[test]
    fn bitmap_repr_agrees_with_tidset_repr() {
        let members = example_members();
        let bitmap_members: Vec<(Item, TidBitmap)> = members
            .iter()
            .map(|(i, t)| (*i, TidBitmap::from_tids(6, t.iter().copied())))
            .collect();
        for min_sup in 1..=6 {
            let mut a = Vec::new();
            bottom_up::<Tidset>(&[], &members, min_sup, &mut a);
            let mut b = Vec::new();
            bottom_up::<TidBitmap>(&[], &bitmap_members, min_sup, &mut b);
            sort_frequents(&mut a);
            sort_frequents(&mut b);
            assert_eq!(a, b, "min_sup={min_sup}");
        }
    }

    #[test]
    fn diffset_variant_agrees() {
        let members = example_members();
        for min_sup in 1..=6 {
            let mut a = Vec::new();
            bottom_up::<Tidset>(&[], &members, min_sup, &mut a);
            let mut b = Vec::new();
            bottom_up_diffset(&[], &members, min_sup, &mut b);
            sort_frequents(&mut a);
            sort_frequents(&mut b);
            assert_eq!(a, b, "min_sup={min_sup}");
        }
    }

    #[test]
    fn emit_sorts_itemsets_with_unsorted_mining_order() {
        // Mining order by ascending support can put a larger item id first.
        let members: Vec<(Item, Tidset)> = vec![(9, vec![0, 1]), (2, vec![0, 1, 2])];
        let mut out = Vec::new();
        bottom_up::<Tidset>(&[], &members, 2, &mut out);
        assert!(out.iter().any(|f| f.items == vec![2, 9] && f.support == 2));
    }

    #[test]
    fn empty_and_singleton_members() {
        let mut out = Vec::new();
        bottom_up::<Tidset>(&[], &[], 1, &mut out);
        assert!(out.is_empty());
        bottom_up::<Tidset>(&[5], &[(7, vec![0])], 1, &mut out);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].items, vec![5, 7]);
    }
}
