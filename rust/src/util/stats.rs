//! Summary statistics used by the benchmark harness and the load-balance
//! ablations.

/// Summary statistics of a sample of f64 observations.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Number of observations.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Sample standard deviation (n-1 denominator; 0 when n < 2).
    pub std_dev: f64,
    /// Minimum observation.
    pub min: f64,
    /// Maximum observation.
    pub max: f64,
    /// Median (average of middle two when n is even).
    pub median: f64,
    /// 95th percentile (nearest-rank).
    pub p95: f64,
}

impl Summary {
    /// Compute summary statistics; returns a zeroed summary for an empty
    /// sample.
    pub fn of(samples: &[f64]) -> Summary {
        if samples.is_empty() {
            return Summary { n: 0, mean: 0.0, std_dev: 0.0, min: 0.0, max: 0.0, median: 0.0, p95: 0.0 };
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            (sorted[n / 2 - 1] + sorted[n / 2]) / 2.0
        };
        let p95 = sorted[((n as f64 * 0.95).ceil() as usize).clamp(1, n) - 1];
        Summary {
            n,
            mean,
            std_dev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median,
            p95,
        }
    }

    /// Coefficient of variation (std dev / mean); 0 when mean is 0.
    /// Used to quantify partition-load imbalance in the A2 ablation.
    pub fn cv(&self) -> f64 {
        if self.mean.abs() < f64::EPSILON {
            0.0
        } else {
            self.std_dev / self.mean
        }
    }
}

/// Relative imbalance of a set of integer loads: `max/mean`. 1.0 is a
/// perfectly balanced partitioning; the paper's §4.5 workload-balance
/// heuristic aims to push this toward 1.
pub fn imbalance(loads: &[usize]) -> f64 {
    if loads.is_empty() {
        return 1.0;
    }
    let max = *loads.iter().max().unwrap() as f64;
    let mean = loads.iter().sum::<usize>() as f64 / loads.len() as f64;
    if mean == 0.0 {
        1.0
    } else {
        max / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.std_dev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_and_single() {
        let e = Summary::of(&[]);
        assert_eq!(e.n, 0);
        let s = Summary::of(&[7.0]);
        assert_eq!(s.n, 1);
        assert_eq!(s.mean, 7.0);
        assert_eq!(s.std_dev, 0.0);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn even_median_averages() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert!((s.median - 2.5).abs() < 1e-12);
    }

    #[test]
    fn imbalance_balanced_vs_skewed() {
        assert!((imbalance(&[10, 10, 10]) - 1.0).abs() < 1e-12);
        assert!(imbalance(&[30, 0, 0]) > 2.9);
        assert_eq!(imbalance(&[]), 1.0);
        assert_eq!(imbalance(&[0, 0]), 1.0);
    }

    #[test]
    fn cv_zero_mean() {
        let s = Summary::of(&[0.0, 0.0]);
        assert_eq!(s.cv(), 0.0);
    }
}
