//! Virtual-cluster makespan simulation.
//!
//! The paper's Fig. 15 measures execution time at 2–10 executor cores on a
//! 24-core workstation. This testbed has one physical core, so core
//! scaling is *simulated from real measurements*: the engine records every
//! task's wall time (see [`super::metrics`]); this module replays those
//! durations through a list scheduler at `k` virtual cores, respecting
//! stage barriers (Spark runs stages sequentially; tasks within a stage
//! run on whatever core frees up first — FIFO within a stage, which is
//! Spark's default task scheduling). Driver-side serial time (job
//! orchestration, result collection, the parts of the algorithm executed
//! in the driver like the paper's `sort(collect())`) is added unchanged —
//! it does not parallelize, which is exactly why the paper's curves
//! flatten at higher core counts (Amdahl).
//!
//! On a many-core machine the same harness runs live instead; the
//! simulation path is the documented substitution for this reproduction
//! (DESIGN.md §2.3).

use std::collections::BTreeMap;
use std::time::Duration;

use super::metrics::{JobId, TaskMetric};

/// One simulated run at a given core count.
#[derive(Debug, Clone, PartialEq)]
pub struct SimResult {
    /// Virtual executor cores.
    pub cores: usize,
    /// Simulated total execution time (serial + parallel makespan).
    pub makespan: Duration,
    /// The parallel fraction: sum of stage makespans.
    pub parallel: Duration,
    /// The serial fraction passed in (driver work).
    pub serial: Duration,
}

/// FIFO list-scheduling makespan of one stage's task durations on `cores`
/// identical workers: each task goes to the earliest-free core, in
/// submission order (Spark's behaviour within a stage).
pub fn stage_makespan(durations: &[Duration], cores: usize) -> Duration {
    let cores = cores.max(1);
    let mut free = vec![Duration::ZERO; cores];
    for d in durations {
        // Earliest-free core.
        let (idx, _) = free
            .iter()
            .enumerate()
            .min_by_key(|(_, t)| **t)
            .expect("at least one core");
        free[idx] += *d;
    }
    free.into_iter().max().unwrap_or(Duration::ZERO)
}

/// Simulate the makespan of a set of recorded tasks at `cores` virtual
/// cores. Tasks are grouped by `(job, stage)`; jobs and stages execute
/// sequentially (stage barrier), tasks within a stage in parallel.
/// `serial` is driver-side time that does not parallelize.
pub fn simulate(tasks: &[TaskMetric], cores: usize, serial: Duration) -> SimResult {
    // Group by (job, stage), preserving (job, stage) order.
    let mut stages: BTreeMap<(JobId, usize), Vec<Duration>> = BTreeMap::new();
    for t in tasks {
        stages.entry((t.job, t.stage)).or_default().push(t.wall);
    }
    let parallel: Duration = stages.values().map(|ds| stage_makespan(ds, cores)).sum();
    SimResult { cores, makespan: serial + parallel, parallel, serial }
}

/// Derive the serial (driver) fraction of a measured run: the job's wall
/// time minus the critical path of its tasks at the measured concurrency.
/// Clamped at zero. `measured_wall` is the driver-observed total time,
/// `tasks` the job's recorded tasks, `measured_cores` the pool size used.
pub fn derive_serial(tasks: &[TaskMetric], measured_wall: Duration, measured_cores: usize) -> Duration {
    let sim = simulate(tasks, measured_cores, Duration::ZERO);
    measured_wall.saturating_sub(sim.parallel)
}

/// Sweep core counts, returning one [`SimResult`] per entry in `cores`.
pub fn sweep(tasks: &[TaskMetric], cores: &[usize], serial: Duration) -> Vec<SimResult> {
    cores.iter().map(|&k| simulate(tasks, k, serial)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::metrics::StageKind;

    fn tm(job: usize, stage: usize, ms: u64) -> TaskMetric {
        TaskMetric {
            job: JobId(job),
            stage,
            kind: StageKind::Result,
            partition: 0,
            wall: Duration::from_millis(ms),
            records: 0,
        }
    }

    #[test]
    fn single_core_makespan_is_sum() {
        let ds = vec![Duration::from_millis(10), Duration::from_millis(20)];
        assert_eq!(stage_makespan(&ds, 1), Duration::from_millis(30));
    }

    #[test]
    fn infinite_cores_makespan_is_max() {
        let ds: Vec<_> = (1..=8).map(|i| Duration::from_millis(i * 10)).collect();
        assert_eq!(stage_makespan(&ds, 100), Duration::from_millis(80));
    }

    #[test]
    fn fifo_two_cores() {
        // Tasks 30,10,10,10 on 2 cores FIFO:
        // c0: 30            -> 30
        // c1: 10,10,10      -> 30
        let ds: Vec<_> = [30u64, 10, 10, 10].iter().map(|&m| Duration::from_millis(m)).collect();
        assert_eq!(stage_makespan(&ds, 2), Duration::from_millis(30));
    }

    #[test]
    fn makespan_monotonically_nonincreasing_in_cores() {
        let ds: Vec<_> = [13u64, 7, 22, 5, 9, 31, 2, 17]
            .iter()
            .map(|&m| Duration::from_millis(m))
            .collect();
        let mut last = stage_makespan(&ds, 1);
        for k in 2..=8 {
            let cur = stage_makespan(&ds, k);
            assert!(cur <= last, "k={k}: {cur:?} > {last:?}");
            last = cur;
        }
    }

    #[test]
    fn stage_barriers_respected() {
        // Two stages of one 10ms task each can never overlap: makespan 20ms
        // regardless of cores.
        let tasks = vec![tm(0, 0, 10), tm(0, 1, 10)];
        let r = simulate(&tasks, 8, Duration::ZERO);
        assert_eq!(r.makespan, Duration::from_millis(20));
    }

    #[test]
    fn serial_fraction_added() {
        let tasks = vec![tm(0, 0, 10), tm(0, 0, 10)];
        let r = simulate(&tasks, 2, Duration::from_millis(5));
        assert_eq!(r.parallel, Duration::from_millis(10));
        assert_eq!(r.makespan, Duration::from_millis(15));
    }

    #[test]
    fn derive_serial_clamps() {
        let tasks = vec![tm(0, 0, 10)];
        let s = derive_serial(&tasks, Duration::from_millis(12), 1);
        assert_eq!(s, Duration::from_millis(2));
        let s = derive_serial(&tasks, Duration::from_millis(5), 1);
        assert_eq!(s, Duration::ZERO);
    }

    #[test]
    fn sweep_shapes_like_fig15() {
        // Ten 10ms tasks in one stage + 10ms serial: classic Amdahl curve.
        let tasks: Vec<_> = (0..10).map(|_| tm(0, 0, 10)).collect();
        let results = sweep(&tasks, &[2, 4, 6, 8, 10], Duration::from_millis(10));
        let times: Vec<u64> = results.iter().map(|r| r.makespan.as_millis() as u64).collect();
        assert_eq!(times, vec![60, 40, 30, 30, 20]);
    }
}
