//! End-to-end tests for the `net` layer (PR 10): wire-format golden
//! vectors and seeded round-trips for every payload, decode hardening
//! (truncation, corruption, version skew — typed errors, no panics),
//! and loopback shard-worker runs: parity with the in-process sharded
//! twin and the from-scratch oracle, survival of worker loss, seeded
//! net chaos, and the `--workers` CLI usage contract.

#![cfg(not(loom))]

use std::thread::JoinHandle;
use std::time::Duration;

use rdd_eclat::algorithms::SeqEclat;
use rdd_eclat::data::clickstream::{generate_range, ClickParams};
use rdd_eclat::engine::{ChaosPolicy, ClusterContext};
use rdd_eclat::fim::sink::FrequentSink;
use rdd_eclat::fim::{sort_frequents, Database, Frequent, MinSup, PooledSink, TidBitmap};
use rdd_eclat::net::transport::{ApplyBatchReq, Hello, MineReq, MinedShard, WorkerShardStats};
use rdd_eclat::net::wire::crc32;
use rdd_eclat::net::{Bounds, Frame, FrameKind, RemoteShardSet, ShardWorker, Wire, VERSION};
use rdd_eclat::stream::window::Batch;
use rdd_eclat::stream::{IngestStats, ShardStats, StreamConfig, StreamingMiner, WindowSpec};
use rdd_eclat::util::prng::Rng;
use rdd_eclat::util::prop::{check, Config};

fn oracle(db: &Database, min_sup: MinSup) -> Vec<Frequent> {
    let mut v = SeqEclat::mine(db, min_sup);
    sort_frequents(&mut v);
    v
}

/// Bind `n` shard workers on loopback port 0 and serve each on its own
/// thread; returns the resolved addresses and the join handles.
fn spawn_workers(n: usize) -> (Vec<String>, Vec<JoinHandle<()>>) {
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n {
        let worker = ShardWorker::bind("127.0.0.1:0").expect("bind loopback");
        addrs.push(worker.local_addr().expect("local addr").to_string());
        handles.push(std::thread::spawn(move || worker.run().expect("worker run")));
    }
    (addrs, handles)
}

// ---------------------------------------------------------------------------
// Wire format
// ---------------------------------------------------------------------------

fn rt<T: Wire + PartialEq + std::fmt::Debug>(v: &T) -> Result<(), String> {
    let back = T::from_bytes(&v.to_bytes()).map_err(|e| format!("decode {v:?}: {e}"))?;
    if &back != v {
        return Err(format!("round-trip mismatch:\n got {back:?}\nwant {v:?}"));
    }
    Ok(())
}

fn random_bitmap(rng: &mut Rng) -> TidBitmap {
    let universe = rng.below(200) as usize;
    let mut bm = TidBitmap::new(universe);
    for _ in 0..rng.below(64) {
        if universe > 0 {
            bm.insert(rng.below(universe as u64) as u32);
        }
    }
    bm
}

fn random_sink(rng: &mut Rng) -> PooledSink {
    let mut sink = PooledSink::with_capacity(8, 4);
    for _ in 0..rng.below(12) {
        let items: Vec<u32> = (0..rng.range(0, 5)).map(|_| rng.below(100) as u32).collect();
        sink.emit(&items, rng.below(1000) as u32 + 1);
    }
    sink
}

fn random_rows(rng: &mut Rng) -> Vec<Vec<u32>> {
    (0..rng.range(0, 6))
        .map(|_| (0..rng.range(0, 5)).map(|_| rng.below(50) as u32).collect())
        .collect()
}

fn random_shard_stats(rng: &mut Rng) -> ShardStats {
    ShardStats {
        rows: rng.below(1 << 40),
        postings: rng.below(1 << 40),
        mined_itemsets: rng.below(1 << 20),
        mine_wall: Duration::from_nanos(rng.below(1 << 40)),
        age: Duration::from_micros(rng.below(1 << 30)),
    }
}

#[test]
fn every_wire_payload_round_trips_across_seeds() {
    check(Config::default().cases(60).seed(0x11E7), |rng| {
        rt(&(rng.below(u64::MAX / 2)))?;
        rt(&(rng.below(u64::MAX / 2) as u32))?;
        rt(&rng.chance(0.5))?;
        rt(&Duration::from_nanos(rng.below(1 << 50)))?;
        rt(&random_bitmap(rng))?;
        rt(&random_sink(rng))?;
        rt(&random_rows(rng))?;
        rt(&random_shard_stats(rng))?;
        rt(&IngestStats {
            batches: rng.below(1 << 30),
            emissions: rng.below(1 << 30),
            skipped: rng.below(100),
            mine_failures: rng.below(100),
            mine_retries: rng.below(100),
            degraded: rng.chance(0.2),
            shards: (0..rng.range(0, 4)).map(|_| random_shard_stats(rng)).collect(),
            age: Duration::from_millis(rng.below(1 << 30)),
        })?;
        rt(&Batch {
            id: rng.below(1 << 40),
            tid_lo: rng.below(1 << 30) as u32,
            txns: rng.range(0, 1000),
            items: (0..rng.range(0, 8)).map(|_| rng.below(50) as u32).collect(),
            rows: random_rows(rng),
        })?;
        rt(&Bounds {
            txns: rng.below(1 << 40),
            live_lo: rng.below(1 << 30) as u32,
            next: rng.below(1 << 30) as u32,
        })?;
        rt(&Hello {
            total_shards: rng.range(1, 8) as u64,
            owned: (0..rng.range(1, 4)).map(|_| rng.below(8) as u32).collect(),
        })?;
        rt(&ApplyBatchReq {
            rows: random_rows(rng),
            evictions: (0..rng.range(0, 3))
                .map(|_| {
                    let touched = (0..rng.range(0, 4)).map(|_| rng.below(50) as u32).collect();
                    (rng.below(100), touched)
                })
                .collect(),
        })?;
        rt(&MineReq {
            min_sup: rng.below(100) as u32 + 1,
            atoms: (0..rng.range(0, 5))
                .map(|_| {
                    (rng.below(50) as u32, random_bitmap(rng), rng.below(1000) as u32)
                })
                .collect(),
        })?;
        rt(&MinedShard {
            shard: rng.below(8),
            wall: Duration::from_micros(rng.below(1 << 30)),
            itemsets: rng.below(1 << 20),
            sink: random_sink(rng),
        })?;
        rt(&WorkerShardStats {
            shard: rng.below(8),
            rows: rng.below(1 << 30),
            postings: rng.below(1 << 30),
            bounds: Bounds { txns: rng.below(100), live_lo: 0, next: rng.below(100) as u32 },
        })?;
        // The frame envelope itself round-trips through encode/decode.
        let frame = Frame::from_msg(FrameKind::ApplyBatch, &random_rows(rng));
        let back = Frame::decode(&frame.encode()).map_err(|e| e.to_string())?;
        if back != frame {
            return Err("frame envelope round-trip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn golden_wire_vectors_are_pinned() {
    // The CRC-32 (IEEE, reflected) check value, and the empty string.
    assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    assert_eq!(crc32(b""), 0);
    assert_eq!(VERSION, 1);

    // Payload encodings are pinned little-endian layouts: changing any
    // of these is a wire-protocol break and must bump `VERSION`.
    assert_eq!(7u32.to_bytes(), vec![7, 0, 0, 0]);
    assert_eq!(
        vec![1u32, 258].to_bytes(),
        vec![2, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 2, 1, 0, 0]
    );
    let bounds = Bounds { txns: 3, live_lo: 1, next: 5 };
    assert_eq!(bounds.to_bytes(), vec![3, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 5, 0, 0, 0]);
    let hello = Hello { total_shards: 2, owned: vec![1] };
    assert_eq!(
        hello.to_bytes(),
        vec![2, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0]
    );
    let mut bm = TidBitmap::new(65);
    bm.insert(0);
    bm.insert(64);
    let mut want = Vec::new();
    want.extend_from_slice(&65u64.to_le_bytes()); // universe
    want.extend_from_slice(&2u64.to_le_bytes()); // word count
    want.extend_from_slice(&1u64.to_le_bytes()); // bit 0
    want.extend_from_slice(&1u64.to_le_bytes()); // bit 64
    assert_eq!(bm.to_bytes(), want);

    // The frame envelope: magic "rdec", version, kind, len, crc, body.
    let frame = Frame::from_msg(FrameKind::Hello, &hello);
    let bytes = frame.encode();
    assert_eq!(&bytes[0..4], &b"rdec"[..]);
    assert_eq!(&bytes[4..6], &VERSION.to_le_bytes()[..]);
    assert_eq!(&bytes[6..8], &1u16.to_le_bytes()[..]); // FrameKind::Hello
    assert_eq!(&bytes[8..12], &(hello.to_bytes().len() as u32).to_le_bytes()[..]);
    assert_eq!(&bytes[16..], &hello.to_bytes()[..]);
    assert_eq!(Frame::decode(&bytes).expect("golden frame decodes"), frame);
}

#[test]
fn decode_rejects_truncation_corruption_and_version_skew() {
    let req = ApplyBatchReq {
        rows: vec![vec![1, 2, 3], vec![], vec![7]],
        evictions: vec![(2, vec![1, 9])],
    };
    let bytes = Frame::from_msg(FrameKind::ApplyBatch, &req).encode();

    // Every proper prefix is a typed error, never a panic.
    for cut in 0..bytes.len() {
        assert!(Frame::decode(&bytes[..cut]).is_err(), "truncated at {cut} must fail");
    }
    // Every single-byte corruption is caught (magic/version/kind/len by
    // their own checks, everything else by the CRC).
    for i in 0..bytes.len() {
        let mut bad = bytes.clone();
        bad[i] ^= 0x20;
        assert!(Frame::decode(&bad).is_err(), "corrupt byte {i} must fail");
    }
    // A peer speaking a different wire version is named as such.
    let mut skew = bytes.clone();
    skew[4] = 2;
    let err = Frame::decode(&skew).expect_err("version skew").to_string();
    assert!(err.contains("version"), "got: {err}");

    // Body-level hardening: a length claim larger than the bytes
    // present is rejected up front, not by attempting the allocation.
    let mut huge = Vec::new();
    huge.extend_from_slice(&u64::MAX.to_le_bytes());
    let err = Vec::<u32>::from_bytes(&huge).expect_err("huge length claim").to_string();
    assert!(err.contains("sequence"), "got: {err}");
    let sane = vec![5u32, 6, 7].to_bytes();
    for cut in 0..sane.len() {
        assert!(Vec::<u32>::from_bytes(&sane[..cut]).is_err(), "body cut {cut} must fail");
    }
}

// ---------------------------------------------------------------------------
// Loopback transport
// ---------------------------------------------------------------------------

#[test]
fn loopback_two_workers_match_local_twin_and_oracle() {
    let params = ClickParams {
        sessions: 800,
        items: 40,
        avg_len: 2.5,
        skew: 0.9,
        locality: 0.5,
        radius: 6,
        drift: 40.0 / 800.0,
    };
    let min_sup = MinSup::count(3);
    let spec = WindowSpec::sliding(4, 1);
    let ctx = ClusterContext::builder().cores(2).build();
    let cfg = StreamConfig { churn_threshold: 1.0, ..StreamConfig::new(spec, min_sup).shards(2) };
    let mut local = StreamingMiner::new(ctx.clone(), cfg.clone());
    let mut remote = StreamingMiner::new(ctx, cfg);
    let (addrs, handles) = spawn_workers(2);
    remote.attach_remote(RemoteShardSet::connect(&addrs).expect("connect workers"));

    let (batch_size, n_batches) = (30, 14);
    for b in 0..n_batches {
        let rows = generate_range(&params, 99, b * batch_size, batch_size);
        let want = local.push_batch(rows.clone()).expect("local push").expect("slide 1 emits");
        let got = remote.push_batch(rows).expect("remote push").expect("slide 1 emits");
        assert_eq!(got.frequents, want.frequents, "batch {b}: remote vs in-process twin");
        assert_eq!(got.rules, want.rules, "batch {b}: rules diverged");
        let exact = oracle(&remote.materialize_window(), min_sup);
        assert_eq!(got.frequents, exact, "batch {b}: remote vs oracle, plan {:?}", got.plan);
    }

    let set = remote.remote_mut().expect("attached");
    assert!(set.all_live(), "clean run must not lose a worker");
    let net = set.net_stats();
    assert_eq!(net.workers_lost, 0);
    assert!(net.rpcs > 0, "remote mining must actually issue RPCs");
    let stats = set.worker_stats().expect("worker stats");
    assert_eq!(stats.len(), 2, "one shard per worker");
    assert!(stats.iter().map(|s| s.postings).sum::<u64>() > 0, "replicas ingested postings");
    let bounds = stats[0].bounds;
    assert!(stats.iter().all(|s| s.bounds == bounds), "replicas share one tid space");
    set.shutdown();
    for h in handles {
        h.join().expect("worker thread exits after Shutdown");
    }
}

#[test]
fn worker_loss_degrades_to_local_mining_and_stays_window_exact() {
    let min_sup = MinSup::count(2);
    let ctx = ClusterContext::builder().cores(2).build();
    let cfg = StreamConfig::new(WindowSpec::sliding(3, 1), min_sup).shards(2);
    let mut miner = StreamingMiner::new(ctx, cfg);
    let (addrs, handles) = spawn_workers(2);
    miner.attach_remote(RemoteShardSet::connect(&addrs).expect("connect workers"));
    let batch = |step: u32| -> Vec<Vec<u32>> {
        (0..4u32).map(|r| vec![step % 5, (step + r) % 5, 5 + (r % 2)]).collect()
    };
    for step in 0..4u32 {
        let snap = miner.push_batch(batch(step)).expect("push").expect("slide 1 emits");
        assert_eq!(snap.frequents, oracle(&miner.materialize_window(), min_sup), "step {step}");
    }
    assert!(miner.remote().expect("attached").all_live());

    // Drain worker 1 only: the next broadcast discovers the dead
    // endpoint (retry → bounds probe → mark lost) and mining degrades
    // to the always-exact local mirror without skipping an emission.
    miner.remote_mut().expect("attached").shutdown_worker(1);
    for step in 4..9u32 {
        let snap = miner.push_batch(batch(step)).expect("push").expect("slide 1 emits");
        assert_eq!(snap.frequents, oracle(&miner.materialize_window(), min_sup), "step {step}");
    }
    let set = miner.remote_mut().expect("attached");
    let net = set.net_stats();
    assert_eq!(net.workers_lost, 1, "exactly the drained worker is lost");
    assert!(net.retries >= 1, "loss must be discovered via the retry path");
    assert!(!set.all_live());
    set.shutdown();
    for h in handles {
        h.join().expect("worker thread exits");
    }
}

#[test]
fn seeded_net_chaos_keeps_parity_without_losing_workers() {
    let min_sup = MinSup::count(2);
    let ctx = ClusterContext::builder().cores(2).build();
    let cfg = StreamConfig::new(WindowSpec::sliding(3, 1), min_sup).shards(2);
    let mut miner = StreamingMiner::new(ctx, cfg);
    let (addrs, handles) = spawn_workers(2);
    let chaos = ChaosPolicy::new(0x0CEA).conn_drops(0.5).reply_corruption(0.5);
    miner.attach_remote(
        RemoteShardSet::connect(&addrs).expect("connect workers").with_chaos(Some(&chaos)),
    );
    for step in 0..10u32 {
        let rows: Vec<Vec<u32>> =
            (0..3u32).map(|r| vec![step % 4, (step + r) % 6, 9]).collect();
        let snap = miner.push_batch(rows).expect("push").expect("slide 1 emits");
        assert_eq!(snap.frequents, oracle(&miner.materialize_window(), min_sup), "step {step}");
    }
    let set = miner.remote_mut().expect("attached");
    let net = set.net_stats();
    assert!(net.retries > 0, "p=0.5 faults over dozens of RPCs must fire at least once");
    assert_eq!(net.workers_lost, 0, "single-retry recovery absorbs every injected fault");
    assert!(set.all_live());
    set.shutdown();
    for h in handles {
        h.join().expect("worker thread exits");
    }
}

// ---------------------------------------------------------------------------
// CLI contract
// ---------------------------------------------------------------------------

fn run_repro(args: &[&str]) -> std::process::Output {
    std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(args)
        .output()
        .expect("run repro binary")
}

#[test]
fn stream_workers_flag_usage_errors() {
    // Malformed worker address: rejected before anything connects.
    let out = run_repro(&["stream", "--workers", "nohost", "--batches", "1"]);
    assert_eq!(out.status.code(), Some(2), "malformed address is a usage error");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("host:port"), "stderr: {stderr}");

    // One shard per worker: the worker list fixes the shard count.
    let out = run_repro(&["stream", "--workers", "127.0.0.1:9", "--shards", "2"]);
    assert_eq!(out.status.code(), Some(2), "--workers with --shards is a usage error");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("mutually exclusive"), "stderr: {stderr}");
}

#[test]
fn shard_worker_requires_listen_address() {
    let out = run_repro(&["shard-worker"]);
    assert_eq!(out.status.code(), Some(2), "--listen is required");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("--listen"), "stderr: {stderr}");
}
