//! Streaming micro-benchmark: steady-state per-batch mining cost of the
//! sliding-window clickstream workload, incremental vs from-scratch.
//!
//! Both modes consume the same pre-generated drifting clickstream. The
//! window is filled outside measurement; each sample then ingests one
//! micro-batch (slide 1), so the measured unit is exactly "one window
//! emission". The `stream/ingest/{sync,async}_push` rows additionally
//! compare the producer-visible per-batch cost of the synchronous
//! `push_batch` (mines inline) against the async `StreamService`
//! (enqueue-and-return; mining overlaps on the service thread), and the
//! `stream/remote/*` rows price mining on two loopback-TCP shard
//! workers against the in-process 2-shard twin. Besides
//! the CSV under `results/`, the run emits the perf-trajectory file
//! `BENCH_stream.json` at the repository root (override with
//! `BENCH_STREAM_OUT`). Reproduce with:
//!
//! ```text
//! cargo bench --bench stream_micro       # SCALE=quick for a fast pass
//! ```

use rdd_eclat::bench::{black_box, Bench, Report};
use rdd_eclat::data::clickstream::{generate_range, ClickParams};
use rdd_eclat::engine::ClusterContext;
use rdd_eclat::fim::MinSup;
use rdd_eclat::net::{RemoteShardSet, ShardWorker};
use rdd_eclat::stream::{
    IngestConfig, MineMode, StreamConfig, StreamService, StreamingMiner, WindowSpec,
};

struct Workload {
    batch: usize,
    window: usize,
    min_sup: u32,
}

fn main() {
    let bench = Bench::from_env();
    let scale = std::env::var("SCALE").unwrap_or_else(|_| "paper".to_string());
    let w = if scale == "quick" {
        Workload { batch: 100, window: 10, min_sup: 8 }
    } else {
        Workload { batch: 250, window: 40, min_sup: 30 }
    };
    // Per-mode batch budget: window fill + warmup + samples + slack.
    let per_mode = w.window + bench.warmup + bench.samples + 4;
    let params = ClickParams { sessions: per_mode * w.batch, ..ClickParams::drift() };
    let batches: Vec<Vec<Vec<u32>>> = (0..per_mode)
        .map(|b| generate_range(&params, 2024, b * w.batch, w.batch))
        .collect();
    println!(
        "sliding clickstream: {} txns/batch, window {} batches, min_sup {} ({} items)",
        w.batch, w.window, w.min_sup, params.items
    );

    let mut report = Report::new();
    let mut final_counts = Vec::new();
    for (mode, name) in [
        (MineMode::Incremental, "stream/incremental/per_batch"),
        (MineMode::FromScratch, "stream/from_scratch/per_batch"),
    ] {
        let ctx = ClusterContext::builder().build();
        let cfg = StreamConfig::new(
            WindowSpec::sliding(w.window, 1),
            MinSup::count(w.min_sup),
        )
        .mode(mode)
        .min_conf(0.9);
        let mut miner = StreamingMiner::new(ctx, cfg);
        // Fill the window outside measurement so every sample sees the
        // steady state: full window, one batch in, one batch out.
        let mut feed = batches.iter().cloned();
        for _ in 0..w.window {
            let _ = miner.push_batch(feed.next().expect("fill batches")).expect("push");
        }
        let mut last_len = 0usize;
        report.add(bench.run(name, || {
            let batch = feed.next().expect("measured batches pre-generated");
            let snap = miner.push_batch(batch).expect("push").expect("slide 1 emits every batch");
            last_len = snap.frequents.len();
            black_box(snap.frequents.len())
        }));
        final_counts.push((name, miner.window_txns(), last_len));
    }

    // Both modes consumed the identical stream prefix; their final
    // windows — and therefore itemset counts — must agree.
    assert_eq!(final_counts[0].1, final_counts[1].1, "window sizes diverged");
    assert_eq!(
        final_counts[0].2, final_counts[1].2,
        "incremental and from-scratch disagree on the final window"
    );
    let speedup = report.rows()[1].mean() / report.rows()[0].mean().max(1e-12);
    println!("\nincremental speedup over from-scratch: {speedup:.2}x per batch");

    // Async vs sync ingest: the producer-visible per-batch cost. The
    // sync path mines inline inside push_batch; the async service
    // enqueues and returns immediately, mining on its own thread (with
    // skip-to-latest coalescing under backpressure), so the producer
    // pays queue handoff only.
    let ingest_cfg =
        StreamConfig::new(WindowSpec::sliding(w.window, 1), MinSup::count(w.min_sup));
    {
        let mut miner =
            StreamingMiner::new(ClusterContext::builder().build(), ingest_cfg.clone());
        let mut feed = batches.iter().cloned();
        for _ in 0..w.window {
            let _ = miner.push_batch(feed.next().expect("fill batches")).expect("push");
        }
        report.add(bench.run("stream/ingest/sync_push", || {
            let batch = feed.next().expect("measured batches pre-generated");
            black_box(miner.push_batch(batch).expect("push").is_some())
        }));
    }
    let async_final = {
        let service = StreamService::spawn(
            StreamingMiner::new(ClusterContext::builder().build(), ingest_cfg),
            IngestConfig::new(4),
        );
        let mut feed = batches.iter().cloned();
        for _ in 0..w.window {
            service.push_batch(feed.next().expect("fill batches")).expect("push");
        }
        service.drain().expect("drain window fill");
        report.add(bench.run("stream/ingest/async_push", || {
            let batch = feed.next().expect("measured batches pre-generated");
            black_box(service.push_batch(batch).expect("push"))
        }));
        // Settle the queue: the served snapshot must cover the final
        // window exactly even if emissions coalesced mid-measurement.
        let snap = service.drain().expect("drain").expect("slide 1 emitted");
        let stats = service.stats();
        let miner = service.shutdown().expect("shutdown");
        assert_eq!(
            snap.window_txns,
            miner.window_txns(),
            "served snapshot does not cover the final window"
        );
        println!(
            "async service: {} emissions, {} skipped under backpressure",
            stats.emissions, stats.skipped
        );
        snap.window_txns
    };
    assert_eq!(async_final, final_counts[0].1, "async window diverged from sync modes");
    let ingest_speedup = report.rows()[2].mean() / report.rows()[3].mean().max(1e-12);
    println!("async ingest producer-side speedup over sync: {ingest_speedup:.0}x per push\n");

    // Sharded store sweep: per-emission cost of the same steady-state
    // workload with the store/mining sharded 1..8 ways. 1 shard is the
    // classic path; the others scatter-gather over the engine pool.
    let sharded_base = report.rows().len();
    let mut sharded_finals = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        let cfg = StreamConfig::new(WindowSpec::sliding(w.window, 1), MinSup::count(w.min_sup))
            .shards(shards);
        let mut miner = StreamingMiner::new(ClusterContext::builder().build(), cfg);
        let mut feed = batches.iter().cloned();
        for _ in 0..w.window {
            let _ = miner.push_batch(feed.next().expect("fill batches")).expect("push");
        }
        let mut last_len = 0usize;
        report.add(bench.run(format!("stream/sharded/{shards}shard_emission"), || {
            let batch = feed.next().expect("measured batches pre-generated");
            let snap = miner.push_batch(batch).expect("push").expect("slide 1 emits every batch");
            last_len = snap.frequents.len();
            black_box(last_len)
        }));
        sharded_finals.push((shards, miner.window_txns(), last_len));
    }
    // Same stream prefix at every shard count: windows and final itemset
    // counts must be shard-count invariant (and match the 1-shard row).
    for &(shards, txns, itemsets) in &sharded_finals[1..] {
        assert_eq!(txns, sharded_finals[0].1, "{shards}-shard window diverged");
        assert_eq!(itemsets, sharded_finals[0].2, "{shards}-shard mining diverged");
    }
    let one_shard = report.rows()[sharded_base].mean().max(1e-12);
    for (i, &(shards, ..)) in sharded_finals.iter().enumerate().skip(1) {
        let ratio = one_shard / report.rows()[sharded_base + i].mean().max(1e-12);
        println!("{shards}-shard emission speedup over 1-shard: {ratio:.2}x");
    }
    println!();

    // Remote shards over loopback TCP vs the in-process 2-shard twin:
    // the same steady-state emission with the shard replicas hosted by
    // two `ShardWorker`s — the measured delta is pure wire cost (frame
    // encode/decode + loopback round-trips of atoms and mined sinks).
    let remote_base = report.rows().len();
    let mut remote_finals = Vec::new();
    for remote in [false, true] {
        let cfg = StreamConfig::new(WindowSpec::sliding(w.window, 1), MinSup::count(w.min_sup))
            .shards(2);
        let mut miner = StreamingMiner::new(ClusterContext::builder().build(), cfg);
        let mut workers = Vec::new();
        if remote {
            let mut addrs = Vec::new();
            for _ in 0..2 {
                let worker = ShardWorker::bind("127.0.0.1:0").expect("bind loopback");
                addrs.push(worker.local_addr().expect("local addr").to_string());
                workers.push(std::thread::spawn(move || worker.run().expect("worker run")));
            }
            miner.attach_remote(RemoteShardSet::connect(&addrs).expect("connect workers"));
        }
        let name = if remote {
            "stream/remote/loopback_2worker_emission"
        } else {
            "stream/remote/local_2shard_emission"
        };
        let mut feed = batches.iter().cloned();
        for _ in 0..w.window {
            let _ = miner.push_batch(feed.next().expect("fill batches")).expect("push");
        }
        let mut last_len = 0usize;
        report.add(bench.run(name, || {
            let batch = feed.next().expect("measured batches pre-generated");
            let snap = miner.push_batch(batch).expect("push").expect("slide 1 emits every batch");
            last_len = snap.frequents.len();
            black_box(last_len)
        }));
        remote_finals.push((miner.window_txns(), last_len));
        if let Some(set) = miner.remote_mut() {
            assert!(set.all_live(), "bench run must not lose a worker");
            set.shutdown();
        }
        for h in workers {
            h.join().expect("worker thread exits after Shutdown");
        }
    }
    assert_eq!(remote_finals[0], remote_finals[1], "remote mining diverged from local twin");
    let wire_tax = report.rows()[remote_base + 1].mean()
        / report.rows()[remote_base].mean().max(1e-12);
    println!("loopback 2-worker emission cost vs in-process 2-shard: {wire_tax:.2}x\n");

    report.write_csv("bench_stream_micro.csv").expect("write csv");
    println!("wrote results/bench_stream_micro.csv");

    // Perf trajectory: BENCH_stream.json at the repo root (cargo runs
    // benches with the package dir as CWD, hence the `..`).
    let out = std::env::var("BENCH_STREAM_OUT").unwrap_or_else(|_| {
        format!("{}/../BENCH_stream.json", env!("CARGO_MANIFEST_DIR"))
    });
    report.write_json(&out, "stream_micro", &scale).expect("write BENCH_stream.json");
    println!("wrote {out}");
}
