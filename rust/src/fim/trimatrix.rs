//! Upper-triangular matrix of candidate-2-itemset counts.
//!
//! Zaki's recommendation (adopted by the paper's Phase-2): computing
//! frequent 2-itemsets by tidset intersection is the most expensive level,
//! so count all 2-itemset occurrences with one pass over the horizontal
//! database into a triangular matrix, then use those counts to prune
//! intersections. The matrix is indexed by *item value* (like the paper,
//! whose matrix size depends on the max item id — the reason it is
//! disabled for BMS1/BMS2), flattened row-major over `i < j`.
//!
//! The matrix is the accumulator payload in EclatV1/V2/V3's Phase-2, and
//! the object the L1 `cooc` Pallas kernel computes as `Aᵀ·A` over 0/1
//! transaction blocks (see `runtime::cooc` for the XLA-backed path).

use super::itemset::Item;

/// Upper-triangular co-occurrence count matrix over items `0..=max_item`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TriMatrix {
    n: usize,
    counts: Vec<u32>,
}

impl TriMatrix {
    /// Matrix covering items `0..=max_item`. Memory is
    /// `(n·(n−1)/2)·4` bytes for `n = max_item+1` — the paper's reason to
    /// disable it for large-vocabulary datasets.
    pub fn new(max_item: Item) -> TriMatrix {
        let n = max_item as usize + 1;
        TriMatrix { n, counts: vec![0; n * (n - 1) / 2] }
    }

    /// Number of item slots (`max_item + 1`).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Approximate heap footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.counts.len() * 4
    }

    #[inline]
    fn index(&self, i: Item, j: Item) -> usize {
        debug_assert!(i < j, "triangular index requires i < j ({i}, {j})");
        let (i, j, n) = (i as usize, j as usize, self.n);
        debug_assert!(j < n);
        // Row-major upper triangle: row i starts after rows 0..i.
        i * (2 * n - i - 1) / 2 + (j - i - 1)
    }

    /// Increment the count of pair `{i, j}` (any order, i ≠ j).
    #[inline]
    pub fn update(&mut self, a: Item, b: Item) {
        let (i, j) = if a < b { (a, b) } else { (b, a) };
        let idx = self.index(i, j);
        self.counts[idx] += 1;
    }

    /// Count every 2-combination of one (sorted, deduped) transaction —
    /// the body of the paper's Phase-2 `flatMap`.
    pub fn update_transaction(&mut self, t: &[Item]) {
        for (x, &i) in t.iter().enumerate() {
            for &j in &t[x + 1..] {
                self.update(i, j);
            }
        }
    }

    /// Add `count` occurrences of pair `{a, b}` (the bulk import path used
    /// by the XLA co-occurrence backend).
    #[inline]
    pub fn add_count(&mut self, a: Item, b: Item, count: u32) {
        if a == b || count == 0 {
            return;
        }
        let (i, j) = if a < b { (a, b) } else { (b, a) };
        let idx = self.index(i, j);
        self.counts[idx] += count;
    }

    /// Support of pair `{a, b}`.
    #[inline]
    pub fn support(&self, a: Item, b: Item) -> u32 {
        if a == b {
            return 0;
        }
        let (i, j) = if a < b { (a, b) } else { (b, a) };
        self.counts[self.index(i, j)]
    }

    /// Merge another matrix in (the accumulator's associative combine).
    pub fn merge(&mut self, other: &TriMatrix) {
        assert_eq!(self.n, other.n, "merging matrices of different size");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
    }

    /// Overwrite from a dense `n×n` co-occurrence matrix (row-major),
    /// taking the upper triangle — the import path from the XLA `cooc`
    /// artifact, whose output is the full symmetric `AᵀA`.
    pub fn from_dense_upper(n: usize, dense: &[f32]) -> TriMatrix {
        assert_eq!(dense.len(), n * n);
        let mut m = TriMatrix { n, counts: vec![0; n * (n - 1) / 2] };
        for i in 0..n {
            for j in (i + 1)..n {
                let idx = m.index(i as Item, j as Item);
                m.counts[idx] = dense[i * n + j].round() as u32;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use std::collections::HashMap;

    #[test]
    fn index_is_bijective() {
        let m = TriMatrix::new(9);
        let mut seen = std::collections::HashSet::new();
        for i in 0..10u32 {
            for j in (i + 1)..10u32 {
                assert!(seen.insert(m.index(i, j)), "collision at ({i},{j})");
            }
        }
        assert_eq!(seen.len(), 45);
        assert_eq!(*seen.iter().max().unwrap(), 44);
    }

    #[test]
    fn update_and_support_symmetric() {
        let mut m = TriMatrix::new(5);
        m.update(3, 1);
        m.update(1, 3);
        assert_eq!(m.support(1, 3), 2);
        assert_eq!(m.support(3, 1), 2);
        assert_eq!(m.support(1, 2), 0);
        assert_eq!(m.support(2, 2), 0);
    }

    #[test]
    fn transaction_update_counts_all_pairs() {
        let mut m = TriMatrix::new(4);
        m.update_transaction(&[0, 2, 4]);
        assert_eq!(m.support(0, 2), 1);
        assert_eq!(m.support(0, 4), 1);
        assert_eq!(m.support(2, 4), 1);
        assert_eq!(m.support(0, 1), 0);
    }

    #[test]
    fn merge_adds() {
        let mut a = TriMatrix::new(3);
        let mut b = TriMatrix::new(3);
        a.update(0, 1);
        b.update(0, 1);
        b.update(1, 2);
        a.merge(&b);
        assert_eq!(a.support(0, 1), 2);
        assert_eq!(a.support(1, 2), 1);
    }

    #[test]
    fn random_matches_hashmap_counts() {
        let mut rng = Rng::new(21);
        let mut m = TriMatrix::new(19);
        let mut reference: HashMap<(u32, u32), u32> = HashMap::new();
        for _ in 0..200 {
            let mut t: Vec<u32> = (0..rng.range(2, 8)).map(|_| rng.below(20) as u32).collect();
            t.sort_unstable();
            t.dedup();
            m.update_transaction(&t);
            for x in 0..t.len() {
                for y in (x + 1)..t.len() {
                    *reference.entry((t[x], t[y])).or_default() += 1;
                }
            }
        }
        for (&(i, j), &c) in &reference {
            assert_eq!(m.support(i, j), c, "pair ({i},{j})");
        }
    }

    #[test]
    fn from_dense_upper_roundtrip() {
        // Dense symmetric 3x3 with upper triangle (0,1)=2, (0,2)=1, (1,2)=3.
        let dense = vec![
            5.0, 2.0, 1.0, //
            2.0, 4.0, 3.0, //
            1.0, 3.0, 6.0,
        ];
        let m = TriMatrix::from_dense_upper(3, &dense);
        assert_eq!(m.support(0, 1), 2);
        assert_eq!(m.support(0, 2), 1);
        assert_eq!(m.support(1, 2), 3);
    }

    #[test]
    fn bytes_reflects_triangle() {
        let m = TriMatrix::new(99);
        assert_eq!(m.bytes(), 100 * 99 / 2 * 4);
    }
}
