//! Streaming correctness: at every emission, incremental window mining
//! must equal `SeqEclat` run from scratch on the materialized window
//! contents — across seeds, window geometries, slide steps (including
//! slides larger than the window, i.e. full eviction between emissions)
//! and degenerate batches (empty batches, empty transactions).

use rdd_eclat::algorithms::SeqEclat;
use rdd_eclat::data::clickstream::{generate_range, ClickParams};
use rdd_eclat::engine::ClusterContext;
use rdd_eclat::fim::{sort_frequents, Database, Frequent, MinSup};
use rdd_eclat::stream::{MineMode, MinePlan, StreamConfig, StreamingMiner, WindowSpec};
use rdd_eclat::util::prng::Rng;
use rdd_eclat::util::prop::{check, prop_assert_eq, Config};

fn oracle(db: &Database, min_sup: MinSup) -> Vec<Frequent> {
    let mut v = SeqEclat::mine(db, min_sup);
    sort_frequents(&mut v);
    v
}

fn random_batch(rng: &mut Rng, n_items: u32) -> Vec<Vec<u32>> {
    let n_rows = rng.range(0, 9); // empty batches included
    (0..n_rows)
        .map(|_| {
            // Occasionally an empty transaction.
            let width = rng.range(0, 6);
            (0..width).map(|_| rng.below(n_items as u64) as u32).collect()
        })
        .collect()
}

#[test]
fn incremental_equals_from_scratch_oracle_at_every_emission() {
    let ctx = ClusterContext::builder().cores(2).build();
    check(Config::default().cases(40).seed(0x57E0), |rng| {
        let n_items = rng.range(3, 14) as u32;
        let window = rng.range(1, 5);
        let slide = rng.range(1, window + 3); // slide > window covered
        let min_sup = if rng.chance(0.5) {
            MinSup::count(rng.range(1, 5) as u32)
        } else {
            MinSup::fraction(0.05 + rng.f64() * 0.6)
        };
        // Low churn thresholds force the delta path; high ones the full
        // re-mine path — both must agree with the oracle.
        let churn_threshold = if rng.chance(0.5) { 1.0 } else { rng.f64() };
        let cfg = StreamConfig {
            churn_threshold,
            ..StreamConfig::new(WindowSpec::sliding(window, slide), min_sup)
        };
        let mut miner = StreamingMiner::new(ctx.clone(), cfg);
        let mut emissions = 0;
        for _ in 0..rng.range(3, 20) {
            let batch = random_batch(rng, n_items);
            if let Some(snap) = miner.push_batch(batch).expect("push") {
                emissions += 1;
                let db = miner.materialize_window();
                prop_assert_eq(snap.window_txns, db.len(), "window size")?;
                let want = oracle(&db, min_sup);
                if snap.frequents != want {
                    return Err(format!(
                        "emission {emissions} (plan {:?}, window {window} slide {slide}, \
                         min_sup {min_sup:?}): got {:?} want {want:?}",
                        snap.plan, snap.frequents
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn long_sliding_run_exercises_delta_reuse_and_compaction() {
    // A drifting clickstream sliding far enough that (a) the delta path
    // actually fires with reuse, and (b) the store's dead prefix exceeds
    // the live span repeatedly (compaction). Parity is checked at every
    // emission.
    let ctx = ClusterContext::builder().cores(2).build();
    let params = ClickParams {
        sessions: 4000,
        items: 120,
        avg_len: 2.5,
        skew: 0.9,
        locality: 0.5,
        radius: 8,
        drift: 120.0 / 4000.0,
    };
    let min_sup = MinSup::count(4);
    let cfg = StreamConfig {
        // Never fall back to a full re-mine: this test wants the delta
        // path (and its cache reuse) under real churn.
        churn_threshold: 1.0,
        ..StreamConfig::new(WindowSpec::sliding(8, 1), min_sup)
    };
    let mut miner = StreamingMiner::new(ctx, cfg);
    let (batch_size, n_batches) = (50, 40);
    let mut deltas_with_reuse = 0;
    for b in 0..n_batches {
        let rows = generate_range(&params, 31, b * batch_size, batch_size);
        let snap = miner.push_batch(rows).expect("push").expect("slide 1 emits");
        let want = oracle(&miner.materialize_window(), min_sup);
        assert_eq!(snap.frequents, want, "batch {b}, plan {:?}", snap.plan);
        if let MinePlan::Delta { reused_itemsets, .. } = snap.plan {
            if reused_itemsets > 0 {
                deltas_with_reuse += 1;
            }
        }
    }
    assert!(
        deltas_with_reuse > 0,
        "the delta path with cache reuse never fired over {n_batches} batches"
    );
}

#[test]
fn modes_agree_and_are_deterministic() {
    let params = ClickParams { sessions: 1200, ..ClickParams::drift() };
    let spec = WindowSpec::sliding(4, 2);
    let min_sup = MinSup::fraction(0.02);
    let run = |mode: MineMode| {
        let ctx = ClusterContext::builder().cores(2).build();
        let mut miner =
            StreamingMiner::new(ctx, StreamConfig::new(spec, min_sup).mode(mode));
        let mut out = Vec::new();
        for b in 0..12 {
            let rows = generate_range(&params, 5, b * 100, 100);
            if let Some(snap) = miner.push_batch(rows).expect("push") {
                out.push((snap.batch_id, snap.frequents, snap.rules.len()));
            }
        }
        out
    };
    let inc = run(MineMode::Incremental);
    let scratch = run(MineMode::FromScratch);
    assert_eq!(inc.len(), 6, "12 pushes at slide 2");
    assert_eq!(inc, scratch, "modes must agree emission by emission");
    assert_eq!(inc, run(MineMode::Incremental), "runs are deterministic");
}

#[test]
fn tumbling_full_eviction_between_emissions() {
    // Tumbling geometry: every emission covers a disjoint set of batches;
    // everything from the previous window is evicted in between.
    let ctx = ClusterContext::builder().cores(2).build();
    let min_sup = MinSup::count(2);
    let mut miner = StreamingMiner::new(
        ctx,
        StreamConfig::new(WindowSpec::tumbling(2), min_sup),
    );
    let phases: [Vec<Vec<u32>>; 6] = [
        vec![vec![1, 2], vec![1, 2]],
        vec![vec![1, 2, 3]],
        vec![vec![4, 5], vec![4, 5]], // disjoint vocabulary
        vec![vec![4, 6]],
        vec![],                       // empty batches
        vec![],
    ];
    let mut snaps = Vec::new();
    for batch in phases {
        if let Some(s) = miner.push_batch(batch).expect("push") {
            let want = oracle(&miner.materialize_window(), min_sup);
            assert_eq!(s.frequents, want);
            snaps.push(s);
        }
    }
    assert_eq!(snaps.len(), 3);
    assert!(snaps[0].frequents.contains(&Frequent::new(vec![1, 2], 3)));
    assert!(snaps[1].frequents.contains(&Frequent::new(vec![4], 3)));
    assert!(
        !snaps[1].frequents.iter().any(|f| f.items.contains(&1)),
        "fully evicted items must vanish"
    );
    assert!(snaps[2].frequents.is_empty(), "empty window mines empty");
    assert_eq!(snaps[2].window_txns, 0);
}
